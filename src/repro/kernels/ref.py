"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernels are validated against
(tests/test_kernels.py sweeps shapes and dtypes with assert_allclose), and
the fallback path used by the models during CPU smoke tests and dry-runs.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.dot(a, b, preferred_element_type=a.dtype)


def _repeat_kv(k: jax.Array, group: int) -> jax.Array:
    """(batch, kv_heads, s, d) -> (batch, kv_heads*group, s, d)."""
    if group == 1:
        return k
    b, h, s, d = k.shape
    return jnp.broadcast_to(k[:, :, None], (b, h, group, s, d)).reshape(b, h * group, s, d)


def attention_ref(
    q: jax.Array,  # (batch, q_heads, q_seq, d)
    k: jax.Array,  # (batch, kv_heads, kv_seq, d)
    v: jax.Array,
    *,
    causal: bool = True,
    sm_scale: float | None = None,
    lengths: jax.Array | None = None,  # (batch,) valid kv prefix
    window: int | None = None,  # sliding-window size (None = full)
) -> jax.Array:
    """GQA attention oracle. Grouped einsum — the KV repeat is NEVER
    materialized (§Perf pick-3 iter-3: broadcasting the cache to q_heads in
    f32 cost 2x512 MiB all-gathers per layer per decode step)."""
    batch, q_heads, q_seq, d = q.shape
    _, kv_heads, kv_seq, _ = k.shape
    group = q_heads // kv_heads
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(d)
    qg = q.reshape(batch, kv_heads, group, q_seq, d)
    s = jnp.einsum(
        "bkgqd,bksd->bkgqs", qg.astype(jnp.float32), k.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ) * sm_scale
    q_pos = jnp.arange(q_seq)[:, None] + (kv_seq - q_seq)  # align ends (decode)
    k_pos = jnp.arange(kv_seq)[None, :]
    mask = jnp.ones((q_seq, kv_seq), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= q_pos - k_pos < window
    s = jnp.where(mask[None, None, None], s, -1e30)
    if lengths is not None:
        valid = k_pos < lengths[:, None, None]       # (batch, q_seq=1?, kv_seq)
        s = jnp.where(valid[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bksd->bkgqd", p, v.astype(jnp.float32))
    return out.reshape(batch, q_heads, q_seq, d).astype(q.dtype)


def decode_attention_ref(
    q: jax.Array,        # (batch, q_heads, 1, d)
    k_cache: jax.Array,  # (batch, kv_heads, S, d)
    v_cache: jax.Array,
    lengths: jax.Array,  # (batch,)
    *,
    sm_scale: float | None = None,
) -> jax.Array:
    return attention_ref(
        q, k_cache, v_cache, causal=False, sm_scale=sm_scale, lengths=lengths
    )
