"""Pallas API compatibility shims shared by all kernels (ROADMAP: Pallas
API dual-support).

jax < 0.6 names the TPU compiler-params container ``TPUCompilerParams``;
jax >= 0.6 renames it ``CompilerParams``. Every kernel imports the alias
from here instead of carrying its own copy; the supported jax range is
pinned in ``pyproject.toml`` and enforced by CI running the tier-1 suite.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

__all__ = ["CompilerParams"]
