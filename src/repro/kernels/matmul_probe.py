"""Pallas TPU matmul kernel — the Minos benchmark probe (paper §II-C, [10]).

The paper's CPU probe is a Go matrix multiplication; the TPU-native
adaptation is an MXU-tiled matmul with explicit VMEM BlockSpecs. Block
shapes default to (128, 128, 512): the MXU wants multiples of 128 in the
contracted and lane dimensions, and 3 blocks of 128x512 f32 ≈ 0.8 MB keeps
the working set comfortably inside the ~16 MB/core VMEM with room for
double-buffering.

Validated in interpret mode on CPU against ``ref.matmul_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams as _CompilerParams


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    """Grid = (M/bm, N/bn, K/bk); K is the innermost (sequential) axis so the
    f32 accumulator scratch carries across K steps."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret")
)
def matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """C = A @ B with explicit MXU tiling. Shapes must divide the blocks
    (the ops wrapper pads otherwise)."""
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"contraction mismatch {a.shape} @ {b.shape}")
    block_m = min(block_m, m)
    block_n = min(block_n, n)
    block_k = min(block_k, k)
    if m % block_m or n % block_n or k % block_k:
        raise ValueError(
            f"shapes ({m},{k})x({k},{n}) must divide blocks ({block_m},{block_n},{block_k})"
        )
    n_k = k // block_k
    grid = (m // block_m, n // block_n, n_k)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(a, b)
