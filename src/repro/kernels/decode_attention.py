"""Pallas TPU single-token decode attention kernel.

Decode (the ``decode_32k`` / ``long_500k`` shapes) computes attention of ONE
new query token against a long KV cache. Arithmetic intensity is O(1)
FLOP/byte — this kernel is memory-bound by design; its job is to stream the
cache through VMEM exactly once with block-level masking for the valid
prefix ``lengths``.

Variable cache occupancy is supported through scalar prefetch
(PrefetchScalarGridSpec): ``lengths[b]`` masks keys at positions >= length.
Fully-masked KV blocks are skipped with ``pl.when`` so short sequences in a
long cache don't pay for the whole stride.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams as _CompilerParams

_NEG_INF = -1e30


def _decode_kernel(
    lengths_ref,  # scalar-prefetch (batch,) int32
    q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
    *, sm_scale: float, block_k: int, n_kv: int, q_heads: int,
):
    h = pl.program_id(0)
    ik = pl.program_id(1)
    b = h // q_heads
    length = lengths_ref[b]

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(ik * block_k < length)
    def _step():
        q = q_ref[0]  # (1, d) — the single new token
        k = k_ref[0]  # (block_k, d)
        v = v_ref[0]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * sm_scale  # (1, block_k)
        k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(k_pos < length, s, _NEG_INF)
        m_prev = m_ref[...]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_cur[:, None])
        alpha = jnp.exp(m_prev - m_cur)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32
        )
        m_ref[...] = m_cur

    @pl.when(ik == n_kv - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-20)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("sm_scale", "block_k", "interpret")
)
def decode_attention(
    q: jax.Array,        # (batch, q_heads, 1, d)
    k_cache: jax.Array,  # (batch, kv_heads, S, d)
    v_cache: jax.Array,  # (batch, kv_heads, S, d)
    lengths: jax.Array,  # (batch,) int32 valid prefix per sequence
    *,
    sm_scale: float | None = None,
    block_k: int = 256,
    interpret: bool = True,
) -> jax.Array:
    batch, q_heads, one, d = q.shape
    if one != 1:
        raise ValueError("decode kernel expects exactly one query token")
    _, kv_heads, s_len, _ = k_cache.shape
    group = q_heads // kv_heads
    block_k = min(block_k, s_len)
    if s_len % block_k:
        raise ValueError(f"cache length {s_len} must divide block_k {block_k}")
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(d)
    n_kv = s_len // block_k

    qf = q.reshape(batch * q_heads, 1, d)
    kf = k_cache.reshape(batch * kv_heads, s_len, d)
    vf = v_cache.reshape(batch * kv_heads, s_len, d)

    def q_map(h, ik, lengths):
        return (h, 0, 0)

    def kv_map(h, ik, lengths):
        b = h // q_heads
        qh = h % q_heads
        return (b * kv_heads + qh // group, ik, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(batch * q_heads, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, d), q_map),
            pl.BlockSpec((1, block_k, d), kv_map),
            pl.BlockSpec((1, block_k, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, d), q_map),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(
            _decode_kernel,
            sm_scale=float(sm_scale),
            block_k=block_k,
            n_kv=n_kv,
            q_heads=q_heads,
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((batch * q_heads, 1, d), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(lengths.astype(jnp.int32), qf, kf, vf)
    return out.reshape(batch, q_heads, 1, d)
