"""Public jit'd wrappers around the Pallas kernels.

Handles padding to block multiples, dtype management, and the
interpret-mode switch: on CPU (this container) kernels execute via
``interpret=True`` — the kernel body runs in Python on CPU, proving
correctness; on TPU the same code lowers to Mosaic. ``use_pallas=False``
falls back to the pure-jnp oracle (used inside pjit'd model code where a
CPU-interpreted pallas_call cannot be SPMD-partitioned).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import ref as _ref
from .decode_attention import decode_attention as _decode_attention
from .flash_attention import flash_attention as _flash_attention
from .matmul_probe import matmul as _matmul

_ON_TPU = any(d.platform == "tpu" for d in jax.devices()) if jax.process_count() >= 0 else False
INTERPRET = not _ON_TPU


def _pad_to(x: jax.Array, axis: int, multiple: int) -> tuple[jax.Array, int]:
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


def matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    use_pallas: bool = True,
) -> jax.Array:
    """Tiled matmul; pads M/N/K up to block multiples then slices back."""
    if not use_pallas:
        return _ref.matmul_ref(a, b)
    m, k = a.shape
    _, n = b.shape
    bm, bn, bk = (min(block_m, m), min(block_n, n), min(block_k, k))
    # pallas wants divisibility; round blocks down to powers that fit, pad rest
    a, _ = _pad_to(a, 0, bm)
    a, _ = _pad_to(a, 1, bk)
    b, _ = _pad_to(b, 0, bk)
    b, _ = _pad_to(b, 1, bn)
    out = _matmul(a, b, block_m=bm, block_n=bn, block_k=bk, interpret=INTERPRET)
    return out[:m, :n]


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    sm_scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    use_pallas: bool = True,
) -> jax.Array:
    if not use_pallas:
        return _ref.attention_ref(q, k, v, causal=causal, sm_scale=sm_scale)
    q_seq, kv_seq = q.shape[2], k.shape[2]
    bq, bk = min(block_q, q_seq), min(block_k, kv_seq)
    if q_seq % bq or kv_seq % bk:
        # padding attention needs mask plumbing; oracle handles ragged shapes
        return _ref.attention_ref(q, k, v, causal=causal, sm_scale=sm_scale)
    return _flash_attention(
        q, k, v, causal=causal, sm_scale=sm_scale, block_q=bq, block_k=bk,
        interpret=INTERPRET,
    )


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    lengths: jax.Array,
    *,
    sm_scale: float | None = None,
    block_k: int = 256,
    use_pallas: bool = True,
) -> jax.Array:
    if not use_pallas:
        return _ref.decode_attention_ref(q, k_cache, v_cache, lengths, sm_scale=sm_scale)
    s_len = k_cache.shape[2]
    bk = min(block_k, s_len)
    if s_len % bk:
        return _ref.decode_attention_ref(q, k_cache, v_cache, lengths, sm_scale=sm_scale)
    return _decode_attention(
        q, k_cache, v_cache, lengths, sm_scale=sm_scale, block_k=bk, interpret=INTERPRET
    )


@functools.cache
def kernel_names() -> tuple[str, ...]:
    return ("matmul", "flash_attention", "decode_attention")
