"""Pallas TPU flash-attention (prefill) kernel.

Block-streaming online-softmax attention for the prefill path — the
compute hot-spot of the serving workload Minos gates. Causal masking and
GQA (q_heads >= kv_heads) are handled inside the kernel; the KV block index
map folds the head-group division so KV tiles are fetched once per group.

Grid: (batch * q_heads, q_seq / block_q, kv_seq / block_k), KV innermost so
the running max / sum / accumulator scratch carries across KV steps.
VMEM working set per step ≈ block_q*d + 2*block_k*d + block_q*block_k
floats — (128, 128, d=128) f32 ≈ 0.25 MB, far under VMEM.

Causal skip: for q-block i, KV blocks strictly after the diagonal are
skipped via ``pl.when`` (no FLOPs, no scratch update), the standard TPU
flash-attention trick.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams as _CompilerParams

_NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
    *, causal: bool, sm_scale: float, block_q: int, block_k: int, n_kv: int,
):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def _step():
        q = q_ref[0]  # (block_q, d)
        k = k_ref[0]  # (block_k, d)
        v = v_ref[0]  # (block_k, d)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * sm_scale
        if causal:
            q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(q_pos >= k_pos, s, _NEG_INF)
        m_prev = m_ref[...]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_cur[:, None])
        alpha = jnp.exp(m_prev - m_cur)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32
        )
        m_ref[...] = m_cur

    if causal:
        # skip fully-masked KV blocks above the diagonal
        pl.when(ik * block_k <= iq * block_q + block_q - 1)(_step)
    else:
        _step()

    @pl.when(ik == n_kv - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-20)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "sm_scale", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jax.Array,  # (batch, q_heads, q_seq, d)
    k: jax.Array,  # (batch, kv_heads, kv_seq, d)
    v: jax.Array,  # (batch, kv_heads, kv_seq, d)
    *,
    causal: bool = True,
    sm_scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    batch, q_heads, q_seq, d = q.shape
    _, kv_heads, kv_seq, _ = k.shape
    if q_heads % kv_heads:
        raise ValueError(f"q_heads {q_heads} not a multiple of kv_heads {kv_heads}")
    group = q_heads // kv_heads
    block_q = min(block_q, q_seq)
    block_k = min(block_k, kv_seq)
    if q_seq % block_q or kv_seq % block_k:
        raise ValueError(f"seq ({q_seq},{kv_seq}) must divide blocks ({block_q},{block_k})")
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(d)
    n_kv = kv_seq // block_k

    # fold (batch, heads) into one grid axis
    qf = q.reshape(batch * q_heads, q_seq, d)
    kf = k.reshape(batch * kv_heads, kv_seq, d)
    vf = v.reshape(batch * kv_heads, kv_seq, d)

    def q_map(h, iq, ik):
        return (h, iq, 0)

    def kv_map(h, iq, ik):
        # GQA: query head h uses kv head (h % q_heads) // group within batch
        b = h // q_heads
        qh = h % q_heads
        return (b * kv_heads + qh // group, ik, 0)

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel,
            causal=causal,
            sm_scale=float(sm_scale),
            block_q=block_q,
            block_k=block_k,
            n_kv=n_kv,
        ),
        grid=(batch * q_heads, q_seq // block_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, block_q, d), q_map),
            pl.BlockSpec((1, block_k, d), kv_map),
            pl.BlockSpec((1, block_k, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), q_map),
        out_shape=jax.ShapeDtypeStruct((batch * q_heads, q_seq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(batch, q_heads, q_seq, d)
