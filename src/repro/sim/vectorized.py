"""Vectorized Monte-Carlo fast path for the single-stage Minos model
(DESIGN.md §11).

Every headline number in this repo comes from Monte-Carlo sweeps over the
pure-Python event engine, which runs seeds one at a time through a
heapq-callback loop — wide grids (pass-fraction × σ × platform × gate) are
unaffordable there. This module expresses the paper's *single-stage* loop —
cold start → probe → elysium gate → requeue-with-penalty → warm reuse with
AR(1) contention drift and diurnal speed, Fig-3 billing — as one
``lax.scan`` over invocation steps, ``vmap``-ed over (arms × seeds), so
thousands of parameter arms run as a single XLA program
(``benchmarks/grid_sweep.py`` measures the speedup; the parity bounds live
in tests/test_vectorized_parity.py).

Model scope — what the fast path deliberately is:

* a **closed-loop single request stream** (the event engine at
  ``n_vus=1``): each scan step is one invocation driven to completion,
  think time between steps. Per-instance request concurrency, the
  load-slowdown curve, and load-aware gating therefore never engage.
* the classic decision stack only: gate off (baseline), a fixed elysium
  threshold, or the §IV adaptive policy (P² quantile + EMA republish,
  the exact :class:`~repro.core.policy.AdaptiveMinosPolicy` estimator,
  running on-device via :class:`~repro.core.estimators.P2State`).
  Workflows, serving bodies, admission control, re-probing and the other
  control-plane handlers stay on the event engine.
* a fixed-capacity array pool: LIFO/FIFO/spread reuse orders are gather
  indices over (validity-masked) slot arrays; idle-timeout and exponential
  recycle deadlines reclaim slots exactly where the event pool would.

On-device estimates reuse the JAX estimator states from
:mod:`repro.core.estimators`: :class:`WelfordState` folds probe /
log-probe / body / latency streams inside the scan (what
``SubstrateEngine`` maintains for Telemetry), and :class:`P2State` + EMA
maintain the adaptive threshold.

Everything is float32; latencies are accumulated as durations (never as
differences of large absolute times), so precision holds over long
horizons. Deterministic per (seed, arm index).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cost import Pricing
from repro.core.estimators import (
    P2State,
    WelfordState,
    p2_init,
    p2_update,
    p2_value,
    welford_init,
    welford_merge,
    welford_std,
    welford_update,
    welford_update_masked,
)

GATE_OFF = 0        # baseline arm: every instance accepted unjudged
GATE_FIXED = 1      # pre-tested elysium threshold (paper §III-A)
GATE_ADAPTIVE = 2   # §IV online threshold: P² quantile + EMA republish

ORDER_CODES = {"lifo": 0, "fifo": 1, "spread": 2}


class ArmParams(NamedTuple):
    """One parameter arm — every leaf a float32 scalar (stack arms along
    axis 0 with :func:`stack_arms` for the vmapped grid)."""

    # variation model
    sigma: Any
    day_factor: Any
    diurnal_amplitude: Any
    diurnal_phase_h: Any
    # function spec (unit-speed durations + noise scales)
    prepare_ms: Any
    prepare_jitter: Any
    body_ms: Any
    body_jitter: Any
    benchmark_ms: Any
    benchmark_noise: Any
    contention_rho: Any
    # hosting knobs
    cold_start_ms: Any
    cold_start_jitter: Any
    idle_timeout_ms: Any
    recycle_lifetime_ms: Any   # inf = never recycled
    bill_cold_start: Any       # 0.0 / 1.0
    requeue_overhead_ms: Any
    requeue_penalty_ms: Any    # backend migration penalty (sim backend: 0)
    order: Any                 # 0 lifo / 1 fifo / 2 spread (int32)
    # gate
    gate_mode: Any             # GATE_OFF / GATE_FIXED / GATE_ADAPTIVE (int32)
    threshold: Any             # fixed elysium threshold (GATE_FIXED)
    pass_fraction: Any         # adaptive quantile (GATE_ADAPTIVE)
    max_retries: Any           # emergency-exit bound (int32)
    warmup_reports: Any        # adaptive warm-up (int32)
    republish_every: Any       # adaptive EMA republish cadence (int32)
    smoothing_alpha: Any       # adaptive EMA smoothing
    # workload + pricing
    think_time_ms: Any
    cost_per_invocation: Any
    cost_per_ms: Any


@dataclasses.dataclass(frozen=True)
class SimConfig:
    """Static (compile-time) shape of one vectorized run."""

    n_steps: int
    # One slot is exact for the single-stream model: a cold start only
    # happens when NO pooled instance is valid (so every slot is dead and
    # placement reuses slot 0), and a warm serve rewrites its own slot —
    # the pool can never hold two live instances. K>1 is kept for future
    # multi-stream extensions.
    pool_size: int = 1
    max_attempts: int = 6      # must exceed every arm's max_retries
    collect_requests: bool = False
    adaptive: bool = True      # False: no arm uses GATE_ADAPTIVE — skip P²
    diurnal: bool = True       # False: every arm has amplitude 0 — skip cos


class _ColdResult(NamedTuple):
    """Outcome of the cold retry chain for one step (scalars per lane)."""

    elapsed: Any      # ms burned by failed attempts (cold+probe+requeue)
    retries: Any      # failed attempts (i32)
    log_speed: Any    # accepted instance's hidden speed (log)
    cold_ms: Any      # accepted attempt's cold-start duration
    ready_ms: Any     # max(prepare, probe) — body start offset
    analysis_ms: Any  # accepted attempt's body duration
    place_rel: Any    # accepted instance's placement time (rel. to step start)
    n_term: Any
    d_term: Any
    probe_w: WelfordState      # probe durations
    log_probe_w: WelfordState  # log probe durations (lognormal fit)
    p2: Any                    # P2State | None
    ema: Any
    ema_init: Any
    since_publish: Any
    n_probes: Any


class _Pool(NamedTuple):
    """Fixed-capacity warm pool as K tuples of per-lane scalars.

    Tuple-of-scalars instead of (K,) arrays: every pool operation
    (validity, reuse-order tournament, placement) is then an unrolled
    chain of elementwise selects, which XLA fuses into the surrounding
    step kernel — batched gathers/argmax/scatter over a (K,) axis each
    cost a separate kernel pass on CPU, and the profiler showed those
    passes dominating the sweep wall-clock."""

    log_speed: tuple   # log-space: AR(1) drift needs no log/exp
    last_used: tuple
    recycle: tuple     # absolute deadline (inf = never)
    alive: tuple


class VecState(NamedTuple):
    t: Any                       # absolute sim time (ms)
    pool: _Pool
    probe_w: WelfordState        # cold probe durations
    log_probe_w: WelfordState    # log of the same (lognormal fit)
    body_w: WelfordState         # observed body durations
    latency_w: WelfordState      # request latencies
    reuse_w: WelfordState        # 1.0 warm-served / 0.0 cold-served
    p2: Any                      # P2State | None (pruned when not adaptive)
    ema: Any
    ema_init: Any
    since_publish: Any
    n_probes: Any
    n_started: Any
    n_terminated: Any
    nb_term: Any                 # Fig-3 billing terms, six scalars
    nb_pass: Any
    nb_reuse: Any
    db_term: Any
    db_pass: Any
    db_reuse: Any


def _diurnal(t_ms, amplitude, phase_h):
    hour = (t_ms / 3.6e6) % 24.0
    return 1.0 + amplitude * jnp.cos(2.0 * jnp.pi * (hour - phase_h) / 24.0)


def _wsel(mask, new, old):
    return jax.tree_util.tree_map(
        lambda a, b: jnp.where(mask, a, b), new, old)


def _attempt_values(params: ArmParams, consts, su, J, day_mean, log_day, i):
    """Attempt ``i``'s sampled quantities from the pre-scaled draw row.

    Draw layout per attempt (base b=3+5i): z0 instance speed, z1 cold
    start, z2 prepare, z3 probe observation noise, z4 body. ``J=exp(su)``
    was computed in one vectorized exp, so everything here is
    multiply/add: speed = exp(σz0)·day_mean, probe = B·exp(bn·z3)/speed,
    body = body_ms·exp(bj·z4)/speed."""
    b = 3 + 5 * i
    cold = params.cold_start_ms * J[b + 1]
    download = params.prepare_ms * J[b + 2]
    inv_speed_rel = J[b + 3] / J[b]
    bench = (params.benchmark_ms / day_mean) * inv_speed_rel
    log_bench = consts["log_bench_ms"] + su[b + 3] - su[b] - log_day
    analysis = (params.body_ms / day_mean) * (J[b + 4] / J[b])
    log_speed = su[b] + log_day
    return cold, download, bench, log_bench, analysis, log_speed


def _cold_chain_fixed(params, cfg, consts, su, J, day_mean, log_day,
                      served_cold, state) -> _ColdResult:
    """The retry chain for attempt-invariant gates (off / fixed
    threshold): an unrolled chain of scalar selects — no P², no
    sequential estimator feedback — the grid sweep's hot path."""
    f32 = jnp.float32
    z = jnp.zeros((), f32)
    pending = served_cold
    thr = jnp.where(params.gate_mode == GATE_FIXED, params.threshold, jnp.inf)
    elapsed = z
    retries = jnp.zeros((), jnp.int32)
    n_term = z
    d_term = z
    cb = z
    s_b = z
    s_b2 = z
    s_lb = z
    s_lb2 = z
    acc_cold = z
    acc_ready = z
    acc_body = z
    acc_logsp = z
    acc_place = z
    for i in range(cfg.max_attempts):
        cold, download, bench, log_bench, analysis, log_speed = \
            _attempt_values(params, consts, su, J, day_mean, log_day, i)
        probed = (params.gate_mode > 0) & (i < params.max_retries)
        passes = (~probed) | (bench <= thr)
        feed = jnp.asarray(pending & probed, f32)
        accept = pending & passes
        fail = jnp.asarray(pending & ~passes, f32)
        # batched Welford moments of this step's probe stream (merged
        # below via Chan — exact up to FP association order)
        cb = cb + feed
        s_b = s_b + feed * bench
        s_b2 = s_b2 + feed * bench * bench
        s_lb = s_lb + feed * log_bench
        s_lb2 = s_lb2 + feed * log_bench * log_bench
        ready = jnp.where(probed, jnp.maximum(download, bench), download)
        acc_cold = jnp.where(accept, cold, acc_cold)
        acc_ready = jnp.where(accept, ready, acc_ready)
        acc_body = jnp.where(accept, analysis, acc_body)
        acc_logsp = jnp.where(accept, log_speed, acc_logsp)
        acc_place = jnp.where(accept, elapsed, acc_place)
        n_term = n_term + fail
        d_term = d_term + fail * (params.bill_cold_start * cold + bench)
        elapsed = elapsed + fail * (cold + bench + params.requeue_overhead_ms
                                    + params.requeue_penalty_ms)
        retries = retries + jnp.asarray(pending & ~passes, jnp.int32)
        pending = pending & ~passes

    def merged(w: WelfordState, s, s2) -> WelfordState:
        mean_b = s / jnp.maximum(cb, 1.0)
        m2_b = jnp.maximum(s2 - cb * mean_b * mean_b, 0.0)
        return welford_merge(w, WelfordState(count=cb, mean=mean_b, m2=m2_b))

    return _ColdResult(
        elapsed=elapsed, retries=retries, log_speed=acc_logsp,
        cold_ms=acc_cold, ready_ms=acc_ready, analysis_ms=acc_body,
        place_rel=acc_place, n_term=n_term, d_term=d_term,
        probe_w=merged(state.probe_w, s_b, s_b2),
        log_probe_w=merged(state.log_probe_w, s_lb, s_lb2),
        p2=state.p2, ema=state.ema, ema_init=state.ema_init,
        since_publish=state.since_publish,
        n_probes=state.n_probes + cb.astype(jnp.int32),
    )


def _cold_chain_adaptive(params, cfg, consts, su, J, day_mean, log_day,
                         served_cold, state) -> _ColdResult:
    """The retry chain when the §IV adaptive threshold is live: every
    probed attempt reports to the on-device P² quantile + EMA republish
    (the exact :class:`~repro.core.policy.AdaptiveMinosPolicy` estimator)
    BEFORE being judged, so attempts are sequential within the step."""
    f32 = jnp.float32
    z = jnp.zeros((), f32)
    c = _ColdResult(
        elapsed=z, retries=jnp.zeros((), jnp.int32), log_speed=z,
        cold_ms=z, ready_ms=z, analysis_ms=z, place_rel=z,
        n_term=z, d_term=z,
        probe_w=state.probe_w, log_probe_w=state.log_probe_w,
        p2=state.p2, ema=state.ema, ema_init=state.ema_init,
        since_publish=state.since_publish, n_probes=state.n_probes,
    )
    pending = served_cold
    for i in range(cfg.max_attempts):
        cold, download, bench, log_bench, analysis, log_speed = \
            _attempt_values(params, consts, su, J, day_mean, log_day, i)
        probed = (params.gate_mode > 0) & (i < params.max_retries)
        feed = pending & probed
        probe_w = welford_update_masked(c.probe_w, bench, feed)
        log_probe_w = welford_update_masked(c.log_probe_w, log_bench, feed)
        n_probes = c.n_probes + jnp.asarray(feed, jnp.int32)
        p2 = _wsel(feed, p2_update(c.p2, bench), c.p2)
        since = c.since_publish + jnp.asarray(feed, jnp.int32)
        publish = feed & (since >= params.republish_every)
        p2v = p2_value(p2)
        ema = jnp.where(
            publish,
            jnp.where(c.ema_init,
                      params.smoothing_alpha * p2v
                      + (1.0 - params.smoothing_alpha) * c.ema,
                      p2v),
            c.ema)
        ema_init = c.ema_init | publish
        since = jnp.where(publish, 0, since)
        thr_adaptive = jnp.where(
            n_probes >= params.warmup_reports,
            jnp.where(ema_init, ema, p2v), jnp.inf)
        thr = jnp.where(params.gate_mode == GATE_FIXED, params.threshold,
                        jnp.where(params.gate_mode == GATE_ADAPTIVE,
                                  thr_adaptive, jnp.inf))
        passes = (~probed) | (bench <= thr)
        accept = pending & passes
        fail = pending & ~passes
        failf = jnp.asarray(fail, f32)
        ready = jnp.where(probed, jnp.maximum(download, bench), download)
        c = _ColdResult(
            elapsed=c.elapsed + failf * (cold + bench
                                         + params.requeue_overhead_ms
                                         + params.requeue_penalty_ms),
            retries=c.retries + jnp.asarray(fail, jnp.int32),
            log_speed=jnp.where(accept, log_speed, c.log_speed),
            cold_ms=jnp.where(accept, cold, c.cold_ms),
            ready_ms=jnp.where(accept, ready, c.ready_ms),
            analysis_ms=jnp.where(accept, analysis, c.analysis_ms),
            place_rel=jnp.where(accept, c.elapsed, c.place_rel),
            n_term=c.n_term + failf,
            d_term=c.d_term + failf * (params.bill_cold_start * cold + bench),
            probe_w=probe_w, log_probe_w=log_probe_w,
            p2=p2, ema=ema, ema_init=ema_init, since_publish=since,
            n_probes=n_probes,
        )
        pending = pending & ~passes
    return c


def _step(params: ArmParams, cfg: SimConfig, consts: dict,
          state: VecState, draws):
    f32 = jnp.float32
    K = cfg.pool_size
    u, ex = draws
    # one vectorized exp covers every lognormal factor of the step
    # (scale<=0 gives exactly exp(0)=1, preserving sample_jitter's
    # disabled-noise contract)
    su = u * consts["scale_vec"]
    J = jnp.exp(su)
    t0 = state.t
    if cfg.diurnal:
        dv = _diurnal(t0, params.diurnal_amplitude, params.diurnal_phase_h)
        day_mean = params.day_factor * dv
        log_day = consts["log_df"] + jnp.log(dv)
    else:
        day_mean = params.day_factor
        log_day = consts["log_df"]

    # ---- warm take: unrolled validity + reuse-order tournament ---------
    pool = state.pool
    valid = [pool.alive[k]
             & ((t0 - pool.last_used[k]) <= params.idle_timeout_ms)
             & (t0 < pool.recycle[k])
             for k in range(K)]
    any_warm = valid[0]
    for k in range(1, K):
        any_warm = any_warm | valid[k]
    served_cold = ~any_warm
    # lifo takes the most recently used valid slot, fifo/spread the
    # oldest (single-stream: pooled loads are all 0, so spread's
    # least-loaded order degenerates to fifo) — maximize a signed score
    sign = jnp.where(params.order == 0, 1.0, -1.0)
    ninf = jnp.asarray(-jnp.inf, f32)
    score = [jnp.where(valid[k], sign * pool.last_used[k], ninf)
             for k in range(K)]
    oh = [None] * K
    oh[0] = score[0] >= ninf  # True; same dtype/shape as the other flags
    best = score[0]
    for k in range(1, K):
        take = score[k] > best
        best = jnp.where(take, score[k], best)
        for j in range(k):
            oh[j] = oh[j] & ~take
        oh[k] = take
    log_i = pool.log_speed[0]
    rc_i = pool.recycle[0]
    for k in range(1, K):
        log_i = jnp.where(oh[k], pool.log_speed[k], log_i)
        rc_i = jnp.where(oh[k], pool.recycle[k], rc_i)

    # ---- warm path: AR(1) drift (pure log-space arithmetic) ------------
    rho = params.contention_rho
    log_drifted = jnp.where(
        rho >= 1.0, log_i,
        log_day + rho * (log_i - log_day)
        + jnp.sqrt(jnp.maximum(1.0 - rho * rho, 0.0)) * su[0])
    download_w = params.prepare_ms * J[1]
    analysis_w = params.body_ms * J[2] * jnp.exp(-log_drifted)
    dur_w = download_w + analysis_w

    # ---- cold path -----------------------------------------------------
    chain = _cold_chain_adaptive if cfg.adaptive else _cold_chain_fixed
    c = chain(params, cfg, consts, su, J, day_mean, log_day,
              served_cold, state)

    # ---- merge warm/cold outcomes --------------------------------------
    analysis = jnp.where(served_cold, c.analysis_ms, analysis_w)
    latency = jnp.where(
        served_cold, c.elapsed + c.cold_ms + c.ready_ms + c.analysis_ms, dur_w)
    billed_final = jnp.where(
        served_cold,
        params.bill_cold_start * c.cold_ms + c.ready_ms + c.analysis_ms,
        dur_w)
    t_end = t0 + latency
    log_speed_served = jnp.where(served_cold, c.log_speed, log_drifted)

    # ---- pool update (unrolled one-hot blend) --------------------------
    # A cold start implies every slot failed validity (all dead), so cold
    # placement always lands in slot 0; a warm serve rewrites its own slot.
    # inf lifetime (no platform recycling) must stay inf even when the
    # exponential draw is exactly 0.0 (0·inf = NaN would kill the slot)
    recycle_new = (t0 + c.place_rel) + jnp.where(
        jnp.isinf(params.recycle_lifetime_ms), jnp.inf,
        ex * params.recycle_lifetime_ms)
    recycle_upd = jnp.where(served_cold, recycle_new, rc_i)
    upd = [served_cold | oh[0]] + [~served_cold & oh[k] for k in range(1, K)]
    new_pool = _Pool(
        log_speed=tuple(
            jnp.where(upd[k], log_speed_served, pool.log_speed[k])
            for k in range(K)),
        last_used=tuple(
            jnp.where(upd[k], t_end, pool.last_used[k]) for k in range(K)),
        recycle=tuple(
            jnp.where(upd[k], recycle_upd, pool.recycle[k])
            for k in range(K)),
        alive=tuple(valid[k] | upd[k] for k in range(K)),
    )

    # ---- Fig-3 billing + telemetry estimators --------------------------
    coldf = jnp.asarray(served_cold, f32)
    warmf = jnp.asarray(any_warm, f32)
    new_state = VecState(
        t=t_end + params.think_time_ms,
        pool=new_pool,
        probe_w=c.probe_w, log_probe_w=c.log_probe_w,
        body_w=welford_update(state.body_w, analysis),
        latency_w=welford_update(state.latency_w, latency),
        reuse_w=welford_update(state.reuse_w, warmf),
        p2=c.p2, ema=c.ema, ema_init=c.ema_init,
        since_publish=c.since_publish, n_probes=c.n_probes,
        n_started=state.n_started + coldf * (
            jnp.asarray(c.retries, f32) + 1.0),
        n_terminated=state.n_terminated + c.n_term,
        nb_term=state.nb_term + c.n_term,
        nb_pass=state.nb_pass + coldf,
        nb_reuse=state.nb_reuse + warmf,
        db_term=state.db_term + c.d_term,
        db_pass=state.db_pass + coldf * billed_final,
        db_reuse=state.db_reuse + warmf * billed_final,
    )
    if cfg.collect_requests:
        out = {
            "latency_ms": latency,
            "analysis_ms": analysis,
            "billed_ms": coldf * c.d_term + billed_final,
            "served_by_cold": served_cold,
            "retries": jnp.where(served_cold, c.retries, 0),
            "instance_speed": jnp.exp(log_speed_served),
        }
    else:
        out = None
    return new_state, out


def _simulate_chain(params: ArmParams, key, cfg: SimConfig):
    f32 = jnp.float32
    K = cfg.pool_size
    ma = cfg.max_attempts
    k_normal, k_exp = jax.random.split(key)
    u_all = jax.random.normal(k_normal, (cfg.n_steps, 3 + 5 * ma), f32)
    ex_all = jax.random.exponential(k_exp, (cfg.n_steps,), f32)
    # Draw layout: u[0] warm drift, u[1] warm prepare, u[2] warm body;
    # attempt i at base 3+5i: z0 speed, z1 cold, z2 prepare, z3 probe
    # noise, z4 body — scale_vec turns the whole row into log-factors.
    pj, bj = params.prepare_jitter, params.body_jitter
    cj, bn, sg = params.cold_start_jitter, params.benchmark_noise, params.sigma
    consts = {
        "scale_vec": jnp.stack([sg, pj, bj] + [sg, cj, pj, bn, bj] * ma),
        "log_df": jnp.log(params.day_factor),
        "log_bench_ms": jnp.log(params.benchmark_ms),
    }
    z = jnp.zeros((), f32)
    state = VecState(
        t=z,
        pool=_Pool(
            log_speed=(z,) * K,
            last_used=(z,) * K,
            recycle=(jnp.asarray(jnp.inf, f32),) * K,
            alive=(jnp.zeros((), bool),) * K,
        ),
        probe_w=welford_init(), log_probe_w=welford_init(),
        body_w=welford_init(), latency_w=welford_init(),
        reuse_w=welford_init(),
        # None prunes the adaptive estimator from the scan carry entirely
        # when no arm needs it (pytree None = empty subtree)
        p2=p2_init(params.pass_fraction) if cfg.adaptive else None,
        ema=z if cfg.adaptive else None,
        ema_init=jnp.zeros((), bool) if cfg.adaptive else None,
        since_publish=jnp.zeros((), jnp.int32) if cfg.adaptive else None,
        n_probes=jnp.zeros((), jnp.int32),
        n_started=z, n_terminated=z,
        nb_term=z, nb_pass=z, nb_reuse=z,
        db_term=z, db_pass=z, db_reuse=z,
    )
    final, requests = jax.lax.scan(
        lambda s, x: _step(params, cfg, consts, s, x), state,
        (u_all, ex_all), unroll=1 if cfg.adaptive else 4)
    cost = params.cost_per_ms * (final.db_term + final.db_pass
                                 + final.db_reuse) \
        + params.cost_per_invocation * (final.nb_term + final.nb_pass
                                        + final.nb_reuse)
    summary = {
        "n_requests": jnp.asarray(cfg.n_steps, f32),
        "n_started": final.n_started,
        "n_terminated": final.n_terminated,
        "n_probes": jnp.asarray(final.n_probes, f32),
        "reuse_rate": final.reuse_w.mean,
        "mean_analysis_ms": final.body_w.mean,
        "std_analysis_ms": welford_std(final.body_w),
        "mean_latency_ms": final.latency_w.mean,
        "probe_mean_ms": final.probe_w.mean,
        "probe_log_mean": final.log_probe_w.mean,
        "probe_log_std": welford_std(final.log_probe_w),
        "pass_rate": 1.0 - final.n_terminated
        / jnp.maximum(jnp.asarray(final.n_probes, f32), 1.0),
        "bill_n": jnp.stack([final.nb_term, final.nb_pass, final.nb_reuse]),
        "bill_d": jnp.stack([final.db_term, final.db_pass, final.db_reuse]),
        "cost": cost,
        "horizon_ms": final.t,
    }
    return summary, requests
# ---------------------------------------------------------------------------
# Open-loop (arrival-driven) scan — DESIGN.md §12
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class OpenSimConfig:
    """Static shape of one open-loop vectorized run.

    ``n_servers`` is the autoscaling supply cap (the event engine's
    ``SubstrateKnobs.max_instances``): K server slots, each carrying its
    own busy-until horizon. Scope: the scan is drop-free (no finite queue
    buffer) and processes arrivals in order — each arrival takes the
    earliest available slot, which IS the FIFO M/G/K queue; drop/defer
    dynamics stay on the event engine (DESIGN.md §12)."""

    n_steps: int
    n_servers: int = 4
    max_attempts: int = 6
    collect_requests: bool = False
    adaptive: bool = True
    diurnal: bool = True


class OpenState(NamedTuple):
    """Scan carry for the open-loop variant. The estimator tail
    (probe_w … n_probes) duck-types :class:`VecState`, so the cold retry
    chain helpers run unchanged on either carry."""

    t_arr: Any                   # previous arrival's absolute time
    busy: tuple                  # per-slot busy-until horizon
    log_speed: tuple
    last_used: tuple             # per-slot last completion time
    recycle: tuple               # absolute recycle deadline (inf = never)
    alive: tuple
    probe_w: WelfordState
    log_probe_w: WelfordState
    body_w: WelfordState
    latency_w: WelfordState
    wait_w: WelfordState         # queue waits (the open-loop metric)
    reuse_w: WelfordState
    p2: Any
    ema: Any
    ema_init: Any
    since_publish: Any
    n_probes: Any
    n_started: Any
    n_terminated: Any
    nb_term: Any
    nb_pass: Any
    nb_reuse: Any
    db_term: Any
    db_pass: Any
    db_reuse: Any


def _open_step(params: ArmParams, cfg: OpenSimConfig, consts: dict,
               state: OpenState, draws):
    f32 = jnp.float32
    K = cfg.n_servers
    u, ex, iat = draws
    su = u * consts["scale_vec"]
    J = jnp.exp(su)
    t_arr = state.t_arr + iat

    # ---- slot availability at arrival time -----------------------------
    free = [state.busy[k] <= t_arr for k in range(K)]
    valid = [state.alive[k] & free[k]
             & ((t_arr - state.last_used[k]) <= params.idle_timeout_ms)
             & (t_arr < state.recycle[k])
             for k in range(K)]
    any_valid = valid[0]
    any_free = free[0]
    for k in range(1, K):
        any_valid = any_valid | valid[k]
        any_free = any_free | free[k]

    # case A — warm now: reuse-order tournament among valid slots
    # (lifo: most recently used; fifo/spread: oldest — concurrency is 1
    # per slot here, so spread degenerates to fifo exactly as in _step)
    sign = jnp.where(params.order == 0, 1.0, -1.0)
    ninf = jnp.asarray(-jnp.inf, f32)
    score = [jnp.where(valid[k], sign * state.last_used[k], ninf)
             for k in range(K)]
    oh_a = [None] * K
    oh_a[0] = score[0] >= ninf
    best_a = score[0]
    for k in range(1, K):
        take = score[k] > best_a
        best_a = jnp.where(take, score[k], best_a)
        for j in range(k):
            oh_a[j] = oh_a[j] & ~take
        oh_a[k] = take

    # case B — no valid warm slot but a free one exists (dead or
    # idle/recycle-expired): cold start now, into the first free slot
    oh_b = [None] * K
    oh_b[0] = free[0]
    taken = free[0]
    for k in range(1, K):
        oh_b[k] = free[k] & ~taken
        taken = taken | free[k]

    # case C — every slot busy: wait for the earliest completion; the
    # freed slot serves this arrival (warm unless its recycle deadline
    # passed while it was busy — idle gap is zero by construction)
    oh_c = [None] * K
    oh_c[0] = jnp.ones((), bool)
    best_c = state.busy[0]
    for k in range(1, K):
        take = state.busy[k] < best_c
        best_c = jnp.where(take, state.busy[k], best_c)
        for j in range(k):
            oh_c[j] = oh_c[j] & ~take
        oh_c[k] = take

    case_a = any_valid
    case_b = ~any_valid & any_free
    case_c = ~any_free
    t_start = jnp.where(case_c, jnp.maximum(best_c, t_arr), t_arr)
    wait = t_start - t_arr

    # the serving slot's one-hot + the warm-path speed/recycle it carries
    upd = [(case_a & oh_a[k]) | (case_b & oh_b[k]) | (case_c & oh_c[k])
           for k in range(K)]
    log_i = jnp.zeros((), f32)
    rc_keep = jnp.zeros((), f32)
    rc_c = jnp.zeros((), f32)
    for k in range(K):
        sel_a = case_a & oh_a[k]
        sel_c = case_c & oh_c[k]
        log_i = jnp.where(sel_a | sel_c, state.log_speed[k], log_i)
        rc_keep = jnp.where(sel_a | sel_c, state.recycle[k], rc_keep)
        rc_c = jnp.where(oh_c[k], state.recycle[k], rc_c)
    recycled_c = case_c & (t_start >= rc_c)
    served_cold = case_b | recycled_c
    any_warm = ~served_cold

    if cfg.diurnal:
        dv = _diurnal(t_start, params.diurnal_amplitude, params.diurnal_phase_h)
        day_mean = params.day_factor * dv
        log_day = consts["log_df"] + jnp.log(dv)
    else:
        day_mean = params.day_factor
        log_day = consts["log_df"]

    # ---- warm path: AR(1) drift, prepare + body ------------------------
    rho = params.contention_rho
    log_drifted = jnp.where(
        rho >= 1.0, log_i,
        log_day + rho * (log_i - log_day)
        + jnp.sqrt(jnp.maximum(1.0 - rho * rho, 0.0)) * su[0])
    download_w = params.prepare_ms * J[1]
    analysis_w = params.body_ms * J[2] * jnp.exp(-log_drifted)
    dur_w = download_w + analysis_w

    # ---- cold path: the shared retry chain -----------------------------
    chain = _cold_chain_adaptive if cfg.adaptive else _cold_chain_fixed
    c = chain(params, cfg, consts, su, J, day_mean, log_day,
              served_cold, state)

    # ---- merge + slot update -------------------------------------------
    analysis = jnp.where(served_cold, c.analysis_ms, analysis_w)
    service = jnp.where(
        served_cold, c.elapsed + c.cold_ms + c.ready_ms + c.analysis_ms, dur_w)
    latency = wait + service
    billed_final = jnp.where(
        served_cold,
        params.bill_cold_start * c.cold_ms + c.ready_ms + c.analysis_ms,
        dur_w)
    t_end = t_start + service
    log_speed_served = jnp.where(served_cold, c.log_speed, log_drifted)
    recycle_new = (t_start + c.place_rel) + jnp.where(
        jnp.isinf(params.recycle_lifetime_ms), jnp.inf,
        ex * params.recycle_lifetime_ms)
    recycle_upd = jnp.where(served_cold, recycle_new, rc_keep)

    new_state = OpenState(
        t_arr=t_arr,
        busy=tuple(jnp.where(upd[k], t_end, state.busy[k]) for k in range(K)),
        log_speed=tuple(
            jnp.where(upd[k], log_speed_served, state.log_speed[k])
            for k in range(K)),
        last_used=tuple(
            jnp.where(upd[k], t_end, state.last_used[k]) for k in range(K)),
        recycle=tuple(
            jnp.where(upd[k], recycle_upd, state.recycle[k])
            for k in range(K)),
        alive=tuple(state.alive[k] | upd[k] for k in range(K)),
        probe_w=c.probe_w, log_probe_w=c.log_probe_w,
        body_w=welford_update(state.body_w, analysis),
        latency_w=welford_update(state.latency_w, latency),
        wait_w=welford_update(state.wait_w, wait),
        reuse_w=welford_update(state.reuse_w, jnp.asarray(any_warm, f32)),
        p2=c.p2, ema=c.ema, ema_init=c.ema_init,
        since_publish=c.since_publish, n_probes=c.n_probes,
        n_started=state.n_started + jnp.asarray(served_cold, f32) * (
            jnp.asarray(c.retries, f32) + 1.0),
        n_terminated=state.n_terminated + c.n_term,
        nb_term=state.nb_term + c.n_term,
        nb_pass=state.nb_pass + jnp.asarray(served_cold, f32),
        nb_reuse=state.nb_reuse + jnp.asarray(any_warm, f32),
        db_term=state.db_term + c.d_term,
        db_pass=state.db_pass + jnp.asarray(served_cold, f32) * billed_final,
        db_reuse=state.db_reuse + jnp.asarray(any_warm, f32) * billed_final,
    )
    if cfg.collect_requests:
        out = {
            "latency_ms": latency,
            "wait_ms": wait,
            "analysis_ms": analysis,
            "billed_ms": jnp.asarray(served_cold, f32) * c.d_term + billed_final,
            "served_by_cold": served_cold,
            "retries": jnp.where(served_cold, c.retries, 0),
            "t_completed_ms": t_end,
        }
    else:
        out = None
    return new_state, out


def _simulate_open_chain(params: ArmParams, key, cfg: OpenSimConfig, iats):
    f32 = jnp.float32
    K = cfg.n_servers
    ma = cfg.max_attempts
    k_normal, k_exp = jax.random.split(key)
    u_all = jax.random.normal(k_normal, (cfg.n_steps, 3 + 5 * ma), f32)
    ex_all = jax.random.exponential(k_exp, (cfg.n_steps,), f32)
    pj, bj = params.prepare_jitter, params.body_jitter
    cj, bn, sg = params.cold_start_jitter, params.benchmark_noise, params.sigma
    consts = {
        "scale_vec": jnp.stack([sg, pj, bj] + [sg, cj, pj, bn, bj] * ma),
        "log_df": jnp.log(params.day_factor),
        "log_bench_ms": jnp.log(params.benchmark_ms),
    }
    z = jnp.zeros((), f32)
    state = OpenState(
        t_arr=z,
        busy=(z,) * K,
        log_speed=(z,) * K,
        last_used=(z,) * K,
        recycle=(jnp.asarray(jnp.inf, f32),) * K,
        alive=(jnp.zeros((), bool),) * K,
        probe_w=welford_init(), log_probe_w=welford_init(),
        body_w=welford_init(), latency_w=welford_init(),
        wait_w=welford_init(), reuse_w=welford_init(),
        p2=p2_init(params.pass_fraction) if cfg.adaptive else None,
        ema=z if cfg.adaptive else None,
        ema_init=jnp.zeros((), bool) if cfg.adaptive else None,
        since_publish=jnp.zeros((), jnp.int32) if cfg.adaptive else None,
        n_probes=jnp.zeros((), jnp.int32),
        n_started=z, n_terminated=z,
        nb_term=z, nb_pass=z, nb_reuse=z,
        db_term=z, db_pass=z, db_reuse=z,
    )
    final, requests = jax.lax.scan(
        lambda s, x: _open_step(params, cfg, consts, s, x), state,
        (u_all, ex_all, jnp.asarray(iats, f32)),
        unroll=1 if cfg.adaptive else 4)
    cost = params.cost_per_ms * (final.db_term + final.db_pass
                                 + final.db_reuse) \
        + params.cost_per_invocation * (final.nb_term + final.nb_pass
                                        + final.nb_reuse)
    summary = {
        "n_requests": jnp.asarray(cfg.n_steps, f32),
        "n_started": final.n_started,
        "n_terminated": final.n_terminated,
        "n_probes": jnp.asarray(final.n_probes, f32),
        "reuse_rate": final.reuse_w.mean,
        "mean_analysis_ms": final.body_w.mean,
        "mean_latency_ms": final.latency_w.mean,
        "mean_wait_ms": final.wait_w.mean,
        "std_wait_ms": welford_std(final.wait_w),
        "probe_mean_ms": final.probe_w.mean,
        "probe_log_std": welford_std(final.log_probe_w),
        "pass_rate": 1.0 - final.n_terminated
        / jnp.maximum(jnp.asarray(final.n_probes, f32), 1.0),
        "bill_n": jnp.stack([final.nb_term, final.nb_pass, final.nb_reuse]),
        "bill_d": jnp.stack([final.db_term, final.db_pass, final.db_reuse]),
        "cost": cost,
        "horizon_ms": final.t_arr,
    }
    return summary, requests


# ---------------------------------------------------------------------------
# Host entry points
# ---------------------------------------------------------------------------

#: compile/call accounting, so sweeps and CI can assert the jit cache hits
#: on the second arm-batch (same shapes → no recompile).
jit_stats = {"compiles": 0, "calls": 0}

_JIT_CACHE: dict = {}


def _get_sim_fn(cfg: SimConfig, batch_shape: tuple):
    cache_key = (cfg, batch_shape)
    if cache_key not in _JIT_CACHE:
        jit_stats["compiles"] += 1

        def run(params, seeds, arm_ids):
            def lane(p, seed, arm):
                key = jax.random.fold_in(jax.random.PRNGKey(seed), arm)
                return _simulate_chain(p, key, cfg)

            per_seed = jax.vmap(lane, in_axes=(None, 0, None))
            return jax.vmap(per_seed, in_axes=(0, None, 0))(
                params, seeds, arm_ids)

        _JIT_CACHE[cache_key] = jax.jit(run)
    return _JIT_CACHE[cache_key]


@dataclasses.dataclass
class VecResult:
    """Grid results as numpy arrays: summary leaves have shape
    (n_arms, n_seeds); per-request leaves (n_arms, n_seeds, n_steps)."""

    summary: dict
    requests: Optional[dict]
    n_arms: int
    n_seeds: int
    n_steps: int

    def mean_over_seeds(self, name: str) -> np.ndarray:
        return np.asarray(self.summary[name]).mean(axis=1)


def simulate_arms(
    arms: ArmParams,
    *,
    seeds,
    n_steps: int,
    pool_size: int = 1,
    max_attempts: Optional[int] = None,
    collect_requests: bool = False,
) -> VecResult:
    """Run every arm × seed lane through the jitted scan; returns numpy."""
    leaves = [np.atleast_1d(np.asarray(x)) for x in arms]
    n_arms = max(leaf.shape[0] for leaf in leaves)
    stacked = ArmParams(*[
        jnp.asarray(np.broadcast_to(leaf, (n_arms,)),
                    jnp.int32 if leaf.dtype.kind in "iu" else jnp.float32)
        for leaf in leaves])
    seeds = np.atleast_1d(np.asarray(seeds, np.uint32))
    max_r = int(np.max(np.asarray(arms.max_retries)))
    if max_attempts is None:
        max_attempts = max_r + 1
    if max_attempts < max_r + 1:
        raise ValueError(
            f"max_attempts={max_attempts} cannot cover max_retries={max_r}")
    adaptive = bool(np.any(np.asarray(arms.gate_mode) == GATE_ADAPTIVE))
    diurnal = bool(np.any(np.asarray(arms.diurnal_amplitude) != 0.0))
    cfg = SimConfig(n_steps=int(n_steps), pool_size=int(pool_size),
                    max_attempts=int(max_attempts),
                    collect_requests=bool(collect_requests),
                    adaptive=adaptive, diurnal=diurnal)
    fn = _get_sim_fn(cfg, (n_arms, len(seeds)))
    jit_stats["calls"] += 1
    summary, requests = fn(stacked, jnp.asarray(seeds),
                           jnp.arange(n_arms, dtype=jnp.uint32))
    summary = {k: np.asarray(v) for k, v in summary.items()}
    if requests is not None:
        # vmap axes lead, scan's step axis last → (arms, seeds, steps)
        requests = {k: np.asarray(v) for k, v in requests.items()}
    return VecResult(summary=summary, requests=requests, n_arms=n_arms,
                     n_seeds=len(seeds), n_steps=int(n_steps))


def _get_open_sim_fn(cfg: OpenSimConfig, batch_shape: tuple):
    cache_key = (cfg, batch_shape)
    if cache_key not in _JIT_CACHE:
        jit_stats["compiles"] += 1

        def run(params, seeds, arm_ids, iats):
            def lane(p, seed, arm, iat_row):
                key = jax.random.fold_in(jax.random.PRNGKey(seed), arm)
                return _simulate_open_chain(p, key, cfg, iat_row)

            # the arrival stream varies per SEED lane (one realization per
            # seed) and is shared across arms — every arm answers the same
            # offered traffic, which is what makes arms comparable
            per_seed = jax.vmap(lane, in_axes=(None, 0, None, 0))
            return jax.vmap(per_seed, in_axes=(0, None, 0, None))(
                params, seeds, arm_ids, iats)

        _JIT_CACHE[cache_key] = jax.jit(run)
    return _JIT_CACHE[cache_key]


def simulate_open_arms(
    arms: ArmParams,
    *,
    seeds,
    iats_ms: np.ndarray,
    n_servers: int = 4,
    max_attempts: Optional[int] = None,
    collect_requests: bool = False,
) -> VecResult:
    """Open-loop variant of :func:`simulate_arms`: instead of a think-time
    loop, the scan consumes ``iats_ms`` — host-generated inter-arrival
    times, shape ``(n_steps,)`` (shared by every seed lane; bit-exact
    trace replay) or ``(n_seeds, n_steps)`` (one realization per seed,
    from :mod:`repro.sim.arrivals`). Each arrival waits for the earliest
    of ``n_servers`` slots (the FIFO M/G/K queue at an autoscaling cap of
    ``max_instances = n_servers``); ``ArmParams.think_time_ms`` is ignored.
    """
    leaves = [np.atleast_1d(np.asarray(x)) for x in arms]
    n_arms = max(leaf.shape[0] for leaf in leaves)
    stacked = ArmParams(*[
        jnp.asarray(np.broadcast_to(leaf, (n_arms,)),
                    jnp.int32 if leaf.dtype.kind in "iu" else jnp.float32)
        for leaf in leaves])
    seeds = np.atleast_1d(np.asarray(seeds, np.uint32))
    iats = np.asarray(iats_ms, np.float32)
    if iats.ndim == 1:
        iats = np.broadcast_to(iats, (len(seeds), iats.shape[0]))
    if iats.ndim != 2 or iats.shape[0] != len(seeds):
        raise ValueError(
            f"iats_ms must be (n_steps,) or (n_seeds, n_steps); got "
            f"{np.asarray(iats_ms).shape} for {len(seeds)} seeds")
    n_steps = int(iats.shape[1])
    max_r = int(np.max(np.asarray(arms.max_retries)))
    if max_attempts is None:
        max_attempts = max_r + 1
    if max_attempts < max_r + 1:
        raise ValueError(
            f"max_attempts={max_attempts} cannot cover max_retries={max_r}")
    adaptive = bool(np.any(np.asarray(arms.gate_mode) == GATE_ADAPTIVE))
    diurnal = bool(np.any(np.asarray(arms.diurnal_amplitude) != 0.0))
    cfg = OpenSimConfig(n_steps=n_steps, n_servers=int(n_servers),
                        max_attempts=int(max_attempts),
                        collect_requests=bool(collect_requests),
                        adaptive=adaptive, diurnal=diurnal)
    fn = _get_open_sim_fn(cfg, (n_arms, len(seeds)))
    jit_stats["calls"] += 1
    summary, requests = fn(stacked, jnp.asarray(seeds),
                           jnp.arange(n_arms, dtype=jnp.uint32),
                           jnp.asarray(iats))
    summary = {k: np.asarray(v) for k, v in summary.items()}
    if requests is not None:
        requests = {k: np.asarray(v) for k, v in requests.items()}
    return VecResult(summary=summary, requests=requests, n_arms=n_arms,
                     n_seeds=len(seeds), n_steps=n_steps)


# ---------------------------------------------------------------------------
# Arm builders (mirror FaaSPlatform's spec/profile knob resolution)
# ---------------------------------------------------------------------------


def arm_from_spec(
    spec,
    variation,
    *,
    profile=None,
    pricing: Optional[Pricing] = None,
    gate: str = "fixed",
    threshold: float = math.inf,
    pass_fraction: float = 0.4,
    max_retries: int = 5,
    warmup_reports: int = 5,
    republish_every: int = 4,
    smoothing_alpha: float = 0.7,
    think_time_ms: float = 1000.0,
) -> ArmParams:
    """Build one arm from the event engine's own config objects
    (:class:`~repro.sim.platform.FunctionSpec`,
    :class:`~repro.sim.platform.PlatformProfile`,
    :class:`~repro.sim.variation.VariationModel`) so a parity test or grid
    sweep describes *one* scenario for both engines. ``gate`` is "off"
    (baseline arm), "fixed" (pre-tested ``threshold``) or "adaptive"
    (:class:`~repro.core.policy.AdaptiveMinosPolicy` defaults)."""
    gate_mode = {"off": GATE_OFF, "fixed": GATE_FIXED,
                 "adaptive": GATE_ADAPTIVE}[gate]
    if gate_mode == GATE_FIXED and not math.isfinite(threshold):
        raise ValueError("gate='fixed' needs a finite threshold")
    if profile is not None:
        knobs = profile.knobs()
        if pricing is None:
            pricing = profile.pricing
    else:
        from repro.core.substrate import SubstrateKnobs
        knobs = SubstrateKnobs(
            cold_start_ms=spec.cold_start_ms,
            cold_start_jitter=spec.cold_start_jitter,
            idle_timeout_ms=spec.idle_timeout_ms,
            recycle_lifetime_ms=spec.recycle_lifetime_ms,
            bill_cold_start=spec.bill_cold_start,
            requeue_overhead_ms=spec.requeue_overhead_ms,
        )
    if pricing is None:
        raise ValueError("pricing is required when no profile is given")
    return ArmParams(
        sigma=float(variation.sigma),
        day_factor=float(variation.day_factor),
        diurnal_amplitude=float(variation.diurnal_amplitude),
        diurnal_phase_h=float(variation.diurnal_phase_h),
        prepare_ms=float(spec.prepare_ms),
        prepare_jitter=float(spec.prepare_jitter),
        body_ms=float(spec.body_ms),
        body_jitter=float(spec.body_jitter),
        benchmark_ms=float(spec.benchmark_ms),
        benchmark_noise=float(spec.benchmark_noise),
        contention_rho=float(spec.contention_rho),
        cold_start_ms=float(knobs.cold_start_ms),
        cold_start_jitter=float(knobs.cold_start_jitter),
        idle_timeout_ms=float(knobs.idle_timeout_ms),
        recycle_lifetime_ms=(
            math.inf if knobs.recycle_lifetime_ms is None
            else float(knobs.recycle_lifetime_ms)),
        bill_cold_start=1.0 if knobs.bill_cold_start else 0.0,
        requeue_overhead_ms=float(knobs.requeue_overhead_ms),
        requeue_penalty_ms=0.0,
        order=int(ORDER_CODES[knobs.warm_pool_order]),
        gate_mode=int(gate_mode),
        threshold=float(threshold),
        pass_fraction=float(pass_fraction),
        max_retries=int(max_retries),
        warmup_reports=int(warmup_reports),
        republish_every=int(republish_every),
        smoothing_alpha=float(smoothing_alpha),
        think_time_ms=float(think_time_ms),
        cost_per_invocation=float(pricing.cost_per_invocation),
        cost_per_ms=float(pricing.cost_per_ms),
    )


def stack_arms(arms: list) -> ArmParams:
    """Stack a list of scalar :class:`ArmParams` into one batched pytree."""
    if not arms:
        raise ValueError("need at least one arm")
    return ArmParams(*[
        np.asarray([getattr(a, f) for a in arms]) for f in ArmParams._fields])


# ---------------------------------------------------------------------------
# Event-engine reference chain (the exact scenario the fast path models)
# ---------------------------------------------------------------------------


def run_event_chain(platform, n_requests: int,
                    think_time_ms: float = 1000.0) -> list:
    """Drive a :class:`~repro.sim.platform.FaaSPlatform` with ONE
    closed-loop virtual user for exactly ``n_requests`` completions — the
    event-engine scenario :func:`simulate_arms` vectorizes. Used by the
    parity tests and as grid_sweep's per-arm timing reference."""
    results: list = []

    def on_complete(res) -> None:
        results.append(res)
        if len(results) < n_requests:
            platform.loop.after(
                think_time_ms, lambda: platform.submit(None, on_complete))

    platform.submit(None, on_complete)
    platform.loop.run_all()
    assert len(results) == n_requests
    return results


__all__ = [
    "ArmParams",
    "GATE_ADAPTIVE",
    "GATE_FIXED",
    "GATE_OFF",
    "ORDER_CODES",
    "OpenSimConfig",
    "SimConfig",
    "VecResult",
    "arm_from_spec",
    "jit_stats",
    "run_event_chain",
    "simulate_arms",
    "simulate_open_arms",
    "stack_arms",
]
