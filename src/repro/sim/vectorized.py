"""Vectorized Monte-Carlo fast path for the single-stage Minos model
(DESIGN.md §11).

Every headline number in this repo comes from Monte-Carlo sweeps over the
pure-Python event engine, which runs seeds one at a time through a
heapq-callback loop — wide grids (pass-fraction × σ × platform × gate) are
unaffordable there. This module expresses the paper's *single-stage* loop —
cold start → probe → elysium gate → requeue-with-penalty → warm reuse with
AR(1) contention drift and diurnal speed, Fig-3 billing — as one
``lax.scan`` over invocation steps, ``vmap``-ed over (arms × seeds), so
thousands of parameter arms run as a single XLA program
(``benchmarks/grid_sweep.py`` measures the speedup; the parity bounds live
in tests/test_vectorized_parity.py).

Model scope — what the fast path deliberately is:

* a **closed-loop pool of ``n_streams`` request streams** (the event
  engine at ``n_vus=n_streams``): each scan step is the next stream's
  invocation driven to completion, think time between a stream's
  requests. At ``n_streams=1`` this is the paper's single-stage loop
  bit-for-bit (the original fast path); at ``n_streams>1`` pool slots
  carry live in-flight occupancy (derived each step from the stream
  completion horizons), the select tournament honors the least-loaded
  "spread" order, warm bodies pay the ``load**alpha`` self-contention
  factor, and ``gate_load_aware`` judges cold probes at the pool's live
  mean occupancy — the load-aware arms that previously fell back to the
  event engine.
* the classic decision stack only: gate off (baseline), a fixed elysium
  threshold, or the §IV adaptive policy (P² quantile + EMA republish,
  the exact :class:`~repro.core.policy.AdaptiveMinosPolicy` estimator,
  running on-device via :class:`~repro.core.estimators.P2State`).
  Workflows, serving bodies, re-probing and the other control-plane
  handlers stay on the event engine; static admission bounds and finite
  queue buffers run in-scan on the open-loop variant
  (:func:`simulate_open_arms`).
* a fixed-capacity array pool: LIFO/FIFO/spread reuse orders are gather
  indices over (validity-masked) slot arrays; idle-timeout and exponential
  recycle deadlines reclaim slots exactly where the event pool would.

On-device estimates reuse the JAX estimator states from
:mod:`repro.core.estimators`: :class:`WelfordState` folds probe /
log-probe / body / latency streams inside the scan (what
``SubstrateEngine`` maintains for Telemetry), and :class:`P2State` + EMA
maintain the adaptive threshold.

Everything is float32; latencies are accumulated as durations (never as
differences of large absolute times), so precision holds over long
horizons. Deterministic per (seed, arm index).
"""
from __future__ import annotations

import dataclasses
import math
import warnings
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import sanitizer as _sanitizer
from repro.core.cost import Pricing
from repro.core.estimators import (
    P2State,
    WelfordState,
    p2_init,
    p2_update,
    p2_value,
    welford_init,
    welford_merge,
    welford_std,
    welford_update,
    welford_update_masked,
)

GATE_OFF = 0        # baseline arm: every instance accepted unjudged
GATE_FIXED = 1      # pre-tested elysium threshold (paper §III-A)
GATE_ADAPTIVE = 2   # §IV online threshold: P² quantile + EMA republish

ORDER_CODES = {"lifo": 0, "fifo": 1, "spread": 2}


class ArmParams(NamedTuple):
    """One parameter arm — every leaf a float32 scalar (stack arms along
    axis 0 with :func:`stack_arms` for the vmapped grid)."""

    # variation model
    sigma: Any
    day_factor: Any
    diurnal_amplitude: Any
    diurnal_phase_h: Any
    # function spec (unit-speed durations + noise scales)
    prepare_ms: Any
    prepare_jitter: Any
    body_ms: Any
    body_jitter: Any
    benchmark_ms: Any
    benchmark_noise: Any
    contention_rho: Any
    # hosting knobs
    cold_start_ms: Any
    cold_start_jitter: Any
    idle_timeout_ms: Any
    recycle_lifetime_ms: Any   # inf = never recycled
    bill_cold_start: Any       # 0.0 / 1.0
    requeue_overhead_ms: Any
    requeue_penalty_ms: Any    # backend migration penalty (sim backend: 0)
    order: Any                 # 0 lifo / 1 fifo / 2 spread (int32)
    # gate
    gate_mode: Any             # GATE_OFF / GATE_FIXED / GATE_ADAPTIVE (int32)
    threshold: Any             # fixed elysium threshold (GATE_FIXED)
    pass_fraction: Any         # adaptive quantile (GATE_ADAPTIVE)
    max_retries: Any           # emergency-exit bound (int32)
    warmup_reports: Any        # adaptive warm-up (int32)
    republish_every: Any       # adaptive EMA republish cadence (int32)
    smoothing_alpha: Any       # adaptive EMA smoothing
    # workload + pricing
    think_time_ms: Any
    cost_per_invocation: Any
    cost_per_ms: Any
    # load-aware slots (defaults reproduce the single-stream model)
    concurrency: Any = 1           # per-slot request capacity (int32)
    load_slowdown_alpha: Any = 0.0  # body pays load**alpha when load > 1
    gate_load_aware: Any = 0.0     # 1.0: judge probes at live mean load
    # open-loop loss/admission (inf = knob disabled)
    queue_capacity: Any = math.inf  # arrivals finding >= this many waiting drop
    admit_bound: Any = math.inf    # defer while in_service + waiting >= bound


@dataclasses.dataclass(frozen=True)
class SimConfig:
    """Static (compile-time) shape of one vectorized run."""

    n_steps: int
    # One slot is exact for the single-stream model: a cold start only
    # happens when NO pooled instance is valid (so every slot is dead and
    # placement reuses slot 0), and a warm serve rewrites its own slot —
    # the pool can never hold two live instances. Multi-stream runs need
    # pool_size >= n_streams (enforced by simulate_arms): at any cold
    # start the other n_streams-1 streams occupy at most n_streams-1
    # slots, so a load-0 slot — necessarily dead, else it would have
    # served warm — always exists for placement.
    pool_size: int = 1
    max_attempts: int = 6      # must exceed every arm's max_retries
    collect_requests: bool = False
    adaptive: bool = True      # False: no arm uses GATE_ADAPTIVE — skip P²
    diurnal: bool = True       # False: every arm has amplitude 0 — skip cos
    # Closed-loop virtual users sharing the slot pool (event engine's
    # n_vus). 1 keeps the original single-stream step (and its compiled
    # program) untouched; >1 switches to the slot-occupancy step.
    n_streams: int = 1


class _ColdResult(NamedTuple):
    """Outcome of the cold retry chain for one step (scalars per lane)."""

    elapsed: Any      # ms burned by failed attempts (cold+probe+requeue)
    retries: Any      # failed attempts (i32)
    log_speed: Any    # accepted instance's hidden speed (log)
    cold_ms: Any      # accepted attempt's cold-start duration
    ready_ms: Any     # max(prepare, probe) — body start offset
    analysis_ms: Any  # accepted attempt's body duration
    place_rel: Any    # accepted instance's placement time (rel. to step start)
    n_term: Any
    d_term: Any
    probe_w: WelfordState      # probe durations
    log_probe_w: WelfordState  # log probe durations (lognormal fit)
    p2: Any                    # P2State | None
    ema: Any
    ema_init: Any
    since_publish: Any
    n_probes: Any


class _Pool(NamedTuple):
    """Fixed-capacity warm pool as K tuples of per-lane scalars.

    Tuple-of-scalars instead of (K,) arrays: every pool operation
    (validity, reuse-order tournament, placement) is then an unrolled
    chain of elementwise selects, which XLA fuses into the surrounding
    step kernel — batched gathers/argmax/scatter over a (K,) axis each
    cost a separate kernel pass on CPU, and the profiler showed those
    passes dominating the sweep wall-clock.

    Multi-stream runs (``n_streams > 1``) store ``(K,)`` *arrays* in the
    same fields instead: at K = n_streams = 8 the unrolled select chains
    exploded XLA's compile time (minutes), while argmin/scatter over a
    tiny (K,) axis compiles in seconds — and the multi-stream step is
    cold-chain-dominated anyway, so the per-step gather cost is noise."""

    log_speed: Any     # log-space: AR(1) drift needs no log/exp
    last_used: Any
    recycle: Any       # absolute deadline (inf = never)
    alive: Any
    # Multi-stream only (None prunes it from single-stream carries): the
    # time a cold-placed slot finishes its first serve. Until then the
    # slot is mid-cold-start and not reusable — the event pool's
    # admit_cold instance, in flight but never yet released.
    avail_from: Any = None
    # Multi-stream only: the time the slot last ENTERED the event pool's
    # available list — the heap key's ``_avail_seq`` position rendered as
    # a timestamp. Instances enter at their first release (admit_cold)
    # and re-enter when a completion drops them back below capacity;
    # while they hover below capacity the seq is FROZEN, so load ties
    # break by a near-static priority order. That staleness is
    # load-bearing for parity: the last slot in the priority order is
    # starved of tie traffic and only receives arrivals in synchronized
    # bursts when it is strictly least-loaded — bursts co-complete, the
    # slot drains to idle, and the pool shrinks at the event engine's
    # rate. (Tie-breaking on any *recency* signal instead spreads ties
    # evenly, phase-locks the streams, and the shrink never happens —
    # measured: a 3-slot pool with zero drains over 1400 s vs the event
    # engine's one per 30–180 s.)
    avail_seq: Any = None
    # Multi-stream only: the take time that filled the slot to capacity
    # (inf = currently in the available list). The first completion after
    # it re-enters the slot into the list with a fresh avail_seq.
    filled_at: Any = None


class _Streams(NamedTuple):
    """Closed-loop virtual users (n_streams > 1): (S,) arrays.

    Per-slot in-flight occupancy is DERIVED each step from these
    completion horizons (``load_k = Σ_s [slot_s == k ∧ ended_s > t]``)
    rather than carried as counters: the scan processes stream events in
    ``next_ready`` order, so a carried counter could only be decremented
    when the *completed* stream's next request is processed — after other
    streams already observed a stale count. The derived form charges each
    completion at its true completion time."""

    next_ready: Any  # when the stream next dispatches (submit or retry)
    ended: Any       # the stream's in-flight horizon on its slot
    slot: Any        # pool slot that served it (int32; -1 = none yet)
    # Retry-as-step bookkeeping (one scan step = ONE cold attempt; a
    # TERMINATEd probe re-fires the stream at the requeue time instead of
    # looping inside the step — see _step_multi):
    req_start: Any   # current request's first dispatch time (latency anchor)
    retries: Any     # failed attempts of the current request (i32)
    pend_bill: Any   # billed ms of those failed attempts (request row total)


class VecState(NamedTuple):
    t: Any                       # absolute sim time (ms)
    pool: _Pool
    probe_w: WelfordState        # cold probe durations
    log_probe_w: WelfordState    # log of the same (lognormal fit)
    body_w: WelfordState         # observed body durations
    latency_w: WelfordState      # request latencies
    reuse_w: WelfordState        # 1.0 warm-served / 0.0 cold-served
    p2: Any                      # P2State | None (pruned when not adaptive)
    ema: Any
    ema_init: Any
    since_publish: Any
    n_probes: Any
    n_started: Any
    n_terminated: Any
    nb_term: Any                 # Fig-3 billing terms, six scalars
    nb_pass: Any
    nb_reuse: Any
    db_term: Any
    db_pass: Any
    db_reuse: Any
    streams: Any = None          # _Streams when n_streams > 1, else pruned


def _diurnal(t_ms, amplitude, phase_h):
    hour = (t_ms / 3.6e6) % 24.0
    return 1.0 + amplitude * jnp.cos(2.0 * jnp.pi * (hour - phase_h) / 24.0)


def _wsel(mask, new, old):
    return jax.tree_util.tree_map(
        lambda a, b: jnp.where(mask, a, b), new, old)


def _attempt_values(params: ArmParams, consts, su, J, day_mean, log_day, i):
    """Attempt ``i``'s sampled quantities from the pre-scaled draw row.

    Draw layout per attempt (base b=3+5i): z0 instance speed, z1 cold
    start, z2 prepare, z3 probe observation noise, z4 body. ``J=exp(su)``
    was computed in one vectorized exp, so everything here is
    multiply/add: speed = exp(σz0)·day_mean, probe = B·exp(bn·z3)/speed,
    body = body_ms·exp(bj·z4)/speed."""
    b = 3 + 5 * i
    cold = params.cold_start_ms * J[b + 1]
    download = params.prepare_ms * J[b + 2]
    inv_speed_rel = J[b + 3] / J[b]
    bench = (params.benchmark_ms / day_mean) * inv_speed_rel
    log_bench = consts["log_bench_ms"] + su[b + 3] - su[b] - log_day
    analysis = (params.body_ms / day_mean) * (J[b + 4] / J[b])
    log_speed = su[b] + log_day
    return cold, download, bench, log_bench, analysis, log_speed


def _cold_chain_fixed(params, cfg, consts, su, J, day_mean, log_day,
                      served_cold, state, judge_mult=None) -> _ColdResult:
    """The retry chain for attempt-invariant gates (off / fixed
    threshold): an unrolled chain of scalar selects — no P², no
    sequential estimator feedback — the grid sweep's hot path.

    ``judge_mult`` (load-aware gating, multi-stream only; ``None`` keeps
    the single-stream graph byte-identical) inflates the JUDGED probe
    duration to the effective speed at the pool's live occupancy — the
    raw observation still feeds the Welford/threshold estimators, exactly
    as :meth:`~repro.core.control.ElysiumGate.judge` records raw and
    judges effective."""
    f32 = jnp.float32
    z = jnp.zeros((), f32)
    pending = served_cold
    thr = jnp.where(params.gate_mode == GATE_FIXED, params.threshold, jnp.inf)
    elapsed = z
    retries = jnp.zeros((), jnp.int32)
    n_term = z
    d_term = z
    cb = z
    s_b = z
    s_b2 = z
    s_lb = z
    s_lb2 = z
    acc_cold = z
    acc_ready = z
    acc_body = z
    acc_logsp = z
    acc_place = z
    for i in range(cfg.max_attempts):
        cold, download, bench, log_bench, analysis, log_speed = \
            _attempt_values(params, consts, su, J, day_mean, log_day, i)
        probed = (params.gate_mode > 0) & (i < params.max_retries)
        b_eff = bench if judge_mult is None else bench * judge_mult
        passes = (~probed) | (b_eff <= thr)
        feed = jnp.asarray(pending & probed, f32)
        accept = pending & passes
        fail = jnp.asarray(pending & ~passes, f32)
        # batched Welford moments of this step's probe stream (merged
        # below via Chan — exact up to FP association order)
        cb = cb + feed
        s_b = s_b + feed * bench
        s_b2 = s_b2 + feed * bench * bench
        s_lb = s_lb + feed * log_bench
        s_lb2 = s_lb2 + feed * log_bench * log_bench
        ready = jnp.where(probed, jnp.maximum(download, bench), download)
        acc_cold = jnp.where(accept, cold, acc_cold)
        acc_ready = jnp.where(accept, ready, acc_ready)
        acc_body = jnp.where(accept, analysis, acc_body)
        acc_logsp = jnp.where(accept, log_speed, acc_logsp)
        acc_place = jnp.where(accept, elapsed, acc_place)
        n_term = n_term + fail
        d_term = d_term + fail * (params.bill_cold_start * cold + bench)
        elapsed = elapsed + fail * (cold + bench + params.requeue_overhead_ms
                                    + params.requeue_penalty_ms)
        retries = retries + jnp.asarray(pending & ~passes, jnp.int32)
        pending = pending & ~passes

    def merged(w: WelfordState, s, s2) -> WelfordState:
        mean_b = s / jnp.maximum(cb, 1.0)
        m2_b = jnp.maximum(s2 - cb * mean_b * mean_b, 0.0)
        return welford_merge(w, WelfordState(count=cb, mean=mean_b, m2=m2_b))

    return _ColdResult(
        elapsed=elapsed, retries=retries, log_speed=acc_logsp,
        cold_ms=acc_cold, ready_ms=acc_ready, analysis_ms=acc_body,
        place_rel=acc_place, n_term=n_term, d_term=d_term,
        probe_w=merged(state.probe_w, s_b, s_b2),
        log_probe_w=merged(state.log_probe_w, s_lb, s_lb2),
        p2=state.p2, ema=state.ema, ema_init=state.ema_init,
        since_publish=state.since_publish,
        n_probes=state.n_probes + cb.astype(jnp.int32),
    )


def _cold_chain_adaptive(params, cfg, consts, su, J, day_mean, log_day,
                         served_cold, state, judge_mult=None) -> _ColdResult:
    """The retry chain when the §IV adaptive threshold is live: every
    probed attempt reports to the on-device P² quantile + EMA republish
    (the exact :class:`~repro.core.policy.AdaptiveMinosPolicy` estimator)
    BEFORE being judged, so attempts are sequential within the step.
    ``judge_mult``: see :func:`_cold_chain_fixed` — estimators always see
    the raw observation; only the pass/terminate comparison inflates."""
    f32 = jnp.float32
    z = jnp.zeros((), f32)
    c = _ColdResult(
        elapsed=z, retries=jnp.zeros((), jnp.int32), log_speed=z,
        cold_ms=z, ready_ms=z, analysis_ms=z, place_rel=z,
        n_term=z, d_term=z,
        probe_w=state.probe_w, log_probe_w=state.log_probe_w,
        p2=state.p2, ema=state.ema, ema_init=state.ema_init,
        since_publish=state.since_publish, n_probes=state.n_probes,
    )
    pending = served_cold
    for i in range(cfg.max_attempts):
        cold, download, bench, log_bench, analysis, log_speed = \
            _attempt_values(params, consts, su, J, day_mean, log_day, i)
        probed = (params.gate_mode > 0) & (i < params.max_retries)
        feed = pending & probed
        probe_w = welford_update_masked(c.probe_w, bench, feed)
        log_probe_w = welford_update_masked(c.log_probe_w, log_bench, feed)
        n_probes = c.n_probes + jnp.asarray(feed, jnp.int32)
        p2 = _wsel(feed, p2_update(c.p2, bench), c.p2)
        since = c.since_publish + jnp.asarray(feed, jnp.int32)
        publish = feed & (since >= params.republish_every)
        p2v = p2_value(p2)
        ema = jnp.where(
            publish,
            jnp.where(c.ema_init,
                      params.smoothing_alpha * p2v
                      + (1.0 - params.smoothing_alpha) * c.ema,
                      p2v),
            c.ema)
        ema_init = c.ema_init | publish
        since = jnp.where(publish, 0, since)
        thr_adaptive = jnp.where(
            n_probes >= params.warmup_reports,
            jnp.where(ema_init, ema, p2v), jnp.inf)
        thr = jnp.where(params.gate_mode == GATE_FIXED, params.threshold,
                        jnp.where(params.gate_mode == GATE_ADAPTIVE,
                                  thr_adaptive, jnp.inf))
        b_eff = bench if judge_mult is None else bench * judge_mult
        passes = (~probed) | (b_eff <= thr)
        accept = pending & passes
        fail = pending & ~passes
        failf = jnp.asarray(fail, f32)
        ready = jnp.where(probed, jnp.maximum(download, bench), download)
        c = _ColdResult(
            elapsed=c.elapsed + failf * (cold + bench
                                         + params.requeue_overhead_ms
                                         + params.requeue_penalty_ms),
            retries=c.retries + jnp.asarray(fail, jnp.int32),
            log_speed=jnp.where(accept, log_speed, c.log_speed),
            cold_ms=jnp.where(accept, cold, c.cold_ms),
            ready_ms=jnp.where(accept, ready, c.ready_ms),
            analysis_ms=jnp.where(accept, analysis, c.analysis_ms),
            place_rel=jnp.where(accept, c.elapsed, c.place_rel),
            n_term=c.n_term + failf,
            d_term=c.d_term + failf * (params.bill_cold_start * cold + bench),
            probe_w=probe_w, log_probe_w=log_probe_w,
            p2=p2, ema=ema, ema_init=ema_init, since_publish=since,
            n_probes=n_probes,
        )
        pending = pending & ~passes
    return c


def _step(params: ArmParams, cfg: SimConfig, consts: dict,
          state: VecState, draws):
    f32 = jnp.float32
    K = cfg.pool_size
    u, ex = draws
    # one vectorized exp covers every lognormal factor of the step
    # (scale<=0 gives exactly exp(0)=1, preserving sample_jitter's
    # disabled-noise contract)
    su = u * consts["scale_vec"]
    J = jnp.exp(su)
    t0 = state.t
    if cfg.diurnal:
        dv = _diurnal(t0, params.diurnal_amplitude, params.diurnal_phase_h)
        day_mean = params.day_factor * dv
        log_day = consts["log_df"] + jnp.log(dv)
    else:
        day_mean = params.day_factor
        log_day = consts["log_df"]

    # ---- warm take: unrolled validity + reuse-order tournament ---------
    pool = state.pool
    valid = [pool.alive[k]
             & ((t0 - pool.last_used[k]) <= params.idle_timeout_ms)
             & (t0 < pool.recycle[k])
             for k in range(K)]
    any_warm = valid[0]
    for k in range(1, K):
        any_warm = any_warm | valid[k]
    served_cold = ~any_warm
    # lifo takes the most recently used valid slot, fifo/spread the
    # oldest (single-stream: pooled loads are all 0, so spread's
    # least-loaded order degenerates to fifo) — maximize a signed score
    sign = jnp.where(params.order == 0, 1.0, -1.0)
    ninf = jnp.asarray(-jnp.inf, f32)
    score = [jnp.where(valid[k], sign * pool.last_used[k], ninf)
             for k in range(K)]
    oh = [None] * K
    oh[0] = score[0] >= ninf  # True; same dtype/shape as the other flags
    best = score[0]
    for k in range(1, K):
        take = score[k] > best
        best = jnp.where(take, score[k], best)
        for j in range(k):
            oh[j] = oh[j] & ~take
        oh[k] = take
    log_i = pool.log_speed[0]
    rc_i = pool.recycle[0]
    for k in range(1, K):
        log_i = jnp.where(oh[k], pool.log_speed[k], log_i)
        rc_i = jnp.where(oh[k], pool.recycle[k], rc_i)

    # ---- warm path: AR(1) drift (pure log-space arithmetic) ------------
    rho = params.contention_rho
    log_drifted = jnp.where(
        rho >= 1.0, log_i,
        log_day + rho * (log_i - log_day)
        + jnp.sqrt(jnp.maximum(1.0 - rho * rho, 0.0)) * su[0])
    download_w = params.prepare_ms * J[1]
    analysis_w = params.body_ms * J[2] * jnp.exp(-log_drifted)
    dur_w = download_w + analysis_w

    # ---- cold path -----------------------------------------------------
    chain = _cold_chain_adaptive if cfg.adaptive else _cold_chain_fixed
    c = chain(params, cfg, consts, su, J, day_mean, log_day,
              served_cold, state)

    # ---- merge warm/cold outcomes --------------------------------------
    analysis = jnp.where(served_cold, c.analysis_ms, analysis_w)
    latency = jnp.where(
        served_cold, c.elapsed + c.cold_ms + c.ready_ms + c.analysis_ms, dur_w)
    billed_final = jnp.where(
        served_cold,
        params.bill_cold_start * c.cold_ms + c.ready_ms + c.analysis_ms,
        dur_w)
    t_end = t0 + latency
    log_speed_served = jnp.where(served_cold, c.log_speed, log_drifted)

    # ---- pool update (unrolled one-hot blend) --------------------------
    # A cold start implies every slot failed validity (all dead), so cold
    # placement always lands in slot 0; a warm serve rewrites its own slot.
    # inf lifetime (no platform recycling) must stay inf even when the
    # exponential draw is exactly 0.0 (0·inf = NaN would kill the slot)
    recycle_new = (t0 + c.place_rel) + jnp.where(
        jnp.isinf(params.recycle_lifetime_ms), jnp.inf,
        ex * params.recycle_lifetime_ms)
    recycle_upd = jnp.where(served_cold, recycle_new, rc_i)
    upd = [served_cold | oh[0]] + [~served_cold & oh[k] for k in range(1, K)]
    new_pool = _Pool(
        log_speed=tuple(
            jnp.where(upd[k], log_speed_served, pool.log_speed[k])
            for k in range(K)),
        last_used=tuple(
            jnp.where(upd[k], t_end, pool.last_used[k]) for k in range(K)),
        recycle=tuple(
            jnp.where(upd[k], recycle_upd, pool.recycle[k])
            for k in range(K)),
        alive=tuple(valid[k] | upd[k] for k in range(K)),
    )

    # ---- Fig-3 billing + telemetry estimators --------------------------
    coldf = jnp.asarray(served_cold, f32)
    warmf = jnp.asarray(any_warm, f32)
    new_state = VecState(
        t=t_end + params.think_time_ms,
        pool=new_pool,
        probe_w=c.probe_w, log_probe_w=c.log_probe_w,
        body_w=welford_update(state.body_w, analysis),
        latency_w=welford_update(state.latency_w, latency),
        reuse_w=welford_update(state.reuse_w, warmf),
        p2=c.p2, ema=c.ema, ema_init=c.ema_init,
        since_publish=c.since_publish, n_probes=c.n_probes,
        n_started=state.n_started + coldf * (
            jnp.asarray(c.retries, f32) + 1.0),
        n_terminated=state.n_terminated + c.n_term,
        nb_term=state.nb_term + c.n_term,
        nb_pass=state.nb_pass + coldf,
        nb_reuse=state.nb_reuse + warmf,
        db_term=state.db_term + c.d_term,
        db_pass=state.db_pass + coldf * billed_final,
        db_reuse=state.db_reuse + warmf * billed_final,
    )
    if cfg.collect_requests:
        out = {
            "latency_ms": latency,
            "analysis_ms": analysis,
            "billed_ms": coldf * c.d_term + billed_final,
            "served_by_cold": served_cold,
            "retries": jnp.where(served_cold, c.retries, 0),
            "instance_speed": jnp.exp(log_speed_served),
        }
    else:
        out = None
    return new_state, out


def _judge_one(params, cfg, est, bench, log_bench, probed):
    """One gate judgment in the retry-as-step models: feed the raw probe
    observation to the estimator stack (Welford moments, plus the
    P²/EMA republish pipeline when ``cfg.adaptive``), then return the
    active threshold to compare the judged — possibly load-inflated —
    duration against. ``est`` is the 7-tuple ``(probe_w, log_probe_w,
    n_probes, p2, ema, ema_init, since_publish)`` pulled off a
    :class:`VecState` or :class:`OpenState` carry; the updated tuple is
    returned alongside ``thr`` so a step can judge several dispatches
    sequentially (the open-loop step judges a parked re-offer and the
    step's own arrival in one pass)."""
    probe_w, log_probe_w, n_probes, p2, ema, ema_init, since = est
    probe_w = welford_update_masked(probe_w, bench, probed)
    log_probe_w = welford_update_masked(log_probe_w, log_bench, probed)
    n_probes = n_probes + jnp.asarray(probed, jnp.int32)
    if cfg.adaptive:
        p2 = _wsel(probed, p2_update(p2, bench), p2)
        since = since + jnp.asarray(probed, jnp.int32)
        publish = probed & (since >= params.republish_every)
        p2v = p2_value(p2)
        ema = jnp.where(
            publish,
            jnp.where(ema_init,
                      params.smoothing_alpha * p2v
                      + (1.0 - params.smoothing_alpha) * ema,
                      p2v),
            ema)
        ema_init = ema_init | publish
        since = jnp.where(publish, 0, since)
        thr_adaptive = jnp.where(
            n_probes >= params.warmup_reports,
            jnp.where(ema_init, ema, p2v), jnp.inf)
        thr = jnp.where(params.gate_mode == GATE_FIXED, params.threshold,
                        jnp.where(params.gate_mode == GATE_ADAPTIVE,
                                  thr_adaptive, jnp.inf))
    else:
        thr = jnp.where(params.gate_mode == GATE_FIXED, params.threshold,
                        jnp.inf)
    return (probe_w, log_probe_w, n_probes, p2, ema, ema_init, since), thr


def _step_multi(params: ArmParams, cfg: SimConfig, consts: dict,
                state: VecState, draws):
    """One invocation step of the ``n_streams > 1`` closed-loop model.

    The step fires the stream with the earliest ``next_ready`` (ties →
    lowest index, the event loop's FIFO order at equal timestamps), so
    step times are non-decreasing and every stream completion earlier
    than the current dispatch has already been accounted. Pool slots
    carry live in-flight occupancy (see :class:`_Streams`): warm
    selection masks full slots, ``order="spread"`` picks the least
    loaded, warm bodies pay the ``(load+1)**alpha`` self-contention
    factor at their observed occupancy, and ``gate_load_aware`` arms
    judge every cold attempt at the pool's live mean occupancy. A cold
    TERMINATE does not loop inside the step: the stream re-fires at the
    requeue time (retry-as-step), so each retry is judged at fresh
    occupancy and can be rescued by a warm slot that freed meanwhile —
    the event dispatcher's requeue semantics. One scan step is therefore
    one dispatch ATTEMPT; steps whose probe fails complete no request
    (``completed`` in the collected rows, ``n_completed`` in summaries).

    Unlike the single-stream step's tuple-of-scalars pool, this step
    keeps ``(K,)``/``(S,)`` arrays: the tournaments become ``argmin``
    reductions instead of unrolled select chains — at K = S = 8 the
    unrolled form made XLA's fusion search blow past minutes of compile
    time, while the array form compiles in seconds and the (small)
    per-step gather cost is dwarfed by the cold-chain math."""
    f32 = jnp.float32
    i32 = jnp.int32
    K = cfg.pool_size
    S = cfg.n_streams
    u, ex = draws
    su = u * consts["scale_vec"]
    J = jnp.exp(su)

    st = state.streams
    # ---- which stream fires (argmin keeps the lowest index on ties,
    # the event loop's FIFO order at equal timestamps) ------------------
    s_star = jnp.argmin(st.next_ready)
    t0 = st.next_ready[s_star]

    if cfg.diurnal:
        dv = _diurnal(t0, params.diurnal_amplitude, params.diurnal_phase_h)
        day_mean = params.day_factor * dv
        log_day = consts["log_df"] + jnp.log(dv)
    else:
        day_mean = params.day_factor
        log_day = consts["log_df"]

    # ---- per-slot live occupancy, exact at t0 --------------------------
    pool = state.pool
    in_flight = (st.slot >= 0) & (st.ended > t0)
    load = jnp.zeros((K,), i32).at[jnp.clip(st.slot, 0)].add(
        in_flight.astype(i32))
    # fold available-list re-entries: a slot taken to capacity left the
    # list (filled_at finite); the first completion after that re-admits
    # it with a fresh position seq. Completions stay visible from their
    # end time until the stream fires again — and the firing step folds
    # before it overwrites — so the earliest qualifying end is never lost.
    vis = (st.slot >= 0) & (st.ended <= t0)
    rejoin_ok = vis & (st.ended > pool.filled_at[jnp.clip(st.slot, 0)])
    rejoin = jnp.full((K,), jnp.inf, f32).at[jnp.clip(st.slot, 0)].min(
        jnp.where(rejoin_ok, st.ended, jnp.inf))
    rejoined = jnp.isfinite(pool.filled_at) & jnp.isfinite(rejoin)
    avail_seq = jnp.where(rejoined, rejoin, pool.avail_seq)
    filled_at = jnp.where(rejoined, jnp.inf, pool.filled_at)

    # ---- warm validity -------------------------------------------------
    # Busy slots (load > 0) stay takeable while they have spare capacity,
    # regardless of idle/recycle deadlines (the event pool only reclaims
    # IDLE instances); idle slots must clear both deadlines; a slot mid
    # cold start (avail_from > t0) is in flight but was never released —
    # the event pool's admit_cold instance — and is not reusable yet.
    idle_ok = ((t0 - pool.last_used) <= params.idle_timeout_ms) \
        & (t0 < pool.recycle)
    valid = pool.alive & (pool.avail_from <= t0) \
        & (load < params.concurrency) & ((load > 0) | idle_ok)
    any_warm = jnp.any(valid)
    served_cold = ~any_warm

    # ---- reuse-order tournament (lifo / fifo / spread) -----------------
    # spread = least loaded, ties by available-list position (see
    # _Pool.avail_seq — at concurrency 1 the position is the release
    # time, so this degenerates to fifo exactly as the single-stream
    # step documents). lifo/fifo ARE list-position orders, so they use
    # the same seq. argmin over a masked key keeps the lowest index on
    # exact ties.
    inf = jnp.asarray(jnp.inf, f32)
    time_key = jnp.where(params.order == 0, -avail_seq, avail_seq)
    min_load = jnp.min(jnp.where(valid, load, jnp.asarray(2**31 - 1, i32)))
    spread_cand = valid & (load == min_load)
    key = jnp.where(params.order == 2,
                    jnp.where(spread_cand, avail_seq, inf),
                    jnp.where(valid, time_key, inf))
    k_warm = jnp.argmin(key)
    log_i = pool.log_speed[k_warm]
    rc_i = pool.recycle[k_warm]
    load_sel = load[k_warm]

    # ---- cold placement: first dead slot -------------------------------
    # (pool_size >= n_streams guarantees one exists on a cold start: the
    # other streams hold < n_streams slots busy, and a load-0 slot that
    # cleared its deadlines would have served warm instead)
    dead = ~pool.alive | ((load == 0) & ~idle_ok)
    k_cold = jnp.argmax(dead)  # first True
    k_upd = jnp.where(served_cold, k_cold, k_warm)
    upd = jnp.arange(K) == k_upd

    # ---- warm path: AR(1) drift + load**alpha self-contention ----------
    rho = params.contention_rho
    log_drifted = jnp.where(
        rho >= 1.0, log_i,
        log_day + rho * (log_i - log_day)
        + jnp.sqrt(jnp.maximum(1.0 - rho * rho, 0.0)) * su[0])
    eff_load = jnp.asarray(load_sel + 1, f32)  # incl. this request
    lmult = jnp.where(
        (params.load_slowdown_alpha > 0.0) & (eff_load > 1.0),
        jnp.power(eff_load, params.load_slowdown_alpha), 1.0)
    download_w = params.prepare_ms * J[1]
    analysis_w = params.body_ms * J[2] * jnp.exp(-log_drifted) * lmult
    dur_w = download_w + analysis_w

    # ---- load-aware gate factor (pool mean occupancy at dispatch) ------
    # counts this request and its cold instance, like the event engine's
    # Telemetry at judge time (admit_cold puts the probing instance in
    # the pool with one in-flight request before the gate fires). Each
    # retry attempt is its own step, so the judge re-reads occupancy at
    # every re-dispatch exactly like the event controller.
    live = pool.alive & ((load > 0) | idle_ok)
    total_if = jnp.sum(load)
    n_live = jnp.sum(live.astype(i32))
    mean_load = jnp.maximum(
        1.0, jnp.asarray(total_if + 1, f32) / jnp.asarray(n_live + 1, f32))
    judge_mult = jnp.where(
        (params.gate_load_aware > 0.5) & (params.load_slowdown_alpha > 0.0),
        jnp.power(mean_load, params.load_slowdown_alpha), 1.0)

    # ---- cold path: ONE probe attempt per step (retry-as-step) ---------
    # The event engine requeues a TERMINATEd cold probe through the
    # dispatcher: the retry re-dispatches ~requeue_overhead_ms after the
    # probe ends, re-reads pool occupancy, and can land on a warm slot
    # that freed meanwhile. Folding the whole retry chain into the step
    # that started it (the single-stream model) freezes one occupancy
    # snapshot across the chain and hides the probing instances from
    # concurrent streams — under load-aware gating that severs the
    # saturation → harsh judge → terminate → still-saturated feedback the
    # event engine exhibits (measured: the frozen snapshot never leaves
    # mean load 1.0, while the event judges 18% of probes at 1.75–2.5).
    # A failed attempt completes no request and leaves no trace in the
    # pool — the event judges and drops the instance synchronously at
    # dispatch time — and the stream re-fires at the requeue time.
    cold_ms, download_c, bench, log_bench, analysis_c, log_speed_c = \
        _attempt_values(params, consts, su, J, day_mean, log_day, 0)
    r_cur = st.retries[s_star]
    req_start = jnp.where(r_cur > 0, st.req_start[s_star], t0)
    probed = served_cold & (params.gate_mode > 0) \
        & (r_cur < params.max_retries)
    est = (state.probe_w, state.log_probe_w, state.n_probes, state.p2,
           state.ema, state.ema_init, state.since_publish)
    est, thr = _judge_one(params, cfg, est, bench, log_bench, probed)
    probe_w, log_probe_w, n_probes, p2, ema, ema_init, since = est
    # estimators see the raw observation; only the verdict inflates, as
    # ElysiumGate.judge records raw and judges effective
    passes = (~probed) | (bench * judge_mult <= thr)
    completed = any_warm | passes
    cold_pass = served_cold & passes
    cold_passf = jnp.asarray(cold_pass, f32)
    failf = jnp.asarray(served_cold & ~passes, f32)

    # ---- merge warm/cold outcomes --------------------------------------
    ready_c = jnp.where(probed, jnp.maximum(download_c, bench), download_c)
    analysis = jnp.where(served_cold, analysis_c, analysis_w)
    t_end = t0 + jnp.where(served_cold, cold_ms + ready_c + analysis_c,
                           dur_w)
    probe_end = t0 + cold_ms + bench
    latency = t_end - req_start
    billed_final = jnp.where(
        served_cold, params.bill_cold_start * cold_ms + ready_c + analysis_c,
        dur_w)
    bill_fail = params.bill_cold_start * cold_ms + bench
    log_speed_served = jnp.where(served_cold, log_speed_c, log_drifted)

    # ---- pool update ---------------------------------------------------
    recycle_new = t0 + jnp.where(
        jnp.isinf(params.recycle_lifetime_ms), jnp.inf,
        ex * params.recycle_lifetime_ms)
    ninf = jnp.asarray(-jnp.inf, f32)
    recycle_upd = jnp.where(served_cold,
                            jnp.where(passes, recycle_new, ninf), rc_i)
    # lazy reclaim exactly like the event pool's sweep: an idle slot past
    # its deadline dies; busy slots (load > 0) always survive. A failed
    # probe never enters the pool at all: the event judges and drops the
    # instance synchronously at dispatch time, so concurrent requests
    # never observe it — mirrored here by not raising `alive` on a fail.
    keep = pool.alive & ((load > 0) | idle_ok)
    new_pool = _Pool(
        log_speed=jnp.where(upd, log_speed_served, pool.log_speed),
        last_used=jnp.where(upd, jnp.where(completed, t_end, ninf),
                            pool.last_used),
        recycle=jnp.where(upd, recycle_upd, pool.recycle),
        alive=keep | (upd & completed),
        avail_from=jnp.where(
            upd & served_cold,
            jnp.where(passes, t_end, jnp.inf), pool.avail_from),
        # a cold-placed slot enters the available list at its first
        # release (t_end); a warm take that fills the slot to capacity
        # removes it from the list until a completion re-admits it
        avail_seq=jnp.where(upd & served_cold, t_end, avail_seq),
        filled_at=jnp.where(
            upd,
            jnp.where(~served_cold & (load_sel + 1 >= params.concurrency),
                      t0, jnp.inf),
            filled_at),
    )

    # A stream whose probe failed holds no slot while it waits to requeue
    # (the event drops the instance at judge time), so it contributes no
    # in-flight load to anyone's occupancy reads until it re-dispatches.
    chosen_idx = jnp.where(completed, k_upd.astype(i32),
                           jnp.asarray(-1, i32))
    s_oh = jnp.arange(S) == s_star
    pend_bill = st.pend_bill[s_star]
    requeue_at = probe_end + params.requeue_overhead_ms \
        + params.requeue_penalty_ms
    new_streams = _Streams(
        next_ready=jnp.where(
            s_oh,
            jnp.where(completed, t_end + params.think_time_ms, requeue_at),
            st.next_ready),
        ended=jnp.where(s_oh, jnp.where(completed, t_end, probe_end),
                        st.ended),
        slot=jnp.where(s_oh, chosen_idx, st.slot),
        req_start=jnp.where(s_oh, req_start, st.req_start),
        retries=jnp.where(s_oh, jnp.where(completed, 0, r_cur + 1),
                          st.retries),
        pend_bill=jnp.where(
            s_oh, jnp.where(completed, 0.0, pend_bill + bill_fail),
            st.pend_bill),
    )

    # ---- Fig-3 billing + telemetry estimators --------------------------
    coldf = jnp.asarray(served_cold, f32)
    warmf = jnp.asarray(any_warm, f32)
    new_state = VecState(
        t=jnp.maximum(state.t, jnp.where(completed, t_end, probe_end)),
        pool=new_pool,
        probe_w=probe_w, log_probe_w=log_probe_w,
        body_w=welford_update_masked(state.body_w, analysis, completed),
        latency_w=welford_update_masked(state.latency_w, latency, completed),
        reuse_w=welford_update_masked(state.reuse_w, warmf, completed),
        p2=p2, ema=ema, ema_init=ema_init,
        since_publish=since, n_probes=n_probes,
        n_started=state.n_started + coldf,
        n_terminated=state.n_terminated + failf,
        nb_term=state.nb_term + failf,
        nb_pass=state.nb_pass + cold_passf,
        nb_reuse=state.nb_reuse + warmf,
        db_term=state.db_term + failf * bill_fail,
        db_pass=state.db_pass + cold_passf * billed_final,
        db_reuse=state.db_reuse + warmf * billed_final,
        streams=new_streams,
    )
    if cfg.collect_requests:
        out = {
            "latency_ms": latency,
            "analysis_ms": analysis,
            "billed_ms": pend_bill + billed_final,
            "served_by_cold": served_cold,
            "retries": r_cur,
            "instance_speed": jnp.exp(log_speed_served),
            # retry-as-step: a failed attempt completes no request — rows
            # with completed=False are attempt records and must be masked
            # out of per-request statistics by consumers
            "completed": completed,
            # slot-accounting stream for the O(n) replay property test
            "slot": chosen_idx,
            "stream": s_star.astype(i32),
            "t_start_ms": t0,
            "t_end_ms": jnp.where(completed, t_end, probe_end),
            # occupancy of the serving slot excluding this request (a
            # cold-placed slot is empty by construction)
            "load_at_start": jnp.where(served_cold, 0, load_sel),
        }
    else:
        out = None
    return new_state, out


def _simulate_chain(params: ArmParams, key, cfg: SimConfig):
    f32 = jnp.float32
    K = cfg.pool_size
    # multi-stream steps run ONE cold attempt each (retry-as-step), so
    # they only consume attempt-0 draws
    ma = 1 if cfg.n_streams > 1 else cfg.max_attempts
    k_normal, k_exp = jax.random.split(key)
    u_all = jax.random.normal(k_normal, (cfg.n_steps, 3 + 5 * ma), f32)
    ex_all = jax.random.exponential(k_exp, (cfg.n_steps,), f32)
    # Draw layout: u[0] warm drift, u[1] warm prepare, u[2] warm body;
    # attempt i at base 3+5i: z0 speed, z1 cold, z2 prepare, z3 probe
    # noise, z4 body — scale_vec turns the whole row into log-factors.
    pj, bj = params.prepare_jitter, params.body_jitter
    cj, bn, sg = params.cold_start_jitter, params.benchmark_noise, params.sigma
    consts = {
        "scale_vec": jnp.stack([sg, pj, bj] + [sg, cj, pj, bn, bj] * ma),
        "log_df": jnp.log(params.day_factor),
        "log_bench_ms": jnp.log(params.benchmark_ms),
    }
    z = jnp.zeros((), f32)
    S = cfg.n_streams
    multi = S > 1
    state = VecState(
        t=z,
        pool=_Pool(
            log_speed=jnp.zeros((K,), f32) if multi else (z,) * K,
            last_used=jnp.zeros((K,), f32) if multi else (z,) * K,
            recycle=(jnp.full((K,), jnp.inf, f32) if multi
                     else (jnp.asarray(jnp.inf, f32),) * K),
            alive=(jnp.zeros((K,), bool) if multi
                   else (jnp.zeros((), bool),) * K),
            avail_from=jnp.zeros((K,), f32) if multi else None,
            avail_seq=jnp.zeros((K,), f32) if multi else None,
            filled_at=jnp.full((K,), jnp.inf, f32) if multi else None,
        ),
        # every stream submits at t=0 (workload.run_closed_loop's n_vus
        # start); ties resolve in index order like the event loop's FIFO
        streams=_Streams(
            next_ready=jnp.zeros((S,), f32),
            ended=jnp.zeros((S,), f32),
            slot=jnp.full((S,), -1, jnp.int32),
            req_start=jnp.zeros((S,), f32),
            retries=jnp.zeros((S,), jnp.int32),
            pend_bill=jnp.zeros((S,), f32),
        ) if multi else None,
        probe_w=welford_init(), log_probe_w=welford_init(),
        body_w=welford_init(), latency_w=welford_init(),
        reuse_w=welford_init(),
        # None prunes the adaptive estimator from the scan carry entirely
        # when no arm needs it (pytree None = empty subtree)
        p2=p2_init(params.pass_fraction) if cfg.adaptive else None,
        ema=z if cfg.adaptive else None,
        ema_init=jnp.zeros((), bool) if cfg.adaptive else None,
        since_publish=jnp.zeros((), jnp.int32) if cfg.adaptive else None,
        n_probes=jnp.zeros((), jnp.int32),
        n_started=z, n_terminated=z,
        nb_term=z, nb_pass=z, nb_reuse=z,
        db_term=z, db_pass=z, db_reuse=z,
    )
    step_fn = _step_multi if multi else _step
    final, requests = jax.lax.scan(
        lambda s, x: step_fn(params, cfg, consts, s, x), state,
        (u_all, ex_all), unroll=1 if cfg.adaptive else 4)
    cost = params.cost_per_ms * (final.db_term + final.db_pass
                                 + final.db_reuse) \
        + params.cost_per_invocation * (final.nb_term + final.nb_pass
                                        + final.nb_reuse)
    summary = {
        "n_requests": jnp.asarray(cfg.n_steps, f32),
        # retry-as-step (n_streams > 1): a step whose cold probe fails
        # completes no request, so completions = steps - terminations
        "n_completed": (jnp.asarray(cfg.n_steps, f32) - final.n_terminated
                        if multi else jnp.asarray(cfg.n_steps, f32)),
        "n_started": final.n_started,
        "n_terminated": final.n_terminated,
        "n_probes": jnp.asarray(final.n_probes, f32),
        "reuse_rate": final.reuse_w.mean,
        "mean_analysis_ms": final.body_w.mean,
        "std_analysis_ms": welford_std(final.body_w),
        "mean_latency_ms": final.latency_w.mean,
        "probe_mean_ms": final.probe_w.mean,
        "probe_log_mean": final.log_probe_w.mean,
        "probe_log_std": welford_std(final.log_probe_w),
        "pass_rate": 1.0 - final.n_terminated
        / jnp.maximum(jnp.asarray(final.n_probes, f32), 1.0),
        "bill_n": jnp.stack([final.nb_term, final.nb_pass, final.nb_reuse]),
        "bill_d": jnp.stack([final.db_term, final.db_pass, final.db_reuse]),
        "cost": cost,
        "horizon_ms": final.t,
    }
    return summary, requests
# ---------------------------------------------------------------------------
# Open-loop (arrival-driven) scan — DESIGN.md §12
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class OpenSimConfig:
    """Static shape of one open-loop vectorized run.

    ``n_servers`` is the autoscaling supply cap (the event engine's
    ``SubstrateKnobs.max_instances``): K server slots, each serving one
    request at a time. The scan runs the event dispatcher's admission
    pipeline in-scan (DESIGN.md §12): a static admission bound
    (``ArmParams.admit_bound``, the controller's ``on_admit``) defers
    arrivals when in-flight work reaches it, a finite
    ``ArmParams.queue_capacity`` (the engine's ``submit``) drops them
    when the wait queue is full, and a failed cold probe releases its
    slot immediately and parks the request until its requeue time
    (retry-as-park) instead of holding the slot through the whole retry
    chain. ``queue_ring`` bounds how many requests can be parked at once
    (deferred + awaiting retry); parking past the ring counts as a
    drop, never a silent loss."""

    n_steps: int
    n_servers: int = 4
    queue_ring: int = 32
    drains_per_step: int = 3
    collect_requests: bool = False
    adaptive: bool = True
    diurnal: bool = True


class OpenState(NamedTuple):
    """Scan carry for the open-loop variant. Slot state is ``(K,)``
    arrays. The park ring (``(W,)``, ``W = cfg.queue_ring``) holds
    requests not currently occupying a slot: admission-deferred arrivals
    (``park_retries == 0``) and failed probes waiting out the requeue
    delay (``park_ready`` = earliest re-dispatch time, ``inf`` = empty
    entry). ``starts`` is a circular log of recent dispatch start times:
    an arrival's wait-queue depth is the number of logged starts still
    in the future — requests with a slot promised but not yet begun
    service. The estimator tail (probe_w … since_publish, n_probes)
    matches the 7-tuple :func:`_judge_one` threads."""

    t_arr: Any                   # previous arrival's absolute time
    busy: Any                    # (K,) per-slot busy-until horizon
    log_speed: Any               # (K,)
    last_used: Any               # (K,) per-slot last completion time
    recycle: Any                 # (K,) absolute recycle deadline
    alive: Any                   # (K,)
    starts: Any                  # (W,) dispatch-start log (queue depth)
    starts_idx: Any              # i32 circular cursor into ``starts``
    park_ready: Any              # (W,) re-dispatch time, inf = empty
    park_start: Any              # (W,) original arrival (latency anchor)
    park_retries: Any            # (W,) i32 failed probes so far
    park_bill: Any               # (W,) billed ms of those failed probes
    park_wait: Any               # (W,) queue wait at FIRST dispatch
    probe_w: WelfordState
    log_probe_w: WelfordState
    body_w: WelfordState
    latency_w: WelfordState
    wait_w: WelfordState         # queue waits (the open-loop metric)
    reuse_w: WelfordState
    p2: Any
    ema: Any
    ema_init: Any
    since_publish: Any
    n_probes: Any
    n_started: Any
    n_terminated: Any
    n_completed: Any
    n_dropped: Any
    n_deferred: Any
    nb_term: Any
    nb_pass: Any
    nb_reuse: Any
    db_term: Any
    db_pass: Any
    db_reuse: Any


def _open_dispatch(params: ArmParams, cfg: OpenSimConfig, consts: dict,
                   slots, est, su, ex, t_req, rc_cur, active):
    """Place and serve ONE open-loop request dispatching at ``t_req``.

    ``slots`` is the ``(busy, log_speed, last_used, recycle, alive)``
    tuple of ``(K,)`` arrays; ``su`` one pre-scaled 8-draw block (warm
    drift/prepare/body + one cold attempt, the layout
    :func:`_attempt_values` reads at ``i=0``); ``rc_cur`` how many
    probes this request already failed (past ``max_retries`` the gate
    accepts anything, the event policy's retry budget). When ``active``
    is false all state threads through untouched and every output is a
    don't-care the caller masks.

    A failed probe is retry-as-park: the attempt bills its cold start +
    benchmark but occupies the slot for ZERO wall time — the event
    engine judges and terminates the instance synchronously at dispatch,
    so no concurrent request ever waits behind it — and the caller parks
    the request until ``requeue_at``. Each re-dispatch therefore sees
    fresh slot state and can be rescued by a slot that freed meanwhile,
    the event dispatcher's requeue semantics."""
    f32 = jnp.float32
    busy, log_speed, last_used, recycle, alive = slots
    J = jnp.exp(su)

    free = busy <= t_req
    idle_ok = ((t_req - last_used) <= params.idle_timeout_ms) \
        & (t_req < recycle)
    valid = alive & free & idle_ok
    any_valid = jnp.any(valid)
    any_free = jnp.any(free)

    # case A — warm now: reuse-order tournament (one request per slot:
    # lifo = most recently used, fifo/spread = oldest; argmax keeps the
    # lowest index on exact ties, the event pool's stable list order)
    sign = jnp.where(params.order == 0, 1.0, -1.0)
    score = jnp.where(valid, sign * last_used, -jnp.inf)
    k_a = jnp.argmax(score)
    # case B — no valid warm slot but a free one exists (dead or
    # idle/recycle-expired): cold start now, into the first free slot
    k_b = jnp.argmax(free)
    # case C — every slot busy: wait for the earliest completion; the
    # freed slot serves warm unless its recycle deadline passed while it
    # was busy (idle gap is zero by construction)
    k_c = jnp.argmin(busy)
    case_c = ~any_free
    k = jnp.where(any_valid, k_a, jnp.where(any_free, k_b, k_c))
    t_start = jnp.where(case_c, jnp.maximum(busy[k_c], t_req), t_req)
    recycled_c = case_c & (t_start >= recycle[k_c])
    served_cold = (~any_valid & any_free) | recycled_c
    any_warm = ~served_cold
    log_i = log_speed[k]

    if cfg.diurnal:
        dv = _diurnal(t_start, params.diurnal_amplitude,
                      params.diurnal_phase_h)
        day_mean = params.day_factor * dv
        log_day = consts["log_df"] + jnp.log(dv)
    else:
        day_mean = params.day_factor
        log_day = consts["log_df"]

    # warm path: AR(1) drift, prepare + body. One request per slot means
    # no load**alpha self-contention and a judge load factor of 1 — the
    # event Telemetry at per-instance concurrency 1.
    rho = params.contention_rho
    log_drifted = jnp.where(
        rho >= 1.0, log_i,
        log_day + rho * (log_i - log_day)
        + jnp.sqrt(jnp.maximum(1.0 - rho * rho, 0.0)) * su[0])
    download_w = params.prepare_ms * J[1]
    analysis_w = params.body_ms * J[2] * jnp.exp(-log_drifted)
    dur_w = download_w + analysis_w

    # cold path: ONE probe attempt (retries re-enter via the park ring)
    cold_ms, download_c, bench, log_bench, analysis_c, log_speed_c = \
        _attempt_values(params, consts, su, J, day_mean, log_day, 0)
    probed = active & served_cold & (params.gate_mode > 0) \
        & (rc_cur < params.max_retries)
    est, thr = _judge_one(params, cfg, est, bench, log_bench, probed)
    passes = (~probed) | (bench <= thr)
    completed = active & (any_warm | passes)
    fail = active & served_cold & ~passes

    ready_c = jnp.where(probed, jnp.maximum(download_c, bench), download_c)
    analysis = jnp.where(served_cold, analysis_c, analysis_w)
    service = jnp.where(served_cold, cold_ms + ready_c + analysis_c, dur_w)
    t_end = t_start + service
    probe_end = t_start + cold_ms + bench
    billed = jnp.where(
        served_cold, params.bill_cold_start * cold_ms + ready_c + analysis_c,
        dur_w)
    bill_fail = params.bill_cold_start * cold_ms + bench
    requeue_at = probe_end + params.requeue_overhead_ms \
        + params.requeue_penalty_ms
    log_speed_served = jnp.where(served_cold, log_speed_c, log_drifted)
    recycle_new = t_start + jnp.where(
        jnp.isinf(params.recycle_lifetime_ms), jnp.inf,
        ex * params.recycle_lifetime_ms)

    # a failed probe leaves no trace in the slot arrays (alive only
    # rises on a completed cold placement)
    upd = completed & (jnp.arange(busy.shape[0]) == k)
    slots = (
        jnp.where(upd, t_end, busy),
        jnp.where(upd, log_speed_served, log_speed),
        jnp.where(upd, t_end, last_used),
        jnp.where(upd, jnp.where(served_cold, recycle_new, recycle[k]),
                  recycle),
        alive | upd,
    )
    o = {
        "t_start": t_start, "t_end": t_end,
        "served_cold": active & served_cold, "completed": completed,
        "fail": fail, "analysis": analysis, "billed": billed,
        "bill_fail": bill_fail, "requeue_at": requeue_at,
    }
    return slots, est, o


def _open_step(params: ArmParams, cfg: OpenSimConfig, consts: dict,
               state: OpenState, draws):
    """One arrival of the open-loop scan, in event-dispatcher order.

    Phase 1 drains up to ``cfg.drains_per_step`` matured park-ring
    entries in FIFO-by-ready order (deferred arrivals and requeued
    retries whose ``park_ready`` has passed) through full placements —
    a drained dispatch runs at its OWN ``park_ready`` timestamp, not at
    this step's arrival time, so retry timing is exact as long as the
    drain budget keeps up. Phase 2 runs the admission pipeline on the
    step's own arrival — defer first (static ``admit_bound`` on
    in-flight work, the controller's ``on_admit``), then drop (finite
    ``queue_capacity`` on the wait queue, the engine's ``submit``) —
    and dispatches it when admitted. Each step emits
    ``drains_per_step + 1`` rows (drains first, arrival last) with
    ``completed`` / ``dropped`` / ``deferred`` masks; consumers filter.

    Approximations vs the event loop, all second-order at the parity
    operating points (measured in EXPERIMENTS.md): a fail burst larger
    than the drain budget lets a later arrival book a slot ahead of a
    matured retry (FIFO inversion); an item is deferred at most once,
    re-offered at the earliest busy horizon rather than at every
    completion; and re-offers skip the drop check (the event re-offer
    can still drop at submit)."""
    f32 = jnp.float32
    i32 = jnp.int32
    W = cfg.queue_ring
    D = cfg.drains_per_step
    u, ex, iat = draws
    su = u * consts["scale_blocks"]
    t_arr = state.t_arr + iat

    slots = (state.busy, state.log_speed, state.last_used, state.recycle,
             state.alive)
    est = (state.probe_w, state.log_probe_w, state.n_probes, state.p2,
           state.ema, state.ema_init, state.since_publish)
    park_ready, park_start = state.park_ready, state.park_start
    park_retries, park_bill = state.park_retries, state.park_bill
    park_wait = state.park_wait
    starts, sidx = state.starts, state.starts_idx

    wf = {"body_w": state.body_w, "latency_w": state.latency_w,
          "wait_w": state.wait_w, "reuse_w": state.reuse_w}
    acc = {k: getattr(state, k) for k in (
        "n_started", "n_terminated", "n_completed", "n_dropped",
        "n_deferred", "nb_term", "nb_pass", "nb_reuse",
        "db_term", "db_pass", "db_reuse")}
    rows: list = []

    def account(o, lat, wait, wait_mask, bill_prev, rc, dropped, deferred):
        cdone = o["completed"]
        warm = cdone & ~o["served_cold"]
        cp = cdone & o["served_cold"]
        failf = jnp.asarray(o["fail"], f32)
        wf["body_w"] = welford_update_masked(
            wf["body_w"], o["analysis"], cdone)
        wf["latency_w"] = welford_update_masked(wf["latency_w"], lat, cdone)
        wf["wait_w"] = welford_update_masked(wf["wait_w"], wait, wait_mask)
        wf["reuse_w"] = welford_update_masked(
            wf["reuse_w"], jnp.asarray(warm, f32), cdone)
        acc["n_started"] += jnp.asarray(o["served_cold"], f32)
        acc["n_terminated"] += failf
        acc["n_completed"] += jnp.asarray(cdone, f32)
        acc["n_dropped"] += jnp.asarray(dropped, f32)
        acc["n_deferred"] += jnp.asarray(deferred, f32)
        acc["nb_term"] += failf
        acc["nb_pass"] += jnp.asarray(cp, f32)
        acc["nb_reuse"] += jnp.asarray(warm, f32)
        acc["db_term"] += failf * o["bill_fail"]
        acc["db_pass"] += jnp.asarray(cp, f32) * o["billed"]
        acc["db_reuse"] += jnp.asarray(warm, f32) * o["billed"]
        if cfg.collect_requests:
            rows.append({
                "latency_ms": lat, "wait_ms": wait,
                "analysis_ms": o["analysis"],
                # a retry completion's bill includes its failed attempts
                "billed_ms": bill_prev + o["billed"],
                "served_by_cold": o["served_cold"],
                "retries": rc, "t_completed_ms": o["t_end"],
                # rows with completed=False are attempt/defer/drop
                # records — consumers must mask them out of per-request
                # statistics
                "completed": cdone, "dropped": dropped,
                "deferred": deferred})

    fz = jnp.zeros((), bool)
    # ---- phase 1: drain matured parked requests, FIFO by ready time ----
    for di in range(D):
        j = jnp.argmin(park_ready)
        ready_j = park_ready[j]
        drain = jnp.isfinite(ready_j) & (ready_j <= t_arr)
        rc_d = park_retries[j]
        start_d = park_start[j]
        bill_prev = park_bill[j]
        slots, est, d = _open_dispatch(
            params, cfg, consts, slots, est, su[8 * di:8 * di + 8], ex[di],
            jnp.where(drain, ready_j, t_arr), rc_d, drain)
        oh = (jnp.arange(W) == j) & drain
        park_ready = jnp.where(
            oh, jnp.where(d["fail"], d["requeue_at"], jnp.inf), park_ready)
        park_retries = jnp.where(oh & d["fail"], rc_d + 1, park_retries)
        park_bill = jnp.where(
            oh, jnp.where(d["fail"], bill_prev + d["bill_fail"], 0.0),
            park_bill)
        # queue wait = until FIRST dispatch, back-dated to arrival for
        # deferred items (run_open_loop's submitted_at_ms); requeues do
        # not reset it (Invocation.first_dispatched_at_ms), so retries
        # carry theirs through the ring
        wait_d = jnp.where(rc_d > 0, park_wait[j], d["t_start"] - start_d)
        park_wait = jnp.where(oh & d["fail"], wait_d, park_wait)
        # log the drained dispatch's start so queue-depth counts see it
        starts = jnp.where(
            drain, starts.at[sidx % W].set(d["t_start"]), starts)
        sidx = sidx + jnp.asarray(drain, i32)
        account(d, d["t_end"] - start_d, wait_d,
                drain & (rc_d == 0), bill_prev, rc_d, fz, fz)

    # ---- phase 2: admission pipeline on the step's own arrival ---------
    busy1 = slots[0]
    parked = jnp.isfinite(park_ready)
    # in-flight work the admission bound sees: in service, slot promised
    # but not yet started, or mid retry-chain. Admission-deferred parks
    # (park_retries == 0) are the event loop's pending deque — NOT
    # in-flight, exactly as ``on_admit`` counts.
    in_service = jnp.sum((busy1 > t_arr).astype(i32))
    q_wait = jnp.sum((starts > t_arr).astype(i32))
    n_retry = jnp.sum((parked & (park_retries > 0)).astype(i32))
    in_flight = in_service + q_wait + n_retry
    defer = jnp.asarray(in_flight, f32) >= params.admit_bound
    # the engine's submit drops when the wait queue is at capacity —
    # checked after admission, run_open_loop's offer → submit order
    drop = ~defer & (jnp.asarray(q_wait, f32) >= params.queue_capacity)
    admitted = ~defer & ~drop

    slots, est, a = _open_dispatch(
        params, cfg, consts, slots, est, su[8 * D:], ex[D], t_arr,
        jnp.zeros((), i32), admitted)
    starts = jnp.where(
        admitted, starts.at[sidx % W].set(a["t_start"]), starts)
    sidx = sidx + jnp.asarray(admitted, i32)

    # park the arrival when deferred, or when its probe failed (retry);
    # a full ring drops the request (counted, never silent)
    want_park = defer | a["fail"]
    empty = ~jnp.isfinite(park_ready)
    j2 = jnp.argmax(empty)
    overflow = want_park & ~jnp.any(empty)
    oh2 = (jnp.arange(W) == j2) & want_park & ~overflow
    # a deferred item re-offers at the next completion (earliest busy
    # horizon), the event loop's done → re-offer hook
    reoffer_at = jnp.maximum(jnp.min(busy1), t_arr)
    park_ready = jnp.where(
        oh2, jnp.where(defer, reoffer_at, a["requeue_at"]), park_ready)
    park_start = jnp.where(oh2, t_arr, park_start)
    park_retries = jnp.where(oh2, jnp.where(defer, 0, 1), park_retries)
    park_bill = jnp.where(oh2, jnp.where(defer, 0.0, a["bill_fail"]),
                          park_bill)
    park_wait = jnp.where(oh2, jnp.where(defer, 0.0, a["t_start"] - t_arr),
                          park_wait)
    account(a, a["t_end"] - t_arr, a["t_start"] - t_arr, admitted,
            jnp.zeros((), f32), jnp.zeros((), i32),
            drop | overflow, defer & ~overflow)

    new_state = OpenState(
        t_arr=t_arr,
        busy=slots[0], log_speed=slots[1], last_used=slots[2],
        recycle=slots[3], alive=slots[4],
        starts=starts, starts_idx=sidx,
        park_ready=park_ready, park_start=park_start,
        park_retries=park_retries, park_bill=park_bill,
        park_wait=park_wait,
        probe_w=est[0], log_probe_w=est[1],
        body_w=wf["body_w"], latency_w=wf["latency_w"],
        wait_w=wf["wait_w"], reuse_w=wf["reuse_w"],
        p2=est[3], ema=est[4], ema_init=est[5], since_publish=est[6],
        n_probes=est[2],
        **acc,
    )
    if cfg.collect_requests:
        out = {k: jnp.stack([r[k] for r in rows]) for k in rows[0]}
    else:
        out = None
    return new_state, out


def _simulate_open_chain(params: ArmParams, key, cfg: OpenSimConfig, iats):
    f32 = jnp.float32
    i32 = jnp.int32
    K = cfg.n_servers
    W = cfg.queue_ring
    D = cfg.drains_per_step
    k_normal, k_exp = jax.random.split(key)
    # one 8-draw dispatch block per drain slot plus one for the arrival —
    # retries consume the drain block of whichever later step drains them
    u_all = jax.random.normal(k_normal, (cfg.n_steps, 8 * (D + 1)), f32)
    ex_all = jax.random.exponential(k_exp, (cfg.n_steps, D + 1), f32)
    pj, bj = params.prepare_jitter, params.body_jitter
    cj, bn, sg = params.cold_start_jitter, params.benchmark_noise, params.sigma
    block = [sg, pj, bj, sg, cj, pj, bn, bj]
    consts = {
        "scale_blocks": jnp.stack(block * (D + 1)),
        "log_df": jnp.log(params.day_factor),
        "log_bench_ms": jnp.log(params.benchmark_ms),
    }
    z = jnp.zeros((), f32)
    state = OpenState(
        t_arr=z,
        busy=jnp.zeros((K,), f32),
        log_speed=jnp.zeros((K,), f32),
        last_used=jnp.zeros((K,), f32),
        recycle=jnp.full((K,), jnp.inf, f32),
        alive=jnp.zeros((K,), bool),
        # -inf: an unused log entry is never counted as a future start
        starts=jnp.full((W,), -jnp.inf, f32),
        starts_idx=jnp.zeros((), i32),
        park_ready=jnp.full((W,), jnp.inf, f32),
        park_start=jnp.zeros((W,), f32),
        park_retries=jnp.zeros((W,), i32),
        park_bill=jnp.zeros((W,), f32),
        park_wait=jnp.zeros((W,), f32),
        probe_w=welford_init(), log_probe_w=welford_init(),
        body_w=welford_init(), latency_w=welford_init(),
        wait_w=welford_init(), reuse_w=welford_init(),
        p2=p2_init(params.pass_fraction) if cfg.adaptive else None,
        ema=z if cfg.adaptive else None,
        ema_init=jnp.zeros((), bool) if cfg.adaptive else None,
        since_publish=jnp.zeros((), i32) if cfg.adaptive else None,
        n_probes=jnp.zeros((), i32),
        n_started=z, n_terminated=z,
        n_completed=z, n_dropped=z, n_deferred=z,
        nb_term=z, nb_pass=z, nb_reuse=z,
        db_term=z, db_pass=z, db_reuse=z,
    )
    final, requests = jax.lax.scan(
        lambda s, x: _open_step(params, cfg, consts, s, x), state,
        (u_all, ex_all, jnp.asarray(iats, f32)),
        unroll=1 if cfg.adaptive else 4)
    cost = params.cost_per_ms * (final.db_term + final.db_pass
                                 + final.db_reuse) \
        + params.cost_per_invocation * (final.nb_term + final.nb_pass
                                        + final.nb_reuse)
    n_steps_f = jnp.asarray(cfg.n_steps, f32)
    summary = {
        "n_requests": n_steps_f,
        # conservation (tested): every arrival completes, drops, or is
        # still parked (deferred / awaiting retry) at the horizon
        "n_completed": final.n_completed,
        "n_dropped": final.n_dropped,
        "n_deferred": final.n_deferred,
        "n_parked_end": jnp.sum(jnp.isfinite(final.park_ready).astype(f32)),
        "drop_rate": final.n_dropped / n_steps_f,
        "defer_rate": final.n_deferred / n_steps_f,
        "n_started": final.n_started,
        "n_terminated": final.n_terminated,
        "n_probes": jnp.asarray(final.n_probes, f32),
        "reuse_rate": final.reuse_w.mean,
        "mean_analysis_ms": final.body_w.mean,
        "mean_latency_ms": final.latency_w.mean,
        "mean_wait_ms": final.wait_w.mean,
        "std_wait_ms": welford_std(final.wait_w),
        "probe_mean_ms": final.probe_w.mean,
        "probe_log_std": welford_std(final.log_probe_w),
        "pass_rate": 1.0 - final.n_terminated
        / jnp.maximum(jnp.asarray(final.n_probes, f32), 1.0),
        "bill_n": jnp.stack([final.nb_term, final.nb_pass, final.nb_reuse]),
        "bill_d": jnp.stack([final.db_term, final.db_pass, final.db_reuse]),
        "cost": cost,
        "horizon_ms": final.t_arr,
    }
    return summary, requests


# ---------------------------------------------------------------------------
# Host entry points
# ---------------------------------------------------------------------------

#: compile/call accounting, so sweeps and CI can assert the jit cache hits
#: on the second arm-batch (same shapes → no recompile).
jit_stats = {"compiles": 0, "calls": 0}

_JIT_CACHE: dict = {}


def _get_sim_fn(cfg: SimConfig, batch_shape: tuple):
    cache_key = (cfg, batch_shape)
    if cache_key not in _JIT_CACHE:
        jit_stats["compiles"] += 1

        def run(params, seeds, arm_ids):
            def lane(p, seed, arm):
                key = jax.random.fold_in(jax.random.PRNGKey(seed), arm)
                return _simulate_chain(p, key, cfg)

            per_seed = jax.vmap(lane, in_axes=(None, 0, None))
            return jax.vmap(per_seed, in_axes=(0, None, 0))(
                params, seeds, arm_ids)

        _JIT_CACHE[cache_key] = jax.jit(run)
    return _JIT_CACHE[cache_key]


@dataclasses.dataclass
class VecResult:
    """Grid results as numpy arrays: summary leaves have shape
    (n_arms, n_seeds); per-request leaves (n_arms, n_seeds, n_steps)."""

    summary: dict
    requests: Optional[dict]
    n_arms: int
    n_seeds: int
    n_steps: int

    def mean_over_seeds(self, name: str) -> np.ndarray:
        return np.asarray(self.summary[name]).mean(axis=1)


def simulate_arms(
    arms: ArmParams,
    *,
    seeds,
    n_steps: int,
    pool_size: Optional[int] = None,
    n_streams: int = 1,
    max_attempts: Optional[int] = None,
    collect_requests: bool = False,
) -> VecResult:
    """Run every arm × seed lane through the jitted scan; returns numpy.

    ``n_streams`` is the number of closed-loop virtual users sharing the
    slot pool (the event engine's ``n_vus``; ``n_steps`` stays the TOTAL
    request count across streams). ``pool_size`` defaults to
    ``max(1, n_streams)`` — the smallest pool that can always place a
    cold start — and must be at least ``n_streams`` when given."""
    if n_streams < 1:
        raise ValueError(f"n_streams must be >= 1, got {n_streams}")
    if pool_size is None:
        pool_size = max(1, n_streams)
    if pool_size < n_streams:
        raise ValueError(
            f"pool_size={pool_size} < n_streams={n_streams}: a cold start "
            "could find no free slot (need pool_size >= n_streams)")
    leaves = [np.atleast_1d(np.asarray(x)) for x in arms]
    n_arms = max(leaf.shape[0] for leaf in leaves)
    stacked = ArmParams(*[
        jnp.asarray(np.broadcast_to(leaf, (n_arms,)),
                    jnp.int32 if leaf.dtype.kind in "iu" else jnp.float32)
        for leaf in leaves])
    seeds = np.atleast_1d(np.asarray(seeds, np.uint32))
    max_r = int(np.max(np.asarray(arms.max_retries)))
    if max_attempts is None:
        max_attempts = max_r + 1
    if max_attempts < max_r + 1:
        raise ValueError(
            f"max_attempts={max_attempts} cannot cover max_retries={max_r}")
    adaptive = bool(np.any(np.asarray(arms.gate_mode) == GATE_ADAPTIVE))
    diurnal = bool(np.any(np.asarray(arms.diurnal_amplitude) != 0.0))
    cfg = SimConfig(n_steps=int(n_steps), pool_size=int(pool_size),
                    max_attempts=int(max_attempts),
                    collect_requests=bool(collect_requests),
                    adaptive=adaptive, diurnal=diurnal,
                    n_streams=int(n_streams))
    fn = _get_sim_fn(cfg, (n_arms, len(seeds)))
    jit_stats["calls"] += 1
    summary, requests = fn(stacked, jnp.asarray(seeds),
                           jnp.arange(n_arms, dtype=jnp.uint32))
    summary = {k: np.asarray(v) for k, v in summary.items()}
    if requests is not None:
        # vmap axes lead, scan's step axis last → (arms, seeds, steps)
        requests = {k: np.asarray(v) for k, v in requests.items()}
    if _sanitizer.enabled():
        _sanitizer.check_finite(summary, where="simulate_arms")
    return VecResult(summary=summary, requests=requests, n_arms=n_arms,
                     n_seeds=len(seeds), n_steps=int(n_steps))


def _get_open_sim_fn(cfg: OpenSimConfig, batch_shape: tuple):
    cache_key = (cfg, batch_shape)
    if cache_key not in _JIT_CACHE:
        jit_stats["compiles"] += 1

        def run(params, seeds, arm_ids, iats):
            def lane(p, seed, arm, iat_row):
                key = jax.random.fold_in(jax.random.PRNGKey(seed), arm)
                return _simulate_open_chain(p, key, cfg, iat_row)

            # the arrival stream varies per SEED lane (one realization per
            # seed) and is shared across arms — every arm answers the same
            # offered traffic, which is what makes arms comparable
            per_seed = jax.vmap(lane, in_axes=(None, 0, None, 0))
            return jax.vmap(per_seed, in_axes=(0, None, 0, None))(
                params, seeds, arm_ids, iats)

        _JIT_CACHE[cache_key] = jax.jit(run)
    return _JIT_CACHE[cache_key]


#: one-shot latch for the think-time contract warning below (tests reset
#: it to re-assert the warning fires).
_OPEN_THINK_WARNED = False


def simulate_open_arms(
    arms: ArmParams,
    *,
    seeds,
    iats_ms: np.ndarray,
    n_servers: int = 4,
    max_attempts: Optional[int] = None,
    queue_ring: int = 32,
    drains_per_step: int = 3,
    collect_requests: bool = False,
) -> VecResult:
    """Open-loop variant of :func:`simulate_arms`: instead of a think-time
    loop, the scan consumes ``iats_ms`` — host-generated inter-arrival
    times, shape ``(n_steps,)`` (shared by every seed lane; bit-exact
    trace replay) or ``(n_seeds, n_steps)`` (one realization per seed,
    from :mod:`repro.sim.arrivals`). Each arrival runs the admission
    pipeline (defer at ``ArmParams.admit_bound``, drop at
    ``ArmParams.queue_capacity``) and then waits for the earliest of
    ``n_servers`` slots (the FIFO M/G/K queue at an autoscaling cap of
    ``max_instances = n_servers``); a failed cold probe parks and
    requeues without holding its slot (``queue_ring`` bounds the park
    ring, see :class:`OpenSimConfig`).

    Contract: ``ArmParams.think_time_ms`` is IGNORED here — arrivals
    come from ``iats_ms``, never from a think-time loop. Arms built by
    :func:`arm_from_spec` carry its default ``think_time_ms=1000``, so
    this is warned once per process rather than raised. ``max_attempts``
    is accepted for call-site compatibility and only validated: retries
    cross scan steps via the park ring, so no per-step attempt budget
    shapes the draws."""
    global _OPEN_THINK_WARNED
    if not _OPEN_THINK_WARNED and np.any(
            np.asarray(arms.think_time_ms) != 0.0):
        warnings.warn(
            "simulate_open_arms ignores ArmParams.think_time_ms: arrivals "
            "come from iats_ms, not a think-time loop (arm_from_spec "
            "defaults think_time_ms=1000, so this is expected for arms "
            "shared with the closed-loop scan). Warned once per process.",
            stacklevel=2)
        _OPEN_THINK_WARNED = True
    leaves = [np.atleast_1d(np.asarray(x)) for x in arms]
    n_arms = max(leaf.shape[0] for leaf in leaves)
    stacked = ArmParams(*[
        jnp.asarray(np.broadcast_to(leaf, (n_arms,)),
                    jnp.int32 if leaf.dtype.kind in "iu" else jnp.float32)
        for leaf in leaves])
    seeds = np.atleast_1d(np.asarray(seeds, np.uint32))
    iats = np.asarray(iats_ms, np.float32)
    if iats.ndim == 1:
        iats = np.broadcast_to(iats, (len(seeds), iats.shape[0]))
    if iats.ndim != 2 or iats.shape[0] != len(seeds):
        raise ValueError(
            f"iats_ms must be (n_steps,) or (n_seeds, n_steps); got "
            f"{np.asarray(iats_ms).shape} for {len(seeds)} seeds")
    n_steps = int(iats.shape[1])
    max_r = int(np.max(np.asarray(arms.max_retries)))
    if max_attempts is not None and max_attempts < max_r + 1:
        raise ValueError(
            f"max_attempts={max_attempts} cannot cover max_retries={max_r}")
    caps = np.asarray(arms.queue_capacity, float)
    finite_cap = caps[np.isfinite(caps)]
    if finite_cap.size and float(np.max(finite_cap)) > queue_ring:
        raise ValueError(
            f"queue_capacity={float(np.max(finite_cap)):g} exceeds "
            f"queue_ring={queue_ring}; the in-scan wait-queue counter "
            f"saturates at the ring size, so the drop gate would never "
            f"fire — raise queue_ring")
    adaptive = bool(np.any(np.asarray(arms.gate_mode) == GATE_ADAPTIVE))
    diurnal = bool(np.any(np.asarray(arms.diurnal_amplitude) != 0.0))
    cfg = OpenSimConfig(n_steps=n_steps, n_servers=int(n_servers),
                        queue_ring=int(queue_ring),
                        drains_per_step=int(drains_per_step),
                        collect_requests=bool(collect_requests),
                        adaptive=adaptive, diurnal=diurnal)
    fn = _get_open_sim_fn(cfg, (n_arms, len(seeds)))
    jit_stats["calls"] += 1
    summary, requests = fn(stacked, jnp.asarray(seeds),
                           jnp.arange(n_arms, dtype=jnp.uint32),
                           jnp.asarray(iats))
    summary = {k: np.asarray(v) for k, v in summary.items()}
    if requests is not None:
        requests = {k: np.asarray(v) for k, v in requests.items()}
    if _sanitizer.enabled():
        _sanitizer.check_open_summary(summary, n_steps,
                                      where="simulate_open_arms")
    return VecResult(summary=summary, requests=requests, n_arms=n_arms,
                     n_seeds=len(seeds), n_steps=n_steps)


# ---------------------------------------------------------------------------
# Arm builders (mirror FaaSPlatform's spec/profile knob resolution)
# ---------------------------------------------------------------------------


def arm_from_spec(
    spec,
    variation,
    *,
    profile=None,
    pricing: Optional[Pricing] = None,
    gate: str = "fixed",
    threshold: float = math.inf,
    pass_fraction: float = 0.4,
    max_retries: int = 5,
    warmup_reports: int = 5,
    republish_every: int = 4,
    smoothing_alpha: float = 0.7,
    think_time_ms: float = 1000.0,
    admit_bound: Optional[float] = None,
) -> ArmParams:
    """Build one arm from the event engine's own config objects
    (:class:`~repro.sim.platform.FunctionSpec`,
    :class:`~repro.sim.platform.PlatformProfile`,
    :class:`~repro.sim.variation.VariationModel`) so a parity test or grid
    sweep describes *one* scenario for both engines. ``gate`` is "off"
    (baseline arm), "fixed" (pre-tested ``threshold``) or "adaptive"
    (:class:`~repro.core.policy.AdaptiveMinosPolicy` defaults).

    Per-instance concurrency, the load-slowdown alpha, load-aware gating
    and the finite queue buffer come from the resolved knobs (profile or
    spec); ``admit_bound`` is the static admission cap the open-loop scan
    defers at (:func:`repro.core.control.static_admission_bound` computes
    the event engine's equivalent), ``None`` = admission disabled."""
    gate_mode = {"off": GATE_OFF, "fixed": GATE_FIXED,
                 "adaptive": GATE_ADAPTIVE}[gate]
    if gate_mode == GATE_FIXED and not math.isfinite(threshold):
        raise ValueError("gate='fixed' needs a finite threshold")
    if profile is not None:
        knobs = profile.knobs()
        if pricing is None:
            pricing = profile.pricing
    else:
        from repro.core.substrate import SubstrateKnobs
        knobs = SubstrateKnobs(
            cold_start_ms=spec.cold_start_ms,
            cold_start_jitter=spec.cold_start_jitter,
            idle_timeout_ms=spec.idle_timeout_ms,
            recycle_lifetime_ms=spec.recycle_lifetime_ms,
            bill_cold_start=spec.bill_cold_start,
            requeue_overhead_ms=spec.requeue_overhead_ms,
        )
    if pricing is None:
        raise ValueError("pricing is required when no profile is given")
    return ArmParams(
        sigma=float(variation.sigma),
        day_factor=float(variation.day_factor),
        diurnal_amplitude=float(variation.diurnal_amplitude),
        diurnal_phase_h=float(variation.diurnal_phase_h),
        prepare_ms=float(spec.prepare_ms),
        prepare_jitter=float(spec.prepare_jitter),
        body_ms=float(spec.body_ms),
        body_jitter=float(spec.body_jitter),
        benchmark_ms=float(spec.benchmark_ms),
        benchmark_noise=float(spec.benchmark_noise),
        contention_rho=float(spec.contention_rho),
        cold_start_ms=float(knobs.cold_start_ms),
        cold_start_jitter=float(knobs.cold_start_jitter),
        idle_timeout_ms=float(knobs.idle_timeout_ms),
        recycle_lifetime_ms=(
            math.inf if knobs.recycle_lifetime_ms is None
            else float(knobs.recycle_lifetime_ms)),
        bill_cold_start=1.0 if knobs.bill_cold_start else 0.0,
        requeue_overhead_ms=float(knobs.requeue_overhead_ms),
        requeue_penalty_ms=0.0,
        order=int(ORDER_CODES[knobs.warm_pool_order]),
        gate_mode=int(gate_mode),
        threshold=float(threshold),
        pass_fraction=float(pass_fraction),
        max_retries=int(max_retries),
        warmup_reports=int(warmup_reports),
        republish_every=int(republish_every),
        smoothing_alpha=float(smoothing_alpha),
        think_time_ms=float(think_time_ms),
        cost_per_invocation=float(pricing.cost_per_invocation),
        cost_per_ms=float(pricing.cost_per_ms),
        concurrency=int(knobs.per_instance_concurrency),
        load_slowdown_alpha=float(knobs.load_slowdown_alpha),
        gate_load_aware=1.0 if knobs.gate_load_aware else 0.0,
        queue_capacity=(
            math.inf if knobs.queue_capacity is None
            else float(knobs.queue_capacity)),
        admit_bound=math.inf if admit_bound is None else float(admit_bound),
    )


def stack_arms(arms: list) -> ArmParams:
    """Stack a list of scalar :class:`ArmParams` into one batched pytree."""
    if not arms:
        raise ValueError("need at least one arm")
    return ArmParams(*[
        np.asarray([getattr(a, f) for a in arms]) for f in ArmParams._fields])


# ---------------------------------------------------------------------------
# Event-engine reference chain (the exact scenario the fast path models)
# ---------------------------------------------------------------------------


def run_event_chain(platform, n_requests: int,
                    think_time_ms: float = 1000.0, n_vus: int = 1) -> list:
    """Drive a :class:`~repro.sim.platform.FaaSPlatform` with ``n_vus``
    closed-loop virtual users for exactly ``n_requests`` total
    completions — the event-engine scenario :func:`simulate_arms`
    vectorizes (``n_vus`` maps to its ``n_streams``). All users submit at
    t=0 (like :func:`repro.sim.workload.run_closed_loop`), each resubmits
    ``think_time_ms`` after its completion while the budget lasts. Used
    by the parity tests and as the sweeps' per-arm timing reference."""
    results: list = []
    # budget is reserved at SCHEDULING time, so concurrent completions
    # (n_vus > 1) can never over-submit past n_requests
    budget = n_requests

    def on_complete(res) -> None:
        nonlocal budget
        results.append(res)
        if budget > 0:
            budget -= 1
            platform.loop.after(
                think_time_ms, lambda: platform.submit(None, on_complete))

    for _ in range(min(n_vus, n_requests)):
        budget -= 1
        platform.submit(None, on_complete)
    platform.loop.run_all()
    assert len(results) == n_requests
    return results


__all__ = [
    "ArmParams",
    "GATE_ADAPTIVE",
    "GATE_FIXED",
    "GATE_OFF",
    "ORDER_CODES",
    "OpenSimConfig",
    "SimConfig",
    "VecResult",
    "arm_from_spec",
    "jit_stats",
    "run_event_chain",
    "simulate_arms",
    "simulate_open_arms",
    "stack_arms",
]
