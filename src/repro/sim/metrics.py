"""Metric aggregation for experiment arms (paper Figs 4-7)."""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.cost import WorkflowCost
from .platform import FaaSPlatform, RequestResult


@dataclasses.dataclass
class ArmSummary:
    """One experiment arm (baseline or Minos) on one day."""

    name: str
    n_successful: int
    n_instance_starts: int
    n_terminated: int
    mean_analysis_ms: float
    median_analysis_ms: float
    mean_download_ms: float
    mean_latency_ms: float
    total_cost: float
    cost_per_million: float
    mean_retries: float
    warm_pool_mean_speed: float
    cost: WorkflowCost

    @staticmethod
    def from_platform(name: str, platform: FaaSPlatform, results: list[RequestResult]) -> "ArmSummary":
        analysis = np.array([r.analysis_ms for r in results]) if results else np.array([np.nan])
        download = np.array([r.download_ms for r in results]) if results else np.array([np.nan])
        latency = np.array([r.latency_ms for r in results]) if results else np.array([np.nan])
        retries = np.array([r.retries for r in results]) if results else np.array([0.0])
        pool = platform.warm_pool_speeds  # cached immutable view — not ours to mutate
        return ArmSummary(
            name=name,
            n_successful=len(results),
            n_instance_starts=platform.instances_started,
            n_terminated=platform.instances_terminated,
            mean_analysis_ms=float(analysis.mean()),
            median_analysis_ms=float(np.median(analysis)),
            mean_download_ms=float(download.mean()),
            mean_latency_ms=float(latency.mean()),
            total_cost=platform.cost.total,
            cost_per_million=platform.cost.cost_per_million_successful(),
            mean_retries=float(retries.mean()),
            warm_pool_mean_speed=float(np.mean(pool)) if pool else float("nan"),
            cost=platform.cost,
        )


def improvement(baseline: float, treatment: float) -> float:
    """Relative improvement (positive = treatment better/lower)."""
    return (baseline - treatment) / baseline


def slo_attainment_by_class(result_classes, latencies_ms, qos_classes) -> tuple:
    """Per-class SLO attainment: fraction of COMPLETED requests of each
    class finishing within its :attr:`~repro.sim.arrivals.QoSClass.slo_ms`.

    Classes without an SLO are skipped. Completed-only carries the same
    survivorship caveat as the latency percentiles (see
    :class:`OpenLoopSummary`): dropped / dead-lettered / still-pending
    requests never appear, so under overload read attainment alongside
    ``drop_rate`` — 100% attainment over 10% of the traffic is not an
    SLO win. A class with an SLO but no completions reports NaN."""
    if not qos_classes:
        return ()
    cls = np.asarray(list(result_classes))
    lat = np.asarray(list(latencies_ms), float)
    out = []
    for c in qos_classes:
        slo = getattr(c, "slo_ms", None)
        if slo is None:
            continue
        mine = lat[cls == c.name] if cls.size else np.empty(0)
        out.append({
            "qos": c.name,
            "slo_ms": float(slo),
            "n_completed": int(mine.size),
            "attainment": float((mine <= slo).mean()) if mine.size
            else float("nan"),
        })
    return tuple(out)


@dataclasses.dataclass
class WorkflowSummary:
    """One (workflow × platform × arm) cell of the sweep
    (EXPERIMENTS.md §Workflow sweep)."""

    name: str
    arm: str
    n_items: int
    mean_item_latency_ms: float
    median_item_latency_ms: float
    mean_item_analysis_ms: float
    total_cost: float
    cost_per_million_items: float
    n_instance_starts: int
    n_terminated: int
    mean_item_retries: float

    @staticmethod
    def from_run(arm: str, run) -> "WorkflowSummary":
        """``run`` is a :class:`~repro.sim.workflow_dag.WorkflowRunResult`
        (duck-typed to keep this module free of a workflow_dag import)."""
        retries = (
            float(np.mean([i.total_retries for i in run.items])) if run.items else 0.0
        )
        return WorkflowSummary(
            name=run.dag.name,
            arm=arm,
            n_items=run.n_items,
            mean_item_latency_ms=run.mean_item_latency_ms,
            median_item_latency_ms=run.median_item_latency_ms,
            mean_item_analysis_ms=run.mean_item_analysis_ms,
            total_cost=run.cost.total,
            cost_per_million_items=run.cost_per_million_items,
            n_instance_starts=run.engine.instances_started,
            n_terminated=run.engine.instances_terminated,
            mean_item_retries=retries,
        )


@dataclasses.dataclass
class OpenLoopSummary:
    """One open-loop arm (EXPERIMENTS.md §Open-loop sweep).

    Latency percentiles are over COMPLETED requests — the usual SLO view,
    and under queue blow-up a survivorship-biased one: requests still
    stuck in the queue (or parked at admission) when the run ends never
    reach the completed set, so completed-only P99 can *fall* as overload
    worsens. ``wait_p99_ms`` is therefore computed over ALL arrived
    requests' queue waits: completed requests' waits, the censored waits
    of everything still pending at the end, and 0.0 for each dropped
    request (a drop is refused instantly; it appears as ``drop_rate``,
    not as wait). Regression-tested in tests/test_arrivals.py."""

    name: str
    process: str
    n_arrived: int
    n_completed: int
    n_dropped: int
    n_deferred: int
    drop_rate: float
    defer_rate: float
    mean_latency_ms: float
    p50_latency_ms: float
    p95_latency_ms: float
    p99_latency_ms: float
    completed_wait_p99_ms: float   # the survivorship-biased version
    wait_p99_ms: float             # over ALL arrived requests
    mean_system_population: float  # time-averaged L (Little's law)
    total_cost: float
    cost_per_1k: float
    n_instance_starts: int
    n_terminated: int
    # retries exhausted under fault injection (DESIGN.md §15); 0 fault-free
    n_dead_lettered: int = 0
    # per-class SLO attainment rows (slo_attainment_by_class); () when no
    # class defines an slo_ms or qos_classes was not passed to from_run
    slo_attainment: tuple = ()

    @staticmethod
    def from_run(name: str, engine, run,
                 qos_classes=None) -> "OpenLoopSummary":
        """``engine`` is a :class:`~repro.core.substrate.SubstrateEngine`,
        ``run`` an :class:`~repro.sim.arrivals.OpenLoopRun` (duck-typed,
        as elsewhere in this module). ``qos_classes`` (the same sequence
        handed to run_open_loop) enables per-class SLO attainment."""
        lat = np.asarray([r.latency_ms for r in run.results]) \
            if run.results else np.asarray([np.nan])
        completed_waits = np.asarray(
            [r.queue_wait_ms for r in run.results]) \
            if run.results else np.asarray([0.0])
        all_waits = np.concatenate([
            completed_waits if run.results else np.empty(0),
            np.asarray(run.censored_waits_ms, float),
            np.zeros(run.n_dropped),
        ]) if (run.results or run.censored_waits_ms or run.n_dropped) \
            else np.asarray([0.0])
        return OpenLoopSummary(
            name=name,
            process=getattr(run, "process_name", "?"),
            n_arrived=run.n_arrived,
            n_completed=run.n_completed,
            n_dropped=run.n_dropped,
            n_deferred=run.n_deferred_items,
            drop_rate=run.drop_rate,
            defer_rate=run.defer_rate,
            mean_latency_ms=float(lat.mean()),
            p50_latency_ms=float(np.percentile(lat, 50)),
            p95_latency_ms=float(np.percentile(lat, 95)),
            p99_latency_ms=float(np.percentile(lat, 99)),
            completed_wait_p99_ms=float(np.percentile(completed_waits, 99)),
            wait_p99_ms=float(np.percentile(all_waits, 99)),
            mean_system_population=run.mean_system_population(),
            total_cost=engine.cost.total,
            cost_per_1k=engine.cost.total / max(run.n_completed, 1) * 1e3,
            n_instance_starts=engine.instances_started,
            n_terminated=engine.instances_terminated,
            n_dead_lettered=getattr(run, "n_dead_lettered", 0),
            slo_attainment=slo_attainment_by_class(
                run.result_classes,
                [r.latency_ms for r in run.results], qos_classes),
        )

    @staticmethod
    def from_vec(name: str, result, arm: int = 0, *,
                 process: str = "poisson") -> "OpenLoopSummary":
        """Summarize one arm of a vectorized open-loop run
        (:func:`repro.sim.vectorized.simulate_open_arms` with
        ``collect_requests=True``), pooled across seeds.

        Mirrors :meth:`from_run` with one censoring caveat: the scan does
        not expose per-request censored waits for requests still parked
        when the horizon ends (``n_parked_end``), so ``wait_p99_ms`` here
        pools completed requests' waits plus a zero per drop — the parked
        tail is omitted rather than guessed. ``n_parked_end`` is small at
        the calibrated loads (≲1 per lane; tests/test_vectorized_parity.py)
        and the omission biases ``wait_p99_ms`` *down*, so treat it as a
        floor under heavy overload. ``mean_system_population`` is Little's
        L from completed work only: Σ latency / horizon, per seed, then
        averaged."""
        if result.requests is None:
            raise ValueError(
                "OpenLoopSummary.from_vec needs per-request rows; rerun "
                "simulate_open_arms with collect_requests=True")
        s = {k: np.asarray(v[arm], float) for k, v in result.summary.items()}
        # (n_seeds, n_steps, D+1) rows; only `completed` rows carry a request
        comp = np.asarray(result.requests["completed"][arm]).astype(bool)
        lat = np.asarray(result.requests["latency_ms"][arm], float)
        wait = np.asarray(result.requests["wait_ms"][arm], float)
        n_arrived = int(s["n_requests"].sum())
        n_completed = int(s["n_completed"].sum())
        n_dropped = int(s["n_dropped"].sum())
        lat_c = lat[comp] if comp.any() else np.asarray([np.nan])
        wait_c = wait[comp] if comp.any() else np.asarray([0.0])
        all_waits = np.concatenate([wait_c, np.zeros(n_dropped)]) \
            if (comp.any() or n_dropped) else np.asarray([0.0])
        # per-seed Little's L, then mean over seeds
        horizon = np.maximum(s["horizon_ms"], 1.0)
        lat_sum = np.where(comp, lat, 0.0).sum(axis=(1, 2))
        total_cost = float(s["cost"].sum())
        return OpenLoopSummary(
            name=name,
            process=process,
            n_arrived=n_arrived,
            n_completed=n_completed,
            n_dropped=n_dropped,
            n_deferred=int(s["n_deferred"].sum()),
            drop_rate=n_dropped / max(n_arrived, 1),
            defer_rate=int(s["n_deferred"].sum()) / max(n_arrived, 1),
            mean_latency_ms=float(lat_c.mean()),
            p50_latency_ms=float(np.percentile(lat_c, 50)),
            p95_latency_ms=float(np.percentile(lat_c, 95)),
            p99_latency_ms=float(np.percentile(lat_c, 99)),
            completed_wait_p99_ms=float(np.percentile(wait_c, 99)),
            wait_p99_ms=float(np.percentile(all_waits, 99)),
            mean_system_population=float((lat_sum / horizon).mean()),
            total_cost=total_cost,
            cost_per_1k=total_cost / max(n_completed, 1) * 1e3,
            n_instance_starts=int(s["n_started"].sum()),
            n_terminated=int(s["n_terminated"].sum()),
        )


@dataclasses.dataclass
class FleetSummary:
    """One fleet-router arm (EXPERIMENTS.md §Fleet sweep).

    Latency percentiles pool the *logical winners* across fleets — each
    hedged request counts exactly once, at its first completion.
    ``total_cost`` is the router's accounting (honest by default: both
    copies of a hedged request are billed; see
    :class:`~repro.fleet.router.FleetRouter.count_hedge_waste`), so a
    policy cannot look cheap by paying for speculation off the books.
    ``per_fleet`` rows expose where the policy actually sent traffic."""

    name: str
    process: str
    n_arrived: int
    n_completed: int
    n_dropped: int
    drop_rate: float
    mean_latency_ms: float
    p50_latency_ms: float
    p95_latency_ms: float
    p99_latency_ms: float
    total_cost: float
    cost_per_1k: float
    n_hedges: int
    n_hedge_wins: int
    hedge_waste_cost: float
    per_fleet: tuple
    # -- failure resilience (DESIGN.md §15); zeros/() fault-free --
    n_rejected: int = 0
    n_shed: int = 0
    n_dead_lettered: int = 0
    breaker_opens: tuple = ()
    slo_attainment: tuple = ()

    @staticmethod
    def from_run(name: str, router, run, qos_classes=None) -> "FleetSummary":
        """``router`` is a :class:`~repro.fleet.router.FleetRouter`,
        ``run`` a :class:`~repro.fleet.router.FleetRunResult` (duck-typed,
        as elsewhere in this module). ``qos_classes`` (the sequence handed
        to run_fleet_open_loop) enables per-class SLO attainment."""
        lat = np.asarray([r.latency_ms for r in run.results]) \
            if run.results else np.asarray([np.nan])
        fleet_idx = np.asarray(run.result_fleets, int) \
            if run.result_fleets else np.empty(0, int)
        per_fleet = []
        for i, fname in enumerate(run.fleet_names):
            mine = fleet_idx == i
            mine_lat = lat[mine] if mine.any() else np.asarray([np.nan])
            engine = router.engines[i]
            per_fleet.append({
                "fleet": fname,
                "share": float(mine.sum()) / max(run.n_completed, 1),
                "completed": int(mine.sum()),
                "dropped": int(run.per_fleet["per_fleet_dropped"][i]),
                "parked": int(run.per_fleet["per_fleet_parked"][i]),
                "p95_ms": float(np.percentile(mine_lat, 95)),
                "cost": float(engine.cost.total),
            })
        return FleetSummary(
            name=name,
            process=getattr(run, "process_name", "?"),
            n_arrived=run.n_arrived,
            n_completed=run.n_completed,
            n_dropped=run.n_dropped,
            drop_rate=run.drop_rate,
            mean_latency_ms=float(lat.mean()),
            p50_latency_ms=float(np.percentile(lat, 50)),
            p95_latency_ms=float(np.percentile(lat, 95)),
            p99_latency_ms=float(np.percentile(lat, 99)),
            total_cost=run.total_cost,
            cost_per_1k=run.total_cost / max(run.n_completed, 1) * 1e3,
            n_hedges=run.n_hedges,
            n_hedge_wins=run.n_hedge_wins,
            hedge_waste_cost=run.hedge_waste_cost,
            per_fleet=tuple(per_fleet),
            n_rejected=getattr(run, "n_rejected", 0),
            n_shed=getattr(run, "n_shed", 0),
            n_dead_lettered=getattr(run, "n_dead_lettered", 0),
            breaker_opens=tuple(getattr(run, "breaker_opens", ())),
            slo_attainment=slo_attainment_by_class(
                run.result_classes,
                [r.latency_ms for r in run.results], qos_classes),
        )


def cost_timeline(
    results: list[RequestResult],
    cost: WorkflowCost,
    window_end_ms: float,
    n_points: int = 200,
    termination_events: list[tuple[float, float]] | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Running average cost per successful request over elapsed time (Fig 7).

    Cost accrues time-locally: each successful request is billed at its
    completion; each terminated instance is billed at crash time. This
    reproduces the paper's shape — Minos more expensive in the first ~200 s
    (cold-start termination burst), crossing under the baseline later."""
    if not results:
        return np.array([]), np.array([])
    order = np.argsort([r.t_completed_ms for r in results])
    times = np.array([results[i].t_completed_ms for i in order])
    per_req = np.array(
        [
            cost.pricing.cost_per_invocation
            + cost.pricing.cost_per_ms * (results[i].download_ms + results[i].analysis_ms)
            for i in order
        ]
    )
    grid = np.linspace(times[0], window_end_ms, n_points)
    idx = np.clip(np.searchsorted(times, grid, side="right"), 1, len(per_req))
    cum_cost = np.cumsum(per_req)[idx - 1]
    cum_n = np.arange(1, len(per_req) + 1)[idx - 1]
    if termination_events:
        t_term = np.array([t for t, _ in termination_events])
        c_term = np.array(
            [
                cost.pricing.cost_per_invocation + cost.pricing.cost_per_ms * billed
                for _, billed in termination_events
            ]
        )
        o = np.argsort(t_term)
        t_term, c_term = t_term[o], np.cumsum(c_term[o])
        j = np.searchsorted(t_term, grid, side="right")
        cum_cost = cum_cost + np.where(j > 0, c_term[np.clip(j - 1, 0, None)], 0.0)
    return grid, cum_cost / cum_n
