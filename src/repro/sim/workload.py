"""Workload generators (paper §III-A) and workflow DAG driving.

* :func:`run_closed_loop` — the paper's workload: N virtual users, each
  sends a request, waits for completion, sleeps 1 s, repeats; for a fixed
  experiment window.
* :class:`WorkflowSpec` / :func:`run_workflow` — multi-stage chains
  ("data processing and machine learning workflows"); each stage is its own
  function with its own warm pool, so longer workflows re-use the fast pool
  more often — the paper's compounding argument.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

from .platform import FaaSPlatform, RequestResult


def run_closed_loop(
    platform: FaaSPlatform,
    *,
    n_vus: int = 10,
    think_time_ms: float = 1000.0,
    duration_ms: float = 30 * 60 * 1000.0,
    start_ms: float = 0.0,
) -> list[RequestResult]:
    """Drive ``platform`` with closed-loop VUs; returns results completed
    inside the window. Requests still in flight at the window end are
    discarded (the paper counts successful requests per 30-min window)."""
    window_end = start_ms + duration_ms
    completed: list[RequestResult] = []

    def make_vu(vu_id: int):
        def on_complete(res: RequestResult) -> None:
            if res.t_completed_ms <= window_end:
                completed.append(res)
            next_t = res.t_completed_ms + think_time_ms
            if next_t < window_end:
                platform.loop.at(next_t, lambda: platform.submit({"vu": vu_id}, on_complete))

        return on_complete

    for vu in range(n_vus):
        cb = make_vu(vu)
        platform.loop.at(start_ms, lambda cb=cb, vu=vu: platform.submit({"vu": vu}, cb))

    platform.loop.run_until(window_end)
    # drain without counting (in-flight at window end)
    platform.loop.run_all(hard_limit_ms=window_end + 10 * 60 * 1000.0)
    return completed


@dataclasses.dataclass(frozen=True)
class WorkflowSpec:
    """A linear chain of stage functions (DAG support reduces to chains for
    the paper's use case; each stage may have its own spec)."""

    stage_platforms: Sequence[FaaSPlatform]

    def __len__(self) -> int:
        return len(self.stage_platforms)


def run_workflow(
    workflow: WorkflowSpec,
    *,
    n_items: int,
    inter_arrival_ms: float = 500.0,
) -> list[list[RequestResult]]:
    """Push ``n_items`` through the stage chain; stage k+1 is submitted when
    stage k completes. All stages share one simulated clock (stage 0's loop
    drives; stages must be constructed with the same loop — see
    :func:`make_chain`). Returns per-stage results."""
    loop = workflow.stage_platforms[0].loop
    for p in workflow.stage_platforms:
        if p.loop is not loop:
            raise ValueError("all workflow stages must share one event loop")
    per_stage: list[list[RequestResult]] = [[] for _ in workflow.stage_platforms]

    def submit_stage(k: int, item: int) -> None:
        plat = workflow.stage_platforms[k]

        def on_complete(res: RequestResult) -> None:
            per_stage[k].append(res)
            if k + 1 < len(workflow.stage_platforms):
                submit_stage(k + 1, item)

        plat.submit({"item": item, "stage": k}, on_complete)

    for i in range(n_items):
        loop.at(i * inter_arrival_ms, lambda i=i: submit_stage(0, i))

    loop.run_all(hard_limit_ms=1e12)
    return per_stage


def make_chain(specs, variation, policy, pricing, seed: int = 0) -> WorkflowSpec:
    """Build a stage chain sharing one event loop."""
    plats = []
    for i, spec in enumerate(specs):
        p = FaaSPlatform(spec, variation, policy, pricing, seed=seed + 97 * i)
        if plats:
            p.loop = plats[0].loop
        plats.append(p)
    return WorkflowSpec(tuple(plats))
