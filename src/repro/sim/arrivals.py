"""Open-loop arrival traffic: processes, QoS classes, and the driver
(DESIGN.md §12; ROADMAP item 1).

Every sweep before this module was closed-loop — a fixed lane count where
the next request fires on completion — so the paper's economic claim was
never tested in the regime where it matters: sustained open-loop traffic
where requeue storms, autoscaling lag, and queue blow-up feed back into
latency and cost. Here arrivals enqueue *independently* of completions.

The configuration idiom follows faas-offloading-sim (SNIPPETS §2): a
function's workload is either a Poisson ``rate`` or a replayable
inter-arrival-time ``trace`` file, and requests carry per-class QoS
arrival weights. Burst and diurnal rate shapes follow the Night Shift
variability methodology (PAPERS.md).

Pieces:

* :class:`ArrivalProcess` — the protocol: draw ``n`` inter-arrival times
  (ms). Implementations: :class:`PoissonProcess` (exponential IATs),
  :class:`MMPPProcess` (2-phase Markov-modulated on/off bursts),
  :class:`DiurnalPoissonProcess` (sinusoidally modulated rate, matching
  :meth:`~repro.sim.variation.VariationModel.diurnal`'s shape), and
  :class:`TraceProcess` (bit-exact, seed-independent file replay).
* :class:`QoSClass` — named arrival-weight classes; arrivals draw a class
  proportionally to weight (the faas-offloading-sim ``arrival-weight``).
* :func:`run_open_loop` — drive one
  :class:`~repro.core.substrate.SubstrateEngine` with an arrival process:
  arrivals flow through the controller's ``on_admit`` decision point
  (deferral back-pressure — this is where
  :class:`~repro.core.control.QueueAwareAdmissionController` finally sees
  real pressure), then ``engine.submit`` (which may drop at a finite
  ``queue_capacity``); the driver samples the system population on a
  fixed cadence so Little's law is measurable *independently* of the
  per-request latencies it is compared against.

Determinism: every random draw comes from the caller's RandomState;
:class:`TraceProcess` draws nothing at all.
"""
from __future__ import annotations

import collections
import dataclasses
import math
from typing import Any, Callable, List, Optional, Protocol, Sequence, runtime_checkable

import numpy as np

from repro.analysis import sanitizer as _sanitizer
from repro.core.control import AdmitContext, AdmitDecision
from repro.core.substrate import RequestResult, SubstrateEngine


# ---------------------------------------------------------------------------
# Processes
# ---------------------------------------------------------------------------


@runtime_checkable
class ArrivalProcess(Protocol):
    """A stream of inter-arrival times (milliseconds)."""

    name: str

    def iats_ms(self, rng: np.random.RandomState, n: int) -> np.ndarray:
        """Draw the first ``n`` inter-arrival times of one realization.

        Must be a *prefix-consistent* single pass: calling with larger
        ``n`` extends the same realization for a fresh ``rng`` in the
        same state (everything here draws sequentially, so cloning the
        RandomState reproduces the stream)."""
        ...

    def mean_rate_per_ms(self) -> float:
        """Long-run mean arrival rate (1/ms) — the λ of Little's law."""
        ...


@dataclasses.dataclass(frozen=True)
class PoissonProcess:
    """Homogeneous Poisson arrivals: IATs ~ Exponential(rate)."""

    rate_per_s: float
    name: str = "poisson"

    def __post_init__(self) -> None:
        if self.rate_per_s <= 0.0:
            raise ValueError("rate_per_s must be > 0")

    def iats_ms(self, rng: np.random.RandomState, n: int) -> np.ndarray:
        return rng.exponential(1000.0 / self.rate_per_s, size=n)

    def mean_rate_per_ms(self) -> float:
        return self.rate_per_s / 1000.0


@dataclasses.dataclass(frozen=True)
class MMPPProcess:
    """2-phase Markov-modulated Poisson process (on/off bursts).

    The rate alternates between a ``base`` (off) and a ``burst`` (on)
    Poisson rate; phase residence times are exponential with the given
    means. This is the standard burstiness model whose index of
    dispersion exceeds 1 (Poisson's), so it stresses exactly what a
    mean-rate ladder hides: admission control and queue blow-up during
    the on-phase, drain behavior after it.
    """

    base_rate_per_s: float
    burst_rate_per_s: float
    mean_off_ms: float = 20_000.0
    mean_on_ms: float = 5_000.0
    start_on: bool = False
    name: str = "mmpp"

    def __post_init__(self) -> None:
        if self.base_rate_per_s <= 0.0 or self.burst_rate_per_s <= 0.0:
            raise ValueError("rates must be > 0")
        if self.mean_off_ms <= 0.0 or self.mean_on_ms <= 0.0:
            raise ValueError("phase means must be > 0")

    def iats_with_phase(
        self, rng: np.random.RandomState, n: int
    ) -> tuple[np.ndarray, np.ndarray]:
        """(iats_ms, on_phase) — ``on_phase[i]`` is True when arrival ``i``
        lands in the burst phase (what the admission-under-burst test
        conditions on)."""
        rates = (self.base_rate_per_s / 1000.0, self.burst_rate_per_s / 1000.0)
        means = (self.mean_off_ms, self.mean_on_ms)
        iats = np.empty(n)
        on = np.empty(n, bool)
        phase = 1 if self.start_on else 0
        phase_left = rng.exponential(means[phase])
        waited = 0.0  # time since the previous arrival
        i = 0
        while i < n:
            gap = rng.exponential(1.0 / rates[phase])
            if gap < phase_left:
                # arrival inside the current phase
                phase_left -= gap
                iats[i] = waited + gap
                on[i] = bool(phase)
                waited = 0.0
                i += 1
            else:
                # phase switch first: the exponential gap restarts in the
                # new phase (memorylessness makes this exact)
                waited += phase_left
                phase = 1 - phase
                phase_left = rng.exponential(means[phase])
        return iats, on

    def iats_ms(self, rng: np.random.RandomState, n: int) -> np.ndarray:
        return self.iats_with_phase(rng, n)[0]

    def mean_rate_per_ms(self) -> float:
        # stationary phase occupancy is proportional to the residence means
        w_on = self.mean_on_ms / (self.mean_on_ms + self.mean_off_ms)
        rate_s = (w_on * self.burst_rate_per_s
                  + (1.0 - w_on) * self.base_rate_per_s)
        return rate_s / 1000.0


@dataclasses.dataclass(frozen=True)
class DiurnalPoissonProcess:
    """Poisson arrivals with a sinusoidal day curve (thinning).

    rate(t) = base · (1 + amplitude · cos(2π(hour − phase_h)/24)) — the
    same shape :meth:`~repro.sim.variation.VariationModel.diurnal`
    applies to instance *speeds*, applied to demand: load peaks are when
    contention (and the paper's variability) peaks. Sampled exactly via
    Lewis-Shedler thinning at the peak rate."""

    base_rate_per_s: float
    amplitude: float = 0.3
    phase_h: float = 14.0
    period_ms: float = 24 * 3.6e6
    name: str = "diurnal"

    def __post_init__(self) -> None:
        if self.base_rate_per_s <= 0.0:
            raise ValueError("base_rate_per_s must be > 0")
        if not 0.0 <= self.amplitude < 1.0:
            raise ValueError("amplitude must be in [0,1)")

    def _rate_per_ms(self, t_ms: np.ndarray) -> np.ndarray:
        frac = (t_ms / self.period_ms) % 1.0
        phase_frac = self.phase_h / 24.0
        mod = 1.0 + self.amplitude * np.cos(2.0 * np.pi * (frac - phase_frac))
        return (self.base_rate_per_s / 1000.0) * mod

    def iats_ms(self, rng: np.random.RandomState, n: int) -> np.ndarray:
        peak = (self.base_rate_per_s / 1000.0) * (1.0 + self.amplitude)
        times: List[float] = []
        t = 0.0
        while len(times) < n:
            m = max(64, 2 * (n - len(times)))
            gaps = rng.exponential(1.0 / peak, size=m)
            cand = t + np.cumsum(gaps)
            keep = rng.uniform(size=m) < self._rate_per_ms(cand) / peak
            times.extend(cand[keep][: n - len(times)])
            t = float(cand[-1])
        arr = np.asarray(times[:n])
        return np.diff(arr, prepend=0.0)

    def mean_rate_per_ms(self) -> float:
        # the cosine integrates to zero over a full period
        return self.base_rate_per_s / 1000.0


@dataclasses.dataclass(frozen=True)
class TraceProcess:
    """Replay a recorded inter-arrival-time trace, cyclically.

    Draws nothing from the RandomState: replay is bit-exact and
    seed-independent (pinned in tests/test_arrivals.py). Trace files are
    the faas-offloading-sim format: one IAT in milliseconds per line,
    ``#`` comments and blank lines ignored."""

    iats: tuple[float, ...]
    name: str = "trace"

    def __post_init__(self) -> None:
        if not self.iats:
            raise ValueError("trace must contain at least one IAT")
        if any(x < 0.0 for x in self.iats):
            raise ValueError("trace IATs must be >= 0")
        if sum(self.iats) <= 0.0:
            raise ValueError("trace must span positive time")

    @staticmethod
    def from_file(path: str, name: Optional[str] = None) -> "TraceProcess":
        iats: List[float] = []
        with open(path) as fh:
            for line in fh:
                s = line.split("#", 1)[0].strip()
                if s:
                    iats.append(float(s))
        return TraceProcess(tuple(iats), name=name or "trace")

    @staticmethod
    def from_azure_csv(
        path: str,
        function: Optional[str] = None,
        name: Optional[str] = None,
        minute_ms: float = 60_000.0,
    ) -> "TraceProcess":
        """Load an Azure-Functions-invocation-trace-style CSV.

        Format (the 2019 Azure Functions dataset): a header row, then one
        row per function — ``HashOwner,HashApp,HashFunction,Trigger``
        followed by one integer invocation count per minute. Each
        minute's ``k`` invocations expand to ``k`` evenly spaced arrivals
        inside that minute (the dataset has no sub-minute timestamps, so
        uniform spacing is the deterministic, assumption-minimal choice);
        zero-count minutes contribute pure gap. ``function`` selects a
        row by HashFunction prefix; None takes the first data row. Like
        every TraceProcess the result draws nothing from the RandomState.
        """
        with open(path) as fh:
            rows = [line.strip() for line in fh if line.strip()
                    and not line.startswith("#")]
        if len(rows) < 2:
            raise ValueError(f"no data rows in {path!r}")
        chosen: Optional[list[str]] = None
        for row in rows[1:]:  # rows[0] is the header
            cells = [c.strip() for c in row.split(",")]
            if len(cells) < 5:
                raise ValueError(f"malformed Azure trace row: {row[:60]!r}")
            if function is None or cells[2].startswith(function):
                chosen = cells
                break
        if chosen is None:
            raise ValueError(
                f"no function matching {function!r} in {path!r}")
        counts = [int(c) for c in chosen[4:]]
        if not counts or not any(counts):
            raise ValueError(
                f"Azure trace row for function {chosen[2][:8]!r} in "
                f"{path!r} has no invocations: every per-minute count "
                "column is missing or zero — pick another function row")
        if sum(counts) < 2:
            raise ValueError("trace needs >= 2 invocations to form IATs")
        times: List[float] = []
        for minute, k in enumerate(counts):
            if k <= 0:
                continue
            start = minute * minute_ms
            step = minute_ms / k
            # center the k arrivals in their minute: minute boundaries are
            # bins, not event times
            times.extend(start + step * (j + 0.5) for j in range(k))
        iats = [times[0]] + [b - a for a, b in zip(times, times[1:])]
        return TraceProcess(
            tuple(iats), name=name or f"azure[{chosen[2][:8]}]")

    def iats_ms(self, rng: np.random.RandomState, n: int) -> np.ndarray:
        reps = -(-n // len(self.iats))  # ceil
        return np.tile(np.asarray(self.iats, float), reps)[:n]

    def mean_rate_per_ms(self) -> float:
        return len(self.iats) / sum(self.iats)


def arrival_times_ms(
    process: ArrivalProcess,
    rng: np.random.RandomState,
    duration_ms: float,
    *,
    max_arrivals: int = 1_000_000,
) -> np.ndarray:
    """Materialize one realization's arrival times within ``[0, duration)``.

    Draws IATs in chunks sized from the process's mean rate until the
    horizon is covered (``max_arrivals`` bounds pathological rates)."""
    if duration_ms <= 0.0:
        return np.empty(0)
    expect = process.mean_rate_per_ms() * duration_ms
    n = min(max_arrivals, max(16, int(expect * 1.25) + 32))
    while True:
        times = np.cumsum(process.iats_ms(rng, n))
        if times[-1] >= duration_ms or n >= max_arrivals:
            return times[times < duration_ms]
        # undershoot: redraw the whole (longer) prefix — prefix consistency
        # is per-rng-state, and the caller's rng advanced, so clone-free
        # growth means drawing again with more headroom
        n = min(max_arrivals, n * 2)


# ---------------------------------------------------------------------------
# QoS classes
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class QoSClass:
    """A named arrival-weight class (faas-offloading-sim idiom): arrivals
    are attributed to classes proportionally to ``weight``. ``priority``
    is carried on the payload for controllers that want it; the substrate
    itself stays class-blind. ``slo_ms`` is the class's end-to-end
    latency objective — None means "no SLO"; when set, the open-loop and
    fleet summaries report per-class SLO attainment against it."""

    name: str = "default"
    weight: float = 1.0
    priority: int = 0
    slo_ms: Optional[float] = None

    def __post_init__(self) -> None:
        if self.weight <= 0.0:
            raise ValueError("weight must be > 0")
        if self.slo_ms is not None and self.slo_ms <= 0.0:
            raise ValueError("slo_ms must be > 0 when set")


def draw_classes(
    rng: np.random.RandomState, n: int, classes: Sequence[QoSClass]
) -> np.ndarray:
    """Class index per arrival, drawn proportionally to arrival weight."""
    w = np.asarray([c.weight for c in classes], float)
    return rng.choice(len(classes), size=n, p=w / w.sum())


# ---------------------------------------------------------------------------
# The open-loop driver
# ---------------------------------------------------------------------------


class _Item:
    __slots__ = ("payload", "arrived_at", "qos", "qos_weight", "deferred")

    def __init__(self, payload: Any, arrived_at: float, qos: str,
                 qos_weight: float = 1.0) -> None:
        self.payload = payload
        self.arrived_at = arrived_at
        self.qos = qos
        self.qos_weight = qos_weight
        self.deferred = False


@dataclasses.dataclass
class OpenLoopRun:
    """One open-loop run: per-request results plus the loss/pressure
    accounting a closed-loop run never needed.

    Conservation (pinned in tests/test_arrivals.py)::

        n_arrived == n_completed + n_dropped + n_dead_lettered
                     + n_pending_at_end

    (``n_dead_lettered`` stays 0 unless the engine carries a FaultPlan
    whose recovery exhausts retries — DESIGN.md §15.)

    ``system_samples`` is the independently measured population process
    N(t) = stage queue + in-flight + admission-deferred, sampled on a
    fixed cadence — the L of Little's law, NOT derived from the request
    timestamps it is compared against."""

    results: List[RequestResult]
    result_classes: List[str]
    n_arrived: int
    n_dropped: int
    n_deferred_items: int          # unique items that waited at admission
    n_defer_decisions: int         # DEFER answers (an item may defer twice)
    n_pending_at_end: int          # queued/deferred/in-flight when run ended
    duration_ms: float
    arrival_times_ms: np.ndarray
    system_samples: List[tuple[float, int]]  # (t_ms, N(t)) on the cadence
    drop_events: List[tuple[float, int]]
    # queue waits of requests still waiting when the run ended (censored
    # at the final clock) — what keeps open-loop wait percentiles honest
    # under blow-up (metrics.OpenLoopSummary folds these into wait_p99)
    censored_waits_ms: List[float] = dataclasses.field(default_factory=list)
    process_name: str = "?"
    n_dead_lettered: int = 0       # retries exhausted (DESIGN.md §15)

    @property
    def n_completed(self) -> int:
        return len(self.results)

    @property
    def drop_rate(self) -> float:
        return self.n_dropped / max(self.n_arrived, 1)

    @property
    def defer_rate(self) -> float:
        return self.n_deferred_items / max(self.n_arrived, 1)

    @property
    def offered_rate_per_ms(self) -> float:
        return self.n_arrived / self.duration_ms if self.duration_ms else 0.0

    def mean_system_population(self) -> float:
        """Time-averaged N(t) from the cadence samples (Little's L)."""
        if not self.system_samples:
            return 0.0
        return float(np.mean([n for _, n in self.system_samples]))


def run_open_loop(
    engine: SubstrateEngine,
    process: ArrivalProcess,
    *,
    rng: np.random.RandomState,
    duration_ms: float,
    qos_classes: Optional[Sequence[QoSClass]] = None,
    payload_fn: Optional[Callable[[int, str], Any]] = None,
    sample_every_ms: float = 250.0,
    drain: bool = True,
    drain_limit_ms: Optional[float] = None,
) -> OpenLoopRun:
    """Drive ``engine`` with open-loop arrivals for ``duration_ms``.

    Each arrival flows through the engine controller's ``on_admit``
    decision point (bound=None — only dynamic admission applies here; a
    DEFER parks the item and every completion re-offers parked items
    FIFO, with latency back-dated to true arrival time via
    ``submit(submitted_at_ms=...)``), then ``engine.submit``, which may
    drop it at a finite ``SubstrateKnobs.queue_capacity``. With ``drain``
    the run continues past the arrival horizon until in-flight work
    finishes (``drain_limit_ms`` bounds a queue that cannot drain).
    """
    if duration_ms <= 0.0:
        raise ValueError("duration_ms must be > 0")
    times = arrival_times_ms(process, rng, duration_ms)
    if qos_classes:
        cls_idx = draw_classes(rng, len(times), qos_classes)
        cls_names = [qos_classes[i].name for i in cls_idx]
        cls_weights = [qos_classes[i].weight for i in cls_idx]
    else:
        cls_names = ["default"] * len(times)
        cls_weights = [1.0] * len(times)

    results: List[RequestResult] = []
    result_classes: List[str] = []
    pending: collections.deque[_Item] = collections.deque()
    samples: List[tuple[float, int]] = []
    counts = {"deferred_items": 0, "defer_decisions": 0, "in_flight": 0,
              "dead_lettered": 0}
    arrived_before = engine.requests_arrived
    dropped_before = engine.requests_dropped

    def admits(item: _Item) -> bool:
        engine._decide("on_admit")
        decision = engine.controller.on_admit(AdmitContext(
            telemetry=engine.telemetry,
            in_flight=counts["in_flight"],
            bound=None,
            admission_queue_depth=len(pending),
        ))
        return decision is AdmitDecision.ADMIT

    def submit_item(item: _Item) -> None:
        def done(res: RequestResult) -> None:
            counts["in_flight"] -= 1
            results.append(res)
            result_classes.append(item.qos)
            while pending and admits(pending[0]):
                submit_item(pending.popleft())

        def dead(_inv: Any) -> None:
            # retries exhausted (DESIGN.md §15): the slot frees without a
            # result, and freed capacity re-offers parked items like a
            # completion would
            counts["in_flight"] -= 1
            counts["dead_lettered"] += 1
            while pending and admits(pending[0]):
                submit_item(pending.popleft())

        ok = engine.submit(item.payload, done,
                           submitted_at_ms=item.arrived_at,
                           qos=item.qos, qos_weight=item.qos_weight,
                           on_dead_letter=dead)
        if ok:
            counts["in_flight"] += 1
        # a drop is already counted by the engine; nothing more to do

    def offer(item: _Item) -> None:
        if admits(item):
            submit_item(item)
        else:
            counts["defer_decisions"] += 1
            if not item.deferred:
                item.deferred = True
                counts["deferred_items"] += 1
            pending.append(item)

    for i, (t, qos, w) in enumerate(zip(times, cls_names, cls_weights)):
        payload = payload_fn(i, qos) if payload_fn is not None else {"qos": qos}
        item = _Item(payload, float(t), qos, w)
        engine.loop.at(float(t), lambda item=item: offer(item))

    def sample() -> None:
        n_sys = (len(engine.queue) + engine.pool.total_in_flight
                 + len(pending))
        samples.append((engine.loop.now, n_sys))
        nxt = engine.loop.now + sample_every_ms
        if nxt < duration_ms:
            engine.loop.at(nxt, sample)

    if sample_every_ms > 0.0:
        engine.loop.at(0.0, sample)

    engine.loop.run_until(duration_ms)
    if drain:
        limit = (duration_ms + 20 * 60 * 1000.0
                 if drain_limit_ms is None else duration_ms + drain_limit_ms)
        engine.loop.run_all(hard_limit_ms=limit)

    n_arrived = engine.requests_arrived - arrived_before + len(pending)
    # NB: admission-deferred items that never reached submit() still count
    # as arrived — they are real offered load the engine turned away at a
    # different layer than the queue-capacity drop
    n_dropped = engine.requests_dropped - dropped_before
    # in_flight counts admitted-but-not-completed (queued + executing), so
    # together with the admission-parked items it is everything arrived
    # that neither completed nor dropped
    pending_at_end = len(pending) + counts["in_flight"]
    end_clock = engine.loop.now
    if _sanitizer.enabled():
        _sanitizer.check_open_loop(
            n_arrived=n_arrived, n_completed=len(results),
            n_dropped=n_dropped, n_pending_at_end=pending_at_end,
            n_dead_lettered=counts["dead_lettered"])
        _sanitizer.check_fault_ledger(engine, where="run_open_loop")
    censored = [end_clock - it.arrived_at for it in pending]
    censored += [
        end_clock - inv.first_enqueued_at_ms
        for inv in engine.queue.waiting()
        if inv.first_enqueued_at_ms is not None
    ]
    return OpenLoopRun(
        results=results,
        result_classes=result_classes,
        n_arrived=n_arrived,
        n_dropped=n_dropped,
        n_deferred_items=counts["deferred_items"],
        n_defer_decisions=counts["defer_decisions"],
        n_pending_at_end=pending_at_end,
        duration_ms=duration_ms,
        arrival_times_ms=times,
        system_samples=samples,
        drop_events=list(engine.drop_events),
        censored_waits_ms=censored,
        process_name=process.name,
        n_dead_lettered=counts["dead_lettered"],
    )


__all__ = [
    "ArrivalProcess",
    "DiurnalPoissonProcess",
    "MMPPProcess",
    "OpenLoopRun",
    "PoissonProcess",
    "QoSClass",
    "TraceProcess",
    "arrival_times_ms",
    "draw_classes",
    "run_open_loop",
]
