"""Performance-variation models for simulated worker nodes.

The paper attributes instance-to-instance variation to co-tenancy on shared
worker nodes (Fig 1) and cites prior work for diurnal platform-level
variation ("night shift" [8]: >10 % faster at night) and day-to-day drift
(Figs 4–6 show the same experiment landing differently across 7 days).

We model an instance's *speed factor* (relative throughput; 1.0 nominal,
higher = faster) as:

    speed = day_factor * diurnal(t) * lognormal(0, sigma_day)

* ``sigma_day`` — contention spread; drawn per day in [0.05, 0.15]. With a
  60th-percentile elysium gate this reproduces the paper's observed
  analysis-step improvement band (4.3 %–13 %): for LogNormal(0, σ), the
  mean speed of the fastest 40 % is E[X]·Φ(σ−z₀.₆)/0.4, i.e. +4.6 % at
  σ=0.05 and +14.7 % at σ=0.15 over the population mean.
* ``day_factor`` — AR(1) day-to-day platform drift.
* ``diurnal`` — low-amplitude time-of-day modulation (experiments all ran
  3–4 pm UTC, so this mostly matters for the longer syntheses).
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np
from scipy import stats


@dataclasses.dataclass(frozen=True)
class VariationModel:
    """Per-day node-speed distribution."""

    sigma: float = 0.10           # contention lognormal spread
    day_factor: float = 1.0       # platform-wide multiplicative drift
    diurnal_amplitude: float = 0.0
    diurnal_phase_h: float = 4.0  # peak speed hour (UTC) — night

    def sample_speed(self, rng: np.random.RandomState, t_ms: float = 0.0) -> float:
        base = math.exp(rng.normal(0.0, self.sigma))
        return base * self.day_factor * self.diurnal(t_ms)

    def diurnal(self, t_ms: float) -> float:
        if self.diurnal_amplitude == 0.0:
            return 1.0
        hour = (t_ms / 3.6e6) % 24.0
        return 1.0 + self.diurnal_amplitude * math.cos(
            2.0 * math.pi * (hour - self.diurnal_phase_h) / 24.0
        )

    # ---- analytic properties (used for calibration + tests) ----

    @property
    def mean_speed(self) -> float:
        return math.exp(self.sigma**2 / 2.0) * self.day_factor

    def top_fraction_mean_speed(self, pass_fraction: float) -> float:
        """E[speed | speed above the (1-pass_fraction) speed quantile].

        For X ~ LogNormal(0, σ): E[X | X > q] = E[X] · Φ(σ − z) / f where
        z = Φ⁻¹(1 − f). This is the analytic speed of the Minos-selected
        pool; tests check the simulator converges to it.
        """
        f = pass_fraction
        z = stats.norm.ppf(1.0 - f)
        return self.mean_speed * stats.norm.cdf(self.sigma - z) / f

    def expected_improvement(self, pass_fraction: float) -> float:
        """Expected relative reduction of the CPU-bound step duration when
        only the fastest ``pass_fraction`` of instances serve requests."""
        return 1.0 - self.mean_speed / self.top_fraction_mean_speed(pass_fraction)

    def speed_quantile(self, q: float) -> float:
        """q-quantile of the speed distribution."""
        return math.exp(stats.norm.ppf(q) * self.sigma) * self.day_factor


def paper_week(
    seed: int = 0,
    n_days: int = 7,
    sigma_lo: float = 0.09,
    sigma_hi: float = 0.22,
    drift_rho: float = 0.6,
    drift_scale: float = 0.04,
) -> list[VariationModel]:
    """Seven daily variation models mimicking the paper's experiment week:
    per-day contention sigma (uniform) + AR(1) platform drift."""
    rng = np.random.RandomState(seed)
    models = []
    drift = 0.0
    for _ in range(n_days):
        drift = drift_rho * drift + rng.normal(0.0, drift_scale)
        sigma = rng.uniform(sigma_lo, sigma_hi)
        models.append(VariationModel(sigma=sigma, day_factor=math.exp(drift)))
    return models
