"""Discrete-event FaaS platform simulator (the paper's evaluation substrate)."""
from .experiment import (
    PAPER_PRICING,
    PAPER_SPEC,
    PASS_FRACTION,
    DayResult,
    WeekResult,
    run_day,
    run_pretest_phase,
    run_week,
)
from .metrics import ArmSummary, cost_timeline, improvement
from .platform import FaaSPlatform, FunctionSpec, RequestResult
from .variation import VariationModel, paper_week
from .workload import WorkflowSpec, make_chain, run_closed_loop, run_workflow

__all__ = [
    "PAPER_PRICING", "PAPER_SPEC", "PASS_FRACTION",
    "DayResult", "WeekResult", "run_day", "run_pretest_phase", "run_week",
    "ArmSummary", "cost_timeline", "improvement",
    "FaaSPlatform", "FunctionSpec", "RequestResult",
    "VariationModel", "paper_week",
    "WorkflowSpec", "make_chain", "run_closed_loop", "run_workflow",
]
