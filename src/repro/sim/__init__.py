"""Discrete-event FaaS platform simulator (the paper's evaluation substrate)."""
from .experiment import (
    ARMS,
    PAPER_PRICING,
    PAPER_SPEC,
    PASS_FRACTION,
    DayResult,
    WeekResult,
    make_arm_policy,
    run_day,
    run_pretest_phase,
    run_week,
    workflow_arm_factory,
)
from .arrivals import (
    ArrivalProcess,
    DiurnalPoissonProcess,
    MMPPProcess,
    OpenLoopRun,
    PoissonProcess,
    QoSClass,
    TraceProcess,
    arrival_times_ms,
    run_open_loop,
)
from .metrics import (
    ArmSummary,
    FleetSummary,
    OpenLoopSummary,
    WorkflowSummary,
    cost_timeline,
    improvement,
    slo_attainment_by_class,
)
from .platform import (
    FaaSPlatform,
    FunctionSpec,
    PlatformProfile,
    RequestResult,
    SimFunctionBackend,
)
from .variation import VariationModel, paper_week
from .vectorized import (
    ArmParams,
    VecResult,
    arm_from_spec,
    run_event_chain,
    simulate_arms,
    simulate_open_arms,
    stack_arms,
)
from .workflow_dag import (
    ItemResult,
    Stage,
    WorkflowDAG,
    WorkflowEngine,
    WorkflowRunResult,
    etl_chain,
    etl_suite,
    run_workflow_batch,
    run_workflow_closed_loop,
    run_workflow_open_loop,
)
from .workload import WorkflowSpec, make_chain, run_closed_loop, run_workflow

__all__ = [
    "ARMS", "PAPER_PRICING", "PAPER_SPEC", "PASS_FRACTION",
    "DayResult", "WeekResult", "make_arm_policy", "run_day",
    "run_pretest_phase", "run_week", "workflow_arm_factory",
    "ArmSummary", "FleetSummary", "OpenLoopSummary", "WorkflowSummary",
    "cost_timeline", "improvement", "slo_attainment_by_class",
    "ArrivalProcess", "DiurnalPoissonProcess", "MMPPProcess", "OpenLoopRun",
    "PoissonProcess", "QoSClass", "TraceProcess", "arrival_times_ms",
    "run_open_loop",
    "FaaSPlatform", "FunctionSpec", "PlatformProfile", "RequestResult",
    "SimFunctionBackend",
    "VariationModel", "paper_week",
    "ArmParams", "VecResult", "arm_from_spec", "run_event_chain",
    "simulate_arms", "simulate_open_arms", "stack_arms",
    "ItemResult", "Stage", "WorkflowDAG", "WorkflowEngine",
    "WorkflowRunResult", "etl_chain", "etl_suite",
    "run_workflow_batch", "run_workflow_closed_loop",
    "run_workflow_open_loop",
    "WorkflowSpec", "make_chain", "run_closed_loop", "run_workflow",
]
