"""Discrete-event FaaS platform simulator (the paper's evaluation substrate)."""
from .experiment import (
    ARMS,
    PAPER_PRICING,
    PAPER_SPEC,
    PASS_FRACTION,
    DayResult,
    WeekResult,
    make_arm_policy,
    run_day,
    run_pretest_phase,
    run_week,
    workflow_arm_factory,
)
from .metrics import ArmSummary, WorkflowSummary, cost_timeline, improvement
from .platform import (
    FaaSPlatform,
    FunctionSpec,
    PlatformProfile,
    RequestResult,
    SimFunctionBackend,
)
from .variation import VariationModel, paper_week
from .vectorized import (
    ArmParams,
    VecResult,
    arm_from_spec,
    run_event_chain,
    simulate_arms,
    stack_arms,
)
from .workflow_dag import (
    ItemResult,
    Stage,
    WorkflowDAG,
    WorkflowEngine,
    WorkflowRunResult,
    etl_chain,
    etl_suite,
    run_workflow_batch,
    run_workflow_closed_loop,
)
from .workload import WorkflowSpec, make_chain, run_closed_loop, run_workflow

__all__ = [
    "ARMS", "PAPER_PRICING", "PAPER_SPEC", "PASS_FRACTION",
    "DayResult", "WeekResult", "make_arm_policy", "run_day",
    "run_pretest_phase", "run_week", "workflow_arm_factory",
    "ArmSummary", "WorkflowSummary", "cost_timeline", "improvement",
    "FaaSPlatform", "FunctionSpec", "PlatformProfile", "RequestResult",
    "SimFunctionBackend",
    "VariationModel", "paper_week",
    "ArmParams", "VecResult", "arm_from_spec", "run_event_chain",
    "simulate_arms", "stack_arms",
    "ItemResult", "Stage", "WorkflowDAG", "WorkflowEngine",
    "WorkflowRunResult", "etl_chain", "etl_suite",
    "run_workflow_batch", "run_workflow_closed_loop",
    "WorkflowSpec", "make_chain", "run_closed_loop", "run_workflow",
]
