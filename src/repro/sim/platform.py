"""Discrete-event FaaS platform simulator.

Models the slice of platform behavior Minos interacts with:

* an elastic supply of worker slots; each new instance draws a hidden
  ``speed_factor`` from the day's :class:`VariationModel`;
* cold-start latency before user code runs;
* a per-function warm pool — idle instances are re-used LIFO (most recently
  used first, matching observed FaaS behavior) and reclaimed after an idle
  timeout;
* one concurrent request per instance (GCF gen1 semantics);
* the Minos path: on a cold start, the matmul probe runs concurrently with
  the function's network-bound prepare phase; the instance then judges
  itself against the elysium threshold and either proceeds, or re-queues
  the invocation and crashes.

Time unit: milliseconds of simulated time. The simulator is fully
deterministic given a seed.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Callable, Optional

import numpy as np

from repro.core.cost import Pricing, WorkflowCost
from repro.core.lifecycle import FunctionInstance, InstanceState
from repro.core.policy import MinosPolicy, Verdict
from repro.core.queue import Invocation, InvocationQueue
from .variation import VariationModel


@dataclasses.dataclass(frozen=True)
class FunctionSpec:
    """A deployed function. Durations are at unit speed (speed_factor 1.0).

    prepare is network-bound (does NOT scale with CPU speed); body is
    CPU-bound (scales 1/speed). benchmark is CPU-bound and runs in parallel
    with prepare on cold starts (paper Fig 2).
    """

    name: str
    prepare_ms: float = 600.0
    prepare_jitter: float = 0.10          # lognormal-ish network jitter
    body_ms: float = 2000.0
    body_jitter: float = 0.02             # residual (non-contention) noise
    benchmark_ms: float = 300.0
    benchmark_noise: float = 0.05         # probe observation noise (lognormal sigma)
    cold_start_ms: float = 250.0
    cold_start_jitter: float = 0.25
    # co-tenancy drift: per-serve AR(1) correlation of an instance's
    # (log-relative) speed. Neighbors on the worker node come and go, so a
    # fast-at-probe-time instance regresses toward the day mean; 1.0 =
    # frozen speeds (the idealized model).
    contention_rho: float = 0.98
    bill_cold_start: bool = True          # platform bills instance startup
    requeue_overhead_ms: float = 30.0     # queue round-trip after a crash
    idle_timeout_ms: float = 15 * 60 * 1000.0
    # platform-initiated instance recycling: exponential lifetime mean (ms).
    # FaaS platforms reclaim/rotate instances opportunistically; this churn
    # is what keeps cold starts (and thus Minos terminations) flowing after
    # the initial pool forms. None = instances live until idle-timeout.
    recycle_lifetime_ms: float | None = 7 * 60 * 1000.0


@dataclasses.dataclass
class RequestResult:
    invocation_id: int
    t_submitted_ms: float
    t_completed_ms: float
    download_ms: float        # observed prepare duration
    analysis_ms: float        # observed body duration
    retries: int              # terminated instances this request caused
    served_by_cold: bool      # final (serving) instance was a cold start
    instance_speed: float
    benchmark_ms: Optional[float] = None  # probe duration on serving instance

    @property
    def latency_ms(self) -> float:
        return self.t_completed_ms - self.t_submitted_ms


class _EventLoop:
    def __init__(self) -> None:
        self._heap: list[tuple[float, int, Callable[[], None]]] = []
        self._seq = itertools.count()
        self.now = 0.0

    def at(self, t_ms: float, fn: Callable[[], None]) -> None:
        heapq.heappush(self._heap, (t_ms, next(self._seq), fn))

    def after(self, dt_ms: float, fn: Callable[[], None]) -> None:
        self.at(self.now + dt_ms, fn)

    def run_until(self, t_end_ms: float) -> None:
        while self._heap and self._heap[0][0] <= t_end_ms:
            t, _, fn = heapq.heappop(self._heap)
            self.now = t
            fn()
        self.now = max(self.now, t_end_ms)

    def run_all(self, hard_limit_ms: float = float("inf")) -> None:
        while self._heap and self._heap[0][0] <= hard_limit_ms:
            t, _, fn = heapq.heappop(self._heap)
            self.now = t
            fn()


class FaaSPlatform:
    """One function deployment on a simulated region."""

    def __init__(
        self,
        spec: FunctionSpec,
        variation: VariationModel,
        policy: MinosPolicy,
        pricing: Pricing,
        seed: int = 0,
        online_controller=None,
    ) -> None:
        """online_controller: an OnlineElysiumController (paper §IV future
        work, implemented here): every cold-start probe result is reported
        to it and the effective elysium threshold follows its estimate —
        the platform keeps working (stale threshold) if it dies."""
        self.spec = spec
        self.variation = variation
        self.policy = policy
        self.online_controller = online_controller
        self.pricing = pricing
        self.rng = np.random.RandomState(seed)
        self.loop = _EventLoop()
        self.queue = InvocationQueue()
        self.warm_pool: list[FunctionInstance] = []   # idle WARM instances (LIFO)
        self.cost = WorkflowCost(pricing)
        self.results: list[RequestResult] = []
        self.benchmark_observations: list[float] = []  # all cold-start probe durations
        self.instances_started = 0
        self.instances_terminated = 0
        self._recycle_deadline: dict[int, float] = {}
        self.termination_events: list[tuple[float, float]] = []  # (t_ms, billed_ms)

    # ------------------------------------------------------------------
    def submit(self, payload, on_complete: Callable[[RequestResult], None] | None = None) -> None:
        inv = Invocation(payload={"on_complete": on_complete, "user": payload},
                         enqueued_at_ms=self.loop.now)
        inv.first_enqueued_at_ms = self.loop.now
        self.queue.push(inv, self.loop.now)
        self.loop.after(0.0, self._dispatch)

    # ------------------------------------------------------------------
    def _take_warm(self) -> Optional[FunctionInstance]:
        now = self.loop.now
        # reclaim idle-expired and platform-recycled instances
        self.warm_pool = [
            i for i in self.warm_pool
            if not i.maybe_expire(now) and not self._recycled(i, now)
        ]
        if self.warm_pool:
            return self.warm_pool.pop()  # LIFO: most recently used first
        return None

    def _recycled(self, inst: FunctionInstance, now: float) -> bool:
        deadline = self._recycle_deadline.get(inst.instance_id)
        if deadline is not None and now >= deadline:
            inst.state = InstanceState.EXPIRED
            return True
        return False

    def _dispatch(self) -> None:
        if len(self.queue) == 0:
            return
        inv = self.queue.pop()
        warm = self._take_warm()
        if warm is not None:
            self._run_on_warm(inv, warm)
        else:
            self._cold_start(inv)

    # ------------------------------------------------------------------
    def _sample_jitter(self, scale: float) -> float:
        if scale <= 0.0:
            return 1.0
        return float(np.exp(self.rng.normal(0.0, scale)))

    def _drift_speed(self, inst: FunctionInstance) -> None:
        """Co-tenancy drift (AR(1) on log-relative speed): the benchmark
        certified the instance's speed at cold-start time, but node
        neighbors change, so the advantage decays toward the day mean."""
        rho = self.spec.contention_rho
        if rho >= 1.0:
            return
        import math
        day = self.variation.day_factor * self.variation.diurnal(self.loop.now)
        log_rel = math.log(inst.speed_factor / day)
        noise = self.rng.normal(0.0, self.variation.sigma)
        log_rel = rho * log_rel + math.sqrt(1.0 - rho * rho) * noise
        inst.speed_factor = day * math.exp(log_rel)

    def _run_on_warm(self, inv: Invocation, inst: FunctionInstance) -> None:
        spec = self.spec
        t0 = self.loop.now
        self._drift_speed(inst)
        download = spec.prepare_ms * self._sample_jitter(spec.prepare_jitter)
        analysis = spec.body_ms * self._sample_jitter(spec.body_jitter) / inst.speed_factor
        duration = download + analysis

        def _complete() -> None:
            inst.serve(self.loop.now)
            self.cost.record_reused(duration)
            self.warm_pool.append(inst)
            self._finish(inv, t0, download, analysis, served_by_cold=False,
                         speed=inst.speed_factor, bench=None)
            self._dispatch()

        self.loop.after(duration, _complete)

    def _cold_start(self, inv: Invocation) -> None:
        spec = self.spec
        t0 = self.loop.now
        self.instances_started += 1
        speed = self.variation.sample_speed(self.rng, t_ms=self.loop.now)
        inst = FunctionInstance(
            speed_factor=speed,
            created_at_ms=t0,
            idle_timeout_ms=spec.idle_timeout_ms,
        )
        if spec.recycle_lifetime_ms is not None:
            self._recycle_deadline[inst.instance_id] = t0 + float(
                self.rng.exponential(spec.recycle_lifetime_ms)
            )
        cold = spec.cold_start_ms * self._sample_jitter(spec.cold_start_jitter)
        download = spec.prepare_ms * self._sample_jitter(spec.prepare_jitter)

        billed_cold = cold if spec.bill_cold_start else 0.0

        do_benchmark = self.policy.should_benchmark(inv.retry_count, is_cold_start=True)
        if not do_benchmark:
            # baseline arm, or emergency exit: run the body directly
            inst.accept_without_benchmark()  # FORCED_PASS / baseline accept
            analysis = spec.body_ms * self._sample_jitter(spec.body_jitter) / speed
            duration = download + analysis

            def _complete_direct() -> None:
                inst.serve(self.loop.now)
                self.cost.record_passed(billed_cold + duration)
                self.warm_pool.append(inst)
                self._finish(inv, t0, download, analysis, served_by_cold=True,
                             speed=speed, bench=None)
                self._dispatch()

            self.loop.after(cold + duration, _complete_direct)
            return

        # Minos path: probe runs in parallel with the download. The probe
        # observes speed with noise (it is short), so selection is imperfect.
        bench = inst.run_benchmark(spec.benchmark_ms) * self._sample_jitter(
            spec.benchmark_noise
        )
        inst.benchmark_result = bench
        self.benchmark_observations.append(bench)
        policy = self.policy
        if self.online_controller is not None:
            # §IV: both passing AND failing probes are reported (otherwise
            # the estimate is survivor-biased); the instance judges against
            # the controller's latest published threshold.
            self.online_controller.report(bench)
            import dataclasses as _dc
            policy = _dc.replace(
                self.policy, elysium_threshold=self.online_controller.threshold
            )
        verdict = inst.judge(policy, inv.retry_count)
        if verdict is Verdict.TERMINATE:
            # judged as soon as the probe finishes; requeue + crash.
            # Billed: startup + probe wall time (download is torn down with
            # the instance; the platform bills active instance time).
            self.instances_terminated += 1
            billed = billed_cold + bench

            def _crash() -> None:
                self.cost.record_terminated(billed)
                self.termination_events.append((self.loop.now, billed))
                self.queue.requeue(inv, self.loop.now)
                self.loop.after(self.spec.requeue_overhead_ms, self._dispatch)

            self.loop.after(cold + bench, _crash)
            return

        # passed (or forced): body starts once BOTH download and probe done
        analysis = spec.body_ms * self._sample_jitter(spec.body_jitter) / speed
        ready = max(download, bench)
        duration = ready + analysis

        def _complete_pass() -> None:
            inst.serve(self.loop.now)
            self.cost.record_passed(billed_cold + duration)
            self.warm_pool.append(inst)
            self._finish(inv, t0, download, analysis, served_by_cold=True,
                         speed=speed, bench=bench)
            self._dispatch()

        self.loop.after(cold + duration, _complete_pass)

    # ------------------------------------------------------------------
    def _finish(
        self, inv: Invocation, t0: float, download: float, analysis: float,
        *, served_by_cold: bool, speed: float, bench: Optional[float],
    ) -> None:
        res = RequestResult(
            invocation_id=inv.invocation_id,
            t_submitted_ms=inv.first_enqueued_at_ms or t0,
            t_completed_ms=self.loop.now,
            download_ms=download,
            analysis_ms=analysis,
            retries=inv.terminations_experienced,
            served_by_cold=served_by_cold,
            instance_speed=speed,
            benchmark_ms=bench,
        )
        self.results.append(res)
        cb = inv.payload.get("on_complete")
        if cb is not None:
            cb(res)

    # ------------------------------------------------------------------
    @property
    def warm_pool_speeds(self) -> list[float]:
        return [i.speed_factor for i in self.warm_pool if i.state is InstanceState.WARM]
