"""Discrete-event FaaS platform simulator.

Models the slice of platform behavior Minos interacts with:

* an elastic supply of worker slots; each new instance draws a hidden
  ``speed_factor`` from the day's :class:`VariationModel`;
* cold-start latency before user code runs;
* a per-function warm pool — idle instances are re-used LIFO (most recently
  used first, matching observed FaaS behavior) and reclaimed after an idle
  timeout;
* one concurrent request per instance (GCF gen1 semantics);
* the Minos path: on a cold start, the matmul probe runs concurrently with
  the function's network-bound prepare phase; the instance then judges
  itself against the elysium threshold and either proceeds, or re-queues
  the invocation and crashes.

Time unit: milliseconds of simulated time. The simulator is fully
deterministic given a seed.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Callable, Optional

import numpy as np

from repro.core.cost import Pricing, WorkflowCost
from repro.core.lifecycle import FunctionInstance, InstanceState
from repro.core.policy import MinosPolicy, Verdict
from repro.core.queue import Invocation, InvocationQueue
from .variation import VariationModel


@dataclasses.dataclass(frozen=True)
class FunctionSpec:
    """A deployed function. Durations are at unit speed (speed_factor 1.0).

    prepare is network-bound (does NOT scale with CPU speed); body is
    CPU-bound (scales 1/speed). benchmark is CPU-bound and runs in parallel
    with prepare on cold starts (paper Fig 2).
    """

    name: str
    prepare_ms: float = 600.0
    prepare_jitter: float = 0.10          # lognormal-ish network jitter
    body_ms: float = 2000.0
    body_jitter: float = 0.02             # residual (non-contention) noise
    benchmark_ms: float = 300.0
    benchmark_noise: float = 0.05         # probe observation noise (lognormal sigma)
    cold_start_ms: float = 250.0
    cold_start_jitter: float = 0.25
    # co-tenancy drift: per-serve AR(1) correlation of an instance's
    # (log-relative) speed. Neighbors on the worker node come and go, so a
    # fast-at-probe-time instance regresses toward the day mean; 1.0 =
    # frozen speeds (the idealized model).
    contention_rho: float = 0.98
    bill_cold_start: bool = True          # platform bills instance startup
    requeue_overhead_ms: float = 30.0     # queue round-trip after a crash
    idle_timeout_ms: float = 15 * 60 * 1000.0
    # platform-initiated instance recycling: exponential lifetime mean (ms).
    # FaaS platforms reclaim/rotate instances opportunistically; this churn
    # is what keeps cold starts (and thus Minos terminations) flowing after
    # the initial pool forms. None = instances live until idle-timeout.
    recycle_lifetime_ms: float | None = 7 * 60 * 1000.0


@dataclasses.dataclass(frozen=True)
class PlatformProfile:
    """Platform-level behavior knobs, separated from the function's own
    workload shape (DESIGN.md §7). A :class:`FunctionSpec` says what the
    *function* does (prepare/body/benchmark durations); the profile says how
    the *platform* hosts it: warm-pool reuse order, per-instance request
    concurrency, cold-start and recycle behavior, billing, and the pricing
    tier. When a profile is passed to :class:`FaaSPlatform` it overrides the
    spec's platform-level fields, so one scenario runs unchanged on several
    platform models.
    """

    name: str
    pricing: Pricing
    warm_pool_order: str = "lifo"          # "lifo" (MRU-first) | "fifo" (round-robin-ish)
    per_instance_concurrency: int = 1      # concurrent requests one warm instance takes
    cold_start_ms: float = 250.0
    cold_start_jitter: float = 0.25
    idle_timeout_ms: float = 15 * 60 * 1000.0
    recycle_lifetime_ms: float | None = 7 * 60 * 1000.0
    bill_cold_start: bool = True
    requeue_overhead_ms: float = 30.0

    def __post_init__(self) -> None:
        if self.warm_pool_order not in ("lifo", "fifo"):
            raise ValueError(f"warm_pool_order must be 'lifo' or 'fifo', got {self.warm_pool_order!r}")
        if self.per_instance_concurrency < 1:
            raise ValueError("per_instance_concurrency must be >= 1")

    @staticmethod
    def gcf_gen1(memory_mb: int = 256) -> "PlatformProfile":
        """The paper's platform: one request per instance, MRU reuse,
        cold starts billed, aggressive instance churn (EXPERIMENTS.md
        calibration)."""
        return PlatformProfile(
            name="gcf-gen1",
            pricing=Pricing.gcf(memory_mb),
            warm_pool_order="lifo",
            per_instance_concurrency=1,
            cold_start_ms=250.0,
            recycle_lifetime_ms=45_000.0,
        )

    @staticmethod
    def gcf_gen2(memory_mb: int = 1024, concurrency: int = 4) -> "PlatformProfile":
        """Cloud-Run-based gen2: request-concurrent instances, slower cold
        start (bigger runtime), request-time-only billing, FIFO-ish reuse
        (the load balancer spreads across the instance set)."""
        return PlatformProfile(
            name="gcf-gen2",
            pricing=Pricing.gcf(memory_mb),
            warm_pool_order="fifo",
            per_instance_concurrency=concurrency,
            cold_start_ms=400.0,
            recycle_lifetime_ms=90_000.0,
            bill_cold_start=False,
        )

    @staticmethod
    def aws_lambda(memory_mb: int = 1024) -> "PlatformProfile":
        """Lambda-like: one request per instance, MRU reuse, fast firecracker
        cold start, init phase unbilled, shorter idle reclaim."""
        return PlatformProfile(
            name="lambda",
            pricing=Pricing.aws_lambda(memory_mb),
            warm_pool_order="lifo",
            per_instance_concurrency=1,
            cold_start_ms=150.0,
            cold_start_jitter=0.20,
            idle_timeout_ms=7 * 60 * 1000.0,
            recycle_lifetime_ms=120_000.0,
            bill_cold_start=False,
        )


@dataclasses.dataclass
class RequestResult:
    invocation_id: int
    t_submitted_ms: float
    t_completed_ms: float
    download_ms: float        # observed prepare duration
    analysis_ms: float        # observed body duration
    retries: int              # terminated instances this request caused
    served_by_cold: bool      # final (serving) instance was a cold start
    instance_speed: float
    benchmark_ms: Optional[float] = None  # probe duration on serving instance

    @property
    def latency_ms(self) -> float:
        return self.t_completed_ms - self.t_submitted_ms


class _EventLoop:
    def __init__(self) -> None:
        self._heap: list[tuple[float, int, Callable[[], None]]] = []
        self._seq = itertools.count()
        self.now = 0.0

    def at(self, t_ms: float, fn: Callable[[], None]) -> None:
        heapq.heappush(self._heap, (t_ms, next(self._seq), fn))

    def after(self, dt_ms: float, fn: Callable[[], None]) -> None:
        self.at(self.now + dt_ms, fn)

    def run_until(self, t_end_ms: float) -> None:
        while self._heap and self._heap[0][0] <= t_end_ms:
            t, _, fn = heapq.heappop(self._heap)
            self.now = t
            fn()
        self.now = max(self.now, t_end_ms)

    def run_all(self, hard_limit_ms: float = float("inf")) -> None:
        while self._heap and self._heap[0][0] <= hard_limit_ms:
            t, _, fn = heapq.heappop(self._heap)
            self.now = t
            fn()


class FaaSPlatform:
    """One function deployment on a simulated region."""

    def __init__(
        self,
        spec: FunctionSpec,
        variation: VariationModel,
        policy: MinosPolicy,
        pricing: Pricing | None = None,
        seed: int = 0,
        online_controller=None,
        profile: Optional[PlatformProfile] = None,
    ) -> None:
        """online_controller: an OnlineElysiumController (paper §IV future
        work, implemented here): every cold-start probe result is reported
        to it and the effective elysium threshold follows its estimate —
        the platform keeps working (stale threshold) if it dies.

        An AdaptiveMinosPolicy (anything with a ``report`` method) is fed
        the same probe stream directly — the §IV wiring without a separate
        controller object.

        profile: platform-level overrides (pool order, concurrency, cold
        start, recycling, billing). Without one, those knobs come from the
        spec and the platform behaves exactly like GCF gen1 (LIFO pool, one
        request per instance)."""
        self.spec = spec
        self.variation = variation
        self.policy = policy
        self.online_controller = online_controller
        self.profile = profile
        if pricing is None:
            if profile is None:
                raise ValueError("pricing is required when no profile is given")
            pricing = profile.pricing
        self.pricing = pricing
        # platform-level knobs: profile overrides the spec's defaults
        if profile is not None:
            self._cold_start_ms = profile.cold_start_ms
            self._cold_start_jitter = profile.cold_start_jitter
            self._idle_timeout_ms = profile.idle_timeout_ms
            self._recycle_lifetime_ms = profile.recycle_lifetime_ms
            self._bill_cold_start = profile.bill_cold_start
            self._requeue_overhead_ms = profile.requeue_overhead_ms
            self._warm_order = profile.warm_pool_order
            self._concurrency = profile.per_instance_concurrency
        else:
            self._cold_start_ms = spec.cold_start_ms
            self._cold_start_jitter = spec.cold_start_jitter
            self._idle_timeout_ms = spec.idle_timeout_ms
            self._recycle_lifetime_ms = spec.recycle_lifetime_ms
            self._bill_cold_start = spec.bill_cold_start
            self._requeue_overhead_ms = spec.requeue_overhead_ms
            self._warm_order = "lifo"
            self._concurrency = 1
        self.rng = np.random.RandomState(seed)
        self.loop = _EventLoop()
        self.queue = InvocationQueue()
        # WARM instances with spare request capacity, in reuse order
        self.warm_pool: list[FunctionInstance] = []
        self._active: dict[int, int] = {}  # instance_id -> in-flight requests
        self.cost = WorkflowCost(pricing)
        self.results: list[RequestResult] = []
        self.benchmark_observations: list[float] = []  # all cold-start probe durations
        self.instances_started = 0
        self.instances_terminated = 0
        self._recycle_deadline: dict[int, float] = {}
        self.termination_events: list[tuple[float, float]] = []  # (t_ms, billed_ms)

    # ------------------------------------------------------------------
    def submit(self, payload, on_complete: Callable[[RequestResult], None] | None = None) -> None:
        inv = Invocation(payload={"on_complete": on_complete, "user": payload},
                         enqueued_at_ms=self.loop.now)
        inv.first_enqueued_at_ms = self.loop.now
        self.queue.push(inv, self.loop.now)
        self.loop.after(0.0, self._dispatch)

    # ------------------------------------------------------------------
    def _take_warm(self) -> Optional[FunctionInstance]:
        now = self.loop.now
        # reclaim idle-expired and platform-recycled instances (never ones
        # with requests in flight)
        self.warm_pool = [
            i for i in self.warm_pool
            if self._active.get(i.instance_id, 0) > 0
            or (not i.maybe_expire(now) and not self._recycled(i, now))
        ]
        if not self.warm_pool:
            return None
        # "lifo": most recently used first (GCF gen1 / Lambda MRU reuse);
        # "fifo": oldest available first (load-balancer spread)
        idx = len(self.warm_pool) - 1 if self._warm_order == "lifo" else 0
        inst = self.warm_pool[idx]
        n = self._active.get(inst.instance_id, 0) + 1
        self._active[inst.instance_id] = n
        if n >= self._concurrency:  # at capacity: no longer available
            self.warm_pool.pop(idx)
        return inst

    def _release(self, inst: FunctionInstance) -> None:
        """A request on ``inst`` completed: free one concurrency slot and
        return the instance to the available pool if it left it."""
        n = self._active.get(inst.instance_id, 0) - 1
        if n <= 0:
            self._active.pop(inst.instance_id, None)
        else:
            self._active[inst.instance_id] = n
        if inst.state is InstanceState.WARM and inst not in self.warm_pool:
            self.warm_pool.append(inst)

    def _recycled(self, inst: FunctionInstance, now: float) -> bool:
        deadline = self._recycle_deadline.get(inst.instance_id)
        if deadline is not None and now >= deadline:
            inst.state = InstanceState.EXPIRED
            return True
        return False

    def _dispatch(self) -> None:
        if len(self.queue) == 0:
            return
        inv = self.queue.pop()
        warm = self._take_warm()
        if warm is not None:
            self._run_on_warm(inv, warm)
        else:
            self._cold_start(inv)

    # ------------------------------------------------------------------
    def _sample_jitter(self, scale: float) -> float:
        if scale <= 0.0:
            return 1.0
        return float(np.exp(self.rng.normal(0.0, scale)))

    def _drift_speed(self, inst: FunctionInstance) -> None:
        """Co-tenancy drift (AR(1) on log-relative speed): the benchmark
        certified the instance's speed at cold-start time, but node
        neighbors change, so the advantage decays toward the day mean."""
        rho = self.spec.contention_rho
        if rho >= 1.0:
            return
        import math
        day = self.variation.day_factor * self.variation.diurnal(self.loop.now)
        log_rel = math.log(inst.speed_factor / day)
        noise = self.rng.normal(0.0, self.variation.sigma)
        log_rel = rho * log_rel + math.sqrt(1.0 - rho * rho) * noise
        inst.speed_factor = day * math.exp(log_rel)

    def _run_on_warm(self, inv: Invocation, inst: FunctionInstance) -> None:
        spec = self.spec
        t0 = self.loop.now
        self._drift_speed(inst)
        download = spec.prepare_ms * self._sample_jitter(spec.prepare_jitter)
        analysis = spec.body_ms * self._sample_jitter(spec.body_jitter) / inst.speed_factor
        duration = download + analysis

        def _complete() -> None:
            inst.serve(self.loop.now)
            self.cost.record_reused(duration)
            self._release(inst)
            self._finish(inv, t0, download, analysis, served_by_cold=False,
                         speed=inst.speed_factor, bench=None)
            self._dispatch()

        self.loop.after(duration, _complete)

    def _cold_start(self, inv: Invocation) -> None:
        spec = self.spec
        t0 = self.loop.now
        self.instances_started += 1
        speed = self.variation.sample_speed(self.rng, t_ms=self.loop.now)
        inst = FunctionInstance(
            speed_factor=speed,
            created_at_ms=t0,
            idle_timeout_ms=self._idle_timeout_ms,
        )
        self._active[inst.instance_id] = 1
        if self._recycle_lifetime_ms is not None:
            self._recycle_deadline[inst.instance_id] = t0 + float(
                self.rng.exponential(self._recycle_lifetime_ms)
            )
        cold = self._cold_start_ms * self._sample_jitter(self._cold_start_jitter)
        download = spec.prepare_ms * self._sample_jitter(spec.prepare_jitter)

        billed_cold = cold if self._bill_cold_start else 0.0

        do_benchmark = self.policy.should_benchmark(inv.retry_count, is_cold_start=True)
        if not do_benchmark:
            # baseline arm, or emergency exit: run the body directly
            inst.accept_without_benchmark()  # FORCED_PASS / baseline accept
            analysis = spec.body_ms * self._sample_jitter(spec.body_jitter) / speed
            duration = download + analysis

            def _complete_direct() -> None:
                inst.serve(self.loop.now)
                self.cost.record_passed(billed_cold + duration)
                self._release(inst)
                self._finish(inv, t0, download, analysis, served_by_cold=True,
                             speed=speed, bench=None)
                self._dispatch()

            self.loop.after(cold + duration, _complete_direct)
            return

        # Minos path: probe runs in parallel with the download. The probe
        # observes speed with noise (it is short), so selection is imperfect.
        bench = inst.run_benchmark(spec.benchmark_ms) * self._sample_jitter(
            spec.benchmark_noise
        )
        inst.benchmark_result = bench
        self.benchmark_observations.append(bench)
        policy = self.policy
        if self.online_controller is not None:
            # §IV: both passing AND failing probes are reported (otherwise
            # the estimate is survivor-biased); the instance judges against
            # the controller's latest published threshold.
            self.online_controller.report(bench)
            import dataclasses as _dc
            policy = _dc.replace(
                self.policy, elysium_threshold=self.online_controller.threshold
            )
        elif hasattr(self.policy, "report"):
            # AdaptiveMinosPolicy: the policy IS the controller (DESIGN.md
            # §6); it sees the probe before judging, so its threshold always
            # reflects the full (unbiased) stream.
            self.policy.report(bench)
        verdict = inst.judge(policy, inv.retry_count)
        if verdict is Verdict.TERMINATE:
            # judged as soon as the probe finishes; requeue + crash.
            # Billed: startup + probe wall time (download is torn down with
            # the instance; the platform bills active instance time).
            self.instances_terminated += 1
            self._active.pop(inst.instance_id, None)
            billed = billed_cold + bench

            def _crash() -> None:
                self.cost.record_terminated(billed)
                self.termination_events.append((self.loop.now, billed))
                self.queue.requeue(inv, self.loop.now)
                self.loop.after(self._requeue_overhead_ms, self._dispatch)

            self.loop.after(cold + bench, _crash)
            return

        # passed (or forced): body starts once BOTH download and probe done
        analysis = spec.body_ms * self._sample_jitter(spec.body_jitter) / speed
        ready = max(download, bench)
        duration = ready + analysis

        def _complete_pass() -> None:
            inst.serve(self.loop.now)
            self.cost.record_passed(billed_cold + duration)
            self._release(inst)
            self._finish(inv, t0, download, analysis, served_by_cold=True,
                         speed=speed, bench=bench)
            self._dispatch()

        self.loop.after(cold + duration, _complete_pass)

    # ------------------------------------------------------------------
    def _finish(
        self, inv: Invocation, t0: float, download: float, analysis: float,
        *, served_by_cold: bool, speed: float, bench: Optional[float],
    ) -> None:
        res = RequestResult(
            invocation_id=inv.invocation_id,
            t_submitted_ms=inv.first_enqueued_at_ms or t0,
            t_completed_ms=self.loop.now,
            download_ms=download,
            analysis_ms=analysis,
            retries=inv.terminations_experienced,
            served_by_cold=served_by_cold,
            instance_speed=speed,
            benchmark_ms=bench,
        )
        self.results.append(res)
        cb = inv.payload.get("on_complete")
        if cb is not None:
            cb(res)

    # ------------------------------------------------------------------
    @property
    def warm_pool_speeds(self) -> list[float]:
        return [i.speed_factor for i in self.warm_pool if i.state is InstanceState.WARM]
