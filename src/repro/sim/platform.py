"""Discrete-event FaaS platform simulator — the *simulated* backend of the
shared execution substrate (DESIGN.md §9).

Models the slice of platform behavior Minos interacts with:

* an elastic supply of worker slots; each new instance draws a hidden
  ``speed_factor`` from the day's :class:`VariationModel`;
* cold-start latency before user code runs;
* a per-function warm pool — idle instances are re-used LIFO (most recently
  used first, matching observed FaaS behavior) and reclaimed after an idle
  timeout;
* one concurrent request per instance (GCF gen1 semantics);
* the Minos path: on a cold start, the matmul probe runs concurrently with
  the function's network-bound prepare phase; the instance then judges
  itself against the elysium threshold and either proceeds, or re-queues
  the invocation and crashes.

The pool/gate/clock/queue machinery and the invocation-processing loop all
live in :mod:`repro.core.substrate`; this module contributes only what is
simulation-specific — :class:`SimFunctionBackend` samples every duration
from a :class:`FunctionSpec` and speeds from the variation model. The
model-serving engine (``serving/engine.py``) is the other backend of the
same substrate, so both paths share identical execution semantics.

Time unit: milliseconds of simulated time. The simulator is fully
deterministic given a seed.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import numpy as np

from repro.core.cost import Pricing
from repro.core.lifecycle import FunctionInstance
from repro.core.policy import MinosPolicy
from repro.core.substrate import (
    RequestResult,
    SimClock,
    SubstrateEngine,
    SubstrateKnobs,
    ar1_drift,
    sample_jitter,
)
from .variation import VariationModel

# Re-exported for compatibility: the event loop lives in core.substrate now.
_EventLoop = SimClock


@dataclasses.dataclass(frozen=True)
class FunctionSpec:
    """A deployed function. Durations are at unit speed (speed_factor 1.0).

    prepare is network-bound (does NOT scale with CPU speed); body is
    CPU-bound (scales 1/speed). benchmark is CPU-bound and runs in parallel
    with prepare on cold starts (paper Fig 2).
    """

    name: str
    prepare_ms: float = 600.0
    prepare_jitter: float = 0.10          # lognormal-ish network jitter
    body_ms: float = 2000.0
    body_jitter: float = 0.02             # residual (non-contention) noise
    benchmark_ms: float = 300.0
    benchmark_noise: float = 0.05         # probe observation noise (lognormal sigma)
    cold_start_ms: float = 250.0
    cold_start_jitter: float = 0.25
    # co-tenancy drift: per-serve AR(1) correlation of an instance's
    # (log-relative) speed. Neighbors on the worker node come and go, so a
    # fast-at-probe-time instance regresses toward the day mean; 1.0 =
    # frozen speeds (the idealized model).
    contention_rho: float = 0.98
    bill_cold_start: bool = True          # platform bills instance startup
    requeue_overhead_ms: float = 30.0     # queue round-trip after a crash
    idle_timeout_ms: float = 15 * 60 * 1000.0
    # platform-initiated instance recycling: exponential lifetime mean (ms).
    # FaaS platforms reclaim/rotate instances opportunistically; this churn
    # is what keeps cold starts (and thus Minos terminations) flowing after
    # the initial pool forms. None = instances live until idle-timeout.
    recycle_lifetime_ms: float | None = 7 * 60 * 1000.0


@dataclasses.dataclass(frozen=True)
class PlatformProfile:
    """Platform-level behavior knobs, separated from the function's own
    workload shape (DESIGN.md §7). A :class:`FunctionSpec` says what the
    *function* does (prepare/body/benchmark durations); the profile says how
    the *platform* hosts it: warm-pool reuse order, per-instance request
    concurrency, cold-start and recycle behavior, billing, and the pricing
    tier. When a profile is passed to :class:`FaaSPlatform` it overrides the
    spec's platform-level fields, so one scenario runs unchanged on several
    platform models.
    """

    name: str
    pricing: Pricing
    warm_pool_order: str = "lifo"          # "lifo" (MRU-first) | "fifo" (round-robin-ish)
    per_instance_concurrency: int = 1      # concurrent requests one warm instance takes
    cold_start_ms: float = 250.0
    cold_start_jitter: float = 0.25
    idle_timeout_ms: float = 15 * 60 * 1000.0
    recycle_lifetime_ms: float | None = 7 * 60 * 1000.0
    bill_cold_start: bool = True
    requeue_overhead_ms: float = 30.0
    # self-contention of concurrent requests on one instance: a request
    # sharing its instance with load-1 others runs load**alpha slower
    # (0.0 = the idealized free-concurrency model; DESIGN.md §9 load model)
    load_slowdown_alpha: float = 0.0
    # gate judges cold-start probes at the pool's current mean occupancy
    gate_load_aware: bool = False

    def __post_init__(self) -> None:
        if self.warm_pool_order not in ("lifo", "fifo", "spread"):
            raise ValueError(
                f"warm_pool_order must be 'lifo', 'fifo' or 'spread', "
                f"got {self.warm_pool_order!r}")
        if self.per_instance_concurrency < 1:
            raise ValueError("per_instance_concurrency must be >= 1")
        if self.load_slowdown_alpha < 0.0:
            raise ValueError("load_slowdown_alpha must be >= 0")

    def knobs(self, max_pool: Optional[int] = None) -> SubstrateKnobs:
        """The substrate's view of this profile."""
        return SubstrateKnobs(
            cold_start_ms=self.cold_start_ms,
            cold_start_jitter=self.cold_start_jitter,
            idle_timeout_ms=self.idle_timeout_ms,
            recycle_lifetime_ms=self.recycle_lifetime_ms,
            bill_cold_start=self.bill_cold_start,
            requeue_overhead_ms=self.requeue_overhead_ms,
            warm_pool_order=self.warm_pool_order,
            per_instance_concurrency=self.per_instance_concurrency,
            max_pool=max_pool,
            load_slowdown_alpha=self.load_slowdown_alpha,
            gate_load_aware=self.gate_load_aware,
        )

    @staticmethod
    def gcf_gen1(memory_mb: int = 256) -> "PlatformProfile":
        """The paper's platform: one request per instance, MRU reuse,
        cold starts billed, aggressive instance churn (EXPERIMENTS.md
        calibration)."""
        return PlatformProfile(
            name="gcf-gen1",
            pricing=Pricing.gcf(memory_mb),
            warm_pool_order="lifo",
            per_instance_concurrency=1,
            cold_start_ms=250.0,
            recycle_lifetime_ms=45_000.0,
        )

    @staticmethod
    def gcf_gen2(memory_mb: int = 1024, concurrency: int = 4) -> "PlatformProfile":
        """Cloud-Run-based gen2: request-concurrent instances, slower cold
        start (bigger runtime), request-time-only billing, FIFO-ish reuse
        (the load balancer spreads across the instance set)."""
        return PlatformProfile(
            name="gcf-gen2",
            pricing=Pricing.gcf(memory_mb),
            warm_pool_order="fifo",
            per_instance_concurrency=concurrency,
            cold_start_ms=400.0,
            recycle_lifetime_ms=90_000.0,
            bill_cold_start=False,
        )

    @staticmethod
    def gcf_gen2_loaded(
        memory_mb: int = 1024, concurrency: int = 4, alpha: float = 0.6,
    ) -> "PlatformProfile":
        """gen2 with self-contention made real: concurrent requests on one
        instance slow each other down (load**alpha) and the gate judges
        probes at the pool's live occupancy. The idealized ``gcf_gen2``
        preset (alpha=0, free concurrency) is what this arm is compared
        against in the load-aware sweeps (EXPERIMENTS.md)."""
        return PlatformProfile(
            name="gcf-gen2-loaded",
            pricing=Pricing.gcf(memory_mb),
            warm_pool_order="spread",
            per_instance_concurrency=concurrency,
            cold_start_ms=400.0,
            recycle_lifetime_ms=90_000.0,
            bill_cold_start=False,
            load_slowdown_alpha=alpha,
            gate_load_aware=True,
        )

    @staticmethod
    def aws_lambda(memory_mb: int = 1024) -> "PlatformProfile":
        """Lambda-like: one request per instance, MRU reuse, fast firecracker
        cold start, init phase unbilled, shorter idle reclaim."""
        return PlatformProfile(
            name="lambda",
            pricing=Pricing.aws_lambda(memory_mb),
            warm_pool_order="lifo",
            per_instance_concurrency=1,
            cold_start_ms=150.0,
            cold_start_jitter=0.20,
            idle_timeout_ms=7 * 60 * 1000.0,
            recycle_lifetime_ms=120_000.0,
            bill_cold_start=False,
        )


class SimFunctionBackend:
    """Substrate backend that *samples* every duration from a
    :class:`FunctionSpec` and instance speeds from a
    :class:`VariationModel` — the paper's evaluation world."""

    def __init__(self, spec: FunctionSpec, variation: VariationModel) -> None:
        self.spec = spec
        self.variation = variation
        self.name = spec.name

    def sample_speed(self, rng: np.random.RandomState, t_ms: float) -> float:
        return self.variation.sample_speed(rng, t_ms=t_ms)

    def reuse_drift(self, inst: FunctionInstance, rng: np.random.RandomState, t_ms: float) -> None:
        ar1_drift(
            inst, rng,
            day_mean=self.variation.day_factor * self.variation.diurnal(t_ms),
            sigma=self.variation.sigma,
            rho=self.spec.contention_rho,
        )

    def prepare_ms(self, rng: np.random.RandomState) -> float:
        return self.spec.prepare_ms * sample_jitter(rng, self.spec.prepare_jitter)

    def probe(self, inst: FunctionInstance, rng: np.random.RandomState) -> float:
        # The probe observes speed with noise (it is short), so selection is
        # imperfect; the noisy observation is what the instance judges on.
        bench = inst.run_benchmark(self.spec.benchmark_ms) * sample_jitter(
            rng, self.spec.benchmark_noise
        )
        inst.benchmark_result = bench
        return bench

    def reprobe(self, inst: FunctionInstance, rng: np.random.RandomState) -> float:
        """Warm re-benchmark (control plane, ReuseDecision.REPROBE): same
        work and observation noise as the cold probe, but measured at the
        instance's *current* (drifted) speed and without the COLD-only
        lifecycle transition."""
        return (self.spec.benchmark_ms / inst.speed_factor) * sample_jitter(
            rng, self.spec.benchmark_noise
        )

    def body(
        self,
        payload: Any,
        inst: FunctionInstance,
        rng: np.random.RandomState,
        *,
        load: int = 1,
    ) -> tuple[float, Any]:
        # load is accounted by the engine's load-slowdown curve; a sampled
        # duration has nothing batched to compute, so it is unused here
        analysis = (
            self.spec.body_ms * sample_jitter(rng, self.spec.body_jitter)
            / inst.speed_factor
        )
        return analysis, None

    def requeue_penalty_ms(self, payload: Any) -> float:
        return 0.0  # stateless function: nothing to migrate


class FaaSPlatform(SubstrateEngine):
    """One function deployment on a simulated region: a
    :class:`~repro.core.substrate.SubstrateEngine` over a
    :class:`SimFunctionBackend`."""

    def __init__(
        self,
        spec: FunctionSpec,
        variation: VariationModel,
        policy: MinosPolicy,
        pricing: Pricing | None = None,
        seed: int = 0,
        online_controller=None,
        profile: Optional[PlatformProfile] = None,
        controller=None,
        knobs: Optional[SubstrateKnobs] = None,
        clock: Optional[SimClock] = None,
        fault_plan=None,
        recovery=None,
    ) -> None:
        """online_controller: an OnlineElysiumController (paper §IV future
        work, implemented here): every cold-start probe result is reported
        to it and the effective elysium threshold follows its estimate —
        the platform keeps working (stale threshold) if it dies.

        An AdaptiveMinosPolicy (anything with a ``report`` method) is fed
        the same probe stream directly — the §IV wiring without a separate
        controller object.

        profile: platform-level overrides (pool order, concurrency, cold
        start, recycling, billing). Without one, those knobs come from the
        spec and the platform behaves exactly like GCF gen1 (LIFO pool, one
        request per instance).

        controller: a :class:`~repro.core.control.Controller` that replaces
        the whole policy stack (pass ``policy=None`` then); the legacy
        arguments build the default ClassicMinosController.

        knobs: explicit :class:`~repro.core.substrate.SubstrateKnobs`,
        overriding both profile and spec — how open-loop drivers set the
        ``max_instances`` / ``queue_capacity`` traffic knobs on top of a
        profile (``dataclasses.replace(profile.knobs(), ...)``).

        clock: a shared :class:`~repro.core.substrate.SimClock` — the
        fleet meta-scheduler (``repro.fleet``) composes several platforms
        on one event loop this way. None builds a private clock.

        fault_plan / recovery: a :class:`~repro.faults.FaultPlan` and
        :class:`~repro.faults.RecoveryPolicy` (DESIGN.md §15). None/None
        is the historical fault-free at-least-once platform."""
        if pricing is None:
            if profile is None:
                raise ValueError("pricing is required when no profile is given")
            pricing = profile.pricing
        if knobs is not None:
            pass  # explicit knobs win
        elif profile is not None:
            knobs = profile.knobs()
        else:
            knobs = SubstrateKnobs(
                cold_start_ms=spec.cold_start_ms,
                cold_start_jitter=spec.cold_start_jitter,
                idle_timeout_ms=spec.idle_timeout_ms,
                recycle_lifetime_ms=spec.recycle_lifetime_ms,
                bill_cold_start=spec.bill_cold_start,
                requeue_overhead_ms=spec.requeue_overhead_ms,
                warm_pool_order="lifo",
                per_instance_concurrency=1,
            )
        super().__init__(
            SimFunctionBackend(spec, variation), policy, pricing,
            knobs=knobs, seed=seed, online_controller=online_controller,
            controller=controller, clock=clock,
            fault_plan=fault_plan, recovery=recovery,
        )
        self.spec = spec
        self.variation = variation
        self.profile = profile

    @property
    def warm_pool(self) -> list[FunctionInstance]:
        return self.pool.available

__all__ = [
    "FaaSPlatform",
    "FunctionSpec",
    "PlatformProfile",
    "RequestResult",
    "SimFunctionBackend",
    "_EventLoop",
]
