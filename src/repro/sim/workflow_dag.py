"""Multi-stage workflow DAG engine (paper §V; DESIGN.md §5).

The paper's headline scaling claim is that "longer and complex workflows
lead to increased savings, as the pool of fast instances is re-used more
often". This module makes that claim testable: a :class:`WorkflowDAG` of
:class:`~repro.sim.platform.FunctionSpec` stages with fan-out/fan-in edges,
where every stage invocation flows through the existing Minos gate on its
own :class:`~repro.sim.platform.FaaSPlatform` — so each stage keeps a
per-stage warm pool of benchmark-certified instances, and pool re-use
compounds across stages.

Execution model (all stages share ONE simulated clock):

* an *item* is one end-to-end workflow execution;
* a stage is submitted for an item as soon as ALL of its parent stages
  have completed for that item (fan-in barrier); source stages are
  submitted at item arrival; the item completes when every sink stage has
  completed;
* a terminated (benchmark-failed) instance re-queues its stage invocation
  on the stage's own queue — downstream stages never observe the retry,
  only the delay; each stage may bound its own emergency exit via
  ``Stage.max_retries``.

Scenario builders: :func:`etl_chain` and :func:`etl_suite` construct the
3-/5-/7-stage ETL workflows used by ``benchmarks/workflow_sweep.py`` and
``examples/etl_workflows.py`` (protocol: EXPERIMENTS.md §Workflow sweep).
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core.control import AdmitContext, AdmitDecision
from repro.core.cost import Pricing, WorkflowCost
from repro.core.substrate import SubstrateEngine
from .platform import FaaSPlatform, FunctionSpec, PlatformProfile, RequestResult
from .variation import VariationModel


# ---------------------------------------------------------------------------
# DAG structure
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Stage:
    """One node of the workflow: an execution binding plus its dependencies.

    A stage is bound to exactly one of:

    * ``spec`` — a simulated :class:`FunctionSpec` (body durations are
      sampled; the paper's evaluation world), or
    * ``backend`` — any :class:`~repro.core.substrate.Backend`, e.g. a
      :class:`~repro.serving.backend.ModelServingBackend` whose body is
      real JAX prefill/decode. The engine runs it on its own Minos-gated
      pool with the same fan-in semantics.

    ``max_retries`` optionally overrides the policy's emergency-exit bound
    for this stage only (e.g. an idempotent transform tolerates more
    re-selection than a stage with external side effects).

    ``max_in_flight`` optionally bounds items concurrently admitted to this
    stage (submitted but not completed, retries included). When a requeue
    storm inflates a stage's queue, further items wait at admission instead
    of piling onto the stage queue — back-pressure, not just latency.

    ``make_request`` adapts the item payload for this stage's backend:
    called with ``(item_payload, parent_results)`` where ``parent_results``
    maps each dependency name to its completed
    :class:`~repro.core.substrate.RequestResult` (whose ``output`` carries
    a serving backend's tokens). Without it, the raw item payload is
    forwarded — simulated stages ignore payloads entirely.
    """

    spec: Optional[FunctionSpec] = None
    deps: tuple[str, ...] = ()
    max_retries: Optional[int] = None
    backend: Optional[object] = None
    max_in_flight: Optional[int] = None
    make_request: Optional[Callable[[Any, Dict[str, RequestResult]], Any]] = None

    def __post_init__(self) -> None:
        if (self.spec is None) == (self.backend is None):
            raise ValueError("a Stage needs exactly one of spec= or backend=")
        if self.max_in_flight is not None and self.max_in_flight < 1:
            raise ValueError("max_in_flight must be >= 1")

    @property
    def name(self) -> str:
        return self.spec.name if self.spec is not None else self.backend.name


class WorkflowDAG:
    """A validated DAG of stages, keyed by stage (function) name."""

    def __init__(self, stages: Sequence[Stage], name: str = "workflow") -> None:
        self.name = name
        self.stages: Dict[str, Stage] = {}
        for s in stages:
            if s.name in self.stages:
                raise ValueError(f"duplicate stage name {s.name!r}")
            self.stages[s.name] = s
        for s in stages:
            for d in s.deps:
                if d not in self.stages:
                    raise ValueError(f"stage {s.name!r} depends on unknown stage {d!r}")
        self.children: Dict[str, tuple[str, ...]] = {n: () for n in self.stages}
        for s in stages:
            for d in s.deps:
                self.children[d] = self.children[d] + (s.name,)
        self.order = self._topo_sort()
        self.sources = tuple(n for n, s in self.stages.items() if not s.deps)
        self.sinks = tuple(n for n in self.stages if not self.children[n])
        if not self.sources:
            raise ValueError("workflow has no source stage")

    def _topo_sort(self) -> tuple[str, ...]:
        indeg = {n: len(s.deps) for n, s in self.stages.items()}
        ready = sorted(n for n, d in indeg.items() if d == 0)
        order: List[str] = []
        while ready:
            n = ready.pop(0)
            order.append(n)
            for c in self.children[n]:
                indeg[c] -= 1
                if indeg[c] == 0:
                    ready.append(c)
        if len(order) != len(self.stages):
            cyc = sorted(set(self.stages) - set(order))
            raise ValueError(f"workflow DAG has a cycle through {cyc}")
        return tuple(order)

    def __len__(self) -> int:
        return len(self.stages)

    def __iter__(self):
        return iter(self.order)

    @staticmethod
    def chain(specs: Sequence[FunctionSpec], name: str = "chain") -> "WorkflowDAG":
        """Linear pipeline: each stage depends on the previous one."""
        stages = []
        prev: tuple[str, ...] = ()
        for spec in specs:
            stages.append(Stage(spec=spec, deps=prev))
            prev = (spec.name,)
        return WorkflowDAG(stages, name=name)


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ItemResult:
    """One completed end-to-end workflow execution."""

    item_id: int
    t_submitted_ms: float
    t_completed_ms: float
    stage_results: Dict[str, RequestResult]

    @property
    def latency_ms(self) -> float:
        return self.t_completed_ms - self.t_submitted_ms

    @property
    def total_analysis_ms(self) -> float:
        return sum(r.analysis_ms for r in self.stage_results.values())

    @property
    def total_retries(self) -> int:
        return sum(r.retries for r in self.stage_results.values())


class _ItemState:
    __slots__ = ("item_id", "t0", "waiting", "results", "on_complete", "payload")

    def __init__(self, item_id: int, t0: float, dag: WorkflowDAG, on_complete,
                 payload: Any = None) -> None:
        self.item_id = item_id
        self.t0 = t0
        self.waiting = {n: len(s.deps) for n, s in dag.stages.items()}
        self.results: Dict[str, RequestResult] = {}
        self.on_complete = on_complete
        self.payload = payload


class WorkflowEngine:
    """Per-stage substrate engines sharing one event loop, plus the fan-in
    and admission logic. A :class:`Stage` bound to a ``spec`` gets a
    :class:`~repro.sim.platform.FaaSPlatform`; one bound to a ``backend``
    (e.g. model serving) gets a bare
    :class:`~repro.core.substrate.SubstrateEngine` — both are the same
    substrate, so mixed simulated/serving pipelines share identical pool,
    gate, and requeue semantics on one clock.

    ``policy_factory`` builds one policy object *per stage* — required for
    :class:`~repro.core.policy.AdaptiveMinosPolicy`, whose threshold is in
    units of the stage's own probe duration and must never be shared across
    stages with different ``benchmark_ms``. It receives the :class:`Stage`
    so it can honor per-stage ``max_retries``.

    ``controller_factory`` instead builds one
    :class:`~repro.core.control.Controller` per stage — the control-plane
    surface (DESIGN.md §10); it supersedes ``policy_factory`` (pass None).
    Item admission to a stage flows through the stage controller's
    ``on_admit`` decision point: the static ``Stage.max_in_flight`` bound
    is the default controller's answer, and a
    :class:`~repro.core.control.QueueAwareAdmissionController` turns it
    into a dynamic bound driven by the stage's live queue depth and pool
    occupancy. Deferred items are re-offered on every completion of that
    stage (a deferral always has work in flight or queued, so progress is
    guaranteed).
    """

    def __init__(
        self,
        dag: WorkflowDAG,
        variation: VariationModel,
        policy_factory: Optional[Callable[[Stage], object]] = None,
        *,
        profile: Optional[PlatformProfile] = None,
        pricing: Optional[Pricing] = None,
        seed: int = 0,
        controller_factory: Optional[Callable[[Stage], object]] = None,
    ) -> None:
        if profile is None and pricing is None:
            raise ValueError("need a PlatformProfile or an explicit Pricing")
        if (policy_factory is None) == (controller_factory is None):
            raise ValueError(
                "need exactly one of policy_factory= or controller_factory=")
        self.dag = dag
        self.variation = variation
        self.profile = profile
        self.platforms: Dict[str, SubstrateEngine] = {}
        self.items: List[ItemResult] = []
        self._next_item = 0
        self._in_flight = {n: 0 for n in dag.order}
        self._admission: Dict[str, collections.deque] = {
            n: collections.deque() for n in dag.order
        }
        loop = None
        for i, name in enumerate(dag.order):
            stage = dag.stages[name]
            policy = policy_factory(stage) if policy_factory is not None else None
            ctrl = controller_factory(stage) if controller_factory is not None else None
            if stage.spec is not None:
                plat: SubstrateEngine = FaaSPlatform(
                    stage.spec, variation, policy,
                    pricing=pricing, seed=seed + 97 * i, profile=profile,
                    controller=ctrl,
                )
            else:
                # a profile overrides hosting knobs but must not silently
                # drop the backend's replica-pool cap
                knobs = (
                    profile.knobs(max_pool=getattr(stage.backend, "max_pool", None))
                    if profile is not None
                    else stage.backend.default_knobs()
                )
                plat = SubstrateEngine(
                    stage.backend, policy,
                    pricing if pricing is not None else profile.pricing,
                    knobs=knobs, seed=seed + 97 * i, controller=ctrl,
                )
            if loop is None:
                loop = plat.loop
            else:
                plat.loop = loop  # all stages share stage-0's clock
            self.platforms[name] = plat
        assert loop is not None
        self.loop = loop

    # -- item flow ------------------------------------------------------
    def submit_item(
        self,
        on_complete: Optional[Callable[[ItemResult], None]] = None,
        payload: Any = None,
    ) -> int:
        """Start one workflow execution now; returns the item id."""
        item_id = self._next_item
        self._next_item += 1
        state = _ItemState(item_id, self.loop.now, self.dag, on_complete, payload)
        for src in self.dag.sources:
            self._submit_stage(state, src)
        return item_id

    def in_flight(self, stage_name: str) -> int:
        """Items admitted to ``stage_name`` and not yet completed."""
        return self._in_flight[stage_name]

    def admission_queue_depth(self, stage_name: str) -> int:
        """Items waiting at ``stage_name``'s admission bound."""
        return len(self._admission[stage_name])

    def stage_pool_load(self, stage_name: str) -> float:
        """Mean in-flight requests per live instance of the stage's pool
        (>= 1.0) — the occupancy the load-slowdown model charges and the
        load-aware gate judges at (DESIGN.md §9 load model). The hook for
        queue-depth-aware dynamic admission (ROADMAP)."""
        return self.platforms[stage_name].pool.mean_load()

    def stage_queue_depth(self, stage_name: str) -> int:
        """Invocations waiting on the stage's own queue (requeues included) —
        distinct from the admission queue, which holds not-yet-admitted
        items."""
        return len(self.platforms[stage_name].queue)

    def _admission_allows(self, name: str) -> bool:
        """Ask the stage controller's on_admit decision point. The default
        (classic) controller answers with the static ``Stage.max_in_flight``
        bound; queue-aware controllers read the live telemetry."""
        stage = self.dag.stages[name]
        plat = self.platforms[name]
        plat._decide("on_admit")
        decision = plat.controller.on_admit(AdmitContext(
            telemetry=plat.telemetry,
            in_flight=self._in_flight[name],
            bound=stage.max_in_flight,
            admission_queue_depth=len(self._admission[name]),
        ))
        return decision is AdmitDecision.ADMIT

    def _submit_stage(self, state: _ItemState, name: str) -> None:
        if not self._admission_allows(name):
            self._admission[name].append(state)  # back-pressure at admission
            return
        self._admit(state, name)

    def _admit(self, state: _ItemState, name: str) -> None:
        stage = self.dag.stages[name]
        plat = self.platforms[name]
        self._in_flight[name] += 1
        if stage.make_request is not None:
            payload = stage.make_request(
                state.payload, {d: state.results[d] for d in stage.deps})
        else:
            payload = state.payload

        def done(res: RequestResult) -> None:
            self._in_flight[name] -= 1
            # a completion may free admission capacity: re-offer deferred
            # items until the controller defers again (the static bound
            # admits exactly one per completion, as before)
            while self._admission[name] and self._admission_allows(name):
                self._admit(self._admission[name].popleft(), name)
            state.results[name] = res
            for child in self.dag.children[name]:
                state.waiting[child] -= 1
                if state.waiting[child] == 0:  # fan-in: ALL parents arrived
                    self._submit_stage(state, child)
            if all(s in state.results for s in self.dag.sinks):
                item = ItemResult(
                    item_id=state.item_id,
                    t_submitted_ms=state.t0,
                    t_completed_ms=self.loop.now,
                    stage_results=dict(state.results),
                )
                self.items.append(item)
                if state.on_complete is not None:
                    state.on_complete(item)

        plat.submit(payload, done)

    # -- aggregates -----------------------------------------------------
    @property
    def cost(self) -> WorkflowCost:
        merged: Optional[WorkflowCost] = None
        for p in self.platforms.values():
            merged = p.cost if merged is None else merged.merge(p.cost)
        assert merged is not None
        return merged

    @property
    def instances_started(self) -> int:
        return sum(p.instances_started for p in self.platforms.values())

    @property
    def instances_terminated(self) -> int:
        return sum(p.instances_terminated for p in self.platforms.values())

    def per_stage_results(self) -> Dict[str, List[RequestResult]]:
        return {n: list(p.results) for n, p in self.platforms.items()}


@dataclasses.dataclass
class WorkflowRunResult:
    """Everything a sweep needs from one workflow run.

    ``items`` are the executions completing inside the measurement window
    (latency statistics); ``n_items_costed`` additionally counts items that
    completed while draining, because the cost ledgers accrue through the
    drain too — dividing drain-inclusive cost by window-only items would
    overstate cost per item, and by more for slower arms.
    """

    dag: WorkflowDAG
    items: List[ItemResult]
    engine: WorkflowEngine

    @property
    def n_items(self) -> int:
        return len(self.items)

    @property
    def n_items_costed(self) -> int:
        return len(self.engine.items)

    @property
    def mean_item_latency_ms(self) -> float:
        return float(np.mean([i.latency_ms for i in self.items])) if self.items else float("nan")

    @property
    def median_item_latency_ms(self) -> float:
        return float(np.median([i.latency_ms for i in self.items])) if self.items else float("nan")

    @property
    def mean_item_analysis_ms(self) -> float:
        return float(np.mean([i.total_analysis_ms for i in self.items])) if self.items else float("nan")

    @property
    def cost(self) -> WorkflowCost:
        return self.engine.cost

    @property
    def cost_per_million_items(self) -> float:
        if not self.engine.items:
            return float("nan")
        return self.engine.cost.total / self.n_items_costed * 1e6


def run_workflow_closed_loop(
    engine: WorkflowEngine,
    *,
    n_vus: int = 10,
    think_time_ms: float = 1000.0,
    duration_ms: float = 10 * 60 * 1000.0,
    start_ms: float = 0.0,
    payload_fn: Optional[Callable[[int], Any]] = None,
) -> WorkflowRunResult:
    """The paper's closed-loop workload lifted to whole workflows: each VU
    submits an item, waits for the full DAG to complete, thinks, repeats.
    Item-level concurrency is what bounds total pool size across stages —
    the amortization the paper's workflow argument rests on.
    ``payload_fn(item_seq)`` builds the item payload (serving pipelines);
    None submits payload-less items (simulated stages ignore payloads)."""
    window_end = start_ms + duration_ms
    completed: List[ItemResult] = []
    seq = itertools.count()

    def submit(cb) -> None:
        payload = payload_fn(next(seq)) if payload_fn is not None else None
        engine.submit_item(cb, payload=payload)

    def make_vu():
        def on_complete(item: ItemResult) -> None:
            if item.t_completed_ms <= window_end:
                completed.append(item)
            next_t = item.t_completed_ms + think_time_ms
            if next_t < window_end:
                engine.loop.at(next_t, lambda: submit(on_complete))

        return on_complete

    for _ in range(n_vus):
        cb = make_vu()
        engine.loop.at(start_ms, lambda cb=cb: submit(cb))

    engine.loop.run_until(window_end)
    engine.loop.run_all(hard_limit_ms=window_end + 20 * 60 * 1000.0)
    return WorkflowRunResult(dag=engine.dag, items=completed, engine=engine)


def run_workflow_batch(
    engine: WorkflowEngine,
    *,
    n_items: int,
    inter_arrival_ms: float = 500.0,
    payload_fn: Optional[Callable[[int], Any]] = None,
) -> WorkflowRunResult:
    """Open-loop: push a fixed batch of items at a fixed rate and drain."""
    for i in range(n_items):
        payload = payload_fn(i) if payload_fn is not None else None
        engine.loop.at(
            i * inter_arrival_ms,
            lambda payload=payload: engine.submit_item(None, payload=payload),
        )
    engine.loop.run_all(hard_limit_ms=1e12)
    return WorkflowRunResult(dag=engine.dag, items=list(engine.items), engine=engine)


def run_workflow_open_loop(
    engine: WorkflowEngine,
    process,
    *,
    rng: np.random.RandomState,
    duration_ms: float,
    payload_fn: Optional[Callable[[int], Any]] = None,
    drain_limit_ms: float = 20 * 60 * 1000.0,
) -> WorkflowRunResult:
    """Open-loop workflow traffic: item arrivals follow an
    :class:`~repro.sim.arrivals.ArrivalProcess` realization instead of the
    fixed rate of :func:`run_workflow_batch` — arrivals are independent of
    completions, so stage admission (``Stage.max_in_flight`` or a
    :class:`~repro.core.control.QueueAwareAdmissionController`) is what
    absorbs bursts. Items arriving within ``duration_ms`` are measured;
    the run drains up to ``drain_limit_ms`` past the horizon."""
    from .arrivals import arrival_times_ms  # local: avoid a module cycle

    times = arrival_times_ms(process, rng, duration_ms)
    for i, t in enumerate(times):
        payload = payload_fn(i) if payload_fn is not None else None
        engine.loop.at(
            float(t),
            lambda payload=payload: engine.submit_item(None, payload=payload),
        )
    engine.loop.run_until(duration_ms)
    engine.loop.run_all(hard_limit_ms=duration_ms + drain_limit_ms)
    return WorkflowRunResult(dag=engine.dag, items=list(engine.items), engine=engine)


# ---------------------------------------------------------------------------
# ETL scenario suite (EXPERIMENTS.md §Workflow sweep)
# ---------------------------------------------------------------------------

# Stage archetypes. The extract stage is network-bound (the paper's weather
# CSV download); transforms are CPU-bound — the Minos-improvable share of an
# item's latency therefore GROWS with workflow length, which is what makes
# the paper's "longer workflows save more" claim come out monotone.
_EXTRACT = dict(prepare_ms=1200.0, body_ms=500.0, benchmark_ms=300.0)
_TRANSFORM = dict(prepare_ms=150.0, body_ms=1300.0, benchmark_ms=300.0)
_LOAD = dict(prepare_ms=300.0, body_ms=800.0, benchmark_ms=300.0)
_COMMON = dict(
    cold_start_ms=250.0,
    recycle_lifetime_ms=45_000.0,
    # higher persistence than the single-function calibration: workflow
    # items re-visit the per-stage pools quickly, so the certified speed
    # must survive long enough for re-use to compound (EXPERIMENTS.md
    # §Workflow sweep documents this choice and its sensitivity)
    contention_rho=0.995,
    benchmark_noise=0.05,
)


def _spec(name: str, archetype: dict) -> FunctionSpec:
    return FunctionSpec(name=name, **archetype, **_COMMON)


def etl_chain(n_stages: int, name: Optional[str] = None) -> WorkflowDAG:
    """Linear ETL pipeline: extract → transform×(n-2) → load. ``n_stages=1``
    degenerates to the paper's single-function scenario shape."""
    if n_stages < 1:
        raise ValueError("n_stages must be >= 1")
    if n_stages == 1:
        specs = [_spec("extract", _EXTRACT)]
    else:
        specs = (
            [_spec("extract", _EXTRACT)]
            + [_spec(f"transform{i}", _TRANSFORM) for i in range(1, n_stages - 1)]
            + [_spec("load", _LOAD)]
        )
    return WorkflowDAG.chain(specs, name=name or f"etl-{n_stages}")


def etl_suite() -> Dict[str, WorkflowDAG]:
    """The 3-/5-/7-stage ETL workflows. The 3-stage is a pure chain; the
    5- and 7-stage add fan-out/fan-in (parallel transforms joined before
    load), exercising the DAG barrier."""
    three = etl_chain(3, name="etl-3")

    five = WorkflowDAG(
        [
            Stage(_spec("extract", _EXTRACT)),
            Stage(_spec("clean", _TRANSFORM), deps=("extract",)),
            Stage(_spec("enrich", _TRANSFORM), deps=("extract",)),
            Stage(_spec("join", _TRANSFORM), deps=("clean", "enrich")),
            Stage(_spec("load", _LOAD), deps=("join",)),
        ],
        name="etl-5",
    )

    seven = WorkflowDAG(
        [
            Stage(_spec("extract", _EXTRACT)),
            Stage(_spec("validate", _TRANSFORM), deps=("extract",)),
            Stage(_spec("clean", _TRANSFORM), deps=("validate",)),
            Stage(_spec("enrich", _TRANSFORM), deps=("validate",)),
            Stage(_spec("aggregate", _TRANSFORM), deps=("validate",)),
            Stage(_spec("join", _TRANSFORM), deps=("clean", "enrich", "aggregate")),
            Stage(_spec("load", _LOAD), deps=("join",)),
        ],
        name="etl-7",
    )
    return {"etl-3": three, "etl-5": five, "etl-7": seven}
