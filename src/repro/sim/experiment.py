"""The paper's evaluation protocol (§III), end to end.

Per day:
  1. **Pre-testing** (§III-A): 10 VUs × 1 min against an unguarded
     deployment; the elysium threshold is the 60th percentile of observed
     probe durations (⇒ fastest 40 % pass).
  2. **Baseline arm**: identical function, all Minos components disabled,
     10 VUs × 30 min.
  3. **Minos arm**: elysium gate active, same workload, same day variation.

Outputs map 1:1 onto the paper's figures:
  Fig 4 — mean/median analysis duration per day, both arms
  Fig 5 — successful requests per day
  Fig 6 — cost per million successful requests per day
  Fig 7 — running cost per successful request over elapsed time
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.cost import Pricing
from repro.core.elysium import pretest_threshold, run_pretest
from repro.core.policy import AdaptiveMinosPolicy, MinosPolicy
from .metrics import ArmSummary, cost_timeline, improvement
from .platform import FaaSPlatform, FunctionSpec
from .variation import VariationModel, paper_week
from .workload import run_closed_loop

# The paper's workload scales (§III-A, Figs 4-7), calibrated so the
# simulated platform reproduces the paper's measurements (see
# EXPERIMENTS.md): regression step lands in the 1-3 s band (Fig 4),
# ~4-5 k successful requests/day per 10 VUs (Fig 5), ~$11-13 per million
# successful requests at the GCF 256 MB tier (Fig 6).
PAPER_SPEC = FunctionSpec(
    name="weather-linreg",
    prepare_ms=1500.0,        # weather-CSV download (network-bound)
    body_ms=1800.0,           # linear-regression analysis (CPU-bound)
    benchmark_ms=450.0,       # matmul probe, hidden under the download
    cold_start_ms=250.0,
    recycle_lifetime_ms=45_000.0,   # platform instance churn
    contention_rho=0.95,            # co-tenancy drift per serve
    benchmark_noise=0.08,           # probe observation noise
)
PAPER_PRICING = Pricing.gcf(256)
PASS_FRACTION = 0.4  # 60th-percentile elysium threshold

ARMS = ("disabled", "fixed", "adaptive")


def make_arm_policy(
    arm: str,
    *,
    threshold: float | None = None,
    pass_fraction: float = PASS_FRACTION,
    max_retries: int = 5,
    warmup_reports: int = 5,
    initial_threshold: float | None = None,
):
    """Policy for one experiment arm.

    * ``disabled`` — the paper's baseline: every instance passes.
    * ``fixed`` — the paper's prototype: a pre-tested elysium threshold
      (§III-A), supplied via ``threshold``.
    * ``adaptive`` — the §IV protocol: :class:`AdaptiveMinosPolicy`
      maintains the threshold online from the probe stream; no pre-test
      phase exists (warm-up passes everything while the estimators fill).
    """
    if arm == "disabled":
        return MinosPolicy(elysium_threshold=float("inf"), enabled=False)
    if arm == "fixed":
        if threshold is None:
            raise ValueError("fixed arm needs a pre-tested threshold")
        return MinosPolicy(elysium_threshold=threshold, max_retries=max_retries)
    if arm == "adaptive":
        return AdaptiveMinosPolicy(
            pass_fraction,
            max_retries=max_retries,
            warmup_reports=warmup_reports,
            initial_threshold=initial_threshold,
        )
    raise ValueError(f"unknown arm {arm!r}; expected one of {ARMS}")


def workflow_arm_factory(
    arm: str,
    variation: VariationModel,
    *,
    pass_fraction: float = PASS_FRACTION,
    max_retries: int = 5,
    warmup_reports: int = 5,
    pricing: Pricing = PAPER_PRICING,
    pretest_seed: int = 1234,
):
    """Per-stage policy factory for :class:`~repro.sim.workflow_dag.WorkflowEngine`.

    The ``fixed`` arm pre-tests each stage's function separately (a stage's
    threshold is in units of its own probe duration); the ``adaptive`` arm
    gets one independent online estimator per stage and skips pre-testing
    entirely. Stage ``max_retries`` overrides the default bound.
    """
    _cache: dict[str, float] = {}

    def factory(stage):
        mr = stage.max_retries if stage.max_retries is not None else max_retries
        if arm == "fixed":
            if stage.name not in _cache:
                import zlib
                _cache[stage.name] = run_pretest_phase(
                    variation, stage.spec, pricing,
                    seed=pretest_seed + zlib.crc32(stage.name.encode()) % 7919,
                    pass_fraction=pass_fraction,
                )
            return make_arm_policy(
                "fixed", threshold=_cache[stage.name],
                pass_fraction=pass_fraction, max_retries=mr,
            )
        return make_arm_policy(
            arm, pass_fraction=pass_fraction, max_retries=mr,
            warmup_reports=warmup_reports,
        )

    return factory


@dataclasses.dataclass
class DayResult:
    day: int
    variation: VariationModel
    elysium_threshold: float
    baseline: ArmSummary
    minos: ArmSummary
    timeline_baseline: tuple[np.ndarray, np.ndarray]
    timeline_minos: tuple[np.ndarray, np.ndarray]
    # §IV arm (no pre-test; threshold maintained online) — populated when
    # run_day(include_adaptive=True)
    adaptive: ArmSummary | None = None

    @property
    def analysis_improvement(self) -> float:
        return improvement(self.baseline.mean_analysis_ms, self.minos.mean_analysis_ms)

    @property
    def successful_requests_delta(self) -> float:
        return (self.minos.n_successful - self.baseline.n_successful) / self.baseline.n_successful

    @property
    def cost_saving(self) -> float:
        return improvement(self.baseline.cost_per_million, self.minos.cost_per_million)


@dataclasses.dataclass
class WeekResult:
    days: list[DayResult]

    @property
    def overall_analysis_improvement(self) -> float:
        b = np.mean([d.baseline.mean_analysis_ms for d in self.days])
        m = np.mean([d.minos.mean_analysis_ms for d in self.days])
        return improvement(b, m)

    @property
    def overall_successful_delta(self) -> float:
        b = sum(d.baseline.n_successful for d in self.days)
        m = sum(d.minos.n_successful for d in self.days)
        return (m - b) / b

    @property
    def overall_cost_saving(self) -> float:
        b = sum(d.baseline.cost.total for d in self.days) / max(
            1, sum(d.baseline.cost.n_successful for d in self.days))
        m = sum(d.minos.cost.total for d in self.days) / max(
            1, sum(d.minos.cost.n_successful for d in self.days))
        return improvement(b, m)


def run_pretest_phase(
    variation: VariationModel,
    spec: FunctionSpec = PAPER_SPEC,
    pricing: Pricing = PAPER_PRICING,
    *,
    n_vus: int = 10,
    duration_ms: float = 60_000.0,
    seed: int = 1234,
    pass_fraction: float = PASS_FRACTION,
) -> float:
    """§III-A: measure the elysium threshold with a short unguarded run."""
    disabled = MinosPolicy(elysium_threshold=float("inf"), enabled=False)
    plat = FaaSPlatform(spec, variation, disabled, pricing, seed=seed)
    run_closed_loop(plat, n_vus=n_vus, duration_ms=duration_ms)
    # the unguarded platform never benchmarks; probe durations are what the
    # probe WOULD have shown: work / speed of each started instance. During
    # pre-testing we benchmark explicitly (it is the pre-test's purpose).
    speeds = [r.instance_speed for r in plat.results if r.served_by_cold]
    if not speeds:
        speeds = [r.instance_speed for r in plat.results]
    probes = [spec.benchmark_ms / s for s in speeds]
    return pretest_threshold(probes, pass_fraction)


def run_day(
    day: int,
    variation: VariationModel,
    *,
    spec: FunctionSpec = PAPER_SPEC,
    pricing: Pricing = PAPER_PRICING,
    n_vus: int = 10,
    duration_ms: float = 30 * 60 * 1000.0,
    max_retries: int = 5,
    seed: int = 0,
    threshold: float | None = None,
    include_adaptive: bool = False,
) -> DayResult:
    if threshold is None:
        threshold = run_pretest_phase(variation, spec, pricing, seed=seed * 7919 + day)

    base_policy = make_arm_policy("disabled")
    base_plat = FaaSPlatform(spec, variation, base_policy, pricing, seed=seed * 31 + day)
    base_results = run_closed_loop(base_plat, n_vus=n_vus, duration_ms=duration_ms)

    minos_policy = make_arm_policy("fixed", threshold=threshold, max_retries=max_retries)
    minos_plat = FaaSPlatform(spec, variation, minos_policy, pricing, seed=seed * 37 + day)
    minos_results = run_closed_loop(minos_plat, n_vus=n_vus, duration_ms=duration_ms)

    adaptive_summary = None
    if include_adaptive:
        ad_policy = make_arm_policy("adaptive", max_retries=max_retries)
        ad_plat = FaaSPlatform(spec, variation, ad_policy, pricing, seed=seed * 41 + day)
        ad_results = run_closed_loop(ad_plat, n_vus=n_vus, duration_ms=duration_ms)
        adaptive_summary = ArmSummary.from_platform("adaptive", ad_plat, ad_results)

    return DayResult(
        day=day,
        variation=variation,
        elysium_threshold=threshold,
        baseline=ArmSummary.from_platform("baseline", base_plat, base_results),
        minos=ArmSummary.from_platform("minos", minos_plat, minos_results),
        timeline_baseline=cost_timeline(
            base_results, base_plat.cost, duration_ms,
            termination_events=base_plat.termination_events),
        timeline_minos=cost_timeline(
            minos_results, minos_plat.cost, duration_ms,
            termination_events=minos_plat.termination_events),
        adaptive=adaptive_summary,
    )


def run_week(
    seed: int = 0,
    n_days: int = 7,
    *,
    spec: FunctionSpec = PAPER_SPEC,
    pricing: Pricing = PAPER_PRICING,
    n_vus: int = 10,
    duration_ms: float = 30 * 60 * 1000.0,
    stale_threshold: bool = False,
) -> WeekResult:
    """The full 7-day experiment (paper: 2025-02-03 .. 02-09, 3-4 pm UTC).

    ``stale_threshold=False`` (default) pre-tests before each day's run —
    the paper repeats the experiment "every day at the same time", with the
    threshold measured by a short pre-test before the runs. This is the
    robust protocol: across seeds it lands on the paper's numbers (analysis
    ~7-9 % faster, cost ~+1 %, max day ~3.3 %).

    ``stale_threshold=True`` pre-tests ONCE and reuses the threshold all
    week; day-to-day platform drift then de-calibrates the gate — a fast
    day passes nearly everyone (little benefit), a slow day terminates
    excessively (waste, emergency exits). Used by the ablation benchmark to
    show why the §IV online recalculation matters."""
    week = paper_week(seed=seed, n_days=n_days)
    threshold = (
        run_pretest_phase(week[0], spec, pricing, seed=seed * 7919)
        if stale_threshold
        else None
    )
    days = []
    for day, variation in enumerate(week):
        days.append(
            run_day(day, variation, spec=spec, pricing=pricing,
                    n_vus=n_vus, duration_ms=duration_ms, seed=seed,
                    threshold=threshold)
        )
    return WeekResult(days)
