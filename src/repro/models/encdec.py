"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

The modality frontend (mel-spectrogram + conv1d feature extractor) is the
permitted STUB: inputs are precomputed frame embeddings (B, frames, d_model)
supplied by ``input_specs``. Everything downstream — the bidirectional
encoder, the causal decoder with cross-attention, KV caches for decode — is
implemented. RoPE replaces Whisper's absolute embeddings (TPU-idiomatic;
noted in DESIGN.md §8).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import shard
from .attention import decode_attention_step, init_attention, prefill_attention
from .layers import cross_entropy, init_swiglu, normal_init, rms_norm, swiglu, unembed


def _init_enc_layer(cfg: ArchConfig, key):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": jnp.ones((cfg.d_model,), cfg.jax_dtype),
        "attn": init_attention(
            k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, False,
            cfg.jax_dtype,
        ),
        "ln2": jnp.ones((cfg.d_model,), cfg.jax_dtype),
        "mlp": init_swiglu(k2, cfg.d_model, cfg.d_ff, cfg.jax_dtype),
    }


def _init_dec_layer(cfg: ArchConfig, key):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": jnp.ones((cfg.d_model,), cfg.jax_dtype),
        "self_attn": init_attention(
            k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, False,
            cfg.jax_dtype,
        ),
        "ln_x": jnp.ones((cfg.d_model,), cfg.jax_dtype),
        "cross_attn": init_attention(
            k2, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, False,
            cfg.jax_dtype,
        ),
        "ln2": jnp.ones((cfg.d_model,), cfg.jax_dtype),
        "mlp": init_swiglu(k3, cfg.d_model, cfg.d_ff, cfg.jax_dtype),
    }


def init_params(cfg: ArchConfig, key) -> dict[str, Any]:
    k_emb, k_enc, k_dec, k_out = jax.random.split(key, 4)
    enc_keys = jax.random.split(k_enc, cfg.n_encoder_layers)
    dec_keys = jax.random.split(k_dec, cfg.n_layers)
    return {
        "embed": normal_init(k_emb, (cfg.vocab, cfg.d_model), 1.0, cfg.jax_dtype),
        "encoder": jax.vmap(functools.partial(_init_enc_layer, cfg))(enc_keys),
        "enc_norm": jnp.ones((cfg.d_model,), cfg.jax_dtype),
        "decoder": jax.vmap(functools.partial(_init_dec_layer, cfg))(dec_keys),
        "final_norm": jnp.ones((cfg.d_model,), cfg.jax_dtype),
        "unembed": normal_init(
            k_out, (cfg.d_model, cfg.vocab), cfg.d_model**-0.5, cfg.jax_dtype
        ),
    }


def encode(cfg: ArchConfig, params, frames: jax.Array, *, remat: bool = True):
    """frames: (B, F, d_model) stub conv-frontend output."""
    B, F, _ = frames.shape
    x = shard(frames.astype(cfg.jax_dtype), "batch", "seq", None)
    positions = jnp.broadcast_to(jnp.arange(F, dtype=jnp.int32)[None], (B, F))

    def body(x, p):
        h, _ = prefill_attention(
            p["attn"], rms_norm(x, p["ln1"], cfg.norm_eps), positions,
            rope_theta=cfg.rope_theta, eps=cfg.norm_eps, causal=False,
        )
        x = x + h
        m = swiglu(rms_norm(x, p["ln2"], cfg.norm_eps), **p["mlp"])
        return x + m, None

    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["encoder"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _cross_kv(cfg, p_attn, enc_out):
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p_attn.wk)
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p_attn.wv)
    return k, v


def decode_train(cfg: ArchConfig, params, tokens, enc_out, *, remat: bool = True):
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    x = shard(x, "batch", "seq", None)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def body(x, p):
        h, _ = prefill_attention(
            p["self_attn"], rms_norm(x, p["ln1"], cfg.norm_eps), positions,
            rope_theta=cfg.rope_theta, eps=cfg.norm_eps, causal=True,
        )
        x = x + h
        kv = _cross_kv(cfg, p["cross_attn"], enc_out)
        h, _ = prefill_attention(
            p["cross_attn"], rms_norm(x, p["ln_x"], cfg.norm_eps), positions,
            rope_theta=cfg.rope_theta, eps=cfg.norm_eps, causal=False,
            cross_kv=kv, use_rope=False,
        )
        x = x + h
        m = swiglu(rms_norm(x, p["ln2"], cfg.norm_eps), **p["mlp"])
        return x + m, None

    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["decoder"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return unembed(x, params["unembed"])


def forward(cfg: ArchConfig, params, batch, *, remat: bool = True):
    enc_out = encode(cfg, params, batch["frames"], remat=remat)
    return decode_train(cfg, params, batch["tokens"], enc_out, remat=remat), 0.0


def loss_fn(cfg: ArchConfig, params, batch, *, remat: bool = True):
    logits, aux = forward(cfg, params, batch, remat=remat)
    ce, nll = cross_entropy(logits, batch["labels"])
    return ce + aux, {"ce": ce, "nll": nll, "aux": aux}


def init_cache(cfg: ArchConfig, batch: int, max_len: int, **_):
    shape = (cfg.n_layers, batch, cfg.n_kv_heads, max_len, cfg.head_dim)
    cross = (cfg.n_layers, batch, cfg.n_kv_heads, cfg.encoder_frames, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, cfg.jax_dtype),
        "v": jnp.zeros(shape, cfg.jax_dtype),
        "cross_k": jnp.zeros(cross, cfg.jax_dtype),
        "cross_v": jnp.zeros(cross, cfg.jax_dtype),
        "lengths": jnp.zeros((batch,), jnp.int32),
    }


def prefill(cfg: ArchConfig, params, batch, cache):
    """Encode frames + store cross-KV; decoder starts empty (lengths=0).

    batch: {"frames": (B,F,d)}.
    """
    enc_out = encode(cfg, params, batch["frames"], remat=False)

    def kv_body(_, p):
        k, v = _cross_kv(cfg, p["cross_attn"], enc_out)
        return None, (k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3))

    _, (ck, cv) = jax.lax.scan(kv_body, None, params["decoder"])
    cache = dict(cache)
    cache["cross_k"], cache["cross_v"] = ck, cv
    return None, cache


def decode_step(cfg: ArchConfig, params, cache, tokens):
    B = tokens.shape[0]
    x = jnp.take(params["embed"], tokens, axis=0)
    x = shard(x, "batch", "seq", None)
    lengths = cache["lengths"]
    frames = cache["cross_k"].shape[3]
    all_frames = jnp.full((B,), frames, jnp.int32)

    def body(x, layer):
        p, kc, vc, ck, cv = layer
        h, kc, vc = decode_attention_step(
            p["self_attn"], rms_norm(x, p["ln1"], cfg.norm_eps), kc, vc, lengths,
            rope_theta=cfg.rope_theta, eps=cfg.norm_eps,
        )
        x = x + h
        h, _, _ = decode_attention_step(
            p["cross_attn"], rms_norm(x, p["ln_x"], cfg.norm_eps), ck, cv,
            all_frames, rope_theta=cfg.rope_theta, eps=cfg.norm_eps,
            use_rope=False, update_cache=False,
        )
        x = x + h
        m = swiglu(rms_norm(x, p["ln2"], cfg.norm_eps), **p["mlp"])
        return x + m, (kc, vc)

    x, (ks, vs) = jax.lax.scan(
        body, x, (params["decoder"], cache["k"], cache["v"],
                  cache["cross_k"], cache["cross_v"])
    )
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(x, params["unembed"])
    new_cache = dict(cache)
    new_cache["k"], new_cache["v"] = ks, vs
    new_cache["lengths"] = lengths + 1
    return logits, new_cache
