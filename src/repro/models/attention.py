"""GQA attention: prefill (full / sliding-window / causal) and single-token
decode against a KV cache. Pure-jnp paths are the default inside pjit (a
CPU-interpreted pallas_call cannot be SPMD-partitioned); the Pallas kernels
are the TPU path and are validated separately in tests.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.kernels import ref as kref
from .layers import normal_init, rms_norm, rope

# Decode attention strategy:
#   "local" (default) — jnp reference attention; SPMD derives collectives.
#   "shard_map" — §Perf pick-3 iter-4: explicit flash-decode. The KV cache
#       is sharded along its LENGTH over the model axis; each shard computes
#       local masked scores + LSE, combines with pmax/psum (KBs of wire
#       instead of the 512 MiB/layer cache all-gather XLA chose), and the
#       new token row is written locally by exactly one shard.
DECODE_ATTN_MODE = "local"

# KV-cache update strategy for decode:
#   "scatter" (default) — per-sequence dynamic_update_slice; touches only the
#       written row (O(hd) bytes/seq). The beyond-paper optimization from
#       EXPERIMENTS.md §Perf pick-3: the one-hot path rewrites the ENTIRE
#       cache every step (~35 GiB/dev/step for llama3.2-1b decode_32k).
#   "onehot" — masked full-cache blend; the paper-faithful baseline we
#       measured first (kept selectable for the §Perf record).
CACHE_UPDATE_MODE = "scatter"


def _write_cache_row(cache: jax.Array, new: jax.Array, slot: jax.Array) -> jax.Array:
    """cache: (B, K, S, hd); new: (B, K, 1, hd); slot: (B,) int32."""
    if CACHE_UPDATE_MODE == "onehot":
        oh = jax.nn.one_hot(slot, cache.shape[2], dtype=cache.dtype)  # (B, S)
        return cache * (1.0 - oh[:, None, :, None]) + new * oh[:, None, :, None]

    def one(c, n, s):  # (K, S, hd), (K, 1, hd), scalar
        return jax.lax.dynamic_update_slice(c, n.astype(c.dtype), (0, s, 0))

    return jax.vmap(one)(cache, new, slot)


class AttnParams(NamedTuple):
    wq: jax.Array  # (d, H, hd)
    wk: jax.Array  # (d, K, hd)
    wv: jax.Array  # (d, K, hd)
    wo: jax.Array  # (H, hd, d)
    q_norm: Optional[jax.Array]  # (hd,) or None
    k_norm: Optional[jax.Array]


def init_attention(key, d_model: int, n_heads: int, n_kv_heads: int, head_dim: int,
                   qk_norm: bool, dtype) -> AttnParams:
    kq, kk, kv, ko = jax.random.split(key, 4)
    s = d_model**-0.5
    so = (n_heads * head_dim) ** -0.5
    return AttnParams(
        wq=normal_init(kq, (d_model, n_heads, head_dim), s, dtype),
        wk=normal_init(kk, (d_model, n_kv_heads, head_dim), s, dtype),
        wv=normal_init(kv, (d_model, n_kv_heads, head_dim), s, dtype),
        wo=normal_init(ko, (n_heads, head_dim, d_model), so, dtype),
        q_norm=jnp.ones((head_dim,), dtype) if qk_norm else None,
        k_norm=jnp.ones((head_dim,), dtype) if qk_norm else None,
    )


def _project_qkv(p: AttnParams, x: jax.Array, positions: jax.Array,
                 rope_theta: float, eps: float, use_rope: bool = True):
    """x: (B, S, d) -> q (B, S, H, hd), k/v (B, S, K, hd)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p.wq)
    k = jnp.einsum("bsd,dhk->bshk", x, p.wk)
    v = jnp.einsum("bsd,dhk->bshk", x, p.wv)
    if p.q_norm is not None:
        q = rms_norm(q, p.q_norm, eps)
        k = rms_norm(k, p.k_norm, eps)
    if use_rope:
        q = rope(q, positions, rope_theta)
        k = rope(k, positions, rope_theta)
    q = shard(q, "batch", None, "heads", None)
    k = shard(k, "batch", None, "kv_heads", None)
    v = shard(v, "batch", None, "kv_heads", None)
    return q, k, v


def prefill_attention(
    p: AttnParams,
    x: jax.Array,                  # (B, S, d)
    positions: jax.Array,          # (B, S)
    *,
    rope_theta: float,
    eps: float,
    causal: bool = True,
    window: Optional[int] = None,
    use_rope: bool = True,
    cross_kv: Optional[tuple[jax.Array, jax.Array]] = None,  # (B, S_kv, K, hd)
) -> tuple[jax.Array, tuple[jax.Array, jax.Array]]:
    """Returns (out (B,S,d), (k_cache, v_cache) in (B,K,S,hd) layout)."""
    if cross_kv is None:
        q, k, v = _project_qkv(p, x, positions, rope_theta, eps, use_rope)
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, p.wq)
        if p.q_norm is not None:
            q = rms_norm(q, p.q_norm, eps)
        if use_rope:
            q = rope(q, positions, rope_theta)
        k, v = cross_kv
    # (B, heads, S, hd) layout for the kernels
    qh = q.transpose(0, 2, 1, 3)
    kh = k.transpose(0, 2, 1, 3)
    vh = v.transpose(0, 2, 1, 3)
    out = kref.attention_ref(qh, kh, vh, causal=causal, window=window)
    out = out.transpose(0, 2, 1, 3)  # (B, S, H, hd)
    y = jnp.einsum("bshk,hkd->bsd", out, p.wo)
    y = shard(y, "batch", "seq", None)
    return y, (kh, vh)


def _sharded_flash_decode(
    q: jax.Array,        # (B, H, 1, hd)
    k_cache: jax.Array,  # (B, K, S, hd) — S sharded over "model"
    v_cache: jax.Array,
    k_new: jax.Array,    # (B, K, 1, hd)
    v_new: jax.Array,
    slot: jax.Array,     # (B,) global write position
    valid: jax.Array,    # (B,) valid prefix length after the write
    sm_scale: float,
):
    """Flash-decode over a length-sharded cache via shard_map."""
    from jax.sharding import PartitionSpec as P

    from repro.distributed.sharding import current_mesh, logical_to_spec

    mesh = current_mesh()
    dp = logical_to_spec("batch")[0]  # physical axes for batch (or None)

    def inner(q, kc, vc, nk, nv, slot, valid):
        idx = jax.lax.axis_index("model")
        B, K, S_loc, hd = kc.shape
        H = q.shape[1]
        G = H // K
        start = idx * S_loc
        ls = slot - start  # local write position, (B,)

        def write(c, n):
            inb = (ls >= 0) & (ls < S_loc)
            lsc = jnp.clip(ls, 0, S_loc - 1)
            upd = jax.vmap(
                lambda cc, nn, s: jax.lax.dynamic_update_slice(
                    cc, nn.astype(cc.dtype), (0, s, 0))
            )(c, n, lsc)
            return jnp.where(inb[:, None, None, None], upd, c)

        kc = write(kc, nk)
        vc = write(vc, nv)
        qg = q.reshape(B, K, G, hd)
        s = jnp.einsum("bkgd,bksd->bkgs", qg, kc,
                       preferred_element_type=jnp.float32) * sm_scale
        k_pos = start + jnp.arange(S_loc)
        s = jnp.where(k_pos[None, None, None, :] < valid[:, None, None, None],
                      s, -1e30)
        m_loc = jnp.max(s, axis=-1)                       # (B, K, G)
        m = jax.lax.pmax(m_loc, "model")
        p = jnp.exp(s - m[..., None])
        l = jax.lax.psum(jnp.sum(p, axis=-1), "model")
        o = jnp.einsum("bkgs,bksd->bkgd", p.astype(vc.dtype), vc,
                       preferred_element_type=jnp.float32)
        o = jax.lax.psum(o, "model")
        o = o / jnp.maximum(l, 1e-20)[..., None]
        return o.reshape(B, H, 1, hd).astype(q.dtype), kc, vc

    bspec = lambda *rest: P(dp, *rest)  # noqa: E731
    out, kc, vc = jax.shard_map(
        inner,
        mesh=mesh,
        in_specs=(
            bspec(None, None, None),            # q replicated over model
            bspec(None, "model", None),         # cache length-sharded
            bspec(None, "model", None),
            bspec(None, None, None),
            bspec(None, None, None),
            P(dp), P(dp),
        ),
        out_specs=(bspec(None, None, None), bspec(None, "model", None),
                   bspec(None, "model", None)),
        check_vma=False,
    )(q, k_cache, v_cache, k_new, v_new, slot, valid)
    return out, kc, vc


def decode_attention_step(
    p: AttnParams,
    x: jax.Array,                 # (B, 1, d) current token activations
    k_cache: jax.Array,           # (B, K, S, hd)
    v_cache: jax.Array,
    lengths: jax.Array,           # (B,) current valid length (position of new tok)
    *,
    rope_theta: float,
    eps: float,
    window: Optional[int] = None,
    use_rope: bool = True,
    update_cache: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One decode step. Returns (out (B,1,d), new_k_cache, new_v_cache).

    With ``window``, the cache has size S == window and new entries are
    written at position ``lengths % window`` (ring buffer); attention masks
    to the min(lengths, window) most recent entries. RoPE uses absolute
    positions so rotations stay consistent in the ring.
    """
    B, _, d = x.shape
    S = k_cache.shape[2]
    positions = lengths[:, None]  # (B, 1) absolute position of the new token
    q, k_new, v_new = _project_qkv(p, x, positions, rope_theta, eps, use_rope)
    qh = q.transpose(0, 2, 1, 3)              # (B, H, 1, hd)
    k_new = k_new.transpose(0, 2, 1, 3)       # (B, K, 1, hd)
    v_new = v_new.transpose(0, 2, 1, 3)
    from repro.distributed.sharding import current_mesh
    if (
        DECODE_ATTN_MODE == "shard_map"
        and update_cache
        and current_mesh() is not None
        and "model" in current_mesh().axis_names
    ):
        import math as _math

        slot = lengths % S if window is not None else lengths
        valid = jnp.minimum(lengths + 1, S)
        out, k_cache, v_cache = _sharded_flash_decode(
            qh, k_cache, v_cache, k_new, v_new, slot, valid,
            sm_scale=1.0 / _math.sqrt(qh.shape[-1]),
        )
        out = out.transpose(0, 2, 1, 3)
        y = jnp.einsum("bshk,hkd->bsd", out, p.wo)
        return shard(y, "batch", None, None), k_cache, v_cache
    if update_cache:
        slot = lengths % S if window is not None else lengths
        k_cache = _write_cache_row(k_cache, k_new, slot)
        v_cache = _write_cache_row(v_cache, v_new, slot)
        valid = jnp.minimum(lengths + 1, S)
    else:
        valid = jnp.minimum(lengths, S)
    out = kref.decode_attention_ref(qh, k_cache, v_cache, valid)
    out = out.transpose(0, 2, 1, 3)
    y = jnp.einsum("bshk,hkd->bsd", out, p.wo)
    return shard(y, "batch", None, None), k_cache, v_cache
