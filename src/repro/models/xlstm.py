"""xLSTM language model (arXiv:2405.04517): a stack of mLSTM blocks with an
sLSTM block every ``cfg.slstm_every`` layers (the paper's mixed-block
design). d_ff == 0: blocks carry their own up/down projections (expand 2x),
no separate FFN.

Layer layout (n_layers=48, slstm_every=8):
  [7x mLSTM, 1x sLSTM] x 6  — the mLSTM run of each group is a scanned
stack (one compiled body), the sLSTM block is applied unscanned (it is the
sequential cell; there are only n_layers/slstm_every of them).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import shard
from .layers import cross_entropy, normal_init, rms_norm, unembed
from .ssm import mlstm_chunked, mlstm_step, slstm_scan

EXPAND = 2


def _dims(cfg: ArchConfig):
    d_inner = EXPAND * cfg.d_model
    H = cfg.n_heads
    P = d_inner // H
    return d_inner, H, P


def init_mlstm_block(cfg: ArchConfig, key) -> dict[str, Any]:
    d, (d_inner, H, P) = cfg.d_model, _dims(cfg)
    ks = jax.random.split(key, 8)
    s = d**-0.5
    si = d_inner**-0.5
    dt = cfg.jax_dtype
    return {
        "ln": jnp.ones((d,), dt),
        "w_up": normal_init(ks[0], (d, 2 * d_inner), s, dt),
        # per-head block-diagonal projections (xLSTM's multi-head design):
        # (H, P, P) instead of dense (d_inner, d_inner)
        "wq": normal_init(ks[1], (H, P, P), P**-0.5, dt),
        "wk": normal_init(ks[2], (H, P, P), P**-0.5, dt),
        "wv": normal_init(ks[3], (H, P, P), P**-0.5, dt),
        "w_i": normal_init(ks[4], (d_inner, H), si, dt),
        "w_f": normal_init(ks[5], (d_inner, H), si, dt),
        "b_f": jnp.full((H,), 3.0, dt),  # open forget gates at init
        "b_i": jnp.full((H,), -2.0, dt),
        "hnorm": jnp.ones((d_inner,), dt),
        "w_down": normal_init(ks[6], (d_inner, d), si, dt),
    }


def _mlstm_qkv(cfg, p, x):
    d_inner, H, P = _dims(cfg)
    xn = rms_norm(x, p["ln"], cfg.norm_eps)
    u = xn @ p["w_up"]
    a, z = jnp.split(u, 2, axis=-1)
    a = shard(a, "batch", None, "ff")
    B, S = x.shape[:2]
    ah = a.reshape(B, S, H, P)
    q = jnp.einsum("bshp,hpr->bshr", ah, p["wq"])
    k = jnp.einsum("bshp,hpr->bshr", ah, p["wk"])
    v = jnp.einsum("bshp,hpr->bshr", ah, p["wv"])
    ig = a @ p["w_i"] + p["b_i"].astype(jnp.float32)
    fg = a @ p["w_f"] + p["b_f"].astype(jnp.float32)
    return q, k, v, ig, fg, z


def mlstm_block(cfg: ArchConfig, p, x, *, chunk: int, state=None):
    """x: (B,S,d). Returns (y, new_state)."""
    q, k, v, ig, fg, z = _mlstm_qkv(cfg, p, x)
    h, new_state = mlstm_chunked(q, k, v, ig, fg, chunk=chunk, state=state)
    B, S = x.shape[:2]
    h = h.reshape(B, S, -1)
    h = rms_norm(h, p["hnorm"], cfg.norm_eps) * jax.nn.silu(z)
    return x + h @ p["w_down"], new_state


def mlstm_block_step(cfg: ArchConfig, p, x, state):
    """x: (B,1,d); single-token decode."""
    q, k, v, ig, fg, z = _mlstm_qkv(cfg, p, x)
    h, new_state = mlstm_step(q[:, 0], k[:, 0], v[:, 0], ig[:, 0], fg[:, 0], state)
    h = h.reshape(x.shape[0], 1, -1)
    h = rms_norm(h, p["hnorm"], cfg.norm_eps) * jax.nn.silu(z)
    return x + h @ p["w_down"], new_state


def init_slstm_block(cfg: ArchConfig, key) -> dict[str, Any]:
    d = cfg.d_model
    H = cfg.n_heads
    P = d // H
    ks = jax.random.split(key, 3)
    dt = cfg.jax_dtype
    return {
        "ln": jnp.ones((d,), dt),
        "w_gates": normal_init(ks[0], (d, 4 * d), d**-0.5, dt),
        "b_gates": jnp.concatenate(
            [jnp.zeros((2 * d,), dt), jnp.full((d,), 3.0, dt), jnp.zeros((d,), dt)]
        ),
        "R": normal_init(ks[1], (4, H, P, P), P**-0.5, dt),
        "hnorm": jnp.ones((d,), dt),
        "w_out": normal_init(ks[2], (d, d), d**-0.5, dt),
    }


def _slstm_gates(cfg, p, x):
    B, S, d = x.shape
    H = cfg.n_heads
    P = d // H
    xn = rms_norm(x, p["ln"], cfg.norm_eps)
    xg = xn @ p["w_gates"] + p["b_gates"].astype(jnp.float32)
    return xg.reshape(B, S, 4, H, P)


def slstm_block(cfg: ArchConfig, p, x, *, state=None):
    xg = _slstm_gates(cfg, p, x)
    h, new_state = _slstm_scan_dispatch(xg, p["R"], state)
    B, S = x.shape[:2]
    h = h.reshape(B, S, -1).astype(x.dtype)
    h = rms_norm(h, p["hnorm"], cfg.norm_eps)
    return x + h @ p["w_out"], new_state


# §Perf pick-2 knob: run the sLSTM cell under shard_map (batch-local, no
# partitioner-inserted per-step collectives). Off by default so baseline
# measurements stay baseline; enabled by dryrun --slstm-shard-map.
SLSTM_SHARD_MAP = False


def _slstm_scan_dispatch(xg, R, state):
    """Run the sequential sLSTM cell under shard_map when a mesh is active:
    the cell is purely batch-parallel (R replicated), so making each device
    run its batch shard locally removes the per-time-step all-reduces XLA's
    SPMD partitioner otherwise inserts in the backward-through-time loop
    (§Perf pick-2: 6 blocks x 4096 steps x 16.8 MB wire)."""
    from jax.sharding import PartitionSpec as P

    from repro.distributed.sharding import current_mesh, logical_to_spec

    mesh = current_mesh()
    B = xg.shape[0]
    dp = logical_to_spec("batch")[0] if mesh is not None else None
    dp_size = 1
    if mesh is not None and dp is not None:
        for a in ((dp,) if isinstance(dp, str) else dp):
            dp_size *= mesh.shape[a]
    if (not SLSTM_SHARD_MAP or mesh is None or dp is None or B % dp_size
            or dp_size == 1):
        return slstm_scan(xg, R, state=state)
    if state is None:
        Bsz, _, _, H, Pd = xg.shape
        z0 = jnp.zeros((Bsz, H, Pd), jnp.float32)
        state = (z0, z0, z0, jnp.full((Bsz, H, Pd), -1e30, jnp.float32))
    bspec = P(dp, None, None)

    def inner(xg, R, state):
        return slstm_scan(xg, R, state=state)

    return jax.shard_map(
        inner,
        mesh=mesh,
        in_specs=(P(dp, None, None, None, None), P(None, None, None, None),
                  (bspec, bspec, bspec, bspec)),
        out_specs=(P(dp, None, None, None), (bspec, bspec, bspec, bspec)),
        check_vma=False,
    )(xg, R, state)


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------


def _plan(cfg: ArchConfig) -> list[tuple[str, int]]:
    """[(kind, count)] groups: runs of mLSTM followed by one sLSTM."""
    if not cfg.slstm_every:
        return [("mlstm", cfg.n_layers)]
    groups = []
    n_groups = cfg.n_layers // cfg.slstm_every
    for _ in range(n_groups):
        groups.append(("mlstm", cfg.slstm_every - 1))
        groups.append(("slstm", 1))
    rem = cfg.n_layers - n_groups * cfg.slstm_every
    if rem:
        groups.append(("mlstm", rem))
    return groups


def init_params(cfg: ArchConfig, key) -> dict[str, Any]:
    k_emb, k_blocks, k_out = jax.random.split(key, 3)
    params: dict[str, Any] = {
        "embed": normal_init(k_emb, (cfg.vocab, cfg.d_model), 1.0, cfg.jax_dtype),
        "final_norm": jnp.ones((cfg.d_model,), cfg.jax_dtype),
        "unembed": normal_init(
            k_out, (cfg.d_model, cfg.vocab), cfg.d_model**-0.5, cfg.jax_dtype
        ),
        "groups": [],
    }
    init_mlstm_stack = jax.vmap(functools.partial(init_mlstm_block, cfg))
    for gi, (kind, count) in enumerate(_plan(cfg)):
        gk = jax.random.fold_in(k_blocks, gi)
        if kind == "mlstm":
            keys = jax.random.split(gk, count)
            params["groups"].append(init_mlstm_stack(keys))
        else:
            params["groups"].append(init_slstm_block(cfg, gk))
    return params


def _apply(cfg: ArchConfig, params, x, *, chunk: int, states=None, remat: bool = True):
    """Returns (x, new_states). states: list aligned with _plan groups —
    for mlstm groups a stacked (C,n,m) tuple, for slstm the (c,n,h,m)."""
    plan = _plan(cfg)
    new_states = []
    for gi, (kind, count) in enumerate(plan):
        p = params["groups"][gi]
        st = states[gi] if states is not None else None
        if kind == "mlstm":

            def body(x, inp):
                pl, s = inp
                y, ns = mlstm_block(cfg, pl, x, chunk=chunk, state=s)
                return y, ns

            if remat:
                body = jax.checkpoint(body)
            if st is None:
                B = x.shape[0]
                d_inner, H, P = _dims(cfg)
                st = (
                    jnp.zeros((count, B, H, P, P), jnp.float32),
                    jnp.zeros((count, B, H, P), jnp.float32),
                    jnp.full((count, B, H), -1e30, jnp.float32),
                )
            x, ns = jax.lax.scan(body, x, (p, st))
            new_states.append(ns)
        else:
            x, ns = slstm_block(cfg, p, x, state=st)
            new_states.append(ns)
    return x, new_states


def forward(cfg: ArchConfig, params, tokens, *, remat: bool = True):
    x = jnp.take(params["embed"], tokens, axis=0)
    x = shard(x, "batch", "seq", None)
    chunk = cfg.ssm.chunk if cfg.ssm else 256
    x, _ = _apply(cfg, params, x, chunk=chunk, remat=remat)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return unembed(x, params["unembed"]), 0.0


def loss_fn(cfg: ArchConfig, params, batch, *, remat: bool = True):
    logits, aux = forward(cfg, params, batch["tokens"], remat=remat)
    ce, nll = cross_entropy(logits, batch["labels"])
    return ce + aux, {"ce": ce, "nll": nll, "aux": aux}


def init_cache(cfg: ArchConfig, batch: int, max_len: int, **_):
    """Recurrent state; O(1) in max_len — the xLSTM long-context advantage."""
    d_inner, H, P = _dims(cfg)
    d = cfg.d_model
    Hs, Ps = cfg.n_heads, d // cfg.n_heads
    states = []
    for kind, count in _plan(cfg):
        if kind == "mlstm":
            states.append(
                (
                    jnp.zeros((count, batch, H, P, P), jnp.float32),
                    jnp.zeros((count, batch, H, P), jnp.float32),
                    jnp.full((count, batch, H), -1e30, jnp.float32),
                )
            )
        else:
            z = jnp.zeros((batch, Hs, Ps), jnp.float32)
            states.append((z, z, z, jnp.full((batch, Hs, Ps), -1e30, jnp.float32)))
    return {"states": states, "lengths": jnp.zeros((batch,), jnp.int32)}


def prefill(cfg: ArchConfig, params, tokens, cache):
    x = jnp.take(params["embed"], tokens, axis=0)
    x = shard(x, "batch", "seq", None)
    chunk = cfg.ssm.chunk if cfg.ssm else 256
    x, states = _apply(cfg, params, x, chunk=chunk, states=cache["states"], remat=False)
    x = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = unembed(x, params["unembed"])
    return logits, {"states": states, "lengths": cache["lengths"] + tokens.shape[1]}


def decode_step(cfg: ArchConfig, params, cache, tokens):
    x = jnp.take(params["embed"], tokens, axis=0)
    x = shard(x, "batch", "seq", None)
    new_states = []
    for gi, (kind, count) in enumerate(_plan(cfg)):
        p = params["groups"][gi]
        st = cache["states"][gi]
        if kind == "mlstm":

            def body(x, inp):
                pl, s = inp
                y, ns = mlstm_block_step(cfg, pl, x, s)
                return y, ns

            x, ns = jax.lax.scan(body, x, (p, st))
            new_states.append(ns)
        else:
            x, ns = slstm_block(cfg, p, x, state=st)
            new_states.append(ns)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(x, params["unembed"])
    return logits, {"states": new_states, "lengths": cache["lengths"] + 1}
