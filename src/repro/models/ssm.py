"""Recurrent sequence mixers, TPU-adapted: Mamba2 SSD (arXiv:2405.21060 as
used by Zamba2) and xLSTM's mLSTM/sLSTM cells (arXiv:2405.04517).

Hardware adaptation (see DESIGN.md §2): the reference CUDA kernels for these
papers are warp-level scans; the TPU-native formulation is the *chunked*
(block-parallel) scan — quadratic attention-like matmuls inside an
MXU-aligned chunk, a `lax.scan` carrying the recurrent state across chunks.
This turns the recurrence into dense (L×L)·(L×P) matmuls the MXU executes at
full throughput, with state materialized once per chunk instead of per step.

All cells expose:
  init_*        — parameter init
  *_chunked     — full-sequence (training/prefill) form
  *_step        — single-token decode form (the long_500k path)
and are validated against a naive per-step recurrence in tests/test_ssm.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from .layers import normal_init, rms_norm

# ---------------------------------------------------------------------------
# Mamba2 SSD: H_t = a_t · H_{t-1} + B_t ⊗ (Δ_t x_t);  y_t = C_t·H_t + D·x_t
#   a_t = exp(Δ_t · A) with A < 0 scalar per head (scalar-identity SSD).
# ---------------------------------------------------------------------------


def ssd_chunked(
    x: jax.Array,       # (B, S, H, P)  inputs (already Δ-scaled NOT applied)
    dt: jax.Array,      # (B, S, H)     Δ_t (positive)
    A: jax.Array,       # (H,)          negative decay rates
    Bm: jax.Array,      # (B, S, N)     input maps (shared across heads, 1 group)
    Cm: jax.Array,      # (B, S, N)
    D: jax.Array,       # (H,)          skip connection
    *,
    chunk: int,
    h0: jax.Array | None = None,  # (B, H, N, P) initial state
):
    """Chunked SSD scan. Returns (y (B,S,H,P), h_final (B,H,N,P))."""
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    L = min(chunk, S)
    pad = (-S) % L
    if pad:
        # padding is a no-op: dt=0 -> decay exp(0)=1 (state kept), input 0
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    S_orig, S = S, S + pad
    nC = S // L

    loga = dt * A[None, None, :]                       # (B, S, H) log decay, <=0
    xdt = x * dt[..., None]                            # Δ_t x_t

    # reshape into chunks
    def ch(t, trailing):  # (B, S, ...) -> (B, nC, L, ...)
        return t.reshape((Bsz, nC, L) + trailing)

    loga_c = ch(loga, (H,))
    xdt_c = ch(xdt, (H, P))
    B_c = ch(Bm, (N,))
    C_c = ch(Cm, (N,))
    csum = jnp.cumsum(loga_c, axis=2)                  # (B, nC, L, H) inclusive

    if h0 is None:
        h0 = jnp.zeros((Bsz, H, N, P), jnp.float32)

    def body(h_prev, inp):
        csum_i, x_i, B_i, C_i = inp                    # per-chunk slices
        # decay from position j (exclusive) to i: exp(csum_i - csum_j), j<=i
        # intra-chunk scores: S_ij = (C_i · B_j) * exp(csum_i - csum_j)
        gap = csum_i[:, :, None, :] - csum_i[:, None, :, :]   # (B, L, L, H)
        mask = jnp.tril(jnp.ones((L, L), bool))
        # mask BEFORE exp: masked (j>i) entries have gap>0; exp(large) is
        # inf and inf*0 in the backward pass poisons every gradient
        gap = jnp.where(mask[None, :, :, None], gap, -1e30)
        dec = jnp.exp(gap)
        cb = jnp.einsum("bin,bjn->bij", C_i.astype(jnp.float32), B_i.astype(jnp.float32))
        scores = cb[..., None] * dec                    # (B, L, L, H)
        y_intra = jnp.einsum("bijh,bjhp->bihp", scores, x_i.astype(jnp.float32))
        # inter-chunk: y_i += C_i · (exp(csum_i) * H_prev)
        y_inter = jnp.einsum(
            "bin,bhnp->bihp", C_i.astype(jnp.float32), h_prev
        ) * jnp.exp(csum_i)[..., None]
        # state update: H_new = exp(csum_L) H_prev + sum_j exp(csum_L - csum_j) B_j x_j
        tail = jnp.exp(csum_i[:, -1:, :] - csum_i)      # (B, L, H)
        h_new = h_prev * jnp.exp(csum_i[:, -1])[..., None, None]  # (B,H,1,1) bcast
        h_new = h_new + jnp.einsum(
            "bjn,bjh,bjhp->bhnp", B_i.astype(jnp.float32), tail, x_i.astype(jnp.float32)
        )
        return h_new, y_intra + y_inter

    inputs = (
        csum.transpose(1, 0, 2, 3),
        xdt_c.transpose(1, 0, 2, 3, 4),
        B_c.transpose(1, 0, 2, 3),
        C_c.transpose(1, 0, 2, 3),
    )
    h_final, y = jax.lax.scan(body, h0, inputs)
    y = y.transpose(1, 0, 2, 3, 4).reshape(Bsz, S, H, P)
    y = y + x.astype(jnp.float32) * D[None, None, :, None]
    return y[:, :S_orig].astype(x.dtype), h_final


def ssd_step(
    x: jax.Array,   # (B, H, P) one token (Δ not applied)
    dt: jax.Array,  # (B, H)
    A: jax.Array,   # (H,)
    Bm: jax.Array,  # (B, N)
    Cm: jax.Array,  # (B, N)
    D: jax.Array,   # (H,)
    h: jax.Array,   # (B, H, N, P) state
):
    """Single-token SSD recurrence (decode)."""
    a = jnp.exp(dt * A[None, :])                       # (B, H)
    xdt = (x * dt[..., None]).astype(jnp.float32)
    h = h * a[..., None, None] + jnp.einsum("bn,bhp->bhnp", Bm.astype(jnp.float32), xdt)
    y = jnp.einsum("bn,bhnp->bhp", Cm.astype(jnp.float32), h)
    y = y + x.astype(jnp.float32) * D[None, :, None]
    return y.astype(x.dtype), h


# ---------------------------------------------------------------------------
# mLSTM (xLSTM): matrix memory C_t (P_k x P_v per head), exp input gating
# with max-stabilizer m; chunked form carries (C, n, m).
# ---------------------------------------------------------------------------


def mlstm_chunked(
    q: jax.Array,   # (B, S, H, P)
    k: jax.Array,
    v: jax.Array,
    i_gate: jax.Array,  # (B, S, H) pre-activation (exp gate)
    f_gate: jax.Array,  # (B, S, H) pre-activation (sigmoid gate)
    *,
    chunk: int,
    state: tuple[jax.Array, jax.Array, jax.Array] | None = None,
):
    """Returns (h (B,S,H,P), (C, n, m) final state).

    State convention: stored C/n are scaled by exp(-m) (m is the running
    log-stabilizer), i.e. C_true = C_stored * exp(m).
    """
    Bsz, S, H, P = q.shape
    L = min(chunk, S)
    pad = (-S) % L
    if pad:
        # padding is a no-op: i_gate -> -inf (no input), f_gate -> +inf
        # (forget gate 1: state kept)
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        i_gate = jnp.pad(i_gate, ((0, 0), (0, pad), (0, 0)), constant_values=-1e30)
        f_gate = jnp.pad(f_gate, ((0, 0), (0, pad), (0, 0)), constant_values=60.0)
    S_orig, S = S, S + pad
    nC = S // L
    scale = P**-0.5

    logf = -jax.nn.softplus(-f_gate).astype(jnp.float32)   # log sigmoid(f)
    i_g = i_gate.astype(jnp.float32)

    def ch(t, trailing):
        return t.reshape((Bsz, nC, L) + trailing)

    q_c, k_c, v_c = ch(q, (H, P)), ch(k, (H, P)), ch(v, (H, P))
    logf_c, i_c = ch(logf, (H,)), ch(i_g, (H,))

    if state is None:
        C0 = jnp.zeros((Bsz, H, P, P), jnp.float32)
        n0 = jnp.zeros((Bsz, H, P), jnp.float32)
        m0 = jnp.full((Bsz, H), -1e30, jnp.float32)
    else:
        C0, n0, m0 = state

    def body(carry, inp):
        C_prev, n_prev, m_prev = carry
        q_i, k_i, v_i, logf_i, ig_i = inp
        b = jnp.cumsum(logf_i, axis=1)                  # (B, L, H) inclusive
        # source log-gain within chunk: a_j = i_j - b_j
        a = ig_i - b
        # per-position stabilizer: m_i = max(b_i + cummax_j<=i(a_j), b_i + m_prev)
        acum = jax.lax.cummax(a, axis=1)
        m_pos = b + jnp.maximum(acum, m_prev[:, None, :])   # (B, L, H)
        # intra scores: D_ij = exp(b_i - b_j + i_j - m_i) for j <= i
        gap = b[:, :, None, :] - b[:, None, :, :] + ig_i[:, None, :, :]  # (B,L,L,H)
        gap = gap - m_pos[:, :, None, :]
        mask = jnp.tril(jnp.ones((L, L), bool))
        gap = jnp.where(mask[None, :, :, None], gap, -1e30)  # pre-exp mask
        dmat = jnp.exp(gap)
        qk = jnp.einsum("bihp,bjhp->bijh", q_i.astype(jnp.float32),
                        k_i.astype(jnp.float32)) * scale
        S_ij = qk * dmat
        num = jnp.einsum("bijh,bjhp->bihp", S_ij, v_i.astype(jnp.float32))
        den = jnp.sum(S_ij, axis=2)                     # (B, L, H)
        # inter-chunk: factor exp(b_i + m_prev - m_i)
        inter_f = jnp.exp(b + m_prev[:, None, :] - m_pos)   # (B, L, H)
        qC = jnp.einsum("bihp,bhpr->bihr", q_i.astype(jnp.float32), C_prev) * scale
        qn = jnp.einsum("bihp,bhp->bih", q_i.astype(jnp.float32), n_prev) * scale
        num = num + qC * inter_f[..., None]
        den = den + qn * inter_f
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_pos))[..., None]
        # ---- state update to chunk end ----
        b_L = b[:, -1, :]                               # (B, H)
        m_new = jnp.maximum(b_L + m_prev, b_L + acum[:, -1, :])
        src = jnp.exp(b_L[:, None, :] - b + ig_i - m_new[:, None, :])  # (B, L, H)
        C_new = C_prev * jnp.exp(b_L + m_prev - m_new)[..., None, None] + jnp.einsum(
            "bjh,bjhp,bjhr->bhpr", src, k_i.astype(jnp.float32), v_i.astype(jnp.float32)
        )
        n_new = n_prev * jnp.exp(b_L + m_prev - m_new)[..., None] + jnp.einsum(
            "bjh,bjhp->bhp", src, k_i.astype(jnp.float32)
        )
        return (C_new, n_new, m_new), h

    inputs = tuple(
        t.transpose(1, 0, 2, 3, 4) if t.ndim == 5 else t.transpose(1, 0, 2, 3)
        for t in (q_c, k_c, v_c, logf_c, i_c)
    )
    (C, n, m), h = jax.lax.scan(body, (C0, n0, m0), inputs)
    h = h.transpose(1, 0, 2, 3, 4).reshape(Bsz, S, H, P)
    return h[:, :S_orig].astype(q.dtype), (C, n, m)


def mlstm_step(
    q: jax.Array,  # (B, H, P)
    k: jax.Array,
    v: jax.Array,
    i_gate: jax.Array,  # (B, H)
    f_gate: jax.Array,  # (B, H)
    state: tuple[jax.Array, jax.Array, jax.Array],
):
    """One mLSTM recurrence step (decode)."""
    C, n, m = state
    P = q.shape[-1]
    scale = P**-0.5
    logf = -jax.nn.softplus(-f_gate).astype(jnp.float32)
    ig = i_gate.astype(jnp.float32)
    m_new = jnp.maximum(logf + m, ig)
    f_s = jnp.exp(logf + m - m_new)
    i_s = jnp.exp(ig - m_new)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    C = C * f_s[..., None, None] + i_s[..., None, None] * kf[..., :, None] * vf[..., None, :]
    n = n * f_s[..., None] + i_s[..., None] * kf
    qf = q.astype(jnp.float32) * scale
    num = jnp.einsum("bhp,bhpr->bhr", qf, C)
    den = jnp.einsum("bhp,bhp->bh", qf, n)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
    return h.astype(q.dtype), (C, n, m_new)


# ---------------------------------------------------------------------------
# sLSTM: scalar memory with true recurrence (h_{t-1} feeds the gates) —
# inherently sequential; lax.scan over time. Block-diagonal recurrent
# matrices per head (the paper's design for parallelizable heads).
# ---------------------------------------------------------------------------


def slstm_scan(
    x_gates: jax.Array,  # (B, S, 4, H, P) pre-activations from input (z,i,f,o)
    R: jax.Array,        # (4, H, P, P) recurrent block-diagonal weights
    *,
    state: tuple | None = None,
):
    """Returns (h (B,S,H,P), final (c,n,h,m)). Gate order: z, i, f, o."""
    Bsz, S, _, H, P = x_gates.shape
    if state is None:
        z0 = jnp.zeros((Bsz, H, P), jnp.float32)
        state = (z0, z0, z0, jnp.full((Bsz, H, P), -1e30, jnp.float32))

    def body(carry, xg):
        c, n, h_prev, m = carry
        # NOTE (§Perf pick-2): a with_sharding_constraint here does NOT stop
        # XLA from inserting per-time-step backward all-reduces (measured:
        # no change); the working fix is running this whole cell under
        # shard_map — see xlstm._slstm_scan_dispatch.
        # gate pre-activations: input part + recurrent part
        rec = jnp.einsum("bhp,ghpr->gbhr", h_prev, R.astype(jnp.float32))
        zt = jnp.tanh(xg[:, 0].astype(jnp.float32) + rec[0])
        it = xg[:, 1].astype(jnp.float32) + rec[1]           # exp gate (log-space)
        ft = xg[:, 2].astype(jnp.float32) + rec[2]           # sigmoid gate
        ot = jax.nn.sigmoid(xg[:, 3].astype(jnp.float32) + rec[3])
        logf = -jax.nn.softplus(-ft)
        m_new = jnp.maximum(logf + m, it)
        i_s = jnp.exp(it - m_new)
        f_s = jnp.exp(logf + m - m_new)
        c = f_s * c + i_s * zt
        n = f_s * n + i_s
        h = ot * c / jnp.maximum(jnp.abs(n), 1e-6)
        return (c, n, h, m_new), h

    (c, n, h_last, m), hs = jax.lax.scan(body, state, x_gates.transpose(1, 0, 2, 3, 4))
    return hs.transpose(1, 0, 2, 3).astype(x_gates.dtype), (c, n, h_last, m)
