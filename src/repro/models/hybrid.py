"""Mamba2 block and the Zamba2 hybrid model (arXiv:2411.15242).

Zamba2: a backbone of Mamba2 blocks with ONE shared transformer block
(attention + SwiGLU) whose parameters are re-applied every
``cfg.hybrid_attn_every`` Mamba layers (the paper's parameter-sharing
design; we omit the per-application LoRA deltas — noted in DESIGN.md). The
shared block uses sliding-window attention when ``cfg.sliding_window`` is
set, which keeps the whole model sub-quadratic for long_500k.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import shard
from .attention import decode_attention_step, init_attention, prefill_attention
from .layers import cross_entropy, init_swiglu, normal_init, rms_norm, swiglu, unembed
from .ssm import ssd_chunked, ssd_step


def _dims(cfg: ArchConfig):
    ssm = cfg.ssm
    d_inner = ssm.expand * cfg.d_model
    N = ssm.d_state
    P = ssm.d_state  # head dim = d_state (mamba2 default P=64=N)
    H = d_inner // P
    return d_inner, H, P, N


def init_mamba_block(cfg: ArchConfig, key) -> dict[str, Any]:
    d = cfg.d_model
    d_inner, H, P, N = _dims(cfg)
    conv_dim = d_inner + 2 * N
    ks = jax.random.split(key, 4)
    dt = cfg.jax_dtype
    return {
        "ln": jnp.ones((d,), dt),
        # in_proj -> [x (d_inner), z (d_inner), B (N), C (N), dt (H)]
        "w_in": normal_init(ks[0], (d, 2 * d_inner + 2 * N + H), d**-0.5, dt),
        "conv_w": normal_init(ks[1], (cfg.ssm.d_conv, conv_dim), 0.5, dt),
        "conv_b": jnp.zeros((conv_dim,), dt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "ynorm": jnp.ones((d_inner,), dt),
        "w_out": normal_init(ks[2], (d_inner, d), d_inner**-0.5, dt),
    }


def _mamba_proj(cfg, p, x):
    d_inner, H, P, N = _dims(cfg)
    xn = rms_norm(x, p["ln"], cfg.norm_eps)
    u = xn @ p["w_in"]
    xs, z, Bm, Cm, dt = jnp.split(
        u, [d_inner, 2 * d_inner, 2 * d_inner + N, 2 * d_inner + 2 * N], axis=-1
    )
    return xs, z, Bm, Cm, dt


def _causal_conv(seq: jax.Array, w: jax.Array, b: jax.Array, ctx: jax.Array | None = None):
    """Depthwise causal conv. seq: (B, S, C); w: (K, C). ctx: (B, K-1, C)
    previous inputs (decode) or None (prefill pads with zeros).
    Returns (out (B,S,C), new_ctx (B, K-1, C))."""
    K = w.shape[0]
    if ctx is None:
        ctx = jnp.zeros((seq.shape[0], K - 1, seq.shape[2]), seq.dtype)
    full = jnp.concatenate([ctx, seq], axis=1)
    out = sum(full[:, i : i + seq.shape[1]] * w[i][None, None, :] for i in range(K))
    out = out + b[None, None, :]
    new_ctx = full[:, -(K - 1) :, :]
    return jax.nn.silu(out), new_ctx


def mamba_block(cfg: ArchConfig, p, x, *, state=None):
    """x: (B,S,d). state: None or (h (B,H,N,P), conv_ctx). Returns (y, state)."""
    d_inner, H, P, N = _dims(cfg)
    B, S, _ = x.shape
    xs, z, Bm, Cm, dt = _mamba_proj(cfg, p, x)
    conv_in = jnp.concatenate([xs, Bm, Cm], axis=-1)
    h0, ctx = (None, None) if state is None else state
    conv_out, new_ctx = _causal_conv(conv_in, p["conv_w"], p["conv_b"], ctx)
    xs, Bm, Cm = jnp.split(conv_out, [d_inner, d_inner + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, h = ssd_chunked(
        xs.reshape(B, S, H, P), dt, A, Bm, Cm, p["D"], chunk=cfg.ssm.chunk, h0=h0
    )
    y = y.reshape(B, S, d_inner)
    y = rms_norm(y * jax.nn.silu(z), p["ynorm"], cfg.norm_eps)
    return x + y @ p["w_out"], (h, new_ctx)


def mamba_block_step(cfg: ArchConfig, p, x, state):
    """x: (B,1,d); state: (h, conv_ctx)."""
    d_inner, H, P, N = _dims(cfg)
    B = x.shape[0]
    xs, z, Bm, Cm, dt = _mamba_proj(cfg, p, x)
    conv_in = jnp.concatenate([xs, Bm, Cm], axis=-1)
    h, ctx = state
    conv_out, new_ctx = _causal_conv(conv_in, p["conv_w"], p["conv_b"], ctx)
    xs, Bm, Cm = jnp.split(conv_out, [d_inner, d_inner + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, h = ssd_step(
        xs[:, 0].reshape(B, H, P), dt[:, 0], A, Bm[:, 0], Cm[:, 0], p["D"], h
    )
    y = y.reshape(B, 1, d_inner)
    y = rms_norm(y * jax.nn.silu(z), p["ynorm"], cfg.norm_eps)
    return x + y @ p["w_out"], (h, new_ctx)


# ---------------------------------------------------------------------------
# Zamba2
# ---------------------------------------------------------------------------


def _n_attn(cfg: ArchConfig) -> int:
    return cfg.n_layers // cfg.hybrid_attn_every if cfg.hybrid_attn_every else 0


def init_params(cfg: ArchConfig, key) -> dict[str, Any]:
    k_emb, k_m, k_a, k_out = jax.random.split(key, 4)
    mkeys = jax.random.split(k_m, cfg.n_layers)
    params: dict[str, Any] = {
        "embed": normal_init(k_emb, (cfg.vocab, cfg.d_model), 1.0, cfg.jax_dtype),
        "mamba": jax.vmap(functools.partial(init_mamba_block, cfg))(mkeys),
        "final_norm": jnp.ones((cfg.d_model,), cfg.jax_dtype),
        "unembed": normal_init(
            k_out, (cfg.d_model, cfg.vocab), cfg.d_model**-0.5, cfg.jax_dtype
        ),
    }
    if cfg.hybrid_attn_every:
        ka1, ka2 = jax.random.split(k_a)
        params["shared_attn"] = {
            "ln1": jnp.ones((cfg.d_model,), cfg.jax_dtype),
            "attn": init_attention(
                ka1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
                cfg.qk_norm, cfg.jax_dtype,
            ),
            "ln2": jnp.ones((cfg.d_model,), cfg.jax_dtype),
            "mlp": init_swiglu(ka2, cfg.d_model, cfg.d_ff, cfg.jax_dtype),
        }
    return params


def _shared_attn_prefill(cfg, p, x, positions):
    h, (k, v) = prefill_attention(
        p["attn"], rms_norm(x, p["ln1"], cfg.norm_eps), positions,
        rope_theta=cfg.rope_theta, eps=cfg.norm_eps, causal=True,
        window=cfg.sliding_window,
    )
    x = x + h
    m = swiglu(rms_norm(x, p["ln2"], cfg.norm_eps), **p["mlp"])
    return x + m, (k, v)


def _group_sizes(cfg: ArchConfig) -> list[int]:
    """Mamba-run lengths between shared-attention applications."""
    if not cfg.hybrid_attn_every:
        return [cfg.n_layers]
    e = cfg.hybrid_attn_every
    sizes = [e] * (cfg.n_layers // e)
    if cfg.n_layers % e:
        sizes.append(cfg.n_layers % e)
    return sizes


def _split_stacked(params, sizes):
    """Split the stacked mamba params into per-group stacks."""
    out, start = [], 0
    for s in sizes:
        out.append(jax.tree.map(lambda t: t[start : start + s], params))
        start += s
    return out


def forward(cfg: ArchConfig, params, tokens, *, remat: bool = True):
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    x = shard(x, "batch", "seq", None)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    sizes = _group_sizes(cfg)
    groups = _split_stacked(params["mamba"], sizes)
    for gi, gp in enumerate(groups):

        def body(x, pl):
            y, _ = mamba_block(cfg, pl, x)
            return y, None

        if remat:
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, gp)
        if cfg.hybrid_attn_every and gi < _n_attn(cfg):
            x, _ = _shared_attn_prefill(cfg, params["shared_attn"], x, positions)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return unembed(x, params["unembed"]), 0.0


def loss_fn(cfg: ArchConfig, params, batch, *, remat: bool = True):
    logits, aux = forward(cfg, params, batch["tokens"], remat=remat)
    ce, nll = cross_entropy(logits, batch["labels"])
    return ce + aux, {"ce": ce, "nll": nll, "aux": aux}


def init_cache(cfg: ArchConfig, batch: int, max_len: int, **_):
    d_inner, H, P, N = _dims(cfg)
    conv_dim = d_inner + 2 * N
    K = cfg.ssm.d_conv
    n_attn = _n_attn(cfg)
    cache: dict[str, Any] = {
        "h": jnp.zeros((cfg.n_layers, batch, H, N, P), jnp.float32),
        "conv": jnp.zeros((cfg.n_layers, batch, K - 1, conv_dim), cfg.jax_dtype),
        "lengths": jnp.zeros((batch,), jnp.int32),
    }
    if n_attn:
        S = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
        cache["attn_k"] = jnp.zeros(
            (n_attn, batch, cfg.n_kv_heads, S, cfg.head_dim), cfg.jax_dtype
        )
        cache["attn_v"] = jnp.zeros_like(cache["attn_k"])
    return cache


def prefill(cfg: ArchConfig, params, tokens, cache):
    """Run the prompt, collecting SSM states, conv contexts, and shared-attn
    KV caches. Returns (last-token logits, cache)."""
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    x = shard(x, "batch", "seq", None)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    sizes = _group_sizes(cfg)
    groups = _split_stacked(params["mamba"], sizes)
    hs, convs = [], []
    attn_ks, attn_vs = [], []
    S_c = cache["attn_k"].shape[3] if "attn_k" in cache else 0
    for gi, gp in enumerate(groups):

        def body(x, pl):
            y, st = mamba_block(cfg, pl, x)
            return y, st

        x, (h_new, c_new) = jax.lax.scan(body, x, gp)
        hs.append(h_new)
        convs.append(c_new)
        if cfg.hybrid_attn_every and gi < _n_attn(cfg):
            x, (k, v) = _shared_attn_prefill(cfg, params["shared_attn"], x, positions)
            if cfg.sliding_window is not None and S > S_c:
                k, v = k[:, :, -S_c:], v[:, :, -S_c:]
                shift = (S - S_c) % S_c
                k = jnp.roll(k, shift=shift, axis=2)
                v = jnp.roll(v, shift=shift, axis=2)
            elif k.shape[2] < S_c:
                pad = S_c - k.shape[2]
                k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
                v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
            attn_ks.append(k)
            attn_vs.append(v)
    x = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = unembed(x, params["unembed"])
    new_cache = dict(cache)
    new_cache["h"] = jnp.concatenate(hs, axis=0)
    new_cache["conv"] = jnp.concatenate(convs, axis=0)
    new_cache["lengths"] = jnp.full((B,), S, jnp.int32)
    if attn_ks:
        new_cache["attn_k"] = jnp.stack(attn_ks, axis=0)
        new_cache["attn_v"] = jnp.stack(attn_vs, axis=0)
    return logits, new_cache


def decode_step(cfg: ArchConfig, params, cache, tokens):
    B = tokens.shape[0]
    x = jnp.take(params["embed"], tokens, axis=0)
    x = shard(x, "batch", "seq", None)
    lengths = cache["lengths"]
    sizes = _group_sizes(cfg)
    groups = _split_stacked(params["mamba"], sizes)
    hs, convs = [], []
    start = 0
    for gi, gp in enumerate(groups):
        s = sizes[gi]
        st = (cache["h"][start : start + s], cache["conv"][start : start + s])

        def body(x, inp):
            pl, h, c = inp
            y, (h2, c2) = mamba_block_step(cfg, pl, x, (h, c))
            return y, (h2, c2)

        x, (h_new, c_new) = jax.lax.scan(body, x, (gp, st[0], st[1]))
        hs.append(h_new)
        convs.append(c_new)
        start += s
        if cfg.hybrid_attn_every and gi < _n_attn(cfg):
            p = params["shared_attn"]
            h_att, kc, vc = decode_attention_step(
                p["attn"], rms_norm(x, p["ln1"], cfg.norm_eps),
                cache["attn_k"][gi], cache["attn_v"][gi], lengths,
                rope_theta=cfg.rope_theta, eps=cfg.norm_eps,
                window=cfg.sliding_window,
            )
            x = x + h_att
            m = swiglu(rms_norm(x, p["ln2"], cfg.norm_eps), **p["mlp"])
            x = x + m
            cache["attn_k"] = cache["attn_k"].at[gi].set(kc)
            cache["attn_v"] = cache["attn_v"].at[gi].set(vc)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(x, params["unembed"])
    new_cache = dict(cache)
    new_cache["h"] = jnp.concatenate(hs, axis=0)
    new_cache["conv"] = jnp.concatenate(convs, axis=0)
    new_cache["lengths"] = lengths + 1
    return logits, new_cache
