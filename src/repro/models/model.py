"""Unified model API: every assigned architecture exposes the same surface.

    model = build_model(cfg)
    params = model.init(key)
    loss, metrics = model.loss(params, batch)          # training
    cache = model.init_cache(batch_size, max_len)
    logits, cache = model.prefill(params, batch, cache)  # inference prefill
    logits, cache = model.decode_step(params, cache, tokens)  # serve_step

Serving additionally uses the **jitted** surface:

    logits, cache = model.prefill_jit(params, batch, cache)
    tokens, cache = model.decode_tokens(params, cache, tok, n_steps)

``decode_tokens`` rolls the whole greedy decode loop into ONE compiled
program (``jax.lax.scan`` over ``decode_step``) instead of ``n_steps``
un-jitted Python dispatches — the difference between seconds and
milliseconds per request on the serving path (ROADMAP: "JIT the serving
decode path"). ``n_steps`` is static: each distinct step count compiles
once and is cached by jax; callers that want few compilations bucket it
(see ``serving/backend.py``). Because step ``t`` depends only on steps
``< t``, running extra (bucket-padding) steps never changes the first
``n`` tokens — callers slice the prefix they asked for.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from . import encdec, hybrid, transformer, xlstm


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    init: Callable[[jax.Array], Any]
    loss: Callable[..., tuple[jax.Array, dict]]
    forward: Callable[..., tuple[jax.Array, jax.Array]]
    init_cache: Callable[..., Any]
    prefill: Callable[..., tuple[Optional[jax.Array], Any]]
    decode_step: Callable[..., tuple[jax.Array, Any]]
    # jitted serving surface (same semantics, compiled)
    prefill_jit: Callable[..., tuple[Optional[jax.Array], Any]]
    decode_tokens: Callable[..., tuple[jax.Array, Any]]


def build_model(cfg: ArchConfig) -> Model:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        mod = transformer
    elif fam == "xlstm":
        mod = xlstm
    elif fam == "hybrid":
        mod = hybrid
    elif fam == "encdec":
        mod = encdec
    else:
        raise ValueError(f"unknown family {fam!r}")

    def init(key):
        return mod.init_params(cfg, key)

    def loss(params, batch, *, remat: bool = True):
        return mod.loss_fn(cfg, params, batch, remat=remat)

    def forward(params, batch, *, remat: bool = False):
        if fam == "encdec":
            return mod.forward(cfg, params, batch, remat=remat)
        return mod.forward(cfg, params, batch["tokens"], remat=remat)

    def init_cache(batch_size: int, max_len: int):
        return mod.init_cache(cfg, batch_size, max_len)

    def prefill(params, batch, cache):
        if fam == "encdec":
            return mod.prefill(cfg, params, batch, cache)
        return mod.prefill(cfg, params, batch["tokens"], cache)

    def decode_step(params, cache, tokens):
        return mod.decode_step(cfg, params, cache, tokens)

    @functools.partial(jax.jit, static_argnames=("n_steps",))
    def decode_tokens(params, cache, tokens, n_steps: int):
        """Greedy-decode ``n_steps`` tokens from ``tokens`` (B, 1) in one
        compiled program. Returns ((B, n_steps) int32 tokens, final cache)."""

        def step(carry, _):
            tok, cache = carry
            logits, cache = mod.decode_step(cfg, params, cache, tok)
            tok = greedy_token(logits)
            return (tok, cache), tok

        (_, cache), toks = jax.lax.scan(
            step, (tokens, cache), None, length=n_steps
        )
        return jnp.swapaxes(toks[:, :, 0], 0, 1), cache  # (T,B,1) -> (B,T)

    return Model(
        cfg=cfg, init=init, loss=loss, forward=forward,
        init_cache=init_cache, prefill=prefill, decode_step=decode_step,
        prefill_jit=jax.jit(prefill), decode_tokens=decode_tokens,
    )


def greedy_token(logits: jax.Array) -> jax.Array:
    """(B, 1, V) -> (B, 1) argmax token."""
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)
