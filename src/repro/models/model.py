"""Unified model API: every assigned architecture exposes the same surface.

    model = build_model(cfg)
    params = model.init(key)
    loss, metrics = model.loss(params, batch)          # training
    cache = model.init_cache(batch_size, max_len)
    logits, cache = model.prefill(params, batch, cache)  # inference prefill
    logits, cache = model.decode_step(params, cache, tokens)  # serve_step
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from . import encdec, hybrid, transformer, xlstm


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    init: Callable[[jax.Array], Any]
    loss: Callable[..., tuple[jax.Array, dict]]
    forward: Callable[..., tuple[jax.Array, jax.Array]]
    init_cache: Callable[..., Any]
    prefill: Callable[..., tuple[Optional[jax.Array], Any]]
    decode_step: Callable[..., tuple[jax.Array, Any]]


def build_model(cfg: ArchConfig) -> Model:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        mod = transformer
    elif fam == "xlstm":
        mod = xlstm
    elif fam == "hybrid":
        mod = hybrid
    elif fam == "encdec":
        mod = encdec
    else:
        raise ValueError(f"unknown family {fam!r}")

    def init(key):
        return mod.init_params(cfg, key)

    def loss(params, batch, *, remat: bool = True):
        return mod.loss_fn(cfg, params, batch, remat=remat)

    def forward(params, batch, *, remat: bool = False):
        if fam == "encdec":
            return mod.forward(cfg, params, batch, remat=remat)
        return mod.forward(cfg, params, batch["tokens"], remat=remat)

    def init_cache(batch_size: int, max_len: int):
        return mod.init_cache(cfg, batch_size, max_len)

    def prefill(params, batch, cache):
        if fam == "encdec":
            return mod.prefill(cfg, params, batch, cache)
        return mod.prefill(cfg, params, batch["tokens"], cache)

    def decode_step(params, cache, tokens):
        return mod.decode_step(cfg, params, cache, tokens)

    return Model(
        cfg=cfg, init=init, loss=loss, forward=forward,
        init_cache=init_cache, prefill=prefill, decode_step=decode_step,
    )


def greedy_token(logits: jax.Array) -> jax.Array:
    """(B, 1, V) -> (B, 1) argmax token."""
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)
