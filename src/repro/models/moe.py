"""Mixture-of-Experts FFN: token-choice top-k routing with capacity-based
dispatch (GShard/Switch style), optional always-on shared experts
(DeepSeekMoE's fine-grained + shared design, arXiv:2401.06066), router
z-loss and load-balance auxiliary loss.

Expert parallelism: the expert dim of all expert weights and of the
dispatch/combine einsums is sharded on the logical "expert" axis (mesh
"model"). Under pjit the dispatch einsum lowers to an all-to-all across the
model axis — the collective this family is bound by (see EXPERIMENTS.md
§Roofline for deepseek-moe).

Capacity: each expert processes at most C = ceil(S·top_k/E · cf) tokens per
sequence-row group; overflow tokens fall through (residual passes them
unchanged) — standard token-dropping semantics.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import shard
from .layers import normal_init


def init_moe(key, cfg: ArchConfig):
    m = cfg.moe
    k_r, k_e, k_s = jax.random.split(key, 3)
    d, de = cfg.d_model, m.d_expert
    s_in, s_out = d**-0.5, de**-0.5
    p = {
        "router": normal_init(k_r, (d, m.n_experts), s_in, jnp.float32),
        "w_gate": normal_init(k_e, (m.n_experts, d, de), s_in, cfg.jax_dtype),
        "w_up": normal_init(
            jax.random.fold_in(k_e, 1), (m.n_experts, d, de), s_in, cfg.jax_dtype
        ),
        "w_down": normal_init(
            jax.random.fold_in(k_e, 2), (m.n_experts, de, d), s_out, cfg.jax_dtype
        ),
    }
    if m.n_shared:
        p["shared"] = {
            "w_gate": normal_init(k_s, (d, m.n_shared * de), s_in, cfg.jax_dtype),
            "w_up": normal_init(
                jax.random.fold_in(k_s, 1), (d, m.n_shared * de), s_in, cfg.jax_dtype
            ),
            "w_down": normal_init(
                jax.random.fold_in(k_s, 2), (m.n_shared * de, d), s_out, cfg.jax_dtype
            ),
        }
    return p


def _capacity(tokens: int, top_k: int, n_experts: int, cf: float) -> int:
    return max(4, int(tokens * top_k * cf / n_experts))


def apply_moe(cfg: ArchConfig, p, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (y, aux_loss)."""
    m = cfg.moe
    B, S, d = x.shape
    E, K = m.n_experts, m.top_k
    C = _capacity(S, K, E, m.capacity_factor)

    logits = (x.astype(jnp.float32) @ p["router"])  # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)

    # --- aux losses (computed on the full distribution) ---
    # load balance (Switch): E * sum_e f_e * p_e
    top1 = jnp.argmax(probs, axis=-1)
    f = jnp.mean(jax.nn.one_hot(top1, E, dtype=jnp.float32), axis=(0, 1))
    pbar = jnp.mean(probs, axis=(0, 1))
    lb = E * jnp.sum(f * pbar)
    z = jnp.mean(jax.scipy.special.logsumexp(logits, axis=-1) ** 2)
    aux = m.load_balance_weight * lb + m.router_z_weight * z

    # --- top-k dispatch with capacity ---
    gate_vals, gate_idx = jax.lax.top_k(probs, K)           # (B, S, K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
    )
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)  # (B, S, K, E)
    # position of each (token, k) within its expert queue
    pos_in_e = jnp.cumsum(onehot.reshape(B, S * K, E), axis=1).reshape(B, S, K, E)
    pos_in_e = (pos_in_e - 1.0) * onehot                     # 0-based, only where routed
    keep = (pos_in_e < C) & (onehot > 0)
    pos = jnp.sum(pos_in_e * onehot, axis=-1).astype(jnp.int32)  # (B, S, K)
    cap_oh = jax.nn.one_hot(pos, C, dtype=jnp.float32) * keep.max(-1, keepdims=False)[
        ..., None
    ].astype(jnp.float32)  # (B, S, K, C)

    # dispatch mask (B, S, E, C)
    dispatch = jnp.einsum("bske,bskc->bsec", onehot * keep.astype(jnp.float32), cap_oh)
    combine = jnp.einsum("bsk,bske,bskc->bsec", gate_vals, onehot * keep.astype(jnp.float32), cap_oh)
    dispatch = shard(dispatch, "batch", None, "expert", None)
    combine = shard(combine, "batch", None, "expert", None)

    xe = jnp.einsum("bsec,bsd->becd", dispatch.astype(x.dtype), x)  # (B, E, C, d)
    xe = shard(xe, "batch", "expert", None, None)
    h = jnp.einsum("becd,edf->becf", xe, p["w_gate"])
    u = jnp.einsum("becd,edf->becf", xe, p["w_up"])
    h = jax.nn.silu(h) * u
    ye = jnp.einsum("becf,efd->becd", h, p["w_down"])               # (B, E, C, d)
    ye = shard(ye, "batch", "expert", None, None)
    y = jnp.einsum("bsec,becd->bsd", combine.astype(x.dtype), ye)

    if m.n_shared:
        sp = p["shared"]
        hs = jax.nn.silu(x @ sp["w_gate"]) * (x @ sp["w_up"])
        y = y + hs @ sp["w_down"]
    return shard(y, "batch", None, None), aux
