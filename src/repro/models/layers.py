"""Shared neural building blocks (pure JAX, no framework deps)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard


def normal_init(key, shape, scale: float, dtype=jnp.float32):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * weight.astype(jnp.float32)).astype(dt)


def layer_norm(x: jax.Array, weight: jax.Array, bias: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (..., seq, half)
    cos = jnp.cos(angles)[..., None, :]  # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array) -> jax.Array:
    """SwiGLU MLP: down( silu(x@gate) * (x@up) ). Hidden dim sharded on "ff"."""
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    h = shard(h, "batch", None, "ff")
    return h @ w_down


def init_swiglu(key, d_model: int, d_ff: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = d_model**-0.5
    s_out = d_ff**-0.5
    return {
        "w_gate": normal_init(k1, (d_model, d_ff), s_in, dtype),
        "w_up": normal_init(k2, (d_model, d_ff), s_in, dtype),
        "w_down": normal_init(k3, (d_ff, d_model), s_out, dtype),
    }


def unembed(x: jax.Array, w: jax.Array) -> jax.Array:
    """Logits projection; vocab dim sharded."""
    logits = x @ w
    return shard(logits, "batch", None, "vocab")


def cross_entropy(logits: jax.Array, labels: jax.Array, z_weight: float = 1e-4):
    """Token-mean cross entropy with z-loss; logits (B, S, V), labels (B, S)."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    z = z_weight * (lse**2)
    return jnp.mean(nll + z), jnp.mean(nll)
