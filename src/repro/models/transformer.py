"""Dense (and MoE — the FFN is pluggable) decoder-only transformer with
scanned layer stacks, KV-cache prefill/decode, and sliding-window support.

Used directly by: llama3.2-1b, phi3-mini, qwen3, mistral-large-123b,
chameleon-34b (early-fusion VLM: image tokens are ordinary vocab ids), and
with MoE FFNs by deepseek-moe-16b / granite-moe-1b.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import shard
from .attention import decode_attention_step, init_attention, prefill_attention
from .layers import cross_entropy, init_swiglu, normal_init, rms_norm, swiglu, unembed
from . import moe as moe_lib


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _init_layer(cfg: ArchConfig, key) -> dict[str, Any]:
    k_attn, k_mlp = jax.random.split(key)
    p = {
        "ln1": jnp.ones((cfg.d_model,), cfg.jax_dtype),
        "attn": init_attention(
            k_attn, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
            cfg.qk_norm, cfg.jax_dtype,
        ),
        "ln2": jnp.ones((cfg.d_model,), cfg.jax_dtype),
    }
    if cfg.moe is not None:
        p["mlp"] = moe_lib.init_moe(k_mlp, cfg)
    else:
        p["mlp"] = init_swiglu(k_mlp, cfg.d_model, cfg.d_ff, cfg.jax_dtype)
    return p


def init_params(cfg: ArchConfig, key) -> dict[str, Any]:
    k_emb, k_layers, k_out = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    layers = jax.vmap(functools.partial(_init_layer, cfg))(layer_keys)
    params = {
        "embed": normal_init(k_emb, (cfg.vocab, cfg.d_model), 1.0, cfg.jax_dtype),
        "layers": layers,
        "final_norm": jnp.ones((cfg.d_model,), cfg.jax_dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = normal_init(
            k_out, (cfg.d_model, cfg.vocab), cfg.d_model**-0.5, cfg.jax_dtype
        )
    return params


# ---------------------------------------------------------------------------
# Layer application
# ---------------------------------------------------------------------------


def _mlp_apply(cfg: ArchConfig, p_mlp, x):
    """Returns (y, aux_loss)."""
    if cfg.moe is not None:
        return moe_lib.apply_moe(cfg, p_mlp, x)
    return swiglu(x, p_mlp["w_gate"], p_mlp["w_up"], p_mlp["w_down"]), 0.0


def _layer_prefill(cfg: ArchConfig, p, x, positions, window):
    h, (k, v) = prefill_attention(
        p["attn"], rms_norm(x, p["ln1"], cfg.norm_eps), positions,
        rope_theta=cfg.rope_theta, eps=cfg.norm_eps, causal=True, window=window,
    )
    x = x + h
    m, aux = _mlp_apply(cfg, p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps))
    return x + m, (k, v), aux


def _layer_decode(cfg: ArchConfig, p, x, k_cache, v_cache, lengths, window):
    h, k_cache, v_cache = decode_attention_step(
        p["attn"], rms_norm(x, p["ln1"], cfg.norm_eps), k_cache, v_cache, lengths,
        rope_theta=cfg.rope_theta, eps=cfg.norm_eps, window=window,
    )
    x = x + h
    m, _ = _mlp_apply(cfg, p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps))
    return x + m, k_cache, v_cache


# ---------------------------------------------------------------------------
# Public model functions
# ---------------------------------------------------------------------------


def forward(
    cfg: ArchConfig,
    params,
    tokens: jax.Array,  # (B, S) int32
    *,
    remat: bool = True,
    window: Optional[int] = None,
) -> tuple[jax.Array, jax.Array]:
    """Training/prefill forward pass. Returns (logits (B,S,V), aux_loss)."""
    B, S = tokens.shape
    window = window if window is not None else cfg.sliding_window
    x = jnp.take(params["embed"], tokens, axis=0)
    x = shard(x, "batch", "seq", None)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def body(x, p):
        y, _, aux = _layer_prefill(cfg, p, x, positions, window)
        return y, aux

    if remat:
        body = jax.checkpoint(body)
    x, auxs = jax.lax.scan(body, x, params["layers"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(x, params["unembed"] if "unembed" in params else params["embed"].T)
    return logits, jnp.sum(auxs)


def loss_fn(cfg: ArchConfig, params, batch, *, remat: bool = True):
    logits, aux = forward(cfg, params, batch["tokens"], remat=remat)
    ce, nll = cross_entropy(logits, batch["labels"])
    return ce + aux, {"ce": ce, "nll": nll, "aux": aux}


def init_cache(cfg: ArchConfig, batch: int, max_len: int, *, window: Optional[int] = None):
    """KV cache pytree. With a window, the cache is a ring of size window."""
    window = window if window is not None else cfg.sliding_window
    S = min(max_len, window) if window is not None else max_len
    shape = (cfg.n_layers, batch, cfg.n_kv_heads, S, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, cfg.jax_dtype),
        "v": jnp.zeros(shape, cfg.jax_dtype),
        "lengths": jnp.zeros((batch,), jnp.int32),
    }


def prefill(cfg: ArchConfig, params, tokens: jax.Array, cache):
    """Run the prompt through the stack, filling the cache. Returns
    (last-token logits, cache)."""
    B, S = tokens.shape
    window = cfg.sliding_window
    x = jnp.take(params["embed"], tokens, axis=0)
    x = shard(x, "batch", "seq", None)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def body(x, p):
        y, (k, v), _ = _layer_prefill(cfg, p, x, positions, window)
        return y, (k, v)

    x, (ks, vs) = jax.lax.scan(body, x, params["layers"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(
        x[:, -1:, :], params["unembed"] if "unembed" in params else params["embed"].T
    )
    S_c = cache["k"].shape[3]
    if window is not None and S > S_c:
        # keep the last `window` positions; ring alignment: slot = pos % window
        ks, vs = ks[:, :, :, -S_c:], vs[:, :, :, -S_c:]
        shift = (S - S_c) % S_c
        ks = jnp.roll(ks, shift=shift, axis=3)
        vs = jnp.roll(vs, shift=shift, axis=3)
    cache = {
        "k": cache["k"].at[:, :, :, : ks.shape[3]].set(ks) if ks.shape[3] < S_c else ks,
        "v": cache["v"].at[:, :, :, : vs.shape[3]].set(vs) if vs.shape[3] < S_c else vs,
        "lengths": jnp.full((B,), S, jnp.int32),
    }
    return logits, cache


def decode_step(cfg: ArchConfig, params, cache, tokens: jax.Array):
    """One greedy decode step. tokens: (B, 1) int32 — the current token.
    Returns (logits (B,1,V), new cache)."""
    B = tokens.shape[0]
    window = cfg.sliding_window
    x = jnp.take(params["embed"], tokens, axis=0)
    x = shard(x, "batch", "seq", None)
    lengths = cache["lengths"]

    def body(x, layer):
        p, kc, vc = layer
        y, kc, vc = _layer_decode(cfg, p, x, kc, vc, lengths, window)
        return y, (kc, vc)

    x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(x, params["unembed"] if "unembed" in params else params["embed"].T)
    new_cache = {"k": ks, "v": vs, "lengths": lengths + 1}
    return logits, new_cache
