"""Logical-axis sharding: models annotate tensors with *logical* axis names;
the launcher binds logical names to physical mesh axes. Outside a mesh
context every annotation is a no-op, so the same model code runs in CPU
smoke tests and in the 512-device dry-run.

Logical axes used by the model zoo:
  "batch"    — data-parallel batch dim            -> ("pod", "data")
  "seq"      — sequence/context dim               -> None (baseline), "data"
               for the long-context flash-decode hillclimb
  "model"    — hidden size / head / expert shards -> "model"
  "vocab"    — embedding vocab shard              -> "model"
  "expert"   — MoE expert dim                     -> "model"
  "ff"       — MLP hidden dim                     -> "model"
  "heads"    — attention head dim                 -> "model"
  "kv_heads" — KV head dim (GQA)                  -> "model" when divisible
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()

DEFAULT_RULES: dict[str, Optional[str | tuple[str, ...]]] = {
    "batch": ("pod", "data"),
    "seq": None,
    "model": "model",
    "vocab": "model",
    "expert": "model",
    "ff": "model",
    "heads": "model",
    "kv_heads": "model",
    "state": None,
}


def _current() -> tuple[Optional[Mesh], dict]:
    mesh = getattr(_state, "mesh", None)
    rules = getattr(_state, "rules", DEFAULT_RULES)
    return mesh, rules


@contextlib.contextmanager
def use_mesh(mesh: Mesh, rules: dict | None = None):
    """Bind a mesh + logical->physical rules for the enclosed region. Rules
    entries may name mesh axes that don't exist on this mesh — they are
    dropped (so the same rules work for single- and multi-pod meshes)."""
    prev = (getattr(_state, "mesh", None), getattr(_state, "rules", DEFAULT_RULES))
    eff_rules = dict(DEFAULT_RULES)
    if rules:
        eff_rules.update(rules)
    # prune axes not present on the mesh
    pruned: dict[str, Optional[str | tuple[str, ...]]] = {}
    for k, v in eff_rules.items():
        if v is None:
            pruned[k] = None
        elif isinstance(v, tuple):
            kept = tuple(a for a in v if a in mesh.axis_names)
            pruned[k] = kept if kept else None
        else:
            pruned[k] = v if v in mesh.axis_names else None
    _state.mesh, _state.rules = mesh, pruned
    try:
        with mesh:
            yield
    finally:
        _state.mesh, _state.rules = prev


def logical_to_spec(*logical_axes: Optional[str]) -> P:
    """Translate logical axis names (one per tensor dim; None = replicated)
    into a PartitionSpec under the current rules."""
    _, rules = _current()
    return P(*[rules.get(a) if a is not None else None for a in logical_axes])


def shard(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """Annotate ``x`` with a sharding constraint; no-op without a mesh.
    Axes whose dim size does not divide the mapped mesh axes are dropped
    (so e.g. a "seq" constraint is harmless on a 1-token decode step)."""
    mesh, _ = _current()
    if mesh is None:
        return x
    spec = logical_to_spec(*logical_axes)
    entries = []
    for dim, entry in zip(x.shape, tuple(spec) + (None,) * (x.ndim - len(spec))):
        if entry is None:
            entries.append(None)
            continue
        axes = (entry,) if isinstance(entry, str) else tuple(entry)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        entries.append(entry if dim % size == 0 and dim >= size else None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*entries)))


def named_sharding(*logical_axes: Optional[str]) -> Optional[NamedSharding]:
    mesh, _ = _current()
    if mesh is None:
        return None
    return NamedSharding(mesh, logical_to_spec(*logical_axes))


def current_mesh() -> Optional[Mesh]:
    return _current()[0]


def axis_size(logical: str) -> int:
    """Product of mesh-axis sizes the logical axis maps to (1 if unmapped)."""
    mesh, rules = _current()
    if mesh is None:
        return 1
    phys = rules.get(logical)
    if phys is None:
        return 1
    if isinstance(phys, str):
        phys = (phys,)
    size = 1
    for a in phys:
        size *= mesh.shape[a]
    return size
