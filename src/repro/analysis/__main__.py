"""CLI: ``python -m repro.analysis [paths...] [--ci] [--baseline F] ...``

Exit status: 0 when every finding is grandfathered in the baseline (or
there are none), 1 when new findings exist, 2 on usage errors. ``--ci``
is the mode the workflow runs — identical checks, but also warns about
stale baseline entries so the grandfather list shrinks as fixes land.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from .lint import (
    Baseline,
    DEFAULT_TARGETS,
    RULES,
    analyze_paths,
    default_baseline_path,
)


def _repo_default_targets() -> list[str]:
    """src/ and benchmarks/ relative to the repo root (the directory
    holding this package's ``src`` parent), falling back to cwd."""
    here = os.path.dirname(os.path.abspath(__file__))
    # .../<root>/src/repro/analysis -> <root>
    root = os.path.dirname(os.path.dirname(os.path.dirname(here)))
    targets = []
    for t in DEFAULT_TARGETS:
        cand = os.path.join(root, t)
        if os.path.isdir(cand):
            targets.append(cand)
    return targets or [os.getcwd()]


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Project-native static checks (DESIGN.md §13). Rules: "
                    + "; ".join(f"{k} {v}" for k, v in RULES.items()))
    parser.add_argument(
        "paths", nargs="*",
        help="files/directories to check (default: repo src/ benchmarks/)")
    parser.add_argument(
        "--ci", action="store_true",
        help="CI mode: fail on non-baseline findings, warn on stale entries")
    parser.add_argument(
        "--baseline", default=None, metavar="FILE",
        help="baseline file (default: the committed analysis/baseline.json)")
    parser.add_argument(
        "--no-baseline", action="store_true",
        help="ignore the baseline: report and fail on every finding")
    parser.add_argument(
        "--write-baseline", action="store_true",
        help="write all current findings to the baseline file "
             "(justifications must then be filled in by hand) and exit 0")
    parser.add_argument(
        "--rules", default=None, metavar="R1,R2,...",
        help="comma-separated subset of rules to run")
    parser.add_argument(
        "--json", action="store_true", dest="as_json",
        help="emit findings as a JSON array instead of text")
    args = parser.parse_args(argv)

    paths = args.paths or _repo_default_targets()
    for p in paths:
        if not os.path.exists(p):
            print(f"error: no such path: {p}", file=sys.stderr)
            return 2
    rules = [r.strip() for r in args.rules.split(",")] if args.rules else None

    try:
        findings = analyze_paths(paths, rules=rules)
    except ValueError as e:  # unknown rule id
        print(f"error: {e}", file=sys.stderr)
        return 2

    baseline_path = args.baseline or default_baseline_path()
    baseline = Baseline({}, path=baseline_path) if args.no_baseline \
        else Baseline.load(baseline_path)

    if args.write_baseline:
        merged = dict(baseline.entries)
        for f in findings:
            merged.setdefault(f.fingerprint, f"TODO justify: {f.message}")
        merged = {fp: j for fp, j in merged.items()
                  if fp in {f.fingerprint for f in findings}}
        Baseline(merged, path=baseline_path).save(baseline_path)
        print(f"wrote {len(merged)} finding(s) to {baseline_path}")
        return 0

    new, grandfathered, stale = baseline.split(findings)

    if args.as_json:
        print(json.dumps([{
            "rule": f.rule, "path": f.path, "line": f.line,
            "symbol": f.symbol, "detail": f.detail, "message": f.message,
            "fingerprint": f.fingerprint,
            "grandfathered": f.fingerprint in baseline.entries,
        } for f in findings], indent=2))
    else:
        for f in new:
            print(f.render())
        if grandfathered:
            print(f"# {len(grandfathered)} grandfathered finding(s) "
                  f"suppressed by {os.path.basename(baseline_path)}")
        if args.ci and stale:
            for fp in stale:
                print(f"# stale baseline entry (fix landed? remove it): {fp}")

    n_files = len({f.path for f in findings}) if findings else 0
    if new:
        print(f"repro.analysis: {len(new)} new finding(s) in "
              f"{n_files} file(s) — fix or justify in the baseline",
              file=sys.stderr)
        return 1
    print(f"repro.analysis: clean "
          f"({len(grandfathered)} grandfathered, {len(stale)} stale)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
