"""Project-native static analysis engine (DESIGN.md §13).

The invariants this repo rests on — scan bodies draw no host RNG or
wall-clock, controllers are pure decisions over a read-only ``Telemetry``,
jitted serving paths never silently recompile, scan carriers are
registered pytrees — were stated in DESIGN.md and re-proved by hand in
every PR (golden digests, CI stdout diffs). This module enforces them
statically: an AST pass over ``src/`` and ``benchmarks/`` builds, per
module, an import-alias table, a local call graph, the set of functions
*traced by JAX* (passed to ``lax.scan`` / ``jit`` / ``vmap`` / ``cond`` /
``while_loop``, or decorated as such — plus everything reachable from
them through local calls), and a per-function traced-parameter taint, and
then runs the project rules over that model:

* **R1 scan-purity** — no host RNG (``np.random.*``, ``random.*``), no
  wall clock (``time.time`` & co, ``datetime.now``), no file/network I/O
  reachable from a traced function. These execute at *trace* time, bake
  one draw into the compiled program, and silently break determinism and
  parity — the exact failure class Night Shift documents for serverless
  measurement (PAPERS.md).
* **R2 tracer-leak** — no ``float()`` / ``int()`` / ``bool()`` /
  ``.item()`` / ``np.asarray`` on traced values, and no ``if``/``while``
  branching on a traced value, inside a traced body (these either raise
  ``TracerConversionError`` at runtime or force a host sync).
* **R3 controller-purity** — ``Controller`` classes must not assign to
  ``Telemetry`` attributes, call pool mutators, or hold mutable
  module-level state (controllers decide; engines act — DESIGN.md §10).
* **R4 recompile-hazard** — no unhashable container literals at jitted
  call sites, no ``jax.jit(f)(x)`` immediate invocation, no ``jax.jit``
  inside a loop (each retraces/recompiles per call).
* **R5 estimator-pytree** — ``lax.scan`` carriers must be NamedTuples /
  registered pytrees with array leaves, not raw ``list``/``dict``/``set``
  literals (an unregistered or shape-unstable carry retraces per step).
* **R6 fault-injector-purity** — ``*FaultPlan``/``*FaultProcess``
  classes (the seeded fault-injection schedules, DESIGN.md §15) must
  draw randomness only from their own injected seeded generator: no
  host RNG beyond constructing ``RandomState(seed)``/``default_rng(seed)``
  *with* a seed, no wall clock, no IO, no environment reads.

Grandfathering: ``baseline.json`` (next to this file) pins the accepted
findings by line-independent fingerprint with a one-line justification
each; ``--ci`` fails only on findings NOT in the baseline, so the floor
can only ratchet down. Pure stdlib (``ast``) — importable everywhere the
repo is.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import os
from typing import Iterable, Optional

#: rule id -> one-line description (the invariant catalog's static rows)
RULES = {
    "R1": "scan-purity: no host RNG / wall-clock / IO reachable from traced code",
    "R2": "tracer-leak: no host conversion or branch on a traced value",
    "R3": "controller-purity: controllers decide, engines act",
    "R4": "recompile-hazard: jitted call sites must hit the compile cache",
    "R5": "estimator-pytree: scan carriers are registered pytrees of arrays",
    "R6": "fault-injector-purity: fault schedules draw only injected seeded RNG",
}

DEFAULT_TARGETS = ("src", "benchmarks")


# ---------------------------------------------------------------------------
# Findings + baseline
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation.

    ``fingerprint`` deliberately excludes the line number, so a baseline
    entry survives unrelated edits to the same file; ``symbol`` (the
    enclosing function/class qualname) plus the machine-stable ``detail``
    keeps it specific enough not to mask new violations of the same rule
    elsewhere in the function — unless they have the identical detail,
    which is the granularity we accept for grandfathering."""

    rule: str
    path: str      # repo-relative posix path
    line: int
    symbol: str    # enclosing qualname ("" = module level)
    detail: str    # machine-stable short form, e.g. "numpy.random.normal"
    message: str   # human explanation

    @property
    def fingerprint(self) -> str:
        return f"{self.rule}:{self.path}:{self.symbol}:{self.detail}"

    def render(self) -> str:
        sym = f" [{self.symbol}]" if self.symbol else ""
        return f"{self.path}:{self.line}: {self.rule}{sym}: {self.message}"


@dataclasses.dataclass
class Baseline:
    """Grandfathered findings: fingerprint -> justification."""

    entries: dict[str, str]
    path: Optional[str] = None

    @staticmethod
    def load(path: str) -> "Baseline":
        try:
            with open(path) as fh:
                data = json.load(fh)
        except OSError:
            return Baseline({}, path=path)
        entries = {
            e["fingerprint"]: e.get("justification", "")
            for e in data.get("findings", [])
        }
        return Baseline(entries, path=path)

    def save(self, path: str) -> None:
        data = {
            "schema": 1,
            "comment": (
                "Grandfathered repro.analysis findings. Every entry needs a "
                "one-line justification; new code must not add entries "
                "(python -m repro.analysis --ci fails on non-baseline "
                "findings)."),
            "findings": [
                {"fingerprint": fp, "justification": j}
                for fp, j in sorted(self.entries.items())
            ],
        }
        with open(path, "w") as fh:
            json.dump(data, fh, indent=2)
            fh.write("\n")

    def split(self, findings: list[Finding]):
        """(new, grandfathered, stale-fingerprints)."""
        seen = {f.fingerprint for f in findings}
        new = [f for f in findings if f.fingerprint not in self.entries]
        old = [f for f in findings if f.fingerprint in self.entries]
        stale = sorted(fp for fp in self.entries if fp not in seen)
        return new, old, stale


def default_baseline_path() -> str:
    return os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "baseline.json")


# ---------------------------------------------------------------------------
# Module model
# ---------------------------------------------------------------------------

#: names under which JAX's tracing entry points appear once import aliases
#: are canonicalized (``import jax.numpy as jnp`` -> ``jax.numpy``).
_TRACE_WRAPPERS = {
    "jax.jit", "jax.pmap", "jax.vmap", "jax.checkpoint", "jax.remat",
    "jax.grad", "jax.value_and_grad", "jax.lax.map",
}
#: attribute reads that yield concrete (non-traced) values at trace time:
#: shapes/dtypes of tracers are Python objects, so branching on them is fine.
_TAINT_BREAKER_ATTRS = {"shape", "ndim", "dtype", "size"}
#: calls whose result is concrete even on traced arguments
_TAINT_BREAKER_CALLS = {"len", "isinstance", "type", "id"}

#: (canonical callable, positional indices of traced function args)
_TRACE_HOF = {
    "jax.lax.scan": (0,),
    "jax.lax.cond": (1, 2),
    "jax.lax.while_loop": (0, 1),
    "jax.lax.fori_loop": (2,),
    "jax.lax.associative_scan": (0,),
}


def dotted_name(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for a Name/Attribute chain, else None."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _static_call_names(call: ast.Call) -> set:
    """``static_argnames`` string constants of a jit-like call."""
    out: set = set()
    for kw in call.keywords:
        if kw.arg != "static_argnames":
            continue
        vals = kw.value.elts if isinstance(
            kw.value, (ast.Tuple, ast.List)) else [kw.value]
        for v in vals:
            if isinstance(v, ast.Constant) and isinstance(v.value, str):
                out.add(v.value)
    return out


def _static_params(call: ast.Call, fi: "FunctionInfo") -> set:
    """Params of ``fi`` declared static by a jit decorator call — via
    ``static_argnames`` strings or ``static_argnums`` indices."""
    out = _static_call_names(call)
    pos = [p for p in fi.params if p != "self"]
    for kw in call.keywords:
        if kw.arg != "static_argnums":
            continue
        vals = kw.value.elts if isinstance(
            kw.value, (ast.Tuple, ast.List)) else [kw.value]
        for v in vals:
            if isinstance(v, ast.Constant) and isinstance(v.value, int) \
                    and 0 <= v.value < len(pos):
                out.add(pos[v.value])
    return out


@dataclasses.dataclass
class FunctionInfo:
    qualname: str
    node: ast.AST                    # FunctionDef / AsyncFunctionDef / Lambda
    params: list[str]
    class_name: Optional[str]        # enclosing class, if a method
    parent: Optional[str]            # enclosing function qualname, if nested
    # call edges: (callee expression, Call node) for Name / self.X calls
    calls: list[tuple[str, ast.Call]] = dataclasses.field(default_factory=list)
    # set lazily by the tracer: which params carry traced values
    traced_params: set[str] = dataclasses.field(default_factory=set)
    trace_reason: Optional[str] = None


@dataclasses.dataclass
class ClassInfo:
    name: str
    node: ast.ClassDef
    bases: list[str]                 # dotted base names as written
    methods: dict[str, str]          # method name -> qualname


class _StatementVisitor(ast.NodeVisitor):
    """Walks one function body without descending into nested defs."""

    def __init__(self, root: ast.AST, on_node) -> None:
        self._root = root
        self._on_node = on_node

    def generic_visit(self, node: ast.AST) -> None:
        if node is not self._root and isinstance(
                node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
            return  # nested function: its own FunctionInfo covers it
        self._on_node(node)
        super().generic_visit(node)


def walk_body(func_node: ast.AST) -> Iterable[ast.AST]:
    """Every AST node lexically inside ``func_node``'s body, excluding
    nested function/lambda bodies (they are separate FunctionInfos)."""
    out: list[ast.AST] = []
    _StatementVisitor(func_node, out.append).visit(func_node)
    return out


class ModuleModel:
    """Everything the rules need about one module, computed once."""

    def __init__(self, path: str, rel_path: str, source: str) -> None:
        self.path = path
        self.rel_path = rel_path
        self.source = source
        self.tree = ast.parse(source, filename=path)
        self.imports: dict[str, str] = {}      # local alias -> canonical module
        self.functions: dict[str, FunctionInfo] = {}
        self.classes: dict[str, ClassInfo] = {}
        self.module_mutables: dict[str, int] = {}  # name -> lineno
        self._collect()
        self.traced: dict[str, FunctionInfo] = {}
        self._find_traced()

    # -- canonicalization ------------------------------------------------
    def canonical(self, dotted: Optional[str]) -> Optional[str]:
        """Resolve the leading segment through the import table:
        ``np.random.normal`` -> ``numpy.random.normal``."""
        if not dotted:
            return None
        head, _, rest = dotted.partition(".")
        base = self.imports.get(head)
        if base is None:
            return dotted
        return f"{base}.{rest}" if rest else base

    # -- collection ------------------------------------------------------
    def _collect(self) -> None:
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    self.imports[a.asname or a.name.split(".")[0]] = (
                        a.name if a.asname else a.name.split(".")[0])
            elif isinstance(node, ast.ImportFrom) and node.module and not node.level:
                for a in node.names:
                    if a.name == "*":
                        continue
                    self.imports[a.asname or a.name] = f"{node.module}.{a.name}"
        self._collect_scope(self.tree.body, prefix="", class_name=None,
                            parent=None)

    def _collect_scope(self, body, *, prefix: str, class_name, parent) -> None:
        for node in body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                qual = f"{prefix}{node.name}"
                self._add_function(node, qual, class_name, parent)
                self._collect_scope(node.body, prefix=f"{qual}.",
                                    class_name=None, parent=qual)
            elif isinstance(node, ast.ClassDef):
                qual = f"{prefix}{node.name}"
                bases = [dotted_name(b) or "" for b in node.bases]
                ci = ClassInfo(name=qual, node=node, bases=bases, methods={})
                self.classes[qual] = ci
                for sub in node.body:
                    if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
                        mq = f"{qual}.{sub.name}"
                        ci.methods[sub.name] = mq
                        self._add_function(sub, mq, qual, parent)
                        self._collect_scope(sub.body, prefix=f"{mq}.",
                                            class_name=None, parent=mq)
            elif isinstance(node, (ast.Assign, ast.AnnAssign)) \
                    and prefix == "" and class_name is None:
                value = node.value
                if isinstance(value, (ast.List, ast.Dict, ast.Set)):
                    targets = node.targets if isinstance(node, ast.Assign) \
                        else [node.target]
                    for t in targets:
                        if isinstance(t, ast.Name):
                            self.module_mutables[t.id] = node.lineno

    def _add_function(self, node, qual: str, class_name, parent) -> None:
        args = node.args
        params = [a.arg for a in (
            list(args.posonlyargs) + list(args.args) + list(args.kwonlyargs))]
        if args.vararg:
            params.append(args.vararg.arg)
        if args.kwarg:
            params.append(args.kwarg.arg)
        fi = FunctionInfo(qualname=qual, node=node, params=params,
                          class_name=class_name, parent=parent)
        for sub in walk_body(node):
            if isinstance(sub, ast.Call):
                name = dotted_name(sub.func)
                if name:
                    fi.calls.append((name, sub))
        self.functions[qual] = fi

    def _lambda_info(self, node: ast.Lambda, reason: str) -> FunctionInfo:
        qual = f"<lambda:{node.lineno}:{node.col_offset}>"
        if qual in self.functions:
            return self.functions[qual]
        params = [a.arg for a in (
            list(node.args.posonlyargs) + list(node.args.args)
            + list(node.args.kwonlyargs))]
        fi = FunctionInfo(qualname=qual, node=node, params=params,
                          class_name=None, parent=None)
        for sub in walk_body(node):
            if isinstance(sub, ast.Call):
                name = dotted_name(sub.func)
                if name:
                    fi.calls.append((name, sub))
        self.functions[qual] = fi
        return fi

    # -- call resolution -------------------------------------------------
    def resolve_call(self, caller: FunctionInfo, name: str) -> Optional[FunctionInfo]:
        """Resolve a called name to a locally defined function: nested
        defs of the caller first, then same-class ``self.X`` methods,
        then module-level functions."""
        if name.startswith("self.") and caller.class_name:
            meth = name[len("self."):]
            ci = self.classes.get(caller.class_name)
            if ci and "." not in meth and meth in ci.methods:
                return self.functions.get(ci.methods[meth])
            return None
        if "." in name:
            return None  # external / attribute call — not a local edge
        nested = self.functions.get(f"{caller.qualname}.{name}")
        if nested is not None:
            return nested
        # enclosing scopes, innermost first
        parent = caller.parent
        while parent:
            cand = self.functions.get(f"{parent}.{name}")
            if cand is not None:
                return cand
            parent = self.functions[parent].parent \
                if parent in self.functions else None
        return self.functions.get(name)

    # -- traced-function discovery ---------------------------------------
    def _mark_traced(self, target: ast.AST, caller: Optional[FunctionInfo],
                     reason: str, static: Optional[set] = None) -> None:
        """``target`` is an expression passed to a tracing wrapper: a
        lambda, a local function name, or ``self.meth``. Mark it (and,
        transitively at propagation time, its callees) as traced; all its
        params are considered traced values unless ``taint_args`` later
        refines them (we keep it simple: every param of a traced root is
        traced — carries, xs and operands all are)."""
        fi: Optional[FunctionInfo] = None
        if isinstance(target, ast.Lambda):
            fi = self._lambda_info(target, reason)
        else:
            name = dotted_name(target)
            if name is None:
                return
            if caller is not None:
                fi = self.resolve_call(caller, name)
            if fi is None:
                fi = self.functions.get(name)
            if fi is None and "." not in name:
                # module-level reference from module scope
                fi = self.functions.get(name)
        if fi is None:
            return
        if fi.qualname not in self.traced:
            fi.trace_reason = reason
            fi.traced_params.update(
                p for p in fi.params if not static or p not in static)
            self.traced[fi.qualname] = fi

    def _enclosing_function(self, node: ast.AST,
                            parents: dict) -> Optional[FunctionInfo]:
        cur = parents.get(node)
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for fi in self.functions.values():
                    if fi.node is cur:
                        return fi
            cur = parents.get(cur)
        return None

    def _find_traced(self) -> None:
        parents: dict[ast.AST, ast.AST] = {}
        for node in ast.walk(self.tree):
            for child in ast.iter_child_nodes(node):
                parents[child] = node

        # decorators: @jax.jit / @jit / @partial(jax.jit, ...)
        for fi in list(self.functions.values()):
            node = fi.node
            for dec in getattr(node, "decorator_list", []):
                dec_name = self.canonical(dotted_name(dec))
                if dec_name in _TRACE_WRAPPERS:
                    self._mark_decorated(fi, f"decorated @{dec_name}")
                elif isinstance(dec, ast.Call):
                    fn = self.canonical(dotted_name(dec.func))
                    if fn in _TRACE_WRAPPERS:
                        self._mark_decorated(fi, f"decorated @{fn}(...)",
                                             static=_static_params(dec, fi))
                    elif fn in ("functools.partial", "partial") and dec.args:
                        inner = self.canonical(dotted_name(dec.args[0]))
                        if inner in _TRACE_WRAPPERS:
                            self._mark_decorated(
                                fi, f"decorated @partial({inner}, ...)",
                                static=_static_params(dec, fi))

        # call sites: jit(f) / vmap(f) / lax.scan(f, ...) / cond / while
        for node in ast.walk(self.tree):
            if not isinstance(node, ast.Call):
                continue
            fn = self.canonical(dotted_name(node.func))
            if fn is None:
                continue
            caller = self._enclosing_function(node, parents)
            if fn in _TRACE_WRAPPERS and node.args:
                self._mark_traced(node.args[0], caller,
                                  f"passed to {fn} at line {node.lineno}",
                                  static=_static_call_names(node))
            elif fn in ("functools.partial", "partial") and node.args:
                inner = self.canonical(dotted_name(node.args[0]))
                if inner in _TRACE_WRAPPERS and len(node.args) > 1:
                    self._mark_traced(
                        node.args[1], caller,
                        f"passed to partial({inner}, ...) at line {node.lineno}")
            elif fn in _TRACE_HOF:
                for idx in _TRACE_HOF[fn]:
                    if idx < len(node.args):
                        self._mark_traced(
                            node.args[idx], caller,
                            f"passed to {fn} at line {node.lineno}")
                for kw in node.keywords:
                    if kw.arg in ("f", "body_fun", "cond_fun", "body"):
                        self._mark_traced(
                            kw.value, caller,
                            f"passed to {fn} at line {node.lineno}")

        # propagate: everything a traced function calls locally is traced;
        # call-argument taint flows into callee params
        frontier = list(self.traced.values())
        while frontier:
            fi = frontier.pop()
            for name, call in fi.calls:
                callee = self.resolve_call(fi, name)
                if callee is None:
                    continue
                tainted_idx = [
                    i for i, a in enumerate(call.args)
                    if _expr_mentions(a, fi.traced_params)]
                tainted_kw = [
                    kw.arg for kw in call.keywords
                    if kw.arg and _expr_mentions(kw.value, fi.traced_params)]
                changed = False
                if callee.qualname not in self.traced:
                    callee.trace_reason = (
                        f"called from traced {fi.qualname or '<module>'}")
                    self.traced[callee.qualname] = callee
                    changed = True
                pos = [p for p in callee.params if p != "self"]
                for i in tainted_idx:
                    if i < len(pos) and pos[i] not in callee.traced_params:
                        callee.traced_params.add(pos[i])
                        changed = True
                for kwname in tainted_kw:
                    if kwname in callee.params \
                            and kwname not in callee.traced_params:
                        callee.traced_params.add(kwname)
                        changed = True
                if changed:
                    frontier.append(callee)

    def _mark_decorated(self, fi: FunctionInfo, reason: str,
                        static: Optional[set] = None) -> None:
        if fi.qualname not in self.traced:
            fi.trace_reason = reason
            fi.traced_params.update(
                p for p in fi.params if not static or p not in static)
            self.traced[fi.qualname] = fi

    # -- taint within one function ---------------------------------------
    def tainted_names(self, fi: FunctionInfo) -> set[str]:
        """Names in ``fi`` holding traced values: traced params plus
        anything assigned from an expression mentioning a tainted name
        (two passes over the body handle use-before-redef chains)."""
        tainted = set(fi.traced_params)
        body_nodes = list(walk_body(fi.node))
        for _ in range(2):
            before = len(tainted)
            for node in body_nodes:
                targets: list[ast.AST] = []
                value: Optional[ast.AST] = None
                if isinstance(node, ast.Assign):
                    targets, value = node.targets, node.value
                elif isinstance(node, ast.AugAssign):
                    targets, value = [node.target], node.value
                elif isinstance(node, ast.AnnAssign) and node.value:
                    targets, value = [node.target], node.value
                elif isinstance(node, ast.NamedExpr):
                    targets, value = [node.target], node.value
                elif isinstance(node, ast.For):
                    targets, value = [node.target], node.iter
                if value is None or not taint_mentions(value, tainted):
                    continue
                for t in targets:
                    for n in ast.walk(t):
                        if isinstance(n, ast.Name):
                            tainted.add(n.id)
            if len(tainted) == before:
                break
        return tainted


def _expr_mentions(expr: ast.AST, names: set[str]) -> bool:
    if not names:
        return False
    for node in ast.walk(expr):
        if isinstance(node, ast.Name) and node.id in names:
            return True
    return False


def taint_mentions(expr: ast.AST, tainted: set[str]) -> bool:
    """Like ``_expr_mentions`` but shape-aware: does ``expr`` produce a
    *traced* value given ``tainted`` names? Subtrees under ``.shape`` /
    ``.ndim`` / ``.dtype`` / ``len(...)`` are concrete at trace time and
    break the taint (``if x.shape[0] > 1:`` is legal under jit)."""
    if not tainted:
        return False
    if isinstance(expr, ast.Attribute) and expr.attr in _TAINT_BREAKER_ATTRS:
        return False
    if isinstance(expr, ast.Call):
        fn = expr.func
        if isinstance(fn, ast.Name) and fn.id in _TAINT_BREAKER_CALLS:
            return False
    if isinstance(expr, ast.Name):
        return expr.id in tainted
    return any(taint_mentions(c, tainted)
               for c in ast.iter_child_nodes(expr))


# ---------------------------------------------------------------------------
# Engine driver
# ---------------------------------------------------------------------------


def iter_python_files(paths: Iterable[str]) -> Iterable[tuple[str, str]]:
    """Yield (abs_path, display_path) for every .py under ``paths``."""
    for root in paths:
        root = os.path.abspath(root)
        if os.path.isfile(root):
            yield root, os.path.basename(root)
            continue
        base = os.path.dirname(root)
        for dirpath, dirnames, filenames in os.walk(root):
            dirnames[:] = sorted(
                d for d in dirnames
                if d not in ("__pycache__", ".git", ".mypy_cache"))
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    full = os.path.join(dirpath, fn)
                    yield full, os.path.relpath(full, base).replace(os.sep, "/")


def analyze_file(path: str, rel_path: str,
                 rules: Optional[Iterable[str]] = None) -> list[Finding]:
    from .rules import run_rules  # late: rules import this module
    with open(path, encoding="utf-8") as fh:
        source = fh.read()
    return analyze_source(source, rel_path, rules=rules, abs_path=path)


def analyze_source(source: str, rel_path: str, *,
                   rules: Optional[Iterable[str]] = None,
                   abs_path: str = "<string>") -> list[Finding]:
    from .rules import run_rules
    try:
        model = ModuleModel(abs_path, rel_path, source)
    except SyntaxError as e:
        return [Finding(rule="R0", path=rel_path, line=e.lineno or 0,
                        symbol="", detail="syntax-error",
                        message=f"does not parse: {e.msg}")]
    return run_rules(model, rules)


def analyze_paths(paths: Iterable[str],
                  rules: Optional[Iterable[str]] = None) -> list[Finding]:
    findings: list[Finding] = []
    for full, rel in iter_python_files(paths):
        findings.extend(analyze_file(full, rel, rules=rules))
    findings.sort(key=lambda f: (f.path, f.line, f.rule, f.detail))
    return findings


__all__ = [
    "Baseline",
    "DEFAULT_TARGETS",
    "Finding",
    "FunctionInfo",
    "ModuleModel",
    "RULES",
    "analyze_paths",
    "analyze_source",
    "default_baseline_path",
    "dotted_name",
    "iter_python_files",
    "taint_mentions",
    "walk_body",
]
