"""R3 controller-purity: controllers decide, engines act (DESIGN.md §10).

The control-plane contract every PR since 4 hand-verified: a
:class:`~repro.core.control.Controller` receives a read-only
:class:`~repro.core.control.Telemetry` view and returns a decision; all
side effects (pool mutation, lifecycle transitions, billing) stay
engine-owned. A controller that mutates the pool or telemetry silently
desynchronizes the engine's O(1) aggregates and the seeded golden digests
— the drift is invisible until a sweep diverges. Statically enforced:

* no assignment (or augmented assignment / delete) to an attribute of a
  telemetry expression (``ctx.telemetry.x = ...``, ``telemetry.y += 1``);
* no calls to pool mutators (``take``/``release``/``retire``/
  ``add_warm``/``drop``/``admit_cold``/``submit``) on pool- or
  engine-reaching expressions (``...pool.take(...)``,
  ``ctx.telemetry._engine...`` — reaching through Telemetry's private
  engine handle is itself the violation);
* no mutable shared state: ``global`` statements in controller methods
  and mutable (list/dict/set literal) class-level attributes — a
  controller must be re-instantiable per engine without cross-run bleed.

A class is a controller when its base chain (resolved within the module)
or its name says so: bases named ``Controller``/``ControllerBase``/
``DelegatingController``/``ClassicMinosController`` (any dotted
spelling), or a class name ending in ``Controller``.

Fleet routing policies (``repro.fleet.policies``) sit on the same side
of the contract: they receive a read-only
:class:`~repro.core.control.FleetTelemetry` per
:class:`~repro.fleet.policies.RouteContext` and return a fleet index —
submits and hedges are the :class:`~repro.fleet.router.FleetRouter`'s
job. So classes named/based ``*RoutingPolicy`` (or
``RoutingPolicyBase``) are scanned under the same rule. The router
itself is deliberately exempt: ``FleetRouter`` is an engine-side actor
(it must call ``engine.submit``), which is why the match is on
``RoutingPolicy``, never on ``*Router``.
"""
from __future__ import annotations

import ast

from ..lint import Finding, ModuleModel, dotted_name, walk_body

_CONTROLLER_BASES = {
    "Controller", "ControllerBase", "DelegatingController",
    "ClassicMinosController", "RoutingPolicy", "RoutingPolicyBase",
}


def _name_is_controller(name: str) -> bool:
    tail = name.split(".")[-1]
    return (tail.endswith("Controller") or tail.endswith("RoutingPolicy")
            or tail == "RoutingPolicyBase")

_POOL_MUTATORS = {
    "take", "release", "retire", "add_warm", "drop", "admit_cold",
    "submit", "requeue", "push",
}


def _is_controller(model: ModuleModel, name: str,
                   _seen: frozenset = frozenset()) -> bool:
    if name in _seen:
        return False
    ci = model.classes.get(name)
    if ci is None:
        return _name_is_controller(name)
    if _name_is_controller(ci.name):
        return True
    for base in ci.bases:
        tail = base.split(".")[-1]
        if tail in _CONTROLLER_BASES:
            return True
        if _is_controller(model, base, _seen | {name}):
            return True
    return False


def _reaches_telemetry(node: ast.AST) -> bool:
    """Expression flows through a telemetry handle: any segment named
    ``telemetry`` in the attribute chain, or a bare name ``telemetry``."""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Attribute) and sub.attr == "telemetry":
            return True
        if isinstance(sub, ast.Name) and sub.id == "telemetry":
            return True
    return False


def _reaches_pool_or_engine(node: ast.AST) -> bool:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Attribute) and sub.attr in (
                "pool", "_engine", "engine", "queue", "loop"):
            return True
        if isinstance(sub, ast.Name) and sub.id in ("pool", "engine"):
            return True
    return False


def check_controller_purity(model: ModuleModel) -> list[Finding]:
    findings: list[Finding] = []
    for cls_name, ci in sorted(model.classes.items()):
        if not _is_controller(model, cls_name):
            continue
        # mutable class-level attributes (shared across instances)
        for node in ci.node.body:
            value = None
            target = None
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name):
                target, value = node.targets[0].id, node.value
            elif isinstance(node, ast.AnnAssign) \
                    and isinstance(node.target, ast.Name):
                target, value = node.target.id, node.value
            if value is not None and isinstance(
                    value, (ast.List, ast.Dict, ast.Set)):
                kind = type(value).__name__.lower()
                findings.append(Finding(
                    rule="R3", path=model.rel_path, line=node.lineno,
                    symbol=cls_name, detail=f"mutable-class-attr:{target}",
                    message=(
                        f"controller class attribute `{target}` is a "
                        f"mutable {kind} literal shared across instances; "
                        f"initialize it per-instance in __init__"),
                ))
        for meth_name, meth_qual in sorted(ci.methods.items()):
            fi = model.functions.get(meth_qual)
            if fi is None:
                continue
            for node in walk_body(fi.node):
                findings.extend(
                    _check_stmt(model, meth_qual, node))
    return findings


def _check_stmt(model: ModuleModel, qual: str, node: ast.AST) -> list[Finding]:
    out: list[Finding] = []
    # telemetry attribute writes
    targets: list[ast.AST] = []
    if isinstance(node, ast.Assign):
        targets = node.targets
    elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
        targets = [node.target]
    elif isinstance(node, ast.Delete):
        targets = node.targets
    for t in targets:
        if isinstance(t, (ast.Attribute, ast.Subscript)) \
                and _reaches_telemetry(t):
            name = dotted_name(t) or "<telemetry attribute>"
            out.append(Finding(
                rule="R3", path=model.rel_path, line=node.lineno,
                symbol=qual, detail=f"telemetry-write:{name}",
                message=(
                    f"controller writes `{name}` through the read-only "
                    f"Telemetry view; controllers decide, engines act "
                    f"(DESIGN.md §10)"),
            ))
    # pool/engine mutator calls
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute):
        attr = node.func.attr
        recv = node.func.value
        if attr in _POOL_MUTATORS and (
                _reaches_pool_or_engine(recv) or _reaches_telemetry(recv)):
            name = dotted_name(node.func) or f"<...>.{attr}"
            out.append(Finding(
                rule="R3", path=model.rel_path, line=node.lineno,
                symbol=qual, detail=f"pool-mutator:{attr}",
                message=(
                    f"controller calls pool/engine mutator `{name}`; "
                    f"lifecycle side effects are engine-owned — return a "
                    f"decision instead"),
            ))
    # global state
    if isinstance(node, ast.Global):
        for gname in node.names:
            out.append(Finding(
                rule="R3", path=model.rel_path, line=node.lineno,
                symbol=qual, detail=f"global-state:{gname}",
                message=(
                    f"controller method declares `global {gname}` — "
                    f"module-level mutable state bleeds across engines/"
                    f"runs; keep controller state per-instance"),
            ))
    return out
