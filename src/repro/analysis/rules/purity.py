"""R1 scan-purity + R2 tracer-leak: what traced code may not do.

A function is *traced* when it is passed to ``lax.scan`` / ``jit`` /
``vmap`` / ``lax.cond`` / ``lax.while_loop`` (directly, via decorator, or
reachable through local calls from such a function — the engine's
per-module call graph resolves this). Traced Python runs ONCE at trace
time; anything host-side it does is baked into the compiled program:

* host RNG (``np.random.*``, stdlib ``random``) freezes one draw for
  every compiled step — the sweep still *runs*, deterministically wrong;
* wall-clock reads (``time.time`` & co) freeze trace time into results;
* file/network I/O executes at trace time, not run time, and re-executes
  on every retrace — silent nondeterminism across cache states.

R2 catches the converse failure: host operations applied to *traced
values* (``float()``/``int()``/``.item()``/``np.asarray`` force a
concretization that raises ``TracerArrayConversionError`` under jit, or
silently falls back to eager under ``lax.scan`` debugging; ``if``/
``while`` on a traced value raises ``TracerBoolConversionError``). The
engine's taint pass knows which names in each traced function derive from
traced parameters, so static config branches (``if cfg.adaptive:`` on a
closed-over dataclass) stay legal while carry branches are flagged.
"""
from __future__ import annotations

import ast
from typing import Optional

from ..lint import (
    Finding, FunctionInfo, ModuleModel, dotted_name, taint_mentions, walk_body,
)

#: canonical dotted prefixes forbidden in traced code (R1), with reasons.
_FORBIDDEN_PREFIXES = (
    ("numpy.random.", "host RNG"),
    ("random.", "host RNG"),
    ("secrets.", "host RNG"),
    ("time.time", "wall clock"),
    ("time.monotonic", "wall clock"),
    ("time.perf_counter", "wall clock"),
    ("time.process_time", "wall clock"),
    ("time.sleep", "host sleep"),
    ("datetime.datetime.now", "wall clock"),
    ("datetime.datetime.utcnow", "wall clock"),
    ("datetime.datetime.today", "wall clock"),
    ("datetime.date.today", "wall clock"),
    ("datetime.now", "wall clock"),
    ("socket.", "network I/O"),
    ("urllib.", "network I/O"),
    ("requests.", "network I/O"),
    ("http.client.", "network I/O"),
    ("os.urandom", "host RNG"),
    ("os.getenv", "host environment read"),
    ("os.environ", "host environment read"),
    ("subprocess.", "host process I/O"),
)

#: bare builtins forbidden as calls in traced code (R1: file I/O).
_FORBIDDEN_BUILTINS = {
    "open": "file I/O",
    "input": "console I/O",
}

#: numpy host-conversion calls (R2) once canonicalized.
_HOST_CONVERSIONS = {
    "numpy.asarray", "numpy.array", "numpy.asanyarray", "numpy.ascontiguousarray",
}


def _forbidden(canon: str) -> Optional[str]:
    for prefix, why in _FORBIDDEN_PREFIXES:
        if canon == prefix or canon.startswith(prefix):
            return why
        if prefix.endswith(".") and canon == prefix[:-1]:
            return why
    return None


def check_scan_purity(model: ModuleModel) -> list[Finding]:
    """R1: no host RNG / wall clock / IO reachable from traced code."""
    findings: list[Finding] = []
    for qual, fi in sorted(model.traced.items()):
        locals_here = _local_names(fi)
        for node in walk_body(fi.node):
            name = None
            if isinstance(node, ast.Call):
                name = dotted_name(node.func)
            elif isinstance(node, ast.Attribute):
                # plain attribute read, e.g. os.environ["X"]
                name = dotted_name(node)
            if not name:
                continue
            head = name.split(".", 1)[0]
            if head in locals_here and head not in model.imports:
                continue  # shadowed by a local binding — not the module
            canon = model.canonical(name)
            why = _forbidden(canon)
            if why is None and isinstance(node, ast.Call) \
                    and name in _FORBIDDEN_BUILTINS and head not in locals_here:
                canon, why = name, _FORBIDDEN_BUILTINS[name]
            if why is not None:
                findings.append(Finding(
                    rule="R1", path=model.rel_path, line=node.lineno,
                    symbol=qual, detail=canon,
                    message=(
                        f"{canon} ({why}) inside traced code "
                        f"({fi.trace_reason}); traced bodies must draw "
                        f"only from jax.random / carried state"),
                ))
    return _dedup(findings)


def check_tracer_leak(model: ModuleModel) -> list[Finding]:
    """R2: no host conversion of, or control flow on, a traced value."""
    findings: list[Finding] = []
    for qual, fi in sorted(model.traced.items()):
        if not fi.traced_params:
            continue
        tainted = model.tainted_names(fi)
        for node in walk_body(fi.node):
            if isinstance(node, ast.Call):
                findings.extend(
                    _check_conversion_call(model, fi, qual, node, tainted))
            elif isinstance(node, (ast.If, ast.While)):
                if _mentions_tainted(node.test, tainted):
                    kind = "if" if isinstance(node, ast.If) else "while"
                    names = _tainted_in(node.test, tainted)
                    findings.append(Finding(
                        rule="R2", path=model.rel_path, line=node.lineno,
                        symbol=qual, detail=f"{kind}-on-traced:{names}",
                        message=(
                            f"`{kind}` branches on traced value(s) "
                            f"{names} ({fi.trace_reason}); use jnp.where/"
                            f"lax.cond — a Python branch raises "
                            f"TracerBoolConversionError under jit"),
                    ))
    return _dedup(findings)


def _check_conversion_call(model: ModuleModel, fi: FunctionInfo, qual: str,
                           node: ast.Call, tainted: set[str]) -> list[Finding]:
    out: list[Finding] = []
    name = dotted_name(node.func)
    if name is None:
        return out
    canon = model.canonical(name)
    arg0_tainted = bool(node.args) and _mentions_tainted(node.args[0], tainted)
    if name in ("float", "int", "bool", "complex") and arg0_tainted:
        names = _tainted_in(node.args[0], tainted)
        out.append(Finding(
            rule="R2", path=model.rel_path, line=node.lineno, symbol=qual,
            detail=f"{name}-on-traced:{names}",
            message=(
                f"{name}() concretizes traced value(s) {names} "
                f"({fi.trace_reason}); this raises "
                f"TracerArrayConversionError under jit"),
        ))
    elif canon in _HOST_CONVERSIONS and arg0_tainted:
        names = _tainted_in(node.args[0], tainted)
        out.append(Finding(
            rule="R2", path=model.rel_path, line=node.lineno, symbol=qual,
            detail=f"{canon}-on-traced:{names}",
            message=(
                f"{canon}() pulls traced value(s) {names} to host "
                f"({fi.trace_reason}); use jnp.asarray to stay on device"),
        ))
    elif isinstance(node.func, ast.Attribute) and node.func.attr == "item" \
            and not node.args \
            and _mentions_tainted(node.func.value, tainted):
        names = _tainted_in(node.func.value, tainted)
        out.append(Finding(
            rule="R2", path=model.rel_path, line=node.lineno, symbol=qual,
            detail=f"item-on-traced:{names}",
            message=(
                f".item() forces a host sync on traced value(s) {names} "
                f"({fi.trace_reason})"),
        ))
    return out


def _local_names(fi: FunctionInfo) -> set[str]:
    """Params + names assigned anywhere in the body (shadow check)."""
    names = set(fi.params)
    for node in walk_body(fi.node):
        if isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign,
                             ast.NamedExpr, ast.For)):
            targets = getattr(node, "targets", None) \
                or [getattr(node, "target", None)]
            for t in targets:
                if t is None:
                    continue
                for n in ast.walk(t):
                    if isinstance(n, ast.Name):
                        names.add(n.id)
    return names


def _mentions_tainted(expr: ast.AST, tainted: set[str]) -> bool:
    # shape-aware: `if x.shape[0] > 1:` on a traced x is legal under jit
    return taint_mentions(expr, tainted)


def _tainted_in(expr: ast.AST, tainted: set[str]) -> str:
    hits = sorted({n.id for n in ast.walk(expr)
                   if isinstance(n, ast.Name) and n.id in tainted})
    return ",".join(hits)


def _dedup(findings: list[Finding]) -> list[Finding]:
    seen: set[tuple] = set()
    out = []
    for f in findings:
        key = (f.fingerprint, f.line)
        if key not in seen:
            seen.add(key)
            out.append(f)
    return out
