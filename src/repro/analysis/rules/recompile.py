"""R4 recompile-hazard + R5 estimator-pytree.

R4 — the serving/sim fast paths assert "zero recompiles on the second
batch" in CI (grid/openloop sweeps); this rule catches the hazards that
break that guard *before* a sweep has to:

* ``jax.jit(f)(x)`` — jit applied and immediately invoked builds a fresh
  compile-cache entry per call site execution;
* ``jax.jit``/``jax.vmap`` application inside a ``for``/``while`` loop —
  a new wrapper per iteration never hits the cache (the repo idiom is a
  module-level ``_JIT_CACHE`` keyed on static config, sim/vectorized.py);
* ``list``/``dict``/``set`` literals passed to a known-jitted callable —
  a per-call container changes the pytree structure (or, for static
  args, is unhashable) and retraces; pass a tuple / NamedTuple.

R5 — scan carriers must be NamedTuples / registered pytrees with array
leaves (the ``VecState``/``OpenState``/``WelfordState`` idiom): a raw
``list``/``dict``/``set`` literal initializer retraces on any structure
drift and defeats the carry-pruning the fast path relies on (``None``
leaves pruning, sim/vectorized.py). Checked at ``lax.scan`` call sites
(the ``init`` argument) and in locally-resolved scan bodies (the carry
element of the returned pair).
"""
from __future__ import annotations

import ast
from typing import Optional

from ..lint import Finding, ModuleModel, dotted_name, walk_body

_JIT_WRAPPERS = {"jax.jit", "jax.pmap"}
_LOOPY_WRAPPERS = {"jax.jit", "jax.pmap", "jax.vmap"}


def _canon_call(model: ModuleModel, node: ast.Call) -> Optional[str]:
    return model.canonical(dotted_name(node.func))


def _is_jit_application(model: ModuleModel, node: ast.AST,
                        wrappers: set) -> bool:
    """``jax.jit(...)`` or ``functools.partial(jax.jit, ...)``."""
    if not isinstance(node, ast.Call):
        return False
    fn = _canon_call(model, node)
    if fn in wrappers:
        return True
    if fn in ("functools.partial", "partial") and node.args:
        return model.canonical(dotted_name(node.args[0])) in wrappers
    return False


def _collect_jitted_names(model: ModuleModel) -> dict[str, int]:
    """Names bound to jitted callables: ``f = jax.jit(g)`` assignments and
    ``@jax.jit`` / ``@partial(jax.jit, ...)`` decorated defs."""
    jitted: dict[str, int] = {}
    for node in ast.walk(model.tree):
        if isinstance(node, ast.Assign) and _is_jit_application(
                model, node.value, _JIT_WRAPPERS):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    jitted[t.id] = node.lineno
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                dec_fn = model.canonical(dotted_name(dec))
                if dec_fn in _JIT_WRAPPERS or _is_jit_application(
                        model, dec, _JIT_WRAPPERS):
                    jitted[node.name] = node.lineno
    return jitted


def check_recompile_hazard(model: ModuleModel) -> list[Finding]:
    findings: list[Finding] = []
    jitted = _collect_jitted_names(model)

    # parent map for enclosing-loop / enclosing-call detection
    parents: dict[ast.AST, ast.AST] = {}
    for node in ast.walk(model.tree):
        for child in ast.iter_child_nodes(node):
            parents[child] = node

    def enclosing_symbol(node: ast.AST) -> str:
        cur = parents.get(node)
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for fi in model.functions.values():
                    if fi.node is cur:
                        return fi.qualname
                return cur.name
            cur = parents.get(cur)
        return ""

    def inside_loop(node: ast.AST) -> bool:
        cur = parents.get(node)
        while cur is not None:
            if isinstance(cur, (ast.For, ast.While, ast.AsyncFor)):
                return True
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef,
                                ast.Lambda)):
                return False  # a def inside a loop compiles once per call
            cur = parents.get(cur)
        return False

    for node in ast.walk(model.tree):
        if not isinstance(node, ast.Call):
            continue
        # jax.jit(f)(x): jit application immediately invoked
        if isinstance(node.func, ast.Call) and _is_jit_application(
                model, node.func, _JIT_WRAPPERS):
            findings.append(Finding(
                rule="R4", path=model.rel_path, line=node.lineno,
                symbol=enclosing_symbol(node), detail="jit-immediate-call",
                message=(
                    "jax.jit(...) applied and immediately called — the "
                    "wrapper (and its compile cache entry) dies with the "
                    "expression; bind the jitted function once (module "
                    "level or a keyed cache) and call that"),
            ))
        # jit/vmap application inside a Python loop
        elif _is_jit_application(model, node, _LOOPY_WRAPPERS) \
                and inside_loop(node):
            fn = _canon_call(model, node) or "jax.jit"
            findings.append(Finding(
                rule="R4", path=model.rel_path, line=node.lineno,
                symbol=enclosing_symbol(node), detail=f"jit-in-loop:{fn}",
                message=(
                    f"{fn} applied inside a loop — every iteration builds "
                    f"a fresh wrapper that cannot hit the compile cache; "
                    f"hoist the application out of the loop"),
            ))
        # container literals at known-jitted call sites
        if isinstance(node.func, ast.Name) and node.func.id in jitted:
            for arg in list(node.args) + [kw.value for kw in node.keywords]:
                if isinstance(arg, (ast.List, ast.Dict, ast.Set)):
                    kind = type(arg).__name__.lower()
                    findings.append(Finding(
                        rule="R4", path=model.rel_path, line=node.lineno,
                        symbol=enclosing_symbol(node),
                        detail=f"container-arg:{node.func.id}:{kind}",
                        message=(
                            f"{kind} literal passed to jitted "
                            f"`{node.func.id}` (bound at line "
                            f"{jitted[node.func.id]}): unhashable as a "
                            f"static arg and structure-unstable as a "
                            f"traced one — pass a tuple / NamedTuple"),
                    ))
    return findings


def _bad_carry_literal(node: ast.AST) -> Optional[str]:
    """'list'/'dict'/'set' when the expression is (or a tuple directly
    contains) a raw mutable-container literal."""
    if isinstance(node, (ast.List, ast.Dict, ast.Set)):
        return type(node).__name__.lower()
    if isinstance(node, ast.Tuple):
        for elt in node.elts:
            bad = _bad_carry_literal(elt)
            if bad:
                return bad
    return None


def check_estimator_pytree(model: ModuleModel) -> list[Finding]:
    findings: list[Finding] = []
    parents: dict[ast.AST, ast.AST] = {}
    for node in ast.walk(model.tree):
        for child in ast.iter_child_nodes(node):
            parents[child] = node

    def enclosing(node: ast.AST):
        cur = parents.get(node)
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for fi in model.functions.values():
                    if fi.node is cur:
                        return fi
            cur = parents.get(cur)
        return None

    for node in ast.walk(model.tree):
        if not isinstance(node, ast.Call):
            continue
        fn = _canon_call(model, node)
        if fn != "jax.lax.scan":
            continue
        sym_fi = enclosing(node)
        sym = sym_fi.qualname if sym_fi else ""
        init = node.args[1] if len(node.args) > 1 else None
        for kw in node.keywords:
            if kw.arg == "init":
                init = kw.value
        if isinstance(init, ast.Name) and sym_fi is not None:
            # resolve one level of local binding: init = {...}; scan(f, init)
            init_name = init.id
            for stmt in walk_body(sym_fi.node):
                if isinstance(stmt, ast.Assign) \
                        and stmt.lineno < node.lineno \
                        and any(isinstance(t, ast.Name) and t.id == init_name
                                for t in stmt.targets):
                    init = stmt.value
        if init is not None:
            bad = _bad_carry_literal(init)
            if bad:
                findings.append(Finding(
                    rule="R5", path=model.rel_path, line=node.lineno,
                    symbol=sym, detail=f"scan-init-literal:{bad}",
                    message=(
                        f"lax.scan carry initialized from a raw {bad} "
                        f"literal; carriers must be NamedTuples / "
                        f"registered pytrees with array leaves (the "
                        f"VecState/WelfordState idiom) so the carry "
                        f"structure is stable across steps"),
                ))
        # resolved scan body: the returned carry must not be a container
        # literal either
        body_expr = node.args[0] if node.args else None
        body_fi = None
        if body_expr is not None and sym_fi is not None:
            name = dotted_name(body_expr)
            if name:
                body_fi = model.resolve_call(sym_fi, name)
        if body_fi is not None:
            for sub in walk_body(body_fi.node):
                if isinstance(sub, ast.Return) and sub.value is not None \
                        and isinstance(sub.value, ast.Tuple) \
                        and sub.value.elts:
                    bad = _bad_carry_literal(sub.value.elts[0])
                    if bad:
                        findings.append(Finding(
                            rule="R5", path=model.rel_path,
                            line=sub.lineno, symbol=body_fi.qualname,
                            detail=f"scan-carry-return-literal:{bad}",
                            message=(
                                f"scan body returns a raw {bad} literal "
                                f"as its carry; return the same "
                                f"NamedTuple/pytree type the scan was "
                                f"initialized with"),
                        ))
    return findings
