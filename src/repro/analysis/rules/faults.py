"""R6 fault-injector purity: fault schedules draw only injected,
seeded randomness (DESIGN.md §15).

The fault-injection substrate's whole value is *reproducible* failure:
a crash schedule that consults the host RNG, the wall clock, the
environment, or a file is a different experiment on every run — and the
acceptance criterion "FaultPlan disabled ⇒ bit-identical goldens" is
unverifiable if the injector can smuggle in entropy. Statically
enforced for every class whose name (or base chain) ends in
``FaultPlan`` or ``FaultProcess``:

* no host RNG / wall clock / IO / environment reads (the R1 forbidden
  set: ``numpy.random.*``, ``random.*``, ``time.time``, ``open``, ...)
  anywhere in a method body — with ONE exemption: *constructing* a
  seeded generator, ``numpy.random.RandomState(seed)`` /
  ``numpy.random.default_rng(seed)`` *with at least one argument*, is
  the sanctioned pattern (the injector owns a private stream);
* the unseeded constructors (``RandomState()`` / ``default_rng()``)
  are flagged separately (``unseeded-rng``): they seed from the OS and
  differ per process.

Module-level fault *configuration* (rates, windows) is plain data and
not scanned; only the injector classes' methods are.
"""
from __future__ import annotations

import ast

from ..lint import Finding, ModuleModel, dotted_name, walk_body
from .purity import _FORBIDDEN_BUILTINS, _forbidden, _local_names

_FAULT_SUFFIXES = ("FaultPlan", "FaultProcess")

#: seeded-generator constructors exempt from the host-RNG ban when
#: called with at least one (seed) argument.
_SEEDED_CTORS = {
    "numpy.random.RandomState",
    "numpy.random.default_rng",
    "numpy.random.Generator",
}


def _name_is_fault_injector(name: str) -> bool:
    tail = name.split(".")[-1]
    return tail.endswith(_FAULT_SUFFIXES)


def _is_fault_injector(model: ModuleModel, name: str,
                       _seen: frozenset = frozenset()) -> bool:
    if name in _seen:
        return False
    ci = model.classes.get(name)
    if ci is None:
        return _name_is_fault_injector(name)
    if _name_is_fault_injector(ci.name):
        return True
    for base in ci.bases:
        if _name_is_fault_injector(base):
            return True
        if _is_fault_injector(model, base, _seen | {name}):
            return True
    return False


def check_fault_injector_purity(model: ModuleModel) -> list[Finding]:
    """R6: ``*FaultPlan``/``*FaultProcess`` methods touch no host
    entropy beyond constructing their own seeded generator."""
    findings: list[Finding] = []
    for cls_name, ci in sorted(model.classes.items()):
        if not _is_fault_injector(model, cls_name):
            continue
        for meth_name, meth_qual in sorted(ci.methods.items()):
            fi = model.functions.get(meth_qual)
            if fi is None:
                continue
            locals_here = _local_names(fi)
            # generator-constructor calls are judged at the Call node;
            # their func attribute chains must not re-fire as bare reads
            ctor_chain_ids: set[int] = set()
            for node in walk_body(fi.node):
                if isinstance(node, ast.Call):
                    name = dotted_name(node.func)
                    if name and model.canonical(name) in _SEEDED_CTORS:
                        ctor_chain_ids.update(
                            id(sub) for sub in ast.walk(node.func))
            for node in walk_body(fi.node):
                name = None
                if isinstance(node, ast.Call):
                    name = dotted_name(node.func)
                elif isinstance(node, ast.Attribute):
                    if id(node) in ctor_chain_ids:
                        continue
                    name = dotted_name(node)
                if not name:
                    continue
                head = name.split(".", 1)[0]
                if head in locals_here and head not in model.imports:
                    continue  # shadowed by a local binding
                canon = model.canonical(name)
                if canon in _SEEDED_CTORS and isinstance(node, ast.Call):
                    if node.args or node.keywords:
                        continue  # seeded ctor: the sanctioned pattern
                    findings.append(Finding(
                        rule="R6", path=model.rel_path, line=node.lineno,
                        symbol=meth_qual, detail=f"unseeded-rng:{canon}",
                        message=(
                            f"{canon}() without a seed draws OS entropy — "
                            f"the fault schedule differs per process; pass "
                            f"the injected seed (DESIGN.md §15)"),
                    ))
                    continue
                why = _forbidden(canon)
                if why is None and isinstance(node, ast.Call) \
                        and name in _FORBIDDEN_BUILTINS \
                        and head not in locals_here:
                    canon, why = name, _FORBIDDEN_BUILTINS[name]
                if why is not None:
                    findings.append(Finding(
                        rule="R6", path=model.rel_path, line=node.lineno,
                        symbol=meth_qual, detail=canon,
                        message=(
                            f"{canon} ({why}) inside fault injector "
                            f"{cls_name}; fault schedules must draw only "
                            f"from their own injected seeded RNG "
                            f"(DESIGN.md §15)"),
                    ))
    return _dedup(findings)


def _dedup(findings: list[Finding]) -> list[Finding]:
    seen: set[tuple] = set()
    out = []
    for f in findings:
        key = (f.fingerprint, f.line)
        if key not in seen:
            seen.add(key)
            out.append(f)
    return out
