"""Rule registry for :mod:`repro.analysis` (DESIGN.md §13).

Each rule module exposes ``check(model) -> list[Finding]`` functions
registered here under their rule ids. Adding a rule = one function + one
registry entry; the engine (``lint.py``) owns the module model (imports,
call graph, traced set), the rules own the judgments.
"""
from __future__ import annotations

from typing import Iterable, Optional

from ..lint import Finding, ModuleModel
from . import controller, faults, purity, recompile

#: rule id -> checker. Order is report order within a file.
REGISTRY = {
    "R1": purity.check_scan_purity,
    "R2": purity.check_tracer_leak,
    "R3": controller.check_controller_purity,
    "R4": recompile.check_recompile_hazard,
    "R5": recompile.check_estimator_pytree,
    "R6": faults.check_fault_injector_purity,
}


def run_rules(model: ModuleModel,
              rules: Optional[Iterable[str]] = None) -> list[Finding]:
    selected = list(rules) if rules else list(REGISTRY)
    unknown = [r for r in selected if r not in REGISTRY]
    if unknown:
        raise ValueError(f"unknown rule(s): {', '.join(unknown)}; "
                         f"available: {', '.join(REGISTRY)}")
    findings: list[Finding] = []
    for rule in selected:
        findings.extend(REGISTRY[rule](model))
    return findings


__all__ = ["REGISTRY", "run_rules"]
