"""repro.analysis — project-native static checks + runtime sanitizer.

Static side (``python -m repro.analysis``): AST lint over ``src/`` and
``benchmarks/`` enforcing the hand-verified invariants of DESIGN.md §13
(scan purity, tracer leaks, controller purity, recompile hazards, scan
carrier pytrees), with a fingerprint baseline so grandfathered findings
only ever ratchet down.

Runtime side (``REPRO_SANITIZE=1``): :mod:`repro.analysis.sanitizer`
wraps SubstrateEngine / InstancePool / run_open_loop with conservation,
heap-consistency, telemetry-immutability, and NaN/inf checks.

Pure stdlib — safe to import before (or without) jax.
"""
from __future__ import annotations

from .lint import (
    Baseline,
    DEFAULT_TARGETS,
    Finding,
    ModuleModel,
    RULES,
    analyze_paths,
    analyze_source,
    default_baseline_path,
)

__all__ = [
    "Baseline",
    "DEFAULT_TARGETS",
    "Finding",
    "ModuleModel",
    "RULES",
    "analyze_paths",
    "analyze_source",
    "default_baseline_path",
]
