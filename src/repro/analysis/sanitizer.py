"""Runtime substrate sanitizer (DESIGN.md §13, env-gated).

``REPRO_SANITIZE=1`` arms cross-checks of the invariants the static rules
cannot see — the ones that live in *state*, not syntax:

* **pool conservation** — ``InstancePool._in_flight`` (the O(1) counter
  the load-aware gate reads per judgment, PR 5) must equal
  ``sum(_active.values())``; ``_live_ids`` must equal
  ``_active.keys() | _avail_seq.keys()``; ``available`` and ``_avail_seq``
  must agree element-for-element.
* **spread-heap consistency** — the lazily-invalidated min-load heap's
  best *valid* entry (latest push id, current load, current seq) must
  name the same instance a full O(n) argmin over ``available`` would.
* **deadline bound** — ``_next_deadline`` is a lower bound: no idle
  pooled instance's reclaim deadline may lie below it (a stale-low bound
  costs a spurious sweep; a stale-high one silently skips reclaims).
* **engine conservation** — ``requests_arrived == len(results) +
  requests_dropped + len(queue) + executing`` at every submit/finish,
  with ``executing`` tracked independently by wrapping the queue's
  ``pop``/``requeue`` (the event-stream side of the ledger); and every
  executing request implies a pending completion event on the clock heap.
* **telemetry immutability** — the read-only view must actually reject
  attribute writes (probed once at attach).
* **fault ledger** (DESIGN.md §15) — with a FaultPlan armed, every
  injected fault must bill a finite non-negative amount, the dead-letter
  counter must match its event log, and no request may be both
  dead-lettered and completed (the idempotent-re-dispatch guarantee).
  The engine conservation equation gains a ``dead_lettered`` term, and
  the pool bound tolerates *zombie* executions — abandoned attempts
  whose instance slot is still legitimately held until their scheduled
  completion/crash event fires.
* **finite outputs** — vectorized-sim summaries must be NaN/inf-free
  (:func:`check_finite`), and the vectorized open-loop summary must
  conserve requests per arm (:func:`check_open_summary`).

Wrapping is per-instance (bound-method replacement on the engine/pool
being sanitized), never global monkeypatching — two engines in one
process sanitize independently, and an un-sanitized engine pays nothing.
Full structural pool checks are O(pool) so they run sampled (every
``_SAMPLE_EVERY`` mutations) plus always after ``retire`` — the lifecycle
edge PRs 4–6 kept re-breaking; per-operation checks stay O(1). Overhead
is measured in BENCH_substrate.sanitize.json (target <=2x).
"""
from __future__ import annotations

import math
import os
from typing import Any, Optional

ENV_VAR = "REPRO_SANITIZE"

#: full O(pool) structural checks run every N pool mutations (and always
#: after retire); O(1) counter checks run on every mutation.
_SAMPLE_EVERY = 32


def enabled() -> bool:
    """True when the sanitizer env gate is set (anything but ''/'0')."""
    return os.environ.get(ENV_VAR, "") not in ("", "0")


class SanitizerError(AssertionError):
    """An armed invariant failed. Subclasses AssertionError so existing
    ``pytest.raises(AssertionError)`` harnesses and -O semantics hold."""


def _fail(what: str, **context: Any) -> None:
    detail = ", ".join(f"{k}={v!r}" for k, v in context.items())
    raise SanitizerError(f"[{ENV_VAR}] {what} ({detail})")


# ---------------------------------------------------------------------------
# Pool checks
# ---------------------------------------------------------------------------


def check_pool(pool: Any, *, where: str = "") -> None:
    """Full structural verification of an :class:`InstancePool` — the
    O(n) recomputations the incremental aggregates (PR 5) replaced."""
    active = pool._active
    recomputed = sum(active.values())
    if pool._in_flight != recomputed:
        _fail("pool._in_flight diverged from sum(_active.values())",
              where=where, counter=pool._in_flight, recomputed=recomputed)
    if pool._in_flight < 0:
        _fail("pool._in_flight negative", where=where, value=pool._in_flight)
    for iid, n in active.items():
        if n <= 0:
            _fail("zero/negative in-flight entry kept in _active",
                  where=where, instance=iid, in_flight=n)
    avail_ids = [i.instance_id for i in pool.available]
    if len(set(avail_ids)) != len(avail_ids):
        _fail("duplicate instance in available list", where=where,
              ids=avail_ids)
    if set(avail_ids) != set(pool._avail_seq):
        _fail("available list and _avail_seq disagree", where=where,
              available=sorted(set(avail_ids)),
              avail_seq=sorted(pool._avail_seq))
    expected_live = set(active) | set(pool._avail_seq)
    if pool._live_ids != expected_live:
        _fail("_live_ids diverged from _active | _avail_seq", where=where,
              live=sorted(pool._live_ids), expected=sorted(expected_live))
    for inst in pool.available:
        if active.get(inst.instance_id, 0) > pool.concurrency:
            _fail("available instance above concurrency cap", where=where,
                  instance=inst.instance_id,
                  load=active[inst.instance_id], cap=pool.concurrency)
    _check_deadline_bound(pool, where=where)
    if pool.order == "spread":
        _check_spread_heap(pool, where=where)


def _check_deadline_bound(pool: Any, *, where: str) -> None:
    bound = pool._next_deadline
    if bound == math.inf:
        return
    for inst in pool.available:
        iid = inst.instance_id
        if pool._active.get(iid, 0) > 0:
            continue  # busy instances are reclaim-protected
        d = inst.last_used_ms + inst.idle_timeout_ms
        rd = pool._recycle_deadline.get(iid)
        if rd is not None and rd < d:
            d = rd
        if d < bound:
            _fail("_next_deadline above an idle instance's deadline "
                  "(sweep would fire late)", where=where, instance=iid,
                  deadline=d, bound=bound)


def _check_spread_heap(pool: Any, *, where: str) -> None:
    """The heap's best *valid* entry must match the O(n) argmin the heap
    replaced (load, then position seq — FIFO among ties)."""
    if not pool.available:
        return
    expected = min(
        ((pool._active.get(i.instance_id, 0), pool._avail_seq[i.instance_id])
         for i in pool.available))
    best: Optional[tuple] = None
    for load, seq, pid, inst in pool._spread_heap:
        iid = inst.instance_id
        if pool._spread_latest.get(iid) != pid:
            continue  # superseded push
        if iid not in pool._avail_seq or pool._avail_seq[iid] != seq:
            continue  # left the pool / moved since this push
        if pool._active.get(iid, 0) != load:
            continue  # load changed since this push
        if best is None or (load, seq) < best:
            best = (load, seq)
    if best is None:
        _fail("spread heap has no valid entry while pool is non-empty",
              where=where, heap_size=len(pool._spread_heap),
              available=len(pool.available))
    if best != expected:
        _fail("spread heap min diverged from O(n) argmin", where=where,
              heap_min=best, argmin=expected)


def attach_pool(pool: Any) -> None:
    """Arm a pool: O(1) counter checks on every mutator call, a full
    :func:`check_pool` every ``_SAMPLE_EVERY`` mutations and after every
    ``retire`` (the edge where counter/heap drift historically entered)."""
    if getattr(pool, "_sanitizer_armed", False):
        return
    pool._sanitizer_armed = True
    state = {"ops": 0}

    def _wrap(name: str, always_full: bool = False):
        inner = getattr(pool, name)

        def wrapped(*args: Any, **kwargs: Any):
            out = inner(*args, **kwargs)
            state["ops"] += 1
            if pool._in_flight < 0:
                _fail("pool._in_flight negative", where=name,
                      value=pool._in_flight)
            if always_full or state["ops"] % _SAMPLE_EVERY == 0:
                check_pool(pool, where=name)
            return out

        wrapped.__name__ = f"sanitized_{name}"
        setattr(pool, name, wrapped)

    for mutator in ("take", "release", "drop", "add_warm", "admit_cold"):
        _wrap(mutator)
    _wrap("retire", always_full=True)


# ---------------------------------------------------------------------------
# Engine checks
# ---------------------------------------------------------------------------


def check_telemetry_readonly(telemetry: Any) -> None:
    """The Telemetry view handed to controllers must reject writes."""
    try:
        telemetry._sanitizer_probe = 1
    except (AttributeError, TypeError):
        return
    try:  # undo the mutation we just proved possible
        del telemetry._sanitizer_probe
    except Exception:
        pass
    _fail("Telemetry accepted an attribute write — the read-only "
          "controller contract is void", type=type(telemetry).__name__)


def check_engine_conservation(engine: Any, *, where: str = "") -> None:
    executing = engine._sanitizer_executing
    dead = getattr(engine, "requests_dead_lettered", 0)
    zombies = getattr(engine, "_zombie_executions", 0)
    lhs = engine.requests_arrived
    rhs = (len(engine.results) + engine.requests_dropped
           + len(engine.queue) + executing + dead)
    if lhs != rhs:
        _fail("engine conservation violated: arrived != results + dropped "
              "+ queued + executing + dead_lettered", where=where,
              arrived=lhs, results=len(engine.results),
              dropped=engine.requests_dropped, queued=len(engine.queue),
              executing=executing, dead_lettered=dead)
    if executing < 0:
        _fail("executing count negative", where=where, executing=executing)
    if zombies < 0:
        _fail("zombie execution count negative", where=where, zombies=zombies)
    # event-stream cross-check: each executing request has a pending
    # completion/crash event; the clock heap may hold extra dispatch
    # timers but never fewer events than executing requests
    if executing > len(engine.loop._heap):
        _fail("executing requests exceed pending clock events", where=where,
              executing=executing, pending_events=len(engine.loop._heap))
    # zombie slack: a timed-out-and-requeued request leaves its original
    # attempt holding a pool slot until that attempt's event fires
    if engine.pool.total_in_flight > executing + zombies:
        _fail("pool in-flight exceeds dispatched-but-unfinished requests",
              where=where, pool_in_flight=engine.pool.total_in_flight,
              executing=executing, zombies=zombies)


def check_fault_ledger(engine: Any, *, where: str = "") -> None:
    """Fault-injection bookkeeping invariants (DESIGN.md §15). Cheap
    unless dead letters exist; no-op on engines without a FaultPlan."""
    events = getattr(engine, "fault_events", None)
    if events is None:
        return
    for t_ms, kind, billed in events:
        if not (math.isfinite(billed) and billed >= 0.0):
            _fail("fault event billed a non-finite or negative amount",
                  where=where, t_ms=t_ms, kind=kind, billed=billed)
    dead_events = getattr(engine, "dead_letter_events", ())
    n_dead = getattr(engine, "requests_dead_lettered", 0)
    if n_dead != len(dead_events):
        _fail("dead-letter counter diverged from its event log",
              where=where, counter=n_dead, events=len(dead_events))
    if dead_events:
        completed_ids = {
            r.invocation_id for r in engine.results
            if getattr(r, "invocation_id", None) is not None}
        both = {iid for _, iid, _ in dead_events
                if iid is not None} & completed_ids
        if both:
            _fail("request both dead-lettered and completed (idempotent "
                  "re-dispatch broken)", where=where,
                  invocation_ids=sorted(both)[:5])


def attach_engine(engine: Any) -> None:
    """Arm a :class:`SubstrateEngine`: pool checks plus conservation /
    event-stream ledger around submit, dispatch (queue.pop), requeue and
    finish. Idempotent; per-instance (no global monkeypatching)."""
    if getattr(engine, "_sanitizer_armed", False):
        return
    engine._sanitizer_armed = True
    engine._sanitizer_executing = 0
    check_telemetry_readonly(engine.telemetry)
    attach_pool(engine.pool)

    queue_pop = engine.queue.pop
    queue_requeue = engine.queue.requeue
    engine_finish = engine._finish
    engine_submit = engine.submit
    engine_dead_letter = getattr(engine, "_dead_letter", None)

    def pop_wrapped(*args: Any, **kwargs: Any):
        inv = queue_pop(*args, **kwargs)
        engine._sanitizer_executing += 1
        return inv

    def requeue_wrapped(*args: Any, **kwargs: Any):
        out = queue_requeue(*args, **kwargs)
        engine._sanitizer_executing -= 1
        check_engine_conservation(engine, where="requeue")
        return out

    def finish_wrapped(*args: Any, **kwargs: Any):
        engine._sanitizer_executing -= 1
        out = engine_finish(*args, **kwargs)
        check_engine_conservation(engine, where="_finish")
        return out

    def submit_wrapped(*args: Any, **kwargs: Any):
        out = engine_submit(*args, **kwargs)
        check_engine_conservation(engine, where="submit")
        return out

    engine.queue.pop = pop_wrapped
    engine.queue.requeue = requeue_wrapped
    engine._finish = finish_wrapped
    engine.submit = submit_wrapped

    if engine_dead_letter is not None:
        def dead_letter_wrapped(*args: Any, **kwargs: Any):
            engine._sanitizer_executing -= 1
            out = engine_dead_letter(*args, **kwargs)
            check_engine_conservation(engine, where="_dead_letter")
            check_fault_ledger(engine, where="_dead_letter")
            return out

        engine._dead_letter = dead_letter_wrapped


# ---------------------------------------------------------------------------
# Open-loop + vectorized-output checks
# ---------------------------------------------------------------------------


def check_open_loop(*, n_arrived: int, n_completed: int, n_dropped: int,
                    n_pending_at_end: int, n_dead_lettered: int = 0) -> None:
    """run_open_loop conservation: everything offered either completed,
    dropped, dead-lettered, or is still parked/queued/in flight at the
    horizon. ``n_dead_lettered`` defaults to 0 (fault-free runs)."""
    if n_arrived != (n_completed + n_dropped + n_pending_at_end
                     + n_dead_lettered):
        _fail("open-loop conservation violated: arrived != completed + "
              "dropped + dead_lettered + pending_at_end", arrived=n_arrived,
              completed=n_completed, dropped=n_dropped,
              dead_lettered=n_dead_lettered,
              pending_at_end=n_pending_at_end)


def check_fleet_conservation(
    *,
    n_arrived: int,
    n_completed: int,
    n_dropped: int,
    n_pending: int,
    n_hedges: int,
    n_hedge_dropped: int,
    n_hedge_cancelled: int,
    per_fleet_arrived: tuple,
    per_fleet_completed: tuple,
    per_fleet_dropped: tuple,
    per_fleet_parked: tuple,
    n_rejected: int = 0,
    n_dead_lettered: int = 0,
    n_hedge_dead_lettered: int = 0,
    per_fleet_dead_lettered: Optional[tuple] = None,
) -> None:
    """Fleet-router conservation ledger (DESIGN.md §14, §15).

    Two levels cross-check each other. The *logical* ledger counts each
    request once regardless of hedging; the *copies* ledger sums the
    per-engine counters, where a hedged request appears twice. The copies
    identity ``Σ arrived_f == (n_arrived − n_rejected) + n_hedges`` is
    the double-dispatch detector: a router that submits a request to two
    fleets without recording a hedge inflates the left side only.
    ``n_pending`` and ``per_fleet_parked`` are tracked/measured
    independently (not residuals), so every equation is a real check.
    The resilience terms (DESIGN.md §15) default to zero, keeping the
    fault-free ledger identical to the §14 form: rejected requests (shed
    or breaker-refused) never reach an engine, and a dead-lettered
    logical request is one whose *last* live copy exhausted retries.
    """
    if n_arrived != (n_completed + n_dropped + n_rejected
                     + n_dead_lettered + n_pending):
        _fail("fleet logical conservation violated: arrived != completed "
              "+ dropped + rejected + dead_lettered + pending",
              arrived=n_arrived, completed=n_completed, dropped=n_dropped,
              rejected=n_rejected, dead_lettered=n_dead_lettered,
              pending=n_pending)
    if sum(per_fleet_arrived) != (n_arrived - n_rejected) + n_hedges:
        _fail("fleet copies conservation violated: sum(per-fleet arrived) "
              "!= submitted logical arrivals + hedges (double dispatch?)",
              per_fleet_arrived=per_fleet_arrived, arrived=n_arrived,
              rejected=n_rejected, hedges=n_hedges)
    if sum(per_fleet_completed) != n_completed + n_hedge_cancelled:
        _fail("fleet completion ledger violated: sum(per-fleet completed) "
              "!= logical completed + hedge losers",
              per_fleet_completed=per_fleet_completed,
              completed=n_completed, hedge_cancelled=n_hedge_cancelled)
    if sum(per_fleet_dropped) != n_dropped + n_hedge_dropped:
        _fail("fleet drop ledger violated: sum(per-fleet dropped) != "
              "logical dropped + hedge-copy drops",
              per_fleet_dropped=per_fleet_dropped, dropped=n_dropped,
              hedge_dropped=n_hedge_dropped)
    if per_fleet_dead_lettered is None:
        per_fleet_dead_lettered = (0,) * len(per_fleet_arrived)
    if sum(per_fleet_dead_lettered) != n_dead_lettered + n_hedge_dead_lettered:
        _fail("fleet dead-letter ledger violated: sum(per-fleet "
              "dead-lettered) != logical dead-lettered + hedge-copy "
              "dead letters",
              per_fleet_dead_lettered=per_fleet_dead_lettered,
              dead_lettered=n_dead_lettered,
              hedge_dead_lettered=n_hedge_dead_lettered)
    for i, (a, c, d, dl, p) in enumerate(zip(
            per_fleet_arrived, per_fleet_completed, per_fleet_dropped,
            per_fleet_dead_lettered, per_fleet_parked)):
        if a != c + d + dl + p:
            _fail("per-fleet conservation violated: arrived != completed "
                  "+ dropped + dead_lettered + parked", fleet=i, arrived=a,
                  completed=c, dropped=d, dead_lettered=dl, parked=p)


def check_finite(summary: dict, *, where: str = "") -> None:
    """NaN/inf guard on a vectorized-sim summary dict of arrays."""
    import numpy as np  # deferred: keep this module stdlib-importable

    for key, value in summary.items():
        arr = np.asarray(value)
        if arr.dtype.kind != "f":
            continue
        if not np.isfinite(arr).all():
            n_bad = int((~np.isfinite(arr)).sum())
            _fail("non-finite values in vectorized summary", where=where,
                  key=key, n_bad=n_bad, shape=arr.shape)


def check_open_summary(summary: dict, n_steps: int, *,
                       where: str = "") -> None:
    """Vectorized open-loop conservation per (arm, stream): every offered
    request completed, dropped, or sits parked at the horizon."""
    import numpy as np

    check_finite(summary, where=where)
    need = ("n_completed", "n_dropped", "n_parked_end")
    if not all(k in summary for k in need):
        return
    total = (np.asarray(summary["n_completed"])
             + np.asarray(summary["n_dropped"])
             + np.asarray(summary["n_parked_end"]))
    if not np.allclose(total, float(n_steps)):
        bad = np.argwhere(~np.isclose(total, float(n_steps)))
        _fail("vectorized open-loop conservation violated: completed + "
              "dropped + parked != n per stream", where=where,
              n_steps=n_steps, first_bad_index=bad[:1].tolist(),
              value=float(np.asarray(total).flat[0]))


__all__ = [
    "ENV_VAR",
    "SanitizerError",
    "attach_engine",
    "attach_pool",
    "check_engine_conservation",
    "check_fault_ledger",
    "check_finite",
    "check_fleet_conservation",
    "check_open_loop",
    "check_open_summary",
    "check_pool",
    "check_telemetry_readonly",
    "enabled",
]
