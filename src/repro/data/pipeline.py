"""Synthetic data pipelines.

* :class:`TokenStream` — deterministic synthetic LM token batches (a
  Zipf-ish unigram mixture with induced bigram structure so a model can
  actually reduce loss — used by the end-to-end training example).
* :func:`weather_dataset` — the paper's workload: synthetic weather-CSV
  rows (features -> next-day temperature with linear ground truth + noise),
  including CSV encode/parse so the serving example exercises a real
  ingest path.
"""
from __future__ import annotations

import dataclasses
import io

import numpy as np


@dataclasses.dataclass
class TokenStream:
    vocab: int
    batch: int
    seq_len: int
    seed: int = 0

    def __post_init__(self):
        rng = np.random.RandomState(self.seed)
        # bigram transition structure: each token prefers a few successors
        self._succ = rng.randint(0, self.vocab, size=(self.vocab, 4))
        base = rng.zipf(1.5, size=self.vocab * 4).astype(np.float64)
        self._unigram = base[: self.vocab] / base[: self.vocab].sum()
        self._step = 0

    def __iter__(self):
        return self

    def __next__(self) -> dict[str, np.ndarray]:
        rng = np.random.RandomState(self.seed * 1_000_003 + self._step)
        self._step += 1
        B, S = self.batch, self.seq_len
        toks = np.empty((B, S + 1), np.int32)
        toks[:, 0] = rng.randint(0, self.vocab, size=B)
        follow = rng.rand(B, S) < 0.8
        choice = rng.randint(0, 4, size=(B, S))
        randtok = rng.randint(0, self.vocab, size=(B, S))
        for t in range(S):
            nxt = self._succ[toks[:, t], choice[:, t]]
            toks[:, t + 1] = np.where(follow[:, t], nxt, randtok[:, t])
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


# ---------------------------------------------------------------------------
# Weather workload (the paper's use case)
# ---------------------------------------------------------------------------

WEATHER_COLUMNS = ("day", "temp", "humidity", "pressure", "wind", "temp_next")


def make_weather_csv(n_rows: int, seed: int = 0) -> str:
    """Synthetic weather history for one location. Ground truth:
    temp_next = 0.8*temp - 3*humidity + 0.02*pressure - 0.1*wind + noise."""
    rng = np.random.RandomState(seed)
    day = np.arange(n_rows)
    temp = 15 + 10 * np.sin(2 * np.pi * day / 365.0) + rng.normal(0, 2, n_rows)
    humidity = np.clip(rng.normal(0.6, 0.15, n_rows), 0, 1)
    pressure = rng.normal(1013, 8, n_rows)
    wind = np.abs(rng.normal(12, 6, n_rows))
    temp_next = (
        0.8 * temp - 3.0 * humidity + 0.02 * pressure - 0.1 * wind
        + rng.normal(0, 0.5, n_rows)
    )
    buf = io.StringIO()
    buf.write(",".join(WEATHER_COLUMNS) + "\n")
    for i in range(n_rows):
        buf.write(
            f"{day[i]},{temp[i]:.3f},{humidity[i]:.4f},{pressure[i]:.2f},"
            f"{wind[i]:.3f},{temp_next[i]:.3f}\n"
        )
    return buf.getvalue()


def parse_weather_csv(text: str) -> tuple[np.ndarray, np.ndarray]:
    """Returns (X (n, 4+intercept), y (n,)) feature matrix / target."""
    lines = text.strip().split("\n")
    header = lines[0].split(",")
    assert tuple(header) == WEATHER_COLUMNS, header
    rows = np.array([[float(v) for v in ln.split(",")] for ln in lines[1:]])
    X = rows[:, 1:5]
    y = rows[:, 5]
    X = np.concatenate([X, np.ones((len(X), 1))], axis=1)
    return X, y


def linear_regression(X: np.ndarray, y: np.ndarray) -> np.ndarray:
    """Closed-form normal-equation solve (the paper's 'analysis' step).
    Done in JAX in examples/weather_workflow.py; numpy here for the
    pipeline unit tests."""
    return np.linalg.lstsq(X, y, rcond=None)[0]
