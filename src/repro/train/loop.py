"""Training loop: jit'd train_step factory + a simple host loop with
checkpointing. Used by examples/train_lm.py and the per-arch smoke tests;
the same ``make_train_step`` output is what launch/dryrun.py lowers on the
production mesh.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.model import Model, build_model
from repro.optim.adamw import AdamW, AdamWState
from repro.optim.schedule import warmup_cosine


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    remat: bool = True


def make_optimizer(tc: TrainConfig) -> AdamW:
    return AdamW(
        learning_rate=warmup_cosine(tc.peak_lr, tc.warmup_steps, tc.total_steps),
        weight_decay=tc.weight_decay,
        clip_norm=tc.clip_norm,
    )


def make_train_step(model: Model, opt: AdamW, *, remat: bool = True) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def train_step(params, opt_state: AdamWState, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: model.loss(p, batch, remat=remat), has_aux=True
        )(params)
        params, opt_state, opt_metrics = opt.update(grads, opt_state, params)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def train(
    cfg: ArchConfig,
    data_iter,
    tc: TrainConfig,
    *,
    steps: int,
    seed: int = 0,
    log_every: int = 10,
    log_fn: Optional[Callable[[int, dict], None]] = None,
) -> tuple[Any, list[dict]]:
    """Host-side loop (single device). Returns (params, history)."""
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    opt = make_optimizer(tc)
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(model, opt, remat=tc.remat))
    history = []
    t0 = time.perf_counter()
    for step in range(steps):
        batch = {k: jnp.asarray(v) for k, v in next(data_iter).items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % log_every == 0 or step == steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = step
            m["wall_s"] = time.perf_counter() - t0
            history.append(m)
            if log_fn:
                log_fn(step, m)
    return params, history
