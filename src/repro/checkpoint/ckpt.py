"""Checkpointing: pytree <-> .npz with structure manifest; works for params
and optimizer state (any pytree of arrays + scalars). Multi-host sharded
save would add per-shard files keyed by process index — single-process here,
the manifest already records the intended PartitionSpec per leaf so restore
can re-shard.
"""
from __future__ import annotations

import json
import pathlib
from typing import Any

import jax
import numpy as np


def _flatten_with_paths(tree) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        out.append((key, leaf))
    return out


def save(path: str | pathlib.Path, tree, *, shardings: dict[str, str] | None = None) -> None:
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    leaves = _flatten_with_paths(tree)
    arrays = {}
    dtypes = []
    for i, (_, leaf) in enumerate(leaves):
        dt = str(getattr(leaf, "dtype", np.asarray(leaf).dtype))
        dtypes.append(dt)
        if dt == "bfloat16":  # numpy has no bf16: store as f32, cast on restore
            import jax.numpy as jnp

            arrays[f"arr_{i}"] = np.asarray(jnp.asarray(leaf, jnp.float32))
        else:
            arrays[f"arr_{i}"] = np.asarray(leaf)
    manifest = {
        "keys": [k for k, _ in leaves],
        "dtypes": dtypes,
        "shardings": shardings or {},
    }
    np.savez(path, __manifest__=json.dumps(manifest), **arrays)


def restore(path: str | pathlib.Path, like):
    """Restore into the structure of ``like`` (a template pytree)."""
    path = pathlib.Path(path)
    with np.load(path, allow_pickle=False) as data:
        manifest = json.loads(str(data["__manifest__"]))
        keys = manifest["keys"]
        dtypes = manifest.get("dtypes", [None] * len(keys))
        arrays = []
        for i in range(len(keys)):
            a = data[f"arr_{i}"]
            if dtypes[i] == "bfloat16":
                import jax.numpy as jnp

                a = jnp.asarray(a, jnp.bfloat16)
            arrays.append(a)
    template = _flatten_with_paths(like)
    by_key = dict(zip(keys, arrays))
    missing = [k for k, _ in template if k not in by_key]
    if missing:
        raise KeyError(f"checkpoint missing keys: {missing[:5]}...")
    leaves = [by_key[k] for k, _ in template]
    treedef = jax.tree_util.tree_structure(like)
    return jax.tree_util.tree_unflatten(treedef, leaves)
