"""phi3-mini-3.8b [dense] — RoPE SwiGLU GQA [arXiv:2404.14219]."""
from .base import ArchConfig, smoke_variant

CONFIG = ArchConfig(
    arch_id="phi3-mini-3.8b", family="dense",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32,
    head_dim=96, d_ff=8192, vocab=32064,
    rope_theta=10_000.0,
    source="arXiv:2404.14219",
)

def smoke():
    return smoke_variant(CONFIG)
