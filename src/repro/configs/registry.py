"""Architecture registry: ``--arch <id>`` resolution for launchers/tests."""
from __future__ import annotations

import importlib

from .base import ArchConfig, smoke_variant

_MODULES = {
    "llama3.2-1b": "llama3_2_1b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "xlstm-1.3b": "xlstm_1_3b",
    "phi3-mini-3.8b": "phi3_mini_3_8b",
    "zamba2-1.2b": "zamba2_1_2b",
    "whisper-small": "whisper_small",
    "qwen3-0.6b": "qwen3_0_6b",
    "chameleon-34b": "chameleon_34b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "mistral-large-123b": "mistral_large_123b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.CONFIG


def get_smoke_config(arch_id: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.smoke()


def all_configs() -> dict[str, ArchConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
