"""whisper-small [audio] — enc-dec, conv frontend stubbed [arXiv:2212.04356].
n_layers is the decoder depth; the encoder has the same depth."""
from .base import ArchConfig, smoke_variant

CONFIG = ArchConfig(
    arch_id="whisper-small", family="encdec",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
    head_dim=64, d_ff=3072, vocab=51865,
    n_encoder_layers=12, encoder_frames=1500,
    rope_theta=10_000.0,
    source="arXiv:2212.04356",
)

def smoke():
    return smoke_variant(CONFIG)
