"""granite-moe-1b-a400m [moe] — 32 experts top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base]."""
from .base import ArchConfig, MoEConfig, smoke_variant

CONFIG = ArchConfig(
    arch_id="granite-moe-1b-a400m", family="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8,
    head_dim=64, d_ff=512, vocab=49155,
    rope_theta=10_000.0, tie_embeddings=True,
    moe=MoEConfig(n_experts=32, top_k=8, n_shared=0, d_expert=512),
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
)

def smoke():
    return smoke_variant(CONFIG)
