"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention block
[arXiv:2411.15242]. Shared attention is sliding-window so the model stays
sub-quadratic for long_500k."""
from .base import ArchConfig, SSMConfig, smoke_variant

CONFIG = ArchConfig(
    arch_id="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
    head_dim=64, d_ff=8192, vocab=32000,
    hybrid_attn_every=6,
    sliding_window=4096,
    ssm=SSMConfig(kind="mamba2", d_state=64, d_conv=4, expand=2, chunk=256),
    source="arXiv:2411.15242",
)

def smoke():
    return smoke_variant(CONFIG)
