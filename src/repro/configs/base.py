"""Architecture config schema. One file per assigned architecture lives in
this package; each exports ``CONFIG`` (the exact assigned spec) and the
family-preserving reduced ``smoke()`` variant used by CPU smoke tests."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    n_shared: int = 0            # always-on shared experts (deepseek-moe)
    d_expert: int = 0            # per-expert FFN hidden size
    capacity_factor: float = 1.25
    router_z_weight: float = 1e-3
    load_balance_weight: float = 1e-2


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    kind: str = "mamba2"         # "mamba2" | "mlstm" | "slstm"
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    chunk: int = 256             # chunked-scan block length


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    arch_id: str
    family: str                  # dense | moe | xlstm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 -> d_model // n_heads
    qk_norm: bool = False        # qwen3 / chameleon style
    rope_theta: float = 500_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (zamba2): one attention block shared across the depth, applied
    # every `hybrid_attn_every` SSM blocks
    hybrid_attn_every: int = 0
    # xlstm: an sLSTM block every `slstm_every` layers (rest mLSTM)
    slstm_every: int = 0
    # encoder-decoder (whisper): n_layers is the decoder depth
    n_encoder_layers: int = 0
    encoder_frames: int = 1500   # stub conv frontend output length
    # sliding-window attention (enables long_500k for dense archs)
    sliding_window: Optional[int] = None
    dtype: str = "bfloat16"
    # citation for the assigned config
    source: str = ""

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def jax_dtype(self):
        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[self.dtype]

    @property
    def is_decode_capable(self) -> bool:
        return True  # every assigned arch has a decoder

    def supports_long_context(self) -> bool:
        """Sub-quadratic path available (SSM/hybrid native, dense via
        sliding window)."""
        if self.family in ("xlstm", "hybrid"):
            return True
        return self.sliding_window is not None

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Total parameters (used for 6·N·D roofline MODEL_FLOPS)."""
        d, hd = self.d_model, self.head_dim
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        att = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
        per = att + 2 * d  # norms
        if self.moe is not None:
            routed = self.moe.n_experts * 3 * d * self.moe.d_expert
            shared = self.moe.n_shared * 3 * d * self.moe.d_expert
            router = d * self.moe.n_experts
            per += routed + shared + router
        elif self.family == "xlstm":
            ex = 2 * d  # expand factor 2 internal dim
            n_sl = self.n_layers // self.slstm_every if self.slstm_every else 0
            n_ml = self.n_layers - n_sl
            P = ex // max(self.n_heads, 1)
            per_ml = d * 2 * ex + 3 * self.n_heads * P * P + 2 * ex * self.n_heads + ex * d + 3 * d
            per_sl = 4 * d * d + 4 * d * (d // max(self.n_heads, 1)) + d * d + 3 * d
            total = emb + n_ml * per_ml + n_sl * per_sl
            return int(total)
        elif self.family == "hybrid" and self.ssm is not None:
            di = self.ssm.expand * d
            N = self.ssm.d_state
            H = di // N
            per = (
                d * (2 * di + 2 * N + H)          # in_proj
                + self.ssm.d_conv * (di + 2 * N)  # conv
                + di * d                          # out_proj
                + di + 3 * d                      # norms
            )
            total = emb + self.n_layers * per
            if self.hybrid_attn_every:
                total += att + 3 * d * self.d_ff + 2 * d  # one shared block
            return int(total)
        elif self.d_ff:
            per += 3 * d * self.d_ff  # SwiGLU
        total = emb + self.n_layers * per
        if self.n_encoder_layers:
            total += self.n_encoder_layers * (att + 2 * d + 3 * d * self.d_ff)
            total += self.n_layers * (att + d)  # decoder cross-attention
        return int(total)

    def active_param_count(self) -> int:
        """Activated parameters per token (MoE: only top-k experts)."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        routed_all = self.n_layers * self.moe.n_experts * 3 * d * self.moe.d_expert
        routed_act = self.n_layers * self.moe.top_k * 3 * d * self.moe.d_expert
        return self.param_count() - routed_all + routed_act


def smoke_variant(cfg: ArchConfig, **overrides) -> ArchConfig:
    """Family-preserving reduced config: 2 layers, d_model<=512, <=4 experts."""
    d = min(cfg.d_model, 256)
    heads = min(cfg.n_heads, 4)
    kv = min(cfg.n_kv_heads, max(1, heads // 2))
    while heads % kv:
        kv -= 1
    changes = dict(
        n_layers=2,
        d_model=d,
        n_heads=heads,
        n_kv_heads=kv,
        head_dim=d // heads,
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab=min(cfg.vocab, 512),
        dtype="float32",
    )
    if cfg.moe is not None:
        changes["moe"] = dataclasses.replace(
            cfg.moe,
            n_experts=min(cfg.moe.n_experts, 4),
            top_k=min(cfg.moe.top_k, 2),
            n_shared=min(cfg.moe.n_shared, 1),
            d_expert=min(cfg.moe.d_expert, 128),
        )
    if cfg.ssm is not None:
        changes["ssm"] = dataclasses.replace(cfg.ssm, d_state=16, chunk=32)
    if cfg.n_encoder_layers:
        changes["n_encoder_layers"] = 2
        changes["encoder_frames"] = 64
    if cfg.slstm_every:
        changes["slstm_every"] = 2
    if cfg.hybrid_attn_every:
        changes["hybrid_attn_every"] = 2
    if cfg.sliding_window:
        changes["sliding_window"] = min(cfg.sliding_window, 64)
    changes.update(overrides)
    return dataclasses.replace(cfg, **changes)
