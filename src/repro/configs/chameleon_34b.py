"""chameleon-34b [vlm] — early-fusion, VQ image tokens [arXiv:2405.09818].
Image tokens are ordinary ids inside the 65536 vocab (early fusion); the
VQ-GAN tokenizer is the permitted stub. qk-norm per the paper."""
from .base import ArchConfig, smoke_variant

CONFIG = ArchConfig(
    arch_id="chameleon-34b", family="vlm",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8,
    head_dim=128, d_ff=22016, vocab=65536,
    qk_norm=True, rope_theta=10_000.0,
    source="arXiv:2405.09818",
)

def smoke():
    return smoke_variant(CONFIG)
