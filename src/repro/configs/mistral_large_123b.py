"""mistral-large-123b [dense] [hf:mistralai/Mistral-Large-Instruct-2407]."""
from .base import ArchConfig, smoke_variant

CONFIG = ArchConfig(
    arch_id="mistral-large-123b", family="dense",
    n_layers=88, d_model=12288, n_heads=96, n_kv_heads=8,
    head_dim=128, d_ff=28672, vocab=32768,
    rope_theta=1_000_000.0,
    source="hf:mistralai/Mistral-Large-Instruct-2407",
)

def smoke():
    return smoke_variant(CONFIG)
