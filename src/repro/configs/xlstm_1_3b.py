"""xlstm-1.3b [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517]."""
from .base import ArchConfig, SSMConfig, smoke_variant

CONFIG = ArchConfig(
    arch_id="xlstm-1.3b", family="xlstm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
    head_dim=512, d_ff=0, vocab=50304,
    slstm_every=8,                      # 7x mLSTM + 1x sLSTM per group
    ssm=SSMConfig(kind="mlstm", chunk=256),
    source="arXiv:2405.04517",
)

def smoke():
    return smoke_variant(CONFIG)
