"""The fleet meta-scheduler: N Minos-gated fleets, one clock, one stream
(DESIGN.md §14; ROADMAP: fleet-level meta-scheduler).

Per-instance selection (the paper's gate) composes with cross-platform
selection: each fleet is a full :class:`~repro.sim.platform.FaaSPlatform`
— its own profile, knobs, warm pool, controller and RNG — and the
:class:`FleetRouter` owns the one :class:`~repro.core.substrate.SimClock`
they all share, so fleet timelines interleave exactly. Every arrival is
routed by a pluggable :class:`~repro.fleet.policies.RoutingPolicy` fed a
read-only :class:`~repro.core.control.FleetTelemetry`; the router alone
performs submits, hedges and the conservation bookkeeping.

Hedging (``hedge_after_ms``): a request still incomplete after that long
is duplicated onto a second fleet (the policy re-routes with the primary
excluded), first completion wins. The loser runs to completion and its
cost is billed by whichever engine served it — there is no free
cancellation; ``count_hedge_waste=False`` is the *idealized* view that
subtracts the measured loser cost (``hedge_waste_cost``) from
``total_cost``, kept as an explicit flag so the honest accounting is the
default.

Conservation (sanitizer ``check_fleet_conservation``, armed under
``REPRO_SANITIZE=1`` at the end of :func:`run_fleet_open_loop`)::

    Σ_f arrived_f   == (n_arrived - n_rejected) + n_hedges  (copies enter once)
    Σ_f completed_f == n_completed + n_hedge_cancelled
    Σ_f dropped_f   == n_dropped + n_hedge_dropped
    Σ_f dead_f      == n_dead_lettered + n_hedge_dead_lettered
    arrived_f       == completed_f + dropped_f + dead_f + parked_f  (per fleet)
    n_arrived       == n_completed + n_dropped + n_rejected
                       + n_dead_lettered + n_pending               (logical)

Failure resilience (DESIGN.md §15): with a ``breaker``
(:class:`~repro.fleet.resilience.BreakerConfig`), each fleet gets a
circuit breaker fed by its engine's per-attempt fault stream (and by
queue-full submit refusals); routing to a tripped fleet fails over
through the policy's ``exclude`` re-route, then a deterministic
first-allowing scan; when every breaker rejects, the request is
*rejected* at the router (``n_rejected``) — never submitted anywhere.
``shed_when_degraded`` additionally sheds the lowest-priority QoS
classes (one priority level per OPEN breaker, the top level never sheds)
— graceful degradation. A request whose every submitted copy
dead-letters inside its engine closes as ``n_dead_lettered``.

Deliberate omissions (documented in DESIGN.md §14): the router does not
run the per-engine admission-deferral layer (arrivals queue inside the
chosen fleet; a finite ``queue_capacity`` drop is a logical drop, not a
re-route), and a hedge is attempted at most once per request.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, List, Optional, Sequence

import numpy as np

from repro.analysis import sanitizer as _sanitizer
from repro.core.control import FleetTelemetry
from repro.core.cost import Pricing
from repro.core.substrate import RequestResult, SimClock, SubstrateKnobs
from repro.sim.arrivals import (
    ArrivalProcess,
    QoSClass,
    arrival_times_ms,
    draw_classes,
)
from repro.sim.platform import FaaSPlatform, FunctionSpec, PlatformProfile
from repro.sim.variation import VariationModel

from .policies import RouteContext, RoutingPolicy
from .resilience import BreakerConfig, BreakerState, CircuitBreaker


@dataclasses.dataclass(frozen=True)
class FleetSpec:
    """One fleet's full configuration. ``policy`` is the classic Minos
    gate stack; ``controller_factory`` builds a fresh
    :class:`~repro.core.control.Controller` per engine (controllers are
    stateful — sharing one across fleets would bleed estimates). Exactly
    one of the two must be provided.

    ``fault_plan_factory`` builds a fresh
    :class:`~repro.faults.FaultPlan` per engine (plans hold a private RNG
    stream — sharing one would entangle fleets) from the engine's derived
    seed; ``recovery`` is a frozen :class:`~repro.faults.RecoveryPolicy`
    and may be shared."""

    name: str
    spec: FunctionSpec
    variation: VariationModel
    profile: Optional[PlatformProfile] = None
    knobs: Optional[SubstrateKnobs] = None
    policy: Any = None
    controller_factory: Optional[Callable[[], Any]] = None
    pricing: Optional[Pricing] = None
    fault_plan_factory: Optional[Callable[[int], Any]] = None
    recovery: Any = None

    def build(self, *, seed: int, clock: SimClock) -> FaaSPlatform:
        controller = (self.controller_factory()
                      if self.controller_factory is not None else None)
        if (controller is None) == (self.policy is None):
            raise ValueError(
                f"fleet {self.name!r} needs exactly one of policy / "
                f"controller_factory")
        fault_plan = (self.fault_plan_factory(seed)
                      if self.fault_plan_factory is not None else None)
        return FaaSPlatform(
            self.spec, self.variation,
            self.policy if controller is None else None,
            pricing=self.pricing, seed=seed, profile=self.profile,
            controller=controller, knobs=self.knobs, clock=clock,
            fault_plan=fault_plan, recovery=self.recovery,
        )


class _FleetRequest:
    """One logical request's live state across its (1 or 2) copies."""

    __slots__ = ("arrival_ms", "qos", "qos_weight", "payload",
                 "primary_fleet", "hedge_fleet", "done", "live_copies")

    def __init__(self, arrival_ms: float, qos: str, qos_weight: float,
                 payload: Any, primary_fleet: int) -> None:
        self.arrival_ms = arrival_ms
        self.qos = qos
        self.qos_weight = qos_weight
        self.payload = payload
        self.primary_fleet = primary_fleet
        self.hedge_fleet: Optional[int] = None
        self.done = False
        self.live_copies = 0  # submitted copies not yet dead-lettered


class FleetRouter:
    """Owns the fleets, the shared clock, the routing policy and the
    request/hedge ledgers. Per-fleet engine seeds derive from ``seed`` so
    one integer reproduces the whole fleet run."""

    def __init__(
        self,
        fleets: Sequence[FleetSpec],
        policy: RoutingPolicy,
        *,
        seed: int = 0,
        hedge_after_ms: Optional[float] = None,
        count_hedge_waste: bool = True,
        breaker: Optional[BreakerConfig] = None,
        shed_when_degraded: bool = False,
        qos_priorities: Optional[dict[str, int]] = None,
    ) -> None:
        """``breaker`` arms one :class:`CircuitBreaker` per fleet, fed by
        the engine's per-attempt fault stream (crashes, cold-start
        failures, probe timeouts, lost completions, request timeouts) and
        by queue-full submit refusals. ``shed_when_degraded`` sheds the
        lowest-priority QoS classes while breakers are OPEN (one priority
        level per OPEN breaker; the highest level never sheds);
        ``qos_priorities`` maps class name → priority (higher = more
        important; unknown classes rank lowest)."""
        fleets = tuple(fleets)
        if not fleets:
            raise ValueError("need at least one FleetSpec")
        names = [f.name for f in fleets]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate fleet names: {names}")
        if hedge_after_ms is not None and hedge_after_ms <= 0.0:
            raise ValueError("hedge_after_ms must be > 0")
        if shed_when_degraded and breaker is None:
            raise ValueError(
                "shed_when_degraded needs a breaker config (shedding is "
                "keyed on OPEN breakers)")
        self.clock = SimClock()
        self.fleets = fleets
        self.policy = policy
        self.rng = np.random.RandomState(seed)  # routing draws only
        self.engines = tuple(
            f.build(seed=seed * 7919 + 101 * i + 1, clock=self.clock)
            for i, f in enumerate(fleets))
        self.telemetry = FleetTelemetry(
            (e.telemetry for e in self.engines), names)
        self.hedge_after_ms = hedge_after_ms
        self.count_hedge_waste = count_hedge_waste
        # -- failure resilience (DESIGN.md §15) --------------------------
        self.shed_when_degraded = shed_when_degraded
        self.qos_priorities = dict(qos_priorities or {})
        self.breakers: Optional[tuple[CircuitBreaker, ...]] = None
        if breaker is not None:
            self.breakers = tuple(CircuitBreaker(breaker) for _ in fleets)
            for i, e in enumerate(self.engines):
                e.fault_listener = (
                    lambda kind, inv, i=i: self._on_engine_fault(i, kind))
        # -- logical ledger (one entry per arrival) ----------------------
        self.n_arrived = 0
        self.n_dropped = 0          # primary copy refused at the fleet queue
        self._open_logical = 0      # submitted, neither won nor dropped
        self.n_rejected = 0         # never submitted: shed + breaker-rejected
        self.n_shed = 0             # rejected by QoS degradation
        self.n_breaker_rejected = 0  # rejected with every breaker open
        self.shed_by_class: dict[str, int] = {}
        self.n_dead_lettered = 0    # logical requests whose copies all died
        # -- hedge ledger (secondary copies) -----------------------------
        self.n_hedges = 0           # hedge submits attempted
        self.n_hedge_dropped = 0    # hedge copies refused at the queue
        self.n_hedge_wins = 0       # logical wins served by the hedge copy
        self.n_hedge_cancelled = 0  # loser copies that ran to completion
        self.n_hedge_dead_lettered = 0  # surplus copy dead-letters
        self.hedge_waste_cost = 0.0
        # -- winner results (exactly one per completed logical request) --
        self.results: List[RequestResult] = []
        self.result_fleets: List[int] = []
        self.result_classes: List[str] = []

    # ------------------------------------------------------------------
    @property
    def n_completed(self) -> int:
        return len(self.results)

    @property
    def total_cost(self) -> float:
        """Σ engine cost — honest by default: hedge losers stay billed.
        ``count_hedge_waste=False`` subtracts the measured loser cost
        (the idealized cancel-on-win accounting)."""
        total = sum(e.cost.total for e in self.engines)
        if not self.count_hedge_waste:
            total -= self.hedge_waste_cost
        return total

    def _route(self, arrival_ms: float, qos: str,
               exclude: Optional[int] = None) -> int:
        idx = int(self.policy.route(RouteContext(
            telemetry=self.telemetry, rng=self.rng,
            arrival_ms=arrival_ms, qos=qos, exclude=exclude)))
        if not 0 <= idx < len(self.engines):
            raise ValueError(
                f"policy {self.policy.name!r} routed to fleet {idx} "
                f"of {len(self.engines)}")
        return idx

    # -- failure resilience (DESIGN.md §15) ----------------------------
    def _on_engine_fault(self, fleet_idx: int, kind: str) -> None:
        """Per-attempt fault feed from engine ``fleet_idx`` (the engine's
        ``fault_listener`` hook; gate terminations never fire it)."""
        if self.breakers is not None:
            self.breakers[fleet_idx].record_failure(self.clock.now)

    def _should_shed(self, qos: str) -> bool:
        """Graceful degradation: with k breakers OPEN, shed the k lowest
        of the configured priority levels (the top level never sheds)."""
        if not self.shed_when_degraded or self.breakers is None:
            return False
        n_open = sum(1 for b in self.breakers
                     if b.state is BreakerState.OPEN)
        if n_open == 0:
            return False
        levels = sorted(set(self.qos_priorities.values()))
        if len(levels) < 2:
            return False  # one class of traffic: nothing lower to shed
        shed_levels = set(levels[:min(n_open, len(levels) - 1)])
        return self.qos_priorities.get(qos, levels[0]) in shed_levels

    def _route_resilient(self, arrival_ms: float, qos: str,
                         exclude: Optional[int] = None) -> Optional[int]:
        """Policy route + breaker gating: fail over through the policy's
        ``exclude`` re-route, then a deterministic first-allowing scan;
        None when every breaker rejects."""
        if self.breakers is None:
            return self._route(arrival_ms, qos, exclude=exclude)
        now = self.clock.now
        idx = self._route(arrival_ms, qos, exclude=exclude)
        if self.breakers[idx].allow(now):
            self.breakers[idx].on_route(now)
            return idx
        if exclude is None and len(self.engines) > 1:
            alt = self._route(arrival_ms, qos, exclude=idx)
            if alt != idx and self.breakers[alt].allow(now):
                self.breakers[alt].on_route(now)
                return alt
        for j in range(len(self.engines)):
            if j != exclude and self.breakers[j].allow(now):
                self.breakers[j].on_route(now)
                return j
        return None

    def offer(self, payload: Any, qos: str = "default",
              qos_weight: float = 1.0) -> None:
        """Route and submit one arrival at the current clock time."""
        now = self.clock.now
        self.n_arrived += 1
        if self._should_shed(qos):
            self.n_rejected += 1
            self.n_shed += 1
            self.shed_by_class[qos] = self.shed_by_class.get(qos, 0) + 1
            return
        idx = self._route_resilient(now, qos)
        if idx is None:
            # every fleet's breaker rejects: fail fast, never submitted
            self.n_rejected += 1
            self.n_breaker_rejected += 1
            return
        req = _FleetRequest(now, qos, qos_weight, payload, idx)
        ok = self.engines[idx].submit(
            payload,
            lambda res, req=req, i=idx: self._complete(req, i, res),
            submitted_at_ms=now, qos=qos, qos_weight=qos_weight,
            on_dead_letter=lambda inv, req=req, i=idx:
                self._copy_dead(req, i))
        if not ok:
            # finite fleet queue refused the primary copy — a logical drop
            # (deliberate omission: no re-route; DESIGN.md §14). An
            # overloaded/throttled fleet is a health signal the breaker
            # should see.
            self.n_dropped += 1
            if self.breakers is not None:
                self.breakers[idx].record_failure(now)
            return
        self._open_logical += 1
        req.live_copies = 1
        if self.hedge_after_ms is not None and len(self.engines) > 1:
            self.clock.after(self.hedge_after_ms,
                             lambda req=req: self._maybe_hedge(req))

    def _maybe_hedge(self, req: _FleetRequest) -> None:
        if req.done or req.hedge_fleet is not None:
            return
        idx = self._route_resilient(
            self.clock.now, req.qos, exclude=req.primary_fleet)
        if idx is None or idx == req.primary_fleet:
            return  # the policy declined to diversify (or breakers reject)
        self.n_hedges += 1
        ok = self.engines[idx].submit(
            req.payload,
            lambda res, req=req, i=idx: self._complete(req, i, res),
            submitted_at_ms=req.arrival_ms, qos=req.qos,
            qos_weight=req.qos_weight,
            on_dead_letter=lambda inv, req=req, i=idx:
                self._copy_dead(req, i))
        if not ok:
            self.n_hedge_dropped += 1
            if self.breakers is not None:
                self.breakers[idx].record_failure(self.clock.now)
            return
        req.hedge_fleet = idx
        req.live_copies += 1

    def _copy_dead(self, req: _FleetRequest, fleet_idx: int) -> None:
        """One submitted copy dead-lettered inside engine ``fleet_idx``.
        The logical request closes only when its LAST live copy dies —
        a hedge twin may still win (first-completion-wins unchanged)."""
        req.live_copies -= 1
        if req.done:
            # the logical request already completed; this was the loser
            self.n_hedge_dead_lettered += 1
            return
        if req.live_copies <= 0:
            req.done = True
            self._open_logical -= 1
            self.n_dead_lettered += 1
        else:
            self.n_hedge_dead_lettered += 1

    def _complete(self, req: _FleetRequest, fleet_idx: int,
                  res: RequestResult) -> None:
        if not req.done:
            # first copy home wins: counted exactly once in latency
            req.done = True
            self._open_logical -= 1
            if fleet_idx == req.hedge_fleet:
                self.n_hedge_wins += 1
            self.results.append(res)
            self.result_fleets.append(fleet_idx)
            self.result_classes.append(req.qos)
        else:
            # the losing copy: latency discarded, cost already billed by
            # the engine that served it — record the waste explicitly
            self.n_hedge_cancelled += 1
            pricing = self.engines[fleet_idx].pricing
            self.hedge_waste_cost += (
                pricing.cost_per_invocation
                + pricing.cost_per_ms * (res.download_ms + res.analysis_ms))
        if self.breakers is not None:
            # winner or loser, the ENGINE served it: a health success
            self.breakers[fleet_idx].record_success(self.clock.now)
        self.policy.on_result(fleet_idx, res, self.telemetry)

    # ------------------------------------------------------------------
    def per_fleet_counts(self) -> dict[str, tuple]:
        """The copies-level ledger the conservation check consumes.
        ``parked`` is measured (queue + in flight), not a residual."""
        return {
            "per_fleet_arrived": tuple(
                e.requests_arrived for e in self.engines),
            "per_fleet_completed": tuple(
                len(e.results) for e in self.engines),
            "per_fleet_dropped": tuple(
                e.requests_dropped for e in self.engines),
            "per_fleet_dead_lettered": tuple(
                e.requests_dead_lettered for e in self.engines),
            "per_fleet_parked": tuple(
                len(e.queue) + e.pool.total_in_flight
                - e._zombie_executions
                for e in self.engines),
        }

    def check_conservation(self) -> None:
        """Cross-check every ledger (raises SanitizerError on violation);
        callable unconditionally — run_fleet_open_loop invokes it when
        the sanitizer env gate is armed."""
        _sanitizer.check_fleet_conservation(
            n_arrived=self.n_arrived,
            n_completed=self.n_completed,
            n_dropped=self.n_dropped,
            n_pending=self._open_logical,
            n_hedges=self.n_hedges,
            n_hedge_dropped=self.n_hedge_dropped,
            n_hedge_cancelled=self.n_hedge_cancelled,
            n_rejected=self.n_rejected,
            n_dead_lettered=self.n_dead_lettered,
            n_hedge_dead_lettered=self.n_hedge_dead_lettered,
            **self.per_fleet_counts(),
        )
        for e in self.engines:
            _sanitizer.check_fault_ledger(e, where="fleet")


@dataclasses.dataclass
class FleetRunResult:
    """One fleet run: winner-level results plus both ledgers."""

    results: List[RequestResult]
    result_fleets: List[int]
    result_classes: List[str]
    n_arrived: int
    n_dropped: int
    n_pending_at_end: int
    n_hedges: int
    n_hedge_dropped: int
    n_hedge_wins: int
    n_hedge_cancelled: int
    hedge_waste_cost: float
    total_cost: float
    duration_ms: float
    process_name: str
    fleet_names: tuple[str, ...]
    per_fleet: dict[str, tuple]
    # -- failure resilience (DESIGN.md §15); zeros when no faults armed --
    n_rejected: int = 0          # shed or breaker-rejected (never submitted)
    n_shed: int = 0
    n_breaker_rejected: int = 0
    n_dead_lettered: int = 0     # logical requests whose last copy died
    n_hedge_dead_lettered: int = 0
    breaker_opens: tuple[int, ...] = ()
    shed_by_class: dict[str, int] = dataclasses.field(default_factory=dict)

    @property
    def n_completed(self) -> int:
        return len(self.results)

    @property
    def drop_rate(self) -> float:
        return self.n_dropped / max(self.n_arrived, 1)


def run_fleet_open_loop(
    router: FleetRouter,
    process: ArrivalProcess,
    *,
    rng: np.random.RandomState,
    duration_ms: float,
    qos_classes: Optional[Sequence[QoSClass]] = None,
    payload_fn: Optional[Callable[[int, str], Any]] = None,
    drain: bool = True,
    drain_limit_ms: Optional[float] = None,
) -> FleetRunResult:
    """Drive the router's fleets with one open-loop arrival stream.

    Arrival times and QoS class draws come from ``rng`` (the traffic
    realization); routing randomness comes from the router's own seeded
    RNG — so the same traffic can be replayed against different policies.
    With ``drain`` the clock runs past the horizon until in-flight work
    finishes (``drain_limit_ms`` bounds a backlog that cannot drain).
    """
    if duration_ms <= 0.0:
        raise ValueError("duration_ms must be > 0")
    times = arrival_times_ms(process, rng, duration_ms)
    if qos_classes:
        cls_idx = draw_classes(rng, len(times), qos_classes)
        names = [qos_classes[i].name for i in cls_idx]
        weights = [qos_classes[i].weight for i in cls_idx]
    else:
        names = ["default"] * len(times)
        weights = [1.0] * len(times)

    for i, (t, qos, w) in enumerate(zip(times, names, weights)):
        payload = payload_fn(i, qos) if payload_fn is not None else {"qos": qos}
        router.clock.at(
            float(t),
            lambda payload=payload, qos=qos, w=w:
                router.offer(payload, qos=qos, qos_weight=w))

    router.clock.run_until(duration_ms)
    if drain:
        limit = (duration_ms + 20 * 60 * 1000.0
                 if drain_limit_ms is None else duration_ms + drain_limit_ms)
        router.clock.run_all(hard_limit_ms=limit)

    if _sanitizer.enabled():
        router.check_conservation()

    return FleetRunResult(
        results=list(router.results),
        result_fleets=list(router.result_fleets),
        result_classes=list(router.result_classes),
        n_arrived=router.n_arrived,
        n_dropped=router.n_dropped,
        n_pending_at_end=router._open_logical,
        n_hedges=router.n_hedges,
        n_hedge_dropped=router.n_hedge_dropped,
        n_hedge_wins=router.n_hedge_wins,
        n_hedge_cancelled=router.n_hedge_cancelled,
        hedge_waste_cost=router.hedge_waste_cost,
        total_cost=router.total_cost,
        duration_ms=duration_ms,
        process_name=process.name,
        fleet_names=router.telemetry.names,
        per_fleet=router.per_fleet_counts(),
        n_rejected=router.n_rejected,
        n_shed=router.n_shed,
        n_breaker_rejected=router.n_breaker_rejected,
        n_dead_lettered=router.n_dead_lettered,
        n_hedge_dead_lettered=router.n_hedge_dead_lettered,
        breaker_opens=(tuple(b.n_opens for b in router.breakers)
                       if router.breakers is not None else ()),
        shed_by_class=dict(router.shed_by_class),
    )


__all__ = [
    "FleetRouter",
    "FleetRunResult",
    "FleetSpec",
    "run_fleet_open_loop",
]
