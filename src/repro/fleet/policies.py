"""Fleet routing policies: which Minos-gated fleet serves this request
(DESIGN.md §14).

The :class:`~repro.fleet.router.FleetRouter` owns N engines and one
request stream; every arrival (and every hedge attempt) flows through a
:class:`RoutingPolicy` — the faas-offloading-sim policy ladder (SNIPPETS
§2) lifted onto per-fleet :class:`~repro.core.control.FleetTelemetry`:

* :class:`RandomRoutingPolicy` — uniform over fleets (the floor);
* :class:`WeightedStaticRoutingPolicy` — fixed split probabilities; a
  one-hot weight vector is the static single-fleet baseline;
* :class:`GreedyRoutingPolicy` — argmin expected response time from live
  telemetry (queue depth, capacity slots, Welford body means, cold-start
  penalty for an empty pool);
* :class:`ProbabilisticRoutingPolicy` — per-fleet split probabilities
  re-solved every ``update_interval_ms`` from an EMA-tracked arrival rate
  and per-fleet certified-speed quantiles / unit-speed body estimates; the
  split LP runs via scipy when available, with a closed-form waterfilling
  fallback (:func:`solve_split`) that provably coincides with it.

Routing policies obey the same purity contract as controllers (analysis
rule R3, extended to ``*RoutingPolicy`` classes): they read the
:class:`~repro.core.control.FleetTelemetry` view and return a fleet
index; submits, hedges, billing and every other side effect stay with the
router. A policy never stores the telemetry view — it arrives on each
:class:`RouteContext`.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Protocol, Sequence, runtime_checkable

import numpy as np

from repro.core.control import FleetTelemetry
from repro.core.estimators import EMA, Welford

try:  # optional dependency: never required, only preferred (DESIGN.md §14)
    from scipy.optimize import linprog as _linprog
except ImportError:  # pragma: no cover - scipy present in the dev container
    _linprog = None


@dataclasses.dataclass(frozen=True)
class RouteContext:
    """One routing decision's inputs.

    ``exclude`` is the hedging hook: when the router duplicates a
    straggling request it re-routes with the primary fleet excluded; a
    policy that still answers the excluded index declines to hedge."""

    telemetry: FleetTelemetry
    rng: np.random.RandomState
    arrival_ms: float
    qos: str = "default"
    exclude: Optional[int] = None


@runtime_checkable
class RoutingPolicy(Protocol):
    """What the router calls. Same shape discipline as
    :class:`~repro.core.control.Controller`: decisions out, no side
    effects on engines/telemetry (rule R3)."""

    name: str

    def route(self, ctx: RouteContext) -> int: ...

    def on_result(self, fleet_index: int, result: Any,
                  telemetry: FleetTelemetry) -> None: ...


class RoutingPolicyBase:
    """Default plumbing: a no-op result feed and the candidate-set helper
    honoring ``RouteContext.exclude``."""

    name = "routing-policy"

    def on_result(self, fleet_index: int, result: Any,
                  telemetry: FleetTelemetry) -> None:
        return None

    @staticmethod
    def _candidates(ctx: RouteContext) -> list[int]:
        n = len(ctx.telemetry)
        cand = [i for i in range(n) if i != ctx.exclude]
        return cand if cand else list(range(n))


class RandomRoutingPolicy(RoutingPolicyBase):
    """Uniform random fleet choice — the baseline every informed policy
    must beat (acceptance bar: greedy never loses to this)."""

    def __init__(self) -> None:
        self.name = "random"

    def route(self, ctx: RouteContext) -> int:
        cand = self._candidates(ctx)
        return cand[int(ctx.rng.randint(len(cand)))]


class WeightedStaticRoutingPolicy(RoutingPolicyBase):
    """Fixed split probabilities proportional to ``weights``.

    ``one_hot(k, n)`` weights make this the static single-fleet
    assignment — the baseline the probabilistic policy is judged against
    in benchmarks/fleet_sweep.py."""

    def __init__(self, weights: Sequence[float]) -> None:
        w = np.asarray(list(weights), float)
        if w.size == 0 or np.any(w < 0.0) or w.sum() <= 0.0:
            raise ValueError("weights must be non-negative with positive sum")
        self.weights = w / w.sum()
        self.name = "weighted-static"

    @staticmethod
    def one_hot(index: int, n_fleets: int) -> "WeightedStaticRoutingPolicy":
        if not 0 <= index < n_fleets:
            raise ValueError("index out of range")
        w = np.zeros(n_fleets)
        w[index] = 1.0
        p = WeightedStaticRoutingPolicy(w)
        p.name = f"static[{index}]"
        return p

    def route(self, ctx: RouteContext) -> int:
        n = len(ctx.telemetry)
        if self.weights.size != n:
            raise ValueError(
                f"{self.weights.size} weights for {n} fleets")
        p = self.weights.copy()
        if ctx.exclude is not None and 0 <= ctx.exclude < n:
            p[ctx.exclude] = 0.0
            if p.sum() <= 0.0:  # excluded the only weighted fleet
                cand = self._candidates(ctx)
                return cand[int(ctx.rng.randint(len(cand)))]
            p /= p.sum()
        return int(ctx.rng.choice(n, p=p))


class GreedyRoutingPolicy(RoutingPolicyBase):
    """Argmin expected response time, from live telemetry only.

    Per fleet: expected service time = the engine's Welford body mean
    (``prior_serve_ms`` until it exists), expected wait = backlog (queue
    depth + in flight) × service time / capacity slots, plus the profile's
    cold-start latency when no warm instance is available. Deterministic:
    draws nothing, ties break toward the lowest fleet index."""

    def __init__(self, prior_serve_ms: float = 1500.0) -> None:
        if prior_serve_ms <= 0.0:
            raise ValueError("prior_serve_ms must be > 0")
        self.name = "greedy"
        self.prior_serve_ms = prior_serve_ms

    def _score(self, ctx: RouteContext, i: int, slots: int) -> float:
        view = ctx.telemetry.fleet(i)
        serve = view.body_mean_ms
        if not np.isfinite(serve) or serve <= 0.0:
            serve = self.prior_serve_ms
        backlog = view.queue_depth + view.total_in_flight
        wait = backlog * serve / max(slots, 1)
        cold = 0.0 if view.pool_available > 0 else view.knobs.cold_start_ms
        return wait + cold + serve

    def route(self, ctx: RouteContext) -> int:
        slots = ctx.telemetry.capacity_slots()
        best, best_score = -1, np.inf
        for i in self._candidates(ctx):
            score = self._score(ctx, i, slots[i])
            if score < best_score:
                best, best_score = i, score
        return best


def solve_split(
    serve_costs: Sequence[float],
    caps: Sequence[float],
    *,
    solver: str = "auto",
) -> tuple[np.ndarray, str]:
    """Split probabilities minimizing expected service time under
    per-fleet capacity caps::

        min Σ c_i·p_i   s.t.   Σ p_i = 1,   0 ≤ p_i ≤ cap_i

    where ``c_i`` is fleet i's expected per-request service time and
    ``cap_i`` the fraction of the offered rate it can absorb at the
    target utilization. This is a continuous knapsack, so the LP's
    optimum IS the closed-form waterfill — fill fleets in ascending cost
    order up to their caps (tested equal in tests/test_fleet.py); scipy
    is an implementation choice, never a requirement. When Σ cap < 1 the
    offered load exceeds total capacity: every fleet saturates and the
    split is capacity-proportional instead (``solver_used='overload'``).

    Returns ``(probs, solver_used)`` with ``solver_used`` one of
    ``lp`` / ``waterfill`` / ``overload`` / ``trivial``.
    """
    if solver not in ("auto", "lp", "waterfill"):
        raise ValueError(f"unknown solver {solver!r}")
    c = np.asarray(list(serve_costs), float)
    cap = np.clip(np.asarray(list(caps), float), 0.0, 1.0)
    n = c.size
    if n == 0 or c.shape != cap.shape:
        raise ValueError("serve_costs and caps must be equal-length, non-empty")
    if n == 1:
        return np.ones(1), "trivial"
    total = float(cap.sum())
    if total < 1.0 - 1e-9:
        if total <= 0.0:
            return np.full(n, 1.0 / n), "overload"
        return cap / total, "overload"
    if solver != "waterfill" and _linprog is not None:
        res = _linprog(c, A_eq=np.ones((1, n)), b_eq=[1.0],
                       bounds=[(0.0, float(u)) for u in cap])
        if getattr(res, "status", 1) == 0 and res.x is not None:
            p = np.clip(np.asarray(res.x, float), 0.0, None)
            return p / p.sum(), "lp"
    p = np.zeros(n)
    remaining = 1.0
    for i in sorted(range(n), key=lambda j: (c[j], j)):
        take = min(float(cap[i]), remaining)
        p[i] = take
        remaining -= take
        if remaining <= 1e-12:
            break
    return p / p.sum(), "waterfill"


class ProbabilisticRoutingPolicy(RoutingPolicyBase):
    """Periodically re-solved probabilistic split (faas-offloading-sim's
    ``probabilistic`` policy, SNIPPETS §2, at fleet granularity).

    State it maintains (all per-instance, rule R3):

    * an EMA of inter-arrival times (``arrival_alpha``) → offered rate λ;
    * per-fleet Welford estimates of the *unit-speed* body time, fed by
      ``on_result`` as ``analysis_ms × instance_speed`` (undoing the
      serving instance's speed so the estimate is fleet-portable);
    * the current split probabilities, re-solved at most every
      ``update_interval_ms`` via :func:`solve_split` with per-fleet
      expected service time ``unit_mean / certified-speed quantile`` and
      capacity cap ``utilization × slots / (serve × λ)``.

    Until the first solve (or while λ is unknown) the split is uniform.
    """

    def __init__(
        self,
        *,
        update_interval_ms: float = 5_000.0,
        arrival_alpha: float = 0.25,
        utilization: float = 0.9,
        speed_quantile: float = 0.5,
        prior_unit_ms: float = 1500.0,
        solver: str = "auto",
    ) -> None:
        if update_interval_ms <= 0.0:
            raise ValueError("update_interval_ms must be > 0")
        if not 0.0 < arrival_alpha <= 1.0:
            raise ValueError("arrival_alpha must be in (0,1]")
        if not 0.0 < utilization <= 1.0:
            raise ValueError("utilization must be in (0,1]")
        if not 0.0 <= speed_quantile <= 1.0:
            raise ValueError("speed_quantile must be in [0,1]")
        if solver not in ("auto", "lp", "waterfill"):
            raise ValueError(f"unknown solver {solver!r}")
        self.name = f"probabilistic[{solver}]" if solver != "auto" \
            else "probabilistic"
        self.update_interval_ms = update_interval_ms
        self.utilization = utilization
        self.speed_quantile = speed_quantile
        self.prior_unit_ms = prior_unit_ms
        self.solver = solver
        self._iat_ema = EMA(arrival_alpha, None)
        self._last_arrival_ms: Optional[float] = None
        self._unit_stats: list[Welford] = []
        self.probs: Optional[np.ndarray] = None
        self._last_solve_ms: Optional[float] = None
        self.n_solves = 0
        self.solver_used = "none"

    def _ensure(self, n: int) -> None:
        if len(self._unit_stats) != n:
            self._unit_stats = [Welford() for _ in range(n)]
            self.probs = None
            self._last_solve_ms = None

    def on_result(self, fleet_index: int, result: Any,
                  telemetry: FleetTelemetry) -> None:
        self._ensure(len(telemetry))
        # analysis_ms was divided by the serving instance's speed; undo it
        # so the Welford tracks the fleet-portable unit-speed body time
        self._unit_stats[fleet_index].update(
            result.analysis_ms * result.instance_speed)

    def _serve_ms(self, t: FleetTelemetry, i: int) -> float:
        stats = self._unit_stats[i]
        unit = stats.mean if stats.count else self.prior_unit_ms
        speed = t.fleet(i).pool_speed_quantile(self.speed_quantile)
        if not np.isfinite(speed) or speed <= 0.0:
            speed = 1.0
        return unit / speed

    def _resolve(self, t: FleetTelemetry) -> np.ndarray:
        n = len(t)
        iat = self._iat_ema.value
        if iat is None or iat <= 0.0:
            return np.full(n, 1.0 / n)
        lam = 1.0 / iat  # arrivals per ms
        serve = np.asarray([self._serve_ms(t, i) for i in range(n)])
        slots = np.asarray(t.capacity_slots(), float)
        mu = slots / np.maximum(serve, 1e-9)  # per-fleet service rate (1/ms)
        caps = self.utilization * mu / lam
        probs, used = solve_split(serve, caps, solver=self.solver)
        self.n_solves += 1
        self.solver_used = used
        return probs

    def route(self, ctx: RouteContext) -> int:
        t = ctx.telemetry
        n = len(t)
        self._ensure(n)
        if ctx.exclude is None:
            # hedge re-routes are duplicates, not offered load: only real
            # arrivals feed the rate estimate
            if self._last_arrival_ms is not None:
                self._iat_ema.update(
                    max(ctx.arrival_ms - self._last_arrival_ms, 1e-6))
            self._last_arrival_ms = ctx.arrival_ms
        if self.probs is None or self._last_solve_ms is None or \
                ctx.arrival_ms - self._last_solve_ms >= self.update_interval_ms:
            self.probs = self._resolve(t)
            self._last_solve_ms = ctx.arrival_ms
        p = np.asarray(self.probs, float).copy()
        if ctx.exclude is not None and 0 <= ctx.exclude < n:
            p[ctx.exclude] = 0.0
        total = p.sum()
        if total <= 0.0:
            cand = self._candidates(ctx)
            return cand[int(ctx.rng.randint(len(cand)))]
        return int(ctx.rng.choice(n, p=p / total))


__all__ = [
    "GreedyRoutingPolicy",
    "ProbabilisticRoutingPolicy",
    "RandomRoutingPolicy",
    "RouteContext",
    "RoutingPolicy",
    "RoutingPolicyBase",
    "WeightedStaticRoutingPolicy",
    "solve_split",
]
