"""Fleet-level failure resilience: per-fleet circuit breakers
(DESIGN.md §15).

The engine-level :class:`~repro.faults.RecoveryPolicy` retries *within*
a fleet; the breaker is the cross-fleet complement — when one fleet's
platform is failing (crash storm, outage window), retrying into it
wastes attempts the router could spend on a healthy fleet. Classic
three-state machine:

* **CLOSED** — traffic flows; outcomes feed a sliding window. When the
  window holds at least ``min_samples`` outcomes and the failure
  fraction reaches ``failure_threshold``, the breaker OPENs.
* **OPEN** — the fleet is skipped (the router fails over through the
  routing policy's ``exclude`` mechanism). After ``open_ms`` the next
  :meth:`allow` probe transitions to HALF_OPEN.
* **HALF_OPEN** — up to ``trial_requests`` trial requests are let
  through. ``trial_requests`` consecutive successes re-CLOSE (window
  cleared — the fleet starts fresh); any failure re-OPENs.

Deliberately clockless-and-RNG-free: simulated time is passed into every
method (the fleet runs on one :class:`~repro.core.substrate.SimClock`),
and state transitions are pure functions of the outcome stream — the
breaker adds zero RNG draws, so arming it cannot shift any seeded
stream. :meth:`allow` is a non-consuming query (safe to ask for several
candidate fleets while failing over); only :meth:`on_route` — called for
the fleet actually routed to — consumes a HALF_OPEN trial slot.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from enum import Enum


class BreakerState(Enum):
    CLOSED = "closed"
    OPEN = "open"
    HALF_OPEN = "half_open"


@dataclasses.dataclass(frozen=True)
class BreakerConfig:
    """Sliding-window circuit-breaker knobs."""

    window: int = 20              # outcomes the failure rate is judged over
    failure_threshold: float = 0.5
    min_samples: int = 5          # don't judge an almost-empty window
    open_ms: float = 5_000.0      # how long an OPEN breaker rejects
    trial_requests: int = 3       # HALF_OPEN probes before re-closing

    def __post_init__(self) -> None:
        if self.window < 1:
            raise ValueError(f"window must be >= 1, got {self.window}")
        if not 0.0 < self.failure_threshold <= 1.0:
            raise ValueError(
                f"failure_threshold must be in (0, 1], got "
                f"{self.failure_threshold}")
        if not 1 <= self.min_samples <= self.window:
            raise ValueError(
                f"min_samples must be in [1, window], got {self.min_samples}")
        if self.open_ms <= 0.0:
            raise ValueError(f"open_ms must be > 0, got {self.open_ms}")
        if self.trial_requests < 1:
            raise ValueError(
                f"trial_requests must be >= 1, got {self.trial_requests}")


class CircuitBreaker:
    """One fleet's breaker. All times are simulated ms, passed in."""

    def __init__(self, config: BreakerConfig = BreakerConfig()) -> None:
        self.config = config
        self.state = BreakerState.CLOSED
        self._outcomes: deque[int] = deque(maxlen=config.window)  # 1 ok / 0 fail
        self._opened_at_ms = 0.0
        self._trials_started = 0
        self._trials_ok = 0
        self.n_opens = 0  # OPEN transitions (observability / sweep rows)

    @property
    def failure_rate(self) -> float:
        if not self._outcomes:
            return 0.0
        return 1.0 - sum(self._outcomes) / len(self._outcomes)

    def allow(self, now_ms: float) -> bool:
        """May a request be routed to this fleet right now? Non-consuming
        (lazily performs the timed OPEN → HALF_OPEN transition)."""
        if self.state is BreakerState.OPEN:
            if now_ms - self._opened_at_ms >= self.config.open_ms:
                self.state = BreakerState.HALF_OPEN
                self._trials_started = 0
                self._trials_ok = 0
            else:
                return False
        if self.state is BreakerState.HALF_OPEN:
            return self._trials_started < self.config.trial_requests
        return True

    def on_route(self, now_ms: float) -> None:
        """The router chose this fleet: consume a HALF_OPEN trial slot."""
        if self.state is BreakerState.HALF_OPEN:
            self._trials_started += 1

    def record_success(self, now_ms: float) -> None:
        if self.state is BreakerState.HALF_OPEN:
            self._trials_ok += 1
            if self._trials_ok >= self.config.trial_requests:
                self.state = BreakerState.CLOSED
                self._outcomes.clear()  # recovered: judge the fleet fresh
            return
        self._outcomes.append(1)

    def record_failure(self, now_ms: float) -> None:
        if self.state is BreakerState.HALF_OPEN:
            # a trial failed: straight back to OPEN for another window
            self._open(now_ms)
            return
        if self.state is BreakerState.OPEN:
            return  # stragglers from before the trip change nothing
        self._outcomes.append(0)
        if (len(self._outcomes) >= self.config.min_samples
                and self.failure_rate >= self.config.failure_threshold):
            self._open(now_ms)

    def _open(self, now_ms: float) -> None:
        self.state = BreakerState.OPEN
        self._opened_at_ms = now_ms
        self.n_opens += 1
        self._outcomes.clear()


__all__ = [
    "BreakerConfig",
    "BreakerState",
    "CircuitBreaker",
]
