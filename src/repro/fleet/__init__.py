"""Fleet-level meta-scheduler (DESIGN.md §14).

The paper's gate picks *instances* inside one platform; this package
routes one live request stream *across* heterogeneous Minos-gated fleets
— each a full :class:`~repro.sim.platform.FaaSPlatform` on a shared
:class:`~repro.core.substrate.SimClock` — through a pluggable
:class:`RoutingPolicy` (random / weighted-static / greedy /
probabilistic-split), with optional request hedging. Per-fleet
:class:`CircuitBreaker` gating, failover, and QoS-priority load shedding
(DESIGN.md §15) sit on top of the same routing policies.
"""
from .policies import (
    GreedyRoutingPolicy,
    ProbabilisticRoutingPolicy,
    RandomRoutingPolicy,
    RouteContext,
    RoutingPolicy,
    RoutingPolicyBase,
    WeightedStaticRoutingPolicy,
    solve_split,
)
from .resilience import BreakerConfig, BreakerState, CircuitBreaker
from .router import FleetRouter, FleetRunResult, FleetSpec, run_fleet_open_loop

__all__ = [
    "BreakerConfig",
    "BreakerState",
    "CircuitBreaker",
    "FleetRouter",
    "FleetRunResult",
    "FleetSpec",
    "GreedyRoutingPolicy",
    "ProbabilisticRoutingPolicy",
    "RandomRoutingPolicy",
    "RouteContext",
    "RoutingPolicy",
    "RoutingPolicyBase",
    "WeightedStaticRoutingPolicy",
    "run_fleet_open_loop",
    "solve_split",
]
