"""AdamW with decoupled weight decay, global-norm clipping, and fp32 master
weights for low-precision params. Hand-rolled (no optax in this
environment); state is a plain pytree so it checkpoints/shards like params.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any      # first moment (fp32)
    nu: Any      # second moment (fp32)
    master: Any  # fp32 master copy of params (None leaves if already fp32)


@dataclasses.dataclass(frozen=True)
class AdamW:
    learning_rate: Callable[[jax.Array], jax.Array] | float
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0

    def init(self, params) -> AdamWState:
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        master = jax.tree.map(lambda p: p.astype(jnp.float32), params)
        return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                          nu=jax.tree.map(jnp.copy, zeros), master=master)

    def _lr(self, step):
        if callable(self.learning_rate):
            return self.learning_rate(step)
        return jnp.asarray(self.learning_rate, jnp.float32)

    def update(self, grads, state: AdamWState, params):
        """Returns (new_params, new_state, metrics)."""
        # global-norm clip (fp32)
        leaves = jax.tree.leaves(grads)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
        scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-9))
        step = state.step + 1
        lr = self._lr(step)
        b1c = 1.0 - self.b1**step.astype(jnp.float32)
        b2c = 1.0 - self.b2**step.astype(jnp.float32)

        def upd(g, m, v, p, master):
            g = g.astype(jnp.float32) * scale
            m = self.b1 * m + (1.0 - self.b1) * g
            v = self.b2 * v + (1.0 - self.b2) * g * g
            mhat = m / b1c
            vhat = v / b2c
            decay = self.weight_decay * master if master.ndim > 1 else 0.0
            new = master - lr * (mhat / (jnp.sqrt(vhat) + self.eps) + decay)
            return new.astype(p.dtype), m, v, new

        flat_g, treedef = jax.tree.flatten(grads)
        flat_m = treedef.flatten_up_to(state.mu)
        flat_v = treedef.flatten_up_to(state.nu)
        flat_p = treedef.flatten_up_to(params)
        flat_ma = treedef.flatten_up_to(state.master)
        out = [upd(g, m, v, p, ma) for g, m, v, p, ma in
               zip(flat_g, flat_m, flat_v, flat_p, flat_ma)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        new_ma = treedef.unflatten([o[3] for o in out])
        return new_p, AdamWState(step, new_m, new_v, new_ma), {
            "grad_norm": gnorm, "lr": lr,
        }
