"""Post-SPMD HLO analysis: FLOPs, HBM-byte and collective-traffic extraction
with while-loop (scan) trip-count accounting, + the three roofline terms.

Why not ``compiled.cost_analysis()``: XLA's summary counts a while-loop body
ONCE, so a 88-layer scanned transformer reports ~1/88th of its FLOPs. We
parse the optimized HLO module instead:

* computations are split into blocks and walked from ENTRY through the call
  graph (while bodies, fusions, calls, conditionals);
* each while's trip count is recovered from its condition computation (the
  scan-induced pattern ``compare(induction_var, constant(N)), direction=LT``);
* FLOPs: 2*result_elems*K for every ``dot`` (K from contracting dims);
* HBM bytes (estimate, documented in EXPERIMENTS.md): sum of result-buffer
  bytes x2 (write + one amortized read) for materializing top-level ops;
* collective wire bytes: result bytes scaled by the algorithm factor
  (all-reduce 2(g-1)/g, all-gather/reduce-scatter/all-to-all (g-1)/g,
  collective-permute 1) with g parsed from replica_groups.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_ASSIGN_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*")
_OPCODE_RE = re.compile(r"(?<![\w%\"/\.])([a-z][\w\-]*)\(")
_TRIP_RE = re.compile(r"known_trip_count\D+(\d+)")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

_NO_BYTES = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "call", "after-all", "add-dependency",
    "partition-id", "replica-id", "iota",
}


def _shape_elems_bytes(shape_str: str) -> tuple[int, int]:
    elems = 0
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        total += n * _DTYPE_BYTES[dt]
    return elems, total


@dataclasses.dataclass
class _Op:
    name: str
    result_type: str
    kind: str
    rest: str  # operand list + attrs (may span the rest of the line)


@dataclasses.dataclass
class _Computation:
    name: str
    ops: list[_Op]


def _split_computations(text: str) -> tuple[dict[str, _Computation], Optional[str]]:
    """Line-based split. A computation header is a top-level (column-0) line
    ending in '{'; ops are the indented '%name = <type> <opcode>(...' lines.
    Returns (computations, entry_name)."""
    comps: dict[str, _Computation] = {}
    entry: Optional[str] = None
    cur: Optional[_Computation] = None
    for line in text.splitlines():
        if not line.startswith(" ") and line.rstrip().endswith("{"):
            name_m = re.match(r"^(ENTRY\s+)?%?([\w\.\-]+)", line)
            if name_m:
                cur = _Computation(name_m.group(2), [])
                comps[cur.name] = cur
                if name_m.group(1):
                    entry = cur.name
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        am = _ASSIGN_RE.match(line)
        if am is None:
            continue
        rest_of_line = line[am.end():]
        om = _OPCODE_RE.search(rest_of_line)
        if om is None:
            continue
        opcode = om.group(1)
        result_type = rest_of_line[: om.start()].strip()
        after = rest_of_line[om.end():]
        cur.ops.append(_Op(am.group(1), result_type, opcode, after))
    return comps, entry


def _trip_count(op: _Op, comps: dict[str, _Computation]) -> int:
    """Trip count of a while op: XLA's backend_config known_trip_count when
    present, else recovered from the condition computation's
    compare(iv, constant(N)) direction=LT pattern."""
    m = _TRIP_RE.search(op.rest)
    if m:
        return max(1, int(m.group(1)))
    cm = re.search(r"condition=%?([\w\.\-]+)", op.rest)
    cond = comps.get(cm.group(1)) if cm else None
    if cond is None:
        return 1
    consts: dict[str, int] = {}
    for o in cond.ops:
        if o.kind == "constant":
            mm = re.match(r"\s*(-?\d+)\s*\)", o.rest)
            if mm:
                consts[o.name] = int(mm.group(1))
    for o in cond.ops:
        if o.kind == "compare" and "direction=LT" in o.rest:
            for ref in re.findall(r"%([\w\.\-]+)", o.rest):
                if ref in consts:
                    return max(1, consts[ref])
    if consts:
        return max(1, max(consts.values()))
    return 1


def _group_size(rest: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(rest)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(rest)
    if m:
        return max(1, len([x for x in m.group(1).split(",") if x.strip()]))
    return default


@dataclasses.dataclass
class HloStats:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    bytes_by_kind: dict = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})
    count_by_kind: dict = dataclasses.field(
        default_factory=lambda: {k: 0 for k in _COLLECTIVES})
    dot_count: int = 0
    while_trips: dict = dataclasses.field(default_factory=dict)


def _dot_flops(op: _Op, opmap: dict[str, str]) -> float:
    out_elems, _ = _shape_elems_bytes(op.result_type)
    mm = re.search(r"lhs_contracting_dims=\{([\d,]+)\}", op.rest)
    # lhs shape: inline type if present, else resolve the operand name
    head = op.rest.split("lhs_", 1)[0]
    inline = _SHAPE_RE.findall(head)
    lhs_dims: list[int] = []
    if inline and inline[0][1]:
        lhs_dims = [int(d) for d in inline[0][1].split(",") if d]
    else:
        om = re.match(r"\s*%([\w\.\-]+)", op.rest)
        if om and om.group(1) in opmap:
            shapes = _SHAPE_RE.findall(opmap[om.group(1)])
            if shapes and shapes[0][1]:
                lhs_dims = [int(d) for d in shapes[0][1].split(",") if d]
    if mm is None or not lhs_dims:
        return 2.0 * out_elems  # degenerate
    k = 1
    for idx in mm.group(1).split(","):
        i = int(idx)
        if i < len(lhs_dims):
            k *= lhs_dims[i]
    return 2.0 * out_elems * k


def analyze_hlo(text: str, default_group: int = 16) -> HloStats:
    comps, entry = _split_computations(text)
    stats = HloStats()
    fused_names = set()
    dus_rooted = set()  # fused computations whose ROOT is a dynamic-update-slice
    for c in comps.values():
        for op in c.ops:
            if op.kind == "fusion":
                m = re.search(r"calls=%?([\w\.\-]+)", op.rest)
                if m:
                    fused_names.add(m.group(1))
    for name in fused_names:
        c = comps.get(name)
        if c and c.ops and any(
            o.kind == "dynamic-update-slice" for o in c.ops
        ):
            dus_rooted.add(name)

    def walk(name: str, mult: float, seen: tuple = ()):
        comp = comps.get(name)
        if comp is None or name in seen:
            return
        opmap = {op.name: op.result_type for op in comp.ops}
        for op in comp.ops:
            if op.kind == "while":
                b = re.search(r"body=%?([\w\.\-]+)", op.rest)
                trips = _trip_count(op, comps)
                if b:
                    stats.while_trips[b.group(1)] = trips
                    walk(b.group(1), mult * trips, seen + (name,))
                continue
            if op.kind == "fusion":
                m = re.search(r"calls=%?([\w\.\-]+)", op.rest)
                if m:
                    walk(m.group(1), mult, seen + (name,))
            elif op.kind in ("call", "custom-call", "reduce", "reduce-window",
                             "scatter", "sort", "map", "select-and-scatter"):
                m = re.search(r"to_apply=%?([\w\.\-]+)", op.rest)
                if m:
                    walk(m.group(1), mult, seen + (name,))
            elif op.kind == "conditional":
                for m in re.finditer(r"branch_computations=\{([^}]*)\}", op.rest):
                    for br in m.group(1).split(","):
                        walk(br.strip().lstrip("%"), mult, seen + (name,))
            if op.kind == "dot":
                stats.flops += mult * _dot_flops(op, opmap)
                stats.dot_count += int(mult)
            elif op.kind == "convolution":
                # rough: 2 * out_elems * (kernel elems per output)
                out_elems, _ = _shape_elems_bytes(op.result_type)
                kshape = _SHAPE_RE.findall(op.rest)
                kelems = 1
                if len(kshape) >= 2 and kshape[1][1]:
                    for d in kshape[1][1].split(","):
                        kelems *= int(d)
                stats.flops += mult * 2.0 * out_elems * kelems
            elif op.kind in _COLLECTIVES or any(
                op.kind == f"{c}-start" for c in _COLLECTIVES
            ):
                base = op.kind.replace("-start", "")
                _, size = _shape_elems_bytes(op.result_type)
                g = _group_size(op.rest, default_group)
                if base == "all-reduce":
                    wire = 2.0 * size * (g - 1) / max(g, 1)
                elif base in ("all-gather", "reduce-scatter", "all-to-all"):
                    wire = size * (g - 1) / max(g, 1)
                else:
                    wire = float(size)
                stats.collective_bytes += mult * wire
                stats.bytes_by_kind[base] += mult * wire
                stats.count_by_kind[base] += int(mult)
            # HBM byte proxy: only in non-fused computations (top level).
            # In-place dynamic-update-slice (scan stacking, KV-cache row
            # writes) writes a DISJOINT slice per loop iteration — count the
            # full buffer once per loop, not once per trip.
            if name not in fused_names and op.kind not in _NO_BYTES:
                _, size = _shape_elems_bytes(op.result_type)
                callee = None
                if op.kind == "fusion":
                    cm = re.search(r"calls=%?([\w\.\-]+)", op.rest)
                    callee = cm.group(1) if cm else None
                is_dus = (
                    op.kind == "dynamic-update-slice"
                    or "dynamic_update_slice" in op.rest
                    or "dynamic-update-slice" in op.rest
                    or (callee is not None and callee in dus_rooted)
                )
                eff = 1.0 if is_dus else mult
                stats.hbm_bytes += eff * 2.0 * size

    if entry is None:
        for cname in comps:
            if "main" in cname:
                entry = cname
                break
    if entry:
        walk(entry, 1.0)
    return stats


# ---------------------------------------------------------------------------
# Roofline (TPU v5e per chip)
# ---------------------------------------------------------------------------

PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # B/s
ICI_BW = 50e9                 # B/s per link


@dataclasses.dataclass
class Roofline:
    flops: float               # per-device HLO FLOPs (post-SPMD module)
    hbm_bytes: float           # per-device HBM traffic estimate
    collective_bytes: float    # per-device wire bytes
    chips: int
    model_flops: float         # analytic 6*N*D (train) / 2*N*tokens (infer)
    stats: Optional[HloStats] = None

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        total = self.flops * self.chips
        if total == 0:
            return float("nan")
        return self.model_flops / total

    def to_dict(self) -> dict:
        return {
            "flops_per_dev": self.flops,
            "hbm_bytes_per_dev": self.hbm_bytes,
            "collective_bytes_per_dev": self.collective_bytes,
            "chips": self.chips,
            "model_flops": self.model_flops,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "collective_counts": self.stats.count_by_kind if self.stats else {},
            "collective_bytes_by_kind": self.stats.bytes_by_kind if self.stats else {},
            "dot_count": self.stats.dot_count if self.stats else 0,
            "while_trips": self.stats.while_trips if self.stats else {},
        }


# Back-compat shim for older callers/tests
def parse_collectives(text: str, default_group: int = 16):
    return analyze_hlo(text, default_group)
