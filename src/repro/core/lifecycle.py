r"""Function-instance lifecycle state machine (paper Fig. 2).

COLD --prepare+benchmark--> BENCHMARKING --pass--> WARM --reuse*--> EXPIRED
                                  \--fail--> TERMINATED (requeue first)

The platform only ever routes new invocations to WARM instances or starts a
new COLD one; every WARM instance has, by construction, passed the benchmark
on its first invocation — this is the invariant that produces the
known-good pool.
"""
from __future__ import annotations

import dataclasses
import itertools
from enum import Enum
from typing import Optional

from .policy import MinosPolicy, Verdict

_ids = itertools.count()


class InstanceState(Enum):
    COLD = "cold"
    BENCHMARKING = "benchmarking"
    WARM = "warm"
    TERMINATED = "terminated"
    EXPIRED = "expired"


class LifecycleError(RuntimeError):
    pass


@dataclasses.dataclass
class FunctionInstance:
    """One function instance. ``speed_factor`` is the (hidden, platform-
    determined) relative performance of the worker node slot this instance
    landed on — 1.0 is nominal, >1 faster. The instance itself never reads
    it directly; it only observes it through the benchmark."""

    speed_factor: float
    created_at_ms: float = 0.0
    idle_timeout_ms: float = 15 * 60 * 1000.0  # GCF-ish idle reclaim
    state: InstanceState = InstanceState.COLD
    instance_id: int = dataclasses.field(default_factory=lambda: next(_ids))
    benchmark_result: Optional[float] = None
    verdict: Optional[Verdict] = None
    invocations_served: int = 0
    last_used_ms: float = 0.0
    # certification age, read by the control plane's on_reuse decision
    # (ReprobeController): when the instance was last benchmarked (None =
    # never, e.g. forced pass) and how many serves it has since absorbed —
    # the unit the per-serve AR(1) drift model decays in.
    last_probe_ms: Optional[float] = None
    serves_since_probe: int = 0

    def run_benchmark(self, work_ms_at_unit_speed: float) -> float:
        """Execute the probe: observed duration = work / speed."""
        if self.state is not InstanceState.COLD:
            raise LifecycleError(f"benchmark only allowed from COLD, got {self.state}")
        self.state = InstanceState.BENCHMARKING
        self.benchmark_result = work_ms_at_unit_speed / self.speed_factor
        return self.benchmark_result

    def judge(self, policy: MinosPolicy, retry_count: int) -> Verdict:
        if self.state is not InstanceState.BENCHMARKING:
            raise LifecycleError(f"judge only allowed from BENCHMARKING, got {self.state}")
        assert self.benchmark_result is not None
        self.verdict = policy.judge(self.benchmark_result, retry_count)
        if self.verdict is Verdict.TERMINATE:
            self.state = InstanceState.TERMINATED
        else:
            self.state = InstanceState.WARM
        return self.verdict

    def accept_without_benchmark(self) -> None:
        """Emergency-exit path and the baseline (Minos disabled) path."""
        if self.state not in (InstanceState.COLD, InstanceState.BENCHMARKING):
            raise LifecycleError(f"cannot accept from {self.state}")
        self.verdict = Verdict.FORCED_PASS
        self.state = InstanceState.WARM

    def serve(self, now_ms: float) -> None:
        if self.state is not InstanceState.WARM:
            raise LifecycleError(f"serve only allowed from WARM, got {self.state}")
        self.invocations_served += 1
        self.serves_since_probe += 1
        self.last_used_ms = now_ms

    def maybe_expire(self, now_ms: float) -> bool:
        if self.state is InstanceState.WARM and now_ms - self.last_used_ms > self.idle_timeout_ms:
            self.state = InstanceState.EXPIRED
            return True
        return False

    @property
    def is_warm(self) -> bool:
        return self.state is InstanceState.WARM

    @property
    def is_dead(self) -> bool:
        return self.state in (InstanceState.TERMINATED, InstanceState.EXPIRED)
