"""The shared execution substrate (DESIGN.md §9).

One copy of the machinery that used to exist twice — once in
``sim/platform.py`` and again, divergently, in ``serving/engine.py``:

* :class:`SimClock` — the discrete event loop (simulated milliseconds);
* :class:`InstancePool` — the warm pool: LIFO/FIFO reuse order,
  per-instance request concurrency, idle-timeout reclaim, and
  platform-initiated recycling;
* :class:`SubstrateEngine` — the generic invocation-processing loop
  (queue → dispatch → warm reuse | gated cold start → complete/requeue)
  with the Fig-3 cost accounting.

Every *decision* in that loop — probe or not, pass or terminate, keep /
re-probe / retire a warm instance, admit an item to a stage — is delegated
to a single :class:`~repro.core.control.Controller` (DESIGN.md §10). The
default :class:`~repro.core.control.ClassicMinosController` wraps the
:class:`~repro.core.control.ElysiumGate` + policy stack and is pinned
bit-identical to the pre-control-plane engine by the seeded golden digests
in tests/test_unified_substrate.py; the engine hands every decision point a
read-only :class:`~repro.core.control.Telemetry` view (pool load, queue
depth, clock, Welford reuse/probe/body estimates) and owns all side effects
itself (lifecycle transitions, billing, requeues).

What *differs* between the simulator and the model-serving engine is
isolated behind the :class:`Backend` protocol: where fresh-instance speeds
come from, how the prepare phase and probe are observed, and — crucially —
what the body *is*: a sampled duration for a simulated
:class:`~repro.sim.platform.FunctionSpec`, real JAX prefill/decode for a
serving replica (``serving/backend.py``). Everything else (pool dynamics,
gating, billing, requeue semantics, contention drift hooks) is shared, so
behavior can no longer drift between the two paths.

Time unit: milliseconds of simulated time; deterministic given a seed.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import math
from typing import Any, Callable, Optional, Protocol

import numpy as np

from .control import (
    ClassicMinosController,
    ColdStartContext,
    ElysiumGate,  # noqa: F401 — re-exported; the gate now lives in control.py
    FailureContext,
    FailureDecision,
    ProbeContext,
    ProbeDecision,
    ReleaseContext,
    ReuseContext,
    ReuseDecision,
    Telemetry,
)
from .cost import Pricing, WorkflowCost
from .estimators import Welford
from .lifecycle import FunctionInstance, InstanceState
from .policy import Verdict
from .queue import Invocation, InvocationQueue
from ..faults import decorrelated_jitter_ms


# ---------------------------------------------------------------------------
# Clock
# ---------------------------------------------------------------------------


class SimClock:
    """Discrete event loop over simulated milliseconds."""

    def __init__(self) -> None:
        self._heap: list[tuple[float, int, Callable[[], None]]] = []
        self._seq = itertools.count()
        self.now = 0.0

    def at(self, t_ms: float, fn: Callable[[], None]) -> None:
        heapq.heappush(self._heap, (t_ms, next(self._seq), fn))

    def after(self, dt_ms: float, fn: Callable[[], None]) -> None:
        self.at(self.now + dt_ms, fn)

    def run_until(self, t_end_ms: float) -> None:
        while self._heap and self._heap[0][0] <= t_end_ms:
            t, _, fn = heapq.heappop(self._heap)
            self.now = t
            fn()
        self.now = max(self.now, t_end_ms)

    def run_all(self, hard_limit_ms: float = float("inf")) -> None:
        while self._heap and self._heap[0][0] <= hard_limit_ms:
            t, _, fn = heapq.heappop(self._heap)
            self.now = t
            fn()


def sample_jitter(rng: np.random.RandomState, scale: float) -> float:
    """Multiplicative lognormal jitter; scale<=0 draws nothing (exactly 1.0),
    so disabling a noise term also removes its RNG consumption."""
    if scale <= 0.0:
        return 1.0
    return float(np.exp(rng.normal(0.0, scale)))


def ar1_drift(
    inst: FunctionInstance,
    rng: np.random.RandomState,
    *,
    day_mean: float,
    sigma: float,
    rho: float,
) -> None:
    """Co-tenancy drift, shared by both backends: AR(1) on the instance's
    log-relative speed. The benchmark certified the speed at cold-start
    time, but node neighbors change, so the advantage decays toward the
    day mean. rho>=1 is the frozen (idealized) model and draws nothing."""
    if rho >= 1.0:
        return
    log_rel = math.log(inst.speed_factor / day_mean)
    noise = rng.normal(0.0, sigma)
    log_rel = rho * log_rel + math.sqrt(1.0 - rho * rho) * noise
    inst.speed_factor = day_mean * math.exp(log_rel)


# ---------------------------------------------------------------------------
# Warm pool
# ---------------------------------------------------------------------------


class InstancePool:
    """WARM instances with spare request capacity, in reuse order.

    * ``order`` — "lifo": most recently used first (GCF gen1 / Lambda MRU
      reuse); "fifo": oldest available first (round-robin-ish);
      "spread": least-loaded first (Cloud-Run-style concurrency target —
      the order that actually relieves per-instance load when
      ``concurrency > 1``; ties fall back to FIFO).
    * ``concurrency`` — requests one warm instance serves at once; an
      instance at capacity leaves the available list until a slot frees.
    * ``recycle_lifetime_ms`` — platform-initiated instance rotation:
      each cold start draws an exponential lifetime deadline from ``rng``.
    * ``max_size`` — optional cap on *available* instances (serving
      replica pools); a release that would exceed it expires the instance.

    Invariants (tested in tests/test_unified_substrate.py): an instance
    with requests in flight is never reclaimed; every pooled instance is
    WARM, i.e. passed the gate (or was force-accepted) on its first
    invocation.

    Hot-path aggregates (PR 5): ``total_in_flight``/``n_instances``/
    ``mean_load`` are O(1) incremental counters (they are read per gate
    judgment under ``gate_load_aware``); :meth:`take` skips the
    available-list rebuild entirely while no pooled idle instance can have
    reached its idle/recycle deadline (``_next_deadline`` lower bound);
    ``order="spread"`` keeps a lazily-invalidated min-load heap instead of
    an O(n) argmin scan per take; :meth:`speeds_view` is a cached tuple.
    Equivalence with the plain O(n) scans is property-tested
    (tests/test_pool_fastpath.py). All mutation must go through the pool's
    methods — external code seeds instances with :meth:`add_warm`, never by
    appending to ``available`` directly.
    """

    def __init__(
        self,
        *,
        order: str = "lifo",
        concurrency: int = 1,
        recycle_lifetime_ms: float | None = None,
        rng: Optional[np.random.RandomState] = None,
        max_size: Optional[int] = None,
    ) -> None:
        if order not in ("lifo", "fifo", "spread"):
            raise ValueError(f"order must be 'lifo', 'fifo' or 'spread', got {order!r}")
        if concurrency < 1:
            raise ValueError("concurrency must be >= 1")
        self.order = order
        self.concurrency = concurrency
        self.recycle_lifetime_ms = recycle_lifetime_ms
        self.max_size = max_size
        self._rng = rng
        self.available: list[FunctionInstance] = []
        self._active: dict[int, int] = {}  # instance_id -> in-flight requests
        self._recycle_deadline: dict[int, float] = {}
        # -- incremental aggregates (kept equal to the O(n) recomputes) --
        self._in_flight = 0                       # sum(_active.values())
        self._live_ids: set[int] = set()          # avail ids | _active keys
        self._avail_seq: dict[int, int] = {}      # id -> stable position seq
        self._pos_seq = itertools.count()         # grows with each append
        # earliest idle/recycle deadline among *idle* pooled instances — a
        # lower bound: removals leave it stale-low (spurious sweep, never a
        # missed one)
        self._next_deadline = math.inf
        # (load, seq, push_id, inst) entries; only an instance's LATEST
        # push is ever valid (plus load/seq currency), so duplicates from
        # repeated take/release cycles go stale and pop lazily instead of
        # accumulating as equally-valid twins — keeps the heap bounded
        self._spread_heap: list[tuple[int, int, int, FunctionInstance]] = []
        self._spread_push = itertools.count()
        self._spread_latest: dict[int, int] = {}  # iid -> latest push id
        self._version = 0                         # bumped on any mutation
        self._speeds_cache: tuple[float, ...] = ()
        self._speeds_version = -1

    # -- lifecycle entry points ----------------------------------------
    def admit_cold(self, inst: FunctionInstance, now: float) -> None:
        """Register a freshly started instance with one request in flight
        (it is serving the invocation that caused the cold start)."""
        self._active[inst.instance_id] = 1
        self._in_flight += 1
        self._live_ids.add(inst.instance_id)
        self._version += 1
        if self.recycle_lifetime_ms is not None:
            assert self._rng is not None, "recycling requires an rng"
            self._recycle_deadline[inst.instance_id] = now + float(
                self._rng.exponential(self.recycle_lifetime_ms)
            )

    def add_warm(self, inst: FunctionInstance, *, in_flight: int = 0) -> None:
        """Admit an externally built WARM instance (tests, pool seeding) at
        ``in_flight`` requests. The instance joins ``available`` unless it
        is already at capacity — the state a normal admit+take sequence
        would have produced."""
        iid = inst.instance_id
        if in_flight:
            self._active[iid] = in_flight
            self._in_flight += in_flight
        self._version += 1
        if in_flight < self.concurrency:
            self._append_available(inst)
        self._sync_live(iid)

    def take(self, now: float) -> Optional[FunctionInstance]:
        """Reserve one request slot on a warm instance, or None."""
        # reclaim idle-expired and platform-recycled instances (never ones
        # with requests in flight). Skipped — O(1) — while no pooled idle
        # instance can have reached a deadline yet.
        if self.available and now >= self._next_deadline:
            self._sweep(now)
        if not self.available:
            return None
        if self.order == "lifo":
            idx = len(self.available) - 1
            inst = self.available[idx]
        elif self.order == "spread":
            inst = self._spread_min()
            idx = None  # resolved only if the instance must leave the list
        else:
            idx = 0
            inst = self.available[idx]
        iid = inst.instance_id
        n = self._active.get(iid, 0) + 1
        self._active[iid] = n
        self._in_flight += 1
        self._live_ids.add(iid)
        self._version += 1
        if n >= self.concurrency:  # at capacity: no longer available
            if idx is None:
                self.available.remove(inst)
            else:
                self.available.pop(idx)
            del self._avail_seq[iid]
            self._spread_latest.pop(iid, None)
        elif self.order == "spread":
            self._spread_push_entry(inst, n)
        return inst

    def release(self, inst: FunctionInstance, now: Optional[float] = None) -> None:
        """A request on ``inst`` completed: free one concurrency slot and
        return the instance to the available pool if it left it.

        Readmission applies the same reclaim filter as :meth:`take`: an
        instance whose recycle deadline (or idle timeout) has passed while
        it was serving must NOT re-enter the pool — it would inflate the
        pool views (``speeds``/``len``) until the next ``take`` swept it.
        ``now=None`` (pool used standalone) skips the time-based checks.
        """
        iid = inst.instance_id
        had = self._active.get(iid, 0)
        n = had - 1
        if n <= 0:
            self._active.pop(iid, None)
        else:
            self._active[iid] = n
        if had > 0:
            self._in_flight -= 1
        self._version += 1
        in_avail = iid in self._avail_seq
        if inst.state is InstanceState.WARM and not in_avail:
            if n <= 0 and now is not None and (
                inst.maybe_expire(now) or self._recycled(inst, now)
            ):
                self._sync_live(iid)
                return  # past its deadline while serving: reclaim, not readmit
            if self.max_size is not None and len(self.available) >= self.max_size:
                if n <= 0:
                    inst.state = InstanceState.EXPIRED  # pool full: despawn
                # else: requests still in flight — an instance is never
                # killed under live work (same invariant as take's reclaim);
                # it stays out of the available list and is re-offered when
                # its last request completes
                self._sync_live(iid)
                return
            self._append_available(inst)
        elif in_avail:
            # still pooled: refresh its min-load entry; once it drains to
            # idle, its deadline starts gating the take fast path
            if self.order == "spread":
                self._spread_push_entry(inst, max(n, 0))
            if n <= 0:
                self._fold_deadline(inst)
        self._sync_live(iid)

    def drop(self, inst: FunctionInstance) -> None:
        """A terminated (gate-failed) instance leaves without serving."""
        had = self._active.pop(inst.instance_id, None)
        if had:
            self._in_flight -= had
        self._version += 1
        self._sync_live(inst.instance_id)

    def retire(self, inst: FunctionInstance) -> None:
        """Remove ``inst`` from the pool entirely — controller-initiated
        retirement (:class:`~repro.core.control.ReuseDecision` RETIRE, or a
        failed warm re-probe). The caller must ensure no *other* requests
        are in flight on it (the engine only offers reuse decisions at
        instance load 1, preserving the never-kill-under-live-work
        invariant)."""
        iid = inst.instance_id
        had = self._active.pop(iid, None)
        if had:
            self._in_flight -= had
        self._recycle_deadline.pop(iid, None)
        if iid in self._avail_seq:
            self.available.remove(inst)
            del self._avail_seq[iid]
        self._spread_latest.pop(iid, None)
        self._version += 1
        self._sync_live(iid)

    # -- internal bookkeeping -------------------------------------------
    def _sync_live(self, iid: int) -> None:
        if iid in self._active or iid in self._avail_seq:
            self._live_ids.add(iid)
        else:
            self._live_ids.discard(iid)

    def _append_available(self, inst: FunctionInstance) -> None:
        iid = inst.instance_id
        seq = next(self._pos_seq)
        self._avail_seq[iid] = seq
        self.available.append(inst)
        load = self._active.get(iid, 0)
        if self.order == "spread":
            self._spread_push_entry(inst, load)
        if load == 0:
            self._fold_deadline(inst)
        self._live_ids.add(iid)

    def _spread_push_entry(self, inst: FunctionInstance, load: int) -> None:
        pid = next(self._spread_push)
        self._spread_latest[inst.instance_id] = pid
        heapq.heappush(
            self._spread_heap,
            (load, self._avail_seq[inst.instance_id], pid, inst))
        # stale entries ABOVE the current min never surface to be popped
        # lazily, so compact once the heap outgrows the live set — O(n)
        # at a geometric trigger = amortized O(1) per operation
        if len(self._spread_heap) > 4 * len(self.available) + 8:
            self._spread_heap = [
                (self._active.get(i.instance_id, 0),
                 self._avail_seq[i.instance_id],
                 self._spread_latest[i.instance_id], i)
                for i in self.available]
            heapq.heapify(self._spread_heap)

    def _fold_deadline(self, inst: FunctionInstance) -> None:
        """Fold an idle pooled instance's reclaim deadline into the take
        fast-path bound. ``maybe_expire`` fires strictly after
        last_used + idle_timeout, so sweeping at >= the bound never misses."""
        d = inst.last_used_ms + inst.idle_timeout_ms
        rd = self._recycle_deadline.get(inst.instance_id)
        if rd is not None and rd < d:
            d = rd
        if d < self._next_deadline:
            self._next_deadline = d

    def _sweep(self, now: float) -> None:
        """The old per-take reclaim filter, now run only when a deadline
        may actually have passed. Bit-identical membership/mutation order:
        busy instances are protected (and not state-checked), idle ones
        run maybe_expire then _recycled."""
        kept: list[FunctionInstance] = []
        next_deadline = math.inf
        removed = False
        for inst in self.available:
            iid = inst.instance_id
            if self._active.get(iid, 0) > 0:
                kept.append(inst)  # in-flight: protected, drains via release
            elif not inst.maybe_expire(now) and not self._recycled(inst, now):
                kept.append(inst)
                d = inst.last_used_ms + inst.idle_timeout_ms
                rd = self._recycle_deadline.get(iid)
                if rd is not None and rd < d:
                    d = rd
                if d < next_deadline:
                    next_deadline = d
            else:
                removed = True
                del self._avail_seq[iid]
                self._spread_latest.pop(iid, None)
                self._sync_live(iid)
        if removed:
            self.available = kept
            self._version += 1
        self._next_deadline = next_deadline

    def _spread_min(self) -> FunctionInstance:
        """Current least-loaded available instance, FIFO among ties —
        identical choice to ``min(range(len(available)), key=load)`` since
        position seqs grow in list order. Amortized O(log n): stale heap
        entries (load or membership changed since push) pop lazily."""
        h = self._spread_heap
        while True:
            while h:
                load, seq, pid, inst = h[0]
                iid = inst.instance_id
                if self._avail_seq.get(iid) == seq \
                        and self._active.get(iid, 0) == load \
                        and self._spread_latest.get(iid) == pid:
                    return inst
                heapq.heappop(h)
            # heap drained (never populated for this membership): rebuild.
            # Entries pushed here are valid by construction, so the outer
            # loop terminates on the next pass.
            for inst in self.available:
                iid = inst.instance_id
                if iid not in self._avail_seq:  # seeded out-of-band
                    self._avail_seq[iid] = next(self._pos_seq)
                self._spread_push_entry(inst, self._active.get(iid, 0))

    def _recycled(self, inst: FunctionInstance, now: float) -> bool:
        deadline = self._recycle_deadline.get(inst.instance_id)
        if deadline is not None and now >= deadline:
            inst.state = InstanceState.EXPIRED
            return True
        return False

    # -- views ----------------------------------------------------------
    def speeds_view(self) -> tuple[float, ...]:
        """Certified speeds of pooled instances, as a cached immutable
        tuple — safe to hand to controllers/telemetry without a per-read
        list rebuild. The cache keys on the pool's mutation version;
        ``speed_factor`` drift always follows a ``take`` (backends drift on
        reuse), so a bumped version covers it."""
        if self._speeds_version != self._version:
            self._speeds_cache = tuple(
                i.speed_factor for i in self.available
                if i.state is InstanceState.WARM)
            self._speeds_version = self._version
        return self._speeds_cache

    @property
    def speeds(self) -> list[float]:
        """Mutable copy of :meth:`speeds_view` (compat; hot readers use the
        cached view so a caller mutating this list cannot corrupt it)."""
        return list(self.speeds_view())

    @property
    def n_warm(self) -> int:
        """Pooled WARM instances — the count the gate actually needs."""
        return len(self.speeds_view())

    def certified_speed_quantile(self, q: float) -> float:
        """q-quantile of the pooled certified speeds (nan when empty)."""
        view = self.speeds_view()
        if not view:
            return float("nan")
        return float(np.quantile(np.asarray(view), q))

    def load(self, inst: FunctionInstance) -> int:
        """Requests currently in flight on ``inst`` (0 if idle)."""
        return self._active.get(inst.instance_id, 0)

    @property
    def total_in_flight(self) -> int:
        """Requests in flight across every instance of this pool. O(1)."""
        return self._in_flight

    @property
    def n_instances(self) -> int:
        """Live instances: available + at-capacity ones serving requests.
        O(1) (was an O(pool) set rebuild per Telemetry read)."""
        return len(self._live_ids)

    def mean_load(self) -> float:
        """Mean in-flight requests per live instance, floored at 1.0 — the
        occupancy a new request should expect; the gate uses it to judge
        *effective* speed under the load-slowdown model (ROADMAP:
        concurrency-aware gating). An idle pool reports 1.0: a request never
        runs at less than single occupancy. O(1) per gate judgment."""
        n = len(self._live_ids)
        if n == 0:
            return 1.0
        return max(1.0, self._in_flight / n)

    def __len__(self) -> int:
        return len(self.available)


# ---------------------------------------------------------------------------
# Backend protocol
# ---------------------------------------------------------------------------


class Backend(Protocol):
    """What an execution backend must supply; everything else is shared.

    Durations are *observed* milliseconds (jitter/noise already applied);
    every random draw must come from the ``rng`` argument so runs stay
    deterministic per seed.
    """

    name: str

    def sample_speed(self, rng: np.random.RandomState, t_ms: float) -> float:
        """Hidden speed factor of a freshly placed instance."""
        ...

    def reuse_drift(self, inst: FunctionInstance, rng: np.random.RandomState, t_ms: float) -> None:
        """Mutate ``inst.speed_factor`` for co-tenancy drift on reuse."""
        ...

    def prepare_ms(self, rng: np.random.RandomState) -> float:
        """Observed prepare-phase duration (network-bound: does not scale
        with instance speed). Runs concurrently with the probe."""
        ...

    def probe(self, inst: FunctionInstance, rng: np.random.RandomState) -> float:
        """Run the benchmark probe on ``inst``; returns the observed
        duration and leaves it in ``inst.benchmark_result``."""
        ...

    def body(
        self,
        payload: Any,
        inst: FunctionInstance,
        rng: np.random.RandomState,
        *,
        load: int = 1,
    ) -> tuple[float, Any]:
        """Execute the body work for ``payload`` on ``inst``; returns
        (observed duration at single occupancy, output). The output rides on
        the :class:`RequestResult` (None for simulated functions).

        ``load`` is the instance's in-flight request count at body start
        (>= 1, including this request). A backend may use it to make the
        compute real — the serving backend batches its decode across the
        replica's concurrent streams — but must NOT fold it into the
        returned duration: the engine applies the platform-level
        load-slowdown curve (``SubstrateKnobs.load_slowdown_alpha``) so the
        model stays backend-independent."""
        ...

    def requeue_penalty_ms(self, payload: Any) -> float:
        """Extra delay when ``payload`` migrates to another instance after
        a termination (e.g. KV-cache re-prefill for attention families)."""
        ...

    # Optional hook (the engine probes for it with getattr):
    #   reprobe(inst, rng) -> float
    # Re-benchmark a WARM instance in place (no lifecycle transition) and
    # return the observed duration — what ReuseDecision.REPROBE runs. A
    # backend without it opts out: REPROBE quietly degrades to KEEP.


@dataclasses.dataclass
class RequestResult:
    invocation_id: int
    t_submitted_ms: float
    t_completed_ms: float
    download_ms: float        # observed prepare duration
    analysis_ms: float        # observed body duration
    retries: int              # terminated instances this request caused
    served_by_cold: bool      # final (serving) instance was a cold start
    instance_speed: float
    benchmark_ms: Optional[float] = None  # probe duration on serving instance
    output: Any = None                    # backend body output (serving: tokens)
    # time from submission (arrival / deferred-arrival time) to the FIRST
    # dispatch attempt — the open-loop queue wait. Closed-loop submits
    # dispatch immediately, so this stays 0.0 there.
    queue_wait_ms: float = 0.0

    @property
    def latency_ms(self) -> float:
        return self.t_completed_ms - self.t_submitted_ms


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SubstrateKnobs:
    """Platform-level hosting knobs, backend-independent (the overlap of
    :class:`~repro.sim.platform.PlatformProfile` and the serving engine's
    constructor arguments)."""

    cold_start_ms: float = 250.0
    cold_start_jitter: float = 0.25
    idle_timeout_ms: float = 15 * 60 * 1000.0
    recycle_lifetime_ms: float | None = 7 * 60 * 1000.0
    bill_cold_start: bool = True
    requeue_overhead_ms: float = 30.0
    warm_pool_order: str = "lifo"
    per_instance_concurrency: int = 1
    max_pool: Optional[int] = None
    # Self-contention: a request sharing its instance with load-1 others
    # runs load**alpha slower (alpha=0: the idealized free-concurrency
    # model; alpha=1: perfect serialization; batched serving replicas sit
    # in between — see ModelServingBackend.calibrate_load_slowdown).
    load_slowdown_alpha: float = 0.0
    # With True, the elysium gate judges a cold-start probe at the pool's
    # current mean occupancy (effective speed), not at single occupancy.
    gate_load_aware: bool = False
    # -- open-loop traffic knobs (DESIGN.md §12) ----------------------------
    # Autoscaling supply cap: live instances (busy + pooled) this deployment
    # may hold at once. None = the elastic-supply model every closed-loop
    # sweep assumed (a cold start is always possible, so the queue never
    # builds). With a cap, a dispatch that finds no warm instance AND no
    # spare instance budget leaves the invocation queued until a release —
    # this is what makes open-loop queueing (and queue blow-up) real.
    max_instances: Optional[int] = None
    # Finite queue buffer: an arrival finding this many invocations already
    # queued is dropped at submit (counted, never served) — the M/G/c/K
    # loss model. None = unbounded queue (drops never happen; sustained
    # overload shows up as unbounded waits instead).
    queue_capacity: Optional[int] = None
    # Weighted-fair dequeue by QoS class weight (start-time fair queueing;
    # core/queue.py). False keeps the historical FIFO heap keys
    # bit-identical, so seeded golden digests are unaffected.
    fair_queue: bool = False

    def load_multiplier(self, load: float) -> float:
        """Body-duration multiplier at ``load`` in-flight requests."""
        if self.load_slowdown_alpha <= 0.0 or load <= 1.0:
            return 1.0
        return float(load) ** self.load_slowdown_alpha


class SubstrateEngine:
    """The unified invocation-processing loop.

    On a cold start the probe runs concurrently with the backend's
    prepare phase (paper Fig 2); the instance is judged at the
    controller's ``on_probe`` decision point and either proceeds (body
    starts once BOTH prepare and probe are done) or re-queues the
    invocation and crashes. Warm reuse consults ``on_reuse``: KEEP is the
    paper's §II-B no-re-benchmarking default, REPROBE re-certifies a
    drifted instance (probe hidden under the prepare phase; a failure
    retires the instance and requeues the request), RETIRE despawns it
    and cold-starts instead.

    All decisions flow through ``self.controller``
    (:class:`~repro.core.control.Controller`); the legacy
    ``policy``/``online_controller`` arguments build the default
    :class:`~repro.core.control.ClassicMinosController`.
    """

    def __init__(
        self,
        backend: Backend,
        policy=None,
        pricing: Pricing = None,
        *,
        knobs: SubstrateKnobs = SubstrateKnobs(),
        seed: int = 0,
        online_controller=None,
        clock: Optional[SimClock] = None,
        rng: Optional[np.random.RandomState] = None,
        controller=None,
        fault_plan=None,
        recovery=None,
    ) -> None:
        if controller is None:
            if policy is None:
                raise TypeError("need a policy (classic stack) or a controller")
            controller = ClassicMinosController(policy, online_controller)
        elif policy is not None or online_controller is not None:
            raise TypeError(
                "pass either a controller or a policy/online_controller "
                "stack, not both — wrap the policy in a "
                "ClassicMinosController if you need both surfaces")
        self.backend = backend
        self.knobs = knobs
        self.controller = controller
        self.gate = getattr(controller, "gate", None)  # classic-stack view
        self.pricing = pricing
        self.rng = rng if rng is not None else np.random.RandomState(seed)
        self.loop = clock if clock is not None else SimClock()
        self.queue = InvocationQueue(fair=knobs.fair_queue)
        self.pool = InstancePool(
            order=knobs.warm_pool_order,
            concurrency=knobs.per_instance_concurrency,
            recycle_lifetime_ms=knobs.recycle_lifetime_ms,
            rng=self.rng,
            max_size=knobs.max_pool,
        )
        self.cost = WorkflowCost(pricing)
        self.results: list[RequestResult] = []
        self.instances_started = 0
        self.instances_terminated = 0
        self.instances_retired = 0    # controller RETIREs + failed re-probes
        self.reprobes = 0             # warm re-benchmarks run
        self.termination_events: list[tuple[float, float]] = []  # (t_ms, billed_ms)
        # open-loop traffic accounting (conservation: requests_arrived ==
        # len(results) + requests_dropped + queued + in-flight at any time)
        self.requests_arrived = 0
        self.requests_dropped = 0
        self.drop_events: list[tuple[float, int]] = []  # (t_ms, queue depth)
        # Welford estimates exposed through Telemetry (control plane inputs)
        self.probe_stats = Welford()      # cold probe durations (ms)
        self.log_probe_stats = Welford()  # log of the same (lognormal fit)
        self.body_stats = Welford()       # observed body durations (ms)
        self.reuse_stats = Welford()      # 1.0 warm-served / 0.0 cold-served
        # -- platform faults + recovery (DESIGN.md §15) --------------------
        # fault_plan: a repro.faults.FaultPlan (own seeded RNG stream; None
        # = the historical no-fault world, bit-identical — zero extra
        # draws). recovery: a repro.faults.RecoveryPolicy (timeouts,
        # bounded attempts, backoff); None = infinite immediate retries,
        # the pre-faults at-least-once semantics.
        self.fault_plan = fault_plan
        self.recovery = recovery
        self._seed = seed
        self._recovery_rng: Optional[np.random.RandomState] = None  # lazy
        self.fault_counts: dict[str, int] = {}       # kind -> occurrences
        self.fault_events: list[tuple[float, str, float]] = []  # (t, kind, billed)
        self.requests_dead_lettered = 0
        self.dead_letter_events: list[tuple[float, Optional[int], str]] = []
        self.failure_stats = Welford()  # per-attempt failure indicator (0/1)
        # abandoned (timed-out) attempts whose execution still holds an
        # instance slot — the sanitizer's pool-vs-executing slack term
        self._zombie_executions = 0
        # per-attempt failure hook (kind, Invocation) — the fleet router's
        # circuit breakers subscribe here; gate terminations never fire it
        self.fault_listener: Optional[Callable[[str, Invocation], None]] = None
        self.telemetry = Telemetry(self)
        # REPRO_SANITIZE=1 arms conservation/heap/immutability cross-checks
        # on this engine and its pool (repro.analysis.sanitizer). Attached
        # per instance here so benchmarks and examples get covered too,
        # not just pytest runs; a cold env check costs one dict lookup.
        from ..analysis import sanitizer as _sanitizer
        if _sanitizer.enabled():
            _sanitizer.attach_engine(self)

    def _decide(self, point: str):
        """Count the decision-point call on the controller (sweep summaries)."""
        d = getattr(self.controller, "decisions", None)
        if d is not None:
            d[point] = d.get(point, 0) + 1

    # -- compatibility views -------------------------------------------
    @property
    def policy(self):
        return getattr(self.controller, "policy", None)

    @property
    def online_controller(self):
        return getattr(self.controller, "online_controller", None)

    @property
    def benchmark_observations(self) -> list[float]:
        return getattr(self.controller, "observations", [])

    @property
    def warm_pool_speeds(self) -> tuple[float, ...]:
        return self.pool.speeds_view()

    # ------------------------------------------------------------------
    def submit(
        self,
        payload: Any,
        on_complete: Callable[[RequestResult], None] | None = None,
        *,
        submitted_at_ms: Optional[float] = None,
        qos: str = "default",
        qos_weight: float = 1.0,
        on_dead_letter: Callable[[Invocation], None] | None = None,
    ) -> bool:
        """Enqueue one invocation; returns False when the finite queue
        buffer (``SubstrateKnobs.queue_capacity``) rejects it — or when the
        :class:`~repro.faults.FaultPlan` throttles the submit or has the
        platform inside an outage window (both count as drops).

        ``submitted_at_ms`` back-dates the request's submission time (and
        therefore its reported latency/queue wait) — the open-loop driver
        uses it for items that waited at admission before being submitted.
        ``qos``/``qos_weight`` ride on the invocation; they only order
        anything under ``SubstrateKnobs.fair_queue`` (weighted-fair
        dequeue, core/queue.py). ``on_dead_letter`` fires if the request
        later exhausts its recovery budget (terminal failure) — the fleet
        router closes its logical-request ledger through it.
        """
        self.requests_arrived += 1
        plan = self.fault_plan
        if plan is not None:
            # outage is schedule (no draw); throttle is rate-gated, so it
            # is only consulted — and only draws — outside an outage
            kind = ("outage" if plan.unavailable(self.loop.now)
                    else "throttle" if plan.throttled(self.loop.now) else None)
            if kind is not None:
                self.fault_counts[kind] = self.fault_counts.get(kind, 0) + 1
                self.fault_events.append((self.loop.now, kind, 0.0))
                self.requests_dropped += 1
                self.drop_events.append((self.loop.now, len(self.queue)))
                return False
        cap = self.knobs.queue_capacity
        if cap is not None and len(self.queue) >= cap:
            self.requests_dropped += 1
            self.drop_events.append((self.loop.now, len(self.queue)))
            return False
        inv = Invocation(payload={"on_complete": on_complete, "user": payload,
                                  "on_dead_letter": on_dead_letter},
                         enqueued_at_ms=self.loop.now,
                         qos=qos, qos_weight=qos_weight)
        inv.first_enqueued_at_ms = (
            self.loop.now if submitted_at_ms is None else submitted_at_ms)
        self.queue.push(inv, self.loop.now)
        self.loop.after(0.0, self._dispatch)
        return True

    def _at_instance_cap(self) -> bool:
        """Supply exhausted: no spare instance budget for a cold start."""
        cap = self.knobs.max_instances
        return cap is not None and self.pool.n_instances >= cap

    def _dispatch(self) -> None:
        if len(self.queue) == 0:
            return
        warm = self.pool.take(self.loop.now)
        if warm is None and self._at_instance_cap():
            # no warm instance and the autoscaling cap is reached: the
            # invocation stays queued; every release/retire re-dispatches,
            # so the queue drains as capacity frees (open-loop queueing)
            return
        inv = self.queue.pop()
        if inv.first_dispatched_at_ms is None:
            inv.first_dispatched_at_ms = self.loop.now
        if warm is not None:
            self._run_on_warm(inv, warm)
        else:
            self._cold_start(inv)

    # ------------------------------------------------------------------
    def _run_on_warm(self, inv: Invocation, inst: FunctionInstance) -> None:
        t0 = self.loop.now
        self.backend.reuse_drift(inst, self.rng, t0)

        # Reuse decisions are only offered for a solo request (instance
        # load 1): REPROBE/RETIRE end the instance, which must never happen
        # under other live work (pool invariant). KEEP draws no RNG, so the
        # default controller's stream is bit-identical to the old engine.
        decision = ReuseDecision.KEEP
        if self.pool.load(inst) == 1:
            self._decide("on_reuse")
            decision = self.controller.on_reuse(ReuseContext(
                telemetry=self.telemetry,
                instance=inst,
                retry_count=inv.retry_count,
                age_ms=t0 - inst.created_at_ms,
                uses_since_probe=inst.serves_since_probe,
                ms_since_probe=(None if inst.last_probe_ms is None
                                else t0 - inst.last_probe_ms),
            ))

        if decision is ReuseDecision.RETIRE:
            # graceful despawn: nothing billed (idle-reclaim analog); the
            # request that wanted the instance cold-starts instead
            inst.state = InstanceState.EXPIRED
            self.pool.retire(inst)
            self.instances_retired += 1
            self._cold_start(inv)
            return

        bench: Optional[float] = None
        if decision is ReuseDecision.REPROBE:
            reprobe = getattr(self.backend, "reprobe", None)
            if reprobe is not None:
                bench = float(reprobe(inst, self.rng))
                self.reprobes += 1
                inst.last_probe_ms = t0
                inst.serves_since_probe = 0
                self._decide("on_probe")
                verdict = self.controller.on_probe(ProbeContext(
                    telemetry=self.telemetry, instance=inst,
                    observed_ms=bench, retry_count=inv.retry_count,
                    is_cold=False,
                ))
                if verdict is Verdict.TERMINATE:
                    # drifted below the bar: retire, requeue the request.
                    # Billed: the re-probe wall time (the instance was busy
                    # measuring itself instead of serving).
                    self.instances_retired += 1
                    inst.state = InstanceState.TERMINATED
                    self.pool.retire(inst)
                    billed = bench
                    delay = self.knobs.requeue_overhead_ms + \
                        self.backend.requeue_penalty_ms(inv.payload["user"])

                    def _retire_crash() -> None:
                        self.cost.record_terminated(billed)
                        self.termination_events.append((self.loop.now, billed))
                        self.queue.requeue(inv, self.loop.now)
                        self.loop.after(delay, self._dispatch)

                    self.loop.after(bench, _retire_crash)
                    return

        download = self.backend.prepare_ms(self.rng)
        load = self.pool.load(inst)  # in-flight count incl. this request
        analysis, output = self.backend.body(
            inv.payload["user"], inst, self.rng, load=load
        )
        mult = self.knobs.load_multiplier(load)
        if self.fault_plan is not None:
            mult *= self.fault_plan.speed_multiplier(t0)  # brownout window
        if mult != 1.0:
            analysis *= mult
        # a re-probe runs concurrently with the prepare phase (paper Fig 2
        # applied to warm reuse): body starts once both are done
        ready = download if bench is None else max(download, bench)
        duration = ready + analysis
        self._schedule_execution(
            inv, inst, pre_ms=0.0, duration=duration, download=download,
            analysis=analysis, served_by_cold=False,
            speed=None,  # warm: report speed as of completion (post-drift)
            bench=bench, output=output, billed_base=0.0)

    def _cold_start(self, inv: Invocation) -> None:
        knobs = self.knobs
        t0 = self.loop.now
        self.instances_started += 1
        speed = self.backend.sample_speed(self.rng, t0)
        inst = FunctionInstance(
            speed_factor=speed,
            created_at_ms=t0,
            idle_timeout_ms=knobs.idle_timeout_ms,
        )
        self.pool.admit_cold(inst, t0)
        cold = knobs.cold_start_ms * sample_jitter(self.rng, knobs.cold_start_jitter)
        download = self.backend.prepare_ms(self.rng)

        billed_cold = cold if knobs.bill_cold_start else 0.0
        plan = self.fault_plan

        if plan is not None and plan.cold_start_fails(t0):
            # the instance never comes up: startup time is billed (if the
            # platform bills cold starts), no user code runs, the request
            # goes through failure recovery. Not a gate termination — the
            # controller never saw this instance.
            inst.state = InstanceState.TERMINATED
            self.pool.drop(inst)
            billed = billed_cold

            def _cold_fail() -> None:
                self.cost.record_terminated(billed)
                self.fault_events.append((self.loop.now, "cold_start", billed))
                self._handle_failure(inv, "cold_start")

            self.loop.after(cold, _cold_fail)
            return

        load = self.pool.load(inst)  # 1 unless warm takes landed mid-start
        mult = self.knobs.load_multiplier(load)
        if plan is not None:
            mult *= plan.speed_multiplier(t0)  # brownout window

        self._decide("on_cold_start")
        probe_decision = self.controller.on_cold_start(ColdStartContext(
            telemetry=self.telemetry, retry_count=inv.retry_count))
        if probe_decision is ProbeDecision.SKIP:
            # baseline arm, or emergency exit: run the body directly
            inst.accept_without_benchmark()  # FORCED_PASS / baseline accept
            analysis, output = self.backend.body(
                inv.payload["user"], inst, self.rng, load=load
            )
            if mult != 1.0:
                analysis *= mult
            duration = download + analysis
            self._schedule_execution(
                inv, inst, pre_ms=cold, duration=duration, download=download,
                analysis=analysis, served_by_cold=True, speed=speed,
                bench=None, output=output, billed_base=billed_cold)
            return

        if plan is not None and plan.probe_times_out(t0):
            # the benchmark hangs: the platform kills the instance after
            # the watchdog window and bills the wait; the probe result
            # never materializes (no probe_stats update, no gate judgment
            # — the gate cannot misread an instance it never measured).
            inst.state = InstanceState.TERMINATED
            self.pool.drop(inst)
            billed = billed_cold + plan.probe_timeout_ms

            def _probe_hang() -> None:
                self.cost.record_terminated(billed)
                self.fault_events.append(
                    (self.loop.now, "probe_timeout", billed))
                self._handle_failure(inv, "probe_timeout")

            self.loop.after(cold + plan.probe_timeout_ms, _probe_hang)
            return

        # Minos path: probe runs in parallel with the prepare phase.
        bench = self.backend.probe(inst, self.rng)
        inst.last_probe_ms = t0
        inst.serves_since_probe = 0
        self.probe_stats.update(bench)
        self.log_probe_stats.update(math.log(bench))
        self._decide("on_probe")
        verdict = self.controller.on_probe(ProbeContext(
            telemetry=self.telemetry, instance=inst, observed_ms=bench,
            retry_count=inv.retry_count, is_cold=True))
        if inst.state is InstanceState.BENCHMARKING:
            # a pure-decision controller (no gate) left lifecycle to us
            inst.verdict = verdict
            inst.state = (InstanceState.TERMINATED if verdict is Verdict.TERMINATE
                          else InstanceState.WARM)
        if verdict is Verdict.TERMINATE:
            # judged as soon as the probe finishes; requeue + crash.
            # Billed: startup + probe wall time (prepare is torn down with
            # the instance; the platform bills active instance time).
            self.instances_terminated += 1
            self.pool.drop(inst)
            billed = billed_cold + bench
            delay = knobs.requeue_overhead_ms + self.backend.requeue_penalty_ms(
                inv.payload["user"]
            )

            def _crash() -> None:
                self.cost.record_terminated(billed)
                self.termination_events.append((self.loop.now, billed))
                self.queue.requeue(inv, self.loop.now)
                self.loop.after(delay, self._dispatch)

            self.loop.after(cold + bench, _crash)
            return

        # passed (or forced): body starts once BOTH prepare and probe done
        analysis, output = self.backend.body(
            inv.payload["user"], inst, self.rng, load=load
        )
        if mult != 1.0:
            analysis *= mult
        ready = max(download, bench)
        duration = ready + analysis
        self._schedule_execution(
            inv, inst, pre_ms=cold, duration=duration, download=download,
            analysis=analysis, served_by_cold=True, speed=speed,
            bench=bench, output=output, billed_base=billed_cold)

    # -- in-flight phase + failure recovery (DESIGN.md §15) -------------
    def _schedule_execution(
        self,
        inv: Invocation,
        inst: FunctionInstance,
        *,
        pre_ms: float,
        duration: float,
        download: float,
        analysis: float,
        served_by_cold: bool,
        speed: Optional[float],
        bench: Optional[float],
        output: Any,
        billed_base: float,
    ) -> None:
        """Schedule the in-flight phase of one dispatch attempt.

        Without a :class:`~repro.faults.FaultPlan` this performs exactly
        the historical completion (serve → bill → release → finish →
        dispatch) at ``pre_ms + duration``, with zero extra RNG draws.
        With one, the attempt's fate is drawn up front from the plan's
        private stream: a mid-body crash bills the *partial* duration
        (Fig-3 ``d_term``) and loses the work; a lost completion bills the
        *full* duration but never delivers the result. Either way the
        request goes through :meth:`_handle_failure`.

        ``inv.dispatch_epoch`` is captured here; a
        :class:`~repro.faults.RecoveryPolicy` timeout that fires first
        bumps it, turning this attempt into a zombie — its completion (or
        crash) still bills and frees the instance, but is dropped exactly
        once, never finished (idempotent re-dispatch: a retried request
        can never double-count). ``speed=None`` reports the instance's
        speed as of completion time (warm path: post-drift), matching the
        historical closure semantics bit-for-bit.
        """
        t0 = self.loop.now
        epoch = inv.dispatch_epoch
        plan = self.fault_plan
        crash_frac: Optional[float] = None
        lost = False
        if plan is not None:
            crash_frac = plan.crash_mid_body(t0)
            if crash_frac is None:
                lost = plan.completion_lost(t0)

        if crash_frac is not None:
            run_ms = pre_ms + crash_frac * duration
            billed = billed_base + crash_frac * duration

            def _crash_mid_body() -> None:
                now = self.loop.now
                self.cost.record_terminated(billed)
                self.fault_events.append((now, "crash", billed))
                if served_by_cold:
                    inst.state = InstanceState.TERMINATED
                    self.pool.drop(inst)
                elif self.pool.load(inst) <= 1:
                    inst.state = InstanceState.TERMINATED
                    self.pool.retire(inst)
                else:
                    # other requests live on this instance: take the fault
                    # at execution scope (never-kill-under-live-work)
                    self.pool.release(inst, now)
                if inv.dispatch_epoch != epoch:
                    self._zombie_executions -= 1  # abandoned before crashing
                    self._dispatch()
                    return
                self._handle_failure(inv, "crash")

            self.loop.after(run_ms, _crash_mid_body)
            self._maybe_schedule_abandon(inv, epoch, t0 + run_ms)
            return

        def _complete() -> None:
            now = self.loop.now
            inst.serve(now)
            if served_by_cold:
                self.cost.record_passed(billed_base + duration)
            else:
                self.cost.record_reused(duration)
            self.pool.release(inst, now)
            if inv.dispatch_epoch != epoch:
                # timed-out attempt: billed, instance freed, result
                # discarded — the retry owns the request now
                self._zombie_executions -= 1
                self.fault_events.append((now, "stale_completion", 0.0))
                self._dispatch()
                return
            if lost:
                # the body ran (and is billed) but the completion
                # notification vanished; detected when it would have been
                # delivered (stand-in for a client acknowledgment timer)
                self.fault_events.append((now, "lost", 0.0))
                self._handle_failure(inv, "lost")
                return
            self._finish(inv, t0, download, analysis,
                         served_by_cold=served_by_cold,
                         speed=inst.speed_factor if speed is None else speed,
                         bench=bench, output=output)
            self._dispatch()

        self.loop.after(pre_ms + duration, _complete)
        if not lost:
            self._maybe_schedule_abandon(inv, epoch, t0 + pre_ms + duration)

    def _maybe_schedule_abandon(
        self, inv: Invocation, epoch: int, t_end_abs: float,
    ) -> None:
        """Arm the per-request timeout: if this attempt would resolve past
        ``first_enqueued + RecoveryPolicy.timeout_ms``, abandon it at the
        deadline (the execution keeps running as a billed zombie)."""
        rec = self.recovery
        if rec is None or rec.timeout_ms is None:
            return
        base = inv.first_enqueued_at_ms
        deadline = (0.0 if base is None else base) + rec.timeout_ms
        if t_end_abs <= deadline:
            return

        def _abandon() -> None:
            if inv.dispatch_epoch != epoch:
                return  # attempt already resolved another way
            self._zombie_executions += 1
            self.fault_events.append((self.loop.now, "timeout", 0.0))
            self._handle_failure(inv, "timeout")

        self.loop.after(max(0.0, deadline - self.loop.now), _abandon)

    def _handle_failure(self, inv: Invocation, kind: str) -> None:
        """One dispatch attempt failed (``kind``: crash / cold_start /
        probe_timeout / lost / timeout): consult the controller's
        ``on_failure`` decision point, then retry with backoff or
        dead-letter. ``RecoveryPolicy.max_attempts`` bounds total attempts
        regardless of the controller's answer."""
        inv.dispatch_epoch += 1
        inv.failed_attempts += 1
        self.fault_counts[kind] = self.fault_counts.get(kind, 0) + 1
        self.failure_stats.update(1.0)
        if self.fault_listener is not None:
            self.fault_listener(kind, inv)
        decision = FailureDecision.RETRY
        on_failure = getattr(self.controller, "on_failure", None)
        if on_failure is not None:
            self._decide("on_failure")
            first = inv.first_enqueued_at_ms
            decision = on_failure(FailureContext(
                telemetry=self.telemetry,
                kind=kind,
                invocation_id=inv.invocation_id,
                attempts=inv.failed_attempts,
                elapsed_ms=(0.0 if first is None
                            else self.loop.now - first),
                qos=inv.qos,
            ))
        rec = self.recovery
        if rec is not None and inv.failed_attempts >= rec.max_attempts:
            decision = FailureDecision.DEAD_LETTER
        if decision is FailureDecision.DEAD_LETTER:
            self._dead_letter(inv, kind)
            return
        self.queue.requeue(inv, self.loop.now)
        delay = self.knobs.requeue_overhead_ms + \
            self.backend.requeue_penalty_ms(inv.payload["user"])
        if rec is not None:
            delay += self._backoff_ms(inv)
        self.loop.after(delay, self._dispatch)

    def _backoff_ms(self, inv: Invocation) -> float:
        """Capped decorrelated-jitter backoff, drawn from a private RNG
        stream (retry jitter must not shift the engine's own draws)."""
        rec = self.recovery
        if rec is None or rec.backoff_base_ms <= 0.0:
            return 0.0
        if self._recovery_rng is None:
            self._recovery_rng = np.random.RandomState(
                (self._seed ^ 0x9E3779B9) & 0xFFFFFFFF)
        delay = decorrelated_jitter_ms(
            self._recovery_rng, inv.backoff_ms,
            base_ms=rec.backoff_base_ms, cap_ms=rec.backoff_cap_ms)
        inv.backoff_ms = delay
        return delay

    def _dead_letter(self, inv: Invocation, kind: str) -> None:
        """Terminal failure: the request leaves the system unserved (and
        is conserved as ``requests_dead_lettered``, not as a drop)."""
        self.requests_dead_lettered += 1
        self.dead_letter_events.append(
            (self.loop.now, inv.invocation_id, kind))
        cb = inv.payload.get("on_dead_letter")
        if cb is not None:
            cb(inv)
        self._dispatch()

    # ------------------------------------------------------------------
    def _finish(
        self, inv: Invocation, t0: float, download: float, analysis: float,
        *, served_by_cold: bool, speed: float, bench: Optional[float],
        output: Any = None,
    ) -> None:
        res = RequestResult(
            invocation_id=inv.invocation_id,
            # NB: 0.0 is a valid submit time — only None falls back to t0
            t_submitted_ms=t0 if inv.first_enqueued_at_ms is None else inv.first_enqueued_at_ms,
            t_completed_ms=self.loop.now,
            download_ms=download,
            analysis_ms=analysis,
            retries=inv.terminations_experienced,
            served_by_cold=served_by_cold,
            instance_speed=speed,
            benchmark_ms=bench,
            output=output,
            queue_wait_ms=(
                0.0 if inv.first_dispatched_at_ms is None
                or inv.first_enqueued_at_ms is None
                else max(0.0, inv.first_dispatched_at_ms - inv.first_enqueued_at_ms)),
        )
        self.results.append(res)
        # control-plane estimator feed (Telemetry reads these Welfords)
        self.reuse_stats.update(0.0 if served_by_cold else 1.0)
        self.body_stats.update(analysis)
        self.failure_stats.update(0.0)  # a successfully finished attempt
        self._decide("on_release")
        self.controller.on_release(ReleaseContext(
            telemetry=self.telemetry, result=res))
        cb = inv.payload.get("on_complete")
        if cb is not None:
            cb(res)
