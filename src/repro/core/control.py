"""The control-plane API: one ``Controller`` surface for every Minos
decision (DESIGN.md §10).

Minos is a *decision loop* — benchmark an instance, keep it or crash it,
and let the warm pool compound the gains (paper §III–IV). Before this
module the loop's decisions were smeared across five surfaces
(``ElysiumGate.judge``, the two policies, ``OnlineElysiumController``,
static ``Stage.max_in_flight`` and the ``gate_load_aware`` knob), which is
why every ROADMAP item that needed a new decision — adaptive pass
fraction, queue-aware admission, re-probing under drift — had no place to
live. Now the :class:`~repro.core.substrate.SubstrateEngine` (and the
workflow layer's admission path) calls exactly one interface:

* :meth:`Controller.on_cold_start` → :class:`ProbeDecision` — benchmark a
  fresh instance, or accept it unjudged (baseline arm, emergency exit);
* :meth:`Controller.on_probe` → :class:`~repro.core.policy.Verdict` — the
  elysium gate: judge a probe observation (cold, or a warm re-probe);
* :meth:`Controller.on_reuse` → :class:`ReuseDecision` — on warm reuse:
  keep serving, re-probe the drifted certification, or retire the
  instance (the drift-recovery hook, ROADMAP: re-probing under drift);
* :meth:`Controller.on_admit` → :class:`AdmitDecision` — per-stage
  admission back-pressure (``Stage.max_in_flight`` is now just the static
  special case);
* :meth:`Controller.on_release` — a request completed; estimator feedback.

Every decision point receives a context carrying a read-only
:class:`Telemetry` view of the live engine: pool load/occupancy, queue
depth, the clock, and Welford reuse-rate / probe / body estimates the
engine maintains — everything a policy needs to close its loop online,
nothing it could corrupt.

The old surfaces survive as thin adapters: :class:`ClassicMinosController`
wraps an :class:`ElysiumGate` (policy + optional
:class:`~repro.core.elysium.OnlineElysiumController`) and reproduces the
pre-control-plane behavior bit-identically (the seeded golden digests in
tests/test_unified_substrate.py run through it). On top, three concrete
controllers close ROADMAP open items: :class:`PassFractionController`
(live Welford estimates → ``optimal_pass_fraction`` → threshold),
:class:`QueueAwareAdmissionController` (dynamic per-stage admission from
queue depth / pool occupancy) and :class:`ReprobeController` (cheap warm
re-benchmark once the certified speed's drift half-life expires).
"""
from __future__ import annotations

import dataclasses
import math
import warnings
from enum import Enum
from typing import Any, Optional, Protocol, runtime_checkable

import numpy as np

from .elysium import optimal_pass_fraction
from .estimators import EMA
from .lifecycle import FunctionInstance
from .policy import Verdict


# ---------------------------------------------------------------------------
# Decisions
# ---------------------------------------------------------------------------


class ProbeDecision(Enum):
    """What to do with a freshly placed (cold) instance."""

    PROBE = "probe"  # run the benchmark, then judge at on_probe
    SKIP = "skip"    # accept without benchmarking (baseline / emergency exit)


class ReuseDecision(Enum):
    """What to do with a warm instance about to serve a reused request."""

    KEEP = "keep"        # paper §II-B: reuse without re-benchmarking
    REPROBE = "reprobe"  # re-benchmark the (possibly drifted) certification
    RETIRE = "retire"    # despawn gracefully; the request cold-starts instead


class AdmitDecision(Enum):
    """Whether a workflow item may enter a stage now."""

    ADMIT = "admit"
    DEFER = "defer"  # wait at the admission queue (back-pressure)


class FailureDecision(Enum):
    """What to do with a request whose dispatch attempt failed (platform
    fault: crash / cold-start failure / probe timeout / lost completion /
    per-request timeout — DESIGN.md §15)."""

    RETRY = "retry"              # re-queue (engine applies backoff)
    DEAD_LETTER = "dead_letter"  # terminal: stop retrying, count + surface


# ---------------------------------------------------------------------------
# Telemetry — the read-only view every decision point receives
# ---------------------------------------------------------------------------


class Telemetry:
    """Live, read-only view of one engine's observable state.

    Not a snapshot: every property reads through to the engine at call
    time, so a controller asking mid-run sees exactly what
    ``InstancePool.load`` / ``total_in_flight`` / ``len(queue)`` would
    report (tested in tests/test_control_plane.py). Mutation raises —
    controllers decide, engines act.
    """

    __slots__ = ("_engine",)

    def __init__(self, engine: Any) -> None:
        object.__setattr__(self, "_engine", engine)

    def __setattr__(self, name: str, value: Any) -> None:
        raise AttributeError("Telemetry is read-only")

    def __delattr__(self, name: str) -> None:
        raise AttributeError("Telemetry is read-only")

    # -- clock / hosting -------------------------------------------------
    @property
    def now_ms(self) -> float:
        return self._engine.loop.now

    @property
    def knobs(self):
        """The engine's (frozen) :class:`~repro.core.substrate.SubstrateKnobs`."""
        return self._engine.knobs

    # -- pool ------------------------------------------------------------
    @property
    def pool_available(self) -> int:
        """Warm instances with spare request capacity."""
        return len(self._engine.pool)

    @property
    def pool_instances(self) -> int:
        """Live instances (available + at-capacity serving ones)."""
        return self._engine.pool.n_instances

    @property
    def total_in_flight(self) -> int:
        return self._engine.pool.total_in_flight

    @property
    def mean_load(self) -> float:
        return self._engine.pool.mean_load()

    @property
    def pool_speeds(self) -> tuple[float, ...]:
        """Certified speeds of pooled instances — the pool's cached
        immutable view (no per-read list rebuild; PR 5)."""
        return self._engine.pool.speeds_view()

    @property
    def pool_warm(self) -> int:
        """Pooled WARM instances (len of :attr:`pool_speeds`, O(1))."""
        return self._engine.pool.n_warm

    def pool_speed_quantile(self, q: float) -> float:
        """q-quantile of the pooled certified speeds (nan when empty) —
        what a gate needs instead of the full speeds list."""
        return self._engine.pool.certified_speed_quantile(q)

    def instance_load(self, inst: FunctionInstance) -> int:
        return self._engine.pool.load(inst)

    # -- queue -----------------------------------------------------------
    @property
    def queue_depth(self) -> int:
        """Invocations waiting on the engine's own queue (requeues incl.)."""
        return len(self._engine.queue)

    # -- open-loop pressure (DESIGN.md §12) ------------------------------
    @property
    def n_arrived(self) -> int:
        """Requests submitted to the engine (accepted + dropped)."""
        return getattr(self._engine, "requests_arrived", 0)

    @property
    def n_dropped(self) -> int:
        """Requests refused at submit because the finite queue
        (``SubstrateKnobs.queue_capacity``) was full."""
        return getattr(self._engine, "requests_dropped", 0)

    # -- platform faults (DESIGN.md §15) ---------------------------------
    @property
    def n_failures(self) -> int:
        """Failed dispatch attempts (crashes, cold-start failures, probe
        timeouts, lost completions, request timeouts) — per-attempt, so a
        request retried twice counts twice."""
        counts = getattr(self._engine, "fault_counts", None)
        return sum(counts.values()) if counts else 0

    @property
    def failure_rate(self) -> float:
        """Failed fraction of finished dispatch attempts (Welford mean of
        the engine's failure indicator stream; 0.0 before any attempt)."""
        s = getattr(self._engine, "failure_stats", None)
        return s.mean if s is not None and s.count else 0.0

    @property
    def n_dead_lettered(self) -> int:
        """Requests that exhausted their attempt budget (terminal)."""
        return getattr(self._engine, "requests_dead_lettered", 0)

    # -- streaming estimates (Welford; maintained by the engine) ---------
    @property
    def n_probes(self) -> int:
        """Cold-start probes observed (warm re-probes excluded)."""
        return self._engine.probe_stats.count

    @property
    def probe_mean_ms(self) -> float:
        s = self._engine.probe_stats
        return s.mean if s.count else float("nan")

    @property
    def probe_std_ms(self) -> float:
        return self._engine.probe_stats.std

    @property
    def probe_log_mean(self) -> float:
        s = self._engine.log_probe_stats
        return s.mean if s.count else float("nan")

    @property
    def probe_log_std(self) -> float:
        """Std of log probe durations ≈ the speed distribution's lognormal
        sigma (plus observation noise) — what the §II-A trade-off needs."""
        return self._engine.log_probe_stats.std

    @property
    def n_requests(self) -> int:
        """Requests completed so far."""
        return self._engine.reuse_stats.count

    @property
    def reuse_rate(self) -> float:
        """Fraction of completed requests served by a warm (reused)
        instance — the live estimate of how often certification pays."""
        s = self._engine.reuse_stats
        return s.mean if s.count else 0.0

    @property
    def expected_reuses(self) -> float:
        """Expected serves per pooled instance beyond its first,
        ≈ r/(1−r) for reuse rate r (geometric reuse chain)."""
        r = min(self.reuse_rate, 0.98)
        return r / (1.0 - r)

    @property
    def body_mean_ms(self) -> float:
        s = self._engine.body_stats
        return s.mean if s.count else float("nan")


class FleetTelemetry:
    """Read-only view over N engines' :class:`Telemetry` views — what a
    fleet-level :class:`~repro.fleet.policies.RoutingPolicy` receives
    (DESIGN.md §14).

    Same contract as :class:`Telemetry`, one level up: every read flows
    through the per-fleet views to the live engines (no snapshots), and
    mutation raises — routing policies decide, the
    :class:`~repro.fleet.router.FleetRouter` acts. Aggregates are plain
    per-fleet tuples so a policy can score fleets without ever touching an
    engine handle.
    """

    __slots__ = ("_views", "_names")

    def __init__(self, views: Any, names: Optional[Any] = None) -> None:
        views = tuple(views)
        if not views:
            raise ValueError("FleetTelemetry needs at least one fleet view")
        if names is None:
            names = tuple(f"fleet{i}" for i in range(len(views)))
        else:
            names = tuple(names)
            if len(names) != len(views):
                raise ValueError("names/views length mismatch")
        object.__setattr__(self, "_views", views)
        object.__setattr__(self, "_names", names)

    def __setattr__(self, name: str, value: Any) -> None:
        raise AttributeError("FleetTelemetry is read-only")

    def __delattr__(self, name: str) -> None:
        raise AttributeError("FleetTelemetry is read-only")

    def __len__(self) -> int:
        return len(self._views)

    def __iter__(self):
        return iter(self._views)

    @property
    def names(self) -> tuple[str, ...]:
        return self._names

    def fleet(self, i: int) -> Telemetry:
        """The i-th fleet's own read-only view."""
        return self._views[i]

    # -- clock (the router runs every fleet on ONE SimClock) -------------
    @property
    def now_ms(self) -> float:
        return self._views[0].now_ms

    # -- per-fleet aggregate tuples (policy scoring inputs) --------------
    def queue_depths(self) -> tuple[int, ...]:
        return tuple(v.queue_depth for v in self._views)

    def in_flights(self) -> tuple[int, ...]:
        return tuple(v.total_in_flight for v in self._views)

    def pool_availables(self) -> tuple[int, ...]:
        return tuple(v.pool_available for v in self._views)

    def capacity_slots(self) -> tuple[int, ...]:
        """Concurrent-request slots each fleet can hold: the autoscaling
        cap × per-instance concurrency when ``max_instances`` is set, else
        the live instance count (elastic supply; floored at 1 slot so an
        idle uncapped fleet still scores as able to serve)."""
        out = []
        for v in self._views:
            cap = v.knobs.max_instances
            n = cap if cap is not None else max(v.pool_instances, 1)
            out.append(n * v.knobs.per_instance_concurrency)
        return tuple(out)

    # -- fleet-wide totals ------------------------------------------------
    @property
    def total_queue_depth(self) -> int:
        return sum(self.queue_depths())

    @property
    def total_in_flight(self) -> int:
        return sum(self.in_flights())


# ---------------------------------------------------------------------------
# Decision contexts
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ColdStartContext:
    telemetry: Telemetry
    retry_count: int


@dataclasses.dataclass(frozen=True)
class ProbeContext:
    telemetry: Telemetry
    instance: FunctionInstance
    observed_ms: float
    retry_count: int
    is_cold: bool = True  # False: warm re-probe (ReuseDecision.REPROBE)


@dataclasses.dataclass(frozen=True)
class ReuseContext:
    telemetry: Telemetry
    instance: FunctionInstance
    retry_count: int
    age_ms: float
    uses_since_probe: int
    ms_since_probe: Optional[float]  # None: never probed (forced pass)


@dataclasses.dataclass(frozen=True)
class AdmitContext:
    telemetry: Telemetry
    in_flight: int               # items admitted to the stage, not completed
    bound: Optional[int]         # the stage's static max_in_flight (if any)
    admission_queue_depth: int   # items already deferred at admission


@dataclasses.dataclass(frozen=True)
class FailureContext:
    """A dispatch attempt failed (DESIGN.md §15). ``attempts`` counts
    failed attempts so far (>= 1); ``elapsed_ms`` is measured from the
    request's first enqueue. The engine still enforces
    ``RecoveryPolicy.max_attempts`` after the controller answers, so a
    RETRY past the budget dead-letters anyway."""

    telemetry: Telemetry
    kind: str                      # "crash" | "cold_start" | "probe_timeout" | "lost" | "timeout"
    invocation_id: Optional[int]
    attempts: int
    elapsed_ms: float
    qos: str = "default"


@dataclasses.dataclass(frozen=True)
class ReleaseContext:
    telemetry: Telemetry
    result: Any  # the completed RequestResult


#: The six decision points, in request-lifecycle order.
DECISION_POINTS = (
    "on_cold_start", "on_probe", "on_reuse", "on_admit", "on_failure",
    "on_release",
)


# ---------------------------------------------------------------------------
# The Controller protocol
# ---------------------------------------------------------------------------


@runtime_checkable
class Controller(Protocol):
    """What the engines call. Controllers decide; engines act (lifecycle
    transitions, billing, requeues stay engine-owned — a controller that
    returns TERMINATE never touches instance state itself)."""

    name: str

    def on_cold_start(self, ctx: ColdStartContext) -> ProbeDecision: ...

    def on_probe(self, ctx: ProbeContext) -> Verdict: ...

    def on_reuse(self, ctx: ReuseContext) -> ReuseDecision: ...

    def on_admit(self, ctx: AdmitContext) -> AdmitDecision: ...

    def on_failure(self, ctx: "FailureContext") -> FailureDecision: ...

    def on_release(self, ctx: ReleaseContext) -> None: ...


class ControllerBase:
    """Shared plumbing: per-decision-point counters (``decisions``) and the
    default answers — probe everything, pass everything, keep warm
    instances, honor the static ``Stage.max_in_flight`` bound.

    ``decisions`` is incremented by the engines (one count per call), so
    sweeps can print which controller handled each decision point
    (``benchmarks/run.py`` per-arm summary)."""

    name = "controller"

    def __init__(self) -> None:
        self.decisions: dict[str, int] = {}

    # -- reporting -------------------------------------------------------
    def handler_name(self, point: str) -> str:
        """Which controller actually answers ``point`` (wrappers delegate)."""
        return self.name

    def decision_summary(self) -> str:
        """``point=handler×count`` per exercised decision point."""
        return "|".join(
            f"{p}={self.handler_name(p)}x{self.decisions[p]}"
            for p in DECISION_POINTS if p in self.decisions
        )

    # -- default decisions ----------------------------------------------
    def on_cold_start(self, ctx: ColdStartContext) -> ProbeDecision:
        return ProbeDecision.PROBE

    def on_probe(self, ctx: ProbeContext) -> Verdict:
        return Verdict.PASS

    def on_reuse(self, ctx: ReuseContext) -> ReuseDecision:
        return ReuseDecision.KEEP

    def on_admit(self, ctx: AdmitContext) -> AdmitDecision:
        # the static Stage.max_in_flight bound, as a controller decision
        if ctx.bound is not None and ctx.in_flight >= ctx.bound:
            return AdmitDecision.DEFER
        return AdmitDecision.ADMIT

    def on_failure(self, ctx: FailureContext) -> FailureDecision:
        # retry by default; the engine's RecoveryPolicy.max_attempts still
        # bounds total attempts regardless of this answer
        return FailureDecision.RETRY

    def on_release(self, ctx: ReleaseContext) -> None:
        return None


class DelegatingController(ControllerBase):
    """Base for wrapper controllers that override a single decision point
    and forward everything else (including attribute access — ``gate``,
    ``policy``, ``observations`` — so engine compatibility views keep
    working through any wrapper stack)."""

    def __init__(self, inner) -> None:
        super().__init__()
        self.inner = inner

    def __getattr__(self, name: str):
        # only reached for attributes not found on the wrapper itself
        return getattr(self.inner, name)

    def handler_name(self, point: str) -> str:
        return self.inner.handler_name(point) if hasattr(self.inner, "handler_name") \
            else getattr(self.inner, "name", type(self.inner).__name__)

    def on_cold_start(self, ctx: ColdStartContext) -> ProbeDecision:
        return self.inner.on_cold_start(ctx)

    def on_probe(self, ctx: ProbeContext) -> Verdict:
        return self.inner.on_probe(ctx)

    def on_reuse(self, ctx: ReuseContext) -> ReuseDecision:
        return self.inner.on_reuse(ctx)

    def on_admit(self, ctx: AdmitContext) -> AdmitDecision:
        return self.inner.on_admit(ctx)

    def on_failure(self, ctx: FailureContext) -> FailureDecision:
        # pre-faults controllers may not implement on_failure; default RETRY
        fn = getattr(self.inner, "on_failure", None)
        return fn(ctx) if fn is not None else FailureDecision.RETRY

    def on_release(self, ctx: ReleaseContext) -> None:
        return self.inner.on_release(ctx)


# ---------------------------------------------------------------------------
# ElysiumGate — now a thin adapter the classic controller wraps
# ---------------------------------------------------------------------------

_gate_kwarg_warned = False


class ElysiumGate:
    """The Minos pass/terminate decision point (paper §II–§IV).

    Owns the probe-observation stream: every cold-start probe result is
    recorded and — before judging — reported to the online controller
    (§IV: passing AND failing probes, otherwise the estimate is
    survivor-biased) or to an :class:`~repro.core.policy.AdaptiveMinosPolicy`
    (anything with a ``report`` method — the policy IS the controller,
    DESIGN.md §6). The instance then judges itself against the latest
    published threshold.

    .. deprecated:: PR 4
        Constructing the gate directly with ``online_controller=...`` is
        deprecated — build a :class:`ClassicMinosController` (which owns a
        gate) and hand it to the engine instead; behavior is bit-identical.
    """

    def __init__(self, policy, online_controller=None, *,
                 _from_controller: bool = False) -> None:
        if online_controller is not None and not dataclasses.is_dataclass(policy):
            # judging with a separate controller rebinds the policy's
            # threshold via dataclasses.replace — impossible for a mutable
            # policy like AdaptiveMinosPolicy, which IS its own controller.
            raise TypeError(
                "online_controller requires a dataclass policy (e.g. "
                f"MinosPolicy); got {type(policy).__name__}. An adaptive "
                "policy already maintains its threshold online — pass it "
                "alone, without a separate controller."
            )
        if online_controller is not None and not _from_controller:
            global _gate_kwarg_warned
            if not _gate_kwarg_warned:
                _gate_kwarg_warned = True
                warnings.warn(
                    "ElysiumGate(online_controller=...) is deprecated; wrap "
                    "policy + controller in a ClassicMinosController and pass "
                    "it to the engine (behavior is identical).",
                    DeprecationWarning, stacklevel=2,
                )
        self.policy = policy
        self.online_controller = online_controller
        self.observations: list[float] = []

    def should_probe(self, retry_count: int, *, is_cold_start: bool = True) -> bool:
        return self.policy.should_benchmark(retry_count, is_cold_start=is_cold_start)

    def _effective_policy(self):
        """The policy at the latest published threshold (no reporting)."""
        if self.online_controller is not None:
            return dataclasses.replace(
                self.policy, elysium_threshold=self.online_controller.threshold
            )
        return self.policy

    @staticmethod
    def _effective_observation(policy, observed_ms: float, load_factor: float) -> float:
        """Fold pool occupancy into the judged value: durations inflate
        under load; throughput-style metrics deflate."""
        if load_factor == 1.0:
            return observed_ms
        if getattr(policy, "higher_is_better", False):
            return observed_ms / load_factor
        return observed_ms * load_factor

    def judge(
        self,
        inst: FunctionInstance,
        observed_ms: float,
        retry_count: int,
        *,
        load_factor: float = 1.0,
    ) -> Verdict:
        """Judge ``inst`` on its cold-start probe result.

        ``load_factor`` > 1 folds the pool's current occupancy into the
        decision (ROADMAP: concurrency-aware gating): the instance is
        judged on the *effective* duration ``observed × load_factor`` —
        the speed a request will actually see under the load-slowdown
        model — not the unloaded cold-start probe speed, so certification
        reflects what the replica can sustain at the occupancy it is about
        to serve. At load 1 this is exactly the paper's gate. The raw
        observation is what is recorded and reported to the controller, so
        threshold estimation stays in unloaded-probe units. The trade-off
        is measured in EXPERIMENTS.md: under frozen certified speeds
        (§Load-aware pipeline sweep) effective-speed gating preserves the
        body-latency gains under real self-contention; under per-serve
        contention drift with a long-lived concurrent pool (§Diurnal
        sweep, load arms) the extra selectivity cannot pay for its churn.
        """
        self.observations.append(observed_ms)
        if self.online_controller is not None:
            self.online_controller.report(observed_ms)
        elif hasattr(self.policy, "report"):
            self.policy.report(observed_ms)
        policy = self._effective_policy()  # threshold AFTER this report
        if load_factor != 1.0:
            inst.benchmark_result = self._effective_observation(
                policy, observed_ms, load_factor)
        return inst.judge(policy, retry_count)

    def rejudge(
        self,
        inst: FunctionInstance,
        observed_ms: float,
        retry_count: int,
        *,
        load_factor: float = 1.0,
    ) -> Verdict:
        """Judge a WARM instance's re-probe against the current threshold.

        Unlike :meth:`judge`, the observation is neither recorded nor
        reported: a re-probe measures a *drifted, in-service* instance,
        and feeding it to the threshold estimators would mix that
        population into the cold-start distribution the pass quantile is
        defined over. No lifecycle transition happens here either — the
        engine retires the instance if the verdict is TERMINATE."""
        policy = self._effective_policy()
        eff = self._effective_observation(policy, observed_ms, load_factor)
        inst.benchmark_result = eff
        if not getattr(policy, "enabled", True):
            return Verdict.PASS
        if retry_count >= getattr(policy, "max_retries", 0):
            return Verdict.FORCED_PASS
        return Verdict.PASS if policy.passes(eff) else Verdict.TERMINATE


# ---------------------------------------------------------------------------
# ClassicMinosController — the default; bit-identical to the old stack
# ---------------------------------------------------------------------------


class ClassicMinosController(ControllerBase):
    """The pre-control-plane decision stack as a :class:`Controller`.

    Policy (fixed or adaptive) + optional
    :class:`~repro.core.elysium.OnlineElysiumController` + the
    ``gate_load_aware`` knob, expressed through the new API. This is the
    engine default; the seeded golden-parity digests
    (tests/test_unified_substrate.py) pin it to the old behavior
    bit-for-bit: same RNG stream, same verdicts, same timings."""

    def __init__(self, policy, online_controller=None) -> None:
        super().__init__()
        self.gate = ElysiumGate(policy, online_controller, _from_controller=True)
        self.name = f"classic[{type(policy).__name__}]"

    # -- compatibility views --------------------------------------------
    @property
    def policy(self):
        return self.gate.policy

    @property
    def online_controller(self):
        return self.gate.online_controller

    @property
    def observations(self) -> list[float]:
        return self.gate.observations

    # -- decisions -------------------------------------------------------
    def _load_factor(self, t: Telemetry) -> float:
        if t.knobs.gate_load_aware:
            # judge at the pool's current occupancy: the certified speed
            # must hold up under the load the replica will actually serve
            return t.knobs.load_multiplier(t.mean_load)
        return 1.0

    def on_cold_start(self, ctx: ColdStartContext) -> ProbeDecision:
        if self.gate.should_probe(ctx.retry_count, is_cold_start=True):
            return ProbeDecision.PROBE
        return ProbeDecision.SKIP

    def on_probe(self, ctx: ProbeContext) -> Verdict:
        lf = self._load_factor(ctx.telemetry)
        if ctx.is_cold:
            return self.gate.judge(ctx.instance, ctx.observed_ms,
                                   ctx.retry_count, load_factor=lf)
        return self.gate.rejudge(ctx.instance, ctx.observed_ms,
                                 ctx.retry_count, load_factor=lf)


# ---------------------------------------------------------------------------
# Lognormal selection math (shared by PassFractionController and tests)
# ---------------------------------------------------------------------------


def _norm_cdf(x: float) -> float:
    return 0.5 * (1.0 + math.erf(x / math.sqrt(2.0)))


def _norm_ppf(p: float) -> float:
    """Inverse standard-normal CDF by bisection (Φ is monotone; 60 steps
    give ~1e-16 interval width — far below what a threshold needs)."""
    if not 0.0 < p < 1.0:
        raise ValueError("p must be in (0,1)")
    lo, hi = -10.0, 10.0
    for _ in range(60):
        mid = 0.5 * (lo + hi)
        if _norm_cdf(mid) < p:
            lo = mid
        else:
            hi = mid
    return 0.5 * (lo + hi)


def lognormal_pool_speedup(pass_fraction: float, log_sigma: float) -> float:
    """Mean-body-time speedup of keeping only the fastest ``pass_fraction``
    when probe/body durations are lognormal with log-std ``log_sigma``.

    For d ~ LogNormal(μ, σ²), E[d | d ≤ q_f] = e^{μ+σ²/2}·Φ(z_f − σ)/f with
    z_f = Φ⁻¹(f), so speedup(f) = E[d]/E[d | selected] = f / Φ(z_f − σ).
    Monotone in σ, → 1 as f → 1 or σ → 0 — the closed form of "mean speed
    of the top-f fraction" the §II-A trade-off needs, computable from two
    Welford moments instead of a stored sample."""
    if not 0.0 < pass_fraction < 1.0:
        raise ValueError("pass_fraction must be in (0,1)")
    if log_sigma <= 0.0:
        return 1.0
    z = _norm_ppf(pass_fraction)
    return pass_fraction / max(_norm_cdf(z - log_sigma), 1e-12)


# ---------------------------------------------------------------------------
# PassFractionController — ROADMAP: adaptive pass fraction
# ---------------------------------------------------------------------------


class PassFractionController(ControllerBase):
    """Closes the §II-A loop online: the pass *fraction* (not just the
    threshold) adapts to the live workload.

    Every ``update_every`` cold probes it re-solves
    :func:`~repro.core.elysium.optimal_pass_fraction` with the engine's
    Welford estimates — probe mean (selection waste), body mean (the work
    a faster instance accelerates), reuse rate (how often certification
    amortizes) and the probe log-std (the platform's variability, feeding
    :func:`lognormal_pool_speedup`) — then republishes the threshold at
    the chosen quantile of the fitted lognormal probe distribution,
    EMA-smoothed. Duration metrics only (lower is better).

    This is "the optimal termination rate depends on the duration of the
    workload, the performance variability of the platform, and the
    relative time of the benchmark" (paper §II-A), closed with live data:
    high churn / low reuse pushes the fraction up (probing waste dominates),
    long bodies and high variability push it down (selectivity pays)."""

    def __init__(
        self,
        initial_pass_fraction: float = 0.4,
        *,
        max_retries: int = 5,
        warmup_reports: int = 5,
        update_every: int = 8,
        fractions: Optional[tuple[float, ...]] = None,
        smoothing_alpha: float = 0.5,
        min_fraction: float = 0.05,
        max_fraction: float = 0.95,
    ) -> None:
        super().__init__()
        if not 0.0 < initial_pass_fraction < 1.0:
            raise ValueError("initial_pass_fraction must be in (0,1)")
        if update_every < 1:
            raise ValueError("update_every must be >= 1")
        self.name = "pass-fraction"
        self.pass_fraction = initial_pass_fraction
        self.max_retries = max_retries
        self.warmup_reports = warmup_reports
        self.update_every = update_every
        self.fractions = tuple(fractions) if fractions is not None else tuple(
            float(f) for f in np.linspace(min_fraction, max_fraction, 19))
        self._ema = EMA(smoothing_alpha, None)
        self.threshold: Optional[float] = None
        self.observations: list[float] = []
        self.fraction_history: list[tuple[float, float]] = []  # (t_ms, fraction)

    def on_cold_start(self, ctx: ColdStartContext) -> ProbeDecision:
        if ctx.retry_count >= self.max_retries:
            return ProbeDecision.SKIP  # emergency exit: accept unjudged
        return ProbeDecision.PROBE

    def on_probe(self, ctx: ProbeContext) -> Verdict:
        t = ctx.telemetry
        if ctx.is_cold:
            self.observations.append(ctx.observed_ms)
            n = len(self.observations)
            if n >= self.warmup_reports and n % self.update_every == 0:
                self._update(t)
        if ctx.retry_count >= self.max_retries:
            return Verdict.FORCED_PASS
        if self.threshold is None:
            return Verdict.PASS  # warm-up: collecting the distribution
        eff = ElysiumGate._effective_observation(
            None, ctx.observed_ms,
            t.knobs.load_multiplier(t.mean_load) if t.knobs.gate_load_aware else 1.0)
        ctx.instance.benchmark_result = eff
        return Verdict.PASS if eff <= self.threshold else Verdict.TERMINATE

    def _update(self, t: Telemetry) -> None:
        sigma = t.probe_log_std
        body, bench = t.body_mean_ms, t.probe_mean_ms
        if sigma <= 0.0 or not math.isfinite(body) or not math.isfinite(bench):
            return  # not enough signal yet
        f = optimal_pass_fraction(
            benchmark_ms=bench,
            body_ms=body,
            expected_reuses=t.expected_reuses,
            speedup_at_fraction=lambda fr: lognormal_pool_speedup(fr, sigma),
            fractions=self.fractions,
        )
        self.pass_fraction = f
        self.fraction_history.append((t.now_ms, f))
        raw = math.exp(t.probe_log_mean + _norm_ppf(f) * sigma)
        self.threshold = self._ema.update(raw)


# ---------------------------------------------------------------------------
# QueueAwareAdmissionController — ROADMAP: dynamic per-stage admission
# ---------------------------------------------------------------------------


class QueueAwareAdmissionController(DelegatingController):
    """Dynamic per-stage admission: defer items while the stage's live
    demand (requests in flight + its own queue depth) exceeds a headroom
    multiple of its *certified* serving capacity.

    Capacity = replica budget × per-instance concurrency, where the
    budget is the pool cap (``SubstrateKnobs.max_pool``) when the backend
    has one, else the live instance count. Under an elastic cold-start
    supply a deep queue never forms — overload instead shows up as
    uncertified extra instances spawned past the pool cap, each paying
    prepare + probe and then being despawned at release (the
    queue-dominated latency of EXPERIMENTS.md §Load-aware pipeline
    sweep). Deferring at ``in_flight + queue_depth ≥ ⌈headroom ×
    capacity⌉`` keeps work on the gate-certified pool instead.

    The static ``Stage.max_in_flight`` bound (the wrapped controller's
    :meth:`on_admit`) still applies first. A deferral only ever happens
    with stage work in flight or queued, and the workflow layer re-offers
    deferred items on every completion of that stage, so progress is
    guaranteed — back-pressure, never deadlock."""

    def __init__(self, inner, *, headroom: float = 1.5,
                 min_slots: int = 4) -> None:
        super().__init__(inner)
        if headroom <= 0.0:
            raise ValueError("headroom must be > 0")
        if min_slots < 1:
            raise ValueError("min_slots must be >= 1")
        self.name = "queue-admission"
        self.headroom = headroom
        self.min_slots = min_slots
        self.deferred = 0  # decisions, not unique items

    def handler_name(self, point: str) -> str:
        if point == "on_admit":
            return self.name
        return super().handler_name(point)

    def on_admit(self, ctx: AdmitContext) -> AdmitDecision:
        if self.inner.on_admit(ctx) is AdmitDecision.DEFER:
            return AdmitDecision.DEFER  # static bound still respected
        t = ctx.telemetry
        if t.knobs.max_pool is not None:
            budget = t.knobs.max_pool
        elif getattr(t.knobs, "max_instances", None) is not None:
            # open-loop autoscaling cap (DESIGN.md §12): the supply the
            # stage can actually spawn, even before instances exist
            budget = t.knobs.max_instances
        else:
            budget = max(1, t.pool_instances)
        capacity = budget * t.knobs.per_instance_concurrency
        bound = max(self.min_slots, math.ceil(self.headroom * capacity))
        if t.total_in_flight + t.queue_depth >= bound:
            self.deferred += 1
            return AdmitDecision.DEFER
        return AdmitDecision.ADMIT


def static_admission_bound(knobs: Any, *, headroom: float = 2.0,
                           min_slots: int = 1) -> float:
    """Static in-flight cap matching :class:`QueueAwareAdmissionController`.

    The vectorized open-loop scan (``repro.sim.vectorized``) cannot call a
    live controller per arrival, so it takes the admission bound as a
    number (``ArmParams.admit_bound``) and defers while
    ``in_flight >= bound``.  This helper derives that number from the same
    capacity formula the dynamic controller uses — replica budget
    (``max_pool``, else ``max_instances``) × per-instance concurrency ×
    headroom — minus the live-pool fallback, which has no static
    equivalent.  With no replica cap at all the supply is elastic and the
    bound is ``inf`` (admission never defers).
    """
    if headroom <= 0.0:
        raise ValueError("headroom must be > 0")
    if min_slots < 1:
        raise ValueError("min_slots must be >= 1")
    budget = getattr(knobs, "max_pool", None)
    if budget is None:
        budget = getattr(knobs, "max_instances", None)
    if budget is None:
        return math.inf
    capacity = budget * knobs.per_instance_concurrency
    return float(max(min_slots, math.ceil(headroom * capacity)))


# ---------------------------------------------------------------------------
# ReprobeController — ROADMAP: re-probing under drift
# ---------------------------------------------------------------------------


class ReprobeController(DelegatingController):
    """Warm re-benchmarking once a certification goes stale (ROADMAP:
    re-probing under drift).

    The paper skips warm re-benchmarking because FaaS instances are
    short-lived (§II-B); under per-serve contention drift
    (``contention_rho < 1``) and long-lived concurrent pools that
    assumption breaks — an instance out-serves its certified speed's
    half-life and the pool silently decays to the day mean (EXPERIMENTS.md
    §Diurnal sweep, load arms). This wrapper re-probes a warm instance
    after ``max_uses_since_probe`` serves and/or ``max_ms_since_probe``
    milliseconds; the inner controller judges the fresh observation
    against its current threshold (via ``on_probe(is_cold=False)``, which
    does NOT pollute the cold-probe estimators) and the engine retires the
    instance on TERMINATE. The re-probe runs concurrently with the
    prepare phase, so a passing instance usually pays nothing.

    The per-serve AR(1) drift model gives the natural trigger unit:
    log-relative speed decays by ρ per serve, so the half-life is
    ln(½)/ln(ρ) serves (ρ=0.95 → ≈13.5) — pick ``max_uses_since_probe``
    around that."""

    def __init__(self, inner, *, max_uses_since_probe: Optional[int] = None,
                 max_ms_since_probe: Optional[float] = None) -> None:
        super().__init__(inner)
        if max_uses_since_probe is None and max_ms_since_probe is None:
            raise ValueError("need max_uses_since_probe and/or max_ms_since_probe")
        if max_uses_since_probe is not None and max_uses_since_probe < 1:
            raise ValueError("max_uses_since_probe must be >= 1")
        self.name = "reprobe"
        self.max_uses_since_probe = max_uses_since_probe
        self.max_ms_since_probe = max_ms_since_probe

    @staticmethod
    def half_life_uses(contention_rho: float) -> int:
        """Serves until the certified log-advantage halves under AR(1)."""
        if not 0.0 < contention_rho < 1.0:
            raise ValueError("contention_rho must be in (0,1)")
        return max(1, round(math.log(0.5) / math.log(contention_rho)))

    def handler_name(self, point: str) -> str:
        if point == "on_reuse":
            return self.name
        return super().handler_name(point)

    def on_reuse(self, ctx: ReuseContext) -> ReuseDecision:
        if ctx.retry_count > 0:
            # a retried invocation has already paid selection waste; serve it
            return self.inner.on_reuse(ctx)
        stale = (
            self.max_uses_since_probe is not None
            and ctx.uses_since_probe >= self.max_uses_since_probe
        ) or (
            self.max_ms_since_probe is not None
            and ctx.ms_since_probe is not None
            and ctx.ms_since_probe >= self.max_ms_since_probe
        )
        if stale:
            return ReuseDecision.REPROBE
        return self.inner.on_reuse(ctx)


__all__ = [
    "AdmitContext",
    "AdmitDecision",
    "ClassicMinosController",
    "ColdStartContext",
    "Controller",
    "ControllerBase",
    "DECISION_POINTS",
    "DelegatingController",
    "ElysiumGate",
    "FailureContext",
    "FailureDecision",
    "FleetTelemetry",
    "PassFractionController",
    "ProbeContext",
    "ProbeDecision",
    "QueueAwareAdmissionController",
    "ReleaseContext",
    "ReprobeController",
    "ReuseContext",
    "ReuseDecision",
    "Telemetry",
    "lognormal_pool_speedup",
    "static_admission_bound",
]
