"""The Minos cost model (paper Fig. 3) and provider pricing tables.

    c_total = c_exec * (sum d_term + sum d_pass + sum d_reuse)
            + c_inv  * (n_term + n_pass + n_reuse)

where *term* are invocations whose instance failed the benchmark and was
terminated (their duration is prepare+benchmark only), *pass* are cold-start
invocations that passed and ran the full body, and *reuse* are warm-instance
invocations (no benchmark at all).

Pricing is parameterized so the same model covers Google Cloud Functions
(the paper's platform), AWS-Lambda-style pricing, and an accelerator
"chip-second" model used by the serving integration.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable

# Google Cloud Functions pricing (europe-west Tier-1 list prices):
#   invocations $0.40/1M; compute $2.5e-6/GiB-s + $1.0e-5/GHz-s (gen1
#   CPU allocation per tier), large tiers per Cloud-Run-style vCPU-s.
# Folded into one $/ms rate per tier. The paper's observation holds: the
# per-invocation fee is worth only a handful-to-tens of ms of execution,
# shrinking as the tier grows (<3 ms at 32 GB), so execution cost dominates
# and Minos' extra terminated invocations amortize quickly (§II-A, Fig 3).
_GCF_PER_INVOCATION = 0.4e-6  # $ per invocation ($0.40 / 1M)
_GCF_GEN1 = {
    # memory_mb: (mem_gib, cpu_ghz)
    128: (0.125, 0.2),
    256: (0.25, 0.4),
    512: (0.5, 0.8),
    1024: (1.0, 1.4),
    2048: (2.0, 2.4),
    4096: (4.0, 4.8),
    8192: (8.0, 4.8),
}
_GCF_TIERS_MS = {
    mb: (gib * 2.5e-6 + ghz * 1.0e-5) / 1000.0 for mb, (gib, ghz) in _GCF_GEN1.items()
}
# gen2 tiers (vCPU-s $2.4e-5, GiB-s $2.5e-6)
_GCF_TIERS_MS[16384] = (16.0 * 2.5e-6 + 4.0 * 2.4e-5) / 1000.0
_GCF_TIERS_MS[32768] = (32.0 * 2.5e-6 + 8.0 * 2.4e-5) / 1000.0


@dataclasses.dataclass(frozen=True)
class Pricing:
    """Linear pay-per-use pricing: fixed per-invocation fee + per-ms rate."""

    cost_per_invocation: float
    cost_per_ms: float
    name: str = "custom"

    @staticmethod
    def gcf(memory_mb: int = 256) -> "Pricing":
        if memory_mb not in _GCF_TIERS_MS:
            raise ValueError(f"unknown GCF tier {memory_mb} MB; tiers: {sorted(_GCF_TIERS_MS)}")
        return Pricing(
            cost_per_invocation=_GCF_PER_INVOCATION,
            cost_per_ms=_GCF_TIERS_MS[memory_mb],
            name=f"gcf-{memory_mb}mb",
        )

    @staticmethod
    def aws_lambda(memory_mb: int = 1024) -> "Pricing":
        """AWS-Lambda-style pricing: $0.20/1M requests + $1.66667e-5/GiB-s,
        CPU allocation proportional to memory (no separate GHz term)."""
        if memory_mb < 128 or memory_mb > 10240:
            raise ValueError(f"Lambda memory must be in [128, 10240] MB, got {memory_mb}")
        return Pricing(
            cost_per_invocation=0.2e-6,
            cost_per_ms=(memory_mb / 1024.0) * 1.66667e-5 / 1000.0,
            name=f"lambda-{memory_mb}mb",
        )

    @staticmethod
    def tpu_chip_seconds(chips: int, usd_per_chip_hour: float = 1.2) -> "Pricing":
        """Accelerator-serving analogue: a replica of ``chips`` chips billed
        per ms of occupancy; 'invocations' (request dispatches) are free."""
        return Pricing(
            cost_per_invocation=0.0,
            cost_per_ms=chips * usd_per_chip_hour / 3600.0 / 1000.0,
            name=f"tpu-{chips}chips",
        )

    @property
    def invocation_break_even_ms(self) -> float:
        """How many ms of execution cost the same as one invocation fee.

        Paper §II-A: ~50 ms at 128 MB, <3 ms at 32 GB. Used to reason about
        when Minos' extra (terminated) invocations amortize.
        """
        if self.cost_per_ms == 0.0:
            return float("inf")
        return self.cost_per_invocation / self.cost_per_ms

    def invocation_cost(self, duration_ms: float) -> float:
        return self.cost_per_invocation + self.cost_per_ms * duration_ms


@dataclasses.dataclass
class WorkflowCost:
    """Accumulates Fig-3 terms over a workflow run."""

    pricing: Pricing
    n_term: int = 0
    n_pass: int = 0
    n_reuse: int = 0
    d_term_ms: float = 0.0
    d_pass_ms: float = 0.0
    d_reuse_ms: float = 0.0

    def record_terminated(self, duration_ms: float) -> None:
        self.n_term += 1
        self.d_term_ms += duration_ms

    def record_passed(self, duration_ms: float) -> None:
        self.n_pass += 1
        self.d_pass_ms += duration_ms

    def record_reused(self, duration_ms: float) -> None:
        self.n_reuse += 1
        self.d_reuse_ms += duration_ms

    @property
    def n_invocations(self) -> int:
        return self.n_term + self.n_pass + self.n_reuse

    @property
    def n_successful(self) -> int:
        """Invocations that actually ran the function body."""
        return self.n_pass + self.n_reuse

    @property
    def exec_cost(self) -> float:
        return self.pricing.cost_per_ms * (self.d_term_ms + self.d_pass_ms + self.d_reuse_ms)

    @property
    def invocation_fees(self) -> float:
        return self.pricing.cost_per_invocation * self.n_invocations

    @property
    def total(self) -> float:
        return self.exec_cost + self.invocation_fees

    @property
    def cost_per_successful(self) -> float:
        if self.n_successful == 0:
            return float("nan")
        return self.total / self.n_successful

    def cost_per_million_successful(self) -> float:
        return self.cost_per_successful * 1e6

    def merge(self, other: "WorkflowCost") -> "WorkflowCost":
        assert self.pricing == other.pricing
        return WorkflowCost(
            self.pricing,
            self.n_term + other.n_term,
            self.n_pass + other.n_pass,
            self.n_reuse + other.n_reuse,
            self.d_term_ms + other.d_term_ms,
            self.d_pass_ms + other.d_pass_ms,
            self.d_reuse_ms + other.d_reuse_ms,
        )


def total_cost(
    pricing: Pricing,
    d_term: Iterable[float],
    d_pass: Iterable[float],
    d_reuse: Iterable[float],
) -> float:
    """Direct transliteration of Fig. 3 for tests/docs."""
    d_term, d_pass, d_reuse = list(d_term), list(d_pass), list(d_reuse)
    exec_cost = pricing.cost_per_ms * (sum(d_term) + sum(d_pass) + sum(d_reuse))
    inv_cost = pricing.cost_per_invocation * (len(d_term) + len(d_pass) + len(d_reuse))
    return exec_cost + inv_cost
