"""Elysium-threshold calculation (paper §II-B, §III-A, §IV).

Two modes:

* **Pre-testing** (what the paper's prototype does): run a short unguarded
  workload (e.g. 10 VUs × 1 min), collect benchmark durations, and set the
  threshold at the p-th percentile (paper: 60th ⇒ only the fastest 40 % of
  fresh instances pass). The threshold is then passed to the function as
  configuration.

* **Online controller** (paper §IV future work): instances report benchmark
  results to a (non-critical) centralized component that maintains the
  percentile with O(1)-memory streaming estimators (P² [12], Welford [13])
  and periodically republishes the threshold. Its failure only degrades
  optimality, never correctness.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np

from .estimators import EMA, P2Quantile, Welford


def pretest_threshold(benchmark_results: Sequence[float], pass_fraction: float = 0.4) -> float:
    """Threshold such that approximately ``pass_fraction`` of the observed
    population passes (durations: lower is better ⇒ threshold is the
    (pass_fraction)-quantile; paper's 60th percentile == pass_fraction 0.4).
    """
    if not 0.0 < pass_fraction < 1.0:
        raise ValueError("pass_fraction must be in (0,1)")
    results = np.asarray(list(benchmark_results), dtype=np.float64)
    if results.size == 0:
        raise ValueError("pre-testing produced no benchmark results")
    return float(np.quantile(results, pass_fraction))


@dataclasses.dataclass
class PretestReport:
    threshold: float
    pass_fraction: float
    n_samples: int
    mean: float
    std: float
    p50: float
    p90: float


def run_pretest(
    benchmark_results: Iterable[float], pass_fraction: float = 0.4
) -> PretestReport:
    results = np.asarray(list(benchmark_results), dtype=np.float64)
    return PretestReport(
        threshold=pretest_threshold(results, pass_fraction),
        pass_fraction=pass_fraction,
        n_samples=int(results.size),
        mean=float(results.mean()),
        std=float(results.std(ddof=1)) if results.size > 1 else 0.0,
        p50=float(np.quantile(results, 0.5)),
        p90=float(np.quantile(results, 0.9)),
    )


class OnlineElysiumController:
    """§IV online threshold recalculation with O(1) memory.

    Not a single point of failure: consumers cache the last published
    threshold; if the controller dies, behavior degrades to stale-threshold
    Minos, which is exactly the pre-testing prototype.
    """

    def __init__(
        self,
        pass_fraction: float = 0.4,
        republish_every: int = 32,
        smoothing_alpha: float = 0.3,
        initial_threshold: float | None = None,
    ) -> None:
        if not 0.0 < pass_fraction < 1.0:
            raise ValueError("pass_fraction must be in (0,1)")
        self.pass_fraction = pass_fraction
        self.republish_every = republish_every
        self._p2 = P2Quantile(pass_fraction)
        self._welford = Welford()
        self._ema = EMA(smoothing_alpha, initial_threshold)
        self._since_publish = 0
        self._published = initial_threshold
        self.n_reports = 0

    def report(self, benchmark_result: float) -> None:
        """An instance reports its cold-start benchmark result.

        IMPORTANT: both passing and failing instances report, otherwise the
        estimate is survivor-biased and the threshold ratchets downward
        forever.
        """
        self._p2.update(benchmark_result)
        self._welford.update(benchmark_result)
        self.n_reports += 1
        self._since_publish += 1
        if self._since_publish >= self.republish_every:
            self._publish()

    def _publish(self) -> None:
        self._published = self._ema.update(self._p2.value)
        self._since_publish = 0

    @property
    def threshold(self) -> float:
        if self._published is None:
            if self.n_reports == 0:
                raise ValueError("no benchmark reports yet and no initial threshold")
            return self._p2.value
        return self._published

    @property
    def population_mean(self) -> float:
        return self._welford.mean

    @property
    def population_std(self) -> float:
        return self._welford.std


def optimal_pass_fraction(
    *,
    benchmark_ms: float,
    body_ms: float,
    expected_reuses: float,
    speedup_at_fraction,
    fractions: Sequence[float] = tuple(np.linspace(0.05, 0.95, 19)),
) -> float:
    """Cost-optimal pass fraction (paper §II-A trade-off), by direct search.

    Keeping only the fastest ``f`` fraction costs
        E[starts] ≈ 1/f  cold starts (each wasting ~benchmark_ms)
    but every subsequent execution runs at speedup ``speedup_at_fraction(f)``
    (mean speed of the top-f fraction of the speed distribution).

    total(f) ≈ (1/f) * benchmark_ms + (1 + expected_reuses) * body_ms / speedup(f)

    Returns the argmin over the candidate grid. This is the quantitative
    form of "the optimal termination rate depends on the duration of the
    workload, the performance variability of the platform, and the relative
    time of the benchmark".
    """
    best_f, best_cost = None, float("inf")
    for f in fractions:
        waste = benchmark_ms / f
        work = (1.0 + expected_reuses) * body_ms / float(speedup_at_fraction(f))
        cost = waste + work
        if cost < best_cost:
            best_f, best_cost = float(f), cost
    assert best_f is not None
    return best_f
