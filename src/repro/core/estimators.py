"""Online statistical estimators used for elysium-threshold maintenance.

The paper (§IV, "Online calculation of the elysium threshold") proposes
updating the threshold live from streaming benchmark results without storing
observations: the mean can be maintained exactly online, the standard
deviation via Welford's algorithm [13, Welford 1962], and percentiles via
the P² algorithm [12, Jain & Chlamtac 1985].

Every estimator is provided in two forms:

* a plain-Python class (used by the controller / simulator hot path), and
* a pure-JAX (pytree-state + ``update`` function) form usable inside
  ``jax.lax.scan`` / jitted loops, so that a fleet of thousands of
  simulated instances can be folded in a single XLA program.
"""
from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Welford mean / variance
# ---------------------------------------------------------------------------


class Welford:
    """Exact online mean and variance (Welford 1962).

    Stores O(1) state: count, running mean, and M2 (sum of squared
    deviations). ``variance`` is the unbiased sample variance.
    """

    __slots__ = ("count", "mean", "m2")

    def __init__(self) -> None:
        self.count = 0
        self.mean = 0.0
        self.m2 = 0.0

    def update(self, x: float) -> None:
        self.count += 1
        delta = x - self.mean
        self.mean += delta / self.count
        self.m2 += delta * (x - self.mean)

    def update_many(self, xs) -> None:
        for x in xs:
            self.update(float(x))

    @property
    def variance(self) -> float:
        if self.count < 2:
            return 0.0
        return self.m2 / (self.count - 1)

    @property
    def std(self) -> float:
        return math.sqrt(self.variance)

    def merge(self, other: "Welford") -> "Welford":
        """Chan et al. parallel merge — lets distributed collectors combine."""
        out = Welford()
        n = self.count + other.count
        if n == 0:
            return out
        delta = other.mean - self.mean
        out.count = n
        out.mean = self.mean + delta * other.count / n
        out.m2 = self.m2 + other.m2 + delta * delta * self.count * other.count / n
        return out


class WelfordState(NamedTuple):
    """JAX pytree state for Welford. All leaves are scalars (f32/f64)."""

    count: jax.Array
    mean: jax.Array
    m2: jax.Array


def welford_init(shape=(), dtype=jnp.float32) -> WelfordState:
    """Welford pytree state; ``shape`` non-() folds several independent
    streams elementwise in one state (e.g. (3,) for body/latency/reuse —
    one fused update instead of three in a jitted hot loop)."""
    z = jnp.zeros(shape, dtype)
    return WelfordState(count=z, mean=z, m2=z)


def welford_update(state: WelfordState, x: jax.Array) -> WelfordState:
    count = state.count + 1.0
    delta = x - state.mean
    mean = state.mean + delta / count
    m2 = state.m2 + delta * (x - mean)
    return WelfordState(count=count, mean=mean, m2=m2)


def welford_update_masked(
    state: WelfordState, x: jax.Array, mask: jax.Array
) -> WelfordState:
    """:func:`welford_update` where ``mask`` is truthy, identity where not —
    fused (arithmetic masking), so a vectorized simulator can fold a
    conditional observation without materializing both states and
    selecting (tested equivalent in tests/test_estimators.py)."""
    m = jnp.asarray(mask, state.count.dtype)
    count = state.count + m
    delta = x - state.mean
    mean = state.mean + m * delta / jnp.maximum(count, 1.0)
    m2 = state.m2 + m * delta * (x - mean)
    return WelfordState(count=count, mean=mean, m2=m2)


def welford_variance(state: WelfordState) -> jax.Array:
    return jnp.where(state.count < 2.0, 0.0, state.m2 / jnp.maximum(state.count - 1.0, 1.0))


def welford_std(state: WelfordState) -> jax.Array:
    return jnp.sqrt(welford_variance(state))


def welford_merge(a: WelfordState, b: WelfordState) -> WelfordState:
    n = a.count + b.count
    safe_n = jnp.maximum(n, 1.0)
    delta = b.mean - a.mean
    mean = a.mean + delta * b.count / safe_n
    m2 = a.m2 + b.m2 + delta * delta * a.count * b.count / safe_n
    return WelfordState(count=n, mean=jnp.where(n == 0, 0.0, mean), m2=jnp.where(n == 0, 0.0, m2))


# ---------------------------------------------------------------------------
# P² quantile estimator (Jain & Chlamtac 1985)
# ---------------------------------------------------------------------------


class P2Quantile:
    """P² dynamic quantile estimation without storing observations.

    Maintains 5 markers whose heights converge to the (0, p/2, p, (1+p)/2, 1)
    quantiles. After the first five observations the estimate is available in
    O(1) memory. This is the paper's cited mechanism for online percentile
    estimation of benchmark results.
    """

    __slots__ = ("p", "n_obs", "heights", "positions", "desired", "increments")

    def __init__(self, p: float) -> None:
        if not 0.0 < p < 1.0:
            raise ValueError(f"quantile p must be in (0,1), got {p}")
        self.p = p
        self.n_obs = 0
        self.heights: list[float] = []
        self.positions = [1.0, 2.0, 3.0, 4.0, 5.0]
        self.desired = [1.0, 1.0 + 2.0 * p, 1.0 + 4.0 * p, 3.0 + 2.0 * p, 5.0]
        self.increments = [0.0, p / 2.0, p, (1.0 + p) / 2.0, 1.0]

    def update(self, x: float) -> None:
        x = float(x)
        if self.n_obs < 5:
            self.heights.append(x)
            self.n_obs += 1
            if self.n_obs == 5:
                self.heights.sort()
            return
        self.n_obs += 1
        q = self.heights
        # locate cell k such that q[k] <= x < q[k+1]
        if x < q[0]:
            q[0] = x
            k = 0
        elif x >= q[4]:
            q[4] = x
            k = 3
        else:
            k = 0
            for i in range(1, 4):
                if x >= q[i]:
                    k = i
        for i in range(k + 1, 5):
            self.positions[i] += 1.0
        for i in range(5):
            self.desired[i] += self.increments[i]
        # adjust interior markers 1..3
        for i in range(1, 4):
            d = self.desired[i] - self.positions[i]
            n_i, n_im, n_ip = self.positions[i], self.positions[i - 1], self.positions[i + 1]
            if (d >= 1.0 and n_ip - n_i > 1.0) or (d <= -1.0 and n_im - n_i < -1.0):
                d_sign = 1.0 if d >= 0 else -1.0
                # parabolic (P²) prediction
                q_new = q[i] + d_sign / (n_ip - n_im) * (
                    (n_i - n_im + d_sign) * (q[i + 1] - q[i]) / (n_ip - n_i)
                    + (n_ip - n_i - d_sign) * (q[i] - q[i - 1]) / (n_i - n_im)
                )
                if q[i - 1] < q_new < q[i + 1]:
                    q[i] = q_new
                else:  # linear fallback
                    j = i + int(d_sign)
                    q[i] = q[i] + d_sign * (q[j] - q[i]) / (self.positions[j] - n_i)
                self.positions[i] += d_sign

    def update_many(self, xs) -> None:
        for x in xs:
            self.update(float(x))

    @property
    def value(self) -> float:
        if self.n_obs == 0:
            raise ValueError("no observations")
        if self.n_obs < 5:
            # exact small-sample quantile
            return float(np.quantile(np.asarray(self.heights[: self.n_obs]), self.p))
        return self.heights[2]


class P2State(NamedTuple):
    """JAX pytree state for the P² estimator (vectorizable via vmap)."""

    n_obs: jax.Array          # scalar int32
    heights: jax.Array        # (5,) f32 — first 5 obs stored raw until full
    positions: jax.Array      # (5,) f32
    desired: jax.Array        # (5,) f32
    p: jax.Array              # scalar f32


def p2_init(p: float | jax.Array) -> P2State:
    p = jnp.asarray(p, jnp.float32)
    return P2State(
        n_obs=jnp.zeros((), jnp.int32),
        heights=jnp.zeros((5,), jnp.float32),
        positions=jnp.arange(1.0, 6.0, dtype=jnp.float32),
        desired=jnp.array([1.0, 0.0, 0.0, 0.0, 5.0], jnp.float32)
        + jnp.array([0.0, 2.0, 4.0, 2.0, 0.0], jnp.float32) * p
        + jnp.array([0.0, 1.0, 1.0, 3.0, 0.0], jnp.float32),
        p=p,
    )


def _p2_increments(p: jax.Array) -> jax.Array:
    return jnp.stack([jnp.zeros_like(p), p / 2.0, p, (1.0 + p) / 2.0, jnp.ones_like(p)])


def p2_update(state: P2State, x: jax.Array) -> P2State:
    """One P² update step, branch-free (jit/vmap-safe)."""
    x = jnp.asarray(x, jnp.float32)

    def warmup(s: P2State) -> P2State:
        h = s.heights.at[s.n_obs].set(x)
        n = s.n_obs + 1
        h = jnp.where(n >= 5, jnp.sort(h), h)
        return s._replace(n_obs=n, heights=h)

    def steady(s: P2State) -> P2State:
        q = s.heights
        below = x < q[0]
        above = x >= q[4]
        q = q.at[0].set(jnp.where(below, x, q[0]))
        q = q.at[4].set(jnp.where(above, x, q[4]))
        # cell index k in [0,3]
        k_mid = jnp.sum(jnp.asarray(x >= q[1:4], jnp.int32))
        k = jnp.where(below, 0, jnp.where(above, 3, k_mid))
        idx = jnp.arange(5)
        pos = s.positions + jnp.asarray(idx > k, jnp.float32)
        des = s.desired + _p2_increments(s.p)

        def adjust(i, carry):
            q, pos = carry
            d = des[i] - pos[i]
            n_i, n_im, n_ip = pos[i], pos[i - 1], pos[i + 1]
            move_up = (d >= 1.0) & (n_ip - n_i > 1.0)
            move_dn = (d <= -1.0) & (n_im - n_i < -1.0)
            do = move_up | move_dn
            s_ = jnp.where(move_up, 1.0, -1.0)
            denom_hi = jnp.where(n_ip - n_i == 0, 1.0, n_ip - n_i)
            denom_lo = jnp.where(n_i - n_im == 0, 1.0, n_i - n_im)
            q_par = q[i] + s_ / (n_ip - n_im) * (
                (n_i - n_im + s_) * (q[i + 1] - q[i]) / denom_hi
                + (n_ip - n_i - s_) * (q[i] - q[i - 1]) / denom_lo
            )
            ok = (q[i - 1] < q_par) & (q_par < q[i + 1])
            # j = i ± 1 with the sign data-dependent: evaluate both static
            # neighbors and select, so the whole update stays gather-free
            q_j = jnp.where(move_up, q[i + 1], q[i - 1])
            pos_j = jnp.where(move_up, pos[i + 1], pos[i - 1])
            denom_lin = jnp.where(pos_j - n_i == 0, 1.0, pos_j - n_i)
            q_lin = q[i] + s_ * (q_j - q[i]) / denom_lin
            q_new = jnp.where(ok, q_par, q_lin)
            q = q.at[i].set(jnp.where(do, q_new, q[i]))
            pos = pos.at[i].set(jnp.where(do, n_i + s_, n_i))
            return (q, pos)

        # Python-unrolled (markers 1..3): static indices lower to cheap
        # slices instead of per-iteration dynamic gathers — same math as
        # the fori_loop form, pinned by tests/test_estimators.py
        carry = (q, pos)
        for i in range(1, 4):
            carry = adjust(i, carry)
        q, pos = carry
        return s._replace(n_obs=s.n_obs + 1, heights=q, positions=pos, desired=des)

    return jax.lax.cond(state.n_obs < 5, warmup, steady, state)


def p2_value(state: P2State) -> jax.Array:
    """Current quantile estimate. In warmup (<5 obs) returns the p-quantile
    of the raw stored observations."""
    n = state.n_obs

    def warm(s):
        h = jnp.sort(
            jnp.where(jnp.arange(5) < jnp.maximum(n, 1), s.heights, jnp.inf)
        )
        # linear-interp quantile over the first n entries
        pos = s.p * (jnp.asarray(n, jnp.float32) - 1.0)
        lo = jnp.clip(jnp.floor(pos).astype(jnp.int32), 0, 4)
        hi = jnp.clip(lo + 1, 0, jnp.maximum(n - 1, 0))
        frac = pos - jnp.floor(pos)
        return h[lo] * (1 - frac) + h[hi] * frac

    return jax.lax.cond(n < 5, warm, lambda s: s.heights[2], state)


# ---------------------------------------------------------------------------
# Exponential moving average (used for drift-tracking thresholds)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class EMA:
    alpha: float
    value: float | None = None

    def update(self, x: float) -> float:
        x = float(x)
        self.value = x if self.value is None else self.alpha * x + (1 - self.alpha) * self.value
        return self.value
