"""Invocation queue with requeue + retry accounting (paper §II, §IV).

Minos requires an *asynchronous* workload: invocations enter a queue; a
terminating instance re-queues its invocation before crashing so no request
is lost (at-least-once). The retry counter travels with the invocation —
it is what the emergency exit reads.

Sequence numbers are **per queue** (engine-local). An earlier revision used
one module-global counter for both invocation ids and the heap tiebreaker,
so the ids an engine produced depended on what else had run in the process
first — two engines in one process could never reproduce the ids of either
engine run alone, breaking cross-run comparability of seeded results. Now
each queue owns both counters: ids are assigned on *first* push (stable
across requeues) and the tiebreaker advances on every push.

Ordering modes:

* default (``fair=False``) — FIFO by enqueue time, then per-queue sequence:
  exactly the historical behavior, bit-identical heap keys (the seeded
  golden digests in tests/test_unified_substrate.py run through it).
* ``fair=True`` — weighted-fair by :class:`~repro.sim.arrivals.QoSClass`
  weight via start-time fair queueing (virtual finish times): each push
  of class ``c`` gets key ``max(V, F_c) + 1/weight_c`` where ``V`` is the
  virtual time of the last pop and ``F_c`` the class's previous finish.
  Under a shared backlog, class throughputs converge to the weight ratio,
  and every class drains at a bounded rate — no starvation (tested in
  tests/test_lifecycle_queue.py). Ties (equal virtual finish) break on the
  per-queue sequence, preserving FIFO order within a class and across
  equal-weight classes. A requeued invocation re-enters at its class's
  *current* virtual finish — a crash costs the request its place in line,
  same as the FIFO mode's requeue-at-now semantics.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Any, Optional


@dataclasses.dataclass
class Invocation:
    payload: Any
    enqueued_at_ms: float = 0.0
    retry_count: int = 0
    first_enqueued_at_ms: Optional[float] = None
    # assigned by the owning InvocationQueue on first push (engine-local ids);
    # an explicit id survives — the queue never reassigns a non-None id
    invocation_id: Optional[int] = None
    # bookkeeping for metrics
    terminations_experienced: int = 0
    # when the engine first popped this invocation for dispatch — the end of
    # its queue wait (requeues after a crash do not reset it)
    first_dispatched_at_ms: Optional[float] = None
    # QoS class (sim/arrivals.QoSClass): name + scheduling weight. Only the
    # fair-queue mode reads these; the default FIFO mode carries them inert.
    qos: str = "default"
    qos_weight: float = 1.0
    # failure-recovery bookkeeping (DESIGN.md §15); inert without a
    # FaultPlan/RecoveryPolicy. dispatch_epoch is bumped on every abandon/
    # failure so stale in-flight executions of this invocation can detect
    # they lost the race (idempotent re-dispatch: a zombie completion or
    # crash must not double-count). backoff_ms carries the previous
    # decorrelated-jitter delay (the "prev" in min(cap, uniform(base, 3*prev))).
    dispatch_epoch: int = 0
    failed_attempts: int = 0
    backoff_ms: float = 0.0

    def __post_init__(self) -> None:
        if self.first_enqueued_at_ms is None:
            self.first_enqueued_at_ms = self.enqueued_at_ms


class InvocationQueue:
    """FIFO (by enqueue time, then per-queue sequence) queue with requeue
    semantics; ``fair=True`` switches to weighted-fair dequeue by QoS
    weight (see module docstring)."""

    def __init__(self, *, fair: bool = False) -> None:
        self._heap: list[tuple[float, int, Invocation]] = []
        self._seq = itertools.count()  # heap tiebreaker: every push
        self._ids = itertools.count()  # invocation ids: first push only
        self.total_enqueued = 0
        self.total_requeued = 0
        self.fair = fair
        self._vtime = 0.0  # virtual time: the key of the last pop
        self._class_vfinish: dict[str, float] = {}

    def __len__(self) -> int:
        return len(self._heap)

    def push(self, inv: Invocation, now_ms: float) -> None:
        if inv.invocation_id is None:
            inv.invocation_id = next(self._ids)
        inv.enqueued_at_ms = now_ms
        if self.fair:
            w = inv.qos_weight if inv.qos_weight > 0.0 else 1.0
            start = max(self._vtime, self._class_vfinish.get(inv.qos, 0.0))
            key = start + 1.0 / w
            self._class_vfinish[inv.qos] = key
        else:
            key = now_ms
        heapq.heappush(self._heap, (key, next(self._seq), inv))
        self.total_enqueued += 1

    def requeue(self, inv: Invocation, now_ms: float) -> None:
        """Called by a terminating instance right before it crashes."""
        inv.retry_count += 1
        inv.terminations_experienced += 1
        self.push(inv, now_ms)
        self.total_requeued += 1

    def pop(self) -> Invocation:
        if not self._heap:
            raise IndexError("pop from empty InvocationQueue")
        key, _, inv = heapq.heappop(self._heap)
        if self.fair and key > self._vtime:
            self._vtime = key
        return inv

    def peek_time(self) -> Optional[float]:
        """Head-of-queue heap key: enqueue time (default) or virtual
        finish (``fair=True``)."""
        return self._heap[0][0] if self._heap else None

    def waiting(self) -> list[Invocation]:
        """The queued invocations, in heap (not pop) order — for end-of-run
        accounting of censored queue waits (open-loop metrics); callers
        must not mutate the invocations' queue fields."""
        return [inv for _, _, inv in self._heap]
