"""The Minos benchmark harness (paper §II-C).

The paper uses matrix multiplication as the CPU probe [10] and runs it
during the function's network-bound *prepare* phase so it does not extend
the critical path. Here the probe is the Pallas ``matmul_probe`` kernel
(TPU-native MXU tiling, validated in interpret mode on CPU); the harness is
pluggable so use-case-specific probes (memory streams, collective pings)
can be swapped in.

In *simulation*, the observed probe duration is ``work_ms / speed_factor``
— the harness computes ``work_ms`` (the probe's duration at unit speed)
once from its FLOP count so simulated and real probes share a scale.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Protocol

import jax
import jax.numpy as jnp


class Probe(Protocol):
    name: str

    def work_ms_at_unit_speed(self) -> float: ...

    def run(self) -> float:
        """Execute the probe for real; returns observed duration in ms."""
        ...


@dataclasses.dataclass
class MatmulProbe:
    """Matrix-multiplication probe (paper's choice, ref. [10]).

    n: square matrix dimension (MXU-aligned). repeats: back-to-back matmuls
    to push duration above timer noise. ``unit_speed_flops_per_ms`` anchors
    the simulated-time scale (0.167 vCPU at ~1 GFLOP/s ≈ the paper's 256 MB
    GCF tier).
    """

    n: int = 512
    repeats: int = 8
    unit_speed_flops_per_ms: float = 1.0e6 * 167  # 0.167 GFLOP/ms nominal
    use_pallas: bool = True
    name: str = "matmul"

    @property
    def flops(self) -> float:
        return 2.0 * self.n**3 * self.repeats

    def work_ms_at_unit_speed(self) -> float:
        return self.flops / self.unit_speed_flops_per_ms

    def _compute(self) -> jax.Array:
        from repro.kernels import ops

        a = jnp.full((self.n, self.n), 0.5, jnp.float32)
        b = jnp.full((self.n, self.n), 0.25, jnp.float32)
        out = a
        for _ in range(self.repeats):
            if self.use_pallas:
                out = ops.matmul(out, b)
            else:
                out = out @ b
        return out

    def run(self) -> float:
        t0 = time.perf_counter()
        jax.block_until_ready(self._compute())
        return (time.perf_counter() - t0) * 1e3


@dataclasses.dataclass
class CallableProbe:
    """Wrap any zero-arg callable returning observed duration in ms."""

    fn: Callable[[], float]
    work_ms: float
    name: str = "custom"

    def work_ms_at_unit_speed(self) -> float:
        return self.work_ms

    def run(self) -> float:
        return self.fn()


def overlap_fraction(prepare_ms: float, benchmark_ms: float) -> float:
    """Fraction of the benchmark hidden under the prepare phase. 1.0 means
    the probe is free (fully overlapped with e.g. the download); <1 means
    the probe extends the critical path by (1-f)*benchmark_ms."""
    if benchmark_ms <= 0:
        return 1.0
    return min(1.0, prepare_ms / benchmark_ms)


def effective_cold_start_overhead_ms(prepare_ms: float, benchmark_ms: float) -> float:
    """Extra wall time a cold start pays for benchmarking (0 when hidden)."""
    return max(0.0, benchmark_ms - prepare_ms)
