"""Minos core: instance selection via benchmark-gated self-termination."""
from .benchmark import CallableProbe, MatmulProbe, effective_cold_start_overhead_ms, overlap_fraction
from .control import (
    AdmitDecision,
    ClassicMinosController,
    Controller,
    ControllerBase,
    PassFractionController,
    ProbeDecision,
    QueueAwareAdmissionController,
    ReprobeController,
    ReuseDecision,
    Telemetry,
    lognormal_pool_speedup,
    static_admission_bound,
)
from .cost import Pricing, WorkflowCost, total_cost
from .elysium import (
    OnlineElysiumController,
    PretestReport,
    optimal_pass_fraction,
    pretest_threshold,
    run_pretest,
)
from .estimators import (
    EMA,
    P2Quantile,
    P2State,
    Welford,
    WelfordState,
    p2_init,
    p2_update,
    p2_value,
    welford_init,
    welford_merge,
    welford_std,
    welford_update,
    welford_variance,
)
from .lifecycle import FunctionInstance, InstanceState, LifecycleError
from .policy import (
    AdaptiveMinosPolicy,
    MinosPolicy,
    Verdict,
    expected_cold_start_attempts,
    retries_for_runaway_budget,
    runaway_probability,
)
from .queue import Invocation, InvocationQueue
from .substrate import (
    ElysiumGate,
    InstancePool,
    RequestResult,
    SimClock,
    SubstrateEngine,
    SubstrateKnobs,
    sample_jitter,
)

__all__ = [
    "CallableProbe", "MatmulProbe", "effective_cold_start_overhead_ms", "overlap_fraction",
    "AdmitDecision", "ClassicMinosController", "Controller", "ControllerBase",
    "PassFractionController", "ProbeDecision", "QueueAwareAdmissionController",
    "ReprobeController", "ReuseDecision", "Telemetry", "lognormal_pool_speedup",
    "static_admission_bound",
    "Pricing", "WorkflowCost", "total_cost",
    "OnlineElysiumController", "PretestReport", "optimal_pass_fraction",
    "pretest_threshold", "run_pretest",
    "EMA", "P2Quantile", "P2State", "Welford", "WelfordState",
    "p2_init", "p2_update", "p2_value",
    "welford_init", "welford_merge", "welford_std", "welford_update", "welford_variance",
    "FunctionInstance", "InstanceState", "LifecycleError",
    "AdaptiveMinosPolicy", "MinosPolicy", "Verdict", "expected_cold_start_attempts",
    "retries_for_runaway_budget", "runaway_probability",
    "Invocation", "InvocationQueue",
    "ElysiumGate", "InstancePool", "RequestResult", "SimClock",
    "SubstrateEngine", "SubstrateKnobs", "sample_jitter",
]
