"""MinosPolicy — the local pass/terminate decision (paper §II-A, §II-B).

A newly started instance runs a benchmark and compares the result against a
single scalar, the *elysium threshold*, stored in the function
configuration. No outside communication is needed during the call.

Conventions: benchmark results are *durations* (lower is better) by default;
``higher_is_better=True`` flips the comparison for throughput-style metrics.

The *emergency exit* (§II-A) prevents infinite requeue loops: if an
invocation has already been requeued ``max_retries`` times, the instance
accepts it without benchmarking. The paper sizes this from the expected
termination rate: at 40 % pass rate, P(5 consecutive terminations) =
0.6^5 ≈ 8 % ... the paper's own example: at an expected termination rate of
40 %, P(5 in a row) = 0.4^5 ≈ 1 %.
"""
from __future__ import annotations

import dataclasses
import math
from enum import Enum


class Verdict(Enum):
    PASS = "pass"            # instance joins the known-good pool
    TERMINATE = "terminate"  # requeue invocation, crash instance
    FORCED_PASS = "forced_pass"  # emergency exit — accepted without/despite benchmark


@dataclasses.dataclass(frozen=True)
class MinosPolicy:
    """The instance-local decision rule.

    elysium_threshold: benchmark result an instance must beat to live.
    max_retries: emergency-exit bound on requeues per invocation.
    higher_is_better: metric direction (False for durations).
    enabled: with False, every instance passes (the paper's baseline arm).
    """

    elysium_threshold: float
    max_retries: int = 5
    higher_is_better: bool = False
    enabled: bool = True

    def passes(self, benchmark_result: float) -> bool:
        if self.higher_is_better:
            return benchmark_result >= self.elysium_threshold
        return benchmark_result <= self.elysium_threshold

    def judge(self, benchmark_result: float, retry_count: int) -> Verdict:
        """Decide the fate of a cold-started instance.

        retry_count is the number of times THIS invocation has already been
        requeued by terminated instances.
        """
        if not self.enabled:
            return Verdict.PASS
        if retry_count >= self.max_retries:
            return Verdict.FORCED_PASS
        return Verdict.PASS if self.passes(benchmark_result) else Verdict.TERMINATE

    def should_benchmark(self, retry_count: int, is_cold_start: bool) -> bool:
        """Warm instances are never re-benchmarked (paper §II-B: short-lived
        instances make re-running benchmarks unnecessary); emergency-exit
        invocations skip the benchmark entirely."""
        if not self.enabled or not is_cold_start:
            return False
        return retry_count < self.max_retries


def runaway_probability(termination_rate: float, retries: int) -> float:
    """P(an invocation is terminated ``retries`` times in a row).

    Paper example: termination_rate=0.4 (60th-pct threshold ⇒ 40 % of fresh
    instances fail... note the paper words it as 'expected termination rate
    is 40%' ⇒ 0.4^5 ≈ 1 %).
    """
    if not 0.0 <= termination_rate <= 1.0:
        raise ValueError("termination_rate must be in [0,1]")
    return termination_rate**retries


def retries_for_runaway_budget(termination_rate: float, budget: float) -> int:
    """Smallest max_retries such that P(runaway) <= budget."""
    if termination_rate <= 0.0:
        return 1
    if termination_rate >= 1.0:
        raise ValueError("termination_rate 1.0 never converges")
    if not 0.0 < budget < 1.0:
        raise ValueError("budget must be in (0,1)")
    return max(1, math.ceil(math.log(budget) / math.log(termination_rate)))


def expected_cold_start_attempts(termination_rate: float, max_retries: int) -> float:
    """Expected number of instance starts per invocation under the policy
    (geometric, truncated by the emergency exit).

    E[starts] = sum_{k=0}^{r-1} t^k  (+ the forced-pass attempt when all
    r retries terminated is already counted by the k=r-1 term's requeue).
    """
    t = termination_rate
    if t == 1.0:
        return float(max_retries + 1)
    # attempts: 1 + t + t^2 + ... + t^max_retries (forced pass at the end)
    return (1.0 - t ** (max_retries + 1)) / (1.0 - t)
