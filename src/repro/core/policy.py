"""MinosPolicy — the local pass/terminate decision (paper §II-A, §II-B).

A newly started instance runs a benchmark and compares the result against a
single scalar, the *elysium threshold*, stored in the function
configuration. No outside communication is needed during the call.

Conventions: benchmark results are *durations* (lower is better) by default;
``higher_is_better=True`` flips the comparison for throughput-style metrics.

The *emergency exit* (§II-A) prevents infinite requeue loops: if an
invocation has already been requeued ``max_retries`` times, the instance
accepts it without benchmarking. Sizing it means picking the termination
rate the bound must survive. With the repo's default gate — threshold at
the 40th percentile, i.e. a 40 % *pass* rate — the termination rate is
60 % and P(5 consecutive terminations) = 0.6^5 ≈ 8 % of invocations hit
the exit. The paper's own example instead assumes a 40 % *termination*
rate (a laxer, 60 %-pass gate), giving 0.4^5 ≈ 1 %. Same formula,
different operating point: max_retries=5 is comfortable for a lax gate
but spends the exit on ~1 in 12 invocations at pass fraction 0.4 — use
:func:`retries_for_runaway_budget` to size it for your gate.
"""
from __future__ import annotations

import dataclasses
import math
from enum import Enum


class Verdict(Enum):
    PASS = "pass"            # instance joins the known-good pool
    TERMINATE = "terminate"  # requeue invocation, crash instance
    FORCED_PASS = "forced_pass"  # emergency exit — accepted without/despite benchmark


@dataclasses.dataclass(frozen=True)
class MinosPolicy:
    """The instance-local decision rule.

    elysium_threshold: benchmark result an instance must beat to live.
    max_retries: emergency-exit bound on requeues per invocation.
    higher_is_better: metric direction (False for durations).
    enabled: with False, every instance passes (the paper's baseline arm).
    """

    elysium_threshold: float
    max_retries: int = 5
    higher_is_better: bool = False
    enabled: bool = True

    def passes(self, benchmark_result: float) -> bool:
        if self.higher_is_better:
            return benchmark_result >= self.elysium_threshold
        return benchmark_result <= self.elysium_threshold

    def judge(self, benchmark_result: float, retry_count: int) -> Verdict:
        """Decide the fate of a cold-started instance.

        retry_count is the number of times THIS invocation has already been
        requeued by terminated instances.
        """
        if not self.enabled:
            return Verdict.PASS
        if retry_count >= self.max_retries:
            return Verdict.FORCED_PASS
        return Verdict.PASS if self.passes(benchmark_result) else Verdict.TERMINATE

    def should_benchmark(self, retry_count: int, is_cold_start: bool) -> bool:
        """Warm instances are never re-benchmarked (paper §II-B: short-lived
        instances make re-running benchmarks unnecessary); emergency-exit
        invocations skip the benchmark entirely."""
        if not self.enabled or not is_cold_start:
            return False
        return retry_count < self.max_retries


class AdaptiveMinosPolicy:
    """The §IV policy: elysium threshold maintained *online* from streaming
    probe results — no pre-test phase (DESIGN.md §6).

    Drop-in for :class:`MinosPolicy` at the platform boundary (``judge`` /
    ``passes`` / ``should_benchmark`` / ``elysium_threshold``), but mutable:
    the platform calls :meth:`report` with every cold-start probe result
    (passing AND failing — a survivor-only stream ratchets the threshold
    down forever), and the threshold follows an
    :class:`~repro.core.elysium.OnlineElysiumController` (P² percentile +
    Welford moments + EMA republish, all O(1) memory).

    Warm-up replaces pre-testing: until ``warmup_reports`` probes have been
    observed the policy passes every instance (it is *collecting* the
    distribution, exactly what the pre-test did — but on production traffic,
    so no separate unguarded phase is billed). The default is P²'s minimum
    (5): every instance admitted unjudged during warm-up pollutes the warm
    pool until platform churn evicts it, so the gate should arm as early as
    the estimate exists (EXPERIMENTS.md §Workflow sweep quantifies this).
    With ``initial_threshold`` set, the warm-up gate uses it instead of
    passing everyone (the stale-threshold degraded mode the paper requires
    on controller failure).
    """

    def __init__(
        self,
        pass_fraction: float = 0.4,
        *,
        max_retries: int = 5,
        warmup_reports: int = 5,
        republish_every: int = 4,
        smoothing_alpha: float = 0.7,
        initial_threshold: float | None = None,
        higher_is_better: bool = False,
    ) -> None:
        from .elysium import OnlineElysiumController  # avoid import cycle at module load

        if warmup_reports < 5:
            raise ValueError("warmup_reports must be >= 5 (P² needs 5 markers)")
        self.pass_fraction = pass_fraction
        self.max_retries = max_retries
        self.warmup_reports = warmup_reports
        self.higher_is_better = higher_is_better
        self.enabled = True
        self._initial_threshold = initial_threshold
        # durations: pass the fastest pass_fraction ⇒ threshold at the
        # pass_fraction quantile; throughput-style (higher is better):
        # passing the top pass_fraction needs the (1 - pass_fraction) one
        self.controller = OnlineElysiumController(
            pass_fraction=(1.0 - pass_fraction) if higher_is_better else pass_fraction,
            republish_every=republish_every,
            smoothing_alpha=smoothing_alpha,
            initial_threshold=initial_threshold,
        )

    # -- streaming input ------------------------------------------------
    def report(self, benchmark_result: float) -> None:
        """Feed one cold-start probe observation to the estimators. The
        platform calls this for every probed instance before judging it."""
        self.controller.report(benchmark_result)

    @property
    def warmed_up(self) -> bool:
        return self.controller.n_reports >= self.warmup_reports

    @property
    def elysium_threshold(self) -> float:
        """Current effective threshold. During warm-up: the initial
        threshold if one was given, else pass-everything (the estimate off
        a handful of probes is not worth terminating on)."""
        if not self.warmed_up and self._initial_threshold is None:
            return -math.inf if self.higher_is_better else math.inf
        return self.controller.threshold

    # -- MinosPolicy-compatible decision surface ------------------------
    def passes(self, benchmark_result: float) -> bool:
        thr = self.elysium_threshold
        if self.higher_is_better:
            return benchmark_result >= thr
        return benchmark_result <= thr

    def judge(self, benchmark_result: float, retry_count: int) -> Verdict:
        if not self.enabled:
            return Verdict.PASS
        if retry_count >= self.max_retries:
            return Verdict.FORCED_PASS
        return Verdict.PASS if self.passes(benchmark_result) else Verdict.TERMINATE

    def should_benchmark(self, retry_count: int, is_cold_start: bool) -> bool:
        # identical to MinosPolicy — warm-up instances still benchmark (the
        # probe result is the estimator's training signal) but always pass.
        if not self.enabled or not is_cold_start:
            return False
        return retry_count < self.max_retries


def runaway_probability(termination_rate: float, retries: int) -> float:
    """P(an invocation is terminated ``retries`` times in a row).

    Paper example: at an expected termination rate of 40 %
    (``termination_rate=0.4``, i.e. a gate that passes 60 % of fresh
    instances), 0.4^5 ≈ 1 %. At the repo default pass fraction 0.4 the
    termination rate is 0.6 and the same bound gives 0.6^5 ≈ 8 % (see the
    module docstring).
    """
    if not 0.0 <= termination_rate <= 1.0:
        raise ValueError("termination_rate must be in [0,1]")
    return termination_rate**retries


def retries_for_runaway_budget(termination_rate: float, budget: float) -> int:
    """Smallest max_retries such that P(runaway) <= budget."""
    if termination_rate <= 0.0:
        return 1
    if termination_rate >= 1.0:
        raise ValueError("termination_rate 1.0 never converges")
    if not 0.0 < budget < 1.0:
        raise ValueError("budget must be in (0,1)")
    return max(1, math.ceil(math.log(budget) / math.log(termination_rate)))


def expected_cold_start_attempts(termination_rate: float, max_retries: int) -> float:
    """Expected number of instance starts per invocation under the policy
    (geometric, truncated by the emergency exit).

    E[starts] = sum_{k=0}^{r-1} t^k  (+ the forced-pass attempt when all
    r retries terminated is already counted by the k=r-1 term's requeue).
    """
    t = termination_rate
    if t == 1.0:
        return float(max_retries + 1)
    # attempts: 1 + t + t^2 + ... + t^max_retries (forced pass at the end)
    return (1.0 - t ** (max_retries + 1)) / (1.0 - t)
