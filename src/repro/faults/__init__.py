"""Seeded, deterministic platform-fault injection (DESIGN.md §15).

Minos *deliberately* crashes instances (the self-crash + re-queue loop),
but real platforms also fail involuntarily: Night Shift (PAPERS.md)
documents failure-laced variability across providers, and "Unveiling
Overlooked Performance Variance in Serverless Computing" catalogs
variance sources well beyond instance speed. This package injects those
platform-side faults into the substrate in a bit-reproducible way:

* a :class:`FaultPlan` owns a **private** seeded RNG stream — it never
  draws from the engine's RNG, so enabling/disabling faults cannot shift
  any other sampled quantity (the golden-digest bit-identity criterion);
* every fault class is gated behind its own rate knob, and a rate of
  zero draws **nothing** — the fault-free path performs zero extra RNG
  draws (same zero-draw contract as
  :func:`repro.core.substrate.sample_jitter`);
* fleet-scope brownout/outage windows are *schedule*, not randomness:
  :meth:`FaultPlan.speed_multiplier` and :meth:`FaultPlan.unavailable`
  are pure functions of simulated time.

Fault taxonomy (where the engine consults the plan — DESIGN.md §15):

==================  =====================================================
``crash``           instance dies mid-body; work lost, the *partial*
                    duration is billed (Fig-3 ``d_term``), request
                    re-queued or dead-lettered
``cold_start``      instance never comes up; cold-start time billed if
                    the platform bills cold starts
``probe_timeout``   the benchmark probe hangs; the instance is killed
                    after ``probe_timeout_ms`` and that wait is billed
``throttle``        transient admission rejection at submit time
``lost``            body ran (and is billed), but the completion
                    notification is dropped — only a timeout recovers it
``brownout``        windowed speed collapse (body-time multiplier)
``outage``          windowed full unavailability (submits rejected)
==================  =====================================================

:class:`RecoveryPolicy` is the engine-side answer: per-request timeout
budgets (abandon-and-requeue), capped exponential backoff with
decorrelated jitter (:func:`decorrelated_jitter_ms`), and bounded
attempts with a dead-letter terminal state. Static rule R6
(``repro.analysis``) enforces that fault classes draw randomness only
from their injected seeded RNG — no host clock/RNG/IO.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

_WINDOW_KINDS = ("brownout", "outage")


@dataclasses.dataclass(frozen=True)
class FaultWindow:
    """A fleet-scope degradation window in simulated time.

    ``brownout`` multiplies body time by ``severity`` (>= 1) for work
    *started* inside the window; ``outage`` rejects submits arriving
    inside it. Windows are half-open ``[start_ms, end_ms)``.
    """

    start_ms: float
    end_ms: float
    kind: str = "brownout"
    severity: float = 2.0

    def __post_init__(self) -> None:
        if self.kind not in _WINDOW_KINDS:
            raise ValueError(
                f"kind must be one of {_WINDOW_KINDS}, got {self.kind!r}")
        if not self.end_ms > self.start_ms >= 0.0:
            raise ValueError(
                f"need 0 <= start_ms < end_ms, got [{self.start_ms}, {self.end_ms})")
        if self.kind == "brownout" and self.severity < 1.0:
            raise ValueError(
                f"brownout severity is a slowdown multiplier, must be >= 1, "
                f"got {self.severity}")

    def active(self, t_ms: float) -> bool:
        return self.start_ms <= t_ms < self.end_ms


@dataclasses.dataclass(frozen=True)
class RecoveryPolicy:
    """Engine-side failure recovery knobs (DESIGN.md §15).

    ``timeout_ms``: per-*request* end-to-end budget measured from first
    enqueue. When an execution would finish past the deadline the engine
    abandons it (the in-flight work becomes a billed zombie) and
    re-queues the request. ``None`` disables timeouts.

    ``max_attempts``: total dispatch attempts (including the first)
    before the request is dead-lettered — the terminal failure state.

    ``backoff_base_ms`` / ``backoff_cap_ms``: capped exponential backoff
    with decorrelated jitter applied to each re-queue after a failure
    (AWS architecture-blog variant: ``sleep = min(cap, uniform(base,
    prev * 3))``). A base of 0 disables backoff (and draws no RNG).
    """

    timeout_ms: Optional[float] = None
    max_attempts: int = 5
    backoff_base_ms: float = 10.0
    backoff_cap_ms: float = 1000.0

    def __post_init__(self) -> None:
        if self.timeout_ms is not None and self.timeout_ms <= 0.0:
            raise ValueError(f"timeout_ms must be > 0, got {self.timeout_ms}")
        if self.max_attempts < 1:
            raise ValueError(f"max_attempts must be >= 1, got {self.max_attempts}")
        if self.backoff_base_ms < 0.0 or self.backoff_cap_ms < 0.0:
            raise ValueError("backoff base/cap must be >= 0")
        if self.backoff_cap_ms < self.backoff_base_ms:
            raise ValueError(
                f"backoff_cap_ms {self.backoff_cap_ms} < backoff_base_ms "
                f"{self.backoff_base_ms}")


class FaultPlan:
    """Bit-reproducible fault schedule consulted by the engine.

    Owns a private ``RandomState(seed)`` stream: the engine's own RNG is
    never touched, and any fault class with rate 0 draws nothing, so a
    plan with all rates at 0 and no windows is behaviorally invisible.
    """

    def __init__(
        self,
        *,
        seed: int,
        crash_rate: float = 0.0,
        cold_fail_rate: float = 0.0,
        probe_timeout_rate: float = 0.0,
        probe_timeout_ms: float = 1000.0,
        throttle_rate: float = 0.0,
        lost_completion_rate: float = 0.0,
        windows: Sequence[FaultWindow] = (),
    ) -> None:
        for name, rate in (
            ("crash_rate", crash_rate),
            ("cold_fail_rate", cold_fail_rate),
            ("probe_timeout_rate", probe_timeout_rate),
            ("throttle_rate", throttle_rate),
            ("lost_completion_rate", lost_completion_rate),
        ):
            if not 0.0 <= rate < 1.0:
                raise ValueError(f"{name} must be in [0, 1), got {rate}")
        if probe_timeout_ms <= 0.0:
            raise ValueError(f"probe_timeout_ms must be > 0, got {probe_timeout_ms}")
        self.seed = seed
        self.crash_rate = crash_rate
        self.cold_fail_rate = cold_fail_rate
        self.probe_timeout_rate = probe_timeout_rate
        self.probe_timeout_ms = probe_timeout_ms
        self.throttle_rate = throttle_rate
        self.lost_completion_rate = lost_completion_rate
        self.windows = tuple(windows)
        # The *only* randomness source this class may touch (rule R6).
        self._rng = np.random.RandomState(seed)

    # -- stochastic fault classes (each rate-gated; 0 -> zero draws) -------

    def _hit(self, rate: float) -> bool:
        if rate <= 0.0:
            return False
        return bool(self._rng.random_sample() < rate)

    def crash_mid_body(self, t_ms: float) -> Optional[float]:
        """None, or the fraction of the body completed before the crash
        (uniform in [0, 1) — the partial duration that gets billed)."""
        if not self._hit(self.crash_rate):
            return None
        return float(self._rng.random_sample())

    def cold_start_fails(self, t_ms: float) -> bool:
        return self._hit(self.cold_fail_rate)

    def probe_times_out(self, t_ms: float) -> bool:
        return self._hit(self.probe_timeout_rate)

    def throttled(self, t_ms: float) -> bool:
        return self._hit(self.throttle_rate)

    def completion_lost(self, t_ms: float) -> bool:
        return self._hit(self.lost_completion_rate)

    # -- scheduled degradation windows (pure functions of sim time) --------

    def unavailable(self, t_ms: float) -> bool:
        for w in self.windows:
            if w.kind == "outage" and w.active(t_ms):
                return True
        return False

    def speed_multiplier(self, t_ms: float) -> float:
        mult = 1.0
        for w in self.windows:
            if w.kind == "brownout" and w.active(t_ms):
                mult *= w.severity
        return mult

    def __repr__(self) -> str:  # keeps sweep arm labels readable
        parts = [f"seed={self.seed}"]
        for name in ("crash_rate", "cold_fail_rate", "probe_timeout_rate",
                     "throttle_rate", "lost_completion_rate"):
            v = getattr(self, name)
            if v:
                parts.append(f"{name}={v}")
        if self.windows:
            parts.append(f"windows={len(self.windows)}")
        return f"FaultPlan({', '.join(parts)})"


def decorrelated_jitter_ms(
    rng: np.random.RandomState,
    prev_ms: float,
    *,
    base_ms: float,
    cap_ms: float,
) -> float:
    """One step of capped decorrelated-jitter backoff.

    ``sleep = min(cap, uniform(base, max(base, prev * 3)))`` — each delay
    is drawn relative to the *previous* delay, which de-synchronizes
    retry storms better than plain exponential-with-jitter. ``base <= 0``
    disables backoff and draws nothing.
    """
    if base_ms <= 0.0:
        return 0.0
    hi = max(base_ms, prev_ms * 3.0)
    delay = base_ms + rng.random_sample() * (hi - base_ms)
    return float(min(cap_ms, delay))


__all__ = [
    "FaultPlan",
    "FaultWindow",
    "RecoveryPolicy",
    "decorrelated_jitter_ms",
]
