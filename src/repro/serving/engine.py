"""Model-serving engine with Minos replica selection.

The FaaS→TPU-serving adaptation (DESIGN.md §2): a *replica* is one
mesh-worth of serving capacity hosting the model; the platform's worker
heterogeneity becomes per-replica speed factors (co-tenant hosts, thermal
variation, degraded links). The Minos layer is the paper's algorithm
verbatim: on replica spin-up a matmul probe runs during the *prepare* phase
(weight load), the replica judges itself against the elysium threshold, and
either joins the pool or re-queues its request and despawns.

The model compute is REAL (JAX prefill/decode of the configured arch); time
is simulated as work/speed so the selection dynamics are measurable without
a fleet. ``requeue_penalty`` accounts for the family asymmetry: full-
attention archs must re-prefill their KV cache on the new replica, SSM
archs just replay O(d_state) state (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.cost import Pricing, WorkflowCost
from repro.core.lifecycle import FunctionInstance
from repro.core.policy import MinosPolicy, Verdict
from repro.core.queue import Invocation, InvocationQueue
from repro.models.model import Model, build_model, greedy_token


@dataclasses.dataclass
class ServeRequest:
    prompt: np.ndarray          # (S,) int32
    max_new_tokens: int = 16
    request_id: int = 0


@dataclasses.dataclass
class ServeResult:
    request_id: int
    tokens: np.ndarray
    sim_duration_ms: float
    replica_speed: float
    retries: int


@dataclasses.dataclass
class Replica:
    instance: FunctionInstance
    params: Any
    model: Model

    @property
    def speed(self) -> float:
        return self.instance.speed_factor


class MinosServingEngine:
    """Single-host engine; replicas share one set of weights (they would be
    per-host copies on a fleet). Work units: prefill = S tokens * c_prefill,
    decode = steps * c_decode ms at unit speed."""

    def __init__(
        self,
        cfg: ArchConfig,
        policy: MinosPolicy,
        pricing: Pricing,
        *,
        seed: int = 0,
        speed_sigma: float = 0.15,
        probe_work_ms: float = 200.0,
        weight_load_ms: float = 400.0,   # the 'prepare' phase that hides the probe
        c_prefill_ms_per_tok: float = 0.5,
        c_decode_ms_per_tok: float = 5.0,
        max_pool: int = 8,
    ) -> None:
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params = self.model.init(jax.random.PRNGKey(seed))
        self.policy = policy
        self.cost = WorkflowCost(pricing)
        self.rng = np.random.RandomState(seed)
        self.speed_sigma = speed_sigma
        self.probe_work_ms = probe_work_ms
        self.weight_load_ms = weight_load_ms
        self.c_prefill = c_prefill_ms_per_tok
        self.c_decode = c_decode_ms_per_tok
        self.max_pool = max_pool
        self.pool: list[Replica] = []
        self.queue = InvocationQueue()
        self.now_ms = 0.0
        self.replicas_started = 0
        self.replicas_terminated = 0
        self.probe_observations: list[float] = []

    # ---- replica lifecycle -------------------------------------------
    def _spawn_replica(self) -> Replica:
        self.replicas_started += 1
        speed = float(np.exp(self.rng.normal(0.0, self.speed_sigma)))
        inst = FunctionInstance(speed_factor=speed, created_at_ms=self.now_ms)
        return Replica(instance=inst, params=self.params, model=self.model)

    def requeue_penalty_ms(self, req: ServeRequest) -> float:
        """Cost of moving an in-flight stream to another replica."""
        if self.cfg.family in ("xlstm", "hybrid"):
            return 5.0  # O(d_state) state transfer
        return self.c_prefill * len(req.prompt)  # re-prefill the KV cache

    # ---- serving ------------------------------------------------------
    def _acquire_replica(self, inv: Invocation) -> Optional[Replica]:
        """Warm replica, or cold spin-up gated by the elysium benchmark.
        Returns None if the spin-up was terminated (request requeued)."""
        if self.pool:
            return self.pool.pop()
        rep = self._spawn_replica()
        if not self.policy.should_benchmark(inv.retry_count, is_cold_start=True):
            rep.instance.accept_without_benchmark()
            self.now_ms += self.weight_load_ms
            self.cost.record_passed(self.weight_load_ms)
            return rep
        probe = rep.instance.run_benchmark(self.probe_work_ms)
        self.probe_observations.append(probe)
        verdict = rep.instance.judge(self.policy, inv.retry_count)
        if verdict is Verdict.TERMINATE:
            self.replicas_terminated += 1
            billed = max(probe, 0.0)
            self.now_ms += max(probe, 0.0)  # probe ran under weight load
            self.cost.record_terminated(billed)
            self.queue.requeue(inv, self.now_ms)
            return None
        self.now_ms += max(self.weight_load_ms, probe)
        return rep

    def _run_request(self, rep: Replica, req: ServeRequest) -> ServeResult:
        model, cfg = rep.model, self.cfg
        prompt = jnp.asarray(req.prompt, jnp.int32)[None]
        cache = model.init_cache(1, len(req.prompt) + req.max_new_tokens)
        if cfg.family == "encdec":
            frames = jnp.zeros((1, cfg.encoder_frames, cfg.d_model), jnp.float32)
            _, cache = model.prefill(self.params, {"frames": frames}, cache)
            tok = prompt[:, :1]
        else:
            _, cache = model.prefill(self.params, {"tokens": prompt}, cache)
            tok = prompt[:, -1:]
        out = []
        for _ in range(req.max_new_tokens):
            logits, cache = model.decode_step(self.params, cache, tok)
            tok = greedy_token(logits)
            out.append(int(tok[0, 0]))
        work = self.c_prefill * len(req.prompt) + self.c_decode * req.max_new_tokens
        dur = work / rep.speed
        return ServeResult(
            request_id=req.request_id,
            tokens=np.asarray(out, np.int32),
            sim_duration_ms=dur,
            replica_speed=rep.speed,
            retries=0,
        )

    def serve(self, requests: list[ServeRequest]) -> list[ServeResult]:
        for r in requests:
            self.queue.push(Invocation(payload=r), self.now_ms)
        results: list[ServeResult] = []
        while len(self.queue):
            inv = self.queue.pop()
            rep = self._acquire_replica(inv)
            if rep is None:
                self.now_ms += self.requeue_penalty_ms(inv.payload)
                continue
            res = self._run_request(rep, inv.payload)
            res.retries = inv.terminations_experienced
            self.now_ms += res.sim_duration_ms
            served_cold = rep.instance.invocations_served == 0
            if served_cold:
                self.cost.record_passed(res.sim_duration_ms)
            else:
                self.cost.record_reused(res.sim_duration_ms)
            rep.instance.serve(self.now_ms)
            results.append(res)
            if len(self.pool) < self.max_pool:
                self.pool.append(rep)
        return results

    @property
    def pool_mean_speed(self) -> float:
        if not self.pool:
            return float("nan")
        return float(np.mean([r.speed for r in self.pool]))
