"""Model-serving engine with Minos replica selection — a thin wrapper over
the shared execution substrate (DESIGN.md §9).

The FaaS→TPU-serving adaptation (DESIGN.md §2): a *replica* is one
mesh-worth of serving capacity hosting the model; the platform's worker
heterogeneity becomes per-replica speed factors. The Minos layer is the
paper's algorithm verbatim: on replica spin-up a matmul probe runs during
the *prepare* phase (weight load), the replica judges itself against the
elysium threshold, and either joins the pool or re-queues its request and
despawns.

All execution machinery (replica pool, gate, clock, queue, billing) is the
:class:`~repro.core.substrate.SubstrateEngine`; this module only adapts the
request/result types and exposes the historical serving API. Because both
this engine and the simulator are backends of the same substrate, the
serving path supports :class:`~repro.sim.platform.PlatformProfile` hosting
knobs, contention drift, LIFO/FIFO pools, and idle/recycle reclaim — and an
:class:`~repro.core.policy.AdaptiveMinosPolicy` gets its probe stream wired
automatically.

The model compute is REAL (JAX prefill/decode of the configured arch); time
is simulated as work/speed so the selection dynamics are measurable without
a fleet.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Any, Optional

import numpy as np

from repro.configs.base import ArchConfig
from repro.core.cost import Pricing
from repro.core.lifecycle import FunctionInstance
from repro.core.substrate import RequestResult, SubstrateEngine
from repro.serving.backend import ModelServingBackend, ServeRequest, ServeResult

if TYPE_CHECKING:
    from repro.sim.platform import PlatformProfile

__all__ = ["MinosServingEngine", "Replica", "ServeRequest", "ServeResult"]


@dataclasses.dataclass
class Replica:
    """View of one pooled serving instance (weights are shared on one host;
    they would be per-host copies on a fleet)."""

    instance: FunctionInstance
    params: Any
    model: Any

    @property
    def speed(self) -> float:
        return self.instance.speed_factor


class MinosServingEngine(SubstrateEngine):
    """Single-host engine over a :class:`ModelServingBackend`.

    ``serve`` keeps the historical synchronous semantics: requests are
    processed in order, each driven to completion on the shared simulated
    clock (so replica reuse compounds across the batch exactly as before).
    """

    def __init__(
        self,
        cfg: ArchConfig,
        policy,
        pricing: Pricing,
        *,
        seed: int = 0,
        speed_sigma: float = 0.15,
        probe_work_ms: float = 200.0,
        weight_load_ms: float = 400.0,
        c_prefill_ms_per_tok: float = 0.5,
        c_decode_ms_per_tok: float = 5.0,
        max_pool: int = 8,
        contention_rho: float = 1.0,
        variation=None,
        profile: Optional["PlatformProfile"] = None,
        online_controller=None,
        per_instance_concurrency: int = 1,
        load_slowdown_alpha: float = 0.0,
        gate_load_aware: bool = False,
        decode_mode: str = "jit",
        controller=None,
    ) -> None:
        backend = ModelServingBackend(
            cfg,
            seed=seed,
            variation=variation,
            speed_sigma=speed_sigma,
            probe_work_ms=probe_work_ms,
            weight_load_ms=weight_load_ms,
            c_prefill_ms_per_tok=c_prefill_ms_per_tok,
            c_decode_ms_per_tok=c_decode_ms_per_tok,
            contention_rho=contention_rho,
            max_pool=max_pool,
            per_instance_concurrency=per_instance_concurrency,
            load_slowdown_alpha=load_slowdown_alpha,
            gate_load_aware=gate_load_aware,
            decode_mode=decode_mode,
        )
        knobs = (
            profile.knobs(max_pool=max_pool)
            if profile is not None
            else backend.default_knobs(max_pool=max_pool)
        )
        super().__init__(
            backend, policy, pricing,
            knobs=knobs, seed=seed, online_controller=online_controller,
            controller=controller,
        )
        self.cfg = cfg
        self.model = backend.model
        self.params = backend.params
        self.max_pool = max_pool

    # ---- serving ------------------------------------------------------
    def serve(self, requests: list[ServeRequest]) -> list[ServeResult]:
        results: list[ServeResult] = []
        for req in requests:
            done: list[RequestResult] = []
            self.submit(req, done.append)
            self.loop.run_all()
            assert done, "request did not complete"
            res = done[0]
            results.append(ServeResult(
                request_id=req.request_id,
                tokens=res.output,
                sim_duration_ms=res.analysis_ms,
                replica_speed=res.instance_speed,
                retries=res.retries,
                latency_ms=res.latency_ms,
            ))
        return results

    def requeue_penalty_ms(self, req: ServeRequest) -> float:
        return self.backend.requeue_penalty_ms(req)

    # ---- historical views --------------------------------------------
    @property
    def now_ms(self) -> float:
        return self.loop.now

    @property
    def replicas(self) -> list[Replica]:
        return [Replica(instance=i, params=self.params, model=self.model)
                for i in self.pool.available]

    @property
    def replicas_started(self) -> int:
        return self.instances_started

    @property
    def replicas_terminated(self) -> int:
        return self.instances_terminated

    @property
    def probe_observations(self) -> list[float]:
        return self.gate.observations

    @property
    def jit_stats(self) -> dict:
        """Compile/call counters of the backend's jitted decode path."""
        return self.backend.jit_stats

    @property
    def pool_mean_speed(self) -> float:
        speeds = self.pool.speeds_view()  # cached: no per-read rebuild
        if not speeds:
            return float("nan")
        return float(np.mean(speeds))
