"""Model-serving backend for the shared execution substrate (DESIGN.md §9).

The FaaS→TPU-serving adaptation (DESIGN.md §2) expressed as a
:class:`~repro.core.substrate.Backend`: a *replica* is just a substrate
instance whose body work is REAL JAX prefill/decode of the configured
architecture instead of a sampled duration. Everything else — the warm
replica pool, the elysium gate, the simulated clock, requeue semantics,
platform profiles, contention drift — comes from the substrate, identical
to the simulator path.

The model compute is **jitted** (ROADMAP: "JIT the serving decode path"):
prefill runs through ``Model.prefill_jit`` and the whole greedy decode loop
is ONE compiled scan (``Model.decode_tokens``) instead of per-token Python
dispatches. Shapes are padded to buckets so the compile cache stays small:

* decode steps and cache length round up to power-of-two buckets — extra
  scan steps only append tokens past the requested prefix, so outputs are
  unchanged (the caller slices the first ``max_new_tokens``);
* the batch dimension rounds the replica's in-flight stream count (the
  ``load`` the engine passes to :meth:`body`) up to a bucket, so
  ``per_instance_concurrency > 1`` is real batched compute, not an
  idealized no-op;
* prompt lengths are NOT padded: causal prefill without per-row length
  masking would change the last-token logits, and a serving stage sees few
  distinct prompt lengths anyway (jax caches one executable per length).

``jit_stats`` counts compiles/calls so sweeps and CI can assert the jitted
path is actually hit (``eager_calls == 0``); ``decode_mode="eager"`` keeps
the un-jitted loop as an explicit baseline for the same guard to measure
against.

Work units: prefill = S tokens × c_prefill, decode = steps × c_decode ms at
unit speed; observed duration = work / replica speed — the engine then
applies the platform's load-slowdown curve on top
(``SubstrateKnobs.load_slowdown_alpha``; :meth:`calibrate_load_slowdown`
fits that curve from the real batched compute). ``requeue_penalty_ms``
accounts for the family asymmetry when an in-flight stream migrates to a new
replica: full-attention archs must re-prefill their KV cache (enc-dec archs
re-encode the audio window), SSM archs just replay O(d_state) state
(DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.lifecycle import FunctionInstance
from repro.core.substrate import SubstrateKnobs, ar1_drift, sample_jitter
from repro.models.model import Model, build_model, greedy_token
from repro.sim.variation import VariationModel


@dataclasses.dataclass
class ServeRequest:
    prompt: np.ndarray          # (S,) int32
    max_new_tokens: int = 16
    request_id: int = 0


@dataclasses.dataclass
class ServeResult:
    request_id: int
    tokens: np.ndarray
    sim_duration_ms: float
    replica_speed: float
    retries: int
    latency_ms: float = 0.0     # end-to-end simulated latency (queue + cold + body)


def _bucket(n: int, base: int = 1) -> int:
    """Round ``n`` up to the next power-of-two bucket, floored at ``base``."""
    if n < 1:
        raise ValueError("bucket size must be >= 1")
    b = base
    while b < n:
        b <<= 1
    return b


class ModelServingBackend:
    """Substrate backend whose body is real (jitted) model compute.

    Replica speed heterogeneity (co-tenant hosts, thermal variation,
    degraded links) comes from a :class:`VariationModel` — the same
    distribution family the simulator uses, so serving runs can exercise
    diurnal cycles and day drift too. ``contention_rho`` < 1 adds the
    per-serve AR(1) drift of a replica's certified speed (1.0 = frozen,
    the idealized model).

    ``per_instance_concurrency`` / ``load_slowdown_alpha`` /
    ``gate_load_aware`` feed :meth:`default_knobs`, making replica load a
    hosting property of this backend (DESIGN.md §9 load model).
    """

    def __init__(
        self,
        cfg: ArchConfig,
        *,
        seed: int = 0,
        variation: Optional[VariationModel] = None,
        speed_sigma: float = 0.15,
        probe_work_ms: float = 200.0,
        probe_noise: float = 0.0,
        weight_load_ms: float = 400.0,   # the 'prepare' phase that hides the probe
        c_prefill_ms_per_tok: float = 0.5,
        c_decode_ms_per_tok: float = 5.0,
        contention_rho: float = 1.0,
        max_pool: Optional[int] = 8,
        name: Optional[str] = None,
        model: Optional[Model] = None,
        params: Any = None,
        per_instance_concurrency: int = 1,
        load_slowdown_alpha: float = 0.0,
        gate_load_aware: bool = False,
        decode_mode: str = "jit",        # "jit" | "eager" (baseline)
        decode_bucket: int = 8,          # decode-step bucket floor
        max_decode_batch: int = 8,       # cap on the batched-stream bucket
    ) -> None:
        if decode_mode not in ("jit", "eager"):
            raise ValueError(f"decode_mode must be 'jit' or 'eager', got {decode_mode!r}")
        self.cfg = cfg
        self.model = model if model is not None else build_model(cfg)
        self.params = params if params is not None else self.model.init(jax.random.PRNGKey(seed))
        self.variation = variation if variation is not None else VariationModel(sigma=speed_sigma)
        self.probe_work_ms = probe_work_ms
        self.probe_noise = probe_noise
        self.weight_load_ms = weight_load_ms
        self.c_prefill = c_prefill_ms_per_tok
        self.c_decode = c_decode_ms_per_tok
        self.contention_rho = contention_rho
        self.max_pool = max_pool
        self.name = name if name is not None else f"serve-{cfg.arch_id}"
        self.per_instance_concurrency = per_instance_concurrency
        self.load_slowdown_alpha = load_slowdown_alpha
        self.gate_load_aware = gate_load_aware
        self.decode_mode = decode_mode
        self.decode_bucket = decode_bucket
        self.max_decode_batch = max_decode_batch
        self._compiled_buckets: set[tuple] = set()
        self.jit_stats = {"jit_calls": 0, "eager_calls": 0, "bucket_compiles": 0}

    # -- substrate hooks -----------------------------------------------
    def sample_speed(self, rng: np.random.RandomState, t_ms: float) -> float:
        return self.variation.sample_speed(rng, t_ms=t_ms)

    def reuse_drift(self, inst: FunctionInstance, rng: np.random.RandomState, t_ms: float) -> None:
        ar1_drift(
            inst, rng,
            day_mean=self.variation.day_factor * self.variation.diurnal(t_ms),
            sigma=self.variation.sigma,
            rho=self.contention_rho,
        )

    def prepare_ms(self, rng: np.random.RandomState) -> float:
        return self.weight_load_ms

    def probe(self, inst: FunctionInstance, rng: np.random.RandomState) -> float:
        obs = inst.run_benchmark(self.probe_work_ms) * sample_jitter(rng, self.probe_noise)
        inst.benchmark_result = obs
        return obs

    def reprobe(self, inst: FunctionInstance, rng: np.random.RandomState) -> float:
        """Warm re-benchmark of a pooled replica (control plane,
        ReuseDecision.REPROBE): the same matmul probe, measured at the
        replica's current (contention-drifted) speed, no lifecycle
        transition. Cheap by construction — probe work, not model work —
        and it hides under the prepare phase like the cold probe does."""
        return (self.probe_work_ms / inst.speed_factor) * sample_jitter(
            rng, self.probe_noise)

    def body(
        self,
        payload: Any,
        inst: FunctionInstance,
        rng: np.random.RandomState,
        *,
        load: int = 1,
    ) -> tuple[float, Any]:
        req: ServeRequest = payload
        tokens = self.run_model(req, load=load)
        work = self.c_prefill * len(req.prompt) + self.c_decode * req.max_new_tokens
        return work / inst.speed_factor, tokens

    def requeue_penalty_ms(self, payload: Any) -> float:
        """Cost of moving an in-flight stream to another replica."""
        if self.cfg.family in ("xlstm", "hybrid"):
            return 5.0  # O(d_state) state transfer
        if self.cfg.family == "encdec":
            # the new replica re-encodes the audio window (cross-attention
            # KV is a function of the encoder output, not the prompt)
            return self.c_prefill * self.cfg.encoder_frames
        return self.c_prefill * len(payload.prompt)  # re-prefill the KV cache

    # -- model compute --------------------------------------------------
    def run_model(
        self, req: ServeRequest, *, load: int = 1, mode: Optional[str] = None,
    ) -> np.ndarray:
        """Greedy-decode ``req`` and return its tokens ((T,) int32).

        ``load`` >= 2 batches the decode across the replica's concurrent
        streams (batch bucket; row 0 is this request — rows are computed
        independently, so the tokens do not depend on the padding).
        ``mode`` overrides ``self.decode_mode`` for measurement.
        """
        mode = mode if mode is not None else self.decode_mode
        model, cfg = self.model, self.cfg
        T = req.max_new_tokens
        prompt = jnp.asarray(req.prompt, jnp.int32)[None]
        S = int(prompt.shape[1])

        if mode == "eager":
            self.jit_stats["eager_calls"] += 1
            cache = model.init_cache(1, S + T)
            if cfg.family == "encdec":
                frames = jnp.zeros((1, cfg.encoder_frames, cfg.d_model), jnp.float32)
                _, cache = model.prefill(self.params, {"frames": frames}, cache)
                tok = prompt[:, :1]
            else:
                _, cache = model.prefill(self.params, {"tokens": prompt}, cache)
                tok = prompt[:, -1:]
            out = []
            for _ in range(T):
                logits, cache = model.decode_step(self.params, cache, tok)
                tok = greedy_token(logits)
                out.append(int(tok[0, 0]))
            return np.asarray(out, np.int32)

        B = min(_bucket(max(1, load)), self.max_decode_batch)
        Tb = _bucket(T, base=self.decode_bucket)
        # cache length is bucketed too, so decode_tokens executables are
        # shared across prompt lengths that land in the same bucket (decode
        # attention masks by `lengths`, so the padded tail is never read)
        cache_len = _bucket(S + Tb, base=self.decode_bucket)
        key = (cfg.family, B, S, Tb, cache_len)
        if key not in self._compiled_buckets:
            self._compiled_buckets.add(key)
            self.jit_stats["bucket_compiles"] += 1
        if B > 1:
            prompt = jnp.broadcast_to(prompt, (B, S))
        cache = model.init_cache(B, cache_len)
        if cfg.family == "encdec":
            frames = jnp.zeros((B, cfg.encoder_frames, cfg.d_model), jnp.float32)
            _, cache = model.prefill_jit(self.params, {"frames": frames}, cache)
            tok = prompt[:, :1]
        else:
            _, cache = model.prefill_jit(self.params, {"tokens": prompt}, cache)
            tok = prompt[:, -1:]
        toks, _ = model.decode_tokens(self.params, cache, tok, Tb)
        self.jit_stats["jit_calls"] += 1
        return np.asarray(toks[0, :T], np.int32)

    def time_model_ms(
        self, req: ServeRequest, *, mode: str, load: int = 1, repeats: int = 1,
    ) -> float:
        """Mean wall-clock ms per ``run_model`` call (one un-timed warmup
        first, so jit compile time is excluded — steady-state serving cost)."""
        self.run_model(req, load=load, mode=mode)
        t0 = time.perf_counter()
        for _ in range(repeats):
            self.run_model(req, load=load, mode=mode)  # np conversion syncs
        return (time.perf_counter() - t0) * 1e3 / max(1, repeats)

    def calibrate_load_slowdown(
        self,
        loads: tuple[int, ...] = (1, 2, 4),
        *,
        max_new_tokens: int = 8,
        repeats: int = 3,
    ) -> float:
        """Fit the load-slowdown exponent from the REAL batched compute:
        time the jitted decode at several stream counts and least-squares
        ``log time = alpha * log load + c``. The result calibrates
        ``SubstrateKnobs.load_slowdown_alpha`` (alpha 0: batching is free,
        1: perfect serialization; hardware lands in between)."""
        req = ServeRequest(prompt=np.arange(4, dtype=np.int32),
                           max_new_tokens=max_new_tokens)
        ts = [self.time_model_ms(req, mode="jit", load=b, repeats=repeats)
              for b in loads]
        logs_b = np.log(np.asarray(loads, np.float64))
        logs_t = np.log(np.asarray(ts, np.float64))
        alpha = float(np.polyfit(logs_b, logs_t, 1)[0])
        return max(0.0, alpha)

    # -- hosting defaults ----------------------------------------------
    def default_knobs(self, max_pool: Optional[int] = None) -> SubstrateKnobs:
        """Serving replica hosting: spin-up latency IS the weight load
        (prepare), replicas never idle out or get recycled by default, and
        occupancy is billed from spin-up (chip-seconds). Load behavior
        (stream concurrency, slowdown curve, load-aware gating) comes from
        this backend's own knobs."""
        return SubstrateKnobs(
            cold_start_ms=0.0,
            cold_start_jitter=0.0,
            idle_timeout_ms=float("inf"),
            recycle_lifetime_ms=None,
            bill_cold_start=True,
            requeue_overhead_ms=0.0,
            warm_pool_order="lifo",
            per_instance_concurrency=self.per_instance_concurrency,
            max_pool=max_pool if max_pool is not None else self.max_pool,
            load_slowdown_alpha=self.load_slowdown_alpha,
            gate_load_aware=self.gate_load_aware,
        )

    def pretest_threshold(self, pass_fraction: float = 0.4) -> float:
        """Analytic §III-A threshold: the probe duration the fastest
        ``pass_fraction`` of replicas beat under this backend's variation
        model (durations: P(probe ≤ thr) = pass_fraction ⇒ thr =
        probe_work / speed-quantile(1 − pass_fraction))."""
        return self.probe_work_ms / self.variation.speed_quantile(1.0 - pass_fraction)
