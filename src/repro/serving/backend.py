"""Model-serving backend for the shared execution substrate (DESIGN.md §9).

The FaaS→TPU-serving adaptation (DESIGN.md §2) expressed as a
:class:`~repro.core.substrate.Backend`: a *replica* is just a substrate
instance whose body work is REAL JAX prefill/decode of the configured
architecture instead of a sampled duration. Everything else — the warm
replica pool, the elysium gate, the simulated clock, requeue semantics,
platform profiles, contention drift — comes from the substrate, identical
to the simulator path.

Work units: prefill = S tokens × c_prefill, decode = steps × c_decode ms at
unit speed; observed duration = work / replica speed. ``requeue_penalty_ms``
accounts for the family asymmetry when an in-flight stream migrates to a new
replica: full-attention archs must re-prefill their KV cache (enc-dec archs
re-encode the audio window), SSM archs just replay O(d_state) state
(DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.lifecycle import FunctionInstance
from repro.core.substrate import SubstrateKnobs, ar1_drift, sample_jitter
from repro.models.model import Model, build_model, greedy_token
from repro.sim.variation import VariationModel


@dataclasses.dataclass
class ServeRequest:
    prompt: np.ndarray          # (S,) int32
    max_new_tokens: int = 16
    request_id: int = 0


@dataclasses.dataclass
class ServeResult:
    request_id: int
    tokens: np.ndarray
    sim_duration_ms: float
    replica_speed: float
    retries: int
    latency_ms: float = 0.0     # end-to-end simulated latency (queue + cold + body)


class ModelServingBackend:
    """Substrate backend whose body is real model compute.

    Replica speed heterogeneity (co-tenant hosts, thermal variation,
    degraded links) comes from a :class:`VariationModel` — the same
    distribution family the simulator uses, so serving runs can exercise
    diurnal cycles and day drift too. ``contention_rho`` < 1 adds the
    per-serve AR(1) drift of a replica's certified speed (1.0 = frozen,
    the idealized model).
    """

    def __init__(
        self,
        cfg: ArchConfig,
        *,
        seed: int = 0,
        variation: Optional[VariationModel] = None,
        speed_sigma: float = 0.15,
        probe_work_ms: float = 200.0,
        probe_noise: float = 0.0,
        weight_load_ms: float = 400.0,   # the 'prepare' phase that hides the probe
        c_prefill_ms_per_tok: float = 0.5,
        c_decode_ms_per_tok: float = 5.0,
        contention_rho: float = 1.0,
        max_pool: Optional[int] = 8,
        name: Optional[str] = None,
        model: Optional[Model] = None,
        params: Any = None,
    ) -> None:
        self.cfg = cfg
        self.model = model if model is not None else build_model(cfg)
        self.params = params if params is not None else self.model.init(jax.random.PRNGKey(seed))
        self.variation = variation if variation is not None else VariationModel(sigma=speed_sigma)
        self.probe_work_ms = probe_work_ms
        self.probe_noise = probe_noise
        self.weight_load_ms = weight_load_ms
        self.c_prefill = c_prefill_ms_per_tok
        self.c_decode = c_decode_ms_per_tok
        self.contention_rho = contention_rho
        self.max_pool = max_pool
        self.name = name if name is not None else f"serve-{cfg.arch_id}"

    # -- substrate hooks -----------------------------------------------
    def sample_speed(self, rng: np.random.RandomState, t_ms: float) -> float:
        return self.variation.sample_speed(rng, t_ms=t_ms)

    def reuse_drift(self, inst: FunctionInstance, rng: np.random.RandomState, t_ms: float) -> None:
        ar1_drift(
            inst, rng,
            day_mean=self.variation.day_factor * self.variation.diurnal(t_ms),
            sigma=self.variation.sigma,
            rho=self.contention_rho,
        )

    def prepare_ms(self, rng: np.random.RandomState) -> float:
        return self.weight_load_ms

    def probe(self, inst: FunctionInstance, rng: np.random.RandomState) -> float:
        obs = inst.run_benchmark(self.probe_work_ms) * sample_jitter(rng, self.probe_noise)
        inst.benchmark_result = obs
        return obs

    def body(
        self, payload: Any, inst: FunctionInstance, rng: np.random.RandomState
    ) -> tuple[float, Any]:
        req: ServeRequest = payload
        model, cfg = self.model, self.cfg
        prompt = jnp.asarray(req.prompt, jnp.int32)[None]
        cache = model.init_cache(1, prompt.shape[1] + req.max_new_tokens)
        if cfg.family == "encdec":
            frames = jnp.zeros((1, cfg.encoder_frames, cfg.d_model), jnp.float32)
            _, cache = model.prefill(self.params, {"frames": frames}, cache)
            tok = prompt[:, :1]
        else:
            _, cache = model.prefill(self.params, {"tokens": prompt}, cache)
            tok = prompt[:, -1:]
        out = []
        for _ in range(req.max_new_tokens):
            logits, cache = model.decode_step(self.params, cache, tok)
            tok = greedy_token(logits)
            out.append(int(tok[0, 0]))
        work = self.c_prefill * int(prompt.shape[1]) + self.c_decode * req.max_new_tokens
        return work / inst.speed_factor, np.asarray(out, np.int32)

    def requeue_penalty_ms(self, payload: Any) -> float:
        """Cost of moving an in-flight stream to another replica."""
        if self.cfg.family in ("xlstm", "hybrid"):
            return 5.0  # O(d_state) state transfer
        if self.cfg.family == "encdec":
            # the new replica re-encodes the audio window (cross-attention
            # KV is a function of the encoder output, not the prompt)
            return self.c_prefill * self.cfg.encoder_frames
        return self.c_prefill * len(payload.prompt)  # re-prefill the KV cache

    # -- hosting defaults ----------------------------------------------
    def default_knobs(self, max_pool: Optional[int] = None) -> SubstrateKnobs:
        """Serving replica hosting: spin-up latency IS the weight load
        (prepare), replicas never idle out or get recycled by default, and
        occupancy is billed from spin-up (chip-seconds)."""
        return SubstrateKnobs(
            cold_start_ms=0.0,
            cold_start_jitter=0.0,
            idle_timeout_ms=float("inf"),
            recycle_lifetime_ms=None,
            bill_cold_start=True,
            requeue_overhead_ms=0.0,
            warm_pool_order="lifo",
            per_instance_concurrency=1,
            max_pool=max_pool if max_pool is not None else self.max_pool,
        )

    def pretest_threshold(self, pass_fraction: float = 0.4) -> float:
        """Analytic §III-A threshold: the probe duration the fastest
        ``pass_fraction`` of replicas beat under this backend's variation
        model (durations: P(probe ≤ thr) = pass_fraction ⇒ thr =
        probe_work / speed-quantile(1 − pass_fraction))."""
        return self.probe_work_ms / self.variation.speed_quantile(1.0 - pass_fraction)
