"""Assigned input shapes and ShapeDtypeStruct stand-ins for the dry-run.

Decode shapes lower ``decode_step`` (ONE new token against a seq_len KV
cache / recurrent state), not ``train_step``. ``long_500k`` is only emitted
for architectures with a sub-quadratic path (SSM/hybrid native; dense via
the sliding-window variant); whisper-small skips it (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.model import build_model


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def applicable(cfg: ArchConfig, shape_name: str) -> tuple[bool, str]:
    """(runs?, reason-if-skipped)."""
    if shape_name == "long_500k" and cfg.family == "encdec":
        return False, (
            f"{cfg.arch_id}: encoder-decoder with full cross-attention and a "
            "448-token decoder — a sub-quadratic long-context variant is not "
            "meaningful (DESIGN.md §4)"
        )
    return True, ""


def config_for_shape(cfg: ArchConfig, shape_name: str) -> ArchConfig:
    """long_500k on dense/MoE/VLM archs runs the documented sliding-window
    VARIANT (w=4096) — the sub-quadratic path; SSM/hybrid run natively."""
    if (
        shape_name == "long_500k"
        and cfg.sliding_window is None
        and cfg.family in ("dense", "moe", "vlm")
    ):
        return dataclasses.replace(cfg, sliding_window=4096)
    return cfg


def input_specs(cfg: ArchConfig, shape_name: str) -> dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of the step kind.

    Returns {"kind", "batch": pytree-of-SDS, "cache": pytree-of-SDS or None}.
    No device memory is allocated.
    """
    shp = INPUT_SHAPES[shape_name]
    B, S = shp.global_batch, shp.seq_len
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct

    if shp.kind == "train":
        batch: dict[str, Any] = {
            "tokens": sds((B, S), i32),
            "labels": sds((B, S), i32),
        }
        if cfg.family == "encdec":
            batch["frames"] = sds((B, cfg.encoder_frames, cfg.d_model), jnp.float32)
        return {"kind": "train", "batch": batch, "cache": None}

    if shp.kind == "prefill":
        if cfg.family == "encdec":
            batch = {"frames": sds((B, cfg.encoder_frames, cfg.d_model), jnp.float32)}
        else:
            batch = {"tokens": sds((B, S), i32)}
        model = build_model(cfg)
        cache = jax.eval_shape(lambda: model.init_cache(B, S))
        return {"kind": "prefill", "batch": batch, "cache": cache}

    # decode: one token against a seq_len cache
    model = build_model(cfg)
    cache = jax.eval_shape(lambda: model.init_cache(B, S))
    return {
        "kind": "decode",
        "batch": {"tokens": sds((B, 1), i32)},
        "cache": cache,
    }
