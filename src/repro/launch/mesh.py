"""Production meshes. A FUNCTION (not a module-level constant) so importing
this module never touches jax device state — device count is locked on
first jax init, and only dryrun.py sets the 512-device XLA flag."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 (data, model) single pod of TPU v5e; 2x16x16 (pod, data, model)
    for the two-pod deployment. Requires 256 / 512 visible devices."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist, as a 1D 'data' mesh (CPU tests)."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))
