import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
combination on the production meshes, print memory/cost analyses, and dump
roofline terms to JSON for benchmarks/roofline_table.py.

Usage:
  python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
  python -m repro.launch.dryrun --all                 # 10 x 4, single pod
  python -m repro.launch.dryrun --all --multi-pod     # + the 2-pod mesh
"""
import argparse
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.configs.registry import ARCH_IDS, get_config
from repro.distributed.sharding import use_mesh
from repro.launch.mesh import make_production_mesh
from repro.launch.shardings import make_rules, shard_inputs, shard_params_like
from repro.launch.specs import INPUT_SHAPES, applicable, config_for_shape, input_specs
from repro.models.model import build_model
from repro.optim.adamw import AdamW
from repro.roofline.hlo import Roofline, analyze_hlo

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[3] / "benchmarks" / "results"


def build_lowerable(cfg: ArchConfig, shape_name: str, mesh, *,
                    dp_only: bool = False, fsdp: bool = False,
                    accum_steps: int = 1):
    """dp_only / fsdp / accum_steps are the §Perf hillclimb knobs; all
    default off = the paper-faithful baseline configuration."""
    model = build_model(cfg)
    specs = input_specs(cfg, shape_name)
    batch_sds, cache_sds = shard_inputs(cfg, mesh, specs)
    params_shape = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    params_sds = shard_params_like(params_shape, cfg, mesh,
                                   fsdp=fsdp, replicate=dp_only)
    kind = specs["kind"]

    if kind == "train":
        opt = AdamW(learning_rate=3e-4)
        opt_shape = jax.eval_shape(opt.init, params_shape)
        # ZeRO-1+: optimizer state is data-sharded under fsdp — including
        # combined with dp_only (replicated params, sharded opt state)
        opt_sds = shard_params_like(opt_shape, cfg, mesh,
                                    fsdp=fsdp, replicate=dp_only and not fsdp)

        if accum_steps > 1:

            def train_step(params, opt_state, batch):
                def micro(b):
                    return jax.tree.map(
                        lambda t: t.reshape((accum_steps, -1) + t.shape[1:]), b
                    )

                mb = micro(batch)

                def body(acc, b):
                    (loss, _), grads = jax.value_and_grad(
                        lambda p: model.loss(p, b, remat=True), has_aux=True
                    )(params)
                    acc = jax.tree.map(
                        lambda a, g: a + g.astype(jnp.float32), acc, grads
                    )
                    return acc, loss

                zeros = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params
                )
                grads, losses = jax.lax.scan(body, zeros, mb)
                grads = jax.tree.map(lambda g: g / accum_steps, grads)
                params, opt_state, _ = opt.update(grads, opt_state, params)
                return params, opt_state, jnp.mean(losses)

        else:

            def train_step(params, opt_state, batch):
                (loss, metrics), grads = jax.value_and_grad(
                    lambda p: model.loss(p, batch, remat=True), has_aux=True
                )(params)
                params, opt_state, om = opt.update(grads, opt_state, params)
                return params, opt_state, loss

        return train_step, (params_sds, opt_sds, batch_sds)

    if kind == "prefill":

        def prefill_step(params, batch, cache):
            return model.prefill(params, batch, cache)

        return prefill_step, (params_sds, batch_sds, cache_sds)

    def decode_step(params, cache, tokens):
        return model.decode_step(params, cache, tokens)

    return decode_step, (params_sds, cache_sds, batch_sds["tokens"])


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            save: bool = True, verbose: bool = True,
            dp_only: bool = False, fsdp: bool = False, accum_steps: int = 1,
            cache_update: str = "onehot", decode_attn: str = "local",
            seq_parallel: bool = False, slstm_shard_map: bool = False,
            tag: str = "") -> dict:
    from repro.launch import shardings as _sh
    from repro.models import attention as _attn
    from repro.models import xlstm as _xl
    _xl.SLSTM_SHARD_MAP = slstm_shard_map
    _attn.CACHE_UPDATE_MODE = cache_update
    _attn.DECODE_ATTN_MODE = decode_attn
    _sh.FORCE_SEQ_SHARD_CACHE = decode_attn == "shard_map"
    cfg = config_for_shape(get_config(arch), shape_name)
    ok, reason = applicable(cfg, shape_name)
    label = f"{arch} x {shape_name} x {'2pod' if multi_pod else '1pod'}"
    if tag:
        label += f" [{tag}]"
    if not ok:
        if verbose:
            print(f"SKIP {label}: {reason}")
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "status": "skipped", "reason": reason}
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = make_rules(cfg, mesh)
    # batch too small to shard over the data axes -> replicate activations
    data_size = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            data_size *= dict(zip(mesh.axis_names, mesh.devices.shape))[a]
    if INPUT_SHAPES[shape_name].global_batch % data_size != 0:
        rules["batch"] = None
    if seq_parallel:
        # §Perf pick-1 iter-4: sequence parallelism — the residual stream's
        # seq dim shards over "model" between blocks (Korthikanti et al.),
        # turning Megatron activation ARs into RS+AG and dividing the
        # backward activation stash by the model-axis size.
        rules["seq"] = "model"
    t0 = time.perf_counter()
    if dp_only:
        from repro.launch.shardings import dp_only_rules
        rules = dp_only_rules(mesh, INPUT_SHAPES[shape_name].global_batch)
    try:
        with use_mesh(mesh, rules):
            fn, args = build_lowerable(cfg, shape_name, mesh, dp_only=dp_only,
                                       fsdp=fsdp, accum_steps=accum_steps)
            lowered = jax.jit(fn).lower(*args)
            t_lower = time.perf_counter() - t0
            compiled = lowered.compile()
            t_compile = time.perf_counter() - t0 - t_lower
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            hlo = compiled.as_text()
    except Exception as e:
        if verbose:
            print(f"FAIL {label}: {type(e).__name__}: {e}")
            traceback.print_exc(limit=3)
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "status": "failed", "error": f"{type(e).__name__}: {e}"}

    chips = mesh.devices.size
    stats = analyze_hlo(hlo, default_group=16)
    shp = INPUT_SHAPES[shape_name]
    tokens = shp.global_batch * (shp.seq_len if shp.kind == "train" else
                                 (shp.seq_len if shp.kind == "prefill" else 1))
    n_active = cfg.active_param_count()
    mult = 3.0 if shp.kind == "train" else 1.0  # fwd+bwd = 3x fwd FLOPs
    model_flops = 2.0 * n_active * tokens * mult
    roof = Roofline(
        flops=stats.flops, hbm_bytes=stats.hbm_bytes,
        collective_bytes=stats.collective_bytes, chips=chips,
        model_flops=model_flops, stats=stats,
    )
    result = {
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
        "status": "ok", "tag": tag,
        "opts": {"dp_only": dp_only, "fsdp": fsdp, "accum_steps": accum_steps,
                 "cache_update": cache_update, "decode_attn": decode_attn,
                 "seq_parallel": seq_parallel, "slstm_shard_map": slstm_shard_map},
        "chips": chips,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": (getattr(mem, "argument_size_in_bytes", 0) or 0)
            + (getattr(mem, "temp_size_in_bytes", 0) or 0),
        },
        "roofline": roof.to_dict(),
    }
    if verbose:
        m = result["memory"]
        r = result["roofline"]
        print(
            f"OK   {label}: compile {t_compile:.0f}s | "
            f"args {(m['argument_bytes'] or 0)/2**30:.2f} GiB/dev, "
            f"temp {(m['temp_bytes'] or 0)/2**30:.2f} GiB/dev | "
            f"T(comp/mem/coll) = {r['t_compute_s']:.3e}/{r['t_memory_s']:.3e}/"
            f"{r['t_collective_s']:.3e} s -> {r['bottleneck']} | "
            f"useful-FLOPs {r['useful_flops_ratio']*100:.0f}%",
            flush=True,
        )
    if save:
        RESULTS_DIR.mkdir(parents=True, exist_ok=True)
        suffix = f"_{tag}" if tag else ""
        fname = f"{arch}_{shape_name}_{'2pod' if multi_pod else '1pod'}{suffix}.json"
        (RESULTS_DIR / fname).write_text(json.dumps(result, indent=2))
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(INPUT_SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    # §Perf hillclimb knobs (defaults = paper-faithful baseline)
    ap.add_argument("--dp-only", action="store_true")
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--accum-steps", type=int, default=1)
    ap.add_argument("--cache-update", choices=("onehot", "scatter"),
                    default="onehot")
    ap.add_argument("--decode-attn", choices=("local", "shard_map"),
                    default="local")
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--slstm-shard-map", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    combos: list[tuple[str, str, bool]] = []
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    if args.all:
        for mp in meshes:
            for a in ARCH_IDS:
                for s in INPUT_SHAPES:
                    combos.append((a, s, mp))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        for mp in meshes:
            combos.append((args.arch, args.shape, mp))

    failures = 0
    for a, s, mp in combos:
        res = run_one(a, s, multi_pod=mp, dp_only=args.dp_only,
                      fsdp=args.fsdp, accum_steps=args.accum_steps,
                      cache_update=args.cache_update,
                      decode_attn=args.decode_attn,
                      seq_parallel=args.seq_parallel,
                      slstm_shard_map=args.slstm_shard_map, tag=args.tag)
        failures += res["status"] == "failed"
    print(f"\n{len(combos)} combos, {failures} failures")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
