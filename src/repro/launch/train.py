"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

On this CPU container it trains the reduced (smoke) variant of the chosen
architecture on the synthetic token stream; on a real TPU fleet the same
entry point takes ``--full --mesh pod|multipod`` and builds the production
mesh + shardings that the dry-run validates.
"""
from __future__ import annotations

import argparse

from repro.configs.registry import ARCH_IDS, get_config, get_smoke_config
from repro.data.pipeline import TokenStream
from repro.train.loop import TrainConfig, train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="llama3.2-1b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--peak-lr", type=float, default=1e-3)
    ap.add_argument("--full", action="store_true",
                    help="full config (requires a TPU fleet; CPU default is "
                         "the reduced smoke variant)")
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else get_smoke_config(args.arch)
    if cfg.family == "encdec":
        raise SystemExit("use examples/ for encoder-decoder training demos")
    data = iter(TokenStream(vocab=cfg.vocab, batch=args.batch,
                            seq_len=args.seq_len, seed=0))
    tc = TrainConfig(peak_lr=args.peak_lr, warmup_steps=max(args.steps // 10, 1),
                     total_steps=args.steps)

    def log(step, m):
        print(f"step {step:5d}  loss {m['loss']:.4f}  nll {m['nll']:.4f}  "
              f"gnorm {m['grad_norm']:.2f}  ({m['wall_s']:.0f}s)", flush=True)

    train(cfg, data, tc, steps=args.steps, log_every=10, log_fn=log)


if __name__ == "__main__":
    main()
