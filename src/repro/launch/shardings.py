"""Per-architecture sharding rules.

Logical->physical rules are computed per arch (divisibility-guarded), and
parameter/optimizer/cache/batch PartitionSpecs are derived from pytree
paths. Anything that cannot shard cleanly falls back to replication — the
roofline table then shows the cost, and the hillclimb (§Perf) fixes the
pairs where it matters.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig

MODEL_AXIS = "model"
DATA_AXES = ("pod", "data")

# §Perf pick-3 iter-4: shard KV caches along LENGTH (flash-decode shard_map
# path). Set by dryrun --decode-attn shard_map.
FORCE_SEQ_SHARD_CACHE = False


def _mesh_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape.get(a, 1) if hasattr(mesh.shape, "get") else dict(zip(mesh.axis_names, mesh.devices.shape))[a]
    return n


def make_rules(cfg: ArchConfig, mesh: Mesh) -> dict[str, Optional[str | tuple[str, ...]]]:
    """Logical-axis rules for this arch on this mesh (divisibility-guarded)."""
    msize = _mesh_size(mesh, MODEL_AXIS)
    data_axes = tuple(a for a in DATA_AXES if a in mesh.axis_names)
    return {
        "batch": data_axes or None,
        "seq": None,
        "model": MODEL_AXIS if cfg.d_model % msize == 0 else None,
        "vocab": MODEL_AXIS if cfg.vocab % msize == 0 else None,
        "expert": MODEL_AXIS if (cfg.moe and cfg.moe.n_experts % msize == 0) else None,
        "ff": MODEL_AXIS,
        "heads": MODEL_AXIS if cfg.n_heads % msize == 0 else None,
        "kv_heads": MODEL_AXIS if cfg.n_kv_heads % msize == 0 else None,
        "state": None,
    }


def _guard(spec: P, shape: tuple[int, ...], mesh: Mesh) -> P:
    """Drop spec axes whose dim isn't divisible by the mesh-axis product."""
    out = []
    for i, entry in enumerate(spec):
        if entry is None:
            out.append(None)
            continue
        if shape[i] % _mesh_size(mesh, entry) != 0:
            out.append(None)
        else:
            out.append(entry)
    return P(*out)


def _path_str(path) -> str:
    return "/".join(
        str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p)))) for p in path
    )


def param_spec(path: str, shape: tuple[int, ...], cfg: ArchConfig, mesh: Mesh) -> P:
    """PartitionSpec for one parameter leaf (layer-stacked leaves have a
    leading L dim — detected by ndim vs the table below)."""
    name = path.split("/")[-1]
    M = MODEL_AXIS
    nd = len(shape)

    def tail(spec_tail: tuple) -> P:
        """Right-align the spec; leading (layer-stack) dims replicate."""
        lead = (None,) * (nd - len(spec_tail))
        return P(*(lead + spec_tail))

    if name == "embed":
        spec = P(M, None)
    elif name == "unembed":
        spec = P(None, M)
    elif name in ("wq",):
        spec = tail((None, M, None))        # (d, H, hd)
    elif name in ("wk", "wv"):
        spec = tail((None, M, None))        # (d, K, hd)
    elif name == "wo" and nd >= 3:
        spec = tail((M, None, None))        # (H, hd, d)
    elif name in ("w_gate", "w_up"):
        if cfg.moe is not None and nd >= 3 and shape[-3] == cfg.moe.n_experts:
            spec = tail((M, None, None))    # (E, d, de): expert-sharded
        else:
            spec = tail((None, M))          # (d, F)
    elif name == "w_down":
        if cfg.moe is not None and nd >= 3 and shape[-3] == cfg.moe.n_experts:
            spec = tail((M, None, None))    # (E, de, d)
        else:
            spec = tail((M, None))          # (F, d)
    elif name == "router":
        spec = tail((None, None))
    elif name in ("w_in",):                 # mamba in_proj (d, mixed-out)
        spec = tail((None, None))
    elif name == "w_out" and nd >= 2:
        spec = tail((M, None))              # (d_inner, d) row-parallel
    elif name in ("w_i", "w_f"):
        spec = tail((M, None))              # (d_inner, H)
    elif name == "R":
        spec = tail((None, None, None, None)) if nd >= 4 else P(*([None] * nd))
    elif name == "conv_w":
        spec = tail((None, M))              # (K, conv_dim) channel-sharded
    elif name in ("conv_b", "ynorm", "hnorm"):
        spec = tail((M,))
    else:
        spec = P(*([None] * nd))
    # pad/truncate to ndim
    entries = list(spec)
    entries = entries[:nd] + [None] * (nd - len(entries))
    return _guard(P(*entries), shape, mesh)


def cache_spec(path: str, shape: tuple[int, ...], cfg: ArchConfig, mesh: Mesh,
               data_axes) -> P:
    """KV caches / recurrent state sharding for decode/prefill."""
    name = path.split("/")[-1]
    M = MODEL_AXIS
    msize = _mesh_size(mesh, M)
    nd = len(shape)
    if name in ("k", "v", "attn_k", "attn_v", "cross_k", "cross_v"):
        # (L, B, K, S, hd): shard batch on data; kv-heads on model if they
        # divide; else head_dim (updates stay local, attention pays a small
        # score all-reduce — §Perf pick-3 iter-2: S-sharding made the
        # per-step cache update all-gather the whole cache); else the
        # cache LENGTH as last resort.
        if FORCE_SEQ_SHARD_CACHE:
            spec = P(None, data_axes, None, M, None)
        elif cfg.n_kv_heads % msize == 0:
            spec = P(None, data_axes, M, None, None)
        elif cfg.head_dim % msize == 0:
            spec = P(None, data_axes, None, None, M)
        else:
            spec = P(None, data_axes, None, M, None)
    elif name == "h":                        # mamba state (L, B, H, N, P)
        spec = P(None, data_axes, M, None, None)
    elif name == "conv":                     # (L, B, K-1, conv_dim)
        spec = P(None, data_axes, None, M)
    elif name == "lengths":
        spec = P(data_axes)
    elif name in ("0", "1", "2", "3"):
        # xlstm tuple states: mLSTM (count,B,H,P,P)/(count,B,H,P)/(count,B,H)
        # or sLSTM (B,H,P): shard batch; shard the first P axis on model.
        if nd == 5:
            spec = P(None, data_axes, None, M, None)
        elif nd == 4:
            spec = P(None, data_axes, None, M)
        elif nd == 3:
            spec = P(data_axes, None, M)
        else:
            spec = P(*([None] * nd))
    else:
        spec = P(*([None] * nd))
    entries = list(spec)[:nd] + [None] * (nd - len(list(spec)))
    return _guard(P(*entries), shape, mesh)


def batch_spec(path: str, shape: tuple[int, ...], mesh: Mesh, data_axes) -> P:
    spec = P(data_axes, *([None] * (len(shape) - 1)))
    return _guard(spec, shape, mesh)


def dp_only_rules(mesh: Mesh, global_batch: int | None = None) -> dict:
    """Pure data-parallel logical rules: batch over as many mesh axes as its
    size divides, no model parallelism. The §Perf pick-2 optimization for
    small recurrent models (xlstm-1.3b) whose 4 heads cannot use a 16-way
    model axis — model-parallel resharding was 92% of the baseline step."""
    axes: list[str] = []
    prod = 1
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for a in mesh.axis_names:
        if global_batch is not None and global_batch % (prod * sizes[a]) != 0:
            break
        axes.append(a)
        prod *= sizes[a]
    return {
        "batch": tuple(axes) or None, "seq": None, "model": None,
        "vocab": None, "expert": None, "ff": None, "heads": None,
        "kv_heads": None, "state": None,
    }


def add_fsdp_axes(spec: P, shape: tuple[int, ...], mesh: Mesh, data_axes) -> P:
    """ZeRO/FSDP: additionally shard a parameter (or optimizer-state leaf)
    over the data axes on the first still-replicated dim that divides.
    XLA re-gathers layer slices inside the scan (FSDP semantics)."""
    if data_axes is None:
        return spec
    dsize = _mesh_size(mesh, data_axes)
    entries = list(spec) + [None] * (len(shape) - len(list(spec)))
    # never the leading (layer-stack) dim of scanned params: the scan's
    # dynamic-slice over a sharded dim forces a FULL weight all-gather
    # (measured: 108 s of ICI per step — §Perf pick-1 iter-2); walk from
    # the trailing dims instead.
    lo = 1 if len(shape) >= 3 else 0
    for i in range(len(entries) - 1, lo - 1, -1):
        if entries[i] is None and shape[i] % dsize == 0 and shape[i] >= dsize:
            entries[i] = data_axes
            return P(*entries)
    return P(*entries)


def tree_shardings(tree, spec_fn, mesh: Mesh):
    """Map a pytree of ShapeDtypeStructs/arrays -> NamedSharding tree."""

    def one(path, leaf):
        spec = spec_fn(_path_str(path), tuple(leaf.shape), mesh)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, tree)


def shard_inputs(cfg: ArchConfig, mesh: Mesh, specs: dict[str, Any]):
    """Attach NamedShardings to input_specs output. Returns
    (batch_sds, cache_sds) with .sharding set."""
    data_axes = tuple(a for a in DATA_AXES if a in mesh.axis_names)
    data_axes = data_axes if data_axes else None

    def with_sharding(tree, fn):
        def one(path, leaf):
            spec = fn(_path_str(path), tuple(leaf.shape))
            return jax.ShapeDtypeStruct(
                leaf.shape, leaf.dtype, sharding=NamedSharding(mesh, spec)
            )

        return jax.tree_util.tree_map_with_path(one, tree)

    batch = with_sharding(
        specs["batch"], lambda p, s: batch_spec(p, s, mesh, data_axes)
    )
    cache = None
    if specs["cache"] is not None:
        cache = with_sharding(
            specs["cache"], lambda p, s: cache_spec(p, s, cfg, mesh, data_axes)
        )
    return batch, cache


def shard_params_like(params_shape, cfg: ArchConfig, mesh: Mesh,
                      *, fsdp: bool = False, replicate: bool = False):
    """ShapeDtypeStruct param tree with NamedShardings attached.
    fsdp: additionally shard over the data axes (ZeRO-style).
    replicate: no sharding at all (the dp-only mode)."""
    data_axes = tuple(a for a in DATA_AXES if a in mesh.axis_names) or None

    def one(path, leaf):
        if replicate:
            spec = P(*([None] * len(leaf.shape)))
        else:
            spec = param_spec(_path_str(path), tuple(leaf.shape), cfg, mesh)
            if fsdp:
                spec = _guard(
                    add_fsdp_axes(spec, tuple(leaf.shape), mesh, data_axes),
                    tuple(leaf.shape), mesh,
                )
        return jax.ShapeDtypeStruct(
            leaf.shape, leaf.dtype, sharding=NamedSharding(mesh, spec)
        )

    return jax.tree_util.tree_map_with_path(one, params_shape)
