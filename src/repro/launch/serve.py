"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]`` —
batched requests through the Minos-gated serving engine (the paper's
technique as a first-class framework feature).
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.configs.registry import ARCH_IDS, get_smoke_config
from repro.core.cost import Pricing
from repro.core.elysium import pretest_threshold
from repro.core.policy import MinosPolicy
from repro.serving.engine import MinosServingEngine, ServeRequest


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen3-0.6b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new-tokens", type=int, default=8)
    ap.add_argument("--pass-fraction", type=float, default=0.4)
    ap.add_argument("--no-minos", action="store_true")
    ap.add_argument("--speed-sigma", type=float, default=0.15)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    rs = np.random.RandomState(0)
    probe_work = 200.0
    thr = pretest_threshold(
        probe_work / np.exp(rs.normal(0, args.speed_sigma, 128)),
        pass_fraction=args.pass_fraction,
    )
    policy = (
        MinosPolicy(elysium_threshold=0.0, enabled=False)
        if args.no_minos
        else MinosPolicy(elysium_threshold=thr, max_retries=5)
    )
    eng = MinosServingEngine(cfg, policy, Pricing.tpu_chip_seconds(4), seed=1,
                             speed_sigma=args.speed_sigma,
                             probe_work_ms=probe_work)
    reqs = [
        ServeRequest(prompt=rs.randint(0, cfg.vocab, 16).astype(np.int32),
                     max_new_tokens=args.max_new_tokens, request_id=i)
        for i in range(args.requests)
    ]
    res = eng.serve(reqs)
    lat = [r.sim_duration_ms for r in res]
    print(f"served {len(res)} requests | replicas started {eng.replicas_started}, "
          f"terminated {eng.replicas_terminated} | pool speed "
          f"{eng.pool_mean_speed:.3f} | mean latency {np.mean(lat):.0f}ms | "
          f"cost ${eng.cost.total:.4f}")


if __name__ == "__main__":
    main()
