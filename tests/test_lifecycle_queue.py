"""Instance lifecycle state machine + invocation queue semantics."""
import pytest

from repro.core.lifecycle import FunctionInstance, InstanceState, LifecycleError
from repro.core.policy import MinosPolicy, Verdict
from repro.core.queue import Invocation, InvocationQueue


def test_happy_path_cold_to_warm():
    inst = FunctionInstance(speed_factor=2.0)
    assert inst.state is InstanceState.COLD
    obs = inst.run_benchmark(100.0)
    assert obs == pytest.approx(50.0)
    assert inst.state is InstanceState.BENCHMARKING
    v = inst.judge(MinosPolicy(elysium_threshold=60.0), retry_count=0)
    assert v is Verdict.PASS and inst.is_warm
    inst.serve(now_ms=1000.0)
    assert inst.invocations_served == 1


def test_slow_instance_terminates():
    inst = FunctionInstance(speed_factor=0.5)
    inst.run_benchmark(100.0)
    v = inst.judge(MinosPolicy(elysium_threshold=150.0), retry_count=0)
    assert v is Verdict.TERMINATE and inst.is_dead
    with pytest.raises(LifecycleError):
        inst.serve(0.0)


def test_benchmark_only_from_cold():
    inst = FunctionInstance(speed_factor=1.0)
    inst.run_benchmark(10.0)
    with pytest.raises(LifecycleError):
        inst.run_benchmark(10.0)


def test_idle_expiry():
    inst = FunctionInstance(speed_factor=1.0, idle_timeout_ms=100.0)
    inst.accept_without_benchmark()
    inst.serve(now_ms=0.0)
    assert not inst.maybe_expire(now_ms=50.0)
    assert inst.maybe_expire(now_ms=151.0)
    assert inst.state is InstanceState.EXPIRED


def test_queue_fifo_and_requeue_counts():
    q = InvocationQueue()
    a, b = Invocation(payload=1), Invocation(payload=2)
    q.push(a, now_ms=0.0)
    q.push(b, now_ms=1.0)
    first = q.pop()
    assert first.payload == 1
    q.requeue(first, now_ms=2.0)
    assert first.retry_count == 1
    assert first.terminations_experienced == 1
    assert q.total_requeued == 1
    assert q.pop().payload == 2
    assert q.pop().payload == 1
    with pytest.raises(IndexError):
        q.pop()


def test_first_enqueued_preserved_across_requeues():
    q = InvocationQueue()
    inv = Invocation(payload=None)
    q.push(inv, now_ms=5.0)
    inv = q.pop()
    t0 = inv.first_enqueued_at_ms
    q.requeue(inv, now_ms=100.0)
    assert q.pop().first_enqueued_at_ms == t0
