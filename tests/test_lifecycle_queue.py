"""Instance lifecycle state machine + invocation queue semantics."""
import pytest

from repro.core.lifecycle import FunctionInstance, InstanceState, LifecycleError
from repro.core.policy import MinosPolicy, Verdict
from repro.core.queue import Invocation, InvocationQueue


def test_happy_path_cold_to_warm():
    inst = FunctionInstance(speed_factor=2.0)
    assert inst.state is InstanceState.COLD
    obs = inst.run_benchmark(100.0)
    assert obs == pytest.approx(50.0)
    assert inst.state is InstanceState.BENCHMARKING
    v = inst.judge(MinosPolicy(elysium_threshold=60.0), retry_count=0)
    assert v is Verdict.PASS and inst.is_warm
    inst.serve(now_ms=1000.0)
    assert inst.invocations_served == 1


def test_slow_instance_terminates():
    inst = FunctionInstance(speed_factor=0.5)
    inst.run_benchmark(100.0)
    v = inst.judge(MinosPolicy(elysium_threshold=150.0), retry_count=0)
    assert v is Verdict.TERMINATE and inst.is_dead
    with pytest.raises(LifecycleError):
        inst.serve(0.0)


def test_benchmark_only_from_cold():
    inst = FunctionInstance(speed_factor=1.0)
    inst.run_benchmark(10.0)
    with pytest.raises(LifecycleError):
        inst.run_benchmark(10.0)


def test_idle_expiry():
    inst = FunctionInstance(speed_factor=1.0, idle_timeout_ms=100.0)
    inst.accept_without_benchmark()
    inst.serve(now_ms=0.0)
    assert not inst.maybe_expire(now_ms=50.0)
    assert inst.maybe_expire(now_ms=151.0)
    assert inst.state is InstanceState.EXPIRED


def test_queue_fifo_and_requeue_counts():
    q = InvocationQueue()
    a, b = Invocation(payload=1), Invocation(payload=2)
    q.push(a, now_ms=0.0)
    q.push(b, now_ms=1.0)
    first = q.pop()
    assert first.payload == 1
    q.requeue(first, now_ms=2.0)
    assert first.retry_count == 1
    assert first.terminations_experienced == 1
    assert q.total_requeued == 1
    assert q.pop().payload == 2
    assert q.pop().payload == 1
    with pytest.raises(IndexError):
        q.pop()


def test_first_enqueued_preserved_across_requeues():
    q = InvocationQueue()
    inv = Invocation(payload=None)
    q.push(inv, now_ms=5.0)
    inv = q.pop()
    t0 = inv.first_enqueued_at_ms
    q.requeue(inv, now_ms=100.0)
    assert q.pop().first_enqueued_at_ms == t0


# ---------------------------------------------------------------------------
# Weighted-fair mode (fair=True; DESIGN.md §14 satellite)
# ---------------------------------------------------------------------------


def test_fair_mode_interleaves_by_weight():
    # a shared backlog of two classes at weight 8:1 must drain ~8:1
    q = InvocationQueue(fair=True)
    for i in range(32):
        q.push(Invocation(payload=("gold", i), qos="gold", qos_weight=8.0),
               now_ms=0.0)
        q.push(Invocation(payload=("econ", i), qos="econ", qos_weight=1.0),
               now_ms=0.0)
    first16 = [q.pop().qos for _ in range(16)]
    gold = first16.count("gold")
    assert gold >= 12, first16  # ~8:1 with integer rounding slack
    # everything still drains — no starvation
    rest = [q.pop().qos for _ in range(len(q))]
    assert rest.count("econ") + first16.count("econ") == 32
    assert len(q) == 0


def test_fair_mode_no_starvation_under_continuous_heavy_load():
    # heavy class keeps arriving; the single light item must still pop
    # within a bounded number of dequeues (virtual time advances past its
    # finish tag no matter how much heavy traffic lands after it)
    q = InvocationQueue(fair=True)
    q.push(Invocation(payload="light", qos="light", qos_weight=1.0),
           now_ms=0.0)
    popped_light_at = None
    for step in range(64):
        q.push(Invocation(payload=("heavy", step), qos="heavy",
                          qos_weight=16.0), now_ms=float(step))
        if q.pop().qos == "light":
            popped_light_at = step
            break
    assert popped_light_at is not None and popped_light_at <= 16


def test_fair_mode_fifo_within_class_and_equal_weights():
    q = InvocationQueue(fair=True)
    for i in range(6):
        q.push(Invocation(payload=i, qos="a", qos_weight=2.0), now_ms=0.0)
    assert [q.pop().payload for i in range(6)] == list(range(6))
    # equal-weight classes tie on virtual finish -> per-queue seq (push
    # order) breaks the tie
    for i in range(4):
        q.push(Invocation(payload=("x", i), qos="x", qos_weight=1.0),
               now_ms=0.0)
        q.push(Invocation(payload=("y", i), qos="y", qos_weight=1.0),
               now_ms=0.0)
    order = [q.pop().payload for _ in range(8)]
    assert order == [("x", 0), ("y", 0), ("x", 1), ("y", 1),
                     ("x", 2), ("y", 2), ("x", 3), ("y", 3)]


def test_default_mode_ignores_weights():
    # fair=False: historical (enqueue-time, seq) keys — weights inert
    q = InvocationQueue()
    q.push(Invocation(payload="first", qos="econ", qos_weight=0.1),
           now_ms=0.0)
    q.push(Invocation(payload="second", qos="gold", qos_weight=99.0),
           now_ms=1.0)
    assert q.pop().payload == "first"
    assert q.pop().payload == "second"


def test_fair_requeue_reenters_at_current_virtual_finish():
    q = InvocationQueue(fair=True)
    for i in range(3):
        q.push(Invocation(payload=("a", i), qos="a", qos_weight=1.0),
               now_ms=0.0)
    crashed = q.pop()
    q.requeue(crashed, now_ms=10.0)  # back of its class's line
    assert crashed.retry_count == 1
    assert [q.pop().payload for _ in range(3)] == \
        [("a", 1), ("a", 2), ("a", 0)]
