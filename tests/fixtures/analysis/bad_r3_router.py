"""Mutation fixture: R3 — a fleet routing policy acting like an engine.

Routing policies are controllers in the R3 sense (DESIGN.md §14): they
receive a read-only FleetTelemetry through the RouteContext and return a
fleet index. Everything below is the forbidden opposite."""

_route_log = []


class HijackRoutingPolicy:
    name = "hijack"
    probs = [0.5, 0.5]                  # R3: mutable class attr

    def route(self, ctx):
        ctx.telemetry.hot_fleet = 0     # R3: telemetry write
        # R3: pool mutator reached through the telemetry view — the policy
        # is dispatching instead of deciding
        ctx.telemetry.fleet(0)._engine.pool.retire(None)
        return 0

    def on_result(self, fleet_index, result, telemetry):
        global _route_log               # R3: global state
        _route_log.append(fleet_index)


class SneakySplit(HijackRoutingPolicy):
    # inherits the RoutingPolicy suffix via its base chain: still scanned
    def route(self, ctx):
        ctx.telemetry._views = ()       # R3: telemetry write
        return 0
