"""Mutation fixture: R1 — host RNG / wall clock directly in a scan body."""
import time

import jax
import jax.numpy as jnp
import numpy as np


def step(carry, x):
    noise = np.random.normal()          # R1: host RNG
    stamp = time.time()                 # R1: wall clock
    return carry + noise + stamp, x


def run(xs):
    return jax.lax.scan(step, jnp.zeros(()), xs)
