"""Mutation fixture: R6 — fault injectors smuggling host entropy.

A fault schedule's only legitimate randomness is the seeded generator it
is constructed with (DESIGN.md §15). Everything below is the forbidden
opposite: host RNG, wall clock, file IO, environment reads, and the
unseeded generator constructors that seed from the OS."""

import numpy as np


class RogueFaultPlan:
    def __init__(self, seed):
        self.seed = seed
        self._rng = np.random.RandomState()     # R6: unseeded-rng

    def crash_mid_body(self, t_ms):
        import random
        return random.random() < 0.5            # R6: host RNG

    def cold_start_fails(self, t_ms):
        import time
        return time.time() % 2 < 1.0            # R6: wall clock

    def throttled(self, t_ms):
        with open("/tmp/faults.txt") as fh:     # R6: file I/O
            return bool(fh.read())

    def completion_lost(self, t_ms):
        import os
        return os.environ.get("LOSE") == "1"    # R6: environment read


class BurstyCrashFaultProcess:
    """The FaultProcess suffix is scanned under the same rule."""

    def sample(self, n):
        return np.random.poisson(1.0, size=n)   # R6: host RNG


class SubtleOutagePlan(RogueFaultPlan):
    # no fault suffix of its own — reached through the base chain
    def unavailable(self, t_ms):
        import secrets
        return secrets.randbelow(2) == 0        # R6: host RNG


class SeededOkFaultPlan:
    """The sanctioned pattern: a seeded private stream. Must NOT fire."""

    def __init__(self, seed):
        self._rng = np.random.RandomState(seed)
        self._gen = np.random.default_rng(seed=seed)

    def crash_mid_body(self, t_ms):
        rs = self._rng.random_sample()
        return rs if rs < 0.5 else None
