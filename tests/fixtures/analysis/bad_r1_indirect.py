"""Mutation fixture: R1 — forbidden call reachable through a local helper
(two hops), exercising the per-module call-graph propagation."""
import random

import jax
import jax.numpy as jnp


def _draw():
    return random.random()              # R1: host RNG, two calls deep


def _helper(carry):
    return carry + _draw()


def step(carry, x):
    return _helper(carry), x


def run(xs):
    return jax.lax.scan(step, jnp.zeros(()), xs)
