"""Mutation fixture: R5 — raw container literals as scan carriers."""
import jax
import jax.numpy as jnp


def step(carry, x):
    return carry, x


def dict_init(xs):
    return jax.lax.scan(step, {"a": jnp.zeros(())}, xs)   # R5: dict literal


def named_dict_init(xs):
    state = {"a": jnp.zeros(()), "b": jnp.ones(())}
    return jax.lax.scan(step, state, xs)                  # R5: via local name


def list_in_tuple_init(xs):
    return jax.lax.scan(step, (jnp.zeros(()), [1.0]), xs)  # R5: list in tuple


def bad_body(xs):
    def step_list(carry, x):
        return [carry[0] + x], x                           # R5: list carry out

    return jax.lax.scan(step_list, (jnp.zeros(()),), xs)
