"""Mutation fixture: R3 — controller mutating telemetry / pool / globals."""

_shared_counter = 0


class RogueController:
    history = []                        # R3: mutable class attr

    def on_admit(self, ctx):
        ctx.telemetry.depth = 3         # R3: telemetry write
        return True

    def on_reuse(self, ctx):
        ctx.telemetry._engine.pool.retire(ctx.instance)  # R3: pool mutator
        return None

    def on_release(self, ctx):
        global _shared_counter          # R3: global state
        _shared_counter += 1
