"""Mutation fixture: R2 — host conversions of / branches on traced values."""
import jax
import jax.numpy as jnp
import numpy as np


def step(carry, x):
    if carry > 0:                       # R2: if-on-traced
        carry = carry - 1.0
    y = float(x)                        # R2: float-on-traced
    z = np.asarray(carry)               # R2: host conversion
    w = carry.item()                    # R2: host sync
    return carry + y + z + w, x


def run(xs):
    return jax.lax.scan(step, jnp.zeros(()), xs)
