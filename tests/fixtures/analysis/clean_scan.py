"""Clean fixture: the idioms the rules must NOT flag.

Covers: jax.random in scan bodies, static_argnames branches, shape-based
control flow on traced arrays, closure-static config branches, host RNG
*outside* traced code, and a NamedTuple carry.
"""
import functools
import time
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class Carry(NamedTuple):
    total: jax.Array
    key: jax.Array


def step(carry, x):
    key, sub = jax.random.split(carry.key)      # device RNG: fine
    noise = jax.random.normal(sub)
    if x.shape[0] > 1:                          # shape branch: concrete
        noise = noise * 2.0
    return Carry(carry.total + noise, key), x


def run(xs, cfg):
    init = Carry(jnp.zeros(()), jax.random.PRNGKey(0))
    if cfg.adaptive:                            # closure-static config: fine
        xs = xs * 2.0
    return jax.lax.scan(step, init, xs)


@functools.partial(jax.jit, static_argnames=("block", "scale"))
def kernel(x, *, block: int = 8, scale: float = 1.0):
    if block > x.shape[0]:                      # static arg branch: fine
        block = x.shape[0]
    if scale is None:
        scale = 1.0
    return x * float(scale) * block             # float() on a static: fine


def host_driver(xs):
    t0 = time.time()                            # host side: fine
    rng = np.random.RandomState(0)              # host side: fine
    _ = rng.normal()
    return time.time() - t0
