"""Clean fixture: a well-behaved controller — reads telemetry, keeps
per-instance state, returns decisions, never mutates engine state."""


class WellBehavedController:
    def __init__(self):
        self.observations = []          # per-instance state: fine

    def on_admit(self, ctx):
        depth = ctx.telemetry.queue_depth      # read: fine
        self.observations.append(depth)
        return depth < 10

    def on_reuse(self, ctx):
        return "KEEP"


def helper_uses_pool_legally(pool, inst):
    # module-level engine code (not a Controller class) may mutate pools
    pool.release(inst)
