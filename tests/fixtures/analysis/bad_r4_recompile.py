"""Mutation fixture: R4 — recompile hazards at jitted call sites."""
import jax
import jax.numpy as jnp


def f(x):
    return x * 2


g = jax.jit(f)


def immediate(x):
    return jax.jit(f)(x)                # R4: jit applied then called


def in_loop(xs):
    out = []
    for x in xs:
        out.append(jax.jit(f)(x))       # R4: jit inside a loop (and immediate)
    return out


def container_arg():
    return g([1.0, 2.0, 3.0])           # R4: list literal to jitted callable
