"""Per-architecture smoke tests: REDUCED variant of each family (2 layers,
d_model<=512, <=4 experts), one forward + one train step on CPU, asserting
output shapes and no NaNs — as required for deliverable (f)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, get_config, get_smoke_config
from repro.models.model import build_model, greedy_token
from repro.optim.adamw import AdamW

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=32):
    b = {
        "tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab),
        "labels": jax.random.randint(KEY, (B, S), 0, cfg.vocab),
    }
    if cfg.family == "encdec":
        b["frames"] = jax.random.normal(KEY, (B, cfg.encoder_frames, cfg.d_model))
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_config_is_reduced(arch):
    cfg = get_smoke_config(arch)
    full = get_config(arch)
    assert cfg.n_layers == 2
    assert cfg.d_model <= 512
    assert cfg.family == full.family
    if cfg.moe is not None:
        assert cfg.moe.n_experts <= 4


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(KEY)
    batch = _batch(cfg)
    logits, aux = model.forward(params, batch)
    assert logits.shape == (2, 32, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step_no_nans(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(KEY)
    opt = AdamW(learning_rate=1e-3)
    opt_state = opt.init(params)
    batch = _batch(cfg)

    @jax.jit
    def step(params, opt_state, batch):
        (loss, m), grads = jax.value_and_grad(
            lambda p: model.loss(p, batch), has_aux=True
        )(params)
        params, opt_state, _ = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    params, opt_state, loss = step(params, opt_state, batch)
    assert np.isfinite(float(loss))
    flat = jax.tree.leaves(params)
    assert all(np.isfinite(np.asarray(x, np.float32)).all() for x in flat)


@pytest.mark.parametrize("arch", ["llama3.2-1b", "qwen3-0.6b", "xlstm-1.3b",
                                  "zamba2-1.2b", "whisper-small"])
def test_decode_three_steps(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(KEY)
    B = 2
    cache = model.init_cache(B, 64)
    if cfg.family == "encdec":
        batch = {"frames": jax.random.normal(KEY, (B, cfg.encoder_frames, cfg.d_model))}
    else:
        batch = {"tokens": jax.random.randint(KEY, (B, 16), 0, cfg.vocab)}
    _, cache = model.prefill(params, batch, cache)
    tok = jnp.ones((B, 1), jnp.int32)
    for _ in range(3):
        logits, cache = model.decode_step(params, cache, tok)
        assert logits.shape == (B, 1, cfg.vocab)
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        tok = greedy_token(logits)


@pytest.mark.parametrize("arch", ["llama3.2-1b", "qwen3-0.6b", "xlstm-1.3b", "zamba2-1.2b"])
def test_decode_matches_parallel_forward(arch):
    """prefill+decode_step == forward at the last position (no token drop)."""
    cfg = get_smoke_config(arch)
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=float(cfg.moe.n_experts))
        )
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    B, S = 2, 33
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab)
    full, _ = model.forward(params, {"tokens": tokens})
    want = np.asarray(full[:, -1])
    cache = model.init_cache(B, 64)
    _, cache = model.prefill(params, {"tokens": tokens[:, :-1]}, cache)
    got, _ = model.decode_step(params, cache, tokens[:, -1:])
    err = np.max(np.abs(np.asarray(got[:, 0]) - want)) / (np.max(np.abs(want)) + 1e-9)
    assert err < 2e-3, err


def test_sliding_window_decode_matches_windowed_forward():
    """Ring-buffer sliding-window decode == full forward with window mask."""
    cfg = get_smoke_config("llama3.2-1b")
    cfg = dataclasses.replace(cfg, sliding_window=16)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(3))
    B, S = 1, 40  # longer than the window
    tokens = jax.random.randint(jax.random.PRNGKey(4), (B, S), 0, cfg.vocab)
    full, _ = model.forward(params, {"tokens": tokens})
    want = np.asarray(full[:, -1])
    cache = model.init_cache(B, S)
    assert cache["k"].shape[3] == 16  # ring of window size
    _, cache = model.prefill(params, {"tokens": tokens[:, :-1]}, cache)
    got, _ = model.decode_step(params, cache, tokens[:, -1:])
    err = np.max(np.abs(np.asarray(got[:, 0]) - want)) / (np.max(np.abs(want)) + 1e-9)
    assert err < 2e-3, err


def test_moe_load_balance_loss_positive():
    cfg = get_smoke_config("deepseek-moe-16b")
    model = build_model(cfg)
    params = model.init(KEY)
    _, aux = model.forward(params, _batch(cfg))
    assert float(aux) > 0.0
