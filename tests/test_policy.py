"""MinosPolicy, emergency exit, cost model (paper §II-A, Fig 3)."""
try:
    import hypothesis
    import hypothesis.strategies as st
except ImportError:  # optional dev dependency (pyproject [dev] extra)
    from _hypothesis_stub import hypothesis, st
import pytest

from repro.core.cost import Pricing, WorkflowCost, total_cost
from repro.core.policy import (
    MinosPolicy,
    Verdict,
    expected_cold_start_attempts,
    retries_for_runaway_budget,
    runaway_probability,
)


def test_judge_pass_terminate():
    pol = MinosPolicy(elysium_threshold=100.0)
    assert pol.judge(99.0, 0) is Verdict.PASS
    assert pol.judge(100.0, 0) is Verdict.PASS   # inclusive
    assert pol.judge(101.0, 0) is Verdict.TERMINATE


def test_higher_is_better():
    pol = MinosPolicy(elysium_threshold=10.0, higher_is_better=True)
    assert pol.judge(11.0, 0) is Verdict.PASS
    assert pol.judge(9.0, 0) is Verdict.TERMINATE


def test_emergency_exit():
    """Paper §II-A: past max_retries the instance is marked good WITHOUT
    benchmarking, preventing infinite requeue loops."""
    pol = MinosPolicy(elysium_threshold=100.0, max_retries=5)
    assert pol.judge(1e9, 5) is Verdict.FORCED_PASS
    assert pol.judge(1e9, 6) is Verdict.FORCED_PASS
    assert not pol.should_benchmark(retry_count=5, is_cold_start=True)
    assert pol.should_benchmark(retry_count=4, is_cold_start=True)


def test_warm_instances_never_rebenchmark():
    pol = MinosPolicy(elysium_threshold=100.0)
    assert not pol.should_benchmark(retry_count=0, is_cold_start=False)


def test_disabled_policy_passes_everything():
    pol = MinosPolicy(elysium_threshold=0.0, enabled=False)
    assert pol.judge(1e12, 0) is Verdict.PASS
    assert not pol.should_benchmark(0, True)


def test_runaway_probability_paper_example():
    """Paper: at 40% termination rate, ~1% chance of 5 consecutive fails."""
    assert runaway_probability(0.4, 5) == pytest.approx(0.01024)
    assert runaway_probability(0.4, 8) < 0.01


@hypothesis.given(st.floats(0.05, 0.95), st.floats(0.001, 0.2))
@hypothesis.settings(deadline=None, max_examples=50)
def test_retries_budget_inverse(rate, budget):
    r = retries_for_runaway_budget(rate, budget)
    assert runaway_probability(rate, r) <= budget + 1e-12
    assert r == 1 or runaway_probability(rate, r - 1) > budget


@hypothesis.given(st.floats(0.0, 0.99))
@hypothesis.settings(deadline=None, max_examples=50)
def test_expected_attempts_bounds(rate):
    e = expected_cold_start_attempts(rate, max_retries=5)
    assert 1.0 <= e <= 6.0 + 1e-9
    # geometric limit when unbounded retries
    if rate < 0.9:
        assert e <= 1.0 / (1.0 - rate) + 1e-9


# ---------------------------------------------------------------------------
# Cost model
# ---------------------------------------------------------------------------


def test_fig3_cost_model():
    p = Pricing(cost_per_invocation=1.0, cost_per_ms=0.1)
    c = total_cost(p, d_term=[100, 50], d_pass=[1000], d_reuse=[900, 800])
    assert c == pytest.approx(0.1 * 2850 + 5.0)


def test_workflow_cost_accumulates_like_fig3():
    p = Pricing.gcf(256)
    wc = WorkflowCost(p)
    wc.record_terminated(120)
    wc.record_passed(2000)
    wc.record_reused(1800)
    wc.record_reused(1700)
    assert wc.n_invocations == 4
    assert wc.n_successful == 3
    assert wc.total == pytest.approx(total_cost(p, [120], [2000], [1800, 1700]))


def test_gcf_invocation_breakeven_shrinks_with_tier():
    """Paper §II-A: the invocation fee is worth far fewer ms of execution on
    bigger tiers (<3 ms at 32 GB)."""
    small = Pricing.gcf(128)
    big = Pricing.gcf(32768)
    assert small.invocation_break_even_ms > big.invocation_break_even_ms
    assert big.invocation_break_even_ms < 3.0


def test_cost_merge():
    p = Pricing.gcf(256)
    a, b = WorkflowCost(p), WorkflowCost(p)
    a.record_passed(100)
    b.record_terminated(50)
    m = a.merge(b)
    assert m.n_invocations == 2 and m.total == pytest.approx(a.total + b.total)


def test_unknown_tier_raises():
    with pytest.raises(ValueError):
        Pricing.gcf(333)
