"""repro.analysis static checker (DESIGN.md §13).

Mutation-style self-tests: each fixture under tests/fixtures/analysis/
injects exactly one violation class and must trigger exactly the expected
rule; the clean fixtures exercise the idioms the rules must NOT flag
(jax.random in scan bodies, static_argnames branches, shape-based control
flow, closure-static config). Plus baseline grandfathering mechanics and
the ``python -m repro.analysis --ci`` contract the CI lint job runs.
"""
import json
import os
import shutil
import subprocess
import sys

import pytest

from repro.analysis import (
    Baseline,
    Finding,
    analyze_paths,
    analyze_source,
)
from repro.analysis.lint import analyze_file

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures", "analysis")
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _rules_for(fixture: str) -> set:
    path = os.path.join(FIXTURES, fixture)
    return {f.rule for f in analyze_file(path, fixture)}


def _findings_for(fixture: str):
    path = os.path.join(FIXTURES, fixture)
    return analyze_file(path, fixture)


# ---------------------------------------------------------------------------
# Mutation fixtures: each violation class fires its rule
# ---------------------------------------------------------------------------


def test_r1_direct_scan_rng_and_clock():
    fs = _findings_for("bad_r1_scan_rng.py")
    details = {f.detail for f in fs if f.rule == "R1"}
    assert any(d.startswith("numpy.random") for d in details), details
    assert "time.time" in details
    assert all(f.symbol == "step" for f in fs if f.rule == "R1")


def test_r1_reaches_through_local_call_chain():
    fs = [f for f in _findings_for("bad_r1_indirect.py") if f.rule == "R1"]
    assert fs, "call-graph propagation missed a two-hop RNG call"
    assert fs[0].detail == "random.random"
    assert fs[0].symbol == "_draw"


def test_r2_conversions_and_branches_on_traced():
    details = {f.detail.split(":")[0]
               for f in _findings_for("bad_r2_tracer.py") if f.rule == "R2"}
    assert "if-on-traced" in details
    assert "float-on-traced" in details
    assert "numpy.asarray-on-traced" in details
    assert "item-on-traced" in details


def test_r3_controller_violations():
    details = {f.detail.split(":")[0]
               for f in _findings_for("bad_r3_controller.py") if f.rule == "R3"}
    assert details == {"mutable-class-attr", "telemetry-write",
                       "pool-mutator", "global-state"}


def test_r3_routing_policy_violations():
    fs = [f for f in _findings_for("bad_r3_router.py") if f.rule == "R3"]
    details = {f.detail.split(":")[0] for f in fs}
    assert details == {"mutable-class-attr", "telemetry-write",
                       "pool-mutator", "global-state"}
    # the subclass is recognized through its *RoutingPolicy base chain
    assert any(f.symbol.startswith("SneakySplit") for f in fs), fs


def test_r3_fleet_router_is_exempt():
    # FleetRouter legitimately submits to engines; only *RoutingPolicy
    # classes fall under R3, so the shipped router module must stay clean
    src_router = os.path.join(
        os.path.dirname(__file__), "..", "src", "repro", "fleet",
        "router.py")
    fs = [f for f in analyze_file(src_router, "fleet/router.py")
          if f.rule == "R3"]
    assert fs == [], fs


def test_r6_fault_injector_violations():
    fs = [f for f in _findings_for("bad_r6_faults.py") if f.rule == "R6"]
    details = {f.detail for f in fs}
    # the unseeded ctor is its own detail; the rest carry the canon name
    assert any(d.startswith("unseeded-rng:") for d in details), details
    assert "random.random" in details
    assert "time.time" in details
    assert "open" in details
    assert any(d.startswith("os.environ") for d in details), details
    # *FaultProcess suffix and base-chain subclasses are both scanned
    assert any(f.symbol.startswith("BurstyCrashFaultProcess") for f in fs)
    assert any(f.symbol.startswith("SubtleOutagePlan") for f in fs)
    # the sanctioned seeded-ctor pattern must NOT fire
    assert not any(f.symbol.startswith("SeededOkFaultPlan") for f in fs), fs


def test_r6_shipped_fault_plan_is_clean():
    # repro.faults.FaultPlan constructs RandomState(seed) — the exemption
    # the rule carves out; the shipped module must stay R6-clean
    src_faults = os.path.join(
        os.path.dirname(__file__), "..", "src", "repro", "faults",
        "__init__.py")
    fs = [f for f in analyze_file(src_faults, "faults/__init__.py")
          if f.rule == "R6"]
    assert fs == [], fs


def test_r4_recompile_hazards():
    details = {f.detail.split(":")[0]
               for f in _findings_for("bad_r4_recompile.py") if f.rule == "R4"}
    assert "jit-immediate-call" in details
    assert "jit-in-loop" in details
    assert "container-arg" in details


def test_r5_carry_literals():
    details = {f.detail for f in _findings_for("bad_r5_carry.py")
               if f.rule == "R5"}
    assert "scan-init-literal:dict" in details          # direct + via name
    assert "scan-init-literal:list" in details          # list inside tuple
    assert "scan-carry-return-literal:list" in details  # body return


def test_every_bad_fixture_fires_only_its_rule():
    expected = {
        "bad_r1_scan_rng.py": {"R1"},
        "bad_r1_indirect.py": {"R1"},
        "bad_r2_tracer.py": {"R2"},
        "bad_r3_controller.py": {"R3"},
        "bad_r3_router.py": {"R3"},
        "bad_r4_recompile.py": {"R4"},
        "bad_r5_carry.py": {"R5"},
        "bad_r6_faults.py": {"R6"},
    }
    for fixture, rules in expected.items():
        assert _rules_for(fixture) == rules, fixture


def test_clean_fixtures_stay_clean():
    for fixture in ("clean_scan.py", "clean_controller.py"):
        assert _rules_for(fixture) == set(), (
            f"{fixture} false positives: {_findings_for(fixture)}")


def test_syntax_error_reported_not_crashed():
    fs = analyze_source("def broken(:\n", "broken.py")
    assert [f.rule for f in fs] == ["R0"]


# ---------------------------------------------------------------------------
# Engine behavior details
# ---------------------------------------------------------------------------


def test_shape_reads_break_taint():
    src = (
        "import jax\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    n = x.shape[0]\n"
        "    if n > 1:\n"
        "        return x * 2\n"
        "    return x\n")
    assert analyze_source(src, "m.py") == []


def test_static_argnums_excluded_from_taint():
    src = (
        "import functools, jax\n"
        "@functools.partial(jax.jit, static_argnums=(1,))\n"
        "def f(x, mode):\n"
        "    if mode:\n"
        "        return x * 2\n"
        "    return x\n")
    assert analyze_source(src, "m.py") == []


def test_import_alias_canonicalization():
    src = (
        "import numpy.random as npr\n"
        "import jax\n"
        "def step(c, x):\n"
        "    return c + npr.normal(), x\n"
        "def run(xs):\n"
        "    return jax.lax.scan(step, 0, xs)\n")
    fs = analyze_source(src, "m.py")
    assert [f.rule for f in fs] == ["R1"]
    assert fs[0].detail == "numpy.random.normal"


def test_local_shadow_suppresses_r1():
    src = (
        "import jax\n"
        "def step(c, x, time):\n"   # param shadows the stdlib module
        "    return c + time.time(), x\n"
        "def run(xs):\n"
        "    return jax.lax.scan(step, 0, xs)\n")
    assert all(f.detail != "time.time" for f in analyze_source(src, "m.py"))


# ---------------------------------------------------------------------------
# Baseline mechanics
# ---------------------------------------------------------------------------


def _finding(line=10, detail="numpy.random.normal"):
    return Finding(rule="R1", path="src/x.py", line=line, symbol="step",
                   detail=detail, message="msg")


def test_fingerprint_is_line_independent():
    assert _finding(line=10).fingerprint == _finding(line=99).fingerprint
    assert _finding(detail="time.time").fingerprint != _finding().fingerprint


def test_baseline_split_and_roundtrip(tmp_path):
    grandfathered = _finding()
    fresh = _finding(detail="time.time")
    bl = Baseline({grandfathered.fingerprint: "pre-existing, tracked"})
    new, old, stale = bl.split([grandfathered, fresh])
    assert new == [fresh]
    assert old == [grandfathered]
    assert stale == []
    # entries for findings that disappeared are reported stale
    new, old, stale = bl.split([fresh])
    assert stale == [grandfathered.fingerprint]
    # save/load round-trips entries
    p = tmp_path / "baseline.json"
    bl.save(str(p))
    assert Baseline.load(str(p)).entries == bl.entries


def test_missing_baseline_file_is_empty():
    assert Baseline.load("/nonexistent/baseline.json").entries == {}


# ---------------------------------------------------------------------------
# CLI contract (what CI runs)
# ---------------------------------------------------------------------------


def _run_cli(*args, cwd=REPO):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        capture_output=True, text=True, env=env, cwd=cwd)


def test_cli_ci_clean_on_shipped_tree():
    proc = _run_cli("--ci")
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "clean" in proc.stdout


def test_cli_fails_on_mutation_fixture(tmp_path):
    bad = tmp_path / "src"
    bad.mkdir()
    shutil.copy(os.path.join(FIXTURES, "bad_r1_scan_rng.py"),
                bad / "bad_r1_scan_rng.py")
    proc = _run_cli("--ci", str(bad))
    assert proc.returncode == 1, proc.stdout + proc.stderr
    assert "R1" in proc.stdout


def test_cli_rules_filter_and_json(tmp_path):
    bad = tmp_path / "src"
    bad.mkdir()
    shutil.copy(os.path.join(FIXTURES, "bad_r2_tracer.py"),
                bad / "bad_r2_tracer.py")
    # a rule filter that excludes the violation passes
    proc = _run_cli("--rules", "R3,R4", str(bad))
    assert proc.returncode == 0, proc.stdout + proc.stderr
    # --json emits machine-readable findings
    proc = _run_cli("--json", "--no-baseline", str(bad))
    assert proc.returncode == 1
    findings = json.loads(proc.stdout)
    assert findings and all(f["rule"] == "R2" for f in findings)


def test_cli_unknown_rule_is_usage_error():
    assert _run_cli("--rules", "R9").returncode == 2


def test_cli_write_baseline_then_clean(tmp_path):
    bad = tmp_path / "src"
    bad.mkdir()
    shutil.copy(os.path.join(FIXTURES, "bad_r5_carry.py"),
                bad / "bad_r5_carry.py")
    bl = tmp_path / "baseline.json"
    proc = _run_cli("--write-baseline", "--baseline", str(bl), str(bad))
    assert proc.returncode == 0, proc.stdout + proc.stderr
    # grandfathered now: same tree passes against that baseline
    proc = _run_cli("--ci", "--baseline", str(bl), str(bad))
    assert proc.returncode == 0, proc.stdout + proc.stderr
    # but a NEW violation still fails
    shutil.copy(os.path.join(FIXTURES, "bad_r1_scan_rng.py"),
                bad / "bad_r1_scan_rng.py")
    proc = _run_cli("--ci", "--baseline", str(bl), str(bad))
    assert proc.returncode == 1


def test_shipped_tree_has_no_baseline_entries():
    """The repo ships lint-clean with an empty grandfather list — new
    engines/controllers must keep it that way (ROADMAP)."""
    from repro.analysis import default_baseline_path
    with open(default_baseline_path()) as fh:
        assert json.load(fh)["findings"] == []
    src = os.path.join(REPO, "src")
    benches = os.path.join(REPO, "benchmarks")
    assert analyze_paths([src, benches]) == []
