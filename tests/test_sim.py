"""Simulator behaviour + reproduction of the paper's measured effects."""
import numpy as np
import pytest

from repro.core.cost import Pricing
from repro.core.policy import MinosPolicy
from repro.sim import (
    PAPER_PRICING,
    PAPER_SPEC,
    FaaSPlatform,
    FunctionSpec,
    VariationModel,
    make_chain,
    run_closed_loop,
    run_day,
    run_week,
    run_workflow,
)
from repro.sim.variation import paper_week


def _quick_spec(**kw):
    base = dict(
        name="t", prepare_ms=300.0, body_ms=600.0, benchmark_ms=100.0,
        cold_start_ms=50.0, recycle_lifetime_ms=None, contention_rho=1.0,
        benchmark_noise=0.0,
    )
    base.update(kw)
    return FunctionSpec(**base)


def test_baseline_never_terminates():
    plat = FaaSPlatform(
        _quick_spec(), VariationModel(sigma=0.3),
        MinosPolicy(elysium_threshold=0.0, enabled=False), PAPER_PRICING, seed=1,
    )
    run_closed_loop(plat, n_vus=4, duration_ms=60_000)
    assert plat.instances_terminated == 0
    assert plat.cost.n_term == 0


def test_minos_pool_is_faster_than_threshold():
    """Invariant: every WARM instance passed the gate, so (noise-free) every
    pool member's probe duration beat the threshold."""
    thr = 100.0  # only speed >= 1.0 instances pass (probe work = 100ms)
    plat = FaaSPlatform(
        _quick_spec(), VariationModel(sigma=0.25),
        MinosPolicy(elysium_threshold=thr, max_retries=10), PAPER_PRICING, seed=2,
    )
    run_closed_loop(plat, n_vus=4, duration_ms=120_000)
    assert plat.instances_terminated > 0
    for s in plat.warm_pool_speeds:
        assert 100.0 / s <= thr + 1e-9


def test_requests_never_lost():
    """At-least-once: every submitted request completes despite terminations."""
    plat = FaaSPlatform(
        _quick_spec(), VariationModel(sigma=0.4),
        MinosPolicy(elysium_threshold=80.0, max_retries=3), PAPER_PRICING, seed=3,
    )
    done = []
    for i in range(25):
        plat.submit({"i": i}, done.append)
    plat.loop.run_all(hard_limit_ms=1e9)
    assert len(done) == 25


def test_emergency_exit_bounds_retries():
    plat = FaaSPlatform(
        _quick_spec(), VariationModel(sigma=0.2),
        # impossible threshold: everything fails the benchmark
        MinosPolicy(elysium_threshold=1e-6, max_retries=4), PAPER_PRICING, seed=4,
    )
    done = []
    for i in range(10):
        plat.submit({"i": i}, done.append)
    plat.loop.run_all(hard_limit_ms=1e9)
    assert len(done) == 10
    assert all(r.retries <= 4 for r in done)


def test_selected_pool_speed_converges_to_analytic():
    """The Minos pool's mean speed approaches E[speed | top 40%]."""
    vm = VariationModel(sigma=0.15)
    thr = 100.0 / vm.speed_quantile(0.6)  # 60th-pct probe duration
    plat = FaaSPlatform(
        _quick_spec(body_ms=200.0), vm,
        MinosPolicy(elysium_threshold=thr, max_retries=8), PAPER_PRICING, seed=5,
    )
    run_closed_loop(plat, n_vus=8, duration_ms=600_000)
    analytic = vm.top_fraction_mean_speed(0.4)
    speeds = [r.instance_speed for r in plat.results if not r.served_by_cold]
    assert abs(np.mean(speeds) - analytic) / analytic < 0.05


def test_day_reproduces_paper_bands_seed0():
    """Day-level run lands inside the paper's observed ranges."""
    vm = paper_week(seed=0)[0]
    day = run_day(0, vm, seed=0, duration_ms=10 * 60 * 1000.0)
    assert 0.0 < day.analysis_improvement < 0.20
    assert day.minos.n_successful > 0.9 * day.baseline.n_successful


@pytest.mark.slow
def test_week_reproduces_paper_headline_numbers():
    """Paper: analysis step 7.8% faster on average (range 4.3-13%); cost
    ~0.9% cheaper overall (max day 3.3%); +2.3% successful requests with at
    least one day not improving. Generous bands around those."""
    wk = run_week(seed=0)
    assert 0.04 < wk.overall_analysis_improvement < 0.14
    for d in wk.days:
        assert d.analysis_improvement > 0.0  # faster every day (Fig 4)
    assert -0.01 < wk.overall_cost_saving < 0.04
    assert max(d.cost_saving for d in wk.days) > 0.015
    assert -0.02 < wk.overall_successful_delta < 0.06
    assert min(d.successful_requests_delta for d in wk.days) < 0.02  # a weak day


def test_workflow_chain_compounds():
    """Longer workflows re-use the known-good pools more often — per-stage
    analysis time of Minos beats baseline on the chained workload."""
    vm = VariationModel(sigma=0.2)
    pol = MinosPolicy(elysium_threshold=100.0 / vm.speed_quantile(0.6), max_retries=6)
    base_pol = MinosPolicy(elysium_threshold=0, enabled=False)
    specs = [_quick_spec(name=f"s{i}") for i in range(3)]
    minos_wf = make_chain(specs, vm, pol, PAPER_PRICING, seed=7)
    base_wf = make_chain(specs, vm, base_pol, PAPER_PRICING, seed=7)
    m = run_workflow(minos_wf, n_items=120)
    b = run_workflow(base_wf, n_items=120)
    m_mean = np.mean([r.analysis_ms for stage in m for r in stage[30:]])
    b_mean = np.mean([r.analysis_ms for stage in b for r in stage[30:]])
    assert m_mean < b_mean


def test_cost_timeline_monotone_time():
    vm = paper_week(seed=0)[0]
    day = run_day(0, vm, seed=0, duration_ms=5 * 60 * 1000.0)
    t, c = day.timeline_minos
    assert (np.diff(t) > 0).all()
    assert np.isfinite(c).all()


def test_online_controller_beats_stale_threshold_under_drift():
    """§IV implemented: when the platform slows mid-experiment, the online
    P²-threshold wastes fewer terminations than a stale pre-test."""
    from repro.core import OnlineElysiumController
    from repro.sim import PAPER_PRICING, PAPER_SPEC

    vm0 = VariationModel(sigma=0.15)
    thr = PAPER_SPEC.benchmark_ms / vm0.speed_quantile(0.6)

    def run(online):
        ctrl = (OnlineElysiumController(pass_fraction=0.4, republish_every=8,
                                        smoothing_alpha=0.5,
                                        initial_threshold=thr)
                if online else None)
        term, succ, cost = 0, 0, 0.0
        for phase, df in enumerate((1.0, 0.75)):
            vm = VariationModel(sigma=0.15, day_factor=df)
            pol = MinosPolicy(elysium_threshold=(ctrl.threshold if ctrl else thr),
                              max_retries=5)
            plat = FaaSPlatform(PAPER_SPEC, vm, pol, PAPER_PRICING,
                                seed=17 + phase, online_controller=ctrl)
            res = run_closed_loop(plat, n_vus=10, duration_ms=6 * 60 * 1000.0)
            term += plat.instances_terminated
            succ += len(res)
            cost += plat.cost.total
        return term, succ, cost / succ

    t_stale, s_stale, c_stale = run(False)
    t_online, s_online, c_online = run(True)
    assert t_online < t_stale          # fewer wasted terminations
    assert c_online < c_stale * 1.02   # not more expensive
