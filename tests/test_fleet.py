"""Fleet meta-scheduler (repro.fleet; DESIGN.md §14).

* FleetTelemetry is read-only and aggregates per-fleet views;
* the routing-policy ladder behaves: static one-hots stay put, exclusion
  is honored, greedy is deterministic and avoids backlog;
* solve_split: the LP and the closed-form waterfill coincide (continuous
  knapsack), caps are respected, overload splits capacity-proportionally;
* conservation holds for every policy × seed, with and without hedging —
  and a router mutated to double-dispatch is caught by the sanitizer
  ledger;
* hedging: each hedged request is counted once in latency, twice in cost
  when both copies ran (count_hedge_waste semantics);
* same seeds → bit-identical runs.
"""
import dataclasses

import numpy as np
import pytest

from repro.analysis.sanitizer import SanitizerError
from repro.core.control import FleetTelemetry
from repro.core.policy import MinosPolicy
from repro.fleet import (
    FleetRouter,
    FleetSpec,
    GreedyRoutingPolicy,
    ProbabilisticRoutingPolicy,
    RandomRoutingPolicy,
    RouteContext,
    WeightedStaticRoutingPolicy,
    run_fleet_open_loop,
    solve_split,
)
from repro.sim import (
    FunctionSpec,
    PlatformProfile,
    PoissonProcess,
    VariationModel,
)
from repro.sim.arrivals import QoSClass
from repro.sim.metrics import FleetSummary

SPEC = FunctionSpec(name="fleet-test", prepare_ms=50.0, body_ms=300.0,
                    benchmark_ms=100.0, contention_rho=0.5)
VM = VariationModel(sigma=0.15)
GATE = MinosPolicy(elysium_threshold=130.0)


def _fleets(n=3, body_ms=None, caps=None):
    profs = [PlatformProfile.gcf_gen1(), PlatformProfile.gcf_gen2(),
             PlatformProfile.aws_lambda()]
    fleets = []
    for i in range(n):
        spec = SPEC if body_ms is None else dataclasses.replace(
            SPEC, body_ms=body_ms[i])
        cap = 4 if caps is None else caps[i]
        prof = profs[i % len(profs)]
        knobs = dataclasses.replace(prof.knobs(), max_instances=cap)
        fleets.append(FleetSpec(name=f"f{i}", spec=spec, variation=VM,
                                profile=prof, knobs=knobs, policy=GATE))
    return fleets


def _run(policy, *, seed=0, traffic_seed=7, rate=2.0, duration=30_000.0,
         hedge=None, fleets=None, qos=None, drain=True):
    router = FleetRouter(fleets or _fleets(), policy, seed=seed,
                         hedge_after_ms=hedge)
    run = run_fleet_open_loop(
        router, PoissonProcess(rate),
        rng=np.random.RandomState(traffic_seed), duration_ms=duration,
        qos_classes=qos, drain=drain)
    return router, run


# ---------------------------------------------------------------------------
# FleetTelemetry
# ---------------------------------------------------------------------------


def test_fleet_telemetry_read_only_and_aggregates():
    router, _ = _run(RandomRoutingPolicy())
    t = router.telemetry
    assert len(t) == 3 and t.names == ("f0", "f1", "f2")
    with pytest.raises(AttributeError):
        t.names = ("x",)
    with pytest.raises(AttributeError):
        del t._views
    assert len(t.queue_depths()) == 3
    assert t.total_queue_depth == sum(t.queue_depths())
    assert t.total_in_flight == sum(t.in_flights())
    assert all(s > 0 for s in t.capacity_slots())
    # per-fleet views are the engines' own read-only Telemetry objects
    assert t.fleet(1) is router.engines[1].telemetry


def test_fleet_telemetry_rejects_empty():
    with pytest.raises(ValueError):
        FleetTelemetry(())


# ---------------------------------------------------------------------------
# Policies
# ---------------------------------------------------------------------------


def test_one_hot_routes_everything_to_its_fleet():
    router, run = _run(WeightedStaticRoutingPolicy.one_hot(2, 3))
    assert run.n_completed > 0
    assert set(run.result_fleets) == {2}
    assert router.engines[0].requests_arrived == 0
    assert router.engines[1].requests_arrived == 0


def test_weighted_static_validates():
    with pytest.raises(ValueError):
        WeightedStaticRoutingPolicy([])
    with pytest.raises(ValueError):
        WeightedStaticRoutingPolicy([0.0, 0.0])
    with pytest.raises(ValueError):
        WeightedStaticRoutingPolicy([1.0, -0.5])
    with pytest.raises(ValueError):
        WeightedStaticRoutingPolicy.one_hot(3, 3)


def test_exclude_is_honored_by_every_policy():
    router, _ = _run(RandomRoutingPolicy(), duration=5_000.0)
    rng = np.random.RandomState(0)
    for policy in (RandomRoutingPolicy(), GreedyRoutingPolicy(),
                   ProbabilisticRoutingPolicy(),
                   WeightedStaticRoutingPolicy([1.0, 1.0, 1.0])):
        for excl in range(3):
            ctx = RouteContext(telemetry=router.telemetry, rng=rng,
                               arrival_ms=0.0, exclude=excl)
            for _ in range(8):
                assert policy.route(ctx) != excl
    # a one-hot asked to avoid its only fleet falls back to the others
    ctx = RouteContext(telemetry=router.telemetry, rng=rng,
                       arrival_ms=0.0, exclude=1)
    assert WeightedStaticRoutingPolicy.one_hot(1, 3).route(ctx) != 1


def test_greedy_is_deterministic_and_prefers_idle_fleet():
    # no drain: fleet 0 (capped to one instance) is flooded far past its
    # service rate by the one-hot, so its backlog is still live
    router, _ = _run(WeightedStaticRoutingPolicy.one_hot(0, 3),
                     duration=10_000.0, rate=8.0, drain=False,
                     fleets=_fleets(caps=[1, 4, 4]))
    assert router.telemetry.fleet(0).queue_depth > 0
    g = GreedyRoutingPolicy(prior_serve_ms=SPEC.body_ms)
    rng = np.random.RandomState(1)
    ctx = RouteContext(telemetry=router.telemetry, rng=rng, arrival_ms=0.0)
    picks = {g.route(ctx) for _ in range(16)}
    assert len(picks) == 1          # no randomness
    assert picks != {0}             # fleet 0 carries all the backlog


def test_probabilistic_resolves_and_tracks_rate():
    p = ProbabilisticRoutingPolicy(update_interval_ms=1_000.0)
    router, run = _run(p, rate=4.0, duration=30_000.0)
    assert run.n_completed > 0
    assert p.n_solves >= 2
    assert p.solver_used in ("lp", "waterfill", "overload")
    assert p.probs is not None and p.probs.shape == (3,)
    assert np.isclose(p.probs.sum(), 1.0)
    # the EMA saw real inter-arrival times near the offered rate
    assert 1e3 / 4.0 * 0.3 < p._iat_ema.value < 1e3 / 4.0 * 3.0


# ---------------------------------------------------------------------------
# solve_split: LP == waterfill (continuous knapsack)
# ---------------------------------------------------------------------------


def test_solve_split_lp_equals_waterfill():
    rng = np.random.RandomState(42)
    for _ in range(50):
        n = int(rng.randint(2, 6))
        costs = rng.uniform(100.0, 3000.0, size=n)
        caps = rng.uniform(0.0, 1.0, size=n)
        if caps.sum() < 1.0:        # feasible instances only, here
            caps = caps / caps.sum() * rng.uniform(1.0, 2.0)
        p_lp, used_lp = solve_split(costs, caps, solver="lp")
        p_wf, used_wf = solve_split(costs, caps, solver="waterfill")
        assert used_wf in ("waterfill", "overload", "trivial")
        assert np.isclose(p_lp.sum(), 1.0) and np.isclose(p_wf.sum(), 1.0)
        # both optima achieve the same objective (argmin may tie)
        assert float(costs @ p_lp) == pytest.approx(
            float(costs @ p_wf), rel=1e-6)
        assert np.all(p_wf <= np.clip(caps, 0.0, 1.0) + 1e-9)


def test_solve_split_overload_is_capacity_proportional():
    p, used = solve_split([100.0, 200.0], [0.3, 0.3])
    assert used == "overload"
    assert np.allclose(p, [0.5, 0.5])
    p, used = solve_split([100.0, 200.0], [0.1, 0.3])
    assert used == "overload"
    assert np.allclose(p, [0.25, 0.75])


def test_solve_split_trivial_and_validation():
    p, used = solve_split([123.0], [0.2])
    assert used == "trivial" and np.allclose(p, [1.0])
    with pytest.raises(ValueError):
        solve_split([], [])
    with pytest.raises(ValueError):
        solve_split([1.0, 2.0], [0.5])
    with pytest.raises(ValueError):
        solve_split([1.0], [1.0], solver="magic")


def test_solve_split_prefers_cheap_fleets():
    p, _ = solve_split([100.0, 2000.0, 3000.0], [0.6, 1.0, 1.0])
    assert p[0] == pytest.approx(0.6)           # cheap fleet filled to cap
    assert p[1] == pytest.approx(0.4)           # remainder to next-cheapest
    assert p[2] == pytest.approx(0.0)


# ---------------------------------------------------------------------------
# Conservation (the property the sanitizer ledger enforces)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy_factory", [
    RandomRoutingPolicy,
    GreedyRoutingPolicy,
    ProbabilisticRoutingPolicy,
    lambda: WeightedStaticRoutingPolicy([3.0, 1.0, 2.0]),
])
@pytest.mark.parametrize("hedge", [None, 900.0])
def test_conservation_every_policy_and_seed(policy_factory, hedge):
    for seed in (0, 3):
        router, run = _run(policy_factory(), seed=seed,
                           traffic_seed=100 + seed, hedge=hedge)
        router.check_conservation()  # raises on any ledger violation
        assert run.n_arrived == (run.n_completed + run.n_dropped
                                 + run.n_pending_at_end)
        assert sum(run.per_fleet["per_fleet_arrived"]) == \
            run.n_arrived + run.n_hedges
        if hedge is None:
            assert run.n_hedges == 0


def test_double_dispatch_is_caught_by_the_ledger():
    class DoubleDispatchRouter(FleetRouter):
        """Mutation: submits every request to TWO fleets without going
        through the hedge ledger — the copies equation must fire."""

        def offer(self, payload, qos="default", qos_weight=1.0):
            super().offer(payload, qos=qos, qos_weight=qos_weight)
            other = (self.result_fleets[-1] + 1) % len(self.engines) \
                if self.result_fleets else 1
            self.engines[other].submit(
                payload, lambda res: None, submitted_at_ms=self.clock.now)

    router = DoubleDispatchRouter(_fleets(), RandomRoutingPolicy(), seed=0)
    with pytest.raises(SanitizerError) as ei:
        run_fleet_open_loop(router, PoissonProcess(2.0),
                            rng=np.random.RandomState(5),
                            duration_ms=10_000.0)
        router.check_conservation()
    assert "double dispatch" in str(ei.value)


# ---------------------------------------------------------------------------
# Hedging: once in latency, twice in cost
# ---------------------------------------------------------------------------


def _hedge_heavy_run(count_hedge_waste=True):
    # fleet 0 is an order of magnitude slower: most primaries straggle
    # past the hedge deadline, and the fleet-1 copy usually wins
    fleets = _fleets(2, body_ms=[3000.0, 250.0], caps=[4, 4])
    router = FleetRouter(fleets, WeightedStaticRoutingPolicy([0.8, 0.2]),
                         seed=2, hedge_after_ms=600.0,
                         count_hedge_waste=count_hedge_waste)
    run = run_fleet_open_loop(router, PoissonProcess(1.0),
                              rng=np.random.RandomState(11),
                              duration_ms=40_000.0)
    return router, run


def test_hedging_counts_once_in_latency_twice_in_cost():
    router, run = _hedge_heavy_run()
    router.check_conservation()
    assert run.n_hedges > 0 and run.n_hedge_wins > 0
    assert run.n_hedge_cancelled > 0
    # latency: exactly one result per completed logical request
    assert len(run.results) == run.n_completed
    assert len(run.results) <= run.n_arrived
    # cost: both copies billed — the engines' ledgers contain the losers
    assert run.hedge_waste_cost > 0.0
    assert run.total_cost == pytest.approx(
        sum(e.cost.total for e in router.engines))
    # hedge latencies are back-dated to the logical arrival: a win by the
    # fast fleet still pays the hedge_after_ms head start
    hedge_wins = [r for r, f in zip(run.results, run.result_fleets)
                  if f == 1]
    assert hedge_wins and all(r.latency_ms > 0 for r in hedge_wins)


def test_count_hedge_waste_false_subtracts_loser_cost():
    router_a, run_a = _hedge_heavy_run(count_hedge_waste=True)
    router_b, run_b = _hedge_heavy_run(count_hedge_waste=False)
    # identical runs (same seeds), different accounting
    assert run_a.n_hedge_cancelled == run_b.n_hedge_cancelled
    assert run_b.total_cost == pytest.approx(
        run_a.total_cost - run_a.hedge_waste_cost)


def test_hedge_validation():
    with pytest.raises(ValueError):
        FleetRouter(_fleets(), RandomRoutingPolicy(), hedge_after_ms=0.0)
    with pytest.raises(ValueError):
        FleetRouter([], RandomRoutingPolicy())
    dup = _fleets()[:2] + [_fleets()[0]]
    with pytest.raises(ValueError):
        FleetRouter(dup, RandomRoutingPolicy())


# ---------------------------------------------------------------------------
# Determinism, QoS plumbing, summary
# ---------------------------------------------------------------------------


def test_same_seeds_reproduce_bit_identical_runs():
    a_router, a = _run(ProbabilisticRoutingPolicy(), hedge=800.0)
    b_router, b = _run(ProbabilisticRoutingPolicy(), hedge=800.0)
    assert [r.latency_ms for r in a.results] == \
        [r.latency_ms for r in b.results]
    assert a.result_fleets == b.result_fleets
    assert a.n_hedges == b.n_hedges
    assert a.total_cost == pytest.approx(b.total_cost)


def test_qos_classes_flow_to_results():
    qos = [QoSClass("gold", weight=3.0), QoSClass("bronze", weight=1.0)]
    _, run = _run(RandomRoutingPolicy(), qos=qos, rate=4.0)
    seen = set(run.result_classes)
    assert seen <= {"gold", "bronze"} and "gold" in seen
    # weight-proportional attribution: gold ~3x bronze
    gold = run.result_classes.count("gold")
    bronze = run.result_classes.count("bronze")
    assert gold > bronze


def test_fleet_summary_pools_winners():
    router, run = _run(RandomRoutingPolicy(), rate=3.0)
    s = FleetSummary.from_run("random", router, run)
    assert s.n_completed == len(run.results)
    assert len(s.per_fleet) == 3
    assert sum(f["completed"] for f in s.per_fleet) == s.n_completed
    assert sum(f["share"] for f in s.per_fleet) == pytest.approx(1.0)
    assert s.cost_per_1k == pytest.approx(
        s.total_cost / max(s.n_completed, 1) * 1e3)
    assert np.isfinite(s.p99_latency_ms)


def test_greedy_not_worse_than_random_on_seeded_scenario():
    # the acceptance direction on a fixed seeded scenario (the benchmark
    # sweep checks it across the whole ladder)
    means = {}
    for name, factory in (("random", RandomRoutingPolicy),
                          ("greedy", GreedyRoutingPolicy)):
        lats = []
        for ts in (21, 22, 23):
            _, run = _run(factory(), traffic_seed=ts, rate=4.0,
                          duration=40_000.0)
            lats.extend(r.latency_ms for r in run.results)
        means[name] = float(np.mean(lats))
    assert means["greedy"] <= means["random"]
