"""Sharding-rule logic (pure functions — no 512-device mesh needed)."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.registry import get_config
from repro.distributed.sharding import use_mesh, shard, logical_to_spec
from repro.launch.shardings import (
    add_fsdp_axes,
    batch_spec,
    cache_spec,
    dp_only_rules,
    make_rules,
    param_spec,
)

MESH = jax.make_mesh((1, 1), ("data", "model"))  # shape-logic only


def test_make_rules_divisibility_guards():
    cfg = get_config("whisper-small")  # heads 12, vocab 51865: both indivisible by 16
    # emulate a 16-wide model axis by checking the rule predicate directly
    assert cfg.n_heads % 16 != 0 and cfg.vocab % 16 != 0
    cfg2 = get_config("llama3.2-1b")   # heads 32, kv 8
    assert cfg2.n_heads % 16 == 0 and cfg2.n_kv_heads % 16 != 0


def test_param_spec_patterns():
    cfg = get_config("llama3.2-1b")
    assert param_spec("embed", (128256, 2048), cfg, MESH) == P("model", None)
    assert param_spec("layers/attn/wq", (16, 2048, 32, 64), cfg, MESH) == \
        P(None, None, "model", None)
    assert param_spec("layers/mlp/w_down", (16, 8192, 2048), cfg, MESH) == \
        P(None, "model", None)
    assert param_spec("final_norm", (2048,), cfg, MESH) == P(None)


def test_param_spec_moe_experts():
    cfg = get_config("deepseek-moe-16b")
    spec = param_spec("layers/mlp/w_gate", (28, 64, 2048, 1408), cfg, MESH)
    assert spec == P(None, "model", None, None)  # expert-sharded
    spec = param_spec("layers/mlp/shared/w_gate", (28, 2048, 2816), cfg, MESH)
    assert spec == P(None, None, "model")        # dense shared expert


def test_guard_drops_indivisible():
    cfg = get_config("whisper-small")
    mesh16 = jax.make_mesh((1, 1), ("data", "model"))
    # vocab 51865 is odd -> any model sharding on it must be dropped when
    # the axis size doesn't divide; with axis size 1 everything divides.
    spec = param_spec("embed", (51865, 768), cfg, mesh16)
    assert spec == P("model", None)  # size-1 axis always divides


def test_fsdp_never_shards_layer_dim():
    spec = add_fsdp_axes(P(None, None, "model", None), (88, 12288, 96, 128),
                         MESH, ("data",))
    assert spec[0] is None  # leading (layer) dim untouched
    assert ("data",) in tuple(spec) or "data" in tuple(spec)


def test_dp_only_rules_cap_to_batch():
    rules = dp_only_rules(MESH, global_batch=256)
    assert rules["model"] is None and rules["ff"] is None
    assert rules["batch"] is not None


def test_cache_spec_kv_head_fallbacks():
    # size-1 model axis: kv always divides -> kv-head branch
    llama = get_config("llama3.2-1b")
    spec = cache_spec("k", (16, 128, 8, 32768, 64), llama, MESH, ("data",))
    assert spec[2] == "model" and spec[3] is None
    ds = get_config("deepseek-moe-16b")  # kv=16: shard kv heads
    spec = cache_spec("k", (28, 128, 16, 32768, 128), ds, MESH, ("data",))
    assert spec[2] == "model"
    # a 16-wide model axis with kv=8 must fall through to head_dim — check
    # the branch predicate directly (can't build a 256-device mesh here)
    assert llama.n_kv_heads % 16 != 0 and llama.head_dim % 16 == 0


def test_batch_spec():
    assert batch_spec("tokens", (256, 4096), MESH, ("data",)) == \
        P(("data",), None)


def test_shard_divisibility_guard_noop():
    """shard() drops axes the dim doesn't divide — a seq constraint on a
    1-token decode tensor must be harmless."""
    import jax.numpy as jnp
    mesh = jax.make_mesh((1,), ("model",))
    with use_mesh(mesh, {"seq": "model", "batch": None}):
        x = jnp.ones((2, 1, 8))
        y = shard(x, "batch", "seq", None)  # seq dim of size 1
        assert y.shape == x.shape


def test_logical_to_spec_respects_rules():
    mesh = jax.make_mesh((1,), ("model",))
    with use_mesh(mesh, {"heads": "model", "batch": None}):
        assert logical_to_spec("batch", None, "heads", None) == \
            P(None, None, "model", None)
