"""The open-loop arrival layer, pinned by queueing theory
(sim/arrivals.py; DESIGN.md §12).

Four families of checks:

* the processes themselves — Poisson IATs pass a KS test against the
  analytic Exponential(λ); MMPP and diurnal long-run rates match their
  stationary values; traces replay bit-exactly and draw no randomness;
* the driver obeys conservation — every arrival is accounted for as
  completed, dropped, or pending, under deferral AND finite-queue loss;
* Little's law — L = λW on a gate-disabled steady-state arm, with L
  measured independently (cadence-sampled system population), not
  derived from the request timestamps it is compared against;
* the survivorship-bias fix in metrics.OpenLoopSummary — under overload
  the completed-only wait percentile understates; ``wait_p99_ms`` folds
  in the censored waits of everything still stuck at the end.
"""
import dataclasses
import math
import os

import numpy as np
import pytest
from scipy import stats

from repro.core.control import (
    ClassicMinosController,
    QueueAwareAdmissionController,
)
from repro.core.policy import MinosPolicy
from repro.sim import (
    ArrivalProcess,
    DiurnalPoissonProcess,
    FaaSPlatform,
    FunctionSpec,
    MMPPProcess,
    PlatformProfile,
    PoissonProcess,
    QoSClass,
    TraceProcess,
    VariationModel,
    arrival_times_ms,
    run_open_loop,
)
from repro.sim.arrivals import draw_classes
from repro.sim.metrics import OpenLoopSummary

SPEC = FunctionSpec(
    name="openloop", prepare_ms=600.0, body_ms=1500.0, benchmark_ms=300.0,
    cold_start_ms=250.0, recycle_lifetime_ms=45_000.0, contention_rho=0.95,
    benchmark_noise=0.08,
)
VM = VariationModel(sigma=0.15)
PROFILE = PlatformProfile.gcf_gen1()


def _baseline_policy() -> MinosPolicy:
    return MinosPolicy(elysium_threshold=float("inf"), enabled=False)


def _platform(max_instances, *, seed=0, queue_capacity=None,
              admission=False) -> FaaSPlatform:
    knobs = dataclasses.replace(
        PROFILE.knobs(), max_instances=max_instances,
        queue_capacity=queue_capacity)
    if admission:
        ctrl = QueueAwareAdmissionController(
            ClassicMinosController(_baseline_policy()),
            headroom=1.25, min_slots=2)
        return FaaSPlatform(SPEC, VM, None, seed=seed, profile=PROFILE,
                            knobs=knobs, controller=ctrl)
    return FaaSPlatform(SPEC, VM, _baseline_policy(), seed=seed,
                        profile=PROFILE, knobs=knobs)


# ---------------------------------------------------------------------------
# The processes
# ---------------------------------------------------------------------------


def test_all_processes_satisfy_protocol():
    procs = [PoissonProcess(1.0),
             MMPPProcess(0.5, 3.0),
             DiurnalPoissonProcess(1.0),
             TraceProcess((100.0, 200.0))]
    for p in procs:
        assert isinstance(p, ArrivalProcess)
        iats = p.iats_ms(np.random.RandomState(0), 50)
        assert iats.shape == (50,) and np.all(iats >= 0.0)
        assert p.mean_rate_per_ms() > 0.0


def test_poisson_iats_are_exponential_ks():
    """KS test against the analytic Exponential(λ): the one distributional
    property the whole M/G/c analysis downstream rests on. Pinned seed;
    p > 0.05 at n=4000 would fail decisively for e.g. a units slip
    (seconds vs ms shifts the scale 1000×) or uniform-instead-of-exp."""
    rate = 2.0  # per second → scale 500 ms
    iats = PoissonProcess(rate).iats_ms(np.random.RandomState(12345), 4000)
    ks = stats.kstest(iats, stats.expon(scale=1000.0 / rate).cdf)
    assert ks.pvalue > 0.05, ks
    assert np.mean(iats) == pytest.approx(500.0, rel=0.05)


def test_mmpp_long_run_rate_matches_stationary():
    proc = MMPPProcess(base_rate_per_s=0.5, burst_rate_per_s=4.0,
                       mean_off_ms=20_000.0, mean_on_ms=5_000.0)
    iats = proc.iats_ms(np.random.RandomState(3), 40_000)
    got = len(iats) / iats.sum()
    assert got == pytest.approx(proc.mean_rate_per_ms(), rel=0.05)


def test_mmpp_is_overdispersed_relative_to_poisson():
    """Index of dispersion of counts > 1 — the defining burstiness
    property (a Poisson process has IDC = 1)."""
    proc = MMPPProcess(base_rate_per_s=0.5, burst_rate_per_s=4.0,
                       mean_off_ms=20_000.0, mean_on_ms=5_000.0)
    times = np.cumsum(proc.iats_ms(np.random.RandomState(5), 30_000))
    window = 10_000.0  # ms; on the order of the phase residence times
    counts = np.histogram(times, bins=np.arange(0.0, times[-1], window))[0]
    idc = counts.var() / counts.mean()
    assert idc > 1.5, idc


def test_diurnal_rate_modulates_with_phase():
    """Thinned arrivals concentrate at the peak: with amplitude 0.5 the
    peak-half-period count is well above the trough's. A short synthetic
    period keeps the test fast — the shape is what's under test."""
    proc = DiurnalPoissonProcess(base_rate_per_s=5.0, amplitude=0.5,
                                 phase_h=0.0, period_ms=60_000.0)
    times = np.cumsum(proc.iats_ms(np.random.RandomState(11), 20_000))
    frac = (times / proc.period_ms) % 1.0
    # peak is centered at frac 0 (phase_h=0): quarter-period either side
    peak = np.sum((frac < 0.25) | (frac >= 0.75))
    trough = np.sum((frac >= 0.25) & (frac < 0.75))
    assert peak > 1.5 * trough, (peak, trough)
    assert proc.mean_rate_per_ms() == pytest.approx(5.0 / 1000.0)


def test_trace_replay_is_bit_exact_and_seed_independent():
    trace = TraceProcess((120.0, 30.0, 500.0))
    a = trace.iats_ms(np.random.RandomState(0), 10)
    b = trace.iats_ms(np.random.RandomState(999), 10)
    np.testing.assert_array_equal(a, b)  # draws nothing from the rng
    # cyclic tiling past the trace length
    np.testing.assert_array_equal(a[:6], [120.0, 30.0, 500.0] * 2)
    rng = np.random.RandomState(4)
    state_before = rng.get_state()[1].copy()
    trace.iats_ms(rng, 100)
    np.testing.assert_array_equal(rng.get_state()[1], state_before)


def test_trace_from_file_round_trip(tmp_path):
    p = tmp_path / "trace.txt"
    p.write_text("# faas-offloading-sim style IAT trace\n"
                 "100.5\n"
                 "\n"
                 "250  # trailing comment\n"
                 "75\n")
    trace = TraceProcess.from_file(str(p), name="cust")
    assert trace.name == "cust"
    assert trace.iats == (100.5, 250.0, 75.0)
    assert trace.mean_rate_per_ms() == pytest.approx(3.0 / 425.5)


def test_trace_validation():
    with pytest.raises(ValueError):
        TraceProcess(())
    with pytest.raises(ValueError):
        TraceProcess((10.0, -1.0))
    with pytest.raises(ValueError):
        TraceProcess((0.0, 0.0))


def test_arrival_times_are_sorted_within_horizon():
    times = arrival_times_ms(PoissonProcess(3.0), np.random.RandomState(8),
                             duration_ms=120_000.0)
    assert np.all(np.diff(times) >= 0.0)
    assert times[-1] < 120_000.0
    # n ≈ λT: 360 expected, CLT bound ±5σ
    assert abs(len(times) - 360) < 5 * math.sqrt(360)


def test_qos_classes_drawn_by_weight():
    classes = [QoSClass("batch", weight=1.0), QoSClass("premium", weight=3.0)]
    idx = draw_classes(np.random.RandomState(21), 8000, classes)
    assert np.mean(idx == 1) == pytest.approx(0.75, abs=0.02)
    with pytest.raises(ValueError):
        QoSClass("bad", weight=0.0)


# ---------------------------------------------------------------------------
# The driver: conservation, Little's law
# ---------------------------------------------------------------------------


def test_conservation_under_finite_queue_loss():
    """arrived == completed + dropped + pending, with real drops: K=2
    servers, queue capacity 5, offered 4/s (ρ≈8) — an M/G/c/K loss
    system. Drops are instant refusals, stamped in drop_events."""
    plat = _platform(2, queue_capacity=5)
    run = run_open_loop(plat, PoissonProcess(4.0),
                        rng=np.random.RandomState(7), duration_ms=60_000.0)
    assert run.n_arrived == (run.n_completed + run.n_dropped
                             + run.n_pending_at_end)
    assert run.n_dropped > 0 and run.drop_rate > 0.5
    assert len(run.drop_events) == run.n_dropped
    assert run.process_name == "poisson"
    # engine-side counters agree with the run's view
    assert plat.requests_dropped == run.n_dropped


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_littles_law_steady_state(seed):
    """L = λW on a gate-disabled arm at ρ≈0.5, L measured independently
    by cadence-sampling N(t) = queue + in-flight + admission-parked.
    Measured agreement at these seeds is ≤0.3%; 5% is the bound because
    the sampled L and the per-request W share no code path."""
    plat = _platform(6, seed=seed)
    run = run_open_loop(plat, PoissonProcess(1.5),
                        rng=np.random.RandomState(42 + seed),
                        duration_ms=600_000.0)
    assert run.n_pending_at_end == 0  # steady state fully drained
    lam = run.n_arrived / run.duration_ms
    W = float(np.mean([r.latency_ms for r in run.results]))
    L = run.mean_system_population()
    assert L == pytest.approx(lam * W, rel=0.05), (L, lam * W)


# ---------------------------------------------------------------------------
# Admission under bursts (QueueAwareAdmissionController × MMPP)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def burst_runs():
    """One pinned MMPP realization (via TraceProcess, so the per-arrival
    phase flags are known exactly) replayed through: the admission-
    controlled platform, the same platform without admission, and a
    Poisson control at the same *realized* rate."""
    proc = MMPPProcess(base_rate_per_s=0.25, burst_rate_per_s=3.0,
                       mean_off_ms=40_000.0, mean_on_ms=6_000.0)
    iats, on = proc.iats_with_phase(np.random.RandomState(2), 500)
    cum = np.cumsum(iats)
    trace = TraceProcess(tuple(iats))
    # one full pass of the trace, and not a single wrapped arrival
    duration = float(cum[-1] + 0.5 * iats[0])
    realized_rate = len(iats) / cum[-1] * 1000.0

    adm = _platform(4, admission=True)
    run_adm = run_open_loop(adm, trace, rng=np.random.RandomState(99),
                            duration_ms=duration)
    noadm = _platform(4)
    run_noadm = run_open_loop(noadm, trace, rng=np.random.RandomState(99),
                              duration_ms=duration)
    pois = _platform(4, admission=True)
    run_pois = run_open_loop(pois, PoissonProcess(realized_rate),
                             rng=np.random.RandomState(200),
                             duration_ms=duration)
    return dict(on=on, cum=cum, adm=adm, run_adm=run_adm, noadm=noadm,
                run_noadm=run_noadm, run_pois=run_pois)


def test_burst_defers_rise_in_on_phase_and_drain(burst_runs):
    b = burst_runs
    run = b["run_adm"]
    # deferral engaged hard during the realization's bursts...
    assert run.n_defer_decisions > 50
    assert run.n_deferred_items > 50
    assert run.defer_rate > 0.1
    # ...and the system fully drains after the last one
    assert run.n_pending_at_end == 0
    assert run.n_arrived == run.n_completed + run.n_dropped
    # phase-conditioned pressure: every completion maps back to its trace
    # index (arrival time = completion − latency, exact by construction),
    # so waits split by the phase the arrival landed in
    arr = np.array([r.t_completed_ms - r.latency_ms for r in run.results])
    idx = np.clip(np.searchsorted(b["cum"], arr + 1e-6), 0,
                  len(b["cum"]) - 1)
    waits = np.array([r.queue_wait_ms for r in run.results])
    on_mask = b["on"][idx]
    assert on_mask.any() and (~on_mask).any()
    assert waits[on_mask].mean() > 3.0 * waits[~on_mask].mean()


def test_burstiness_not_mean_rate_drives_deferral(burst_runs):
    """A Poisson control at the SAME realized rate barely defers: the
    admission pressure is the on-phase's doing, which a mean-rate ladder
    cannot see (the point of the MMPP satellite)."""
    b = burst_runs
    assert b["run_pois"].n_defer_decisions < 0.2 * b["run_adm"].n_defer_decisions


def test_admission_does_not_increase_churn(burst_runs):
    """Deferral smooths the same offered load through the same K-capped
    supply: it must never create extra instance churn over the
    no-admission baseline on the identical trace."""
    b = burst_runs
    assert b["adm"].instances_started <= b["noadm"].instances_started
    assert b["run_adm"].n_completed == b["run_noadm"].n_completed


# ---------------------------------------------------------------------------
# Survivorship bias (metrics.OpenLoopSummary)
# ---------------------------------------------------------------------------


def test_wait_p99_includes_censored_waits_under_overload():
    """Regression for the survivorship bias: at ρ≈8 with a bounded drain
    only ~1/4 of arrivals complete, so completed-only percentiles look at
    the lucky survivors. wait_p99_ms folds in the censored waits of the
    stuck majority and must exceed the completed-only figure."""
    plat = _platform(2)
    run = run_open_loop(plat, PoissonProcess(4.0),
                        rng=np.random.RandomState(7),
                        duration_ms=60_000.0, drain_limit_ms=1.0)
    assert run.n_pending_at_end > run.n_completed  # genuinely overloaded
    assert len(run.censored_waits_ms) > 0
    s = OpenLoopSummary.from_run("overload", plat, run)
    assert s.wait_p99_ms > s.completed_wait_p99_ms
    assert s.n_arrived == run.n_arrived
    assert s.process == "poisson"
    # the censored waits really are censored at the final clock, not the
    # arrival horizon
    assert max(run.censored_waits_ms) <= plat.loop.now


def test_open_loop_summary_on_healthy_run():
    plat = _platform(6)
    run = run_open_loop(plat, PoissonProcess(1.0),
                        rng=np.random.RandomState(1), duration_ms=120_000.0)
    s = OpenLoopSummary.from_run("healthy", plat, run)
    assert s.n_dropped == 0 and s.drop_rate == 0.0
    assert s.p50_latency_ms <= s.p95_latency_ms <= s.p99_latency_ms
    # no queueing to speak of: the honest and the survivor views coincide
    assert s.wait_p99_ms == pytest.approx(s.completed_wait_p99_ms, abs=1.0)
    assert s.cost_per_1k > 0.0
    assert s.mean_system_population == pytest.approx(
        run.n_arrived / run.duration_ms
        * float(np.mean([r.latency_ms for r in run.results])), rel=0.1)


# ---------------------------------------------------------------------------
# Azure-Functions-style trace loader (tests/data fixture)
# ---------------------------------------------------------------------------

AZURE_FIXTURE = os.path.join(os.path.dirname(__file__), "data",
                             "azure_invocations_sample.csv")


def test_azure_csv_loader_expands_minute_counts():
    tp = TraceProcess.from_azure_csv(AZURE_FIXTURE, function="a7f3")
    assert tp.name.startswith("azure[a7f3")
    # fixture row: 12 minute-counts summing to 1279 invocations
    assert len(tp.iats) == 1279
    # IATs reconstruct arrival times confined to the 12-minute span
    times = np.cumsum(tp.iats)
    assert 0.0 < times[0] < 60_000.0
    assert times[-1] < 12 * 60_000.0
    assert tp.mean_rate_per_ms() * 1e3 == pytest.approx(1.78, abs=0.05)


def test_azure_csv_loader_is_seed_independent():
    tp = TraceProcess.from_azure_csv(AZURE_FIXTURE, function="a7f3")
    a = tp.iats_ms(np.random.RandomState(0), 200)
    b = tp.iats_ms(np.random.RandomState(999), 200)
    assert np.array_equal(a, b)


def test_azure_csv_loader_row_selection_and_errors():
    # no selector -> first data row (the sparse timer function)
    tp = TraceProcess.from_azure_csv(AZURE_FIXTURE)
    assert tp.name.startswith("azure[c0ldfn")
    assert len(tp.iats) == 8
    with pytest.raises(ValueError):
        TraceProcess.from_azure_csv(AZURE_FIXTURE, function="nonexistent")


def test_azure_csv_minute_ms_rescales_time():
    full = TraceProcess.from_azure_csv(AZURE_FIXTURE, function="a7f3")
    fast = TraceProcess.from_azure_csv(AZURE_FIXTURE, function="a7f3",
                                       minute_ms=6_000.0)
    assert fast.mean_rate_per_ms() == pytest.approx(
        10.0 * full.mean_rate_per_ms())


def test_azure_csv_all_zero_minute_row_raises_clear_error(tmp_path):
    """Regression: a function row whose every per-minute count is zero
    (common in the sparse tail of the 2019 dataset) used to fall through
    to an opaque IndexError in the IAT reconstruction; it must fail fast
    with an actionable message naming the offending function."""
    p = tmp_path / "degenerate.csv"
    p.write_text(
        "HashOwner,HashApp,HashFunction,Trigger,1,2,3\n"
        "o1,a1,deadfn00,http,0,0,0\n"
        "o1,a1,livefn00,http,2,0,1\n")
    with pytest.raises(ValueError, match="deadfn00.*no invocations"):
        TraceProcess.from_azure_csv(str(p))  # first row is the dead one
    with pytest.raises(ValueError, match="no invocations"):
        TraceProcess.from_azure_csv(str(p), function="deadfn")
    # the live sibling row still loads
    assert len(TraceProcess.from_azure_csv(str(p), function="livefn").iats) == 3


def test_azure_trace_drives_open_loop():
    tp = TraceProcess.from_azure_csv(AZURE_FIXTURE, function="a7f3")
    plat = _platform(8)
    run = run_open_loop(plat, tp, rng=np.random.RandomState(3),
                        duration_ms=60_000.0)
    assert run.n_arrived == (run.n_completed + run.n_dropped
                             + run.n_pending_at_end)
    assert run.n_completed > 50
    assert run.process_name == tp.name


# ---------------------------------------------------------------------------
# Per-class SLOs (QoSClass.slo_ms -> summary attainment rows)
# ---------------------------------------------------------------------------


def test_qos_slo_validation():
    with pytest.raises(ValueError):
        QoSClass("x", slo_ms=0.0)
    with pytest.raises(ValueError):
        QoSClass("x", slo_ms=-5.0)
    assert QoSClass("x").slo_ms is None  # no SLO by default


def test_slo_attainment_by_class_math():
    from repro.sim import slo_attainment_by_class
    qos = (QoSClass("gold", slo_ms=100.0), QoSClass("bronze", slo_ms=50.0),
           QoSClass("free"))  # no SLO: skipped, not reported as 100%
    rows = slo_attainment_by_class(
        ["gold", "gold", "bronze"], [80.0, 120.0, 40.0], qos)
    assert [r["qos"] for r in rows] == ["gold", "bronze"]
    gold, bronze = rows
    assert gold["n_completed"] == 2 and gold["attainment"] == 0.5
    assert bronze["attainment"] == 1.0 and bronze["slo_ms"] == 50.0
    # a class with an SLO but no completions reports NaN, not a fake 100%
    empty = slo_attainment_by_class([], [], (QoSClass("g", slo_ms=10.0),))
    assert math.isnan(empty[0]["attainment"])
    assert slo_attainment_by_class(["g"], [1.0], None) == ()


def test_open_loop_summary_reports_per_class_slo():
    plat = _platform(6)
    qos = (QoSClass("gold", weight=1.0, slo_ms=120_000.0),
           QoSClass("bronze", weight=1.0, slo_ms=1.0))  # unattainable
    run = run_open_loop(plat, PoissonProcess(1.0),
                        rng=np.random.RandomState(2),
                        duration_ms=60_000.0, qos_classes=qos)
    s = OpenLoopSummary.from_run("slo", plat, run, qos_classes=qos)
    by_name = {r["qos"]: r for r in s.slo_attainment}
    assert set(by_name) == {"gold", "bronze"}
    assert by_name["gold"]["attainment"] == 1.0   # generous budget
    assert by_name["bronze"]["attainment"] == 0.0  # 1ms is impossible
    assert (by_name["gold"]["n_completed"]
            + by_name["bronze"]["n_completed"]) == run.n_completed
    # without qos_classes the summary stays backward-compatible
    assert OpenLoopSummary.from_run("plain", plat, run).slo_attainment == ()


# ---------------------------------------------------------------------------
# QoS weights flow into the engine's weighted-fair queue
# ---------------------------------------------------------------------------


def test_qos_weights_reach_fair_queue_under_backlog():
    """fair_queue=True + a shared backlog: the heavy class's completions
    must outpace the light class's well beyond its 3:1 arrival share."""
    classes = [QoSClass("gold", weight=6.0), QoSClass("econ", weight=1.0)]
    knobs = dataclasses.replace(PROFILE.knobs(), max_instances=1,
                                fair_queue=True)
    plat = FaaSPlatform(SPEC, VM, _baseline_policy(), seed=0,
                        profile=PROFILE, knobs=knobs)
    run = run_open_loop(plat, PoissonProcess(3.0),
                        rng=np.random.RandomState(9),
                        duration_ms=30_000.0, qos_classes=classes,
                        drain=False)
    # completion-weighted: under permanent backlog, gold share of the
    # completions exceeds its 6/7 arrival share's FIFO expectation; the
    # crisp invariant is the queue itself, tested in
    # test_lifecycle_queue.py — here we pin the end-to-end plumbing
    inv_weights = {i.qos: i.qos_weight for i in plat.queue.waiting()}
    assert inv_weights.get("gold") == 6.0
    assert inv_weights.get("econ") == 1.0
    gold_done = run.result_classes.count("gold")
    econ_done = run.result_classes.count("econ")
    assert gold_done > 4 * max(econ_done, 1)
