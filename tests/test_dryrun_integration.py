"""Integration: the multi-pod dry-run lowers + compiles in a subprocess
(device count is locked at first jax init, so the 512-device environment
must be a fresh interpreter)."""
import json
import pathlib
import subprocess
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parents[1]


def _run(*args: str) -> subprocess.CompletedProcess:
    env = {"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin",
           "HOME": "/root"}
    import os
    env.update({k: v for k, v in os.environ.items() if k not in env})
    env.pop("XLA_FLAGS", None)
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", *args],
        capture_output=True, text=True, timeout=900, env=env, cwd=REPO,
    )


@pytest.mark.slow
def test_dryrun_single_combo_compiles():
    r = _run("--arch", "qwen3-0.6b", "--shape", "decode_32k")
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK   qwen3-0.6b x decode_32k" in r.stdout
    res = json.loads(
        (REPO / "benchmarks/results/qwen3-0.6b_decode_32k_1pod.json").read_text()
    )
    assert res["status"] == "ok"
    assert res["chips"] == 256
    rf = res["roofline"]
    assert rf["flops_per_dev"] > 0
    assert rf["bottleneck"] in ("compute", "memory", "collective")


@pytest.mark.slow
def test_dryrun_multipod_compiles():
    r = _run("--arch", "granite-moe-1b-a400m", "--shape", "long_500k",
             "--multi-pod", "--tag", "itest")
    assert r.returncode == 0, r.stdout + r.stderr
    res = json.loads(
        (REPO / "benchmarks/results/granite-moe-1b-a400m_long_500k_2pod_itest.json").read_text()
    )
    assert res["chips"] == 512


@pytest.mark.slow
def test_dryrun_whisper_long_skipped():
    r = _run("--arch", "whisper-small", "--shape", "long_500k")
    assert r.returncode == 0
    assert "SKIP" in r.stdout
