"""The jitted serving decode path (ROADMAP: "JIT the serving decode path").

The contract: jitting is a pure performance change — tokens are identical
to the eager per-step loop, regardless of shape bucketing (decode-length
padding) or stream batching (batch padding). Plus the compile-count
bookkeeping the CI guard relies on.
"""
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.core.cost import Pricing
from repro.core.policy import MinosPolicy
from repro.serving.backend import ModelServingBackend, ServeRequest, _bucket


@pytest.fixture(scope="module")
def dense_backend():
    return ModelServingBackend(get_smoke_config("llama3.2-1b"), seed=0)


def test_bucket_rounding():
    assert [_bucket(n) for n in (1, 2, 3, 5, 8, 9)] == [1, 2, 4, 8, 8, 16]
    assert _bucket(3, base=8) == 8
    with pytest.raises(ValueError):
        _bucket(0)


@pytest.mark.parametrize("arch", ["llama3.2-1b", "whisper-small"])
def test_jit_tokens_equal_eager_tokens(arch):
    be = ModelServingBackend(get_smoke_config(arch), seed=0)
    req = ServeRequest(prompt=np.arange(1, 6, dtype=np.int32), max_new_tokens=5)
    eager = be.run_model(req, mode="eager")
    jit = be.run_model(req, mode="jit")
    assert np.array_equal(eager, jit)
    assert jit.dtype == np.int32 and jit.shape == (5,)


def test_batched_streams_do_not_change_tokens(dense_backend):
    """load > 1 pads the batch with replicas of the stream; row 0 must be
    byte-identical to the unbatched result (the pipeline sweep's
    outputs-identical-across-arms invariant depends on this)."""
    req = ServeRequest(prompt=np.arange(1, 5, dtype=np.int32), max_new_tokens=4)
    solo = dense_backend.run_model(req, load=1)
    for load in (2, 3, 4):
        assert np.array_equal(solo, dense_backend.run_model(req, load=load))


def test_decode_bucket_padding_preserves_prefix(dense_backend):
    """Extra scan steps from decode-length bucketing only append tokens
    past the requested prefix."""
    prompt = np.arange(1, 5, dtype=np.int32)
    long = dense_backend.run_model(
        ServeRequest(prompt=prompt, max_new_tokens=8))
    for t in (2, 5, 7):
        short = dense_backend.run_model(
            ServeRequest(prompt=prompt, max_new_tokens=t))
        assert np.array_equal(short, long[:t])


def test_jit_stats_count_compiles_and_guard_eager():
    be = ModelServingBackend(get_smoke_config("llama3.2-1b"), seed=0)
    req = ServeRequest(prompt=np.arange(4, dtype=np.int32), max_new_tokens=4)
    be.run_model(req)
    assert be.jit_stats == {"jit_calls": 1, "eager_calls": 0,
                            "bucket_compiles": 1}
    be.run_model(req)                       # same bucket: no new compile
    assert be.jit_stats["bucket_compiles"] == 1
    be.run_model(req, load=2)               # new batch bucket
    assert be.jit_stats["bucket_compiles"] == 2
    be.run_model(req, mode="eager")
    assert be.jit_stats["eager_calls"] == 1


def test_body_duration_is_work_over_speed(dense_backend):
    from repro.core.lifecycle import FunctionInstance

    inst = FunctionInstance(speed_factor=2.0)
    req = ServeRequest(prompt=np.arange(6, dtype=np.int32), max_new_tokens=4)
    dur, toks = dense_backend.body(req, inst, np.random.RandomState(0), load=2)
    work = dense_backend.c_prefill * 6 + dense_backend.c_decode * 4
    assert dur == pytest.approx(work / 2.0)   # load handled by the engine
    assert len(toks) == 4


def test_serving_engine_serves_on_jitted_path():
    from repro.serving.engine import MinosServingEngine

    eng = MinosServingEngine(
        get_smoke_config("llama3.2-1b"),
        MinosPolicy(elysium_threshold=float("inf"), enabled=False),
        Pricing.tpu_chip_seconds(4), seed=1, max_pool=2)
    reqs = [ServeRequest(prompt=np.arange(4, dtype=np.int32),
                         max_new_tokens=3, request_id=i) for i in range(4)]
    res = eng.serve(reqs)
    assert len(res) == 4
    assert eng.jit_stats["eager_calls"] == 0
    assert eng.jit_stats["jit_calls"] == 4


def test_calibrate_load_slowdown_fits_nonnegative_exponent(dense_backend):
    alpha = dense_backend.calibrate_load_slowdown(
        loads=(1, 2), max_new_tokens=4, repeats=1)
    assert isinstance(alpha, float)
    assert alpha >= 0.0


def test_decode_mode_validated():
    with pytest.raises(ValueError, match="decode_mode"):
        ModelServingBackend(get_smoke_config("llama3.2-1b"), seed=0,
                            decode_mode="magic")
