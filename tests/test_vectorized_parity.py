"""Statistical parity: the vectorized fast path vs the event engine
(DESIGN.md §11; sim/vectorized.py).

The jitted scan is a *fast path for parameter exploration*, not a
replacement — golden digests stay on the event engine — so what it must
prove is distributional agreement on the scenarios it models: closed-loop
VU streams (single- and multi-stream; see also
tests/test_multistream_vectorized.py) and open-loop Poisson arrivals
against a capped supply (second half of this file). Both engines run the
SAME config (spec, profile, threshold, think time) on pinned seeds; the
checks are the ISSUE's bounds:

* two-sample KS on per-request analysis / latency / billed-duration
  distributions,
* gated-vs-baseline mean speedup within ±1pp,
* probe pass-rate within ±2pp,

on the gcf-gen1 / gcf-gen2 / lambda platform profiles. A skip-marked slow
variant sweeps a fuller grid.
"""
import dataclasses
import math
import os

import numpy as np
import pytest
from scipy import stats
from scipy.stats import ks_2samp

from repro.core.policy import AdaptiveMinosPolicy, MinosPolicy
from repro.sim import FaaSPlatform, FunctionSpec, PlatformProfile, VariationModel
from repro.sim.arrivals import PoissonProcess, run_open_loop
from repro.sim.vectorized import (
    arm_from_spec,
    jit_stats,
    run_event_chain,
    simulate_arms,
    simulate_open_arms,
    stack_arms,
)

# Churny config: recycle every ~8 s keeps cold probes flowing, so the
# pass-rate estimate has real sample mass on both sides.
SPEC = FunctionSpec(
    name="parity", prepare_ms=600.0, body_ms=1500.0, benchmark_ms=300.0,
    cold_start_ms=250.0, recycle_lifetime_ms=8_000.0, contention_rho=0.95,
    benchmark_noise=0.08,
)
VM = VariationModel(sigma=0.15)
THINK_MS = 500.0
N_REQUESTS = 600
EVENT_SEEDS = range(10)
VEC_SEEDS = range(20)
GATES = ("off", "fixed", "adaptive")

# analytic f=0.4 probe-duration quantile (probes are lognormal with
# log-std sqrt(sigma^2 + noise^2)); both engines judge against this number
THRESHOLD = SPEC.benchmark_ms * math.exp(
    stats.norm.ppf(0.4) * math.sqrt(VM.sigma ** 2 + SPEC.benchmark_noise ** 2))


def _profile(name: str) -> PlatformProfile:
    prof = {"gcf-gen1": PlatformProfile.gcf_gen1,
            "gcf-gen2": PlatformProfile.gcf_gen2,
            "lambda": PlatformProfile.aws_lambda}[name]()
    return dataclasses.replace(prof, recycle_lifetime_ms=8_000.0)


def _policy(gate: str):
    if gate == "off":
        return MinosPolicy(elysium_threshold=float("inf"), enabled=False)
    if gate == "fixed":
        return MinosPolicy(elysium_threshold=THRESHOLD, max_retries=5)
    return AdaptiveMinosPolicy(0.4, max_retries=5)


@pytest.fixture(scope="module")
def runs():
    """Both engines over (3 profiles × 3 gates), computed once."""
    event = {}
    for pname in ("gcf-gen1", "gcf-gen2", "lambda"):
        for gate in GATES:
            an, lat, nterm, nprobe = [], [], 0, 0
            billed_ms = cost = 0.0
            for seed in EVENT_SEEDS:
                plat = FaaSPlatform(SPEC, VM, _policy(gate), seed=seed,
                                    profile=_profile(pname))
                rs = run_event_chain(plat, N_REQUESTS, THINK_MS)
                an += [r.analysis_ms for r in rs]
                lat += [r.latency_ms for r in rs]
                nterm += plat.instances_terminated
                nprobe += len(plat.benchmark_observations)
                c = plat.cost
                billed_ms += c.d_term_ms + c.d_pass_ms + c.d_reuse_ms
                cost += c.total
            n_req = len(list(EVENT_SEEDS)) * N_REQUESTS
            event[(pname, gate)] = {
                "analysis": np.asarray(an), "latency": np.asarray(lat),
                "pass_rate": 1.0 - nterm / max(nprobe, 1),
                "billed_mean": billed_ms / n_req,
                "cost_per_req": cost / n_req,
            }
    arms, keys = [], []
    for pname in ("gcf-gen1", "gcf-gen2", "lambda"):
        for gate in GATES:
            arms.append(arm_from_spec(
                SPEC, VM, profile=_profile(pname), gate=gate,
                threshold=THRESHOLD, pass_fraction=0.4,
                think_time_ms=THINK_MS))
            keys.append((pname, gate))
    res = simulate_arms(stack_arms(arms), seeds=VEC_SEEDS,
                        n_steps=N_REQUESTS, collect_requests=True)
    vec = {}
    for i, key in enumerate(keys):
        vec[key] = {
            "analysis": res.requests["analysis_ms"][i].ravel(),
            "latency": res.requests["latency_ms"][i].ravel(),
            "billed": res.requests["billed_ms"][i].ravel(),
            "pass_rate": float(res.summary["pass_rate"][i].mean()),
            "cost_per_req": float(res.summary["cost"][i].mean()) / N_REQUESTS,
        }
    return event, vec


PROFILES = ("gcf-gen1", "gcf-gen2", "lambda")


@pytest.mark.parametrize("pname", PROFILES)
@pytest.mark.parametrize("gate", GATES)
def test_ks_duration_distributions(runs, pname, gate):
    """Per-request analysis & latency distributions agree (two-sample KS).

    The bound is on the KS *statistic* D, not its p-value: requests within
    one run are autocorrelated (a warm chain shares its instance's drifted
    speed), so the iid p-value is wildly anti-conservative — across seed
    partitions of a single engine D itself fluctuates in ~[0.01, 0.04].
    D < 0.05 holds for matching models and fails decisively for real
    modeling errors (e.g. mis-billed cold starts shift D by >0.1). Pinned
    seeds make the check deterministic."""
    event, vec = runs
    for field in ("analysis", "latency"):
        ks = ks_2samp(event[(pname, gate)][field], vec[(pname, gate)][field])
        assert ks.statistic < 0.05, (pname, gate, field, ks)


@pytest.mark.parametrize("pname", PROFILES)
@pytest.mark.parametrize("gate", GATES)
def test_billed_duration_and_cost(runs, pname, gate):
    """Fig-3 billing agrees: terminations billed startup+probe, passes
    cold(+)ready+body, reuses duration only. The event engine exposes
    billing as per-run WorkflowCost totals (not per-request), so the
    cross-engine check is on mean billed ms per request and mean $ per
    request; per-request coherence (billed never exceeds latency — the
    requeue overhead is unbilled wait) is asserted on the vec stream."""
    event, vec = runs
    ev, v = event[(pname, gate)], vec[(pname, gate)]
    assert np.all(v["billed"] <= v["latency"] + 1e-3)
    vec_billed_mean = float(v["billed"].mean())
    assert vec_billed_mean == pytest.approx(ev["billed_mean"], rel=0.02), \
        (pname, gate, ev["billed_mean"], vec_billed_mean)
    assert v["cost_per_req"] == pytest.approx(
        ev["cost_per_req"], rel=0.02), (pname, gate)


@pytest.mark.parametrize("pname", PROFILES)
@pytest.mark.parametrize("gate", ("fixed", "adaptive"))
def test_pass_rate_within_2pp(runs, pname, gate):
    event, vec = runs
    d = abs(event[(pname, gate)]["pass_rate"] - vec[(pname, gate)]["pass_rate"])
    assert d < 0.02, (pname, gate, event[(pname, gate)]["pass_rate"],
                      vec[(pname, gate)]["pass_rate"])


@pytest.mark.parametrize("pname", PROFILES)
@pytest.mark.parametrize("gate", ("fixed", "adaptive"))
def test_mean_speedup_within_1pp(runs, pname, gate):
    """Gated-vs-baseline analysis improvement matches across engines."""
    event, vec = runs
    imp_ev = 1.0 - (event[(pname, gate)]["analysis"].mean()
                    / event[(pname, "off")]["analysis"].mean())
    imp_vec = 1.0 - (vec[(pname, gate)]["analysis"].mean()
                     / vec[(pname, "off")]["analysis"].mean())
    assert abs(imp_ev - imp_vec) < 0.01, (pname, gate, imp_ev, imp_vec)


def test_jit_cache_hits_on_same_shape(runs):
    """A second batch with identical static shape must not recompile."""
    arms = stack_arms([
        arm_from_spec(SPEC, VM, profile=_profile("gcf-gen1"), gate=g,
                      threshold=THRESHOLD, think_time_ms=THINK_MS)
        for g in GATES])
    simulate_arms(arms, seeds=range(2), n_steps=50)
    before = jit_stats["compiles"]
    simulate_arms(arms, seeds=range(2), n_steps=50)
    assert jit_stats["compiles"] == before


def test_seeded_determinism(runs):
    """Identical (arms, seeds) produce bit-identical summaries."""
    arms = stack_arms([
        arm_from_spec(SPEC, VM, profile=_profile("gcf-gen1"), gate="fixed",
                      threshold=THRESHOLD, think_time_ms=THINK_MS)])
    a = simulate_arms(arms, seeds=[7], n_steps=80)
    b = simulate_arms(arms, seeds=[7], n_steps=80)
    for k in a.summary:
        np.testing.assert_array_equal(a.summary[k], b.summary[k])


# ---------------------------------------------------------------------------
# Open-loop parity: both engines consume Poisson arrivals at the same offered
# rate against the same K-instance supply cap and must agree on the resulting
# latency (wait + service) distribution — i.e. the queueing physics, not just
# the per-request service model, matches.
#
# Model note (DESIGN.md §12): a failed probe frees its server slot at judge
# time in BOTH engines. The vec scan parks the gated request in a retry ring
# (ready at probe_end + requeue overhead) and drains up to
# `drains_per_step` matured retries before each arrival's own dispatch, so
# retries keep their FIFO priority over later arrivals exactly as the event
# queue's (enqueued_at, seq) ordering grants it. At the default drain budget
# the measured gated P99 gap is < 1% (the earlier atomic-retry-chain model,
# which held the slot through the whole crash chain, sat at ~5–12%), so one
# 5% P99 bound applies to every cell. Scan rows are (drains..., arrival) per
# step; only rows flagged `completed` carry a finished request — consumers
# MUST mask, the rest is ring padding / drops / defers.
# ---------------------------------------------------------------------------

OPEN_RATE_PER_S = 0.9     # offered load; with K=4 and ~2.1 s service, rho≈0.55
OPEN_SERVERS = 4
OPEN_DURATION_MS = 400_000.0
OPEN_STEPS = 360          # ≈ rate × duration arrivals per vec seed
OPEN_EVENT_SEEDS = range(8)
OPEN_VEC_SEEDS = range(16)
OPEN_PROFILES = ("gcf-gen1", "lambda")
OPEN_GATES = ("off", "fixed")


@pytest.fixture(scope="module")
def open_runs():
    """Both engines over (2 profiles × 2 gates) open-loop, computed once.

    All four vec arms stack into ONE simulate_open_arms call so the scan
    compiles once; the event side is 8 capped-supply runs per cell."""
    event = {}
    for pname in OPEN_PROFILES:
        for gate in OPEN_GATES:
            lat, nterm, nprobe, n_req = [], 0, 0, 0
            billed_ms = 0.0
            for seed in OPEN_EVENT_SEEDS:
                prof = _profile(pname)
                knobs = dataclasses.replace(
                    prof.knobs(), max_instances=OPEN_SERVERS)
                plat = FaaSPlatform(SPEC, VM, _policy(gate), seed=seed,
                                    profile=prof, knobs=knobs)
                run = run_open_loop(
                    plat, PoissonProcess(OPEN_RATE_PER_S),
                    rng=np.random.RandomState(1000 + seed),
                    duration_ms=OPEN_DURATION_MS)
                # nothing is ever lost at rho≈0.55 with an uncapped queue
                assert run.n_arrived == (run.n_completed + run.n_dropped
                                         + run.n_pending_at_end)
                assert run.n_dropped == 0 and run.n_pending_at_end == 0
                lat += [r.latency_ms for r in run.results]
                nterm += plat.instances_terminated
                nprobe += len(plat.benchmark_observations)
                c = plat.cost
                billed_ms += c.d_term_ms + c.d_pass_ms + c.d_reuse_ms
                n_req += run.n_completed
            event[(pname, gate)] = {
                "latency": np.asarray(lat),
                "pass_rate": 1.0 - nterm / max(nprobe, 1),
                "billed_mean": billed_ms / n_req,
            }
    arms, keys = [], []
    for pname in OPEN_PROFILES:
        for gate in OPEN_GATES:
            arms.append(arm_from_spec(
                SPEC, VM, profile=_profile(pname), gate=gate,
                threshold=THRESHOLD, think_time_ms=0.0))
            keys.append((pname, gate))
    proc = PoissonProcess(OPEN_RATE_PER_S)
    iats = np.stack([proc.iats_ms(np.random.RandomState(5000 + i), OPEN_STEPS)
                     for i in OPEN_VEC_SEEDS])
    res = simulate_open_arms(stack_arms(arms), seeds=OPEN_VEC_SEEDS,
                             iats_ms=iats, n_servers=OPEN_SERVERS,
                             collect_requests=True)
    vec = {}
    for i, key in enumerate(keys):
        # in-scan conservation, per seed and exact: every arrival either
        # completed, dropped, or is still parked when the horizon ends
        np.testing.assert_array_equal(
            np.asarray(res.summary["n_requests"][i]),
            np.asarray(res.summary["n_completed"][i])
            + np.asarray(res.summary["n_dropped"][i])
            + np.asarray(res.summary["n_parked_end"][i]))
        comp = np.asarray(res.requests["completed"][i]).astype(bool)
        vec[key] = {
            "latency": np.asarray(res.requests["latency_ms"][i])[comp],
            "billed": np.asarray(res.requests["billed_ms"][i])[comp],
            "wait": np.asarray(res.requests["wait_ms"][i])[comp],
            "pass_rate": float(res.summary["pass_rate"][i].mean()),
        }
    return event, vec


@pytest.mark.parametrize("pname", OPEN_PROFILES)
@pytest.mark.parametrize("gate", OPEN_GATES)
def test_open_loop_ks_latency(open_runs, pname, gate):
    """End-to-end latency (wait + service) distributions agree.

    Same D-statistic bound rationale as test_ks_duration_distributions;
    measured D at these pinned seeds is 0.016–0.046."""
    event, vec = open_runs
    ks = ks_2samp(event[(pname, gate)]["latency"], vec[(pname, gate)]["latency"])
    assert ks.statistic < 0.06, (pname, gate, ks)


@pytest.mark.parametrize("pname", OPEN_PROFILES)
@pytest.mark.parametrize("gate", OPEN_GATES)
def test_open_loop_p99(open_runs, pname, gate):
    """Tail latency agrees within 5% on every cell, gated included: the
    retry-as-park drain model gives failed probes the same slot-release
    and FIFO-priority semantics as the event queue (header note above).
    Measured gaps at these pinned seeds are 0.4–4.3%."""
    event, vec = open_runs
    p99_ev = float(np.percentile(event[(pname, gate)]["latency"], 99))
    p99_v = float(np.percentile(vec[(pname, gate)]["latency"], 99))
    assert abs(p99_v - p99_ev) / p99_ev < 0.05, (pname, gate, p99_ev, p99_v)


@pytest.mark.parametrize("pname", OPEN_PROFILES)
@pytest.mark.parametrize("gate", OPEN_GATES)
def test_open_loop_billing(open_runs, pname, gate):
    """Mean billed ms per request agrees; waits are never billed."""
    event, vec = open_runs
    ev, v = event[(pname, gate)], vec[(pname, gate)]
    assert float(v["billed"].mean()) == pytest.approx(
        ev["billed_mean"], rel=0.03), (pname, gate)
    # billed covers service only: strictly less than latency whenever the
    # request waited for a slot
    waited = v["wait"] > 1e-6
    assert np.all(v["billed"][waited] < v["latency"][waited])


@pytest.mark.parametrize("pname", OPEN_PROFILES)
def test_open_loop_pass_rate_within_2pp(open_runs, pname):
    event, vec = open_runs
    d = abs(event[(pname, "fixed")]["pass_rate"]
            - vec[(pname, "fixed")]["pass_rate"])
    assert d < 0.02, (pname, event[(pname, "fixed")]["pass_rate"],
                      vec[(pname, "fixed")]["pass_rate"])


def test_open_loop_jit_cache_and_determinism(open_runs):
    """Same (arms, seeds, iats shape): no recompile, bit-identical output."""
    arms = stack_arms([arm_from_spec(
        SPEC, VM, profile=_profile("gcf-gen1"), gate="fixed",
        threshold=THRESHOLD)])
    iats = PoissonProcess(2.0).iats_ms(np.random.RandomState(3), 40)
    a = simulate_open_arms(arms, seeds=[5], iats_ms=iats, n_servers=2)
    before = jit_stats["compiles"]
    b = simulate_open_arms(arms, seeds=[5], iats_ms=iats, n_servers=2)
    assert jit_stats["compiles"] == before
    for k in a.summary:
        np.testing.assert_array_equal(a.summary[k], b.summary[k])


@pytest.mark.slow
@pytest.mark.skipif(not os.environ.get("RUN_SLOW_GRID"),
                    reason="full-grid parity sweep; set RUN_SLOW_GRID=1")
def test_full_grid_parity_slow():
    """Pass-fraction × σ grid: vec pass rates track the analytic lognormal
    quantile target and the event engine across the full grid."""
    fracs = np.linspace(0.15, 0.85, 8)
    sigmas = (0.08, 0.15, 0.22)
    arms, metas = [], []
    for s in sigmas:
        vm = VariationModel(sigma=float(s))
        for f in fracs:
            thr = SPEC.benchmark_ms * math.exp(
                stats.norm.ppf(float(f))
                * math.sqrt(s ** 2 + SPEC.benchmark_noise ** 2))
            arms.append(arm_from_spec(
                SPEC, vm, profile=_profile("gcf-gen1"), gate="fixed",
                threshold=thr, think_time_ms=THINK_MS))
            metas.append((float(s), float(f), thr))
    res = simulate_arms(stack_arms(arms), seeds=range(8), n_steps=1200)
    rates = res.mean_over_seeds("pass_rate")
    for (s, f, thr), got in zip(metas, rates):
        assert abs(got - f) < 0.04, (s, f, got)
    # spot-check three cells against the event engine
    for i in (0, len(metas) // 2, len(metas) - 1):
        s, f, thr = metas[i]
        nterm = nprobe = 0
        for seed in range(4):
            plat = FaaSPlatform(
                SPEC, VariationModel(sigma=s),
                MinosPolicy(elysium_threshold=thr, max_retries=5),
                seed=seed, profile=_profile("gcf-gen1"))
            run_event_chain(plat, 600, THINK_MS)
            nterm += plat.instances_terminated
            nprobe += len(plat.benchmark_observations)
        assert abs((1 - nterm / nprobe) - rates[i]) < 0.02, (s, f)
