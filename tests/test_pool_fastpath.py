"""InstancePool hot-path aggregates (PR 5).

The pool keeps incremental aggregates — ``total_in_flight`` /
``n_instances`` / ``mean_load`` counters, a min-load heap for
``order="spread"``, the ``_next_deadline`` take fast path, and the cached
``speeds_view`` — that must stay *equal* to the O(n) scans they replaced.
These tests drive random (but seeded) engine-shaped operation sequences
through a pool and compare every aggregate against the direct recompute
after each operation; hypothesis widens the sequence space when the dev
extra is installed.
"""
import numpy as np
import pytest

try:
    import hypothesis
    import hypothesis.strategies as st
except ImportError:  # pragma: no cover - dev extra absent
    from _hypothesis_stub import hypothesis, st

from repro.core.lifecycle import FunctionInstance, InstanceState
from repro.core.substrate import InstancePool


def _assert_aggregates_match(pool: InstancePool) -> None:
    """Every incremental aggregate equals its O(n) reference scan."""
    ref_in_flight = sum(pool._active.values())
    ids = {i.instance_id for i in pool.available}
    ids.update(pool._active)
    ref_speeds = [i.speed_factor for i in pool.available
                  if i.state is InstanceState.WARM]
    assert pool.total_in_flight == ref_in_flight
    assert pool.n_instances == len(ids)
    assert pool.speeds == ref_speeds
    assert tuple(ref_speeds) == pool.speeds_view()
    ref_mean = 1.0 if not ids else max(1.0, ref_in_flight / len(ids))
    assert pool.mean_load() == pytest.approx(ref_mean)
    # every pooled instance is WARM and registered
    for inst in pool.available:
        assert inst.state is InstanceState.WARM
        assert inst.instance_id in pool._avail_seq


def _spread_reference(pool: InstancePool):
    """The original O(n) argmin: least loaded, first list position wins."""
    if not pool.available:
        return None
    idx = min(range(len(pool.available)),
              key=lambda i: pool._active.get(
                  pool.available[i].instance_id, 0))
    return pool.available[idx]


def _drive(pool: InstancePool, ops, *, check_spread: bool = False) -> None:
    """Replay an engine-shaped op sequence: dispatch (warm take | cold
    start | gate termination), release, retire-at-load<=1, time advance."""
    now = 0.0
    counts: dict[int, int] = {}           # instance_id -> our in-flight view
    by_id: dict[int, FunctionInstance] = {}
    for code, x in ops:
        if code == 0:  # dispatch
            if check_spread and pool.order == "spread":
                pool._sweep(now)  # pin membership, then compare choices
                expect = _spread_reference(pool)
                got = pool.take(now)
                assert got is expect
            else:
                got = pool.take(now)
            if got is None:
                inst = FunctionInstance(
                    speed_factor=0.5 + x, created_at_ms=now,
                    idle_timeout_ms=60.0)
                pool.admit_cold(inst, now)
                if x < 0.25:  # gate-terminated cold start
                    inst.state = InstanceState.TERMINATED
                    pool.drop(inst)
                else:
                    inst.accept_without_benchmark()
                    counts[inst.instance_id] = 1
                    by_id[inst.instance_id] = inst
            else:
                counts[got.instance_id] = counts.get(got.instance_id, 0) + 1
                by_id[got.instance_id] = got
        elif code == 1 and counts:  # one request completes
            iid = sorted(counts)[int(x * len(counts)) % len(counts)]
            inst = by_id[iid]
            if inst.state is InstanceState.WARM:
                inst.serve(now)
            pool.release(inst, now)
            counts[iid] -= 1
            if counts[iid] <= 0:
                del counts[iid]
        elif code == 2:  # controller retirement (only ever at load <= 1)
            cands = [i for i in pool.available if pool.load(i) <= 1]
            if cands:
                inst = cands[int(x * len(cands)) % len(cands)]
                had = pool.load(inst)
                inst.state = InstanceState.EXPIRED
                pool.retire(inst)
                counts.pop(inst.instance_id, None)
                assert pool.load(inst) == 0 and had <= 1
        else:  # time passes (idle/recycle deadlines approach)
            now += x * 45.0
        _assert_aggregates_match(pool)


def _random_ops(seed: int, n: int = 300):
    rng = np.random.RandomState(seed)
    return [(int(rng.randint(4)), float(rng.uniform())) for _ in range(n)]


@pytest.mark.parametrize("order", ["lifo", "fifo", "spread"])
@pytest.mark.parametrize("concurrency", [1, 3])
def test_aggregates_equal_reference_scans_seeded(order, concurrency):
    for seed in range(4):
        rng = np.random.RandomState(1000 + seed)
        pool = InstancePool(order=order, concurrency=concurrency,
                            recycle_lifetime_ms=200.0, rng=rng)
        _drive(pool, _random_ops(seed), check_spread=True)


@hypothesis.given(
    ops=st.lists(st.tuples(st.integers(min_value=0, max_value=3),
                           st.floats(min_value=0.0, max_value=1.0)),
                 max_size=120),
    order=st.sampled_from(["lifo", "fifo", "spread"]),
    concurrency=st.integers(min_value=1, max_value=4),
)
@hypothesis.settings(deadline=None, max_examples=60)
def test_aggregates_equal_reference_scans_property(ops, order, concurrency):
    pool = InstancePool(order=order, concurrency=concurrency,
                        recycle_lifetime_ms=150.0,
                        rng=np.random.RandomState(0))
    _drive(pool, ops, check_spread=True)


def test_take_skips_sweep_until_a_deadline_passes():
    """The take fast path: while no pooled idle instance can have reached
    its idle/recycle deadline, take must not rebuild ``available``."""
    pool = InstancePool(order="fifo", concurrency=2)
    sweeps = 0
    orig = pool._sweep

    def counting_sweep(now):
        nonlocal sweeps
        sweeps += 1
        orig(now)

    pool._sweep = counting_sweep
    for s in (1.0, 2.0):
        inst = FunctionInstance(speed_factor=s, created_at_ms=0.0,
                                idle_timeout_ms=1000.0)
        inst.accept_without_benchmark()
        pool.add_warm(inst)
    for t in (10.0, 20.0, 30.0):  # far below the idle deadline
        got = pool.take(t)
        assert got is not None
        got.serve(t)
        pool.release(got, t)
    assert sweeps == 0
    # past the idle deadline the sweep must run and reclaim
    assert pool.take(5000.0) is None
    assert sweeps == 1
    assert len(pool) == 0


def test_speeds_view_is_cached_and_invalidated():
    pool = InstancePool()
    inst = FunctionInstance(speed_factor=1.5, created_at_ms=0.0)
    inst.accept_without_benchmark()
    pool.add_warm(inst)
    v1 = pool.speeds_view()
    assert v1 == (1.5,)
    assert pool.speeds_view() is v1          # cached: same object, no rebuild
    taken = pool.take(0.0)
    assert taken is inst
    assert pool.speeds_view() == ()           # take invalidated the cache
    # drift-on-reuse happens after take, so the post-take rebuild sees it
    inst.speed_factor = 2.0
    inst.serve(1.0)
    pool.release(inst, 1.0)
    assert pool.speeds_view() == (2.0,)
    # the mutable compat copy cannot corrupt the cache
    pool.speeds.append(99.0)
    assert pool.speeds_view() == (2.0,)
    assert pool.n_warm == 1
    assert pool.certified_speed_quantile(0.5) == pytest.approx(2.0)


def test_add_warm_at_capacity_stays_out_of_available():
    pool = InstancePool(concurrency=2)
    inst = FunctionInstance(speed_factor=1.0, created_at_ms=0.0)
    inst.accept_without_benchmark()
    pool.add_warm(inst, in_flight=2)
    assert len(pool) == 0
    assert pool.total_in_flight == 2
    assert pool.n_instances == 1
    pool.release(inst, 0.0)                   # one slot frees: available again
    assert len(pool) == 1
    assert pool.take(0.0) is inst


def test_spread_heap_stays_bounded_under_take_release_cycles():
    """Regression: repeated take/release on a concurrency>=2 spread pool
    must not accumulate equally-valid duplicate heap entries (only an
    instance's latest push is valid, older twins pop lazily)."""
    pool = InstancePool(order="spread", concurrency=2)
    inst = FunctionInstance(speed_factor=1.0, created_at_ms=0.0,
                            idle_timeout_ms=1e12)
    inst.accept_without_benchmark()
    pool.add_warm(inst)
    for t in range(2000):
        got = pool.take(float(t))
        assert got is inst
        got.serve(float(t))
        pool.release(got, float(t))
    assert len(pool._spread_heap) < 50, len(pool._spread_heap)
    assert len(pool._spread_latest) == 1
