"""Workflow DAG engine: structure validation, scheduling invariants
(fan-in barrier, per-stage retry bounds), platform profiles, and the
paper's §V compounding claim on the ETL suite."""
import numpy as np
import pytest

from repro.core.cost import Pricing
from repro.core.policy import MinosPolicy
from repro.sim import (
    FaaSPlatform,
    FunctionSpec,
    PlatformProfile,
    Stage,
    VariationModel,
    WorkflowDAG,
    WorkflowEngine,
    etl_chain,
    etl_suite,
    improvement,
    run_workflow_batch,
    run_workflow_closed_loop,
    workflow_arm_factory,
)

PRICING = Pricing.gcf(256)


def _det_spec(name, prepare_ms=100.0, body_ms=400.0, **kw):
    """Fully deterministic stage spec (no jitter, no noise, no churn)."""
    base = dict(
        name=name, prepare_ms=prepare_ms, prepare_jitter=0.0,
        body_ms=body_ms, body_jitter=0.0, benchmark_ms=50.0,
        benchmark_noise=0.0, cold_start_ms=20.0, cold_start_jitter=0.0,
        recycle_lifetime_ms=None, contention_rho=1.0,
    )
    base.update(kw)
    return FunctionSpec(**base)


def _disabled(stage):
    return MinosPolicy(elysium_threshold=float("inf"), enabled=False)


# ---------------------------------------------------------------------------
# DAG structure
# ---------------------------------------------------------------------------


def test_dag_rejects_cycle():
    with pytest.raises(ValueError, match="cycle"):
        WorkflowDAG([
            Stage(_det_spec("a"), deps=("b",)),
            Stage(_det_spec("b"), deps=("a",)),
        ])


def test_dag_rejects_unknown_dep():
    with pytest.raises(ValueError, match="unknown stage"):
        WorkflowDAG([Stage(_det_spec("a"), deps=("nope",))])


def test_dag_rejects_duplicate_names():
    with pytest.raises(ValueError, match="duplicate"):
        WorkflowDAG([Stage(_det_spec("a")), Stage(_det_spec("a"))])


def test_topo_order_respects_deps():
    dag = etl_suite()["etl-7"]
    pos = {n: i for i, n in enumerate(dag.order)}
    for name, stage in dag.stages.items():
        for d in stage.deps:
            assert pos[d] < pos[name]
    assert set(dag.order) == set(dag.stages)


def test_chain_builder():
    dag = etl_chain(5)
    assert len(dag) == 5
    assert dag.sources == (dag.order[0],)
    assert dag.sinks == (dag.order[-1],)
    # each non-source stage depends on exactly the previous stage
    for prev, cur in zip(dag.order, dag.order[1:]):
        assert dag.stages[cur].deps == (prev,)


def test_etl_suite_shapes():
    suite = etl_suite()
    assert [len(suite[k]) for k in ("etl-3", "etl-5", "etl-7")] == [3, 5, 7]
    # the 5- and 7-stage DAGs actually fan out (some stage has 2+ children)
    for key in ("etl-5", "etl-7"):
        dag = suite[key]
        assert max(len(c) for c in dag.children.values()) >= 2


# ---------------------------------------------------------------------------
# Scheduling invariants
# ---------------------------------------------------------------------------


def test_fan_in_waits_for_all_parents():
    """The join stage must not start until BOTH parents completed — with a
    deterministic spec the join's submit time equals the slow parent's
    completion time exactly."""
    dag = WorkflowDAG([
        Stage(_det_spec("src", body_ms=100.0)),
        Stage(_det_spec("fast", body_ms=300.0), deps=("src",)),
        Stage(_det_spec("slow", body_ms=2500.0), deps=("src",)),
        Stage(_det_spec("join", body_ms=100.0), deps=("fast", "slow")),
    ])
    engine = WorkflowEngine(
        dag, VariationModel(sigma=0.0), _disabled, pricing=PRICING, seed=0)
    run = run_workflow_batch(engine, n_items=3, inter_arrival_ms=10_000.0)
    assert run.n_items == 3
    for item in run.items:
        fast = item.stage_results["fast"]
        slow = item.stage_results["slow"]
        join = item.stage_results["join"]
        assert slow.t_completed_ms > fast.t_completed_ms
        assert join.t_submitted_ms == pytest.approx(
            max(fast.t_completed_ms, slow.t_completed_ms))


def test_sink_completion_requires_all_sinks():
    """An item is complete only when every sink finished (multi-sink DAG)."""
    dag = WorkflowDAG([
        Stage(_det_spec("src")),
        Stage(_det_spec("sink_a", body_ms=200.0), deps=("src",)),
        Stage(_det_spec("sink_b", body_ms=3000.0), deps=("src",)),
    ])
    engine = WorkflowEngine(
        dag, VariationModel(sigma=0.0), _disabled, pricing=PRICING, seed=0)
    run = run_workflow_batch(engine, n_items=2, inter_arrival_ms=10_000.0)
    for item in run.items:
        assert item.t_completed_ms == pytest.approx(
            max(r.t_completed_ms for r in item.stage_results.values()))


def test_per_stage_max_retries_respected():
    """With an impossible threshold every instance fails; each stage's
    emergency exit must trigger at ITS OWN bound."""
    # short idle timeout: the forced-pass survivor of one item must be gone
    # before the next item arrives, so every item pays the full retry chain
    dag = WorkflowDAG([
        Stage(_det_spec("first", idle_timeout_ms=10_000.0), max_retries=2),
        Stage(_det_spec("second", idle_timeout_ms=10_000.0), deps=("first",),
              max_retries=4),
    ])

    def impossible(stage):
        mr = stage.max_retries
        return MinosPolicy(elysium_threshold=1e-9, max_retries=mr)

    engine = WorkflowEngine(
        dag, VariationModel(sigma=0.1), impossible, pricing=PRICING, seed=1)
    run = run_workflow_batch(engine, n_items=4, inter_arrival_ms=60_000.0)
    assert run.n_items == 4  # at-least-once: nothing lost
    for item in run.items:
        assert item.stage_results["first"].retries == 2
        assert item.stage_results["second"].retries == 4


def test_requests_flow_through_chain_exactly_once():
    dag = etl_chain(3)
    engine = WorkflowEngine(
        dag, VariationModel(sigma=0.1), _disabled, pricing=PRICING, seed=2)
    run = run_workflow_batch(engine, n_items=20, inter_arrival_ms=300.0)
    assert run.n_items == 20
    per_stage = engine.per_stage_results()
    for name in dag.order:
        assert len(per_stage[name]) == 20
    # merged cost counts one successful execution per stage per item
    assert run.cost.n_successful == 20 * len(dag)


# ---------------------------------------------------------------------------
# Platform profiles
# ---------------------------------------------------------------------------


def test_profile_validation():
    with pytest.raises(ValueError, match="warm_pool_order"):
        PlatformProfile(name="x", pricing=PRICING, warm_pool_order="random")
    with pytest.raises(ValueError, match="concurrency"):
        PlatformProfile(name="x", pricing=PRICING, per_instance_concurrency=0)
    with pytest.raises(ValueError):
        FaaSPlatform(_det_spec("f"), VariationModel(), MinosPolicy(1.0))


def test_profile_presets_distinct():
    g1, g2, lam = (PlatformProfile.gcf_gen1(), PlatformProfile.gcf_gen2(),
                   PlatformProfile.aws_lambda())
    assert g1.per_instance_concurrency == 1 and g2.per_instance_concurrency > 1
    assert g1.bill_cold_start and not g2.bill_cold_start and not lam.bill_cold_start
    assert {g1.warm_pool_order, g2.warm_pool_order} == {"lifo", "fifo"}
    assert lam.pricing.name.startswith("lambda")


def test_per_instance_concurrency_shares_instances():
    """Two simultaneous requests: a concurrency-2 instance serves both (one
    cold start total); a concurrency-1 platform must start a second."""
    spec = _det_spec("f", body_ms=1000.0)
    results = {}
    for conc in (1, 2):
        prof = PlatformProfile(
            name=f"c{conc}", pricing=PRICING, per_instance_concurrency=conc,
            cold_start_ms=20.0, cold_start_jitter=0.0, recycle_lifetime_ms=None)
        plat = FaaSPlatform(
            spec, VariationModel(sigma=0.0),
            MinosPolicy(elysium_threshold=0.0, enabled=False), profile=prof, seed=0)
        plat.submit({"i": 0}, lambda r: None)   # form one warm instance
        plat.loop.run_all(hard_limit_ms=1e9)
        plat.submit({"i": 1}, lambda r: None)   # two concurrent requests
        plat.submit({"i": 2}, lambda r: None)
        plat.loop.run_all(hard_limit_ms=1e9)
        results[conc] = plat.instances_started
    assert results[2] == 1
    assert results[1] == 2


def test_warm_pool_order_lifo_vs_fifo():
    """LIFO reuses the most recently used instance, FIFO the oldest."""
    spec = _det_spec("f", body_ms=500.0)
    picked = {}
    for order in ("lifo", "fifo"):
        prof = PlatformProfile(
            name=order, pricing=PRICING, warm_pool_order=order,
            cold_start_ms=20.0, cold_start_jitter=0.0, recycle_lifetime_ms=None)
        plat = FaaSPlatform(
            spec, VariationModel(sigma=0.3),
            MinosPolicy(elysium_threshold=0.0, enabled=False), profile=prof, seed=7)
        plat.submit({"i": 0}, lambda r: None)   # two concurrent cold starts
        plat.submit({"i": 1}, lambda r: None)
        plat.loop.run_all(hard_limit_ms=1e9)
        pool_speeds = [i.speed_factor for i in plat.warm_pool]
        assert len(pool_speeds) == 2
        got = []
        plat.submit({"i": 2}, lambda r: got.append(r))
        plat.loop.run_all(hard_limit_ms=1e9)
        picked[order] = (pool_speeds, got[0].instance_speed)
    lifo_pool, lifo_speed = picked["lifo"]
    fifo_pool, fifo_speed = picked["fifo"]
    assert lifo_speed == pytest.approx(lifo_pool[-1])
    assert fifo_speed == pytest.approx(fifo_pool[0])


# ---------------------------------------------------------------------------
# The §V claim, end to end
# ---------------------------------------------------------------------------


def test_minos_workflow_beats_baseline_end_to_end():
    """5-stage ETL on GCF gen1: the fixed-threshold arm completes items
    faster than the unguarded baseline (the benchmark sweep checks the full
    monotone curve; this is the cheap smoke version)."""
    vm = VariationModel(sigma=0.18)
    prof = PlatformProfile.gcf_gen1()
    dag = etl_chain(5)
    lat = {}
    for arm in ("disabled", "fixed"):
        engine = WorkflowEngine(
            dag, vm, workflow_arm_factory(arm, vm), profile=prof, seed=42)
        run = run_workflow_closed_loop(engine, n_vus=10, duration_ms=8 * 60 * 1000.0)
        assert run.n_items > 100
        # cost denominator counts drained completions too (cost ledgers
        # accrue through the drain)
        assert run.n_items_costed >= run.n_items
        lat[arm] = run.mean_item_latency_ms
    assert improvement(lat["disabled"], lat["fixed"]) > 0.01
