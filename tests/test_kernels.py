"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.matmul_probe import matmul


def _rand(shape, dtype, seed):
    x = np.random.RandomState(seed).randn(*shape)
    return jnp.asarray(x, dtype)


TOL = {jnp.float32: 2e-3, jnp.bfloat16: 5e-2}


@pytest.mark.parametrize("m,k,n", [(128, 512, 128), (256, 1024, 256), (128, 128, 384)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_shapes(m, k, n, dtype):
    a, b = _rand((m, k), dtype, 0), _rand((k, n), dtype, 1)
    out = matmul(a, b, interpret=True)
    want = ref.matmul_ref(a, b)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        rtol=TOL[dtype], atol=TOL[dtype] * 10,
    )


def test_matmul_padding_path():
    """ops.matmul pads ragged shapes up to block multiples."""
    a, b = _rand((100, 300), jnp.float32, 2), _rand((300, 77), jnp.float32, 3)
    out = ops.matmul(a, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(a) @ np.asarray(b),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("batch,qh,kvh,seq,d", [
    (1, 4, 4, 128, 64),     # MHA
    (2, 8, 2, 256, 64),     # GQA 4:1
    (2, 4, 1, 128, 128),    # MQA
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(batch, qh, kvh, seq, d, causal):
    q = _rand((batch, qh, seq, d), jnp.float32, 0)
    k = _rand((batch, kvh, seq, d), jnp.float32, 1)
    v = _rand((batch, kvh, seq, d), jnp.float32, 2)
    out = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64, interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-3, atol=2e-3)


def test_flash_attention_bf16():
    q = _rand((1, 4, 128, 64), jnp.bfloat16, 0)
    k = _rand((1, 2, 128, 64), jnp.bfloat16, 1)
    v = _rand((1, 2, 128, 64), jnp.bfloat16, 2)
    out = flash_attention(q, k, v, causal=True, interpret=True)
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(want, np.float32),
                               rtol=5e-2, atol=5e-2)


@pytest.mark.parametrize("batch,qh,kvh,S,d,block_k", [
    (2, 4, 2, 512, 64, 256),
    (1, 8, 8, 1024, 128, 128),
    (3, 4, 1, 256, 64, 64),
])
def test_decode_attention_sweep(batch, qh, kvh, S, d, block_k):
    q = _rand((batch, qh, 1, d), jnp.float32, 0)
    kc = _rand((batch, kvh, S, d), jnp.float32, 1)
    vc = _rand((batch, kvh, S, d), jnp.float32, 2)
    lengths = jnp.asarray(
        np.random.RandomState(3).randint(1, S + 1, size=batch), jnp.int32
    )
    out = decode_attention(q, kc, vc, lengths, block_k=block_k, interpret=True)
    want = ref.decode_attention_ref(q, kc, vc, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-3, atol=2e-3)


def test_decode_attention_skips_empty_blocks():
    """length=1: only the first block contributes; result equals attending
    to position 0 only."""
    q = _rand((1, 2, 1, 64), jnp.float32, 0)
    kc = _rand((1, 2, 512, 64), jnp.float32, 1)
    vc = _rand((1, 2, 512, 64), jnp.float32, 2)
    out = decode_attention(q, kc, vc, jnp.array([1], jnp.int32), interpret=True)
    np.testing.assert_allclose(
        np.asarray(out)[0, :, 0], np.asarray(vc)[0, :, 0], rtol=1e-4, atol=1e-4
    )


def test_ops_fallback_matches_kernel():
    """use_pallas=False (the pjit-safe path) agrees with the kernel path."""
    q = _rand((1, 4, 128, 64), jnp.float32, 0)
    k = _rand((1, 2, 128, 64), jnp.float32, 1)
    v = _rand((1, 2, 128, 64), jnp.float32, 2)
    a = ops.flash_attention(q, k, v, use_pallas=True)
    b = ops.flash_attention(q, k, v, use_pallas=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3, atol=2e-3)
