"""AdaptiveMinosPolicy (§IV online thresholds) and the P² estimator it
rests on — accuracy against np.percentile on random streams, warm-up
semantics, and full platform integration without a pre-test phase."""
import numpy as np
import pytest

from repro.core.estimators import P2Quantile
from repro.core.policy import AdaptiveMinosPolicy, MinosPolicy, Verdict
from repro.sim import (
    FaaSPlatform,
    FunctionSpec,
    PlatformProfile,
    VariationModel,
    make_arm_policy,
    run_closed_loop,
)

# ---------------------------------------------------------------------------
# P² vs np.percentile on random streams
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("p", [0.1, 0.25, 0.4, 0.5, 0.75, 0.9])
@pytest.mark.parametrize("dist,seed", [
    ("lognormal", 0), ("lognormal", 1), ("uniform", 2),
    ("exponential", 3), ("normal", 4),
])
def test_p2_matches_np_percentile(p, dist, seed):
    rs = np.random.RandomState(seed)
    n = 4000
    xs = {
        "lognormal": lambda: rs.lognormal(1.0, 0.4, n) * 50,
        "uniform": lambda: rs.uniform(10, 200, n),
        "exponential": lambda: rs.exponential(80, n) + 5,
        "normal": lambda: rs.normal(500, 60, n),
    }[dist]()
    est = P2Quantile(p)
    est.update_many(xs)
    true = float(np.percentile(xs, p * 100))
    spread = float(np.percentile(xs, 90) - np.percentile(xs, 10))
    assert abs(est.value - true) / spread < 0.03, (dist, p, est.value, true)


def test_p2_small_sample_is_exact_quantile():
    est = P2Quantile(0.4)
    for x in [30.0, 10.0, 20.0]:
        est.update(x)
    assert est.value == pytest.approx(float(np.quantile([30.0, 10.0, 20.0], 0.4)))


def test_p2_shifts_with_distribution_drift():
    rs = np.random.RandomState(5)
    est = P2Quantile(0.4)
    est.update_many(rs.uniform(100, 200, 2000))
    before = est.value
    est.update_many(rs.uniform(150, 300, 6000))
    assert est.value > before


# ---------------------------------------------------------------------------
# AdaptiveMinosPolicy unit behavior
# ---------------------------------------------------------------------------


def test_warmup_passes_everything():
    pol = AdaptiveMinosPolicy(0.4, warmup_reports=10)
    assert not pol.warmed_up
    for i in range(9):
        pol.report(100.0 + i)
        assert pol.judge(1e9, retry_count=0) is Verdict.PASS
    pol.report(200.0)
    assert pol.warmed_up
    assert pol.judge(1e9, retry_count=0) is Verdict.TERMINATE


def test_warmup_uses_initial_threshold_when_given():
    pol = AdaptiveMinosPolicy(0.4, warmup_reports=10, initial_threshold=50.0)
    # stale-pretest degraded mode: gate active from the first probe
    assert pol.judge(60.0, retry_count=0) is Verdict.TERMINATE
    assert pol.judge(40.0, retry_count=0) is Verdict.PASS


def test_adaptive_threshold_tracks_quantile():
    rs = np.random.RandomState(6)
    pol = AdaptiveMinosPolicy(0.4, warmup_reports=25, smoothing_alpha=1.0)
    xs = rs.lognormal(0.0, 0.3, 3000) * 100
    for x in xs:
        pol.report(x)
    true = float(np.quantile(xs, 0.4))
    assert abs(pol.elysium_threshold - true) / true < 0.05


def test_adaptive_higher_is_better_tracks_upper_quantile():
    """Throughput-style metric: passing the top 40% needs the 60th-
    percentile threshold, not the 40th."""
    rs = np.random.RandomState(7)
    pol = AdaptiveMinosPolicy(0.4, warmup_reports=25, smoothing_alpha=1.0,
                              higher_is_better=True)
    xs = rs.uniform(100, 200, 4000)
    for x in xs:
        pol.report(x)
    true = float(np.quantile(xs, 0.6))
    assert abs(pol.elysium_threshold - true) / true < 0.05
    assert pol.judge(true * 1.05, retry_count=0) is Verdict.PASS
    assert pol.judge(true * 0.95, retry_count=0) is Verdict.TERMINATE


def test_adaptive_emergency_exit():
    pol = AdaptiveMinosPolicy(0.4, max_retries=3, warmup_reports=5)
    for x in (1.0, 1.0, 1.0, 1.0, 1.0):
        pol.report(x)
    assert pol.judge(99.0, retry_count=3) is Verdict.FORCED_PASS
    assert not pol.should_benchmark(retry_count=3, is_cold_start=True)
    assert not pol.should_benchmark(retry_count=0, is_cold_start=False)
    assert pol.should_benchmark(retry_count=0, is_cold_start=True)


def test_make_arm_policy():
    assert not make_arm_policy("disabled").enabled
    fixed = make_arm_policy("fixed", threshold=123.0)
    assert isinstance(fixed, MinosPolicy) and fixed.elysium_threshold == 123.0
    assert isinstance(make_arm_policy("adaptive"), AdaptiveMinosPolicy)
    with pytest.raises(ValueError):
        make_arm_policy("fixed")
    with pytest.raises(ValueError):
        make_arm_policy("nope")


# ---------------------------------------------------------------------------
# Platform integration — §IV without a pre-test phase
# ---------------------------------------------------------------------------


def _spec(**kw):
    base = dict(
        name="t", prepare_ms=300.0, body_ms=600.0, benchmark_ms=100.0,
        cold_start_ms=50.0, recycle_lifetime_ms=20_000.0, contention_rho=1.0,
        benchmark_noise=0.0,
    )
    base.update(kw)
    return FunctionSpec(**base)


def test_adaptive_policy_on_platform_converges_to_oracle():
    """Running the gate with NO pre-test: after enough probe reports the
    live threshold approaches the analytic 40th-percentile probe duration
    and the selected pool is faster than the population mean."""
    vm = VariationModel(sigma=0.2)
    pol = AdaptiveMinosPolicy(0.4, max_retries=6, warmup_reports=20)
    plat = FaaSPlatform(
        _spec(), vm, pol, profile=PlatformProfile.gcf_gen1(), seed=11)
    res = run_closed_loop(plat, n_vus=8, duration_ms=8 * 60 * 1000.0)
    assert plat.instances_terminated > 0
    assert pol.controller.n_reports > 50
    oracle = 100.0 / vm.speed_quantile(0.6)  # benchmark_ms / 60th-pct speed
    assert abs(pol.elysium_threshold - oracle) / oracle < 0.15
    warm_speeds = [r.instance_speed for r in res if not r.served_by_cold]
    assert np.mean(warm_speeds) > vm.mean_speed


def test_adaptive_tracks_platform_slowdown():
    """The §IV motivation: the platform slows 30% mid-run; the adaptive
    threshold rises instead of over-terminating forever."""
    pol = AdaptiveMinosPolicy(0.4, max_retries=6, warmup_reports=15)
    plat = FaaSPlatform(
        _spec(), VariationModel(sigma=0.15), pol,
        profile=PlatformProfile.gcf_gen1(), seed=12)
    run_closed_loop(plat, n_vus=8, duration_ms=4 * 60 * 1000.0)
    thr_before = pol.elysium_threshold
    plat2 = FaaSPlatform(
        _spec(), VariationModel(sigma=0.15, day_factor=0.7), pol,
        profile=PlatformProfile.gcf_gen1(), seed=13)
    run_closed_loop(plat2, n_vus=8, duration_ms=8 * 60 * 1000.0)
    assert pol.elysium_threshold > thr_before * 1.1
