"""HLO analyzer: trip counts, dot FLOPs, collective scaling — validated on a
real compiled module (tiny model, 4 fake devices via a sub-mesh is not
possible on 1 CPU device, so we compile unsharded and check the structural
invariants instead)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.hlo import Roofline, analyze_hlo


def _compiled_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_scan_trip_count_multiplies_flops():
    """A matmul inside lax.scan over N steps must count N times the FLOPs of
    the same matmul compiled alone."""
    w = jnp.ones((64, 64), jnp.float32)
    x = jnp.ones((8, 64), jnp.float32)

    def once(x):
        return x @ w

    def scanned(x):
        def body(c, _):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, None, length=12)
        return out

    f1 = analyze_hlo(_compiled_text(once, x)).flops
    f12 = analyze_hlo(_compiled_text(scanned, x)).flops
    assert f1 > 0
    assert f12 == pytest.approx(12 * f1, rel=0.01), (f1, f12)


def test_dot_flops_exact():
    a = jnp.ones((32, 128), jnp.float32)
    b = jnp.ones((128, 16), jnp.float32)
    st = analyze_hlo(_compiled_text(lambda a, b: a @ b, a, b))
    assert st.flops == pytest.approx(2 * 32 * 16 * 128)


def test_nested_scan_multiplies():
    w = jnp.ones((32, 32), jnp.float32)

    def nested(x):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ w, None
            c, _ = jax.lax.scan(inner, c, None, length=3)
            return c, None
        c, _ = jax.lax.scan(outer, x, None, length=5)
        return c

    x = jnp.ones((4, 32), jnp.float32)
    base = analyze_hlo(_compiled_text(lambda x: x @ w, x)).flops
    st = analyze_hlo(_compiled_text(nested, x))
    assert st.flops == pytest.approx(15 * base, rel=0.01)


def test_hbm_bytes_nonzero_and_scale():
    x = jnp.ones((256, 256), jnp.float32)
    st = analyze_hlo(_compiled_text(lambda x: x * 2.0 + 1.0, x))
    # at least write the output once: 256*256*4 bytes
    assert st.hbm_bytes >= 256 * 256 * 4


def test_roofline_terms_and_bottleneck():
    r = Roofline(flops=197e12, hbm_bytes=819e9 * 2, collective_bytes=0.0,
                 chips=1, model_flops=197e12)
    assert r.t_compute == pytest.approx(1.0)
    assert r.t_memory == pytest.approx(2.0)
    assert r.bottleneck == "memory"
    assert r.useful_flops_ratio == pytest.approx(1.0)


def test_collective_factor_math():
    """Synthetic HLO text: one all-reduce of 1 MiB f32 in a group of 4
    should count 2*(4-1)/4 * 1MiB wire bytes."""
    text = """HloModule m

ENTRY %main (p: f32[262144]) -> f32[262144] {
  %p = f32[262144]{0} parameter(0)
  ROOT %ar = f32[262144]{0} all-reduce(%p), replica_groups={{0,1,2,3}}, to_apply=%add
}
"""
    st = analyze_hlo(text, default_group=4)
    assert st.count_by_kind["all-reduce"] == 1
    assert st.collective_bytes == pytest.approx(2 * 0.75 * 262144 * 4)
