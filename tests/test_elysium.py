"""Elysium threshold: pre-testing, online controller, optimal pass fraction."""
import numpy as np
import pytest

try:
    import hypothesis
    import hypothesis.strategies as st
except ImportError:  # optional dev dependency (pyproject [dev] extra)
    from _hypothesis_stub import hypothesis, st

from repro.core.elysium import (
    OnlineElysiumController,
    optimal_pass_fraction,
    pretest_threshold,
    run_pretest,
)
from repro.sim.variation import VariationModel


def test_pretest_is_quantile():
    xs = np.arange(1, 101, dtype=float)  # 1..100
    thr = pretest_threshold(xs, pass_fraction=0.4)
    # 40% of durations at or below the threshold pass
    assert np.mean(xs <= thr) == pytest.approx(0.4, abs=0.01)


@hypothesis.given(
    st.lists(st.floats(1.0, 1e4, allow_nan=False), min_size=10, max_size=500),
    st.floats(0.1, 0.9),
)
@hypothesis.settings(deadline=None, max_examples=40)
def test_pretest_pass_rate_property(xs, pf):
    """Property: the threshold admits ~pf of the pre-test population."""
    thr = pretest_threshold(xs, pass_fraction=pf)
    rate = np.mean(np.asarray(xs) <= thr)
    assert rate >= pf - 1.5 / len(xs) - 1e-9


def test_run_pretest_report():
    rs = np.random.RandomState(0)
    rep = run_pretest(rs.lognormal(5, 0.3, 400), pass_fraction=0.4)
    assert rep.n_samples == 400
    assert rep.p50 < rep.p90
    assert rep.threshold < rep.p50  # 40th pct below the median


def test_online_controller_tracks_quantile():
    rs = np.random.RandomState(1)
    ctrl = OnlineElysiumController(pass_fraction=0.4, republish_every=16,
                                   smoothing_alpha=1.0)
    xs = rs.lognormal(0, 0.4, 4000) * 100
    for x in xs:
        ctrl.report(x)
    true = np.quantile(xs, 0.4)
    assert abs(ctrl.threshold - true) / true < 0.05
    assert abs(ctrl.population_mean - xs.mean()) / xs.mean() < 1e-6


def test_online_controller_adapts_to_drift():
    """Platform slows down 30% mid-stream; the threshold follows (the §IV
    argument for online recalculation)."""
    rs = np.random.RandomState(2)
    ctrl = OnlineElysiumController(pass_fraction=0.4, republish_every=8,
                                   smoothing_alpha=0.5)
    for x in rs.lognormal(0, 0.2, 2000) * 100:
        ctrl.report(x)
    before = ctrl.threshold
    for x in rs.lognormal(0, 0.2, 6000) * 130:
        ctrl.report(x)
    after = ctrl.threshold
    assert after > before * 1.1


def test_controller_requires_data_or_initial():
    ctrl = OnlineElysiumController()
    with pytest.raises(ValueError):
        _ = ctrl.threshold
    ctrl2 = OnlineElysiumController(initial_threshold=123.0)
    assert ctrl2.threshold == 123.0


def test_optimal_pass_fraction_tradeoff():
    """§II-A: with many reuses, selecting harder (small f) wins; with a
    one-shot workload, the benchmark waste dominates and f -> 1 is optimal."""
    vm = VariationModel(sigma=0.15)

    def speedup(f):
        return vm.top_fraction_mean_speed(f) / vm.mean_speed

    harsh = optimal_pass_fraction(
        benchmark_ms=300, body_ms=2000, expected_reuses=200,
        speedup_at_fraction=speedup)
    lax_ = optimal_pass_fraction(
        benchmark_ms=300, body_ms=500, expected_reuses=0,
        speedup_at_fraction=speedup)
    assert harsh < lax_
    assert lax_ >= 0.9
