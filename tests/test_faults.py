"""Fault injection + failure-aware recovery (repro.faults; DESIGN.md §15).

* FaultPlan/FaultWindow/RecoveryPolicy validation and semantics; a plan
  with every rate at zero draws NOTHING (the zero-draw contract) and an
  engine carrying such a plan + a RecoveryPolicy is bit-identical to the
  historical no-faults engine;
* engine recovery: crash bills the partial duration, cold-start failure
  and probe hangs bill their platform time, lost completions bill the
  full body, dead-letter after max_attempts, per-request timeouts turn
  in-flight attempts into billed zombies that drain cleanly;
* faults are logged in ``fault_counts``/``fault_events``, never in the
  gate's ``instances_terminated`` (the misattribution separation);
* the circuit breaker's full state machine, clockless and RNG-free;
* fleet resilience: shed-by-priority under open breakers; hedging ×
  faults × recovery keeps the fleet-conservation ledger exact across
  routing policies × seeds (a hedged loser dying must not corrupt it);
* the sanitizer's fault-ledger checks demonstrably fire on double-count,
  dead-letter+complete, and unbilled/negative crash billing.
"""
import dataclasses
import math
import types

import numpy as np
import pytest

from repro.analysis.sanitizer import (
    SanitizerError,
    attach_engine,
    check_engine_conservation,
    check_fault_ledger,
)
from repro.core.control import FailureDecision
from repro.core.policy import MinosPolicy
from repro.faults import (
    FaultPlan,
    FaultWindow,
    RecoveryPolicy,
    decorrelated_jitter_ms,
)
from repro.fleet import (
    BreakerConfig,
    BreakerState,
    CircuitBreaker,
    FleetRouter,
    FleetSpec,
    GreedyRoutingPolicy,
    ProbabilisticRoutingPolicy,
    RandomRoutingPolicy,
    run_fleet_open_loop,
)
from repro.sim import (
    FaaSPlatform,
    FunctionSpec,
    PlatformProfile,
    PoissonProcess,
    VariationModel,
)
from repro.sim.arrivals import QoSClass
from repro.sim.workload import run_closed_loop

SPEC = FunctionSpec(name="faults-test", prepare_ms=50.0, body_ms=300.0,
                    benchmark_ms=100.0, contention_rho=0.5)
VM = VariationModel(sigma=0.15)
GATE = MinosPolicy(elysium_threshold=130.0)
PROFILE = PlatformProfile.gcf_gen1()


def _no_gate() -> MinosPolicy:
    return MinosPolicy(elysium_threshold=float("inf"), enabled=False)


def _platform(*, fault_plan=None, recovery=None, policy=None, seed=3):
    return FaaSPlatform(SPEC, VM, policy or _no_gate(), seed=seed,
                        profile=PROFILE, fault_plan=fault_plan,
                        recovery=recovery)


def _submit_n(plat, n, gap_ms=500.0, **kwargs):
    """Schedule n spaced submits, run to quiescence, return the engine."""
    for i in range(n):
        plat.loop.at(i * gap_ms,
                     lambda i=i: plat.submit({"i": i}, **kwargs))
    plat.loop.run_all()
    return plat


def _rng_fingerprint(plan: FaultPlan):
    s = plan._rng.get_state()
    return (s[0], s[1].tobytes(), s[2], s[3], s[4])


# ---------------------------------------------------------------------------
# FaultPlan / FaultWindow / RecoveryPolicy semantics
# ---------------------------------------------------------------------------


def test_fault_window_validation_and_half_open_bounds():
    with pytest.raises(ValueError):
        FaultWindow(start_ms=0.0, end_ms=10.0, kind="meteor")
    with pytest.raises(ValueError):
        FaultWindow(start_ms=10.0, end_ms=10.0)
    with pytest.raises(ValueError):
        FaultWindow(start_ms=-1.0, end_ms=10.0)
    with pytest.raises(ValueError):
        FaultWindow(start_ms=0.0, end_ms=10.0, kind="brownout", severity=0.5)
    w = FaultWindow(start_ms=100.0, end_ms=200.0, kind="outage")
    assert w.active(100.0) and w.active(199.999)
    assert not w.active(99.999) and not w.active(200.0)


def test_fault_plan_rate_validation():
    for bad in (-0.1, 1.0, 1.5):
        with pytest.raises(ValueError):
            FaultPlan(seed=0, crash_rate=bad)
        with pytest.raises(ValueError):
            FaultPlan(seed=0, lost_completion_rate=bad)
    with pytest.raises(ValueError):
        FaultPlan(seed=0, probe_timeout_ms=0.0)


def test_recovery_policy_validation():
    with pytest.raises(ValueError):
        RecoveryPolicy(timeout_ms=0.0)
    with pytest.raises(ValueError):
        RecoveryPolicy(max_attempts=0)
    with pytest.raises(ValueError):
        RecoveryPolicy(backoff_base_ms=-1.0)
    with pytest.raises(ValueError):
        RecoveryPolicy(backoff_base_ms=100.0, backoff_cap_ms=50.0)
    assert RecoveryPolicy().timeout_ms is None  # timeouts off by default


def test_zero_rates_draw_nothing():
    """The zero-draw contract: every hook on an all-zero plan consumes no
    RNG state — a disabled fault class cannot shift any other stream."""
    plan = FaultPlan(seed=42, windows=(
        FaultWindow(start_ms=0.0, end_ms=50.0, kind="brownout", severity=3.0),
        FaultWindow(start_ms=60.0, end_ms=70.0, kind="outage"),
    ))
    before = _rng_fingerprint(plan)
    for t in (0.0, 55.0, 65.0, 1e6):
        assert plan.crash_mid_body(t) is None
        assert not plan.cold_start_fails(t)
        assert not plan.probe_times_out(t)
        assert not plan.throttled(t)
        assert not plan.completion_lost(t)
        plan.unavailable(t)
        plan.speed_multiplier(t)
    assert _rng_fingerprint(plan) == before
    # a nonzero rate does draw
    hot = FaultPlan(seed=42, crash_rate=0.5)
    before = _rng_fingerprint(hot)
    hot.crash_mid_body(0.0)
    assert _rng_fingerprint(hot) != before


def test_fault_plan_same_seed_same_schedule():
    kw = dict(crash_rate=0.3, lost_completion_rate=0.2, cold_fail_rate=0.1)
    a, b = FaultPlan(seed=7, **kw), FaultPlan(seed=7, **kw)
    seq_a = [(a.crash_mid_body(t), a.completion_lost(t), a.cold_start_fails(t))
             for t in range(50)]
    seq_b = [(b.crash_mid_body(t), b.completion_lost(t), b.cold_start_fails(t))
             for t in range(50)]
    assert seq_a == seq_b
    # crash fractions are valid partial-billing fractions
    fracs = [f for f, _, _ in seq_a if f is not None]
    assert fracs and all(0.0 <= f < 1.0 for f in fracs)


def test_windows_are_pure_schedule():
    plan = FaultPlan(seed=0, windows=(
        FaultWindow(start_ms=1_000.0, end_ms=2_000.0, severity=3.0),
        FaultWindow(start_ms=1_500.0, end_ms=2_500.0, severity=2.0),
        FaultWindow(start_ms=5_000.0, end_ms=6_000.0, kind="outage"),
    ))
    assert plan.speed_multiplier(500.0) == 1.0
    assert plan.speed_multiplier(1_200.0) == 3.0
    assert plan.speed_multiplier(1_700.0) == 6.0  # overlap multiplies
    assert plan.speed_multiplier(2_200.0) == 2.0
    assert not plan.unavailable(4_999.0)
    assert plan.unavailable(5_000.0) and not plan.unavailable(6_000.0)


def test_decorrelated_jitter_bounds_and_zero_base():
    rng = np.random.RandomState(0)
    before = rng.get_state()[2]
    assert decorrelated_jitter_ms(rng, 500.0, base_ms=0.0, cap_ms=100.0) == 0.0
    assert rng.get_state()[2] == before  # base<=0 draws nothing
    # prev=0 collapses the interval to [base, base]
    assert decorrelated_jitter_ms(rng, 0.0, base_ms=10.0, cap_ms=100.0) == 10.0
    draws = [decorrelated_jitter_ms(rng, 400.0, base_ms=10.0, cap_ms=100.0)
             for _ in range(200)]
    assert all(10.0 <= d <= 100.0 for d in draws)
    assert max(draws) == 100.0  # prev*3 >> cap: the cap binds


# ---------------------------------------------------------------------------
# Bit-identity: disabled faults change nothing
# ---------------------------------------------------------------------------


def _result_digest(plat, res):
    return ([(r.t_submitted_ms, r.t_completed_ms, r.download_ms,
              r.analysis_ms, r.retries, r.served_by_cold,
              r.instance_speed, r.benchmark_ms) for r in res],
            plat.cost.total, plat.instances_started,
            plat.instances_terminated)


def test_all_zero_plan_and_idle_recovery_are_bit_identical():
    """An engine carrying a rate-0 FaultPlan + a RecoveryPolicy must be
    bit-identical to the historical engine (no plan, no recovery): the
    fault path performs zero extra RNG draws when nothing fires."""
    def run(fault_plan, recovery):
        plat = FaaSPlatform(SPEC, VM, MinosPolicy(elysium_threshold=130.0),
                            seed=11, profile=PROFILE,
                            fault_plan=fault_plan, recovery=recovery)
        res = run_closed_loop(plat, n_vus=5, think_time_ms=500.0,
                              duration_ms=40_000.0)
        return plat, res

    base_plat, base_res = run(None, None)
    armed_plat, armed_res = run(FaultPlan(seed=999), RecoveryPolicy())
    assert base_res, "run produced no traffic"
    assert _result_digest(base_plat, base_res) == \
        _result_digest(armed_plat, armed_res)
    # the recovery backoff stream was never built: no failures, no draws
    assert armed_plat._recovery_rng is None
    assert armed_plat.fault_counts == {} and armed_plat.fault_events == []


# ---------------------------------------------------------------------------
# Engine: fault classes + recovery
# ---------------------------------------------------------------------------


def test_crash_bills_partial_duration_and_retries_to_completion():
    plat = _platform(fault_plan=FaultPlan(seed=5, crash_rate=0.5))
    _submit_n(plat, 20)
    assert plat.fault_counts["crash"] > 0
    # infinite retries (no RecoveryPolicy): every request completes
    assert len(plat.results) == 20
    assert plat.requests_arrived == 20 and plat.requests_dropped == 0
    crash_bills = [b for _, k, b in plat.fault_events if k == "crash"]
    assert crash_bills and all(b >= 0.0 for b in crash_bills)
    assert max(crash_bills) > 0.0  # partial duration actually billed
    assert plat.cost.total > 0.0
    # platform faults never land in the gate's termination counter
    assert plat.instances_terminated == 0
    check_fault_ledger(plat, where="test-crash")


def test_cold_start_failure_billed_and_separated_from_gate():
    plat = _platform(fault_plan=FaultPlan(seed=2, cold_fail_rate=0.6))
    _submit_n(plat, 10)
    assert len(plat.results) == 10
    n_cold_fail = plat.fault_counts["cold_start"]
    assert n_cold_fail > 0
    bills = [b for _, k, b in plat.fault_events if k == "cold_start"]
    # gen1 bills cold starts: each failed startup costs its cold time
    assert len(bills) == n_cold_fail and all(b > 0.0 for b in bills)
    assert plat.instances_terminated == 0
    # starts split into failed startups + instances that came up (and
    # then served, possibly many requests each via warm reuse)
    assert plat.instances_started > n_cold_fail


def test_probe_timeout_bills_watchdog_window():
    plan = FaultPlan(seed=9, probe_timeout_rate=0.6, probe_timeout_ms=1_234.0)
    plat = _platform(fault_plan=plan, policy=GATE)
    _submit_n(plat, 12)
    assert len(plat.results) == 12
    assert plat.fault_counts["probe_timeout"] > 0
    bills = [b for _, k, b in plat.fault_events if k == "probe_timeout"]
    # billed = cold start + the watchdog wait the hung probe burned
    assert bills and all(b >= 1_234.0 for b in bills)


def test_lost_completion_bills_full_body_and_never_duplicates():
    plat = _platform(fault_plan=FaultPlan(seed=4, lost_completion_rate=0.5))
    _submit_n(plat, 15)
    assert plat.fault_counts["lost"] > 0
    assert len(plat.results) == 15
    # idempotent re-dispatch: a recovered request completes exactly once
    assert len({r.invocation_id for r in plat.results}) == 15
    check_fault_ledger(plat, where="test-lost")


def test_throttle_drops_at_submit():
    plat = _platform(fault_plan=FaultPlan(seed=1, throttle_rate=0.4))
    accepted = [plat.submit({"i": i}) for i in range(40)]
    plat.loop.run_all()
    n_dropped = accepted.count(False)
    assert 0 < n_dropped < 40
    assert plat.requests_dropped == n_dropped
    assert plat.fault_counts["throttle"] == n_dropped
    assert len(plat.results) == 40 - n_dropped


def test_outage_window_rejects_submits_inside_it():
    plan = FaultPlan(seed=0, windows=(
        FaultWindow(start_ms=0.0, end_ms=10_000.0, kind="outage"),))
    plat = _platform(fault_plan=plan)
    assert plat.submit({"i": 0}) is False  # t=0: inside the outage
    plat.loop.at(20_000.0, lambda: plat.submit({"i": 1}))
    plat.loop.run_all()
    assert plat.fault_counts["outage"] == 1
    assert plat.requests_dropped == 1 and len(plat.results) == 1


def test_dead_letter_after_max_attempts():
    dead = []
    plat = _platform(
        fault_plan=FaultPlan(seed=13, crash_rate=0.9),
        recovery=RecoveryPolicy(max_attempts=2, backoff_base_ms=0.0,
                                backoff_cap_ms=0.0))
    _submit_n(plat, 12, on_dead_letter=dead.append)
    assert plat.requests_dead_lettered > 0
    assert plat.requests_dead_lettered == len(plat.dead_letter_events)
    assert len(dead) == plat.requests_dead_lettered
    assert all(inv.failed_attempts == 2 for inv in dead)
    # conservation incl. the terminal state; no overlap with completions
    assert len(plat.results) + plat.requests_dead_lettered == 12
    dead_ids = {iid for _, iid, _ in plat.dead_letter_events}
    assert dead_ids.isdisjoint({r.invocation_id for r in plat.results})
    check_fault_ledger(plat, where="test-dead-letter")


def test_timeout_abandons_attempt_and_zombies_drain():
    """A per-request timeout turns the in-flight attempt into a billed
    zombie; its late completion is discarded exactly once and the pool
    slot is returned — never a double-finish, never a leaked slot."""
    plat = _platform(
        recovery=RecoveryPolicy(timeout_ms=200.0, max_attempts=2,
                                backoff_base_ms=0.0, backoff_cap_ms=0.0))
    plat.submit({"i": 0})
    plat.loop.run_all()
    # both attempts blew the 200ms budget (body alone is 300ms)
    assert plat.fault_counts["timeout"] == 2
    assert plat.requests_dead_lettered == 1 and len(plat.results) == 0
    stale = [e for e in plat.fault_events if e[1] == "stale_completion"]
    assert len(stale) == 2  # both zombies completed, were discarded once
    assert plat._zombie_executions == 0
    assert plat.pool.total_in_flight == 0
    check_fault_ledger(plat, where="test-timeout")


def test_on_failure_controller_decision_is_honored():
    contexts = []

    def fail_fast(ctx):
        contexts.append(ctx)
        return FailureDecision.DEAD_LETTER

    plat = _platform(fault_plan=FaultPlan(seed=21, crash_rate=0.7),
                     recovery=RecoveryPolicy(max_attempts=10))
    plat.controller.on_failure = fail_fast
    _submit_n(plat, 10)
    assert contexts, "no failures reached the controller"
    assert plat.requests_dead_lettered == len(contexts)
    assert len(plat.results) + plat.requests_dead_lettered == 10
    for ctx in contexts:
        assert ctx.kind == "crash" and ctx.attempts == 1
        assert ctx.elapsed_ms >= 0.0 and isinstance(ctx.invocation_id, int)


def test_recovery_runs_are_deterministic_per_seed():
    def run():
        plat = _platform(
            fault_plan=FaultPlan(seed=5, crash_rate=0.5,
                                 lost_completion_rate=0.2),
            recovery=RecoveryPolicy(max_attempts=4, backoff_base_ms=50.0,
                                    backoff_cap_ms=500.0),
            seed=8)
        _submit_n(plat, 15)
        return (_result_digest(plat, plat.results), plat.fault_events,
                plat.requests_dead_lettered)

    a, b = run(), run()
    assert a == b
    assert a[1], "no fault events: the determinism claim is vacuous"


# ---------------------------------------------------------------------------
# Circuit breaker state machine
# ---------------------------------------------------------------------------


def test_breaker_config_validation():
    with pytest.raises(ValueError):
        BreakerConfig(window=0)
    with pytest.raises(ValueError):
        BreakerConfig(failure_threshold=0.0)
    with pytest.raises(ValueError):
        BreakerConfig(failure_threshold=1.1)
    with pytest.raises(ValueError):
        BreakerConfig(window=4, min_samples=5)
    with pytest.raises(ValueError):
        BreakerConfig(open_ms=0.0)
    with pytest.raises(ValueError):
        BreakerConfig(trial_requests=0)


def test_breaker_min_samples_guard():
    b = CircuitBreaker(BreakerConfig(window=10, failure_threshold=0.5,
                                     min_samples=5))
    for _ in range(4):
        b.record_failure(0.0)  # 100% failing but under min_samples
    assert b.state is BreakerState.CLOSED and b.allow(0.0)
    b.record_failure(0.0)
    assert b.state is BreakerState.OPEN and b.n_opens == 1


def test_breaker_full_cycle_closed_open_halfopen_closed():
    cfg = BreakerConfig(window=8, failure_threshold=0.5, min_samples=4,
                        open_ms=1_000.0, trial_requests=2)
    b = CircuitBreaker(cfg)
    for _ in range(2):
        b.record_success(0.0)
    for _ in range(3):
        b.record_failure(10.0)  # 3/5 failing >= 0.5 with min_samples met
    assert b.state is BreakerState.OPEN
    assert not b.allow(10.0) and not b.allow(1_009.0)
    # OPEN -> HALF_OPEN lazily once open_ms has elapsed
    assert b.allow(1_010.0)
    assert b.state is BreakerState.HALF_OPEN
    # only trial_requests may route; allow is non-consuming
    assert b.allow(1_010.0) and b.allow(1_010.0)
    b.on_route(1_010.0)
    b.on_route(1_010.0)
    assert not b.allow(1_010.0)  # trial slots consumed
    b.record_success(1_200.0)
    assert b.state is BreakerState.HALF_OPEN
    b.record_success(1_300.0)
    assert b.state is BreakerState.CLOSED
    assert b.failure_rate == 0.0  # recovered fleet is judged fresh
    assert b.n_opens == 1


def test_breaker_halfopen_failure_reopens():
    cfg = BreakerConfig(window=4, failure_threshold=0.5, min_samples=2,
                        open_ms=500.0, trial_requests=3)
    b = CircuitBreaker(cfg)
    b.record_failure(0.0)
    b.record_failure(0.0)
    assert b.state is BreakerState.OPEN
    assert b.allow(600.0)  # HALF_OPEN now
    b.on_route(600.0)
    b.record_failure(650.0)
    assert b.state is BreakerState.OPEN and b.n_opens == 2
    assert not b.allow(1_000.0)  # a fresh open_ms window started at 650
    assert b.allow(1_200.0)
    # stragglers while OPEN change nothing
    b2 = CircuitBreaker(cfg)
    b2.record_failure(0.0)
    b2.record_failure(0.0)
    b2.record_success(10.0)
    b2.record_failure(10.0)
    assert b2.state is BreakerState.OPEN and b2.n_opens == 1


# ---------------------------------------------------------------------------
# Fleet resilience: shed + hedging x faults conservation
# ---------------------------------------------------------------------------


def _fault_fleets(crash=(0.6, 0.0), recovery=None, caps=(6, 6)):
    profs = (PlatformProfile.gcf_gen1(), PlatformProfile.gcf_gen2())
    fleets = []
    for i, (c, cap) in enumerate(zip(crash, caps)):
        knobs = dataclasses.replace(profs[i].knobs(), max_instances=cap)
        factory = None
        if c > 0.0:
            factory = lambda seed, c=c: FaultPlan(
                seed=seed, crash_rate=c, lost_completion_rate=c / 4,
                cold_fail_rate=c / 6)
        fleets.append(FleetSpec(
            name=f"f{i}", spec=SPEC, variation=VM, profile=profs[i],
            knobs=knobs, policy=MinosPolicy(elysium_threshold=130.0),
            fault_plan_factory=factory, recovery=recovery))
    return fleets


def test_shed_requires_breaker():
    with pytest.raises(ValueError):
        FleetRouter(_fault_fleets(), RandomRoutingPolicy(), seed=0,
                    shed_when_degraded=True)


def test_breaker_discriminates_faulty_fleet_and_sheds_bronze_first():
    recovery = RecoveryPolicy(max_attempts=3, backoff_base_ms=20.0,
                              backoff_cap_ms=200.0)
    router = FleetRouter(
        _fault_fleets(crash=(0.7, 0.0), recovery=recovery),
        RandomRoutingPolicy(), seed=0,
        breaker=BreakerConfig(window=8, failure_threshold=0.5,
                              min_samples=4, open_ms=10_000.0,
                              trial_requests=2),
        shed_when_degraded=True,
        qos_priorities={"gold": 1, "bronze": 0})
    qos = (QoSClass("gold", weight=1.0, priority=1, slo_ms=20_000.0),
           QoSClass("bronze", weight=1.0, priority=0))
    run = run_fleet_open_loop(
        router, PoissonProcess(2.0), rng=np.random.RandomState(3),
        duration_ms=40_000.0, qos_classes=qos, drain_limit_ms=120_000.0)
    router.check_conservation()
    # the breaker trips on the crashing fleet, not the healthy one
    assert router.breakers[0].n_opens >= 1
    assert router.breakers[1].n_opens == 0
    assert run.breaker_opens == tuple(b.n_opens for b in router.breakers)
    # graceful degradation: only the lowest-priority class sheds
    assert run.n_shed > 0
    assert set(run.shed_by_class) == {"bronze"}
    assert run.n_rejected == run.n_shed + run.n_breaker_rejected


@pytest.mark.parametrize("policy_factory", [
    RandomRoutingPolicy,
    GreedyRoutingPolicy,
    lambda: ProbabilisticRoutingPolicy(update_interval_ms=1_000.0),
])
@pytest.mark.parametrize("seed", [0, 1])
def test_hedging_with_faults_conserves(policy_factory, seed):
    """Property: across routing policies x seeds, with crashes, lost
    completions, dead-letters AND hedging all armed, the fleet ledger
    stays exact — a hedged loser that crashes or loses its completion
    must not corrupt conservation."""
    recovery = RecoveryPolicy(timeout_ms=25_000.0, max_attempts=2,
                              backoff_base_ms=20.0, backoff_cap_ms=200.0)
    router = FleetRouter(
        _fault_fleets(crash=(0.3, 0.25), recovery=recovery),
        policy_factory(), seed=seed, hedge_after_ms=900.0,
        breaker=BreakerConfig(window=16, failure_threshold=0.6,
                              min_samples=6, open_ms=5_000.0))
    run = run_fleet_open_loop(
        router, PoissonProcess(2.0), rng=np.random.RandomState(100 + seed),
        duration_ms=20_000.0, drain_limit_ms=120_000.0)
    router.check_conservation()  # raises SanitizerError on any imbalance
    total_faults = sum(sum(e.fault_counts.values()) for e in router.engines)
    assert total_faults > 0, "fault machinery never engaged"
    assert run.n_arrived == (run.n_completed + run.n_dropped
                             + run.n_rejected + run.n_dead_lettered
                             + run.n_pending_at_end)


# ---------------------------------------------------------------------------
# Sanitizer fault-ledger checks fire on corruption
# ---------------------------------------------------------------------------


def _ledger_stub(*, results_ids=(1, 2), dead=(), events=()):
    ns = types.SimpleNamespace()
    ns.fault_events = list(events)
    ns.dead_letter_events = list(dead)
    ns.requests_dead_lettered = len(dead)
    ns.results = [types.SimpleNamespace(invocation_id=i)
                  for i in results_ids]
    return ns


def test_fault_ledger_clean_stub_passes():
    check_fault_ledger(_ledger_stub(dead=((5.0, 7, "crash"),),
                                    events=((1.0, "crash", 42.0),)))
    # engines without the fault substrate are a no-op, not a crash
    check_fault_ledger(types.SimpleNamespace(fault_events=None))


def test_fault_ledger_catches_unbilled_crash():
    with pytest.raises(SanitizerError, match="non-finite or negative"):
        check_fault_ledger(_ledger_stub(events=((1.0, "crash", -1.0),)))
    with pytest.raises(SanitizerError, match="non-finite or negative"):
        check_fault_ledger(
            _ledger_stub(events=((1.0, "crash", float("nan")),)))


def test_fault_ledger_catches_counter_divergence():
    eng = _ledger_stub(dead=((5.0, 7, "crash"),))
    eng.requests_dead_lettered = 2  # counter bumped without an event
    with pytest.raises(SanitizerError, match="diverged"):
        check_fault_ledger(eng)


def test_fault_ledger_catches_dead_letter_plus_complete():
    """A request that both dead-lettered and completed means idempotent
    re-dispatch broke — proven on a REAL engine run, then corrupted."""
    plat = _platform(
        fault_plan=FaultPlan(seed=13, crash_rate=0.9),
        recovery=RecoveryPolicy(max_attempts=2, backoff_base_ms=0.0,
                                backoff_cap_ms=0.0))
    _submit_n(plat, 12)
    assert plat.results and plat.requests_dead_lettered > 0
    check_fault_ledger(plat)  # the honest ledger passes
    plat.dead_letter_events.append(
        (plat.loop.now, plat.results[0].invocation_id, "crash"))
    plat.requests_dead_lettered += 1
    with pytest.raises(SanitizerError, match="dead-lettered and completed"):
        check_fault_ledger(plat)


def test_engine_conservation_catches_double_counted_retry():
    plat = _platform(fault_plan=FaultPlan(seed=5, crash_rate=0.4))
    attach_engine(plat)
    _submit_n(plat, 10)
    check_engine_conservation(plat)  # honest run balances
    plat.results.append(plat.results[0])  # a retry finishing twice
    with pytest.raises(SanitizerError, match="conservation"):
        check_engine_conservation(plat)
