"""The unified execution substrate (core/substrate.py; DESIGN.md §9).

* seeded golden parity: the substrate refactor preserved FaaSPlatform
  behavior exactly (digests captured from the pre-refactor engine);
* InstancePool invariants shared by both backends (LIFO/FIFO order,
  concurrency slots, idle/recycle reclaim, max-size cap);
* serving-vs-sim parity: identical seeds + equivalent specs drive the two
  backends through identical gate decisions and timings;
* mixed-backend pipeline fan-in and per-stage admission bounds.
"""
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.core.cost import Pricing
from repro.core.lifecycle import FunctionInstance, InstanceState
from repro.core.policy import AdaptiveMinosPolicy, MinosPolicy
from repro.core.substrate import InstancePool
from repro.sim import (
    FaaSPlatform,
    FunctionSpec,
    PlatformProfile,
    Stage,
    VariationModel,
    WorkflowDAG,
    WorkflowEngine,
    run_workflow_batch,
    run_workflow_closed_loop,
    workflow_arm_factory,
)
from repro.sim.workload import run_closed_loop

PRICING = Pricing.gcf(256)


# ---------------------------------------------------------------------------
# Golden parity: refactor preserved FaaSPlatform behavior per-request
# ---------------------------------------------------------------------------

_GOLDEN_SPEC = FunctionSpec(
    name="golden", prepare_ms=400.0, body_ms=900.0, benchmark_ms=200.0,
    cold_start_ms=120.0, recycle_lifetime_ms=30_000.0, contention_rho=0.97,
    benchmark_noise=0.06,
)
_GOLDEN_VM = VariationModel(sigma=0.18, diurnal_amplitude=0.05)

# Digests captured from the pre-substrate engine (PR 1 tree) on the same
# seeds/specs: (n, Σlatency, Σanalysis, Σdownload, Σretries, n_cold,
# Σspeed, started, terminated, cost·1e6, Σprobe_obs, pool_n, Σpool_speed)
# plus the first five per-request latencies. Two documented deviations:
# (1) the PR 1 engine's `first_enqueued_at_ms or t0` dropped the failed
# first attempt from the latency of t=0-submitted requests that were
# gate-terminated; the capture was re-run on the PR 1 tree with that
# one-line fix applied, so these digests still certify the refactor itself.
# (2) PR 3's InstancePool.release reclaim fix: an instance finishing past
# its recycle deadline is no longer readmitted, so gen1-fixed's END-OF-RUN
# pool view lost exactly the one zombie the old capture counted — ONLY the
# last two digest fields changed (pool_n 5→4, Σpool_speed
# 5.218109→4.396192); every per-request field below and the other three
# cases are the original PR 1 capture, bit-for-bit. The fix itself is
# pinned by tests/test_load_aware.py::test_release_never_readmits_*.
_GOLDEN = {
    "gen1-fixed": ((263, 326525.9068, 214260.3485, 104656.1097, 8, 14, 297.324946, 22, 8, 1649.445256, 4467.0315, 4, 4.396192),
                   [1271.911643, 1419.517809, 1468.134493, 1669.135905, 2407.484372]),
    "gen2-fixed": ((255, 333860.9103, 227360.2664, 103064.1559, 2, 6, 262.390023, 8, 2, 5656.502875, 1553.2891, 2, 1.794619),
                   [1409.752119, 1443.994068, 1625.242325, 1659.233192, 2223.909222]),
    "lambda-adaptive": ((260, 329582.2324, 213130.532, 104251.6583, 25, 11, 290.289559, 37, 26, 5554.6833, 7654.0102, 4, 4.233978),
                        [1247.954299, 1355.480524, 1438.951415, 1684.055399, 2384.037487]),
    "gen1-disabled": ((259, 331566.9131, 223510.3213, 103613.0622, 0, 18, 276.264599, 18, 0, 1668.263337, 0, 6, 6.091948),
                      [1316.761863, 1390.946399, 1436.904543, 1473.013597, 1589.485981]),
}


def _golden_digest(profile, policy, seed):
    plat = FaaSPlatform(_GOLDEN_SPEC, _GOLDEN_VM, policy, seed=seed, profile=profile)
    res = run_closed_loop(plat, n_vus=6, think_time_ms=800.0, duration_ms=90_000.0)
    tup = (len(res),
           round(sum(r.latency_ms for r in res), 4),
           round(sum(r.analysis_ms for r in res), 4),
           round(sum(r.download_ms for r in res), 4),
           sum(r.retries for r in res),
           sum(1 for r in res if r.served_by_cold),
           round(sum(r.instance_speed for r in res), 6),
           plat.instances_started, plat.instances_terminated,
           round(plat.cost.total * 1e6, 6),
           round(sum(plat.benchmark_observations), 4),
           len(plat.warm_pool_speeds),
           round(sum(plat.warm_pool_speeds), 6))
    return tup, [round(r.latency_ms, 6) for r in res[:5]]


@pytest.mark.parametrize("case,profile,policy,seed", [
    ("gen1-fixed", PlatformProfile.gcf_gen1(),
     MinosPolicy(elysium_threshold=200.0, max_retries=4), 7),
    ("gen2-fixed", PlatformProfile.gcf_gen2(),
     MinosPolicy(elysium_threshold=210.0, max_retries=4), 11),
    ("lambda-adaptive", PlatformProfile.aws_lambda(),
     AdaptiveMinosPolicy(0.4, max_retries=5), 13),
    ("gen1-disabled", PlatformProfile.gcf_gen1(),
     MinosPolicy(elysium_threshold=0.0, enabled=False), 7),
])
def test_faas_platform_golden_parity(case, profile, policy, seed):
    assert _golden_digest(profile, policy, seed) == _GOLDEN[case]


def test_workflow_engine_golden_parity():
    vm = VariationModel(sigma=0.15)
    prof = PlatformProfile.gcf_gen1()
    from repro.sim import etl_chain
    eng = WorkflowEngine(etl_chain(3), vm,
                         workflow_arm_factory("fixed", vm, pricing=prof.pricing),
                         profile=prof, seed=21)
    run = run_workflow_closed_loop(eng, n_vus=5, duration_ms=120_000.0)
    got = (run.n_items, run.n_items_costed,
           round(run.mean_item_latency_ms, 6),
           round(run.mean_item_analysis_ms, 6),
           eng.instances_started, eng.instances_terminated,
           round(eng.cost.total * 1e6, 6))
    assert got == (118, 122, 4012.726521, 2107.16842, 62, 37, 2416.320648)


# ---------------------------------------------------------------------------
# InstancePool invariants (shared by both backends)
# ---------------------------------------------------------------------------


def _warm(speed=1.0, t=0.0, idle=1e9):
    inst = FunctionInstance(speed_factor=speed, created_at_ms=t, idle_timeout_ms=idle)
    inst.accept_without_benchmark()
    inst.last_used_ms = t
    return inst


def test_pool_lifo_vs_fifo_order():
    for order, expect in (("lifo", 3.0), ("fifo", 1.0)):
        pool = InstancePool(order=order)
        for s in (1.0, 2.0, 3.0):
            pool.add_warm(_warm(speed=s))
        assert pool.take(0.0).speed_factor == expect


def test_pool_concurrency_slots():
    pool = InstancePool(concurrency=2)
    inst = _warm()
    pool.add_warm(inst)
    assert pool.take(0.0) is inst       # slot 1: still available
    assert len(pool) == 1
    assert pool.take(0.0) is inst       # slot 2: now at capacity
    assert len(pool) == 0
    assert pool.take(0.0) is None       # no capacity anywhere
    pool.release(inst)
    assert len(pool) == 1               # one slot freed: available again
    assert pool.take(0.0) is inst


def test_pool_never_reclaims_inflight_instances():
    pool = InstancePool(concurrency=2)
    busy = _warm(idle=10.0)
    pool.add_warm(busy)
    assert pool.take(0.0) is busy        # one request in flight, still listed
    # long idle gap: would be idle-expired, but a request holds it — the
    # pool must never reclaim an instance with work in flight
    assert pool.take(1000.0) is busy     # second slot granted, not evicted
    pool.release(busy)
    pool.release(busy)
    assert pool.take(2000.0) is None     # now truly idle: reclaimed
    assert busy.state is InstanceState.EXPIRED


def test_pool_idle_and_recycle_reclaim():
    rng = np.random.RandomState(0)
    pool = InstancePool(recycle_lifetime_ms=100.0, rng=rng)
    inst = _warm(idle=50.0)
    pool.admit_cold(inst, now=0.0)
    pool.release(inst)
    deadline = pool._recycle_deadline[inst.instance_id]
    # before both deadlines: reusable
    t = min(deadline, 50.0) / 2.0
    assert pool.take(t) is inst
    pool.release(inst)
    inst.last_used_ms = t
    # after the recycle deadline: reclaimed even if not idle-expired
    assert pool.take(deadline + 1.0) is None
    assert inst.state is InstanceState.EXPIRED


def test_pool_max_size_expires_overflow():
    pool = InstancePool(max_size=1)
    a, b = _warm(), _warm()
    for inst in (a, b):
        pool.add_warm(inst, in_flight=1)
    pool.release(a)
    pool.release(b)
    assert pool.available == [a]
    assert b.state is InstanceState.EXPIRED


# ---------------------------------------------------------------------------
# Serving-vs-sim parity on identical seeds/specs
# ---------------------------------------------------------------------------


def test_serving_and_sim_backends_agree_on_identical_seeds():
    """A serving engine and a FaaSPlatform given the same seed, the same
    variation model, and duration-equivalent specs make identical gate
    decisions with identical timings — the substrate is one engine."""
    from repro.serving.engine import MinosServingEngine, ServeRequest

    cfg = get_smoke_config("llama3.2-1b")
    probe_work, weight_load = 200.0, 400.0
    c_prefill, c_decode = 0.5, 5.0
    prompt_len, new_tokens = 4, 2
    body_work = c_prefill * prompt_len + c_decode * new_tokens
    vm = VariationModel(sigma=0.2)
    policy = MinosPolicy(elysium_threshold=probe_work * 1.01, max_retries=4)

    serving = MinosServingEngine(
        cfg, policy, Pricing.tpu_chip_seconds(4), seed=9, variation=vm,
        probe_work_ms=probe_work, weight_load_ms=weight_load,
        c_prefill_ms_per_tok=c_prefill, c_decode_ms_per_tok=c_decode)
    reqs = [ServeRequest(prompt=np.arange(prompt_len, dtype=np.int32),
                         max_new_tokens=new_tokens, request_id=i)
            for i in range(8)]
    sres = serving.serve(reqs)

    # spec whose every duration matches the serving engine's, noise-free;
    # requeue overhead = the serving requeue penalty (dense: re-prefill)
    spec = FunctionSpec(
        name="mirror", prepare_ms=weight_load, prepare_jitter=0.0,
        body_ms=body_work, body_jitter=0.0, benchmark_ms=probe_work,
        benchmark_noise=0.0, cold_start_ms=0.0, cold_start_jitter=0.0,
        contention_rho=1.0, requeue_overhead_ms=c_prefill * prompt_len,
        recycle_lifetime_ms=None,
    )
    sim = FaaSPlatform(spec, vm, policy, Pricing.tpu_chip_seconds(4), seed=9)
    fres = []
    for _ in range(8):
        sim.submit(None, fres.append)
        sim.loop.run_all()

    assert serving.instances_started == sim.instances_started
    assert serving.instances_terminated == sim.instances_terminated
    np.testing.assert_allclose(serving.benchmark_observations,
                               sim.benchmark_observations)
    np.testing.assert_allclose(sorted(serving.warm_pool_speeds),
                               sorted(sim.warm_pool_speeds))
    for a, b in zip(sres, fres):
        assert a.retries == b.retries
        np.testing.assert_allclose(a.sim_duration_ms, b.analysis_ms)
        np.testing.assert_allclose(a.latency_ms, b.latency_ms)


def test_serving_engine_feeds_adaptive_policy():
    """The §IV probe-stream wiring is substrate-level: an adaptive policy on
    the SERVING engine sees every cold-start probe (previously sim-only)."""
    from repro.serving.engine import MinosServingEngine, ServeRequest

    cfg = get_smoke_config("granite-moe-1b-a400m")
    policy = AdaptiveMinosPolicy(0.4, max_retries=5)
    eng = MinosServingEngine(cfg, policy, Pricing.tpu_chip_seconds(4), seed=2,
                             max_pool=2)
    reqs = [ServeRequest(prompt=np.arange(4, dtype=np.int32), max_new_tokens=2,
                         request_id=i) for i in range(6)]
    eng.serve(reqs)
    assert policy.controller.n_reports == len(eng.probe_observations)
    assert policy.controller.n_reports == eng.instances_started


def test_serving_engine_supports_platform_profiles():
    """PlatformProfile hosting knobs apply to serving replicas (gen2-style
    request concurrency + FIFO pool) — gained from the substrate."""
    from repro.serving.engine import MinosServingEngine, ServeRequest

    cfg = get_smoke_config("granite-moe-1b-a400m")
    prof = PlatformProfile.gcf_gen2(concurrency=2)
    eng = MinosServingEngine(
        cfg, MinosPolicy(elysium_threshold=float("inf"), enabled=False),
        Pricing.tpu_chip_seconds(4), seed=3, max_pool=4, profile=prof)
    assert eng.pool.order == "fifo"
    assert eng.pool.concurrency == 2
    reqs = [ServeRequest(prompt=np.arange(4, dtype=np.int32), max_new_tokens=2,
                         request_id=i) for i in range(3)]
    res = eng.serve(reqs)
    assert len(res) == 3


# ---------------------------------------------------------------------------
# Mixed-backend pipelines + admission bounds
# ---------------------------------------------------------------------------


def _det_spec(name, prepare_ms=50.0, body_ms=200.0, **kw):
    base = dict(
        name=name, prepare_ms=prepare_ms, prepare_jitter=0.0,
        body_ms=body_ms, body_jitter=0.0, benchmark_ms=20.0,
        benchmark_noise=0.0, cold_start_ms=10.0, cold_start_jitter=0.0,
        recycle_lifetime_ms=None, contention_rho=1.0,
    )
    base.update(kw)
    return FunctionSpec(**base)


def _disabled(stage):
    return MinosPolicy(elysium_threshold=float("inf"), enabled=False)


def test_mixed_backend_pipeline_fan_in():
    """Two simulated source stages fan into a serving sink; the serving
    request is built only after BOTH parents completed, and model outputs
    ride the item results."""
    from repro.serving.backend import ModelServingBackend, ServeRequest

    cfg = get_smoke_config("llama3.2-1b")
    backend = ModelServingBackend(cfg, seed=0, variation=VariationModel(sigma=0.0),
                                  weight_load_ms=100.0, name="gen")
    seen_parents = []

    def make_request(payload, parents):
        seen_parents.append(sorted(parents))
        assert all(p.t_completed_ms <= backendless_engine.loop.now
                   for p in parents.values())
        return ServeRequest(prompt=np.arange(4, dtype=np.int32), max_new_tokens=2)

    dag = WorkflowDAG([
        Stage(_det_spec("fetch_a", body_ms=100.0)),
        Stage(_det_spec("fetch_b", body_ms=300.0)),
        Stage(backend=backend, deps=("fetch_a", "fetch_b"),
              make_request=make_request),
    ], name="mixed")
    backendless_engine = WorkflowEngine(dag, VariationModel(sigma=0.0), _disabled,
                                        pricing=Pricing.tpu_chip_seconds(4), seed=0)
    run = run_workflow_batch(backendless_engine, n_items=3, inter_arrival_ms=50.0)
    assert run.n_items == 3
    assert seen_parents == [["fetch_a", "fetch_b"]] * 3
    for item in run.items:
        assert item.stage_results["gen"].output is not None
        assert len(item.stage_results["gen"].output) == 2
        # fan-in barrier: the sink started only after the slower parent
        assert (item.stage_results["gen"].t_submitted_ms
                >= item.stage_results["fetch_b"].t_completed_ms)


def test_max_in_flight_serializes_admission():
    """With max_in_flight=1, items enter the stage one at a time: each
    admission waits for the previous item's completion (back-pressure),
    and nothing is lost."""
    bounded = WorkflowDAG([Stage(_det_spec("slow", body_ms=500.0),
                                 max_in_flight=1)], name="bounded")
    eng = WorkflowEngine(bounded, VariationModel(sigma=0.0), _disabled,
                         pricing=PRICING, seed=0)
    run = run_workflow_batch(eng, n_items=4, inter_arrival_ms=0.0)
    assert run.n_items == 4
    assert eng.in_flight("slow") == 0
    assert eng.admission_queue_depth("slow") == 0
    rs = sorted(eng.platforms["slow"].results, key=lambda r: r.t_submitted_ms)
    for prev, nxt in zip(rs, rs[1:]):
        assert nxt.t_submitted_ms >= prev.t_completed_ms

    # same scenario unbounded: all four admitted immediately
    unbounded = WorkflowDAG([Stage(_det_spec("slow", body_ms=500.0))],
                            name="unbounded")
    eng2 = WorkflowEngine(unbounded, VariationModel(sigma=0.0), _disabled,
                          pricing=PRICING, seed=0)
    run2 = run_workflow_batch(eng2, n_items=4, inter_arrival_ms=0.0)
    assert run2.n_items == 4
    subs = [r.t_submitted_ms for r in eng2.platforms["slow"].results]
    assert max(subs) == min(subs)


def test_profile_on_backend_stage_keeps_replica_pool_cap():
    """A PlatformProfile overrides hosting knobs for backend-bound stages
    but must not silently drop the backend's replica-pool cap."""
    from repro.serving.backend import ModelServingBackend

    cfg = get_smoke_config("llama3.2-1b")
    backend = ModelServingBackend(cfg, model=object(), params={}, max_pool=2,
                                  name="gen")
    dag = WorkflowDAG([Stage(backend=backend)], name="one")
    eng = WorkflowEngine(dag, VariationModel(sigma=0.0), _disabled,
                         profile=PlatformProfile.gcf_gen2())
    assert eng.platforms["gen"].pool.max_size == 2
    assert eng.platforms["gen"].pool.order == "fifo"


def test_max_in_flight_validation():
    with pytest.raises(ValueError, match="max_in_flight"):
        Stage(_det_spec("x"), max_in_flight=0)
    with pytest.raises(ValueError, match="exactly one"):
        Stage()
