"""End-to-end behaviour: the complete Minos system on the paper's workload
and the serving integration — the top-level acceptance tests."""
import numpy as np

from repro.core import MinosPolicy, Pricing
from repro.sim import run_day
from repro.sim.variation import paper_week


def test_end_to_end_minos_beats_baseline_on_analysis_step():
    """The core paper claim, end to end: pre-test -> elysium threshold ->
    instance selection -> faster CPU-bound step, requests never lost."""
    day = run_day(0, paper_week(seed=0)[0], seed=0,
                  duration_ms=10 * 60 * 1000.0)
    assert day.minos.mean_analysis_ms < day.baseline.mean_analysis_ms
    assert day.minos.n_terminated > 0
    assert day.minos.warm_pool_mean_speed > 1.0 or np.isnan(
        day.minos.warm_pool_mean_speed)
    assert day.elysium_threshold > 0


def test_serving_integration_outputs_invariant():
    """Minos gating is performance-transparent: identical model outputs."""
    from repro.configs.registry import get_smoke_config
    from repro.serving.engine import MinosServingEngine, ServeRequest

    cfg = get_smoke_config("llama3.2-1b")
    reqs = [ServeRequest(prompt=np.arange(6, dtype=np.int32) % cfg.vocab,
                         max_new_tokens=3, request_id=i) for i in range(4)]
    out = {}
    for name, pol in (("base", MinosPolicy(0.0, enabled=False)),
                      ("minos", MinosPolicy(200.0 * 0.95, max_retries=5))):
        eng = MinosServingEngine(cfg, pol, Pricing.tpu_chip_seconds(1), seed=2)
        out[name] = eng.serve(list(reqs))
    for a, b in zip(out["base"], out["minos"]):
        np.testing.assert_array_equal(a.tokens, b.tokens)
