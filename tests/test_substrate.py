"""Data pipeline, optimizer, schedules, checkpointing, serving engine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import restore, save
from repro.configs.registry import get_smoke_config
from repro.core.cost import Pricing
from repro.core.policy import MinosPolicy
from repro.data.pipeline import (
    TokenStream,
    linear_regression,
    make_weather_csv,
    parse_weather_csv,
)
from repro.optim.adamw import AdamW
from repro.optim.schedule import warmup_cosine, warmup_linear


def test_token_stream_deterministic_and_structured():
    a = list(x for _, x in zip(range(2), TokenStream(128, 4, 32, seed=1)))
    b = list(x for _, x in zip(range(2), TokenStream(128, 4, 32, seed=1)))
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x["tokens"], y["tokens"])
    batch = a[0]
    assert batch["tokens"].shape == (4, 32)
    np.testing.assert_array_equal(batch["tokens"][:, 1:], batch["labels"][:, :-1])


def test_weather_csv_roundtrip_and_regression():
    csv = make_weather_csv(2000, seed=2)
    X, y = parse_weather_csv(csv)
    assert X.shape == (2000, 5)
    coef = linear_regression(X, y)
    np.testing.assert_allclose(coef[:4], [0.8, -3.0, 0.02, -0.1], atol=0.35)


def test_adamw_converges_quadratic():
    opt = AdamW(learning_rate=0.1, weight_decay=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = opt.init(params)

    @jax.jit
    def step(p, s):
        g = jax.grad(lambda q: jnp.sum(q["w"] ** 2))(p)
        return opt.update(g, s, p)

    for _ in range(200):
        params, state, m = step(params, state)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_adamw_clips_grad_norm():
    opt = AdamW(learning_rate=0.0, clip_norm=1.0)
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    _, _, m = opt.update({"w": jnp.full(3, 100.0)}, state, params)
    assert float(m["grad_norm"]) > 100.0  # reported pre-clip


def test_mixed_precision_master_weights():
    """bf16 params accumulate tiny updates via the fp32 master copy."""
    opt = AdamW(learning_rate=1e-5, weight_decay=0.0)
    params = {"w": jnp.ones(4, jnp.bfloat16)}
    state = opt.init(params)
    for _ in range(3):
        params, state, _ = opt.update({"w": jnp.ones(4, jnp.bfloat16)}, state, params)
    master = state.master["w"]
    assert master.dtype == jnp.float32
    assert float(jnp.abs(master - 1.0).max()) > 0.0  # master moved


def test_schedules():
    lr = warmup_cosine(1e-3, 10, 100)
    assert float(lr(0)) == 0.0
    assert float(lr(10)) == pytest.approx(1e-3, rel=1e-3)
    assert float(lr(100)) == pytest.approx(1e-4, rel=1e-2)
    lin = warmup_linear(1e-3, 10, 110)
    assert float(lin(110)) == pytest.approx(0.0, abs=1e-9)


def test_checkpoint_roundtrip_nested():
    tree = {
        "a": jnp.arange(6).reshape(2, 3),
        "b": {"c": jnp.ones(4, jnp.bfloat16), "d": [jnp.zeros(2), jnp.ones(1)]},
    }
    save("/tmp/test_ck.npz", tree)
    back = restore("/tmp/test_ck.npz", tree)
    flat_a, flat_b = jax.tree.leaves(tree), jax.tree.leaves(back)
    for x, y in zip(flat_a, flat_b):
        np.testing.assert_array_equal(np.asarray(x, np.float32), np.asarray(y, np.float32))


def test_serving_engine_minos_improves_pool():
    from repro.serving.engine import MinosServingEngine, ServeRequest

    cfg = get_smoke_config("granite-moe-1b-a400m")
    probe_work = 200.0
    reqs = [ServeRequest(prompt=np.arange(4, dtype=np.int32), max_new_tokens=2,
                         request_id=i) for i in range(6)]
    base = MinosServingEngine(
        cfg, MinosPolicy(elysium_threshold=0, enabled=False),
        Pricing.tpu_chip_seconds(4), seed=5, probe_work_ms=probe_work)
    gated = MinosServingEngine(
        cfg, MinosPolicy(elysium_threshold=probe_work * 0.98, max_retries=6),
        Pricing.tpu_chip_seconds(4), seed=5, probe_work_ms=probe_work)
    rb = base.serve(list(reqs))
    rg = gated.serve(list(reqs))
    assert len(rb) == len(rg) == 6
    for a, b in zip(rb, rg):
        np.testing.assert_array_equal(a.tokens, b.tokens)
    # the gate only admits replicas with speed >= ~1.02
    assert gated.warm_pool_speeds and all(s >= 1.0 for s in gated.warm_pool_speeds)
