"""PR 3: replica load as a first-class substrate concept, plus the
pool/queue correctness fixes that rode along.

* InstancePool.release applies the same reclaim filter as take (an
  instance past its recycle/idle deadline is never readmitted) and never
  kills an instance with requests in flight;
* per-queue sequence counters: engines in one process are isolated — each
  reproduces the ids and results of a solo run;
* ElysiumGate rejects the online_controller + non-dataclass-policy
  combination at construction;
* the load-slowdown model: body durations scale load**alpha, the default
  (alpha=0) is bit-for-bit the PR 2 idealized behavior, and the gate can
  judge probes at pool occupancy;
* the "spread" (least-loaded) pool order.
"""
import numpy as np
import pytest

from repro.core.cost import Pricing
from repro.core.lifecycle import FunctionInstance, InstanceState
from repro.core.policy import AdaptiveMinosPolicy, MinosPolicy, Verdict
from repro.core.queue import Invocation, InvocationQueue
from repro.core.substrate import ElysiumGate, InstancePool, SubstrateKnobs
from repro.sim import FaaSPlatform, FunctionSpec, PlatformProfile, VariationModel
from repro.sim.workload import run_closed_loop

PRICING = Pricing.gcf(256)


def _warm(speed=1.0, t=0.0, idle=1e9):
    inst = FunctionInstance(speed_factor=speed, created_at_ms=t, idle_timeout_ms=idle)
    inst.accept_without_benchmark()
    inst.last_used_ms = t
    return inst


# ---------------------------------------------------------------------------
# InstancePool.release reclaim filter (satellite bugfix)
# ---------------------------------------------------------------------------


def test_release_never_readmits_recycled_instance():
    rng = np.random.RandomState(0)
    pool = InstancePool(recycle_lifetime_ms=100.0, rng=rng)
    inst = _warm()
    pool.admit_cold(inst, now=0.0)
    deadline = pool._recycle_deadline[inst.instance_id]
    # the request finishes AFTER the platform's recycle deadline passed:
    # the instance must be reclaimed, not readmitted
    pool.release(inst, now=deadline + 1.0)
    assert pool.available == []
    assert inst.state is InstanceState.EXPIRED
    assert pool.speeds == []


def test_release_never_readmits_idle_expired_instance():
    pool = InstancePool()
    inst = _warm(idle=50.0)
    inst.last_used_ms = 0.0
    pool.add_warm(inst, in_flight=1)
    pool.release(inst, now=1000.0)  # idle deadline long gone
    assert pool.available == []
    assert inst.state is InstanceState.EXPIRED


def test_release_without_now_keeps_standalone_behavior():
    # pool used standalone (no clock): time-based reclaim is skipped
    pool = InstancePool()
    inst = _warm(idle=50.0)
    inst.last_used_ms = 0.0
    pool.add_warm(inst, in_flight=1)
    pool.release(inst)
    assert pool.available == [inst]


def test_release_on_full_pool_never_kills_inflight_instance():
    """per_instance_concurrency > 1: one of an instance's requests
    completing while the pool is at max_size must not despawn the instance
    under its remaining in-flight work (latent until load became real)."""
    pool = InstancePool(concurrency=2, max_size=1)
    busy, other = _warm(), _warm()
    pool.add_warm(other)
    pool.add_warm(busy, in_flight=2)
    pool.release(busy, now=0.0)          # 1 request still in flight
    assert busy.state is InstanceState.WARM
    assert pool.available == [other]     # stays out of the full list ...
    pool.release(busy, now=0.0)          # ... and only dies once drained
    assert busy.state is InstanceState.EXPIRED


def test_engine_run_has_no_zombie_pool_entries():
    """End-to-end regression: after a run with aggressive recycling, no
    pooled instance is past its recycle deadline (the bug inflated
    warm_pool_speeds until the next take)."""
    spec = FunctionSpec(name="churn", prepare_ms=50.0, body_ms=400.0,
                        benchmark_ms=50.0, recycle_lifetime_ms=2_000.0)
    plat = FaaSPlatform(spec, VariationModel(sigma=0.2),
                        MinosPolicy(elysium_threshold=60.0), PRICING, seed=5)
    run_closed_loop(plat, n_vus=4, think_time_ms=100.0, duration_ms=30_000.0)
    for inst in plat.pool.available:
        assert inst.state is InstanceState.WARM
        deadline = plat.pool._recycle_deadline.get(inst.instance_id)
        busy = plat.pool.load(inst) > 0
        # nothing was READMITTED past its recycle deadline: every pooled
        # idle instance last finished serving before the deadline (it may
        # legally sit idle past it until the next take sweeps it)
        assert busy or deadline is None or inst.last_used_ms < deadline


# ---------------------------------------------------------------------------
# Spread (least-loaded) pool order
# ---------------------------------------------------------------------------


def test_spread_order_picks_least_loaded():
    pool = InstancePool(order="spread", concurrency=4)
    a, b, c = _warm(speed=1.0), _warm(speed=2.0), _warm(speed=3.0)
    for inst, load in ((a, 2), (b, 0), (c, 1)):
        pool.add_warm(inst, in_flight=load)
    assert pool.take(0.0) is b      # load 0 beats 1 and 2
    assert pool.take(0.0) is b      # b now at 1, ties with c: first wins
    assert pool.take(0.0) is c      # b at 2 ties a; c at 1 is least
    assert pool.mean_load() == pytest.approx(2.0)  # loads now (2, 2, 2)


def test_pool_order_validation():
    with pytest.raises(ValueError, match="spread"):
        InstancePool(order="mru")
    with pytest.raises(ValueError, match="spread"):
        PlatformProfile(name="x", pricing=PRICING, warm_pool_order="mru")


# ---------------------------------------------------------------------------
# Per-queue sequence counters (satellite bugfix)
# ---------------------------------------------------------------------------


def test_invocation_ids_are_queue_local():
    q1, q2 = InvocationQueue(), InvocationQueue()
    a, b = Invocation(payload="a"), Invocation(payload="b")
    q1.push(a, 0.0)
    q2.push(b, 0.0)
    assert a.invocation_id == 0 and b.invocation_id == 0
    q1.requeue(a, 1.0)
    assert a.invocation_id == 0          # stable across requeues
    c = Invocation(payload="c")
    q1.push(c, 2.0)
    assert c.invocation_id == 1          # per-queue, not per-process


def _id_digest(seed=7):
    spec = FunctionSpec(name="iso", prepare_ms=100.0, body_ms=500.0,
                        benchmark_ms=80.0, recycle_lifetime_ms=10_000.0)
    plat = FaaSPlatform(spec, VariationModel(sigma=0.2),
                        MinosPolicy(elysium_threshold=100.0), PRICING, seed=seed)
    res = run_closed_loop(plat, n_vus=3, think_time_ms=200.0, duration_ms=20_000.0)
    return ([r.invocation_id for r in res],
            [round(r.latency_ms, 6) for r in res])


def test_engines_in_one_process_reproduce_solo_runs():
    """Two engines run back-to-back in one process produce identical
    seeded ids and results — under the old module-global counter the
    second engine's ids depended on how much the first had run."""
    first = _id_digest()
    second = _id_digest()
    assert first == second
    assert sorted(set(first[0])) == list(range(len(set(first[0]))))  # 0..n-1


# ---------------------------------------------------------------------------
# online_controller + adaptive policy rejected (satellite bugfix)
# ---------------------------------------------------------------------------


def test_online_controller_with_adaptive_policy_rejected():
    from repro.core.elysium import OnlineElysiumController

    ctl = OnlineElysiumController(pass_fraction=0.4)
    with pytest.raises(TypeError, match="online_controller requires a dataclass"):
        ElysiumGate(AdaptiveMinosPolicy(0.4), online_controller=ctl)
    # and at engine construction, through the public entry point
    spec = FunctionSpec(name="x")
    with pytest.raises(TypeError, match="dataclass"):
        FaaSPlatform(spec, VariationModel(), AdaptiveMinosPolicy(0.4),
                     PRICING, online_controller=ctl)
    # the valid combinations still construct
    ElysiumGate(MinosPolicy(elysium_threshold=1.0), online_controller=ctl)
    ElysiumGate(AdaptiveMinosPolicy(0.4))


# ---------------------------------------------------------------------------
# Load-slowdown model
# ---------------------------------------------------------------------------


def _det_spec(**kw):
    base = dict(
        name="det", prepare_ms=100.0, prepare_jitter=0.0, body_ms=1000.0,
        body_jitter=0.0, benchmark_ms=50.0, benchmark_noise=0.0,
        cold_start_ms=10.0, cold_start_jitter=0.0, recycle_lifetime_ms=None,
        contention_rho=1.0,
    )
    base.update(kw)
    return FunctionSpec(**base)


def _loaded_profile(alpha, concurrency=2, gate_load_aware=False):
    return PlatformProfile(
        name="loaded", pricing=PRICING, warm_pool_order="spread",
        per_instance_concurrency=concurrency, cold_start_ms=10.0,
        cold_start_jitter=0.0, recycle_lifetime_ms=None,
        load_slowdown_alpha=alpha, gate_load_aware=gate_load_aware,
    )


def _two_stream_run(alpha):
    """One warm instance, then two concurrent requests on it: the second
    take sees load 2."""
    plat = FaaSPlatform(_det_spec(), VariationModel(sigma=0.0),
                        MinosPolicy(elysium_threshold=float("inf"), enabled=False),
                        PRICING, seed=0, profile=_loaded_profile(alpha))
    done = []
    plat.submit(None, done.append)
    plat.loop.run_all()                      # instance is warm now
    plat.submit(None, done.append)
    plat.submit(None, done.append)
    plat.loop.run_all()
    return [r.analysis_ms for r in done]


def test_load_slowdown_scales_body_duration():
    idealized = _two_stream_run(alpha=0.0)
    loaded = _two_stream_run(alpha=0.7)
    # cold request + first warm stream run at load 1: unchanged
    assert loaded[0] == idealized[0]
    assert loaded[1] == idealized[1]
    # second concurrent stream pays 2**alpha
    assert loaded[2] == pytest.approx(idealized[2] * 2 ** 0.7)
    assert idealized[1] == idealized[2] == pytest.approx(1000.0)


def test_load_default_preserves_idealized_behavior_bit_for_bit():
    """alpha=0 (the default) is not merely 'close': per-request results are
    identical to the PR 2 idealized-concurrency engine. (The seeded golden
    digests in test_unified_substrate.py pin the same property on the
    calibrated scenarios; this pins it on a concurrency-2 profile.)"""
    spec = FunctionSpec(name="par", prepare_ms=80.0, body_ms=600.0,
                        benchmark_ms=70.0, recycle_lifetime_ms=20_000.0)

    def digest(profile):
        plat = FaaSPlatform(spec, VariationModel(sigma=0.15),
                            MinosPolicy(elysium_threshold=90.0), PRICING,
                            seed=11, profile=profile)
        res = run_closed_loop(plat, n_vus=4, think_time_ms=300.0,
                              duration_ms=30_000.0)
        return [(r.invocation_id, r.latency_ms, r.analysis_ms, r.retries)
                for r in res]

    explicit_zero = PlatformProfile(
        name="c2", pricing=PRICING, per_instance_concurrency=2,
        load_slowdown_alpha=0.0)
    default = PlatformProfile(
        name="c2", pricing=PRICING, per_instance_concurrency=2)
    assert digest(explicit_zero) == digest(default)


def test_gate_judges_effective_speed_under_load():
    inst = FunctionInstance(speed_factor=1.0)
    inst.run_benchmark(80.0)  # observed 80 ms
    gate = ElysiumGate(MinosPolicy(elysium_threshold=100.0))
    assert gate.judge(inst, 80.0, 0) is Verdict.PASS

    inst2 = FunctionInstance(speed_factor=1.0)
    inst2.run_benchmark(80.0)
    # at occupancy factor 1.5 the effective duration 120 ms fails the gate
    assert gate.judge(inst2, 80.0, 0, load_factor=1.5) is Verdict.TERMINATE
    assert inst2.benchmark_result == pytest.approx(120.0)
    # raw observations recorded (controller units stay unloaded)
    assert gate.observations == [80.0, 80.0]


def test_knobs_load_multiplier():
    k = SubstrateKnobs(load_slowdown_alpha=0.5)
    assert k.load_multiplier(1) == 1.0
    assert k.load_multiplier(4) == pytest.approx(2.0)
    assert SubstrateKnobs().load_multiplier(8) == 1.0


def test_profile_threads_load_knobs_to_engine():
    prof = _loaded_profile(alpha=0.6, concurrency=3, gate_load_aware=True)
    plat = FaaSPlatform(_det_spec(), VariationModel(sigma=0.0),
                        MinosPolicy(elysium_threshold=1.0), PRICING,
                        seed=0, profile=prof)
    assert plat.knobs.load_slowdown_alpha == 0.6
    assert plat.knobs.gate_load_aware is True
    assert plat.pool.concurrency == 3
    assert plat.pool.order == "spread"
